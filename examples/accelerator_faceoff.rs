//! Accelerator face-off: runs every (model, dataset) workload through the
//! GHOST simulator and all nine baseline roofline models, printing a
//! per-workload leaderboard — the data behind Figs. 10–12.
//!
//! ```bash
//! cargo run --release --example accelerator_faceoff [model] [dataset]
//! ```

use ghost::baselines::{platform_by_name, run_baseline, supports, PLATFORMS};
use ghost::config::GhostConfig;
use ghost::coordinator::{BatchEngine, OptFlags, SimRequest};
use ghost::gnn::models::{Model, ModelKind};
use ghost::gnn::workload::Workload;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model_filter = args.first().and_then(|m| ModelKind::by_name(m));
    let dataset_filter = args.get(1).cloned();

    let cfg = GhostConfig::paper_optimal();
    let flags = OptFlags::ghost_default();
    // One engine for the whole leaderboard: each dataset is generated and
    // partitioned once even though several models share it.
    let engine = BatchEngine::new();

    for kind in ModelKind::ALL {
        if model_filter.map(|m| m != kind).unwrap_or(false) {
            continue;
        }
        for ds_name in kind.datasets() {
            if dataset_filter
                .as_deref()
                .map(|d| !d.eq_ignore_ascii_case(ds_name))
                .unwrap_or(false)
            {
                continue;
            }
            let dataset = engine.dataset(ds_name).expect("table-2 dataset");
            let ghost_report = engine
                .run(&SimRequest::new(kind, ds_name, cfg, flags))
                .expect("simulation");
            let model = Model::for_dataset(kind, &dataset.spec);
            let w = Workload::characterize(&model, &dataset);

            println!("== {} / {} ==", kind.name(), ds_name);
            println!(
                "  {:<10} {:>12} {:>14} {:>12} {:>10}",
                "platform", "GOPS", "EPB (J/bit)", "latency", "vs GHOST"
            );
            println!(
                "  {:<10} {:>12.1} {:>14.2e} {:>9.2} us {:>10}",
                "GHOST",
                ghost_report.metrics.gops(),
                ghost_report.metrics.epb(),
                ghost_report.metrics.latency_s * 1e6,
                "--"
            );
            let mut rows: Vec<_> = PLATFORMS
                .iter()
                .filter(|p| supports(p.name, kind))
                .map(|p| (p.name, run_baseline(&platform_by_name(p.name).unwrap(), &w)))
                .collect();
            rows.sort_by(|a, b| b.1.gops().partial_cmp(&a.1.gops()).unwrap());
            for (name, m) in rows {
                println!(
                    "  {:<10} {:>12.2} {:>14.2e} {:>9.2} us {:>9.1}x",
                    name,
                    m.gops(),
                    m.epb(),
                    m.latency_s * 1e6,
                    ghost_report.metrics.gops() / m.gops()
                );
            }
            println!();
        }
    }
}
