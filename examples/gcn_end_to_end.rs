//! End-to-end validation driver: real GCN inference on the synthetic Cora
//! workload, executed through the full three-layer stack —
//!
//!   L1 Pallas photonic-array kernels → L2 JAX forward pass →
//!   AOT HLO artifact → L3 Rust coordinator executing via PJRT —
//!
//! reporting classification accuracy (vs the build-time JAX measurement),
//! PJRT wall latency, and the GHOST simulator's projected photonic
//! latency/energy for the same workload. Recorded in EXPERIMENTS.md §E2E.
//!
//! ```bash
//! make artifacts && cargo run --release --example gcn_end_to_end
//! ```

use ghost::config::GhostConfig;
use ghost::coordinator::{simulate, OptFlags};
use ghost::gnn::models::ModelKind;
use ghost::runtime::{argmax_rows, masked_accuracy, Engine};
use ghost::util::json::Json;

fn main() -> anyhow::Result<()> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("gcn_cora.json").exists() {
        eprintln!("artifacts missing: run `make artifacts` first");
        std::process::exit(1);
    }

    println!("== GHOST end-to-end: GCN / Cora ==\n");
    println!("[1/3] loading + compiling AOT artifact (HLO text -> PJRT)...");
    let t0 = std::time::Instant::now();
    let engine = Engine::load(&dir, "gcn_cora")?;
    println!("      compiled on '{}' in {:.2?}", engine.platform(), t0.elapsed());
    println!(
        "      {} executable inputs ({} data + weights), int8 photonic quantization",
        engine.manifest.inputs.len(),
        3
    );

    println!("[2/3] executing inference over all 2708 vertices...");
    let mut best = f64::INFINITY;
    let mut outputs = None;
    for rep in 0..5 {
        let t = std::time::Instant::now();
        let out = engine.run()?;
        let dt = t.elapsed().as_secs_f64();
        best = best.min(dt);
        if rep == 0 {
            outputs = Some(out);
        }
    }
    let outputs = outputs.unwrap();
    let logits = outputs[0].as_f32()?;
    let shape = outputs[0].shape().to_vec();
    let labels = engine.extra("labels")?;
    let test_mask = engine.extra("test_mask")?;
    let pred = argmax_rows(logits, shape[0], shape[1]);
    let acc = masked_accuracy(&pred, labels.as_i32()?, Some(test_mask.as_i32()?));
    let expected = engine.manifest.meta.get("acc_int8").and_then(Json::as_f64).unwrap_or(0.0);
    println!("      test accuracy : {:.2}% (build-time JAX int8: {:.2}%)", acc * 100.0, expected * 100.0);
    println!("      PJRT latency  : {:.2} ms best-of-5 (CPU interpret substrate)", best * 1e3);
    assert!((acc - expected).abs() < 0.02, "functional path diverged from build-time model");

    println!("[3/3] projecting the same workload on the photonic architecture...");
    let sim = simulate(ModelKind::Gcn, "Cora", GhostConfig::paper_optimal(), OptFlags::ghost_default())
        .map_err(anyhow::Error::msg)?;
    println!(
        "      GHOST simulator: {:.1} us, {:.3} mJ, {:.0} GOPS at {:.1} W",
        sim.metrics.latency_s * 1e6,
        sim.metrics.energy_j * 1e3,
        sim.metrics.gops(),
        sim.metrics.power_w()
    );
    println!("\nall layers composed: kernels -> JAX -> HLO -> PJRT -> coordinator OK");
    Ok(())
}
