//! Design-space exploration walkthrough: re-derives the paper's device
//! feasibility frontiers (Figs. 7a/7b) and architecture optimum (Fig. 7c),
//! then shows what happens to a config that violates the device limits.
//!
//! ```bash
//! cargo run --release --example dse_explore
//! ```

use ghost::config::GhostConfig;
use ghost::coordinator::dse as arch_dse;
use ghost::coordinator::BatchEngine;
use ghost::photonics::devices::DeviceParams;
use ghost::photonics::dse as device_dse;
use ghost::photonics::snr::required_snr_db;
use ghost::photonics::mr::MicroringDesign;

fn main() {
    let p = DeviceParams::paper();
    let mr = MicroringDesign::paper();

    println!("== device level ==");
    println!(
        "SNR cutoff (eq. 12, Q={}, 2^7 levels): {:.1} dB (paper: 21.3 dB)",
        mr.q_factor,
        required_snr_db(&mr, ghost::config::N_LEVELS)
    );
    println!("\ncoherent summation chains (Fig. 7a):");
    for lambda in [1520.0, 1540.0, 1560.0] {
        println!(
            "  {:.0} nm: up to {} MRs",
            lambda,
            device_dse::max_feasible_coherent(&p, lambda, 40)
        );
    }
    println!("non-coherent WDM banks (Fig. 7b):");
    println!(
        "  up to {} wavelengths at 1 nm spacing from 1550 nm",
        device_dse::max_feasible_noncoherent(30)
    );

    println!("\n== architecture level (Fig. 7c, quick workload set) ==");
    let grid = arch_dse::default_grid();
    let workloads = arch_dse::workload_set(true).expect("table-2 workload set");
    let engine = BatchEngine::new();
    let report = arch_dse::explore_with_engine(&engine, &grid, &workloads);
    let points = &report.points;
    println!("swept {} feasible configurations; top 5 by EPB/GOPS:", points.len());
    for (i, pt) in points.iter().take(5).enumerate() {
        println!(
            "  #{} [N={}, V={}, Rr={}, Rc={}, Tr={}]  EPB/GOPS {:.3e}  ({:.0} GOPS)",
            i + 1,
            pt.cfg.n,
            pt.cfg.v,
            pt.cfg.r_r,
            pt.cfg.r_c,
            pt.cfg.t_r,
            pt.epb_per_gops,
            pt.gops
        );
    }
    let paper = GhostConfig::paper_optimal();
    if let Some(rank) = points.iter().position(|pt| pt.cfg == paper) {
        println!("paper optimum [20,20,18,7,17] ranks #{} of {}", rank + 1, points.len());
    }
    println!(
        "partition sets built once per (dataset, V, N): {} (grid points: {})",
        engine.partition_builds(),
        grid.len()
    );
    if !report.failures.is_empty() {
        println!("{} point(s) failed or were filtered:", report.failures.len());
        for f in report.failures.iter().take(5) {
            println!("  {:?}: {}", f.cfg, f.error);
        }
    }

    println!("\n== device limits enforced ==");
    let infeasible = GhostConfig { r_c: 25, ..paper };
    match infeasible.validate() {
        Err(e) => println!("R_c=25 rejected: {e}"),
        Ok(()) => unreachable!(),
    }
    // An infeasible point inside a sweep degrades to a recorded failure,
    // never a process abort.
    let sweep = arch_dse::explore_with_engine(&engine, &[paper, infeasible], &workloads);
    println!(
        "sweep over [paper, infeasible]: {} point(s), {} recorded failure(s)",
        sweep.points.len(),
        sweep.failures.len()
    );
}
