//! GAT inference through the functional PJRT datapath: loads the 8-head
//! graph-attention artifact for Citeseer, runs inference, reports accuracy
//! at int8, and contrasts the simulator's GAT execution ordering
//! (transform-first, §3.4.2) against the GCN ordering on the same graph.
//!
//! ```bash
//! make artifacts && cargo run --release --example gat_inference
//! ```

use ghost::config::GhostConfig;
use ghost::coordinator::{simulate, OptFlags};
use ghost::gnn::models::ModelKind;
use ghost::runtime::{argmax_rows, masked_accuracy, Engine};

fn main() -> anyhow::Result<()> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("gat_citeseer.json").exists() {
        eprintln!("artifacts missing: run `make artifacts` first");
        std::process::exit(1);
    }

    println!("== GAT (8 heads -> 1 head) on Citeseer ==\n");
    let engine = Engine::load(&dir, "gat_citeseer")?;
    let t0 = std::time::Instant::now();
    let outputs = engine.run()?;
    let wall = t0.elapsed();
    let logits = outputs[0].as_f32()?;
    let shape = outputs[0].shape().to_vec();
    let labels = engine.extra("labels")?;
    let mask = engine.extra("test_mask")?;
    let pred = argmax_rows(logits, shape[0], shape[1]);
    let acc = masked_accuracy(&pred, labels.as_i32()?, Some(mask.as_i32()?));
    println!("logits {shape:?}, test accuracy {:.2}%, PJRT wall {:.2?}", acc * 100.0, wall);

    let cfg = GhostConfig::paper_optimal();
    let flags = OptFlags::ghost_default();
    let gat = simulate(ModelKind::Gat, "Citeseer", cfg, flags).map_err(anyhow::Error::msg)?;
    let gcn = simulate(ModelKind::Gcn, "Citeseer", cfg, flags).map_err(anyhow::Error::msg)?;

    println!("\nsimulated on the photonic architecture:");
    let (ga, gc, gu) = gat.breakdown();
    let (ca, cc, cu) = gcn.breakdown();
    println!(
        "  GAT (transform-first): {:.1} us | agg {:.0}% comb {:.0}% upd {:.0}%",
        gat.metrics.latency_s * 1e6,
        ga * 100.0,
        gc * 100.0,
        gu * 100.0
    );
    println!(
        "  GCN (aggregate-first): {:.1} us | agg {:.0}% comb {:.0}% upd {:.0}%",
        gcn.metrics.latency_s * 1e6,
        ca * 100.0,
        cc * 100.0,
        cu * 100.0
    );
    println!(
        "\nGAT shifts the bottleneck from aggregation to combine/update\n\
         (8 attention heads + per-edge digital softmax), matching Fig. 9."
    );
    Ok(())
}
