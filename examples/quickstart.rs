//! Quickstart: simulate GCN inference on Cora through the GHOST
//! accelerator and print the headline metrics.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use ghost::config::GhostConfig;
use ghost::coordinator::{simulate, OptFlags};
use ghost::gnn::models::ModelKind;

fn main() {
    // The paper's DSE-optimal configuration [N,V,Rr,Rc,Tr] = [20,20,18,7,17]
    // with GHOST's shipping optimizations (BP + PP + weight-DAC sharing).
    let cfg = GhostConfig::paper_optimal();
    let flags = OptFlags::ghost_default();

    println!("GHOST quickstart: GCN on Cora (2-layer, 8-bit photonic datapath)\n");
    let report = simulate(ModelKind::Gcn, "Cora", cfg, flags).expect("simulation");

    println!("configuration : [N,V,Rr,Rc,Tr] = [{}, {}, {}, {}, {}]",
        cfg.n, cfg.v, cfg.r_r, cfg.r_c, cfg.t_r);
    println!("optimizations : {}", report.flags.label());
    println!("latency       : {:.1} us", report.metrics.latency_s * 1e6);
    println!("energy        : {:.3} mJ", report.metrics.energy_j * 1e3);
    println!("power         : {:.1} W (the paper quotes ~18 W)", report.metrics.power_w());
    println!("throughput    : {:.0} GOPS", report.metrics.gops());
    println!("EPB           : {:.2e} J/bit", report.metrics.epb());
    let (agg, comb, upd) = report.breakdown();
    println!(
        "block shares  : aggregate {:.0}% | combine {:.0}% | update {:.0}%",
        agg * 100.0,
        comb * 100.0,
        upd * 100.0
    );

    // Toggling the optimizations off shows what the §3.4 machinery buys.
    let baseline = simulate(ModelKind::Gcn, "Cora", cfg, OptFlags::baseline()).unwrap();
    println!(
        "\nwithout optimizations: {:.1} us, {:.3} mJ ({:.1}x more energy)",
        baseline.metrics.latency_s * 1e6,
        baseline.metrics.energy_j * 1e3,
        baseline.metrics.energy_j / report.metrics.energy_j
    );
}
