"""Synthetic dataset invariants: Table-2 statistics, determinism, padded
neighbor-table validity, and the label signal the GNNs learn from."""

import numpy as np
import pytest

from compile import datasets as D


def test_all_eight_specs_present():
    assert len(D.SPECS) == 8
    for name in ["cora", "pubmed", "citeseer", "amazon", "proteins", "mutag", "bzr", "imdb-binary"]:
        assert name in D.SPECS


def test_table2_row_values():
    s = D.SPECS["cora"]
    assert (s.avg_nodes, s.avg_edges, s.n_features, s.n_labels, s.n_graphs) == (
        2708,
        10_556,
        1433,
        7,
        1,
    )
    s = D.SPECS["imdb-binary"]
    assert (s.avg_nodes, s.avg_edges, s.n_graphs) == (20, 193, 1000)


def test_node_dataset_shapes_and_masks():
    ds = D.make_node_dataset("cora")
    n, f = ds.spec.avg_nodes, ds.spec.n_features
    assert ds.x.shape == (n, f)
    assert ds.labels.shape == (n,)
    assert ds.nbr_idx.shape == (n, D.NODE_DEGREE_CAP)
    assert ds.nbr_mask.shape == (n, D.NODE_DEGREE_CAP)
    assert ds.labels.min() >= 0 and ds.labels.max() < ds.spec.n_labels
    # Padding entries point at the vertex itself (in-bounds gathers).
    pad = ds.nbr_mask == 0
    rows = np.arange(n)[:, None].repeat(D.NODE_DEGREE_CAP, 1)
    np.testing.assert_array_equal(ds.nbr_idx[pad], rows[pad])
    # Train/test masks are disjoint and non-trivial.
    assert int((ds.train_mask & ds.test_mask).sum()) == 0
    assert ds.train_mask.sum() > n // 3
    assert ds.test_mask.sum() > n // 10


def test_edge_count_close_to_spec():
    ds = D.make_node_dataset("citeseer")
    assert abs(len(ds.edges) - ds.spec.avg_edges) / ds.spec.avg_edges < 0.02


def test_determinism():
    a = D.make_node_dataset("cora")
    b = D.make_node_dataset("cora")
    np.testing.assert_array_equal(a.x, b.x)
    np.testing.assert_array_equal(a.nbr_idx, b.nbr_idx)
    np.testing.assert_array_equal(a.labels, b.labels)


def test_homophily_present():
    ds = D.make_node_dataset("cora")
    src = np.array([s for s, _ in ds.edges])
    dst = np.array([d for _, d in ds.edges])
    same = (ds.labels[src] == ds.labels[dst]).mean()
    assert same > 0.5, f"homophily {same} too low for GNN signal"


def test_graph_dataset_shapes():
    ds = D.make_graph_dataset("mutag")
    b = ds.spec.n_graphs
    assert ds.x.shape[0] == b
    assert ds.labels.shape == (b,)
    assert ds.nbr_idx.shape[:2] == ds.x.shape[:2]
    # Masked-out padding nodes have zero features.
    dead = ds.node_mask == 0
    assert np.abs(ds.x[dead]).max() == 0.0
    # Graph sizes vary (irregular corpus).
    sizes = ds.node_mask.sum(axis=1)
    assert sizes.std() > 0.5


def test_graph_labels_balanced_enough():
    ds = D.make_graph_dataset("proteins")
    frac = ds.labels.mean()
    assert 0.3 < frac < 0.7


@pytest.mark.parametrize("name", ["proteins", "mutag", "bzr", "imdb-binary"])
def test_loader_dispatch(name):
    ds = D.load(name)
    assert isinstance(ds, D.GraphDataset)


def test_loader_dispatch_node():
    assert isinstance(D.load("cora"), D.NodeDataset)
