"""Quantization properties — must mirror rust/src/gnn/quant.rs exactly."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.quant import N_LEVELS, fake_quantize, quantize_int, scale_for


def test_n_levels_is_128():
    assert N_LEVELS == 128


def test_zero_tensor_round_trips():
    z = np.zeros(16, dtype=np.float32)
    np.testing.assert_array_equal(np.asarray(fake_quantize(z)), z)


def test_extremes_hit_full_scale():
    x = np.array([-2.0, 0.0, 2.0], dtype=np.float32)
    q, s = quantize_int(x)
    assert int(q[0]) == -127 and int(q[2]) == 127 and int(q[1]) == 0
    assert abs(float(s) - 2.0 / 127) < 1e-7


def test_error_bounded_by_half_step():
    rng = np.random.default_rng(7)
    x = (rng.standard_normal(1000) * 3).astype(np.float32)
    s = float(scale_for(x))
    err = np.abs(np.asarray(fake_quantize(x)) - x)
    assert err.max() <= s / 2 + 1e-6


def test_idempotent():
    rng = np.random.default_rng(9)
    x = rng.standard_normal(64).astype(np.float32)
    once = np.asarray(fake_quantize(x))
    twice = np.asarray(fake_quantize(once))
    np.testing.assert_allclose(once, twice, rtol=0, atol=1e-7)


@settings(max_examples=50, deadline=None)
@given(st.lists(st.floats(-1e3, 1e3, allow_nan=False), min_size=1, max_size=64))
def test_hypothesis_error_bound(values):
    x = np.asarray(values, dtype=np.float32)
    s = float(scale_for(x))
    err = np.abs(np.asarray(fake_quantize(x)) - x)
    assert err.max() <= s / 2 + 1e-4 * max(1.0, np.abs(x).max())


def test_matches_rust_convention():
    # A vector whose quantization is easy to verify by hand, pinned so the
    # Rust mirror (gnn::quant tests) and this file agree forever.
    x = np.array([1.0, -0.5, 0.25, 0.0], dtype=np.float32)
    q, s = quantize_int(x)
    assert abs(float(s) - 1.0 / 127) < 1e-7
    assert list(np.asarray(q)) == [127, -64, 32, 0]
