"""L2 model correctness: kernel path ≡ oracle path, shape contracts, and
learnability smoke (a few Adam steps reduce the loss)."""

import numpy as np
import pytest

from compile import model as M

RNG = np.random.default_rng(0xD00D)


def tiny_graph(n=23, d=6, f=12):
    x = RNG.standard_normal((n, f)).astype(np.float32)
    nbr_idx = RNG.integers(0, n, size=(n, d)).astype(np.int32)
    nbr_mask = (RNG.random((n, d)) < 0.7).astype(np.float32)
    return x, nbr_idx, nbr_mask


def tiny_batch(b=4, n=9, d=4, f=7):
    x = RNG.standard_normal((b, n, f)).astype(np.float32)
    nbr_idx = RNG.integers(0, n, size=(b, n, d)).astype(np.int32)
    nbr_mask = (RNG.random((b, n, d)) < 0.7).astype(np.float32)
    node_mask = np.ones((b, n), dtype=np.float32)
    node_mask[:, -2:] = 0.0
    return x, nbr_idx, nbr_mask, node_mask


@pytest.mark.parametrize("model", ["gcn", "graphsage", "gat"])
@pytest.mark.parametrize("quantized", [False, True])
def test_node_models_kernel_path_matches_ref(model, quantized):
    x, idx, mask = tiny_graph()
    params = M.init_params(model, np.random.default_rng(3), x.shape[1], 5)
    fwd = M.forward_fn(model)
    (a,) = fwd(params, x, idx, mask, quantized=quantized, use_kernels=True)
    (b,) = fwd(params, x, idx, mask, quantized=quantized, use_kernels=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("quantized", [False, True])
def test_gin_kernel_path_matches_ref(quantized):
    x, idx, mask, node_mask = tiny_batch()
    params = M.init_params("gin", np.random.default_rng(5), x.shape[2], 2)
    (a,) = M.gin_forward(params, x, idx, mask, node_mask, quantized=quantized, use_kernels=True)
    (b,) = M.gin_forward(params, x, idx, mask, node_mask, quantized=quantized, use_kernels=False)
    assert a.shape == (4, 2)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-5)


def test_output_shapes():
    x, idx, mask = tiny_graph(n=23, f=12)
    for model, labels in [("gcn", 5), ("graphsage", 3), ("gat", 4)]:
        params = M.init_params(model, np.random.default_rng(1), 12, labels)
        (logits,) = M.forward_fn(model)(params, x, idx, mask, use_kernels=False)
        assert logits.shape == (23, labels), model


def test_gat_heads_shape_contract():
    params = M.init_params("gat", np.random.default_rng(2), 12, 4)
    assert params["w0"].shape == (12, M.GAT_HEADS * M.GAT_HEAD_DIM)
    assert params["a_src0"].shape == (M.GAT_HEADS, M.GAT_HEAD_DIM)
    assert params["w1"].shape == (M.GAT_HEADS * M.GAT_HEAD_DIM, 4)


def test_gin_has_eight_mlp_layers():
    params = M.init_params("gin", np.random.default_rng(4), 7, 2)
    mlp_keys = [k for k in params if k.startswith("mlp")]
    assert len(mlp_keys) == 8  # 2 convs × 4-layer MLPs (paper §4.1)


def test_attention_blockdiag_structure():
    a = np.arange(6, dtype=np.float32).reshape(2, 3)  # H=2, d=3
    bd = np.asarray(M._attn_blockdiag(a))
    assert bd.shape == (6, 2)
    # Column h only touches rows of head h.
    np.testing.assert_array_equal(bd[:3, 1], 0)
    np.testing.assert_array_equal(bd[3:, 0], 0)
    np.testing.assert_array_equal(bd[:3, 0], a[0])
    np.testing.assert_array_equal(bd[3:, 1], a[1])


def test_few_training_steps_reduce_loss():
    import jax
    import jax.numpy as jnp
    from compile.train import _adam_init, _adam_step, _cross_entropy

    x, idx, mask = tiny_graph(n=40, f=10)
    labels = jnp.asarray(RNG.integers(0, 3, size=40).astype(np.int32))
    train_mask = jnp.ones(40, dtype=jnp.float32)
    params = M.init_params("gcn", np.random.default_rng(8), 10, 3)

    def loss_fn(p):
        (logits,) = M.gcn_forward(p, x, idx, mask, quantized=False, use_kernels=False)
        return _cross_entropy(logits, labels, train_mask)

    l0 = float(loss_fn(params))
    state = _adam_init(params)
    for _ in range(30):
        grads = jax.grad(loss_fn)(params)
        params, state = _adam_step(params, grads, state, lr=0.05)
    l1 = float(loss_fn(params))
    assert l1 < l0 * 0.9, f"loss {l0} -> {l1}"
