"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

This is the core correctness signal of the functional path — including
hypothesis sweeps over shapes (padding/masking edge cases) and all three
reduce modes.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.coherent_reduce import (
    coherent_reduce,
    coherent_reduce_batched,
)
from compile.kernels.photonic_mvm import photonic_mvm, photonic_mvm_batched
from compile.kernels import ref

RNG = np.random.default_rng(0xBEEF)


def rand(*shape):
    return RNG.standard_normal(shape).astype(np.float32)


# ------------------------------------------------------------ photonic_mvm


class TestPhotonicMvm:
    def test_matches_ref_fp32(self):
        x, w = rand(40, 36), rand(36, 34)
        np.testing.assert_allclose(
            photonic_mvm(x, w, quantized=False), ref.mvm_ref(x, w, quantized=False), rtol=1e-4, atol=1e-4
        )

    def test_matches_ref_quantized(self):
        x, w = rand(40, 36), rand(36, 34)
        np.testing.assert_allclose(
            photonic_mvm(x, w, quantized=True), ref.mvm_ref(x, w, quantized=True), rtol=1e-4, atol=1e-4
        )

    def test_non_divisible_shapes_padded_correctly(self):
        # Shapes deliberately coprime with (V=20, R_R=18, T_R=17).
        x, w = rand(7, 5), rand(5, 3)
        np.testing.assert_allclose(
            photonic_mvm(x, w, quantized=False), ref.mvm_ref(x, w, quantized=False), rtol=1e-4, atol=1e-4
        )

    def test_quantization_changes_result_but_bounded(self):
        x, w = rand(30, 50), rand(50, 10)
        exact = np.asarray(ref.mvm_ref(x, w, quantized=False))
        quant = np.asarray(photonic_mvm(x, w, quantized=True))
        err = np.abs(exact - quant).max()
        assert err > 0, "int8 quantization must be visible"
        # Worst-case error bound: k × (|x|max·sw/2 + |w|max·sx/2 + sx·sw/4).
        sx = np.abs(x).max() / 127
        sw = np.abs(w).max() / 127
        bound = 50 * (np.abs(x).max() * sw / 2 + np.abs(w).max() * sx / 2 + sx * sw / 4) * 1.1
        assert err < bound, f"err {err} above bound {bound}"

    def test_batched(self):
        x, w = rand(3, 11, 9), rand(9, 6)
        out = photonic_mvm_batched(x, w, quantized=False)
        assert out.shape == (3, 11, 6)
        for b in range(3):
            np.testing.assert_allclose(
                out[b], ref.mvm_ref(x[b], w, quantized=False), rtol=1e-4, atol=1e-4
            )

    @settings(max_examples=20, deadline=None)
    @given(
        m=st.integers(1, 45),
        k=st.integers(1, 40),
        n=st.integers(1, 38),
        quantized=st.booleans(),
    )
    def test_hypothesis_shape_sweep(self, m, k, n, quantized):
        x, w = rand(m, k), rand(k, n)
        np.testing.assert_allclose(
            photonic_mvm(x, w, quantized=quantized),
            ref.mvm_ref(x, w, quantized=quantized),
            rtol=1e-4,
            atol=1e-5,
        )


# --------------------------------------------------------- coherent_reduce


class TestCoherentReduce:
    @pytest.mark.parametrize("op", ["sum", "mean", "max"])
    def test_matches_ref(self, op):
        g = rand(25, 9, 21)
        mask = (RNG.random((25, 9)) < 0.6).astype(np.float32)
        np.testing.assert_allclose(
            coherent_reduce(g, mask, op=op), ref.reduce_ref(g, mask, op=op), rtol=1e-4, atol=1e-4
        )

    def test_all_masked_vertex(self):
        g = rand(5, 4, 6)
        mask = np.zeros((5, 4), dtype=np.float32)
        for op in ["sum", "mean", "max"]:
            out = np.asarray(coherent_reduce(g, mask, op=op))
            np.testing.assert_allclose(out, 0.0, atol=1e-6)

    def test_single_neighbor(self):
        g = rand(8, 1, 5)
        mask = np.ones((8, 1), dtype=np.float32)
        np.testing.assert_allclose(
            coherent_reduce(g, mask, op="mean"), g[:, 0, :], rtol=1e-4, atol=1e-4
        )
        np.testing.assert_allclose(
            coherent_reduce(g, mask, op="max"), g[:, 0, :], rtol=1e-4, atol=1e-4
        )

    def test_batched(self):
        g = rand(2, 6, 5, 7)
        mask = (RNG.random((2, 6, 5)) < 0.7).astype(np.float32)
        out = coherent_reduce_batched(g, mask, op="sum")
        assert out.shape == (2, 6, 7)
        np.testing.assert_allclose(out, ref.reduce_ref(g, mask, op="sum"), rtol=1e-4, atol=1e-4)

    @settings(max_examples=15, deadline=None)
    @given(
        n=st.integers(1, 30),
        d=st.integers(1, 16),
        f=st.integers(1, 25),
        op=st.sampled_from(["sum", "mean", "max"]),
        density=st.floats(0.0, 1.0),
    )
    def test_hypothesis_shape_sweep(self, n, d, f, op, density):
        g = rand(n, d, f)
        mask = (RNG.random((n, d)) < density).astype(np.float32)
        np.testing.assert_allclose(
            coherent_reduce(g, mask, op=op),
            ref.reduce_ref(g, mask, op=op),
            rtol=1e-4,
            atol=1e-5,
        )

    def test_mean_equals_sum_over_count(self):
        g = rand(10, 6, 4)
        mask = np.ones((10, 6), dtype=np.float32)
        s = np.asarray(coherent_reduce(g, mask, op="sum"))
        m = np.asarray(coherent_reduce(g, mask, op="mean"))
        np.testing.assert_allclose(m, s / 6.0, rtol=1e-4, atol=1e-4)
