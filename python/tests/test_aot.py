"""AOT pipeline smoke: lowering a kernel-path model produces parseable HLO
text, and the BinWriter offsets line up with the manifest contract."""

import jax
import jax.numpy as jnp
import numpy as np

from compile.aot import BinWriter, to_hlo_text
from compile.kernels.photonic_mvm import photonic_mvm


def test_to_hlo_text_smoke():
    def fn(x, w):
        return (photonic_mvm(x, w, quantized=True),)

    spec = jax.ShapeDtypeStruct((8, 6), jnp.float32)
    wspec = jax.ShapeDtypeStruct((6, 4), jnp.float32)
    lowered = jax.jit(fn).lower(spec, wspec)
    hlo = to_hlo_text(lowered)
    assert "HloModule" in hlo
    assert "f32[8,6]" in hlo  # parameter shape survives
    assert "ROOT" in hlo


def test_binwriter_offsets_and_roundtrip(tmp_path):
    w = BinWriter("data")
    a = np.arange(6, dtype=np.float32).reshape(2, 3)
    b = np.arange(4, dtype=np.int32)
    ea = w.add("a", a)
    eb = w.add("b", b)
    assert ea["offset"] == 0 and ea["dtype"] == "f32" and ea["shape"] == [2, 3]
    assert eb["offset"] == 24 and eb["dtype"] == "i32"
    path = tmp_path / "t.bin"
    w.write(str(path))
    raw = path.read_bytes()
    assert len(raw) == 24 + 16
    back_a = np.frombuffer(raw[:24], dtype=np.float32).reshape(2, 3)
    back_b = np.frombuffer(raw[24:], dtype=np.int32)
    np.testing.assert_array_equal(back_a, a)
    np.testing.assert_array_equal(back_b, b)


def test_lowered_hlo_is_deterministic():
    def fn(x, w):
        return (photonic_mvm(x, w, quantized=True),)

    spec = jax.ShapeDtypeStruct((5, 5), jnp.float32)
    l1 = to_hlo_text(jax.jit(fn).lower(spec, spec))
    l2 = to_hlo_text(jax.jit(fn).lower(spec, spec))
    assert l1 == l2
