"""System-level validation of the SNR design point (Fig. 7 ↔ Table 3):
at the eq.-12 cutoff (~21.2 dB) GNN accuracy is preserved; far below it,
inference collapses toward chance."""

import numpy as np
import pytest

from compile import model as M
from compile.noise import noisy_gcn_forward, snr_to_sigma

RNG = np.random.default_rng(0x51C)


def _toy_task(n=300, f=32, classes=4, deg=6):
    emb = RNG.standard_normal((classes, f)).astype(np.float32)
    labels = RNG.integers(0, classes, size=n).astype(np.int32)
    x = (emb[labels] + 0.7 * RNG.standard_normal((n, f))).astype(np.float32)
    # Homophilous neighbors: mostly same-class.
    nbr_idx = np.zeros((n, deg), dtype=np.int32)
    for v in range(n):
        pool = np.flatnonzero(labels == labels[v])
        nbr_idx[v] = RNG.choice(pool, size=deg)
    nbr_mask = np.ones((n, deg), dtype=np.float32)
    return x, labels, nbr_idx, nbr_mask


def _train_gcn(x, labels, nbr_idx, nbr_mask, epochs=60):
    import jax
    import jax.numpy as jnp
    from compile.train import _adam_init, _adam_step, _cross_entropy

    params = M.init_params("gcn", np.random.default_rng(1), x.shape[1], int(labels.max()) + 1)
    mask = jnp.ones(len(labels), dtype=jnp.float32)
    yl = jnp.asarray(labels)

    def loss_fn(p):
        (logits,) = M.gcn_forward(p, x, nbr_idx, nbr_mask, quantized=False, use_kernels=False)
        return _cross_entropy(logits, yl, mask)

    state = _adam_init(params)
    for _ in range(epochs):
        grads = jax.grad(loss_fn)(params)
        params, state = _adam_step(params, grads, state, lr=0.02)
    return params


def _acc(logits, labels):
    return float((np.asarray(logits).argmax(-1) == labels).mean())


@pytest.fixture(scope="module")
def trained():
    x, labels, idx, mask = _toy_task()
    params = _train_gcn(x, labels, idx, mask)
    (clean,) = M.gcn_forward(params, x, idx, mask, quantized=True, use_kernels=False)
    return params, x, labels, idx, mask, _acc(clean, labels)


def test_snr_sigma_conversion():
    assert abs(snr_to_sigma(20.0) - 0.1) < 1e-9
    assert abs(snr_to_sigma(0.0) - 1.0) < 1e-9
    assert snr_to_sigma(40.0) < snr_to_sigma(10.0)


def test_design_point_snr_preserves_accuracy(trained):
    params, x, labels, idx, mask, clean_acc = trained
    assert clean_acc > 0.85, f"toy task must be learnable, got {clean_acc}"
    (noisy,) = noisy_gcn_forward(params, x, idx, mask, snr_db=21.3)
    acc = _acc(noisy, labels)
    assert acc > clean_acc - 0.05, f"design-point SNR degraded accuracy: {clean_acc} -> {acc}"


def test_low_snr_destroys_accuracy(trained):
    params, x, labels, idx, mask, clean_acc = trained
    (noisy,) = noisy_gcn_forward(params, x, idx, mask, snr_db=-5.0)
    acc = _acc(noisy, labels)
    assert acc < clean_acc - 0.15, f"SNR -5 dB should collapse accuracy ({clean_acc} -> {acc})"


def test_accuracy_monotone_in_snr(trained):
    params, x, labels, idx, mask, _ = trained
    accs = []
    for snr in [-5.0, 5.0, 21.3, 40.0]:
        (noisy,) = noisy_gcn_forward(params, x, idx, mask, snr_db=snr, seed=7)
        accs.append(_acc(noisy, labels))
    # Allow small non-monotonic wiggle at the top; overall trend must rise.
    assert accs[0] < accs[2], f"accuracy vs SNR not increasing: {accs}"
    assert accs[1] <= accs[3] + 0.03, f"accuracy vs SNR not increasing: {accs}"
