"""Photonic analog-noise injection — ties the device-level SNR analysis to
end-to-end model accuracy.

The Fig. 7 design-space exploration admits MR banks only above the eq.-12
SNR cutoff (≈21.2 dB for 2⁷ levels per polarity). This module injects the
corresponding Gaussian amplitude noise into the MVM outputs so tests can
verify the *system-level* consequence: at the design-point SNR, GNN
accuracy is unaffected; well below the cutoff, it collapses. Used by
``python/tests/test_noise.py``; the deployed artifacts stay noise-free
(noise is a property of the analog hardware, not of the HLO).
"""

import jax
import jax.numpy as jnp

from .kernels import ref


def snr_to_sigma(snr_db: float) -> float:
    """Amplitude noise σ relative to a unit-full-scale signal for a given
    SNR (dB): P_noise/P_signal = 10^(−SNR/10), σ = sqrt(P_noise)."""
    return float(10.0 ** (-snr_db / 20.0))


def noisy_mvm(key, x, w, snr_db: float, quantized=True):
    """MVM with per-output photodetector noise at the given SNR. Noise is
    scaled to the full-scale output amplitude (the BPD's signal swing)."""
    out = ref.mvm_ref(x, w, quantized=quantized)
    full_scale = jnp.max(jnp.abs(out))
    sigma = snr_to_sigma(snr_db) * full_scale
    return out + sigma * jax.random.normal(key, out.shape)


def noisy_gcn_forward(params, x, nbr_idx, nbr_mask, snr_db: float, seed: int = 0):
    """2-layer GCN with every photonic MVM subject to analog noise at
    ``snr_db`` (mirrors ``model.gcn_forward``)."""
    key = jax.random.PRNGKey(seed)
    h = x
    for li, w in enumerate([params["w0"], params["w1"]]):
        key, sub = jax.random.split(key)
        hw = noisy_mvm(sub, h, w, snr_db)
        gathered = hw[nbr_idx]
        agg = ref.reduce_ref(gathered, nbr_mask, op="mean")
        h = hw + agg
        if li == 0:
            h = jax.nn.relu(h)
    return (h,)
