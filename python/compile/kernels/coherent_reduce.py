"""L1 Pallas kernel: the GHOST *reduce unit* as a chunked masked reduction.

Hardware mapping: one reduce unit is an ``R_r × R_c`` coherent-summation
array — each column imprints one neighbor's feature chunk and constructive
interference sums the columns; the trailing recirculation MR feeds the
partial sum back for the next ``R_c`` neighbors (Fig. 5(a)). Max-reduce
routes through the optical comparator instead.

In Pallas: the grid iterates the ``R_c``-wide neighbor column blocks —
the *architecturally sequential* axis, with the accumulator carried across
grid steps playing the recirculation MR. The spatially parallel hardware
dimensions (``V`` reduce units, ``R_r`` wavelength rows) are folded into
the block, so one grid step computes one coherent pass of the whole
aggregate plane. Inputs are the gathered neighbor features ``g [n, D, f]``
and the 0/1 validity mask ``m [n, D]`` from the padded neighbor table.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Reduce-array dimensions (paper-optimal config).
R_R = 18  # feature rows (wavelengths)
R_C = 7  # neighbor columns per coherent pass
V = 20  # reduce units operating in parallel (one per lane)

# Lowering optimization (§Perf): recirculation passes batched per grid
# step (see photonic_mvm.PASSES_PER_STEP); accumulation order preserved.
PASSES_PER_STEP = 8
D_TILE = R_C * PASSES_PER_STEP

_NEG = -3.4e38  # -inf stand-in for masked max entries


def _sum_kernel(g_ref, m_ref, o_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # One coherent pass: R_C neighbor columns interfere into the partial sum.
    o_ref[...] += jnp.sum(g_ref[...] * m_ref[...][..., None], axis=1)


def _max_kernel(g_ref, m_ref, o_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.full_like(o_ref, _NEG)

    masked = jnp.where(m_ref[...][..., None] > 0, g_ref[...], _NEG)
    o_ref[...] = jnp.maximum(o_ref[...], jnp.max(masked, axis=1))


def _pad_to(a, axis, multiple):
    size = a.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths)


@functools.partial(jax.jit, static_argnames=("op",))
def coherent_reduce(gathered, mask, op="sum"):
    """Reduce gathered neighbor features over the neighbor axis.

    ``gathered [n, D, f]``, ``mask [n, D]`` → ``[n, f]``.
    ``op``: "sum" | "mean" | "max" (the three §3.3.1 reduce modes; mean is
    the trailing-MR 1/n scaling after the coherent sum).
    """
    n, d, f = gathered.shape
    gp = _pad_to(_pad_to(_pad_to(gathered, 0, V), 1, D_TILE), 2, R_R)
    mp = _pad_to(_pad_to(mask, 0, V), 1, D_TILE)
    npad, dp, fp = gp.shape
    # Grid over the sequential recirculation (pass-burst) axis only.
    grid = (dp // D_TILE,)
    kernel = _max_kernel if op == "max" else _sum_kernel
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((npad, D_TILE, fp), lambda kk: (0, kk, 0)),
            pl.BlockSpec((npad, D_TILE), lambda kk: (0, kk)),
        ],
        out_specs=pl.BlockSpec((npad, fp), lambda kk: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((npad, fp), jnp.float32),
        interpret=True,
    )(gp, mp)
    out = out[:n, :f]
    counts = jnp.sum(mask, axis=1)
    if op == "mean":
        out = out / jnp.maximum(counts, 1.0)[:, None]
    elif op == "max":
        # Vertices with no neighbors contribute zero (blocker-gated lanes).
        out = jnp.where(counts[:, None] > 0, out, 0.0)
    return out


def coherent_reduce_batched(gathered, mask, op="sum"):
    """Batched variant ``[B, n, D, f] → [B, n, f]``."""
    b, n, d, f = gathered.shape
    out = coherent_reduce(
        gathered.reshape(b * n, d, f), mask.reshape(b * n, d), op=op
    )
    return out.reshape(b, n, f)
