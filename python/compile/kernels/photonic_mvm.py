"""L1 Pallas kernel: the GHOST *transform unit* as a blocked MVM.

Hardware mapping (DESIGN.md §Hardware-Adaptation): one transform unit is a
``T_r × R_r`` microring bank — ``R_r`` WDM wavelengths carry the activation
vector, each of the ``T_r`` rows imprints one weight row and a balanced
photodetector accumulates one output feature per pass. The ECU re-maps
weight tiles over multiple passes when the layer is bigger than the array.

In Pallas that is a k-blocked matmul with an accumulator: the grid
iterates the ``R_r``-wide input chunks — the *architecturally sequential*
axis (each chunk is one optical pass, with digital partial-sum buffering
between passes, §3.3.2). The spatially parallel hardware dimensions — the
``V`` execution lanes and ``T_r`` BPD rows, which all fire simultaneously
in every pass — are folded into the block so one grid step computes what
the whole photonic plane computes in one pass. Values are fake-quantized
to the 2⁷-per-polarity amplitude grid before entering the array — the
imprint precision of the photonic datapath.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; real-TPU tiling/roofline is estimated analytically in
DESIGN.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..quant import fake_quantize

# Photonic array dimensions (paper-optimal [N,V,Rr,Rc,Tr] = [20,20,18,7,17]).
R_R = 18  # wavelengths per waveguide == input chunk
T_R = 17  # transform-unit rows == output chunk
V = 20  # execution lanes == vertex-block rows per pass

# Lowering optimization (§Perf): issue this many back-to-back optical
# passes per grid step. The recirculation accumulation order within the
# step is preserved (contiguous k-columns), so numerics match pass-granular
# execution up to fp reassociation; interpret-mode per-step overhead drops
# ~8×. One grid step = one *burst* of passes.
PASSES_PER_STEP = 16
K_TILE = R_R * PASSES_PER_STEP


def _mvm_kernel(x_ref, w_ref, o_ref):
    """One grid step = one optical pass: every lane × BPD row accumulates
    its (·, R_R) × (R_R, ·) partial product simultaneously."""

    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


def _pad_to(a, axis, multiple):
    size = a.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths)


@functools.partial(jax.jit, static_argnames=("quantized",))
def photonic_mvm(x, w, quantized=True):
    """``x [m, k] @ w [k, n]`` through the photonic transform array.

    ``quantized=True`` applies the 8-bit amplitude-grid fake-quantization
    to both operands (the deployment configuration); ``False`` bypasses it
    (the fp32 reference configuration of Table 3).
    """
    if quantized:
        x = fake_quantize(x)
        w = fake_quantize(w)
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"shape mismatch {x.shape} @ {w.shape}"
    xp = _pad_to(_pad_to(x, 0, V), 1, K_TILE)
    wp = _pad_to(_pad_to(w, 0, K_TILE), 1, T_R)
    mp, kp = xp.shape
    _, np_ = wp.shape
    # Grid over the sequential pass-burst axis only; lanes/rows are
    # spatially parallel hardware and live inside the block.
    grid = (kp // K_TILE,)
    out = pl.pallas_call(
        _mvm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((mp, K_TILE), lambda kk: (0, kk)),
            pl.BlockSpec((K_TILE, np_), lambda kk: (kk, 0)),
        ],
        out_specs=pl.BlockSpec((mp, np_), lambda kk: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, wp)
    return out[:m, :n]


def photonic_mvm_batched(x, w, quantized=True):
    """Batched variant for graph-classification inputs ``x [B, m, k]``:
    flattens the batch onto the lane axis (the ECU schedules graphs
    back-to-back on the same arrays)."""
    b, m, k = x.shape
    out = photonic_mvm(x.reshape(b * m, k), w, quantized=quantized)
    return out.reshape(b, m, -1)
