"""Pure-jnp oracles for the Pallas kernels — the CORE correctness signal.

Every kernel result is checked against these references in
``python/tests/test_kernel.py`` (including hypothesis shape sweeps).
"""

import jax.numpy as jnp

from ..quant import fake_quantize


def mvm_ref(x, w, quantized=True):
    """Reference for ``photonic_mvm``."""
    if quantized:
        x = fake_quantize(x)
        w = fake_quantize(w)
    return jnp.dot(x, w, preferred_element_type=jnp.float32)


def reduce_ref(gathered, mask, op="sum"):
    """Reference for ``coherent_reduce``."""
    m = mask[..., None]
    if op == "sum":
        return jnp.sum(gathered * m, axis=-2)
    if op == "mean":
        s = jnp.sum(gathered * m, axis=-2)
        counts = jnp.maximum(jnp.sum(mask, axis=-1), 1.0)
        return s / counts[..., None]
    if op == "max":
        neg = jnp.full_like(gathered, -3.4e38)
        masked = jnp.where(m > 0, gathered, neg)
        out = jnp.max(masked, axis=-2)
        any_valid = jnp.sum(mask, axis=-1) > 0
        return jnp.where(any_valid[..., None], out, 0.0)
    raise ValueError(f"unknown op {op}")
