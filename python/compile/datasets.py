"""Synthetic functional-path datasets matched to Table 2 of the paper.

The real Cora/PubMed/Citeseer/Amazon/TU datasets cannot be downloaded in
this environment, so each dataset is generated with the Table-2 statistics
(#nodes, #edges, #features, #labels, #graphs) plus the two properties GNN
accuracy actually depends on:

* **homophily** — edges preferentially connect same-class vertices
  (p_same = 0.8), so neighborhood aggregation carries label signal;
* **feature signal** — node features are noisy class embeddings, so the
  linear transform carries label signal too.

Topology is stored as a padded in-neighbor table ``nbr_idx [n, D]`` with a
0/1 mask — the static-shape form the AOT-lowered HLO consumes. ``D`` is the
functional-path degree cap (documented substitution: Table 2 fixes only the
*average* degree). Everything is deterministic per-dataset (seeded numpy
Generator).
"""

from dataclasses import dataclass, field

import numpy as np

# Functional-path neighbor-table cap for node-classification datasets.
NODE_DEGREE_CAP = 32


@dataclass(frozen=True)
class Spec:
    name: str
    avg_nodes: int
    avg_edges: int
    n_features: int
    n_labels: int
    n_graphs: int
    degree_cap: int
    seed: int
    graph_task: bool = False


SPECS = {
    "cora": Spec("Cora", 2708, 10_556, 1433, 7, 1, NODE_DEGREE_CAP, 0xC08A),
    "pubmed": Spec("PubMed", 19_717, 88_651, 500, 3, 1, NODE_DEGREE_CAP, 0x9B3D),
    "citeseer": Spec("Citeseer", 3327, 9104, 3703, 6, 1, NODE_DEGREE_CAP, 0xC17E),
    "amazon": Spec("Amazon", 7650, 238_162, 745, 8, 1, NODE_DEGREE_CAP, 0xA32),
    "proteins": Spec("Proteins", 39, 73, 3, 2, 1113, 16, 0x980, graph_task=True),
    "mutag": Spec("Mutag", 18, 40, 143, 2, 188, 8, 0x3074, graph_task=True),
    "bzr": Spec("BZR", 34, 38, 189, 2, 405, 8, 0xB2, graph_task=True),
    "imdb-binary": Spec("IMDB-binary", 20, 193, 136, 2, 1000, 19, 0x1DB, graph_task=True),
}


@dataclass
class NodeDataset:
    """Single-graph node-classification dataset."""

    spec: Spec
    x: np.ndarray  # [n, f] float32
    labels: np.ndarray  # [n] int32
    nbr_idx: np.ndarray  # [n, D] int32 (self-padded)
    nbr_mask: np.ndarray  # [n, D] float32
    train_mask: np.ndarray  # [n] int32
    test_mask: np.ndarray  # [n] int32
    edges: list = field(default_factory=list)  # raw (src, dst) pairs


@dataclass
class GraphDataset:
    """Multi-graph graph-classification dataset, padded and batched."""

    spec: Spec
    x: np.ndarray  # [B, n_max, f] float32
    node_mask: np.ndarray  # [B, n_max] float32
    labels: np.ndarray  # [B] int32
    nbr_idx: np.ndarray  # [B, n_max, D] int32
    nbr_mask: np.ndarray  # [B, n_max, D] float32
    train_mask: np.ndarray  # [B] int32
    test_mask: np.ndarray  # [B] int32


def _homophilous_edges(rng, n, n_edges, labels, cap, p_same=0.8):
    """Directed edges with in-degree cap and 80 % same-class preference."""
    by_class = {}
    for c in np.unique(labels):
        by_class[int(c)] = np.flatnonzero(labels == c)
    degree = np.zeros(n, dtype=np.int64)
    edges = []
    attempts = 0
    while len(edges) < n_edges and attempts < n_edges * 30:
        attempts += 1
        dst = int(rng.integers(0, n))
        if degree[dst] >= cap:
            continue
        if rng.random() < p_same:
            pool = by_class[int(labels[dst])]
            src = int(pool[rng.integers(0, len(pool))])
        else:
            src = int(rng.integers(0, n))
        if src == dst:
            continue
        degree[dst] += 1
        edges.append((src, dst))
    return edges


def _neighbor_table(edges, n, cap):
    """Padded in-neighbor table + mask. Padding points at the vertex itself
    with mask 0, keeping gathers in-bounds."""
    nbrs = [[] for _ in range(n)]
    for src, dst in edges:
        if len(nbrs[dst]) < cap:
            nbrs[dst].append(src)
    idx = np.zeros((n, cap), dtype=np.int32)
    mask = np.zeros((n, cap), dtype=np.float32)
    for v in range(n):
        k = len(nbrs[v])
        idx[v, :k] = nbrs[v]
        idx[v, k:] = v
        mask[v, :k] = 1.0
    return idx, mask


# Class-embedding scale vs unit feature noise: keeps linear separability
# imperfect so accuracies land in the paper's 0.6–0.95 band instead of
# saturating (the high-dimensional synthetic task is otherwise too easy).
EMB_SCALE = 0.25
# Fraction of labels flipped uniformly (irreducible task noise).
LABEL_NOISE = 0.10


def _class_features(rng, labels, n_features, noise=1.0):
    """Noisy class embeddings: x_v = s·e_{y_v} + ε."""
    emb = EMB_SCALE * rng.standard_normal((int(labels.max()) + 1, n_features)).astype(np.float32)
    x = emb[labels] + noise * rng.standard_normal((len(labels), n_features))
    return x.astype(np.float32)


def _flip_labels(rng, labels, n_labels, frac=LABEL_NOISE):
    flip = rng.random(len(labels)) < frac
    noisy = labels.copy()
    noisy[flip] = rng.integers(0, n_labels, size=int(flip.sum()))
    return noisy.astype(np.int32)


def make_node_dataset(name: str) -> NodeDataset:
    spec = SPECS[name.lower()]
    assert not spec.graph_task, f"{name} is a graph-classification dataset"
    rng = np.random.default_rng(spec.seed)
    n = spec.avg_nodes
    labels = rng.integers(0, spec.n_labels, size=n).astype(np.int32)
    edges = _homophilous_edges(rng, n, spec.avg_edges, labels, cap=256)
    nbr_idx, nbr_mask = _neighbor_table(edges, n, spec.degree_cap)
    x = _class_features(rng, labels, spec.n_features)
    # Observed labels carry irreducible noise (as real citation data does).
    labels = _flip_labels(rng, labels, spec.n_labels)
    split = rng.random(n)
    train_mask = (split < 0.6).astype(np.int32)
    test_mask = (split >= 0.8).astype(np.int32)
    return NodeDataset(spec, x, labels, nbr_idx, nbr_mask, train_mask, test_mask, edges)


def make_graph_dataset(name: str) -> GraphDataset:
    spec = SPECS[name.lower()]
    assert spec.graph_task, f"{name} is a node-classification dataset"
    rng = np.random.default_rng(spec.seed)
    B = spec.n_graphs
    n_max = int(spec.avg_nodes * 1.3) + 2
    emb = EMB_SCALE * rng.standard_normal((spec.n_labels, spec.n_features)).astype(np.float32)

    x = np.zeros((B, n_max, spec.n_features), dtype=np.float32)
    node_mask = np.zeros((B, n_max), dtype=np.float32)
    labels = rng.integers(0, spec.n_labels, size=B).astype(np.int32)
    nbr_idx = np.zeros((B, n_max, spec.degree_cap), dtype=np.int32)
    nbr_mask = np.zeros((B, n_max, spec.degree_cap), dtype=np.float32)

    for b in range(B):
        n = int(rng.integers(max(2, int(spec.avg_nodes * 0.7)), int(spec.avg_nodes * 1.3) + 1))
        # Class-dependent edge density: class 1 graphs are ~30 % denser —
        # a structural signal only a GNN readout can pick up.
        density_boost = 1.0 + 0.3 * float(labels[b])
        e = max(1, int(rng.integers(max(1, int(spec.avg_edges * 0.7)),
                                    int(spec.avg_edges * 1.3) + 1) * density_boost))
        node_labels = np.full(n, labels[b], dtype=np.int32)
        edges = _homophilous_edges(rng, n, e, node_labels, cap=spec.degree_cap, p_same=0.5)
        idx, mask = _neighbor_table(edges, n, spec.degree_cap)
        nbr_idx[b, :n] = idx
        nbr_mask[b, :n] = mask
        node_mask[b, :n] = 1.0
        # Features: class embedding + noise on real nodes.
        x[b, :n] = emb[labels[b]] + rng.standard_normal((n, spec.n_features)).astype(np.float32)

    split = rng.random(B)
    train_mask = (split < 0.8).astype(np.int32)
    test_mask = (split >= 0.8).astype(np.int32)
    return GraphDataset(spec, x, node_mask, labels, nbr_idx, nbr_mask, train_mask, test_mask)


def load(name: str):
    """Loads either kind of dataset by Table-2 name."""
    spec = SPECS[name.lower()]
    return make_graph_dataset(name) if spec.graph_task else make_node_dataset(name)
