"""AOT lowering: JAX/Pallas forward passes → HLO text + binary artifacts.

For every (model, dataset) pair this writes, under ``artifacts/``:

* ``<model>_<dataset>.hlo.txt`` — the quantized, kernel-path forward pass
  lowered to HLO **text** (the interchange format xla_extension 0.5.1 can
  parse; jax ≥ 0.5 serialized protos are rejected — see
  /opt/xla-example/README.md),
* ``<dataset>.data.bin`` — the dataset arrays (features, neighbor tables,
  labels, masks), shared across models,
* ``<model>_<dataset>.weights.bin`` — trained parameters (from
  ``compile.train``, invoked lazily if missing),
* ``<model>_<dataset>.json`` — the manifest the Rust runtime consumes:
  executable input order, tensor shapes/dtypes/offsets, eval extras, and
  measured Table-3 accuracies.

Python runs ONCE at build time (``make artifacts``); the Rust binary is
self-contained afterwards.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import datasets as D
from . import model as M
from . import train as T

ARTIFACTS = T.ARTIFACTS


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple=True so the Rust
    side can uniformly unwrap outputs)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _np_dtype_tag(a: np.ndarray) -> str:
    if a.dtype == np.float32:
        return "f32"
    if a.dtype == np.int32:
        return "i32"
    raise ValueError(f"unsupported dtype {a.dtype}")


class BinWriter:
    """Accumulates raw little-endian tensors and their manifest entries."""

    def __init__(self, file_key: str):
        self.file_key = file_key
        self.chunks = []
        self.offset = 0

    def add(self, name: str, array: np.ndarray) -> dict:
        array = np.ascontiguousarray(array)
        entry = {
            "name": name,
            "shape": list(array.shape),
            "dtype": _np_dtype_tag(array),
            "file": self.file_key,
            "offset": self.offset,
        }
        raw = array.tobytes()
        self.chunks.append(raw)
        self.offset += len(raw)
        return entry

    def write(self, path: str):
        with open(path, "wb") as f:
            for c in self.chunks:
                f.write(c)


def _dataset_arrays(model: str, ds) -> list[tuple[str, np.ndarray]]:
    """Executable data inputs, in call order."""
    arrays = [
        ("x", ds.x.astype(np.float32)),
        ("nbr_idx", ds.nbr_idx.astype(np.int32)),
        ("nbr_mask", ds.nbr_mask.astype(np.float32)),
    ]
    if model == "gin":
        arrays.append(("node_mask", ds.node_mask.astype(np.float32)))
    return arrays


def _sorted_params(params: dict) -> list[tuple[str, np.ndarray]]:
    return [(k, np.asarray(params[k], dtype=np.float32)) for k in sorted(params)]


def build_artifact(model: str, dataset: str, accuracies: dict, out_dir: str):
    """Lower one (model, dataset) pair and write all its artifact files."""
    ds = D.load(dataset)
    wpath = T.weights_path(model, dataset)
    if not os.path.exists(wpath):
        raise FileNotFoundError(f"{wpath}: run compile.train first")
    loaded = np.load(wpath)
    params = {k: jnp.asarray(loaded[k]) for k in loaded.files}
    fwd = M.forward_fn(model)

    data_inputs = _dataset_arrays(model, ds)
    weight_inputs = _sorted_params(params)
    weight_names = [k for k, _ in weight_inputs]

    def flat_fwd(*args):
        n_data = len(data_inputs)
        data = args[:n_data]
        p = dict(zip(weight_names, args[n_data:]))
        return fwd(p, *data, quantized=True, use_kernels=True)

    example = [jax.ShapeDtypeStruct(a.shape, a.dtype) for _, a in data_inputs] + [
        jax.ShapeDtypeStruct(a.shape, a.dtype) for _, a in weight_inputs
    ]
    print(f"lowering {model}/{dataset}...")
    lowered = jax.jit(flat_fwd).lower(*example)
    hlo = to_hlo_text(lowered)

    name = f"{model}_{dataset}"
    hlo_file = f"{name}.hlo.txt"
    with open(os.path.join(out_dir, hlo_file), "w") as f:
        f.write(hlo)

    # Shared per-dataset data bin (idempotent across models, but GIN adds
    # node_mask — keep it per-dataset and include every array any model
    # needs plus eval extras).
    data_bin = f"{dataset}.data.bin"
    dwriter = BinWriter("data")
    input_entries = [dwriter.add(n, a) for n, a in data_inputs]
    extras = {
        "labels": dwriter.add("labels", ds.labels.astype(np.int32)),
        "test_mask": dwriter.add("test_mask", ds.test_mask.astype(np.int32)),
        "train_mask": dwriter.add("train_mask", ds.train_mask.astype(np.int32)),
    }
    dwriter.write(os.path.join(out_dir, data_bin))

    weights_bin = f"{name}.weights.bin"
    wwriter = BinWriter("weights")
    weight_entries = [wwriter.add(n, a) for n, a in weight_inputs]
    wwriter.write(os.path.join(out_dir, weights_bin))

    acc = accuracies.get((model, dataset), {})
    manifest = {
        "hlo": hlo_file,
        "inputs": input_entries + weight_entries,
        "extras": extras,
        "files": {"data": data_bin, "weights": weights_bin},
        "meta": {
            "model": model,
            "dataset": D.SPECS[dataset].name,
            "acc_fp32": acc.get("acc_fp32"),
            "acc_int8": acc.get("acc_int8"),
            "quantized": True,
        },
    }
    with open(os.path.join(out_dir, f"{name}.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"  wrote {hlo_file} ({len(hlo)} chars), {weights_bin}, {name}.json")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=ARTIFACTS, help="artifacts directory")
    ap.add_argument("--model", default=None, help="single model to build")
    ap.add_argument("--dataset", default=None, help="single dataset to build")
    ap.add_argument(
        "--skip-training", action="store_true", help="fail instead of training on missing weights"
    )
    args = ap.parse_args()
    out_dir = os.path.abspath(args.out)
    os.makedirs(out_dir, exist_ok=True)

    if args.skip_training:
        acc_rows = []
        acc_path = os.path.join(out_dir, "accuracy.json")
        if os.path.exists(acc_path):
            with open(acc_path) as f:
                acc_rows = json.load(f)
    else:
        acc_rows = T.train_all()
    accuracies = {}
    for r in acc_rows:
        model_key = r["model"].lower()
        ds_key = r["dataset"].lower()
        accuracies[(model_key, ds_key)] = r

    pairs = []
    for model, ds_names in T.MODEL_DATASETS.items():
        for dataset in ds_names:
            if args.model and model != args.model:
                continue
            if args.dataset and dataset != args.dataset.lower():
                continue
            pairs.append((model, dataset))
    for model, dataset in pairs:
        build_artifact(model, dataset, accuracies, out_dir)
    # Build stamp consumed by the Makefile.
    with open(os.path.join(out_dir, ".stamp"), "w") as f:
        f.write("ok\n")


if __name__ == "__main__":
    main()
