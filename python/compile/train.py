"""Build-time training for Table 3: trains each model on each of its four
datasets (fp32, hand-rolled Adam), evaluates test accuracy at fp32 and at
GHOST's 8-bit photonic quantization, and saves the trained weights for the
AOT lowering.

Run directly (``python -m compile.train``) or let ``compile.aot`` invoke it
lazily. Outputs:

* ``artifacts/weights/<model>_<dataset>.npz`` — trained parameters,
* ``artifacts/accuracy.json`` — the Table-3 rows.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from . import datasets as D
from . import model as M

EPOCHS = 120
LR = 0.01

MODEL_DATASETS = {
    "gcn": ["cora", "pubmed", "citeseer", "amazon"],
    "graphsage": ["cora", "pubmed", "citeseer", "amazon"],
    "gat": ["cora", "pubmed", "citeseer", "amazon"],
    "gin": ["proteins", "mutag", "bzr", "imdb-binary"],
}

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def _adam_init(params):
    zeros = jax.tree.map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree.map(jnp.zeros_like, params), "t": 0}


def _adam_step(params, grads, state, lr=LR, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mhat = jax.tree.map(lambda m: m / (1 - b1**t), m)
    vhat = jax.tree.map(lambda v: v / (1 - b2**t), v)
    new_params = jax.tree.map(
        lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + eps), params, mhat, vhat
    )
    return new_params, {"m": m, "v": v, "t": t}


def _cross_entropy(logits, labels, mask):
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None].astype(jnp.int32), axis=-1)[:, 0]
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def _accuracy(logits, labels, mask):
    pred = jnp.argmax(logits, axis=-1)
    ok = (pred == labels).astype(jnp.float32) * mask
    return float(jnp.sum(ok) / jnp.maximum(jnp.sum(mask), 1.0))


def _model_inputs(model, ds):
    if model == "gin":
        return (
            jnp.asarray(ds.x),
            jnp.asarray(ds.nbr_idx),
            jnp.asarray(ds.nbr_mask),
            jnp.asarray(ds.node_mask),
        )
    return (jnp.asarray(ds.x), jnp.asarray(ds.nbr_idx), jnp.asarray(ds.nbr_mask))


def train_one(model: str, dataset: str, epochs: int = EPOCHS, verbose: bool = True):
    """Trains one (model, dataset) pair; returns (params, acc_fp32, acc_int8)."""
    ds = D.load(dataset)
    rng = np.random.default_rng(ds.spec.seed ^ 0x7A31)
    params = M.init_params(model, rng, ds.spec.n_features, ds.spec.n_labels)
    fwd = M.forward_fn(model)
    inputs = _model_inputs(model, ds)
    labels = jnp.asarray(ds.labels)
    train_mask = jnp.asarray(ds.train_mask, dtype=jnp.float32)
    test_mask = jnp.asarray(ds.test_mask, dtype=jnp.float32)

    # Training runs the pure-jnp path in fp32 (Pallas interpret calls are
    # not differentiated); post-training quantization gives the int8 column.
    def loss_fn(p):
        (logits,) = fwd(p, *inputs, quantized=False, use_kernels=False)
        return _cross_entropy(logits, labels, train_mask)

    step = jax.jit(
        lambda p, s: (lambda g: _adam_step(p, g, s))(jax.grad(loss_fn)(p))
    )
    state = _adam_init(params)
    for epoch in range(epochs):
        params, state = step(params, state)
        if verbose and (epoch + 1) % 40 == 0:
            loss = float(loss_fn(params))
            print(f"  {model}/{dataset}: epoch {epoch + 1}, train loss {loss:.4f}")

    eval_fwd = jax.jit(
        lambda p, q: fwd(p, *inputs, quantized=q, use_kernels=False)[0],
        static_argnames="q",
    )
    acc_fp32 = _accuracy(eval_fwd(params, False), labels, test_mask)
    acc_int8 = _accuracy(eval_fwd(params, True), labels, test_mask)
    if verbose:
        print(f"  {model}/{dataset}: fp32 {acc_fp32:.3f}  int8 {acc_int8:.3f}")
    return params, acc_fp32, acc_int8


def weights_path(model: str, dataset: str) -> str:
    return os.path.join(ARTIFACTS, "weights", f"{model}_{dataset}.npz")


def train_all(force: bool = False):
    """Trains every Table-3 pair (skipping already-saved weights), writes
    accuracy.json, and returns the accuracy rows."""
    os.makedirs(os.path.join(ARTIFACTS, "weights"), exist_ok=True)
    acc_path = os.path.join(ARTIFACTS, "accuracy.json")
    rows = []
    existing = {}
    if os.path.exists(acc_path) and not force:
        with open(acc_path) as f:
            existing = {(r["model"], r["dataset"]): r for r in json.load(f)}
    for model, ds_names in MODEL_DATASETS.items():
        for dataset in ds_names:
            wpath = weights_path(model, dataset)
            key = (model, dataset)
            if os.path.exists(wpath) and key in existing and not force:
                rows.append(existing[key])
                continue
            print(f"training {model} on {dataset}...")
            params, acc_fp32, acc_int8 = train_one(model, dataset)
            flat = {k: np.asarray(v) for k, v in params.items()}
            np.savez(wpath, **flat)
            rows.append(
                {
                    "model": model.upper() if model != "graphsage" else "GraphSAGE",
                    "dataset": D.SPECS[dataset].name,
                    "acc_fp32": acc_fp32,
                    "acc_int8": acc_int8,
                }
            )
            with open(acc_path, "w") as f:
                json.dump(rows, f, indent=1)
    with open(acc_path, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"wrote {acc_path}")
    return rows


if __name__ == "__main__":
    import sys

    train_all(force="--force" in sys.argv)
