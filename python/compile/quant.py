"""8-bit symmetric quantization onto GHOST's photonic amplitude levels.

GHOST carries positive and negative values on separate balanced-photodetector
arms, so each polarity resolves ``N_LEVELS = 2**(bits-1) = 128`` amplitude
steps (paper §3.2, eq. 12). This module mirrors ``rust/src/gnn/quant.rs``
bit-for-bit: per-tensor symmetric scale ``max|x| / (N_LEVELS - 1)``,
round-to-nearest, clamp to ±(N_LEVELS − 1).
"""

import jax.numpy as jnp

PRECISION_BITS = 8
N_LEVELS = 1 << (PRECISION_BITS - 1)  # 128 per polarity
_QMAX = float(N_LEVELS - 1)


def scale_for(x: jnp.ndarray) -> jnp.ndarray:
    """Per-tensor symmetric scale; zero tensors get scale 1 (zeros
    round-trip under any scale)."""
    max_abs = jnp.max(jnp.abs(x))
    return jnp.where(max_abs == 0.0, 1.0, max_abs / _QMAX)


def fake_quantize(x: jnp.ndarray) -> jnp.ndarray:
    """Quantize → dequantize: the value the MR bank actually imprints."""
    s = scale_for(x)
    q = jnp.clip(jnp.round(x / s), -_QMAX, _QMAX)
    return q * s


def quantize_int(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Integer levels plus the scale (for storage/inspection)."""
    s = scale_for(x)
    q = jnp.clip(jnp.round(x / s), -_QMAX, _QMAX).astype(jnp.int8)
    return q, s
