"""L2: the four GNN model families in JAX, calling the L1 Pallas kernels.

Each forward pass exists in two numerically identical realizations:

* ``use_kernels=True`` — transforms run through the Pallas
  ``photonic_mvm`` and aggregations through ``coherent_reduce``: the
  configuration that is AOT-lowered to the ``artifacts/*.hlo.txt`` the Rust
  runtime executes.
* ``use_kernels=False`` — the pure-jnp oracle path (same math via
  ``kernels.ref``), used for training (Pallas interpret-mode calls are not
  differentiated) and for fast Table-3 evaluation. Equality of the two
  paths is asserted by ``python/tests/``.

``quantized=True`` applies GHOST's 8-bit amplitude-grid quantization to
every operand entering a photonic array (deployment); ``False`` is the
fp32 reference of Table 3.

Model configurations follow §4.1: GCN and GraphSAGE with 2 layers, GAT
with 2 layers (8 heads then 1), GIN with an 8-layer MLP (2 convolutions ×
4-layer MLPs) plus sum readout.
"""

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels.coherent_reduce import coherent_reduce, coherent_reduce_batched
from .kernels.photonic_mvm import photonic_mvm, photonic_mvm_batched

HIDDEN = 16
GIN_HIDDEN = 64
GAT_HEADS = 8
GAT_HEAD_DIM = 8
SAGE_SAMPLE = 25


def _mvm(x, w, quantized, use_kernels):
    if use_kernels:
        if x.ndim == 3:
            return photonic_mvm_batched(x, w, quantized=quantized)
        return photonic_mvm(x, w, quantized=quantized)
    return ref.mvm_ref(x, w, quantized=quantized)


def _reduce(g, m, op, use_kernels):
    if use_kernels:
        if g.ndim == 4:
            return coherent_reduce_batched(g, m, op=op)
        return coherent_reduce(g, m, op=op)
    return ref.reduce_ref(g, m, op=op)


def _glorot(rng, shape):
    fan_in, fan_out = shape[0], shape[-1]
    scale = jnp.sqrt(2.0 / (fan_in + fan_out))
    return (scale * rng.standard_normal(shape)).astype(jnp.float32)


# ------------------------------------------------------------------- GCN


def gcn_init(rng, n_features, n_labels):
    return {
        "w0": _glorot(rng, (n_features, HIDDEN)),
        "w1": _glorot(rng, (HIDDEN, n_labels)),
    }


def gcn_forward(params, x, nbr_idx, nbr_mask, quantized=True, use_kernels=True):
    """2-layer GCN; aggregation is the paper's reduce-unit formula
    ``h_v + mean_u h_u`` (self + trailing-MR mean). Transform-then-
    aggregate order (linear maps commute with aggregation; keeps the
    gathered tensor at hidden width)."""
    h = x
    for li, w in enumerate([params["w0"], params["w1"]]):
        hw = _mvm(h, w, quantized, use_kernels)
        gathered = hw[nbr_idx]  # [n, D, out]
        agg = _reduce(gathered, nbr_mask, "mean", use_kernels)
        h = hw + agg
        if li == 0:
            h = jax.nn.relu(h)
    return (h,)


# ------------------------------------------------------------- GraphSAGE


def sage_init(rng, n_features, n_labels):
    return {
        "w_self0": _glorot(rng, (n_features, HIDDEN)),
        "w_nbr0": _glorot(rng, (n_features, HIDDEN)),
        "w_self1": _glorot(rng, (HIDDEN, n_labels)),
        "w_nbr1": _glorot(rng, (HIDDEN, n_labels)),
    }


def sage_forward(params, x, nbr_idx, nbr_mask, quantized=True, use_kernels=True):
    """2-layer GraphSAGE, mean aggregator over a fixed neighbor sample."""
    idx = nbr_idx[:, :SAGE_SAMPLE]
    mask = nbr_mask[:, :SAGE_SAMPLE]
    h = x
    for li in range(2):
        w_self = params[f"w_self{li}"]
        w_nbr = params[f"w_nbr{li}"]
        agg = _reduce(h[idx], mask, "mean", use_kernels)
        h = _mvm(h, w_self, quantized, use_kernels) + _mvm(agg, w_nbr, quantized, use_kernels)
        if li == 0:
            h = jax.nn.relu(h)
    return (h,)


# ------------------------------------------------------------------- GIN


def gin_init(rng, n_features, n_labels):
    params = {"eps0": jnp.zeros(()), "eps1": jnp.zeros(())}
    dims0 = [n_features] + [GIN_HIDDEN] * 4
    dims1 = [GIN_HIDDEN] + [GIN_HIDDEN] * 4
    for conv, dims in enumerate([dims0, dims1]):
        for i in range(4):
            params[f"mlp{conv}_{i}"] = _glorot(rng, (dims[i], dims[i + 1]))
    params["w_cls"] = _glorot(rng, (GIN_HIDDEN, n_labels))
    return params


def gin_forward(params, x, nbr_idx, nbr_mask, node_mask, quantized=True, use_kernels=True):
    """2 GIN convolutions (4-layer MLPs → the paper's 8 MLP layers), sum
    readout, linear classifier. Batched over padded graphs."""
    b, n, _ = x.shape
    batch_ix = jnp.arange(b)[:, None, None]
    h = x
    for conv in range(2):
        gathered = h[batch_ix, nbr_idx]  # [B, n, D, f]
        s = _reduce(gathered, nbr_mask, "sum", use_kernels)
        h = (1.0 + params[f"eps{conv}"]) * h + s
        for i in range(4):
            h = _mvm(h, params[f"mlp{conv}_{i}"], quantized, use_kernels)
            h = jax.nn.relu(h)
        h = h * node_mask[..., None]
    pooled = jnp.sum(h, axis=1)  # [B, hidden] sum readout
    logits = _mvm(pooled, params["w_cls"], quantized, use_kernels)
    return (logits,)


# ------------------------------------------------------------------- GAT


def gat_init(rng, n_features, n_labels):
    return {
        "w0": _glorot(rng, (n_features, GAT_HEADS * GAT_HEAD_DIM)),
        "a_src0": _glorot(rng, (GAT_HEADS, GAT_HEAD_DIM)),
        "a_dst0": _glorot(rng, (GAT_HEADS, GAT_HEAD_DIM)),
        "w1": _glorot(rng, (GAT_HEADS * GAT_HEAD_DIM, n_labels)),
        "a_src1": _glorot(rng, (1, n_labels)),
        "a_dst1": _glorot(rng, (1, n_labels)),
    }


def _attn_blockdiag(a):
    """Builds the block-diagonal [H*d, H] matrix that computes per-head
    attention dot products on the transform arrays (the paper routes the
    attention-vector multiplication through the combine block)."""
    heads, dim = a.shape
    eye = jnp.eye(heads)  # [H, H]
    return (a[:, :, None] * eye[:, None, :]).reshape(heads * dim, heads)


def _gat_layer(x, w, a_src, a_dst, nbr_idx, nbr_mask, quantized, use_kernels, concat):
    heads, dim = a_src.shape
    n = x.shape[0]
    wh = _mvm(x, w, quantized, use_kernels)  # [n, H*d]
    e_src = _mvm(wh, _attn_blockdiag(a_src), quantized, use_kernels)  # [n, H]
    e_dst = _mvm(wh, _attn_blockdiag(a_dst), quantized, use_kernels)  # [n, H]
    # Logit for destination i attending to neighbor j: src term of j plus
    # dst term of i (LeakyReLU on the optical path, §3.4.2).
    logits = jax.nn.leaky_relu(
        e_src[nbr_idx] + e_dst[:, None, :], negative_slope=0.2
    )  # [n, D, H]
    logits = jnp.where(nbr_mask[..., None] > 0, logits, -1e9)
    alpha = jax.nn.softmax(logits, axis=1)  # digital LUT unit
    alpha = alpha * nbr_mask[..., None]
    gathered = wh[nbr_idx].reshape(n, nbr_idx.shape[1], heads, dim)  # [n, D, H, d]
    weighted = (gathered * alpha[..., None]).reshape(n, nbr_idx.shape[1], heads * dim)
    out = _reduce(weighted, nbr_mask, "sum", use_kernels)  # [n, H*d]
    if concat:
        return jax.nn.elu(out)
    # Single-head output layer: average (here heads == 1 → identity).
    return out.reshape(n, heads, dim).mean(axis=1)


def gat_forward(params, x, nbr_idx, nbr_mask, quantized=True, use_kernels=True):
    h = _gat_layer(
        x,
        params["w0"],
        params["a_src0"],
        params["a_dst0"],
        nbr_idx,
        nbr_mask,
        quantized,
        use_kernels,
        concat=True,
    )
    logits = _gat_layer(
        h,
        params["w1"],
        params["a_src1"],
        params["a_dst1"],
        nbr_idx,
        nbr_mask,
        quantized,
        use_kernels,
        concat=False,
    )
    return (logits,)


# ------------------------------------------------------------ dispatching

MODELS = {
    "gcn": (gcn_init, gcn_forward),
    "graphsage": (sage_init, sage_forward),
    "gin": (gin_init, gin_forward),
    "gat": (gat_init, gat_forward),
}


def init_params(model: str, rng, n_features: int, n_labels: int):
    return MODELS[model][0](rng, n_features, n_labels)


def forward_fn(model: str):
    return MODELS[model][1]
