//! Memory-system models: HBM2 off-chip DRAM and the ECU's on-chip SRAM
//! buffers.
//!
//! The paper simulates buffers with CACTI (scaled 20 nm → 7 nm using the
//! Stillmaker–Baas relations [40]) and the 8 GB HBM2 main memory with
//! DRAMsim3. The simulator consumes only per-access latencies/energies and
//! sustained bandwidth, so we embed analytic models with CACTI-class /
//! HBM2-spec constants (documented substitution in `DESIGN.md`).

pub mod hbm;
pub mod sram;

pub use hbm::Hbm2;
pub use sram::SramBuffer;
