//! On-chip SRAM buffer model (the ECU's four buffers, §4.1).
//!
//! Per-access latency and energy follow CACTI-class values for small
//! single-bank SRAMs, scaled to 7 nm with the Stillmaker–Baas factors [40]
//! (latency ×0.28, energy ×0.133 from 20 nm — folded into the constants).


/// Width of one buffer access in bytes (64 B line).
pub const ACCESS_WIDTH_BYTES: usize = 64;

/// A single on-chip SRAM buffer.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SramBuffer {
    /// Human-readable role of the buffer.
    pub name: &'static str,
    /// Capacity in bytes.
    pub size_bytes: usize,
    /// Per-access latency, seconds.
    pub access_latency_s: f64,
    /// Per-access energy for one 64 B line, joules.
    pub access_energy_j: f64,
    /// Leakage power, watts.
    pub leakage_w: f64,
}

impl SramBuffer {
    /// CACTI-7 nm-class constants for a buffer of `size_kb` kilobytes:
    /// latency and energy grow weakly (≈ √size) with capacity.
    pub fn cacti_7nm(name: &'static str, size_kb: usize) -> Self {
        let scale = (size_kb as f64 / 128.0).sqrt();
        Self {
            name,
            size_bytes: size_kb * 1024,
            access_latency_s: 0.25e-9 * scale.max(0.5),
            access_energy_j: 9.6e-12 * scale.max(0.5), // per 64 B line
            leakage_w: 0.4e-3 * (size_kb as f64 / 128.0),
        }
    }

    /// Latency to stream `bytes` through the buffer (line-granular,
    /// fully pipelined at one access per cycle → one line per access
    /// latency).
    pub fn stream_latency_s(&self, bytes: usize) -> f64 {
        let lines = bytes.div_ceil(ACCESS_WIDTH_BYTES);
        lines as f64 * self.access_latency_s
    }

    /// Energy to stream `bytes` through the buffer.
    pub fn stream_energy_j(&self, bytes: usize) -> f64 {
        let lines = bytes.div_ceil(ACCESS_WIDTH_BYTES);
        lines as f64 * self.access_energy_j
    }
}

/// The ECU buffer set from §4.1: input vertices (128 KB), output vertices
/// (128 KB), edges (256 KB), weights (128 KB).
#[derive(Debug, Clone, Copy)]
pub struct EcuBuffers {
    pub input_vertices: SramBuffer,
    pub output_vertices: SramBuffer,
    pub edges: SramBuffer,
    pub weights: SramBuffer,
}

impl EcuBuffers {
    pub fn paper() -> Self {
        Self {
            input_vertices: SramBuffer::cacti_7nm("input_vertices", 128),
            output_vertices: SramBuffer::cacti_7nm("output_vertices", 128),
            edges: SramBuffer::cacti_7nm("edges", 256),
            weights: SramBuffer::cacti_7nm("weights", 128),
        }
    }

    /// Total leakage of the buffer set, watts.
    pub fn total_leakage_w(&self) -> f64 {
        self.input_vertices.leakage_w
            + self.output_vertices.leakage_w
            + self.edges.leakage_w
            + self.weights.leakage_w
    }
}

impl Default for EcuBuffers {
    fn default() -> Self {
        Self::paper()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_buffer_sizes() {
        let b = EcuBuffers::paper();
        assert_eq!(b.input_vertices.size_bytes, 128 * 1024);
        assert_eq!(b.output_vertices.size_bytes, 128 * 1024);
        assert_eq!(b.edges.size_bytes, 256 * 1024);
        assert_eq!(b.weights.size_bytes, 128 * 1024);
    }

    #[test]
    fn bigger_buffer_slower_and_hungrier() {
        let small = SramBuffer::cacti_7nm("s", 128);
        let big = SramBuffer::cacti_7nm("b", 256);
        assert!(big.access_latency_s > small.access_latency_s);
        assert!(big.access_energy_j > small.access_energy_j);
        assert!(big.leakage_w > small.leakage_w);
    }

    #[test]
    fn stream_costs_are_line_granular() {
        let b = SramBuffer::cacti_7nm("s", 128);
        assert_eq!(b.stream_latency_s(1), b.stream_latency_s(64));
        assert!((b.stream_latency_s(128) - 2.0 * b.stream_latency_s(64)).abs() < 1e-18);
        assert!(b.stream_energy_j(65) > b.stream_energy_j(64));
    }
}
