//! HBM2 off-chip memory model (§4.1: 8 GB HBM2, 256 GB/s peak).
//!
//! The paper runs DRAMsim3; the GHOST simulator consumes sustained
//! bandwidth, first-access latency, and per-bit access energy, so we use a
//! bandwidth/latency queueing model with HBM2 datasheet constants. The
//! paper's largest workload demands 174.4 GB/s, under the 256 GB/s peak.


/// HBM2 main memory.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Hbm2 {
    /// Capacity, bytes (8 GB).
    pub capacity_bytes: u64,
    /// Peak bandwidth, bytes/second (256 GB/s).
    pub peak_bw_bytes_per_s: f64,
    /// Fraction of peak achievable for the streaming, partition-ordered
    /// access pattern produced by the buffer-and-partition preprocessing.
    pub streaming_efficiency: f64,
    /// Fraction of peak achievable for irregular (non-partitioned,
    /// on-demand) access — the baseline configuration of Fig. 8.
    pub random_efficiency: f64,
    /// First-word latency of a burst, seconds.
    pub access_latency_s: f64,
    /// Energy per bit moved, joules (≈ 3.9 pJ/bit for HBM2).
    pub energy_per_bit_j: f64,
    /// Fixed energy per independent (row-activating) burst, joules — paid
    /// once per random access, amortized away by partition-ordered
    /// streaming.
    pub burst_overhead_j: f64,
}

impl Hbm2 {
    pub fn paper() -> Self {
        Self {
            capacity_bytes: 8 * (1 << 30),
            peak_bw_bytes_per_s: 256e9,
            streaming_efficiency: 0.70, // covers the paper peak demand of 174.4 GB/s
            random_efficiency: 0.12,
            access_latency_s: 100e-9,
            energy_per_bit_j: 3.9e-12,
            burst_overhead_j: 1.5e-9,
        }
    }

    /// Time to move `bytes` with the partition-ordered streaming pattern.
    pub fn stream_time_s(&self, bytes: u64) -> f64 {
        self.access_latency_s
            + bytes as f64 / (self.peak_bw_bytes_per_s * self.streaming_efficiency)
    }

    /// Time to move `bytes` with irregular on-demand accesses of
    /// `burst_bytes` each (each burst pays the access latency).
    pub fn random_time_s(&self, bytes: u64, burst_bytes: u64) -> f64 {
        let bursts = bytes.div_ceil(burst_bytes.max(1));
        bursts as f64 * self.access_latency_s
            + bytes as f64 / (self.peak_bw_bytes_per_s * self.random_efficiency)
    }

    /// Energy to move `bytes`.
    pub fn transfer_energy_j(&self, bytes: u64) -> f64 {
        bytes as f64 * 8.0 * self.energy_per_bit_j
    }

    /// Sustained streaming bandwidth, bytes/s.
    pub fn sustained_bw(&self) -> f64 {
        self.peak_bw_bytes_per_s * self.streaming_efficiency
    }
}

impl Default for Hbm2 {
    fn default() -> Self {
        Self::paper()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sustained_bw_supports_paper_peak_demand() {
        let m = Hbm2::paper();
        // The paper's max workload needs 174.4 GB/s; sustained must cover it.
        assert!(m.sustained_bw() >= 174.4e9, "sustained = {}", m.sustained_bw());
        assert!(m.sustained_bw() <= m.peak_bw_bytes_per_s);
    }

    #[test]
    fn streaming_beats_random() {
        let m = Hbm2::paper();
        let bytes = 1 << 20; // 1 MiB
        assert!(m.stream_time_s(bytes) < m.random_time_s(bytes, 64));
    }

    #[test]
    fn transfer_energy_linear() {
        let m = Hbm2::paper();
        let e1 = m.transfer_energy_j(1000);
        let e2 = m.transfer_energy_j(2000);
        assert!((e2 - 2.0 * e1).abs() < 1e-18);
    }

    #[test]
    fn stream_time_monotone() {
        let m = Hbm2::paper();
        assert!(m.stream_time_s(2 << 20) > m.stream_time_s(1 << 20));
    }
}
