//! 8-bit symmetric quantization onto the photonic amplitude levels.
//!
//! GHOST represents positive and negative values on separate BPD arms, so
//! each polarity gets `N_levels = 2^(b−1) = 128` amplitude steps (§3.2).
//! This module mirrors `python/compile/quant.py` *bit-for-bit* — the Rust
//! runtime uses it to verify that PJRT-executed artifacts and the native
//! reference agree on the quantization grid.

use crate::config::N_LEVELS;

/// Symmetric per-tensor scale for values in `data`: `max|x| / (N_levels−1)`.
/// A zero tensor gets scale 1.0 (any scale round-trips zeros).
pub fn scale_for(data: &[f32]) -> f32 {
    let max_abs = data.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
    if max_abs == 0.0 {
        1.0
    } else {
        max_abs / (N_LEVELS - 1) as f32
    }
}

/// Quantize one value to the signed level grid, clamped to ±(N_levels−1).
pub fn quantize(x: f32, scale: f32) -> i16 {
    let q = (x / scale).round();
    let lim = (N_LEVELS - 1) as f32;
    q.clamp(-lim, lim) as i16
}

/// Dequantize back to f32.
pub fn dequantize(q: i16, scale: f32) -> f32 {
    q as f32 * scale
}

/// Fake-quantize a whole tensor in place (quantize→dequantize), the
/// operation the photonic imprint performs on every parameter/activation.
pub fn fake_quantize(data: &mut [f32]) -> f32 {
    let scale = scale_for(data);
    for x in data.iter_mut() {
        *x = dequantize(quantize(*x, scale), scale);
    }
    scale
}

/// Worst-case absolute quantization error for a tensor with the given
/// scale: half a step.
pub fn max_error(scale: f32) -> f32 {
    scale / 2.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_error_bounded() {
        let data: Vec<f32> = (0..1000).map(|i| (i as f32 * 0.137).sin() * 3.0).collect();
        let scale = scale_for(&data);
        for &x in &data {
            let err = (dequantize(quantize(x, scale), scale) - x).abs();
            assert!(err <= max_error(scale) + 1e-6, "x={x}, err={err}");
        }
    }

    #[test]
    fn extremes_map_to_full_scale() {
        let data = vec![-2.0f32, 0.0, 2.0];
        let scale = scale_for(&data);
        assert_eq!(quantize(2.0, scale), 127);
        assert_eq!(quantize(-2.0, scale), -127);
        assert_eq!(quantize(0.0, scale), 0);
    }

    #[test]
    fn clamping_works() {
        assert_eq!(quantize(1e9, 1.0), 127);
        assert_eq!(quantize(-1e9, 1.0), -127);
    }

    #[test]
    fn zero_tensor_round_trips() {
        let mut z = vec![0.0f32; 16];
        fake_quantize(&mut z);
        assert!(z.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn fake_quantize_idempotent() {
        let mut a: Vec<f32> = (0..64).map(|i| (i as f32 - 32.0) / 7.0).collect();
        fake_quantize(&mut a);
        let b = a.clone();
        fake_quantize(&mut a);
        assert_eq!(a, b, "quantizing a quantized tensor must be identity");
    }
}
