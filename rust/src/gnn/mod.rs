//! GNN model descriptors and workload characterization.
//!
//! * [`models`] — the four evaluated model families (GCN, GraphSAGE, GIN,
//!   GAT) with the exact layer configurations of §4.1.
//! * [`workload`] — converts a `(model, dataset)` pair into the MAC / byte /
//!   stage-op counts that drive both the GHOST simulator and the baseline
//!   roofline models (one shared convention, so comparisons are fair).
//! * [`quant`] — the 8-bit symmetric quantization GHOST maps onto its
//!   photonic amplitude levels (mirrors `python/compile/` exactly; used by
//!   the runtime verification path).

pub mod models;
pub mod quant;
pub mod workload;

pub use models::{ExecOrdering, LayerSpec, Model, ModelKind, Reduction};
pub use workload::{LayerWork, Workload};
