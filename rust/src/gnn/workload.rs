//! Workload characterization: one shared convention for counting work and
//! traffic, consumed by the GHOST simulator *and* every baseline roofline
//! model so that Figs. 10–12 compare like against like.
//!
//! Conventions:
//! * a MAC counts as 2 ops (multiply + add); an aggregation add or compare
//!   counts as 1 op; activations count 1 op per element;
//! * bits = everything that must cross the memory interface once:
//!   input features, all weights, the edge list, and each layer's output
//!   feature map (written once, read once by the next consumer) — at the
//!   8-bit precision GHOST executes at.


use super::models::{Activation, ExecOrdering, Model, ModelKind};
use crate::graph::datasets::Dataset;

/// Work of one layer across the whole dataset.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct LayerWork {
    /// Aggregation ops (adds or max-compares), all graphs.
    pub agg_ops: u64,
    /// Linear-transform MACs.
    pub comb_macs: u64,
    /// Attention-mechanism MACs (GAT only).
    pub attn_macs: u64,
    /// Activation ops evaluated optically (ReLU / LeakyReLU).
    pub optical_act_ops: u64,
    /// Softmax elements handled by the digital LUT unit.
    pub softmax_ops: u64,
    /// Effective edges aggregated (post neighbor-sampling), all graphs.
    pub eff_edges: u64,
    /// Input feature dimensionality of the layer.
    pub in_dim: usize,
    /// Output feature dimensionality × heads.
    pub out_width: usize,
    /// Weight bytes for this layer (8-bit).
    pub weight_bytes: u64,
    /// Output feature-map bytes (8-bit), all graphs.
    pub out_feature_bytes: u64,
}

impl LayerWork {
    /// Total ops of this layer under the shared convention.
    pub fn ops(&self) -> u64 {
        2 * (self.comb_macs + self.attn_macs)
            + self.agg_ops
            + self.optical_act_ops
            + self.softmax_ops
    }
}

/// A fully characterized `(model, dataset)` workload.
#[derive(Debug, Clone)]
pub struct Workload {
    pub model_kind: ModelKind,
    pub dataset_name: String,
    pub ordering: ExecOrdering,
    pub per_layer: Vec<LayerWork>,
    /// Vertices across all graphs.
    pub n_vertices: u64,
    /// Edges across all graphs (before sampling).
    pub n_edges: u64,
    /// Input feature bytes (8-bit).
    pub input_feature_bytes: u64,
    /// Edge-list bytes (2 × u32 per edge).
    pub edge_bytes: u64,
    /// Readout (graph pooling + classify) ops, if any.
    pub readout_ops: u64,
    /// Number of graphs (inference invocations).
    pub n_graphs: u64,
}

impl Workload {
    /// Characterize `model` over the realized `dataset`.
    pub fn characterize(model: &Model, dataset: &Dataset) -> Self {
        let n_v: u64 = dataset.total_vertices() as u64;
        let n_e: u64 = dataset.total_edges() as u64;
        let mut per_layer = Vec::with_capacity(model.layers.len());
        for layer in &model.layers {
            // Effective edges after (optional) neighbor sampling.
            let eff_edges: u64 = match layer.neighbor_sample {
                Some(s) => dataset
                    .graphs
                    .iter()
                    .map(|g| {
                        (0..g.n_vertices).map(|v| g.degree(v).min(s) as u64).sum::<u64>()
                    })
                    .sum(),
                None => n_e,
            };
            let heads = layer.heads as u64;
            let in_dim = layer.in_dim as u64;
            let out = layer.out_dim as u64;
            // Aggregation dimensionality depends on the execution ordering:
            // aggregate-first models reduce raw in_dim features; GAT reduces
            // the transformed per-head features.
            let (agg_ops, attn_macs) = match layer.reduction {
                None => (0, 0),
                Some(_) => match model.ordering {
                    ExecOrdering::AggregateFirst => (eff_edges * in_dim, 0),
                    ExecOrdering::TransformFirst => {
                        // GAT: aggregate transformed features per head, and
                        // compute attention logits aᵀ[Wh_i ‖ Wh_j] per edge
                        // per head (2·out MACs each).
                        (eff_edges * out * heads, eff_edges * 2 * out * heads)
                    }
                },
            };
            let comb_macs = n_v * in_dim * out * heads;
            let (optical_act_ops, softmax_ops) = match layer.activation {
                Activation::Relu | Activation::LeakyRelu => (n_v * out * heads, 0),
                Activation::Softmax => {
                    // GAT: LeakyReLU on logits (optical) + softmax over each
                    // vertex's neighborhood (digital LUT, one op per edge
                    // per head).
                    (eff_edges * heads, eff_edges * heads)
                }
                Activation::None => (0, 0),
            };
            per_layer.push(LayerWork {
                agg_ops,
                comb_macs,
                attn_macs,
                optical_act_ops,
                softmax_ops,
                eff_edges,
                in_dim: layer.in_dim,
                out_width: layer.out_dim * layer.heads,
                weight_bytes: in_dim * out * heads,
                out_feature_bytes: n_v * out * heads,
            });
        }
        let readout_ops = if model.has_readout {
            // Sum-pool every vertex embedding + classifier handled in the
            // final layer already; pooling adds one add per vertex per
            // pooled dim — the *output* width of the last layer
            // (out_dim × heads), matching the schedule's readout stage.
            n_v * model.layers.last().map(|l| (l.out_dim * l.heads) as u64).unwrap_or(0)
        } else {
            0
        };
        Self {
            model_kind: model.kind,
            dataset_name: dataset.spec.name.to_string(),
            ordering: model.ordering,
            per_layer,
            n_vertices: n_v,
            n_edges: n_e,
            input_feature_bytes: n_v * dataset.spec.n_features as u64,
            edge_bytes: n_e * 8,
            readout_ops,
            n_graphs: dataset.graphs.len() as u64,
        }
    }

    /// Total ops.
    pub fn total_ops(&self) -> u64 {
        self.per_layer.iter().map(|l| l.ops()).sum::<u64>() + self.readout_ops
    }

    /// Total MACs (combine + attention).
    pub fn total_macs(&self) -> u64 {
        self.per_layer.iter().map(|l| l.comb_macs + l.attn_macs).sum()
    }

    /// Total bytes crossing the memory interface once (8-bit datapath).
    pub fn total_bytes(&self) -> u64 {
        let weights: u64 = self.per_layer.iter().map(|l| l.weight_bytes).sum();
        let out_feats: u64 = self.per_layer.iter().map(|l| l.out_feature_bytes).sum();
        // Outputs are written once and read once by the next consumer.
        self.input_feature_bytes + self.edge_bytes + weights + 2 * out_feats
    }

    /// Total bits moved — the denominator convention for EPB.
    pub fn total_bits(&self) -> u64 {
        self.total_bytes() * 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gnn::models::Model;
    use crate::graph::datasets::Dataset;

    fn workload(kind: ModelKind, ds: &str) -> Workload {
        let dataset = Dataset::by_name(ds).unwrap();
        let model = Model::for_dataset(kind, &dataset.spec);
        Workload::characterize(&model, &dataset)
    }

    #[test]
    fn gcn_cora_magnitudes() {
        let w = workload(ModelKind::Gcn, "Cora");
        // Layer-1 combine: 2708 × 1433 × 16 MACs ≈ 62.1 M.
        assert_eq!(w.per_layer[0].comb_macs, 2708 * 1433 * 16);
        // Layer-1 aggregation: 10556 × 1433 adds.
        assert_eq!(w.per_layer[0].agg_ops, 10_556 * 1433);
        assert!(w.total_ops() > 100_000_000);
        assert_eq!(w.per_layer[0].attn_macs, 0);
    }

    #[test]
    fn sage_sampling_reduces_edges() {
        let full = workload(ModelKind::Gcn, "Amazon");
        let sampled = workload(ModelKind::GraphSage, "Amazon");
        assert!(
            sampled.per_layer[0].eff_edges < full.per_layer[0].eff_edges,
            "sampling must reduce effective edges on a high-degree graph"
        );
    }

    #[test]
    fn gat_has_attention_and_softmax() {
        let w = workload(ModelKind::Gat, "Citeseer");
        assert!(w.per_layer[0].attn_macs > 0);
        assert!(w.per_layer[0].softmax_ops > 0);
        assert_eq!(w.ordering, ExecOrdering::TransformFirst);
        // 8 heads on layer 1.
        assert_eq!(w.per_layer[0].out_width, 64);
    }

    #[test]
    fn gin_has_readout_and_nine_layers() {
        let w = workload(ModelKind::Gin, "Proteins");
        assert_eq!(w.per_layer.len(), 9);
        assert!(w.readout_ops > 0);
        assert_eq!(w.n_graphs, 1113);
    }

    #[test]
    fn bytes_dominated_by_features_for_cora() {
        let w = workload(ModelKind::Gcn, "Cora");
        // 2708 × 1433 input features dwarf the 16-dim intermediates.
        assert!(w.input_feature_bytes > w.total_bytes() / 2);
    }

    #[test]
    fn ops_layer_sum_consistent() {
        let w = workload(ModelKind::Gat, "Cora");
        let manual: u64 = w.per_layer.iter().map(|l| l.ops()).sum();
        assert_eq!(w.total_ops(), manual + w.readout_ops);
    }
}
