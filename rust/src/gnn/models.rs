//! The four GNN model families evaluated in the paper (§4.1):
//!
//! * **GCN** — 2 layers, mean(-normalized sum) aggregation;
//! * **GraphSAGE** — 2 layers, mean aggregation over a fixed neighbor
//!   sample;
//! * **GIN** — graph classification; the conv's MLP totals 8 layers
//!   (we realize it as 2 GIN convolutions with 4-layer MLPs each, plus a
//!   sum readout and linear classifier);
//! * **GAT** — 2 layers, 8 attention heads then 1, with the
//!   transform-before-aggregate execution ordering of §3.4.2.


use crate::graph::datasets::DatasetSpec;

/// Which model family.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ModelKind {
    Gcn,
    GraphSage,
    Gin,
    Gat,
}

impl ModelKind {
    pub const ALL: [ModelKind; 4] =
        [ModelKind::Gcn, ModelKind::GraphSage, ModelKind::Gin, ModelKind::Gat];

    pub fn name(&self) -> &'static str {
        match self {
            ModelKind::Gcn => "GCN",
            ModelKind::GraphSage => "GraphSAGE",
            ModelKind::Gin => "GIN",
            ModelKind::Gat => "GAT",
        }
    }

    /// Datasets each model processes in the paper's evaluation: the
    /// node-classification corpora for GCN/GraphSAGE/GAT, the
    /// graph-classification corpora for GIN.
    pub fn datasets(&self) -> [&'static str; 4] {
        match self {
            ModelKind::Gin => ["Proteins", "Mutag", "BZR", "IMDB-binary"],
            _ => ["Cora", "PubMed", "Citeseer", "Amazon"],
        }
    }

    pub fn by_name(name: &str) -> Option<Self> {
        match name.to_ascii_lowercase().as_str() {
            "gcn" => Some(ModelKind::Gcn),
            "graphsage" | "sage" | "gs" => Some(ModelKind::GraphSage),
            "gin" => Some(ModelKind::Gin),
            "gat" => Some(ModelKind::Gat),
            _ => None,
        }
    }
}

/// Reduce operation of the aggregation stage (§3.3.1: the reduce unit
/// supports sum, mean via the trailing scaling MR, and max via the optical
/// comparator).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Reduction {
    Sum,
    Mean,
    Max,
}

/// Execution ordering a model requires (§3.4.2 / Fig. 6).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ExecOrdering {
    /// Gather → reduce → transform → update (GCN, GraphSAGE, GIN).
    AggregateFirst,
    /// Gather → transform (+attention) → update → … → reduce at the end
    /// (GAT).
    TransformFirst,
}

/// Non-linearity applied by the update block.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Activation {
    /// SOA-implemented (gain ≈ 1) ReLU — optical.
    Relu,
    /// LeakyReLU for GAT attention — optical (SOA with adjusted gain).
    LeakyRelu,
    /// Digital LUT softmax [37] — electronic, 294 MHz.
    Softmax,
    /// No activation (final layer logits).
    None,
}

/// One GNN layer as mapped onto the accelerator.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LayerSpec {
    /// Input feature dimensionality.
    pub in_dim: usize,
    /// Output feature dimensionality (per head).
    pub out_dim: usize,
    /// Attention heads (1 for non-GAT layers).
    pub heads: usize,
    /// Aggregation reduce op; `None` for pure-MLP layers (GIN's inner MLP).
    pub reduction: Option<Reduction>,
    /// Update-block activation.
    pub activation: Activation,
    /// Neighbor sample cap (GraphSAGE); `None` aggregates the full
    /// neighborhood.
    pub neighbor_sample: Option<usize>,
}

/// A model instantiated for a dataset.
#[derive(Debug, Clone, PartialEq)]
pub struct Model {
    pub kind: ModelKind,
    pub layers: Vec<LayerSpec>,
    pub ordering: ExecOrdering,
    /// Graph-classification models add a readout (sum pool) + classifier.
    pub has_readout: bool,
}

/// Hidden width for GCN/GraphSAGE, and per-head width for GAT layer 1.
pub const HIDDEN_DIM: usize = 16;
/// GIN hidden width.
pub const GIN_HIDDEN: usize = 64;
/// GAT layer-1 heads (paper: 8 then 1).
pub const GAT_HEADS: usize = 8;
/// GAT per-head hidden width.
pub const GAT_HEAD_DIM: usize = 8;
/// GraphSAGE neighbor sample size (standard [13] fan-out).
pub const SAGE_SAMPLE: usize = 25;

impl Model {
    /// Instantiate the paper's configuration of `kind` for a dataset.
    pub fn for_dataset(kind: ModelKind, ds: &DatasetSpec) -> Self {
        let f = ds.n_features;
        let c = ds.n_labels;
        match kind {
            ModelKind::Gcn => Model {
                kind,
                ordering: ExecOrdering::AggregateFirst,
                has_readout: false,
                layers: vec![
                    LayerSpec {
                        in_dim: f,
                        out_dim: HIDDEN_DIM,
                        heads: 1,
                        reduction: Some(Reduction::Mean),
                        activation: Activation::Relu,
                        neighbor_sample: None,
                    },
                    LayerSpec {
                        in_dim: HIDDEN_DIM,
                        out_dim: c,
                        heads: 1,
                        reduction: Some(Reduction::Mean),
                        activation: Activation::None,
                        neighbor_sample: None,
                    },
                ],
            },
            ModelKind::GraphSage => Model {
                kind,
                ordering: ExecOrdering::AggregateFirst,
                has_readout: false,
                layers: vec![
                    LayerSpec {
                        in_dim: f,
                        out_dim: HIDDEN_DIM,
                        heads: 1,
                        reduction: Some(Reduction::Mean),
                        activation: Activation::Relu,
                        neighbor_sample: Some(SAGE_SAMPLE),
                    },
                    LayerSpec {
                        in_dim: HIDDEN_DIM,
                        out_dim: c,
                        heads: 1,
                        reduction: Some(Reduction::Mean),
                        activation: Activation::None,
                        neighbor_sample: Some(SAGE_SAMPLE),
                    },
                ],
            },
            ModelKind::Gin => {
                // Two GIN convolutions, each with a 4-layer MLP → the
                // paper's 8 MLP layers; sum readout + linear classifier.
                let mut layers = Vec::new();
                for conv in 0..2 {
                    let in0 = if conv == 0 { f } else { GIN_HIDDEN };
                    // First MLP layer of the conv aggregates neighbors.
                    layers.push(LayerSpec {
                        in_dim: in0,
                        out_dim: GIN_HIDDEN,
                        heads: 1,
                        reduction: Some(Reduction::Sum),
                        activation: Activation::Relu,
                        neighbor_sample: None,
                    });
                    for _ in 0..3 {
                        layers.push(LayerSpec {
                            in_dim: GIN_HIDDEN,
                            out_dim: GIN_HIDDEN,
                            heads: 1,
                            reduction: None,
                            activation: Activation::Relu,
                            neighbor_sample: None,
                        });
                    }
                }
                // Classifier over the pooled graph embedding.
                layers.push(LayerSpec {
                    in_dim: GIN_HIDDEN,
                    out_dim: c,
                    heads: 1,
                    reduction: None,
                    activation: Activation::None,
                    neighbor_sample: None,
                });
                Model {
                    kind,
                    ordering: ExecOrdering::AggregateFirst,
                    has_readout: true,
                    layers,
                }
            }
            ModelKind::Gat => Model {
                kind,
                ordering: ExecOrdering::TransformFirst,
                has_readout: false,
                layers: vec![
                    LayerSpec {
                        in_dim: f,
                        out_dim: GAT_HEAD_DIM,
                        heads: GAT_HEADS,
                        reduction: Some(Reduction::Sum),
                        activation: Activation::Softmax, // attention softmax
                        neighbor_sample: None,
                    },
                    LayerSpec {
                        in_dim: GAT_HEADS * GAT_HEAD_DIM,
                        out_dim: c,
                        heads: 1,
                        reduction: Some(Reduction::Sum),
                        activation: Activation::Softmax,
                        neighbor_sample: None,
                    },
                ],
            },
        }
    }

    /// Count of MLP (non-aggregating) + conv layers; sanity handle.
    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    /// Total weight parameter count (including attention vectors for GAT).
    pub fn n_parameters(&self) -> usize {
        self.layers
            .iter()
            .map(|l| {
                let w = l.in_dim * l.out_dim * l.heads;
                let attn = if self.kind == ModelKind::Gat { 2 * l.out_dim * l.heads } else { 0 };
                w + attn
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets::spec_by_name;

    #[test]
    fn gcn_is_two_layers() {
        let ds = spec_by_name("Cora").unwrap();
        let m = Model::for_dataset(ModelKind::Gcn, &ds);
        assert_eq!(m.n_layers(), 2);
        assert_eq!(m.layers[0].in_dim, 1433);
        assert_eq!(m.layers[1].out_dim, 7);
        assert_eq!(m.ordering, ExecOrdering::AggregateFirst);
    }

    #[test]
    fn gin_mlp_totals_eight_layers() {
        let ds = spec_by_name("Mutag").unwrap();
        let m = Model::for_dataset(ModelKind::Gin, &ds);
        // 8 MLP layers + classifier.
        assert_eq!(m.n_layers(), 9);
        assert!(m.has_readout);
        assert_eq!(m.layers.iter().filter(|l| l.reduction.is_some()).count(), 2);
    }

    #[test]
    fn gat_heads_match_paper() {
        let ds = spec_by_name("PubMed").unwrap();
        let m = Model::for_dataset(ModelKind::Gat, &ds);
        assert_eq!(m.layers[0].heads, 8);
        assert_eq!(m.layers[1].heads, 1);
        assert_eq!(m.layers[1].in_dim, 64);
        assert_eq!(m.ordering, ExecOrdering::TransformFirst);
    }

    #[test]
    fn sage_samples_neighbors() {
        let ds = spec_by_name("Amazon").unwrap();
        let m = Model::for_dataset(ModelKind::GraphSage, &ds);
        assert_eq!(m.layers[0].neighbor_sample, Some(SAGE_SAMPLE));
    }

    #[test]
    fn model_dataset_pairing() {
        assert_eq!(ModelKind::Gin.datasets()[0], "Proteins");
        assert_eq!(ModelKind::Gcn.datasets()[0], "Cora");
    }

    #[test]
    fn parameter_counts_positive() {
        for kind in ModelKind::ALL {
            for ds in kind.datasets() {
                let spec = spec_by_name(ds).unwrap();
                let m = Model::for_dataset(kind, &spec);
                assert!(m.n_parameters() > 0);
            }
        }
    }
}
