//! EPB / GOPS / EPB-per-GOPS accounting — the shared metric convention for
//! GHOST and every baseline (Figs. 10–12).


/// Throughput/efficiency metrics of one workload execution on one platform.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Metrics {
    /// End-to-end latency, seconds.
    pub latency_s: f64,
    /// Total energy, joules.
    pub energy_j: f64,
    /// Ops executed (2 per MAC + 1 per add/activation; shared convention).
    pub ops: u64,
    /// Bits moved across the memory interface.
    pub bits: u64,
}

impl Metrics {
    /// Giga-operations per second.
    pub fn gops(&self) -> f64 {
        self.ops as f64 / self.latency_s / 1e9
    }

    /// Energy per bit, joules/bit.
    pub fn epb(&self) -> f64 {
        self.energy_j / self.bits as f64
    }

    /// The paper's combined figure of merit (lower is better).
    pub fn epb_per_gops(&self) -> f64 {
        self.epb() / self.gops()
    }

    /// Average power, watts.
    pub fn power_w(&self) -> f64 {
        self.energy_j / self.latency_s
    }
}

/// Geometric mean of a sequence of positive ratios — the paper's "on
/// average n× better" aggregation across model × dataset pairs.
pub fn geomean(values: impl IntoIterator<Item = f64>) -> f64 {
    let mut log_sum = 0.0;
    let mut n = 0usize;
    for v in values {
        debug_assert!(v > 0.0);
        log_sum += v.ln();
        n += 1;
    }
    if n == 0 {
        return f64::NAN;
    }
    (log_sum / n as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn metrics_arithmetic() {
        let m = Metrics { latency_s: 1e-3, energy_j: 1e-2, ops: 2_000_000_000, bits: 1_000_000 };
        assert!((m.gops() - 2000.0).abs() < 1e-9);
        assert!((m.epb() - 1e-8).abs() < 1e-20);
        assert!((m.power_w() - 10.0).abs() < 1e-9);
        assert!((m.epb_per_gops() - 1e-8 / 2000.0).abs() < 1e-20);
    }

    #[test]
    fn geomean_basics() {
        assert!((geomean([4.0, 1.0]) - 2.0).abs() < 1e-12);
        assert!((geomean([10.0, 10.0, 10.0]) - 10.0).abs() < 1e-9);
        assert!(geomean(std::iter::empty()).is_nan());
    }
}
