//! Laser-power sizing — the paper's second eq. 13 (§4.1):
//!
//! `P_laser − S_detector ≥ P_photo_loss + 10·log₁₀(N_λ)`
//!
//! The laser must overcome every loss on the optical path plus the 1/N_λ
//! power split across wavelengths, and still deliver the photodetector's
//! sensitivity at the output.

use super::devices::{dbm_to_watts, DeviceParams};

/// Loss accumulated along one optical path, in dB.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PathLoss {
    /// Waveguide length traversed, cm.
    pub waveguide_cm: f64,
    /// Number of splitters on the path.
    pub splitters: usize,
    /// Number of combiners on the path.
    pub combiners: usize,
    /// Number of MRs passed *through* (off-resonance).
    pub mr_throughs: usize,
    /// Number of MRs that actively modulate the signal.
    pub mr_modulations: usize,
    /// EO-tuned waveguide length, cm (EO junctions add 6 dB/cm).
    pub eo_cm: f64,
}

impl PathLoss {
    /// Total path loss in dB for the given device parameter set.
    pub fn total_db(&self, p: &DeviceParams) -> f64 {
        self.waveguide_cm * p.waveguide_loss_db_per_cm
            + self.splitters as f64 * p.splitter_loss_db
            + self.combiners as f64 * p.combiner_loss_db
            + self.mr_throughs as f64 * p.mr_through_loss_db
            + self.mr_modulations as f64 * p.mr_modulation_loss_db
            + self.eo_cm * p.eo_tuning_loss_db_per_cm
    }
}

/// Required laser output power (dBm) for a path with `n_wavelengths`
/// multiplexed channels and total photonic loss `path_loss_db`.
pub fn required_laser_dbm(p: &DeviceParams, path_loss_db: f64, n_wavelengths: usize) -> f64 {
    p.pd_sensitivity_dbm + path_loss_db + 10.0 * (n_wavelengths.max(1) as f64).log10()
}

/// Electrical power (watts) drawn to produce the required optical power,
/// given the wall-plug efficiency.
pub fn laser_electrical_w(p: &DeviceParams, path_loss_db: f64, n_wavelengths: usize) -> f64 {
    dbm_to_watts(required_laser_dbm(p, path_loss_db, n_wavelengths)) / p.laser_wall_plug_efficiency
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loss_budget_adds_up() {
        let p = DeviceParams::paper();
        let path = PathLoss {
            waveguide_cm: 1.0,
            splitters: 2,
            combiners: 1,
            mr_throughs: 10,
            mr_modulations: 2,
            eo_cm: 0.01,
        };
        let db = path.total_db(&p);
        let expect = 1.0 + 2.0 * 0.13 + 0.9 + 10.0 * 0.02 + 2.0 * 0.72 + 0.01 * 6.0;
        assert!((db - expect).abs() < 1e-9, "db = {db}, expect = {expect}");
    }

    #[test]
    fn laser_power_grows_with_wavelength_count() {
        let p = DeviceParams::paper();
        let one = required_laser_dbm(&p, 3.0, 1);
        let eighteen = required_laser_dbm(&p, 3.0, 18);
        // 18 wavelengths cost 10·log10(18) ≈ 12.6 dB more.
        assert!((eighteen - one - 12.55).abs() < 0.05);
    }

    #[test]
    fn electrical_power_positive_and_sane() {
        let p = DeviceParams::paper();
        let w = laser_electrical_w(&p, 5.0, 18);
        // −20 dBm sensitivity + 5 dB loss + 12.6 dB → ≈ −2.4 dBm ≈ 0.57 mW
        // optical → ~2.3 mW electrical at 25 % wall-plug.
        assert!(w > 1e-3 && w < 1e-2, "w = {w}");
    }
}
