//! Hybrid EO/TO tuning circuit with TED thermal-crosstalk cancellation
//! (paper §3.1).
//!
//! Small resonance shifts are imprinted with fast, low-power electro-optic
//! (EO) tuning; shifts beyond the EO range fall back to thermo-optic (TO)
//! heaters. Heaters thermally couple to their neighbors; the Thermal
//! Eigenmode Decomposition method (TED, [32]) pre-solves the coupling so
//! each ring lands on target without iterative re-trimming. We model the
//! heater array as a linear system `K·p = t` (coupling matrix `K`, heater
//! powers `p`, target thermal shifts `t`) and solve it directly — the
//! matrix-form equivalent of TED for the steady state.

use super::devices::DeviceParams;
use super::mr::MicroringDesign;

/// Maximum resonance shift the EO junction can induce, nm. BaTiO₃-class EO
/// tuning [29] covers sub-nm shifts; larger excursions need the heater.
pub const EO_RANGE_NM: f64 = 1.0;

/// Thermal coupling between adjacent heaters in an MR bank (fraction of a
/// heater's shift felt by its nearest neighbor; decays geometrically with
/// distance). Value in the range measured for 10 µm-pitch SOI banks [32].
pub const NEIGHBOR_COUPLING: f64 = 0.15;

/// One tuning event: how a requested resonance shift is realized.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TuningEvent {
    /// Requested shift, nm.
    pub shift_nm: f64,
    /// Latency to settle, seconds.
    pub latency_s: f64,
    /// Energy consumed, joules.
    pub energy_j: f64,
    /// True if the slow TO path was needed.
    pub used_thermal: bool,
}

/// Plan a single-MR tuning event under the hybrid policy.
pub fn plan_tuning(p: &DeviceParams, mr: &MicroringDesign, shift_nm: f64) -> TuningEvent {
    let shift = shift_nm.abs();
    if shift <= EO_RANGE_NM {
        // EO: 20 ns settle, 4 µW/nm held for the settle window.
        let power = p.eo_tuning.power_w * shift;
        TuningEvent {
            shift_nm,
            latency_s: p.eo_tuning.latency_s,
            energy_j: power * p.eo_tuning.latency_s,
            used_thermal: false,
        }
    } else {
        // TO: 4 µs settle, 27.5 mW per FSR of shift; EO handles the
        // residual fine trim within the same window.
        let fsr_nm = mr.fsr_m() * 1e9;
        let power = p.to_tuning.power_w * (shift / fsr_nm);
        TuningEvent {
            shift_nm,
            latency_s: p.to_tuning.latency_s,
            energy_j: power * p.to_tuning.latency_s,
            used_thermal: true,
        }
    }
}

/// Symmetric thermal-coupling matrix for a linear bank of `n` heaters:
/// `K[i][j] = c^{|i−j|}` with `c =` [`NEIGHBOR_COUPLING`].
pub fn coupling_matrix(n: usize) -> Vec<Vec<f64>> {
    (0..n)
        .map(|i| {
            (0..n)
                .map(|j| NEIGHBOR_COUPLING.powi((i as i32 - j as i32).abs()))
                .collect()
        })
        .collect()
}

/// Solve `K·p = t` by Gaussian elimination with partial pivoting. Returns
/// the decoupled heater powers `p` (TED steady-state solution). `K` is
/// diagonally dominant for `c < 0.5`, so the solve is well-conditioned.
pub fn ted_solve(k: &[Vec<f64>], t: &[f64]) -> Vec<f64> {
    let n = t.len();
    assert_eq!(k.len(), n);
    let mut a: Vec<Vec<f64>> = k
        .iter()
        .zip(t)
        .map(|(row, &ti)| {
            let mut r = row.clone();
            r.push(ti);
            r
        })
        .collect();
    for col in 0..n {
        // Pivot.
        let piv = (col..n)
            .max_by(|&i, &j| a[i][col].abs().partial_cmp(&a[j][col].abs()).unwrap())
            .unwrap();
        a.swap(col, piv);
        let d = a[col][col];
        assert!(d.abs() > 1e-12, "singular thermal coupling matrix");
        for i in 0..n {
            if i != col {
                let f = a[i][col] / d;
                for j in col..=n {
                    a[i][j] -= f * a[col][j];
                }
            }
        }
    }
    (0..n).map(|i| a[i][n] / a[i][i]).collect()
}

/// Total TO heater power with TED (solving the coupling) vs naive
/// (each heater independently set to its target, then iteratively bumped to
/// fight its neighbors' heat — modeled as the Neumann-series overshoot
/// `Σ‖K−I‖` which the TED solve avoids). Returns `(ted_w, naive_w)`.
pub fn ted_power_saving(targets_fsr_fraction: &[f64], p: &DeviceParams) -> (f64, f64) {
    let n = targets_fsr_fraction.len();
    let k = coupling_matrix(n);
    let t: Vec<f64> = targets_fsr_fraction.iter().map(|&f| f * p.to_tuning.power_w).collect();
    let solved = ted_solve(&k, &t);
    let ted_w: f64 = solved.iter().map(|&x| x.abs()).sum();
    // Naive: every heater holds its own target, plus first-order
    // compensation for neighbor heating (the overshoot that TED removes).
    let naive_w: f64 = t
        .iter()
        .enumerate()
        .map(|(i, &ti)| {
            let spill: f64 = (0..n).filter(|&j| j != i).map(|j| k[i][j] * t[j]).sum();
            ti + spill
        })
        .sum();
    (ted_w, naive_w)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_shift_uses_eo() {
        let p = DeviceParams::paper();
        let mr = MicroringDesign::paper();
        let ev = plan_tuning(&p, &mr, 0.4);
        assert!(!ev.used_thermal);
        assert_eq!(ev.latency_s, 20e-9);
        // 0.4 nm × 4 µW/nm × 20 ns = 3.2e-14 J
        assert!((ev.energy_j - 0.4 * 4e-6 * 20e-9).abs() < 1e-20);
    }

    #[test]
    fn large_shift_uses_to() {
        let p = DeviceParams::paper();
        let mr = MicroringDesign::paper();
        let ev = plan_tuning(&p, &mr, 3.0);
        assert!(ev.used_thermal);
        assert_eq!(ev.latency_s, 4e-6);
        assert!(ev.energy_j > plan_tuning(&p, &mr, 0.4).energy_j);
    }

    #[test]
    fn ted_solve_exact() {
        let k = coupling_matrix(6);
        let t = vec![1.0, 0.5, 0.2, 0.8, 0.3, 0.9];
        let pwr = ted_solve(&k, &t);
        // K·p must reproduce t.
        for i in 0..6 {
            let recon: f64 = (0..6).map(|j| k[i][j] * pwr[j]).sum();
            assert!((recon - t[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn ted_saves_power() {
        let p = DeviceParams::paper();
        let targets = vec![0.3, 0.25, 0.4, 0.1, 0.35, 0.2, 0.3, 0.28];
        let (ted, naive) = ted_power_saving(&targets, &p);
        assert!(ted < naive, "ted = {ted}, naive = {naive}");
        // The saving should be meaningful (> 10 %) for a packed bank.
        assert!(ted < 0.9 * naive);
    }
}
