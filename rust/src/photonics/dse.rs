//! Device-level design-space exploration — Figs. 7(a) and 7(b).
//!
//! Sweeps the coherent-summation chain length (vs. operating wavelength)
//! and the non-coherent WDM bank size against the SNR cutoff of paper
//! eq. 12, reproducing the paper's feasibility frontiers:
//!
//! * coherent: up to **20 MRs** per summation chain at **1520 nm**,
//! * non-coherent: up to **36 MRs = 18 wavelengths** (1550–1568 nm, 1 nm
//!   channel spacing).


use super::crosstalk::{homodyne_noise, worst_case_heterodyne};
use super::devices::{linear_to_db, DeviceParams};
use super::mr::MicroringDesign;
use super::snr::required_snr_db;
use crate::config::N_LEVELS;

/// Feasibility frontier established by [`coherent_sweep`]; pinned here so
/// the architectural layer can validate against it cheaply.
pub const MAX_COHERENT_MRS: usize = 20;

/// Feasibility frontier established by [`noncoherent_sweep`].
pub const MAX_NONCOHERENT_WAVELENGTHS: usize = 18;

/// First wavelength of the non-coherent WDM comb, meters (paper §4.2).
pub const NONCOHERENT_BASE_LAMBDA_M: f64 = 1550e-9;

/// Channel spacing of the WDM comb, meters.
pub const CHANNEL_SPACING_M: f64 = 1e-9;

/// One sample of a device-level sweep.
#[derive(Debug, Clone, Copy)]
pub struct DsePoint {
    /// Operating (or base) wavelength, nm.
    pub lambda_nm: f64,
    /// Number of MRs in the circuit.
    pub n_mrs: usize,
    /// Achieved worst-case SNR, dB.
    pub snr_db: f64,
    /// Required SNR at this design point, dB (eq. 12 cutoff).
    pub cutoff_db: f64,
    /// Whether the point satisfies eq. 12.
    pub feasible: bool,
}

fn mr_at(lambda_m: f64) -> MicroringDesign {
    MicroringDesign { resonant_wavelength_m: lambda_m, ..MicroringDesign::paper() }
}

/// Achieved SNR of a coherent-summation chain of `n_mrs` rings at
/// wavelength `lambda_m`: the signal accumulates per-MR through losses
/// while eq.-6 homodyne leakage builds the noise floor.
pub fn coherent_snr_db(p: &DeviceParams, lambda_m: f64, n_mrs: usize) -> f64 {
    let signal = 1.0 / super::devices::db_to_linear(p.mr_through_loss_db * n_mrs as f64);
    let noise = homodyne_noise(n_mrs, lambda_m, p.mr_through_loss_db);
    linear_to_db(signal / noise)
}

/// Achieved worst-case SNR of a non-coherent WDM multiply circuit with
/// `n_wavelengths` channels starting at `base_lambda_m` with 1 nm spacing.
/// Signal and heterodyne leakage co-propagate through the same waveguide,
/// so path losses cancel in the ratio.
pub fn noncoherent_snr_db(base_lambda_m: f64, n_wavelengths: usize) -> f64 {
    let mid = base_lambda_m + CHANNEL_SPACING_M * (n_wavelengths as f64 - 1.0) / 2.0;
    let mr = mr_at(mid);
    let wavelengths: Vec<f64> =
        (0..n_wavelengths).map(|i| base_lambda_m + i as f64 * CHANNEL_SPACING_M).collect();
    let noise = worst_case_heterodyne(&mr, &wavelengths);
    linear_to_db(1.0 / noise)
}

/// Fig. 7(a): sweep coherent chain length × operating wavelength.
/// `lambdas_nm` defaults in callers to 1520..=1570 step 10.
pub fn coherent_sweep(p: &DeviceParams, lambdas_nm: &[f64], max_mrs: usize) -> Vec<DsePoint> {
    let mut out = Vec::new();
    for &lnm in lambdas_nm {
        let lm = lnm * 1e-9;
        let cutoff = required_snr_db(&mr_at(lm), N_LEVELS);
        for n in 2..=max_mrs {
            let snr = coherent_snr_db(p, lm, n);
            out.push(DsePoint {
                lambda_nm: lnm,
                n_mrs: n,
                snr_db: snr,
                cutoff_db: cutoff,
                feasible: snr >= cutoff,
            });
        }
    }
    out
}

/// Fig. 7(b): sweep the WDM bank size (x-axis in MRs = 2 × wavelengths,
/// as the multiply circuit needs an activation bank and a weight bank).
pub fn noncoherent_sweep(max_wavelengths: usize) -> Vec<DsePoint> {
    (2..=max_wavelengths)
        .map(|nw| {
            let mid =
                NONCOHERENT_BASE_LAMBDA_M + CHANNEL_SPACING_M * (nw as f64 - 1.0) / 2.0;
            let cutoff = required_snr_db(&mr_at(mid), N_LEVELS);
            let snr = noncoherent_snr_db(NONCOHERENT_BASE_LAMBDA_M, nw);
            DsePoint {
                lambda_nm: NONCOHERENT_BASE_LAMBDA_M * 1e9,
                n_mrs: 2 * nw,
                snr_db: snr,
                cutoff_db: cutoff,
                feasible: snr >= cutoff,
            }
        })
        .collect()
}

/// Largest feasible coherent chain at a given wavelength.
pub fn max_feasible_coherent(p: &DeviceParams, lambda_nm: f64, search_to: usize) -> usize {
    coherent_sweep(p, &[lambda_nm], search_to)
        .into_iter()
        .filter(|pt| pt.feasible)
        .map(|pt| pt.n_mrs)
        .max()
        .unwrap_or(0)
}

/// Largest feasible wavelength count for the non-coherent circuit.
pub fn max_feasible_noncoherent(search_to: usize) -> usize {
    noncoherent_sweep(search_to)
        .into_iter()
        .filter(|pt| pt.feasible)
        .map(|pt| pt.n_mrs / 2)
        .max()
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig7a_cutoff_is_20_mrs_at_1520() {
        let p = DeviceParams::paper();
        assert_eq!(max_feasible_coherent(&p, 1520.0, 40), MAX_COHERENT_MRS);
    }

    #[test]
    fn fig7a_higher_wavelengths_are_worse() {
        let p = DeviceParams::paper();
        let at_1520 = max_feasible_coherent(&p, 1520.0, 40);
        let at_1550 = max_feasible_coherent(&p, 1550.0, 40);
        let at_1570 = max_feasible_coherent(&p, 1570.0, 40);
        assert!(at_1550 < at_1520, "1550: {at_1550} vs 1520: {at_1520}");
        assert!(at_1570 <= at_1550);
    }

    #[test]
    fn fig7b_cutoff_is_18_wavelengths() {
        assert_eq!(max_feasible_noncoherent(30), MAX_NONCOHERENT_WAVELENGTHS);
    }

    #[test]
    fn fig7b_comb_spans_1550_to_1568() {
        // 18 wavelengths at 1 nm spacing from 1550 nm end at 1567 nm +
        // base = the paper's quoted 1550–1568 nm window (inclusive bounds).
        let last =
            NONCOHERENT_BASE_LAMBDA_M + 17.0 * CHANNEL_SPACING_M;
        assert!((last * 1e9 - 1567.0).abs() < 1e-6);
    }

    #[test]
    fn snr_decreases_with_bank_size() {
        let p = DeviceParams::paper();
        assert!(coherent_snr_db(&p, 1520e-9, 5) > coherent_snr_db(&p, 1520e-9, 20));
        assert!(noncoherent_snr_db(NONCOHERENT_BASE_LAMBDA_M, 4) > noncoherent_snr_db(NONCOHERENT_BASE_LAMBDA_M, 18));
    }

    #[test]
    fn sweep_shapes() {
        let p = DeviceParams::paper();
        let pts = coherent_sweep(&p, &[1520.0, 1550.0], 25);
        assert_eq!(pts.len(), 2 * 24);
        let pts = noncoherent_sweep(25);
        assert_eq!(pts.len(), 24);
    }
}
