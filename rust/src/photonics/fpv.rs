//! Fabrication-process-variation (FPV) analysis — the paper's §5 names FPV
//! as the key open silicon-photonic challenge and points at optical channel
//! remapping and intra-channel wavelength tuning [7] as mitigations. This
//! module implements that extension: die-level resonance-shift variation,
//! its SNR/tuning-power consequences, and both mitigation strategies.
//!
//! Model: each fabricated MR's resonance deviates from nominal by a
//! Gaussian shift (σ_fpv, typically 0.3–0.8 nm for SOI [27][49]). Untuned,
//! the deviation eats into the 2×FWHM tunable range and forces the hybrid
//! tuning circuit to burn TO power for shifts beyond the EO range.

use crate::photonics::devices::DeviceParams;
use crate::photonics::mr::MicroringDesign;
use crate::photonics::tuning::{plan_tuning, EO_RANGE_NM};
use crate::util::rng::Pcg64;

/// Die-level FPV model.
#[derive(Debug, Clone, Copy)]
pub struct FpvModel {
    /// Std-dev of the per-MR resonance shift, nm.
    pub sigma_nm: f64,
    /// Systematic (wafer-level) offset, nm.
    pub mean_nm: f64,
}

impl FpvModel {
    /// Typical SOI corner from the paper's own characterization work [27].
    pub fn typical() -> Self {
        Self { sigma_nm: 0.5, mean_nm: 0.2 }
    }

    /// Samples the resonance deviations of a bank of `n` MRs.
    pub fn sample_shifts(&self, n: usize, rng: &mut Pcg64) -> Vec<f64> {
        (0..n).map(|_| self.mean_nm + self.sigma_nm * rng.next_gaussian()).collect()
    }
}

/// Per-bank FPV compensation report.
#[derive(Debug, Clone, Copy)]
pub struct FpvReport {
    /// MRs whose deviation the fast EO tuner absorbs.
    pub eo_corrected: usize,
    /// MRs needing the slow TO heater.
    pub to_corrected: usize,
    /// Total static correction power, watts.
    pub correction_power_w: f64,
    /// Worst-case residual deviation after correction, nm (0 when every
    /// ring can be pulled on-grid).
    pub residual_nm: f64,
}

/// Corrects a sampled bank with the hybrid tuning circuit directly
/// (no remapping): every ring is pulled back to its nominal channel.
pub fn correct_direct(p: &DeviceParams, mr: &MicroringDesign, shifts_nm: &[f64]) -> FpvReport {
    let mut eo = 0;
    let mut to = 0;
    let mut power = 0.0;
    for &s in shifts_nm {
        let ev = plan_tuning(p, mr, s);
        if ev.used_thermal {
            to += 1;
            power += p.to_tuning.power_w * (s.abs() / (mr.fsr_m() * 1e9));
        } else {
            eo += 1;
            power += p.eo_tuning.power_w * s.abs();
        }
    }
    FpvReport { eo_corrected: eo, to_corrected: to, correction_power_w: power, residual_nm: 0.0 }
}

/// Optical channel remapping: instead of forcing ring `i` onto channel
/// `i`, greedily assign fabricated rings to the nearest free channel
/// (channels at `spacing_nm` apart), then EO/TO-trim the much smaller
/// residuals. This is the [7] mitigation the paper's conclusion cites.
pub fn correct_with_remapping(
    p: &DeviceParams,
    mr: &MicroringDesign,
    shifts_nm: &[f64],
    spacing_nm: f64,
) -> FpvReport {
    let n = shifts_nm.len();
    // Fabricated absolute detunings relative to channel 0, in channel
    // units: ring i sits near channel i + shift/spacing.
    let mut pos: Vec<(f64, usize)> = shifts_nm
        .iter()
        .enumerate()
        .map(|(i, &s)| (i as f64 + s / spacing_nm, i))
        .collect();
    // Sorted fabricated positions map onto sorted channel indices — the
    // optimal assignment for 1-D transport cost.
    pos.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    // Comb-level alignment ("intra-channel wavelength tuning" [7]): the
    // laser comb absorbs the mean systematic offset, leaving only the
    // ring-to-ring spread to trim.
    let comb_offset_nm = pos
        .iter()
        .enumerate()
        .map(|(rank, &(fab_pos, _))| (fab_pos - rank as f64) * spacing_nm)
        .sum::<f64>()
        / n.max(1) as f64;
    let mut eo = 0;
    let mut to = 0;
    let mut power = 0.0;
    let mut worst: f64 = 0.0;
    for (rank, &(fab_pos, _ring)) in pos.iter().enumerate() {
        let residual_nm = (fab_pos - rank as f64) * spacing_nm - comb_offset_nm;
        worst = worst.max(residual_nm.abs());
        let ev = plan_tuning(p, mr, residual_nm);
        if ev.used_thermal {
            to += 1;
            power += p.to_tuning.power_w * (residual_nm.abs() / (mr.fsr_m() * 1e9));
        } else {
            eo += 1;
            power += p.eo_tuning.power_w * residual_nm.abs();
        }
    }
    debug_assert_eq!(eo + to, n);
    FpvReport {
        eo_corrected: eo,
        to_corrected: to,
        correction_power_w: power,
        residual_nm: worst,
    }
}

/// Fraction of dies (banks) fully correctable with EO-only tuning, under
/// direct assignment vs remapping — the headline FPV-mitigation metric.
pub fn eo_only_yield(
    p: &DeviceParams,
    mr: &MicroringDesign,
    model: &FpvModel,
    bank_size: usize,
    spacing_nm: f64,
    trials: usize,
    seed: u64,
) -> (f64, f64) {
    let mut rng = Pcg64::seed_from_u64(seed);
    let mut direct_ok = 0;
    let mut remap_ok = 0;
    for _ in 0..trials {
        let shifts = model.sample_shifts(bank_size, &mut rng);
        if correct_direct(p, mr, &shifts).to_corrected == 0 {
            direct_ok += 1;
        }
        if correct_with_remapping(p, mr, &shifts, spacing_nm).to_corrected == 0 {
            remap_ok += 1;
        }
    }
    (direct_ok as f64 / trials as f64, remap_ok as f64 / trials as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (DeviceParams, MicroringDesign) {
        (DeviceParams::paper(), MicroringDesign::paper())
    }

    #[test]
    fn direct_correction_splits_eo_to_by_range() {
        let (p, mr) = setup();
        let shifts = vec![0.2, -0.8, 1.5, 0.0, -2.5];
        let r = correct_direct(&p, &mr, &shifts);
        assert_eq!(r.eo_corrected, 3); // |s| ≤ 1 nm
        assert_eq!(r.to_corrected, 2);
        assert!(r.correction_power_w > 0.0);
    }

    #[test]
    fn remapping_reduces_thermal_corrections() {
        let (p, mr) = setup();
        let model = FpvModel { sigma_nm: 0.8, mean_nm: 0.3 };
        let mut rng = Pcg64::seed_from_u64(11);
        let mut direct_to = 0usize;
        let mut remap_to = 0usize;
        for _ in 0..50 {
            let shifts = model.sample_shifts(18, &mut rng);
            direct_to += correct_direct(&p, &mr, &shifts).to_corrected;
            remap_to += correct_with_remapping(&p, &mr, &shifts, 1.0).to_corrected;
        }
        assert!(
            remap_to < direct_to,
            "remapping must cut TO corrections: direct={direct_to}, remap={remap_to}"
        );
    }

    #[test]
    fn remapping_improves_eo_only_yield() {
        let (p, mr) = setup();
        let model = FpvModel::typical();
        let (direct, remap) = eo_only_yield(&p, &mr, &model, 18, 1.0, 200, 42);
        assert!(remap >= direct, "remap yield {remap} < direct yield {direct}");
        assert!(remap > 0.3, "remapped yield should be non-trivial, got {remap}");
    }

    #[test]
    fn zero_variation_is_free() {
        let (p, mr) = setup();
        let shifts = vec![0.0; 10];
        let r = correct_direct(&p, &mr, &shifts);
        assert_eq!(r.to_corrected, 0);
        assert!(r.correction_power_w < 1e-12);
        let r2 = correct_with_remapping(&p, &mr, &shifts, 1.0);
        assert_eq!(r2.to_corrected, 0);
        assert!(r2.residual_nm < 1e-12);
    }

    #[test]
    fn systematic_offset_handled_by_remapping() {
        // A pure wafer-level offset shifts every ring identically —
        // remapping absorbs it almost entirely (rings keep their order).
        let (p, mr) = setup();
        let shifts = vec![1.4; 12]; // beyond EO range individually
        let direct = correct_direct(&p, &mr, &shifts);
        assert_eq!(direct.to_corrected, 12);
        let remap = correct_with_remapping(&p, &mr, &shifts, 1.0);
        // Comb alignment absorbs the offset entirely.
        assert_eq!(remap.to_corrected, 0);
        assert!(remap.residual_nm < 1e-9);
    }
}
