//! Crosstalk noise models — paper eqs. 2, 3 and 6.
//!
//! Two noise mechanisms limit MR bank sizes:
//!
//! * **Heterodyne (inter-channel) crosstalk** in the non-coherent WDM
//!   multiply circuits: power from neighboring wavelengths leaks through a
//!   ring's filter skirt (eqs. 2–3), plus a small incoherent scatter
//!   contribution from every ring a signal passes through.
//!
//! * **Homodyne (coherent) crosstalk** in the coherent-summation circuits:
//!   same-wavelength leakage with a phase mismatch interferes at the output
//!   (eq. 6: `P_hom = Σᵢ P_in · X_MR^i(ρ) · L_P^{n−i}`).
//!
//! The paper extracts the coupling factors `Φ(λᵢ, λⱼ, Q)` and
//! `X_MR(ρ)·L_P^{n−i}` from Ansys Lumerical multiphysics simulations that we
//! cannot run; we substitute closed-form models *calibrated to the paper's
//! own published design-space cutoffs* (Fig. 7): ≤ 20 MRs per coherent chain
//! at 1520 nm and ≤ 18 wavelengths (36 MRs) per non-coherent waveguide at
//! 1 nm spacing, both at the ≈ 21.2 dB SNR cutoff of eq. 12. The calibrated
//! constants ([`X_MR_REF`], [`SCATTER_PER_PASS`], the filter order) are all
//! within physically reported ranges for SOI add-drop rings [33].

use super::devices::db_to_linear;
use super::mr::MicroringDesign;

/// Per-MR same-wavelength leakage fraction `X_MR` at the reference
/// wavelength (1520 nm). −34.4 dB: calibrated so the coherent-summation
/// feasibility cutoff of Fig. 7(a) lands at exactly 20 MRs at 1520 nm.
pub const X_MR_REF: f64 = 3.6e-4;

/// Reference wavelength for [`X_MR_REF`], meters.
pub const X_MR_REF_LAMBDA_M: f64 = 1520e-9;

/// Wavelength scaling exponent for the homodyne leakage: the leaked
/// fraction grows with the resonance line width (∝ λ at fixed Q) and the
/// mode overlap; the quartic captures the steep Lumerical-observed trend
/// that makes 1520 nm the quietest operating point in the paper's sweep.
pub const X_MR_LAMBDA_EXP: i32 = 4;

/// Incoherent scatter coupled into a channel per off-resonance MR passage
/// in the WDM multiply circuit (−36.8 dB). Calibrated so the non-coherent
/// waveguide of Fig. 7(b) saturates at 18 wavelengths; within the
/// backscatter range measured for SOI rings.
pub const SCATTER_PER_PASS: f64 = 2.1e-4;

/// Effective filter order of the add-drop skirt suppressing neighboring
/// channels: the fabricated design's roll-off is steeper than a first-order
/// Lorentzian; the cubed line shape matches the paper's 1 nm channel
/// spacing feasibility.
pub const FILTER_ORDER: i32 = 3;

/// Spectra-overlap coupling factor `Φ(λᵢ, λⱼ, Q)` between two channels
/// (paper eqs. 2–3): the Lorentzian response of the ring tuned to `λᵢ`
/// evaluated at the neighbor `λⱼ`.
pub fn phi(mr: &MicroringDesign, lambda_i_m: f64, lambda_j_m: f64) -> f64 {
    mr.lorentzian(lambda_j_m - lambda_i_m)
}

/// Heterodyne noise power seen by channel `victim` in a WDM multiply bank
/// of `wavelengths` (paper eq. 3), normalized to unit per-channel input
/// power. Two terms:
///
/// * filtered adjacent-channel leakage `Σ_{j≠v} Φ(λᵥ, λⱼ)^FILTER_ORDER`,
/// * accumulated scatter from the `2·(n−1)` off-resonance rings the victim
///   traverses across the two MR banks of the multiply circuit.
pub fn heterodyne_noise(mr: &MicroringDesign, wavelengths_m: &[f64], victim: usize) -> f64 {
    let leak: f64 = wavelengths_m
        .iter()
        .enumerate()
        .filter(|&(j, _)| j != victim)
        .map(|(_, &lj)| phi(mr, wavelengths_m[victim], lj).powi(FILTER_ORDER))
        .sum();
    let passes = 2 * (wavelengths_m.len().saturating_sub(1));
    leak + passes as f64 * SCATTER_PER_PASS
}

/// Worst-case heterodyne noise over all channels in the bank (the victim
/// with the most/closest neighbors — the middle channel).
pub fn worst_case_heterodyne(mr: &MicroringDesign, wavelengths_m: &[f64]) -> f64 {
    (0..wavelengths_m.len())
        .map(|v| heterodyne_noise(mr, wavelengths_m, v))
        .fold(0.0, f64::max)
}

/// Same-wavelength leakage fraction of one MR at wavelength `lambda_m`
/// (see [`X_MR_REF`] / [`X_MR_LAMBDA_EXP`]).
pub fn x_mr(lambda_m: f64) -> f64 {
    X_MR_REF * (lambda_m / X_MR_REF_LAMBDA_M).powi(X_MR_LAMBDA_EXP)
}

/// Homodyne crosstalk noise power in a coherent-summation chain of
/// `n_mrs` rings (paper eq. 6), normalized to unit input power:
///
/// `P_hom = Σ_{i=1}^{n} X_MR(λ) · L_P^{n−i}`
///
/// where `L_P` is the linear per-MR passing transmission the leaked signal
/// experiences on its way to the output.
pub fn homodyne_noise(n_mrs: usize, lambda_m: f64, mr_through_loss_db: f64) -> f64 {
    let lp = 1.0 / db_to_linear(mr_through_loss_db); // transmission < 1
    let x = x_mr(lambda_m);
    (1..=n_mrs).map(|i| x * lp.powi((n_mrs - i) as i32)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn paper_mr() -> MicroringDesign {
        MicroringDesign::paper()
    }

    #[test]
    fn phi_symmetric_and_decaying() {
        let mr = paper_mr();
        let l0 = 1550e-9;
        let p1 = phi(&mr, l0, l0 + 1e-9);
        let p2 = phi(&mr, l0, l0 + 2e-9);
        assert!(p1 > p2, "coupling must decay with spacing");
        assert!((phi(&mr, l0, l0 + 1e-9) - phi(&mr, l0 + 1e-9, l0)).abs() < 1e-12);
    }

    #[test]
    fn heterodyne_grows_with_bank_size() {
        let mr = paper_mr();
        let mk = |n: usize| -> Vec<f64> { (0..n).map(|i| 1550e-9 + i as f64 * 1e-9).collect() };
        let n4 = worst_case_heterodyne(&mr, &mk(4));
        let n18 = worst_case_heterodyne(&mr, &mk(18));
        assert!(n18 > n4);
    }

    #[test]
    fn middle_channel_is_worst_victim() {
        let mr = paper_mr();
        let w: Vec<f64> = (0..9).map(|i| 1550e-9 + i as f64 * 1e-9).collect();
        let mid = heterodyne_noise(&mr, &w, 4);
        let edge = heterodyne_noise(&mr, &w, 0);
        assert!(mid > edge);
    }

    #[test]
    fn homodyne_monotone_in_n_and_lambda() {
        let loss = 0.02;
        assert!(homodyne_noise(20, 1520e-9, loss) > homodyne_noise(10, 1520e-9, loss));
        assert!(homodyne_noise(20, 1560e-9, loss) > homodyne_noise(20, 1520e-9, loss));
    }

    #[test]
    fn homodyne_scale_matches_calibration() {
        // 20 MRs at 1520 nm ≈ 20 × X_MR_REF (through loss ≈ 1).
        let p = homodyne_noise(20, 1520e-9, 0.02);
        let approx = 20.0 * X_MR_REF;
        assert!((p - approx).abs() / approx < 0.05, "p = {p}, approx = {approx}");
    }
}
