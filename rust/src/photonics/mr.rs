//! Microring-resonator (MR) device model.
//!
//! The paper extracts its MR operating point from Lumerical FDTD / CHARGE /
//! MODE / INTERCONNECT simulations (§4.2): ring + input waveguide width
//! 450 nm, radius 10 µm, gap 300 nm, Q ≈ 3100. We reproduce the *derived*
//! quantities those tools feed into the noise analysis with closed-form
//! models: the Lorentzian line shape of an all-pass/add-drop ring, the
//! FWHM = λ/Q relation (paper eq. 5), and the Q(a, κ) relation (paper
//! eq. 7).


/// Group index of the Si ridge waveguide used for FSR/Q calculations
/// (typical SOI value at 1550 nm).
pub const GROUP_INDEX: f64 = 4.2;

/// Geometric + spectral design of a single microring.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MicroringDesign {
    /// Ring radius, meters.
    pub radius_m: f64,
    /// Waveguide (ring and bus) width, meters.
    pub waveguide_width_m: f64,
    /// Bus-to-ring coupling gap, meters.
    pub gap_m: f64,
    /// Loaded quality factor.
    pub q_factor: f64,
    /// Resonant wavelength, meters.
    pub resonant_wavelength_m: f64,
}

impl MicroringDesign {
    /// The paper's chosen design point (§4.2): 450 nm width, 10 µm radius,
    /// 300 nm gap, Q = 3100, resonance at 1550 nm.
    pub fn paper() -> Self {
        Self {
            radius_m: 10e-6,
            waveguide_width_m: 450e-9,
            gap_m: 300e-9,
            q_factor: 3100.0,
            resonant_wavelength_m: 1550e-9,
        }
    }

    /// Ring circumference `L = 2πR`, meters.
    pub fn circumference_m(&self) -> f64 {
        2.0 * std::f64::consts::PI * self.radius_m
    }

    /// Full width at half maximum of the resonance, meters
    /// (paper eq. 5: `FWHM = λ_res / Q`).
    pub fn fwhm_m(&self) -> f64 {
        self.resonant_wavelength_m / self.q_factor
    }

    /// Free spectral range `FSR = λ² / (n_g · L)`, meters.
    pub fn fsr_m(&self) -> f64 {
        self.resonant_wavelength_m.powi(2) / (GROUP_INDEX * self.circumference_m())
    }

    /// Tunable range available for imprinting parameters: `2 × FWHM`
    /// (paper §3.2).
    pub fn tunable_range_m(&self) -> f64 {
        2.0 * self.fwhm_m()
    }

    /// Lorentzian drop-port transmission at detuning `delta_lambda_m` from
    /// resonance: `T(Δλ) = 1 / (1 + (2Δλ/FWHM)²)`. This is the line shape a
    /// first-order add-drop ring presents; it is also the spectra-overlap
    /// factor `Φ(λᵢ, λⱼ, Q)` of paper eqs. 2–3 when evaluated at the channel
    /// spacing.
    pub fn lorentzian(&self, delta_lambda_m: f64) -> f64 {
        let x = 2.0 * delta_lambda_m / self.fwhm_m();
        1.0 / (1.0 + x * x)
    }

    /// Loaded Q from round-trip amplitude transmission `a` and cross-over
    /// coupling coefficient `kappa` (paper eq. 7):
    ///
    /// `Q = π n_g L sqrt((1−κ²) a) / (λ (1 − a(1−κ²)))`.
    pub fn q_from_coupling(&self, a: f64, kappa: f64) -> f64 {
        let t2 = 1.0 - kappa * kappa; // |t|² = 1 − κ²
        let num = std::f64::consts::PI * GROUP_INDEX * self.circumference_m() * (t2 * a).sqrt();
        let den = self.resonant_wavelength_m * (1.0 - a * t2);
        num / den
    }

    /// Inverts eq. 7 for the coupling coefficient κ that yields this
    /// design's `q_factor` given round-trip amplitude transmission `a`
    /// (bisection; used by the homodyne-crosstalk mitigation study which
    /// trades κ against Q by widening the gap).
    pub fn kappa_for_q(&self, a: f64) -> Option<f64> {
        let (mut lo, mut hi) = (1e-4, 0.999);
        // Q decreases monotonically with κ (more coupling → lower Q).
        let f = |k: f64| self.q_from_coupling(a, k) - self.q_factor;
        if f(lo) < 0.0 || f(hi) > 0.0 {
            return None;
        }
        for _ in 0..80 {
            let mid = 0.5 * (lo + hi);
            if f(mid) > 0.0 {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        Some(0.5 * (lo + hi))
    }
}

impl Default for MicroringDesign {
    fn default() -> Self {
        Self::paper()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fwhm_is_half_nm_at_paper_point() {
        let mr = MicroringDesign::paper();
        // 1550 nm / 3100 = 0.5 nm
        assert!((mr.fwhm_m() - 0.5e-9).abs() < 1e-12);
        assert!((mr.tunable_range_m() - 1.0e-9).abs() < 1e-12);
    }

    #[test]
    fn lorentzian_shape() {
        let mr = MicroringDesign::paper();
        assert!((mr.lorentzian(0.0) - 1.0).abs() < 1e-12);
        // At Δλ = FWHM/2 the transmission is exactly 1/2.
        let half = mr.lorentzian(mr.fwhm_m() / 2.0);
        assert!((half - 0.5).abs() < 1e-12);
        // 1 nm away (two FWHM) it is strongly suppressed.
        assert!(mr.lorentzian(1e-9) < 0.06);
    }

    #[test]
    fn fsr_in_plausible_range() {
        let mr = MicroringDesign::paper();
        let fsr_nm = mr.fsr_m() * 1e9;
        // 10 µm radius Si ring: FSR ≈ 9 nm.
        assert!(fsr_nm > 5.0 && fsr_nm < 15.0, "fsr = {fsr_nm} nm");
    }

    #[test]
    fn q_coupling_round_trip() {
        let mr = MicroringDesign::paper();
        let a = 0.99; // low-loss ring
        let kappa = mr.kappa_for_q(a).expect("paper Q reachable");
        let q = mr.q_from_coupling(a, kappa);
        assert!((q - mr.q_factor).abs() / mr.q_factor < 1e-3);
        // Wider gap → smaller κ → larger Q (monotonicity used in §3.2).
        assert!(mr.q_from_coupling(a, kappa * 0.8) > q);
    }
}
