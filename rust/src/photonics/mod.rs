//! Photonic device and circuit models for the GHOST accelerator.
//!
//! Everything the paper obtains from Ansys Lumerical multiphysics sweeps is
//! reproduced here with closed-form models: Lorentzian microring line
//! shapes, the crosstalk coupling factors Φ(λᵢ, λⱼ, Q) and X_MR(ρ)·L_P^{n−i}
//! (paper eqs. 2–7), SNR feasibility (eqs. 8–13), laser-power sizing, and
//! the hybrid EO/TO tuning circuit with TED thermal-crosstalk cancellation.
//!
//! The [`dse`] submodule re-derives the paper's Fig. 7(a)/(b) bank-size
//! cutoffs (≤ 20 MRs coherent @ 1520 nm, ≤ 36 MRs = 18 wavelengths
//! non-coherent from 1550 nm at 1 nm spacing) from these models.

pub mod crosstalk;
pub mod devices;
pub mod dse;
pub mod fpv;
pub mod laser;
pub mod mr;
pub mod snr;
pub mod tuning;

pub use devices::DeviceParams;
pub use mr::MicroringDesign;
