//! Optoelectronic device parameters — Table 1 of the paper, plus the
//! photonic-loss budget from §4.1.
//!
//! All latencies are seconds, powers are watts, losses are dB. Sources are
//! the paper's citations: EO tuning [29], TO tuning [28], VCSEL/PD/SOA [10],
//! DAC [46], ADC [47], losses [42][44][45][29].


/// Latency + power pair for a single device.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Device {
    /// Per-operation latency in seconds.
    pub latency_s: f64,
    /// Active power draw in watts.
    pub power_w: f64,
}

impl Device {
    /// Energy of one operation, joules.
    pub fn energy_j(&self) -> f64 {
        self.latency_s * self.power_w
    }
}

/// The full Table-1 parameter set plus the loss budget.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DeviceParams {
    /// Electro-optic tuning: 20 ns, 4 µW/nm (power here is per-nm of shift;
    /// see [`crate::photonics::tuning`] for the nm-dependent energy).
    pub eo_tuning: Device,
    /// Thermo-optic tuning: 4 µs, 27.5 mW/FSR.
    pub to_tuning: Device,
    /// Vertical-cavity surface-emitting laser: 0.07 ns, 1.3 mW.
    pub vcsel: Device,
    /// Photodetector: 5.8 ps, 2.8 mW.
    pub photodetector: Device,
    /// Semiconductor optical amplifier (non-linearity): 0.3 ns, 2.2 mW.
    pub soa: Device,
    /// 8-bit DAC: 0.29 ns, 3 mW.
    pub dac: Device,
    /// 8-bit ADC: 0.82 ns, 3.1 mW.
    pub adc: Device,

    /// Waveguide propagation loss, dB/cm.
    pub waveguide_loss_db_per_cm: f64,
    /// Splitter loss, dB (0.13 dB [42]).
    pub splitter_loss_db: f64,
    /// Combiner loss, dB (0.9 dB [42]).
    pub combiner_loss_db: f64,
    /// MR through (passing) loss, dB (0.02 dB [44]).
    pub mr_through_loss_db: f64,
    /// MR modulation loss, dB (0.72 dB [45]).
    pub mr_modulation_loss_db: f64,
    /// EO tuning loss, dB/cm (6 dB/cm [29]).
    pub eo_tuning_loss_db_per_cm: f64,
    /// Photodetector sensitivity, dBm. The paper does not list it in
    /// Table 1; −20 dBm is the value used by the same group's CrossLight /
    /// RecLight accelerators and is assumed here (documented substitution).
    pub pd_sensitivity_dbm: f64,
    /// Laser wall-plug efficiency used to convert required optical power to
    /// electrical draw (VCSEL arrays, ≈ 25 %).
    pub laser_wall_plug_efficiency: f64,
    /// Digital LUT softmax unit max frequency, Hz (294 MHz design of [37]).
    pub softmax_freq_hz: f64,
}

impl DeviceParams {
    /// The exact Table-1 values.
    pub const fn paper() -> Self {
        Self {
            eo_tuning: Device { latency_s: 20e-9, power_w: 4e-6 },
            to_tuning: Device { latency_s: 4e-6, power_w: 27.5e-3 },
            vcsel: Device { latency_s: 0.07e-9, power_w: 1.3e-3 },
            photodetector: Device { latency_s: 5.8e-12, power_w: 2.8e-3 },
            soa: Device { latency_s: 0.3e-9, power_w: 2.2e-3 },
            dac: Device { latency_s: 0.29e-9, power_w: 3.0e-3 },
            adc: Device { latency_s: 0.82e-9, power_w: 3.1e-3 },
            waveguide_loss_db_per_cm: 1.0,
            splitter_loss_db: 0.13,
            combiner_loss_db: 0.9,
            mr_through_loss_db: 0.02,
            mr_modulation_loss_db: 0.72,
            eo_tuning_loss_db_per_cm: 6.0,
            pd_sensitivity_dbm: -20.0,
            laser_wall_plug_efficiency: 0.25,
            softmax_freq_hz: 294e6,
        }
    }
}

impl Default for DeviceParams {
    fn default() -> Self {
        Self::paper()
    }
}

/// dB → linear power ratio.
pub fn db_to_linear(db: f64) -> f64 {
    10f64.powf(db / 10.0)
}

/// Linear power ratio → dB.
pub fn linear_to_db(ratio: f64) -> f64 {
    10.0 * ratio.log10()
}

/// dBm → watts.
pub fn dbm_to_watts(dbm: f64) -> f64 {
    1e-3 * db_to_linear(dbm)
}

/// Watts → dBm.
pub fn watts_to_dbm(w: f64) -> f64 {
    linear_to_db(w / 1e-3)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_values() {
        let p = DeviceParams::paper();
        assert_eq!(p.eo_tuning.latency_s, 20e-9);
        assert_eq!(p.to_tuning.power_w, 27.5e-3);
        assert_eq!(p.vcsel.latency_s, 0.07e-9);
        assert_eq!(p.photodetector.latency_s, 5.8e-12);
        assert_eq!(p.soa.power_w, 2.2e-3);
        assert_eq!(p.dac.latency_s, 0.29e-9);
        assert_eq!(p.adc.power_w, 3.1e-3);
    }

    #[test]
    fn db_round_trip() {
        for &db in &[-30.0, -3.0, 0.0, 10.0, 21.3] {
            assert!((linear_to_db(db_to_linear(db)) - db).abs() < 1e-9);
        }
        assert!((dbm_to_watts(0.0) - 1e-3).abs() < 1e-12);
        assert!((watts_to_dbm(1e-3)).abs() < 1e-9);
    }

    #[test]
    fn device_energy() {
        let d = Device { latency_s: 1e-9, power_w: 2e-3 };
        assert!((d.energy_j() - 2e-12).abs() < 1e-20);
    }
}
