//! SNR feasibility analysis — paper eqs. 4 and 8–13.
//!
//! The design constraint tying noise to precision: the smallest amplitude
//! step the MRs must represent (`P_lpar`, eq. 11) has to stay above the
//! noise floor (eq. 8). Rearranged (eqs. 12–13) this gives the cutoff SNR
//! that the device-level DSE (Fig. 7) sweeps against:
//!
//! `SNR_required = 10·log₁₀(N_levels / R_tune)` with `R_tune = 2·FWHM`
//! expressed in nm — at the paper's design point (`Q = 3100`,
//! `λ = 1520–1550 nm`, `N_levels = 2⁷`) this evaluates to ≈ 21.2 dB, the
//! paper's "21.3 dB" within rounding.

use super::devices::linear_to_db;
use super::mr::MicroringDesign;

/// Signal-to-noise ratio in dB (paper eq. 4).
pub fn snr_db(p_signal: f64, p_noise: f64) -> f64 {
    linear_to_db(p_signal / p_noise)
}

/// The minimum SNR (dB) needed to resolve `n_levels` amplitude levels
/// across the tunable range of the given MR design (paper eq. 12 with
/// `R_tune = 2×FWHM` in nm, matching the paper's unit convention).
pub fn required_snr_db(mr: &MicroringDesign, n_levels: u32) -> f64 {
    let r_tune_nm = mr.tunable_range_m() * 1e9;
    linear_to_db(n_levels as f64 / r_tune_nm)
}

/// Eq. 13 feasibility check in its original form:
/// `2·λ_MR/Q > N_levels × 10^(−SNR/10)` — true when the design resolves all
/// levels at the achieved SNR.
pub fn feasible(mr: &MicroringDesign, n_levels: u32, achieved_snr_db: f64) -> bool {
    let lhs = 2.0 * mr.resonant_wavelength_m * 1e9 / mr.q_factor; // nm
    let rhs = n_levels as f64 * 10f64.powf(-achieved_snr_db / 10.0);
    lhs > rhs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::N_LEVELS;

    #[test]
    fn paper_cutoff_is_about_21_3_db() {
        let mr = MicroringDesign::paper();
        let snr = required_snr_db(&mr, N_LEVELS);
        // Paper reports 21.3 dB for the chosen design point.
        assert!((snr - 21.3).abs() < 0.4, "required SNR = {snr} dB");
    }

    #[test]
    fn feasibility_matches_required_snr() {
        let mr = MicroringDesign::paper();
        let cutoff = required_snr_db(&mr, N_LEVELS);
        assert!(feasible(&mr, N_LEVELS, cutoff + 0.1));
        assert!(!feasible(&mr, N_LEVELS, cutoff - 0.1));
    }

    #[test]
    fn more_levels_need_more_snr() {
        let mr = MicroringDesign::paper();
        assert!(required_snr_db(&mr, 256) > required_snr_db(&mr, 128));
        // One extra bit costs ~3 dB.
        let delta = required_snr_db(&mr, 256) - required_snr_db(&mr, 128);
        assert!((delta - 3.01).abs() < 0.05);
    }

    #[test]
    fn snr_db_basics() {
        assert!((snr_db(10.0, 1.0) - 10.0).abs() < 1e-9);
        assert!((snr_db(1.0, 1.0)).abs() < 1e-9);
    }
}
