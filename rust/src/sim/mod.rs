//! Pipeline-stage simulator.
//!
//! GHOST processes output-vertex groups through a fixed stage sequence
//! (gather → reduce → transform → update for GCN-family models; the GAT
//! ordering re-arranges the same stages, §3.4.2). Stage `s` of group `g`
//! can start only when stage `s−1` of the same group *and* stage `s` of the
//! previous group have finished — the classic non-reordering pipeline
//! recurrence, which this module evaluates exactly:
//!
//! `end[g][s] = max(end[g][s−1], end[g−1][s]) + t[g][s]`
//!
//! With pipelining disabled (the Fig. 8 baseline) groups and stages run
//! back-to-back and the makespan is the plain sum.
//!
//! Two evaluation families share the recurrence:
//!
//! * [`pipelined`] / [`sequential`] — latency-only rows (`Vec<f64>`), the
//!   original interface;
//! * [`pipelined_costs`] / [`sequential_costs`] — full [`StageCost`]
//!   schedules, returning makespan, total dynamic energy, and exact
//!   per-stage-position busy/energy totals in one pass. This is what the
//!   typed schedule IR ([`crate::coordinator::plan`]) evaluates.

use std::fmt;

use crate::arch::StageCost;

/// Per-stage latencies of one group, seconds. All groups in a schedule must
/// have the same stage count.
pub type GroupStages = Vec<f64>;

/// A pipelined schedule was handed groups with mismatched stage counts.
/// This used to be a `debug_assert` only: in `--release` a longer group
/// panicked on the recurrence array and a shorter one silently
/// under-accounted its missing stages. It is a real error now.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RaggedStages {
    /// Index of the first offending group.
    pub group: usize,
    /// Stage count of group 0 (the schedule's shape).
    pub expected: usize,
    /// Stage count of the offending group.
    pub got: usize,
}

impl fmt::Display for RaggedStages {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "ragged schedule: group {} has {} stage(s) but the schedule has {}",
            self.group, self.got, self.expected
        )
    }
}

impl std::error::Error for RaggedStages {}

/// Result of evaluating a schedule.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ScheduleResult {
    /// End-to-end makespan, seconds.
    pub makespan_s: f64,
    /// Sum of all stage latencies (the no-overlap lower bound on energy
    /// accounting and the sequential makespan).
    pub total_stage_time_s: f64,
}

/// Exact makespan of the two-level pipelined schedule (§3.4.2: stages
/// overlap within a group via the early-start rules, and group `V_{i+1}`
/// overlaps with `V_i`). Every group must carry the same stage count;
/// ragged input is a [`RaggedStages`] error, in `--release` too.
pub fn pipelined(groups: &[GroupStages]) -> Result<ScheduleResult, RaggedStages> {
    if groups.is_empty() {
        return Ok(ScheduleResult { makespan_s: 0.0, total_stage_time_s: 0.0 });
    }
    let n_stages = groups[0].len();
    let mut prev_end = vec![0.0f64; n_stages];
    let mut total = 0.0;
    for (gi, g) in groups.iter().enumerate() {
        if g.len() != n_stages {
            return Err(RaggedStages { group: gi, expected: n_stages, got: g.len() });
        }
        let mut cur_end = vec![0.0f64; n_stages];
        let mut prev_stage_end = 0.0f64;
        for (s, &t) in g.iter().enumerate() {
            let start = prev_stage_end.max(prev_end[s]);
            cur_end[s] = start + t;
            prev_stage_end = cur_end[s];
            total += t;
        }
        prev_end = cur_end;
    }
    Ok(ScheduleResult {
        makespan_s: prev_end.last().copied().unwrap_or(0.0),
        total_stage_time_s: total,
    })
}

/// Makespan with no pipelining: every stage of every group runs
/// sequentially.
pub fn sequential(groups: &[GroupStages]) -> ScheduleResult {
    let total: f64 = groups.iter().flat_map(|g| g.iter()).sum();
    ScheduleResult { makespan_s: total, total_stage_time_s: total }
}

/// Per-stage busy time across all groups — drives the Fig. 9 latency
/// breakdown. Ragged input is a [`RaggedStages`] error, exactly like
/// [`pipelined`]: a group longer than group 0 used to panic on the totals
/// index in `--release`, and a shorter one silently under-reported its
/// missing stages.
pub fn stage_totals(groups: &[GroupStages]) -> Result<Vec<f64>, RaggedStages> {
    if groups.is_empty() {
        return Ok(Vec::new());
    }
    let n_stages = groups[0].len();
    let mut totals = vec![0.0; n_stages];
    for (gi, g) in groups.iter().enumerate() {
        if g.len() != n_stages {
            return Err(RaggedStages { group: gi, expected: n_stages, got: g.len() });
        }
        for (s, &t) in g.iter().enumerate() {
            totals[s] += t;
        }
    }
    Ok(totals)
}

/// Result of evaluating a schedule whose stages carry full [`StageCost`]s:
/// the makespan plus the energy and per-stage-position busy totals, all
/// computed in the same single pass over the groups.
#[derive(Debug, Clone, PartialEq)]
pub struct CostScheduleResult {
    /// End-to-end makespan, seconds (identical to the latency-only
    /// evaluation of the same schedule).
    pub makespan_s: f64,
    /// Sum of all stage latencies.
    pub total_stage_time_s: f64,
    /// Total dynamic energy of every stage of every group, joules.
    pub energy_j: f64,
    /// Busy time per stage *position* across all groups, seconds
    /// (`stage_busy_s[s]` sums column `s`). Empty for an empty schedule.
    pub stage_busy_s: Vec<f64>,
    /// Dynamic energy per stage position across all groups, joules.
    pub stage_energy_j: Vec<f64>,
}

impl CostScheduleResult {
    fn empty() -> Self {
        CostScheduleResult {
            makespan_s: 0.0,
            total_stage_time_s: 0.0,
            energy_j: 0.0,
            stage_busy_s: Vec::new(),
            stage_energy_j: Vec::new(),
        }
    }
}

/// Exact makespan of the two-level pipelined schedule over full stage
/// costs — the same recurrence as [`pipelined`], evaluated on
/// `latency_s`, while energy and per-position busy totals accumulate in
/// the same pass. Every group must carry the same stage count.
pub fn pipelined_costs(groups: &[&[StageCost]]) -> Result<CostScheduleResult, RaggedStages> {
    if groups.is_empty() {
        return Ok(CostScheduleResult::empty());
    }
    let n_stages = groups[0].len();
    let mut prev_end = vec![0.0f64; n_stages];
    let mut total = 0.0;
    let mut energy = 0.0;
    let mut stage_busy_s = vec![0.0f64; n_stages];
    let mut stage_energy_j = vec![0.0f64; n_stages];
    for (gi, g) in groups.iter().enumerate() {
        if g.len() != n_stages {
            return Err(RaggedStages { group: gi, expected: n_stages, got: g.len() });
        }
        let mut cur_end = vec![0.0f64; n_stages];
        let mut prev_stage_end = 0.0f64;
        let mut group_energy = 0.0f64;
        for (s, c) in g.iter().enumerate() {
            let start = prev_stage_end.max(prev_end[s]);
            cur_end[s] = start + c.latency_s;
            prev_stage_end = cur_end[s];
            total += c.latency_s;
            stage_busy_s[s] += c.latency_s;
            stage_energy_j[s] += c.energy_j;
            group_energy += c.energy_j;
        }
        energy += group_energy;
        prev_end = cur_end;
    }
    Ok(CostScheduleResult {
        makespan_s: prev_end.last().copied().unwrap_or(0.0),
        total_stage_time_s: total,
        energy_j: energy,
        stage_busy_s,
        stage_energy_j,
    })
}

/// Cost-schedule evaluation with no pipelining: every stage of every group
/// runs sequentially (the makespan is the flat latency sum). Ragged groups
/// are tolerated, mirroring [`sequential`]; per-position totals are sized
/// to the longest group.
pub fn sequential_costs(groups: &[&[StageCost]]) -> CostScheduleResult {
    let n_stages = groups.iter().map(|g| g.len()).max().unwrap_or(0);
    let mut out = CostScheduleResult {
        stage_busy_s: vec![0.0; n_stages],
        stage_energy_j: vec![0.0; n_stages],
        ..CostScheduleResult::empty()
    };
    for g in groups {
        let mut group_energy = 0.0f64;
        for (s, c) in g.iter().enumerate() {
            out.makespan_s += c.latency_s;
            out.total_stage_time_s += c.latency_s;
            out.stage_busy_s[s] += c.latency_s;
            out.stage_energy_j[s] += c.energy_j;
            group_energy += c.energy_j;
        }
        out.energy_j += group_energy;
    }
    out
}

/// Makespan of a barriered multi-chip schedule. `chip_phases[c][p]` is the
/// local busy time chip `c` spends inside synchronization phase `p`; a
/// barrier at every phase boundary means phase `p + 1` starts (on every
/// chip) only when the slowest chip has finished phase `p`, so the
/// makespan is `Σ_p max_c chip_phases[c][p]`. Every chip must report the
/// same phase count — a chip skipping a barrier would deadlock the real
/// machine — so ragged input is a [`RaggedStages`] error (`group` is the
/// offending chip index). With one chip this reduces to the plain sum of
/// its phases.
pub fn barriered_makespan(chip_phases: &[Vec<f64>]) -> Result<f64, RaggedStages> {
    if chip_phases.is_empty() {
        return Ok(0.0);
    }
    let n_phases = chip_phases[0].len();
    for (ci, phases) in chip_phases.iter().enumerate() {
        if phases.len() != n_phases {
            return Err(RaggedStages { group: ci, expected: n_phases, got: phases.len() });
        }
    }
    let mut makespan = 0.0f64;
    for p in 0..n_phases {
        let mut slowest = 0.0f64;
        for phases in chip_phases {
            slowest = slowest.max(phases[p]);
        }
        makespan += slowest;
    }
    Ok(makespan)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_schedule() {
        assert_eq!(pipelined(&[]).unwrap().makespan_s, 0.0);
        assert_eq!(sequential(&[]).makespan_s, 0.0);
    }

    #[test]
    fn single_group_equals_sum() {
        let g = vec![vec![1.0, 2.0, 3.0]];
        assert_eq!(pipelined(&g).unwrap().makespan_s, 6.0);
        assert_eq!(sequential(&g).makespan_s, 6.0);
    }

    #[test]
    fn uniform_pipeline_formula() {
        // G groups of S stages, each of latency t:
        // makespan = (S + G − 1) · t.
        let g: Vec<GroupStages> = (0..10).map(|_| vec![1.0; 4]).collect();
        let r = pipelined(&g).unwrap();
        assert!((r.makespan_s - 13.0).abs() < 1e-12);
        assert!((sequential(&g).makespan_s - 40.0).abs() < 1e-12);
    }

    #[test]
    fn bottleneck_stage_dominates() {
        // One slow stage of latency 5 in each of 8 groups → makespan ≈
        // fill + 8×5.
        let g: Vec<GroupStages> = (0..8).map(|_| vec![1.0, 5.0, 1.0]).collect();
        let r = pipelined(&g).unwrap();
        assert!((r.makespan_s - (1.0 + 8.0 * 5.0 + 1.0)).abs() < 1e-12);
    }

    #[test]
    fn pipelined_never_slower_than_sequential() {
        let g: Vec<GroupStages> =
            (0..7).map(|i| vec![0.5 + i as f64, 2.0, 1.0 / (1 + i) as f64]).collect();
        assert!(pipelined(&g).unwrap().makespan_s <= sequential(&g).makespan_s + 1e-12);
    }

    #[test]
    fn stage_totals_sum() {
        let g = vec![vec![1.0, 2.0], vec![3.0, 4.0]];
        assert_eq!(stage_totals(&g).unwrap(), vec![4.0, 6.0]);
        assert!(stage_totals(&[]).unwrap().is_empty());
    }

    #[test]
    fn stage_totals_ragged_longer_group_is_an_error_not_a_panic() {
        // Pre-fix: `totals[s]` indexed out of bounds on the third stage.
        let g = vec![vec![1.0, 2.0], vec![1.0, 2.0, 3.0]];
        assert_eq!(
            stage_totals(&g).unwrap_err(),
            RaggedStages { group: 1, expected: 2, got: 3 }
        );
    }

    #[test]
    fn stage_totals_ragged_shorter_group_is_an_error_not_underreporting() {
        // Pre-fix: the short group's missing stages silently counted as 0.
        let g = vec![vec![1.0, 2.0, 3.0], vec![4.0], vec![1.0, 1.0, 1.0]];
        assert_eq!(
            stage_totals(&g).unwrap_err(),
            RaggedStages { group: 1, expected: 3, got: 1 }
        );
    }

    fn costs(rows: &[&[(f64, f64)]]) -> Vec<Vec<StageCost>> {
        rows.iter()
            .map(|r| {
                r.iter()
                    .map(|&(latency_s, energy_j)| StageCost { latency_s, energy_j })
                    .collect()
            })
            .collect()
    }

    fn views(groups: &[Vec<StageCost>]) -> Vec<&[StageCost]> {
        groups.iter().map(|g| g.as_slice()).collect()
    }

    #[test]
    fn cost_schedule_matches_latency_schedule() {
        let g = costs(&[
            &[(2.0, 1.0), (1.0, 0.5)],
            &[(1.0, 2.0), (3.0, 0.25)],
            &[(0.5, 4.0), (0.5, 8.0)],
        ]);
        let lat: Vec<GroupStages> =
            g.iter().map(|r| r.iter().map(|c| c.latency_s).collect()).collect();
        let c = pipelined_costs(&views(&g)).unwrap();
        let l = pipelined(&lat).unwrap();
        assert_eq!(c.makespan_s, l.makespan_s);
        assert_eq!(c.total_stage_time_s, l.total_stage_time_s);
        assert_eq!(c.stage_busy_s, stage_totals(&lat).unwrap());
        assert_eq!(c.energy_j, 1.0 + 0.5 + 2.0 + 0.25 + 4.0 + 8.0);
        assert_eq!(c.stage_energy_j, vec![1.0 + 2.0 + 4.0, 0.5 + 0.25 + 8.0]);
    }

    #[test]
    fn cost_schedule_sequential_is_flat_sum() {
        let g = costs(&[&[(1.0, 1.0), (2.0, 2.0)], &[(3.0, 4.0), (4.0, 8.0)]]);
        let c = sequential_costs(&views(&g));
        assert_eq!(c.makespan_s, 10.0);
        assert_eq!(c.total_stage_time_s, 10.0);
        assert_eq!(c.energy_j, 15.0);
        assert_eq!(c.stage_busy_s, vec![4.0, 6.0]);
        assert_eq!(c.stage_energy_j, vec![5.0, 10.0]);
    }

    #[test]
    fn cost_schedule_handles_empty_and_ragged() {
        let empty: Vec<&[StageCost]> = Vec::new();
        let c = pipelined_costs(&empty).unwrap();
        assert_eq!(c.makespan_s, 0.0);
        assert!(c.stage_busy_s.is_empty());
        assert_eq!(sequential_costs(&empty).makespan_s, 0.0);
        let g = costs(&[&[(1.0, 0.0)], &[(1.0, 0.0), (2.0, 0.0)]]);
        assert_eq!(
            pipelined_costs(&views(&g)).unwrap_err(),
            RaggedStages { group: 1, expected: 1, got: 2 }
        );
    }

    #[test]
    fn irregular_groups_exact() {
        // Hand-computed DP check.
        let g = vec![vec![2.0, 1.0], vec![1.0, 3.0]];
        // g0: s0 ends 2, s1 ends 3. g1: s0 starts max(0,2)=2 ends 3;
        // s1 starts max(3,3)=3 ends 6.
        assert!((pipelined(&g).unwrap().makespan_s - 6.0).abs() < 1e-12);
    }

    #[test]
    fn ragged_longer_group_is_an_error_not_a_panic() {
        // Pre-fix: index-out-of-bounds panic on `prev_end[s]` in --release.
        let g = vec![vec![1.0, 2.0], vec![1.0, 2.0, 3.0]];
        assert_eq!(
            pipelined(&g).unwrap_err(),
            RaggedStages { group: 1, expected: 2, got: 3 }
        );
    }

    #[test]
    fn ragged_shorter_group_is_an_error_not_underaccounting() {
        // Pre-fix: silently evaluated as if the missing stages were free.
        let g = vec![vec![1.0, 2.0, 3.0], vec![4.0], vec![1.0, 1.0, 1.0]];
        assert_eq!(
            pipelined(&g).unwrap_err(),
            RaggedStages { group: 1, expected: 3, got: 1 }
        );
    }

    #[test]
    fn ragged_error_displays_context() {
        let e = RaggedStages { group: 3, expected: 4, got: 2 };
        let msg = e.to_string();
        assert!(msg.contains('3') && msg.contains('4') && msg.contains('2'), "{msg}");
    }

    #[test]
    fn barriered_makespan_is_sum_of_phase_maxima() {
        // Chip 0: [2, 1, 4], chip 1: [1, 3, 2] → 2 + 3 + 4 = 9.
        let phases = vec![vec![2.0, 1.0, 4.0], vec![1.0, 3.0, 2.0]];
        assert_eq!(barriered_makespan(&phases).unwrap(), 9.0);
    }

    #[test]
    fn barriered_single_chip_reduces_to_sum() {
        let phases = vec![vec![1.5, 2.5, 3.0]];
        assert_eq!(barriered_makespan(&phases).unwrap(), 7.0);
        assert_eq!(barriered_makespan(&[]).unwrap(), 0.0);
    }

    #[test]
    fn barriered_ragged_chip_is_an_error() {
        let phases = vec![vec![1.0, 2.0], vec![1.0]];
        assert_eq!(
            barriered_makespan(&phases).unwrap_err(),
            RaggedStages { group: 1, expected: 2, got: 1 }
        );
    }
}
