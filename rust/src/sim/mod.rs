//! Pipeline-stage simulator.
//!
//! GHOST processes output-vertex groups through a fixed stage sequence
//! (gather → reduce → transform → update for GCN-family models; the GAT
//! ordering re-arranges the same stages, §3.4.2). Stage `s` of group `g`
//! can start only when stage `s−1` of the same group *and* stage `s` of the
//! previous group have finished — the classic non-reordering pipeline
//! recurrence, which this module evaluates exactly:
//!
//! `end[g][s] = max(end[g][s−1], end[g−1][s]) + t[g][s]`
//!
//! With pipelining disabled (the Fig. 8 baseline) groups and stages run
//! back-to-back and the makespan is the plain sum.
//!
//! Two evaluation families share the recurrence:
//!
//! * [`pipelined`] / [`sequential`] — latency-only rows (`Vec<f64>`), the
//!   original interface;
//! * [`pipelined_costs`] / [`sequential_costs`] — full [`StageCost`]
//!   schedules, returning makespan, total dynamic energy, and exact
//!   per-stage-position busy/energy totals in one pass. This is what the
//!   typed schedule IR ([`crate::coordinator::plan`]) evaluates.

use std::fmt;

use crate::arch::StageCost;

/// Per-stage latencies of one group, seconds. All groups in a schedule must
/// have the same stage count.
pub type GroupStages = Vec<f64>;

/// A pipelined schedule was handed groups with mismatched stage counts.
/// This used to be a `debug_assert` only: in `--release` a longer group
/// panicked on the recurrence array and a shorter one silently
/// under-accounted its missing stages. It is a real error now.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RaggedStages {
    /// Index of the first offending group.
    pub group: usize,
    /// Stage count of group 0 (the schedule's shape).
    pub expected: usize,
    /// Stage count of the offending group.
    pub got: usize,
}

impl fmt::Display for RaggedStages {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "ragged schedule: group {} has {} stage(s) but the schedule has {}",
            self.group, self.got, self.expected
        )
    }
}

impl std::error::Error for RaggedStages {}

/// Result of evaluating a schedule.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ScheduleResult {
    /// End-to-end makespan, seconds.
    pub makespan_s: f64,
    /// Sum of all stage latencies (the no-overlap lower bound on energy
    /// accounting and the sequential makespan).
    pub total_stage_time_s: f64,
}

/// Exact makespan of the two-level pipelined schedule (§3.4.2: stages
/// overlap within a group via the early-start rules, and group `V_{i+1}`
/// overlaps with `V_i`). Every group must carry the same stage count;
/// ragged input is a [`RaggedStages`] error, in `--release` too.
pub fn pipelined(groups: &[GroupStages]) -> Result<ScheduleResult, RaggedStages> {
    if groups.is_empty() {
        return Ok(ScheduleResult { makespan_s: 0.0, total_stage_time_s: 0.0 });
    }
    let n_stages = groups[0].len();
    let mut prev_end = vec![0.0f64; n_stages];
    let mut total = 0.0;
    for (gi, g) in groups.iter().enumerate() {
        if g.len() != n_stages {
            return Err(RaggedStages { group: gi, expected: n_stages, got: g.len() });
        }
        let mut cur_end = vec![0.0f64; n_stages];
        let mut prev_stage_end = 0.0f64;
        for (s, &t) in g.iter().enumerate() {
            let start = prev_stage_end.max(prev_end[s]);
            cur_end[s] = start + t;
            prev_stage_end = cur_end[s];
            total += t;
        }
        prev_end = cur_end;
    }
    Ok(ScheduleResult {
        makespan_s: prev_end.last().copied().unwrap_or(0.0),
        total_stage_time_s: total,
    })
}

/// Makespan with no pipelining: every stage of every group runs
/// sequentially.
pub fn sequential(groups: &[GroupStages]) -> ScheduleResult {
    let total: f64 = groups.iter().flat_map(|g| g.iter()).sum();
    ScheduleResult { makespan_s: total, total_stage_time_s: total }
}

/// Per-stage busy time across all groups — drives the Fig. 9 latency
/// breakdown. Ragged input is a [`RaggedStages`] error, exactly like
/// [`pipelined`]: a group longer than group 0 used to panic on the totals
/// index in `--release`, and a shorter one silently under-reported its
/// missing stages.
pub fn stage_totals(groups: &[GroupStages]) -> Result<Vec<f64>, RaggedStages> {
    if groups.is_empty() {
        return Ok(Vec::new());
    }
    let n_stages = groups[0].len();
    let mut totals = vec![0.0; n_stages];
    for (gi, g) in groups.iter().enumerate() {
        if g.len() != n_stages {
            return Err(RaggedStages { group: gi, expected: n_stages, got: g.len() });
        }
        for (s, &t) in g.iter().enumerate() {
            totals[s] += t;
        }
    }
    Ok(totals)
}

/// Result of evaluating a schedule whose stages carry full [`StageCost`]s:
/// the makespan plus the energy and per-stage-position busy totals, all
/// computed in the same single pass over the groups.
#[derive(Debug, Clone, PartialEq)]
pub struct CostScheduleResult {
    /// End-to-end makespan, seconds (identical to the latency-only
    /// evaluation of the same schedule).
    pub makespan_s: f64,
    /// Sum of all stage latencies.
    pub total_stage_time_s: f64,
    /// Total dynamic energy of every stage of every group, joules.
    pub energy_j: f64,
    /// Busy time per stage *position* across all groups, seconds
    /// (`stage_busy_s[s]` sums column `s`). Empty for an empty schedule.
    pub stage_busy_s: Vec<f64>,
    /// Dynamic energy per stage position across all groups, joules.
    pub stage_energy_j: Vec<f64>,
}

impl CostScheduleResult {
    fn empty() -> Self {
        CostScheduleResult {
            makespan_s: 0.0,
            total_stage_time_s: 0.0,
            energy_j: 0.0,
            stage_busy_s: Vec::new(),
            stage_energy_j: Vec::new(),
        }
    }
}

/// Copy an array-of-structs schedule into flat structure-of-arrays lanes
/// plus a CSR-style group offset table (mirroring `PartitionMatrix`'s flat
/// layout): `group_ptr[g]..group_ptr[g + 1]` indexes group `g`'s slots in
/// both lanes.
fn lanes_of(groups: &[&[StageCost]]) -> (Vec<f64>, Vec<f64>, Vec<usize>) {
    let n_slots: usize = groups.iter().map(|g| g.len()).sum();
    let mut latency_s = Vec::with_capacity(n_slots);
    let mut energy_j = Vec::with_capacity(n_slots);
    let mut group_ptr = Vec::with_capacity(groups.len() + 1);
    group_ptr.push(0);
    for g in groups {
        for c in *g {
            latency_s.push(c.latency_s);
            energy_j.push(c.energy_j);
        }
        group_ptr.push(latency_s.len());
    }
    (latency_s, energy_j, group_ptr)
}

/// Exact makespan of the two-level pipelined schedule over full stage
/// costs — the same recurrence as [`pipelined`], evaluated on
/// `latency_s`, while energy and per-position busy totals accumulate in
/// the same pass. Every group must carry the same stage count. Thin view
/// over [`pipelined_lanes`].
pub fn pipelined_costs(groups: &[&[StageCost]]) -> Result<CostScheduleResult, RaggedStages> {
    let (latency_s, energy_j, group_ptr) = lanes_of(groups);
    pipelined_lanes(&latency_s, &energy_j, &group_ptr)
}

/// [`pipelined_costs`] over structure-of-arrays lanes: flat `latency_s` /
/// `energy_j` slots partitioned into groups by the CSR offset table
/// `group_ptr` (`group_ptr[0] == 0`, `group_ptr.last() == slots`). The
/// recurrence runs tight over the lanes with no per-group allocation;
/// accumulation order is exactly that of the array-of-structs walk, so
/// results are bit-identical.
pub fn pipelined_lanes(
    latency_s: &[f64],
    energy_j: &[f64],
    group_ptr: &[usize],
) -> Result<CostScheduleResult, RaggedStages> {
    let n_groups = group_ptr.len().saturating_sub(1);
    if n_groups == 0 {
        return Ok(CostScheduleResult::empty());
    }
    let n_stages = group_ptr[1] - group_ptr[0];
    for gi in 0..n_groups {
        let got = group_ptr[gi + 1] - group_ptr[gi];
        if got != n_stages {
            return Err(RaggedStages { group: gi, expected: n_stages, got });
        }
    }
    let mut prev_end = vec![0.0f64; n_stages];
    let mut cur_end = vec![0.0f64; n_stages];
    let mut total = 0.0;
    let mut energy = 0.0;
    let mut stage_busy_s = vec![0.0f64; n_stages];
    let mut stage_energy_j = vec![0.0f64; n_stages];
    let lat_groups = latency_s[..n_groups * n_stages].chunks_exact(n_stages);
    let en_groups = energy_j[..n_groups * n_stages].chunks_exact(n_stages);
    for (lat, en) in lat_groups.zip(en_groups) {
        let mut prev_stage_end = 0.0f64;
        let mut group_energy = 0.0f64;
        for s in 0..n_stages {
            let start = prev_stage_end.max(prev_end[s]);
            cur_end[s] = start + lat[s];
            prev_stage_end = cur_end[s];
            total += lat[s];
            stage_busy_s[s] += lat[s];
            stage_energy_j[s] += en[s];
            group_energy += en[s];
        }
        energy += group_energy;
        std::mem::swap(&mut prev_end, &mut cur_end);
    }
    Ok(CostScheduleResult {
        makespan_s: prev_end.last().copied().unwrap_or(0.0),
        total_stage_time_s: total,
        energy_j: energy,
        stage_busy_s,
        stage_energy_j,
    })
}

/// Cost-schedule evaluation with no pipelining: every stage of every group
/// runs sequentially (the makespan is the flat latency sum). Ragged groups
/// are tolerated, mirroring [`sequential`]; per-position totals are sized
/// to the longest group. Thin view over [`sequential_lanes`].
pub fn sequential_costs(groups: &[&[StageCost]]) -> CostScheduleResult {
    let (latency_s, energy_j, group_ptr) = lanes_of(groups);
    sequential_lanes(&latency_s, &energy_j, &group_ptr)
}

/// [`sequential_costs`] over structure-of-arrays lanes (see
/// [`pipelined_lanes`] for the layout). Ragged groups are tolerated.
pub fn sequential_lanes(
    latency_s: &[f64],
    energy_j: &[f64],
    group_ptr: &[usize],
) -> CostScheduleResult {
    let n_groups = group_ptr.len().saturating_sub(1);
    let n_stages =
        (0..n_groups).map(|g| group_ptr[g + 1] - group_ptr[g]).max().unwrap_or(0);
    let mut out = CostScheduleResult {
        stage_busy_s: vec![0.0; n_stages],
        stage_energy_j: vec![0.0; n_stages],
        ..CostScheduleResult::empty()
    };
    for g in 0..n_groups {
        let mut group_energy = 0.0f64;
        for (s, slot) in (group_ptr[g]..group_ptr[g + 1]).enumerate() {
            out.makespan_s += latency_s[slot];
            out.total_stage_time_s += latency_s[slot];
            out.stage_busy_s[s] += latency_s[slot];
            out.stage_energy_j[s] += energy_j[slot];
            group_energy += energy_j[slot];
        }
        out.energy_j += group_energy;
    }
    out
}

/// Width of the fixed-size lane core used by the plan IR: every
/// `PipelineSegment` carries exactly this many stage positions per group
/// (`plan::PIPELINE_STAGES`).
pub const QUAD_WIDTH: usize = 4;

/// [`CostScheduleResult`] specialized to the plan IR's fixed four-stage
/// segments: per-position totals live in stack arrays, so evaluating a
/// segment allocates nothing. Field-by-field bit-identical to the
/// general result on the same lanes.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct QuadSched {
    /// End-to-end makespan, seconds.
    pub makespan_s: f64,
    /// Sum of all stage latencies.
    pub total_stage_time_s: f64,
    /// Total dynamic energy, joules.
    pub energy_j: f64,
    /// Busy time per stage position across all groups, seconds.
    pub stage_busy_s: [f64; QUAD_WIDTH],
    /// Dynamic energy per stage position across all groups, joules.
    pub stage_energy_j: [f64; QUAD_WIDTH],
}

/// Pipelined recurrence over width-4 lanes (`latency_s`/`energy_j` are
/// group-major, `4 * n_groups` slots each). Branch-free inner loop over
/// stack arrays; bit-identical to [`pipelined_lanes`] with a uniform
/// `group_ptr` of stride 4.
pub fn pipelined_quads(latency_s: &[f64], energy_j: &[f64]) -> QuadSched {
    debug_assert_eq!(latency_s.len() % QUAD_WIDTH, 0);
    debug_assert_eq!(latency_s.len(), energy_j.len());
    let mut out = QuadSched::default();
    let mut prev_end = [0.0f64; QUAD_WIDTH];
    for (lat, en) in
        latency_s.chunks_exact(QUAD_WIDTH).zip(energy_j.chunks_exact(QUAD_WIDTH))
    {
        let mut cur_end = [0.0f64; QUAD_WIDTH];
        let mut prev_stage_end = 0.0f64;
        let mut group_energy = 0.0f64;
        for s in 0..QUAD_WIDTH {
            let start = prev_stage_end.max(prev_end[s]);
            cur_end[s] = start + lat[s];
            prev_stage_end = cur_end[s];
            out.total_stage_time_s += lat[s];
            out.stage_busy_s[s] += lat[s];
            out.stage_energy_j[s] += en[s];
            group_energy += en[s];
        }
        out.energy_j += group_energy;
        prev_end = cur_end;
    }
    out.makespan_s = prev_end[QUAD_WIDTH - 1];
    out
}

/// Sequential (no-pipelining) evaluation over width-4 lanes; bit-identical
/// to [`sequential_lanes`] with a uniform stride-4 `group_ptr`.
pub fn sequential_quads(latency_s: &[f64], energy_j: &[f64]) -> QuadSched {
    debug_assert_eq!(latency_s.len() % QUAD_WIDTH, 0);
    debug_assert_eq!(latency_s.len(), energy_j.len());
    let mut out = QuadSched::default();
    for (lat, en) in
        latency_s.chunks_exact(QUAD_WIDTH).zip(energy_j.chunks_exact(QUAD_WIDTH))
    {
        let mut group_energy = 0.0f64;
        for s in 0..QUAD_WIDTH {
            out.makespan_s += lat[s];
            out.total_stage_time_s += lat[s];
            out.stage_busy_s[s] += lat[s];
            out.stage_energy_j[s] += en[s];
            group_energy += en[s];
        }
        out.energy_j += group_energy;
    }
    out
}

/// Makespan of a barriered multi-chip schedule. `chip_phases[c][p]` is the
/// local busy time chip `c` spends inside synchronization phase `p`; a
/// barrier at every phase boundary means phase `p + 1` starts (on every
/// chip) only when the slowest chip has finished phase `p`, so the
/// makespan is `Σ_p max_c chip_phases[c][p]`. Every chip must report the
/// same phase count — a chip skipping a barrier would deadlock the real
/// machine — so ragged input is a [`RaggedStages`] error (`group` is the
/// offending chip index). With one chip this reduces to the plain sum of
/// its phases.
pub fn barriered_makespan(chip_phases: &[Vec<f64>]) -> Result<f64, RaggedStages> {
    if chip_phases.is_empty() {
        return Ok(0.0);
    }
    let n_phases = chip_phases[0].len();
    for (ci, phases) in chip_phases.iter().enumerate() {
        if phases.len() != n_phases {
            return Err(RaggedStages { group: ci, expected: n_phases, got: phases.len() });
        }
    }
    let flat: Vec<f64> = chip_phases.iter().flat_map(|p| p.iter().copied()).collect();
    Ok(barriered_lanes(&flat, n_phases))
}

/// [`barriered_makespan`] over a flat chip-major lane:
/// `phase_busy_s[c * n_phases + p]` is chip `c`'s local busy time in phase
/// `p`. The lane length must be a multiple of `n_phases` (checked by the
/// slice-of-`Vec` entry point); branch-free maxima over strided slots.
pub fn barriered_lanes(phase_busy_s: &[f64], n_phases: usize) -> f64 {
    if n_phases == 0 {
        return 0.0;
    }
    debug_assert_eq!(phase_busy_s.len() % n_phases, 0);
    let mut makespan = 0.0f64;
    for p in 0..n_phases {
        let mut slowest = 0.0f64;
        for chip in phase_busy_s.chunks_exact(n_phases) {
            slowest = slowest.max(chip[p]);
        }
        makespan += slowest;
    }
    makespan
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_schedule() {
        assert_eq!(pipelined(&[]).unwrap().makespan_s, 0.0);
        assert_eq!(sequential(&[]).makespan_s, 0.0);
    }

    #[test]
    fn single_group_equals_sum() {
        let g = vec![vec![1.0, 2.0, 3.0]];
        assert_eq!(pipelined(&g).unwrap().makespan_s, 6.0);
        assert_eq!(sequential(&g).makespan_s, 6.0);
    }

    #[test]
    fn uniform_pipeline_formula() {
        // G groups of S stages, each of latency t:
        // makespan = (S + G − 1) · t.
        let g: Vec<GroupStages> = (0..10).map(|_| vec![1.0; 4]).collect();
        let r = pipelined(&g).unwrap();
        assert!((r.makespan_s - 13.0).abs() < 1e-12);
        assert!((sequential(&g).makespan_s - 40.0).abs() < 1e-12);
    }

    #[test]
    fn bottleneck_stage_dominates() {
        // One slow stage of latency 5 in each of 8 groups → makespan ≈
        // fill + 8×5.
        let g: Vec<GroupStages> = (0..8).map(|_| vec![1.0, 5.0, 1.0]).collect();
        let r = pipelined(&g).unwrap();
        assert!((r.makespan_s - (1.0 + 8.0 * 5.0 + 1.0)).abs() < 1e-12);
    }

    #[test]
    fn pipelined_never_slower_than_sequential() {
        let g: Vec<GroupStages> =
            (0..7).map(|i| vec![0.5 + i as f64, 2.0, 1.0 / (1 + i) as f64]).collect();
        assert!(pipelined(&g).unwrap().makespan_s <= sequential(&g).makespan_s + 1e-12);
    }

    #[test]
    fn stage_totals_sum() {
        let g = vec![vec![1.0, 2.0], vec![3.0, 4.0]];
        assert_eq!(stage_totals(&g).unwrap(), vec![4.0, 6.0]);
        assert!(stage_totals(&[]).unwrap().is_empty());
    }

    #[test]
    fn stage_totals_ragged_longer_group_is_an_error_not_a_panic() {
        // Pre-fix: `totals[s]` indexed out of bounds on the third stage.
        let g = vec![vec![1.0, 2.0], vec![1.0, 2.0, 3.0]];
        assert_eq!(
            stage_totals(&g).unwrap_err(),
            RaggedStages { group: 1, expected: 2, got: 3 }
        );
    }

    #[test]
    fn stage_totals_ragged_shorter_group_is_an_error_not_underreporting() {
        // Pre-fix: the short group's missing stages silently counted as 0.
        let g = vec![vec![1.0, 2.0, 3.0], vec![4.0], vec![1.0, 1.0, 1.0]];
        assert_eq!(
            stage_totals(&g).unwrap_err(),
            RaggedStages { group: 1, expected: 3, got: 1 }
        );
    }

    fn costs(rows: &[&[(f64, f64)]]) -> Vec<Vec<StageCost>> {
        rows.iter()
            .map(|r| {
                r.iter()
                    .map(|&(latency_s, energy_j)| StageCost { latency_s, energy_j })
                    .collect()
            })
            .collect()
    }

    fn views(groups: &[Vec<StageCost>]) -> Vec<&[StageCost]> {
        groups.iter().map(|g| g.as_slice()).collect()
    }

    #[test]
    fn cost_schedule_matches_latency_schedule() {
        let g = costs(&[
            &[(2.0, 1.0), (1.0, 0.5)],
            &[(1.0, 2.0), (3.0, 0.25)],
            &[(0.5, 4.0), (0.5, 8.0)],
        ]);
        let lat: Vec<GroupStages> =
            g.iter().map(|r| r.iter().map(|c| c.latency_s).collect()).collect();
        let c = pipelined_costs(&views(&g)).unwrap();
        let l = pipelined(&lat).unwrap();
        assert_eq!(c.makespan_s, l.makespan_s);
        assert_eq!(c.total_stage_time_s, l.total_stage_time_s);
        assert_eq!(c.stage_busy_s, stage_totals(&lat).unwrap());
        assert_eq!(c.energy_j, 1.0 + 0.5 + 2.0 + 0.25 + 4.0 + 8.0);
        assert_eq!(c.stage_energy_j, vec![1.0 + 2.0 + 4.0, 0.5 + 0.25 + 8.0]);
    }

    #[test]
    fn cost_schedule_sequential_is_flat_sum() {
        let g = costs(&[&[(1.0, 1.0), (2.0, 2.0)], &[(3.0, 4.0), (4.0, 8.0)]]);
        let c = sequential_costs(&views(&g));
        assert_eq!(c.makespan_s, 10.0);
        assert_eq!(c.total_stage_time_s, 10.0);
        assert_eq!(c.energy_j, 15.0);
        assert_eq!(c.stage_busy_s, vec![4.0, 6.0]);
        assert_eq!(c.stage_energy_j, vec![5.0, 10.0]);
    }

    #[test]
    fn cost_schedule_handles_empty_and_ragged() {
        let empty: Vec<&[StageCost]> = Vec::new();
        let c = pipelined_costs(&empty).unwrap();
        assert_eq!(c.makespan_s, 0.0);
        assert!(c.stage_busy_s.is_empty());
        assert_eq!(sequential_costs(&empty).makespan_s, 0.0);
        let g = costs(&[&[(1.0, 0.0)], &[(1.0, 0.0), (2.0, 0.0)]]);
        assert_eq!(
            pipelined_costs(&views(&g)).unwrap_err(),
            RaggedStages { group: 1, expected: 1, got: 2 }
        );
    }

    #[test]
    fn irregular_groups_exact() {
        // Hand-computed DP check.
        let g = vec![vec![2.0, 1.0], vec![1.0, 3.0]];
        // g0: s0 ends 2, s1 ends 3. g1: s0 starts max(0,2)=2 ends 3;
        // s1 starts max(3,3)=3 ends 6.
        assert!((pipelined(&g).unwrap().makespan_s - 6.0).abs() < 1e-12);
    }

    #[test]
    fn ragged_longer_group_is_an_error_not_a_panic() {
        // Pre-fix: index-out-of-bounds panic on `prev_end[s]` in --release.
        let g = vec![vec![1.0, 2.0], vec![1.0, 2.0, 3.0]];
        assert_eq!(
            pipelined(&g).unwrap_err(),
            RaggedStages { group: 1, expected: 2, got: 3 }
        );
    }

    #[test]
    fn ragged_shorter_group_is_an_error_not_underaccounting() {
        // Pre-fix: silently evaluated as if the missing stages were free.
        let g = vec![vec![1.0, 2.0, 3.0], vec![4.0], vec![1.0, 1.0, 1.0]];
        assert_eq!(
            pipelined(&g).unwrap_err(),
            RaggedStages { group: 1, expected: 3, got: 1 }
        );
    }

    #[test]
    fn ragged_error_displays_context() {
        let e = RaggedStages { group: 3, expected: 4, got: 2 };
        let msg = e.to_string();
        assert!(msg.contains('3') && msg.contains('4') && msg.contains('2'), "{msg}");
    }

    #[test]
    fn barriered_makespan_is_sum_of_phase_maxima() {
        // Chip 0: [2, 1, 4], chip 1: [1, 3, 2] → 2 + 3 + 4 = 9.
        let phases = vec![vec![2.0, 1.0, 4.0], vec![1.0, 3.0, 2.0]];
        assert_eq!(barriered_makespan(&phases).unwrap(), 9.0);
    }

    #[test]
    fn barriered_single_chip_reduces_to_sum() {
        let phases = vec![vec![1.5, 2.5, 3.0]];
        assert_eq!(barriered_makespan(&phases).unwrap(), 7.0);
        assert_eq!(barriered_makespan(&[]).unwrap(), 0.0);
    }

    #[test]
    fn quad_core_bit_identical_to_general_lanes() {
        // 3 groups × 4 stages with awkward magnitudes to surface any
        // accumulation-order drift between the stack-array core and the
        // general lane walk.
        let lat = [2.0, 1.0, 0.5, 3.0, 1e-9, 4.0, 2.5, 0.125, 7.0, 1.0, 1.0, 9.0];
        let en = [1.0, 0.5, 2.0, 0.25, 4.0, 8.0, 1e-12, 3.0, 0.75, 6.0, 0.5, 2.5];
        let ptr = [0usize, 4, 8, 12];
        let general = pipelined_lanes(&lat, &en, &ptr).unwrap();
        let quad = pipelined_quads(&lat, &en);
        assert_eq!(quad.makespan_s, general.makespan_s);
        assert_eq!(quad.total_stage_time_s, general.total_stage_time_s);
        assert_eq!(quad.energy_j, general.energy_j);
        assert_eq!(quad.stage_busy_s.to_vec(), general.stage_busy_s);
        assert_eq!(quad.stage_energy_j.to_vec(), general.stage_energy_j);
        let general_seq = sequential_lanes(&lat, &en, &ptr);
        let quad_seq = sequential_quads(&lat, &en);
        assert_eq!(quad_seq.makespan_s, general_seq.makespan_s);
        assert_eq!(quad_seq.energy_j, general_seq.energy_j);
        assert_eq!(quad_seq.stage_busy_s.to_vec(), general_seq.stage_busy_s);
        assert_eq!(quad_seq.stage_energy_j.to_vec(), general_seq.stage_energy_j);
        // Empty lanes: zero makespan, zero totals.
        assert_eq!(pipelined_quads(&[], &[]).makespan_s, 0.0);
        assert_eq!(sequential_quads(&[], &[]).energy_j, 0.0);
    }

    #[test]
    fn lane_ragged_group_is_an_error() {
        let lat = [1.0, 2.0, 3.0];
        let en = [0.0, 0.0, 0.0];
        assert_eq!(
            pipelined_lanes(&lat, &en, &[0, 2, 3]).unwrap_err(),
            RaggedStages { group: 1, expected: 2, got: 1 }
        );
        // Sequential tolerates ragged groups, sizing totals to the longest.
        let seq = sequential_lanes(&lat, &en, &[0, 2, 3]);
        assert_eq!(seq.makespan_s, 6.0);
        assert_eq!(seq.stage_busy_s, vec![4.0, 2.0]);
    }

    #[test]
    fn barriered_lanes_matches_slice_view() {
        let phases = vec![vec![2.0, 1.0, 4.0], vec![1.0, 3.0, 2.0]];
        let flat = [2.0, 1.0, 4.0, 1.0, 3.0, 2.0];
        assert_eq!(barriered_lanes(&flat, 3), barriered_makespan(&phases).unwrap());
        assert_eq!(barriered_lanes(&[], 0), 0.0);
    }

    #[test]
    fn barriered_ragged_chip_is_an_error() {
        let phases = vec![vec![1.0, 2.0], vec![1.0]];
        assert_eq!(
            barriered_makespan(&phases).unwrap_err(),
            RaggedStages { group: 1, expected: 2, got: 1 }
        );
    }
}
