//! Global architectural configuration for the GHOST accelerator.
//!
//! The five architectural parameters from §3.3 / §4.3 of the paper:
//!
//! * `n` — number of edge-control units = input-vertex group size (the `N`
//!   dimension of the partition matrix),
//! * `v` — number of execution lanes = output-vertex group size (`V`),
//! * `r_r` — rows per reduce unit (feature lanes of the coherent-summation
//!   array; also the number of WDM wavelengths feeding a transform unit),
//! * `r_c` — columns per reduce unit (neighbor vertices summed per pass),
//! * `t_r` — rows per transform unit (output features produced per pass).
//!
//! The paper's DSE (Fig. 7(c)) selects `[N, V, Rr, Rc, Tr] = [20, 20, 18, 7,
//! 17]`; [`GhostConfig::paper_optimal`] pins that point, and
//! [`crate::coordinator::dse`] re-derives it.


/// Precision of GNN parameters/activations mapped onto the photonic levels.
pub const PRECISION_BITS: u32 = 8;

/// Amplitude levels per polarity: positive and negative values are carried
/// on separate BPD arms, so `N_levels = 2^(n-1)` (paper §3.2).
pub const N_LEVELS: u32 = 1 << (PRECISION_BITS - 1);

/// Symbol (modulation) rate of the photonic datapath, Hz. Set by the slowest
/// converter in the loop — the 8-bit ADC at 1.2 GS/s (Table 1, [47]) — and
/// rounded down to 1 GHz as a conservative system clock for the analog path.
pub const SYMBOL_RATE_HZ: f64 = 1.0e9;

/// Architectural configuration of one GHOST accelerator instance.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct GhostConfig {
    /// `N`: edge-control units / input-vertex group size.
    pub n: usize,
    /// `V`: execution lanes / output-vertex group size.
    pub v: usize,
    /// `R_r`: rows (feature lanes) per reduce unit == wavelengths per
    /// transform-unit waveguide.
    pub r_r: usize,
    /// `R_c`: columns (neighbors per pass) per reduce unit.
    pub r_c: usize,
    /// `T_r`: rows (output features per pass) per transform unit.
    pub t_r: usize,
    /// Per-chip memory budget, bytes: the graph state one accelerator can
    /// hold resident (features + edge descriptors + partition metadata).
    /// Defaults to the HBM2 capacity of the paper platform (8 GiB). A
    /// graph whose [`footprint`](crate::graph::partition::PartitionMatrix::footprint_bytes)
    /// exceeds this budget must be sharded across multiple chips.
    pub chip_mem_bytes: u64,
}

/// Default per-chip memory budget: the 8 GiB HBM2 stack of the paper
/// platform (`Hbm2::paper().capacity_bytes`).
pub const DEFAULT_CHIP_MEM_BYTES: u64 = 8 << 30;

impl GhostConfig {
    /// The paper's DSE-optimal configuration `[20, 20, 18, 7, 17]`.
    pub fn paper_optimal() -> Self {
        Self { n: 20, v: 20, r_r: 18, r_c: 7, t_r: 17, chip_mem_bytes: DEFAULT_CHIP_MEM_BYTES }
    }

    /// Validates the configuration against the device-level feasibility
    /// bounds established by the Fig. 7(a)/(b) exploration:
    /// coherent summation arrays support at most
    /// [`crate::photonics::dse::MAX_COHERENT_MRS`] MRs per summation chain
    /// and non-coherent waveguides at most
    /// [`crate::photonics::dse::MAX_NONCOHERENT_WAVELENGTHS`] wavelengths.
    pub fn validate(&self) -> Result<(), String> {
        use crate::photonics::dse::{MAX_COHERENT_MRS, MAX_NONCOHERENT_WAVELENGTHS};
        if self.n == 0 || self.v == 0 || self.r_r == 0 || self.r_c == 0 || self.t_r == 0 {
            return Err("all GhostConfig dimensions must be non-zero".into());
        }
        if self.chip_mem_bytes == 0 {
            return Err("chip_mem_bytes must be non-zero".into());
        }
        if self.r_c > MAX_COHERENT_MRS {
            return Err(format!(
                "R_c={} exceeds coherent bank limit of {MAX_COHERENT_MRS} MRs (Fig. 7a)",
                self.r_c
            ));
        }
        if self.r_r > MAX_NONCOHERENT_WAVELENGTHS {
            return Err(format!(
                "R_r={} exceeds non-coherent waveguide limit of {MAX_NONCOHERENT_WAVELENGTHS} wavelengths (Fig. 7b)",
                self.r_r
            ));
        }
        Ok(())
    }

    /// Total MR count in the aggregate block (`V` reduce units of
    /// `R_r × R_c` MRs plus one recirculation MR per feature row).
    pub fn aggregate_mrs(&self) -> usize {
        self.v * self.r_r * (self.r_c + 1)
    }

    /// Total MR count in the combine block (`V` transform units of
    /// `T_r × R_r` MRs plus `T_r` broadband BN MRs each).
    pub fn combine_mrs(&self) -> usize {
        self.v * self.t_r * (self.r_r + 1)
    }

    /// DAC count for the combine block *without* weight-DAC sharing: one DAC
    /// per weight MR.
    pub fn combine_dacs_unshared(&self) -> usize {
        self.v * self.t_r * self.r_r
    }

    /// DAC count for the combine block *with* weight-DAC sharing (§3.4.3):
    /// all `V` transform units are tuned with the same weights, so the DAC
    /// count drops by a factor of `V` to one per MR of a single unit.
    pub fn combine_dacs_shared(&self) -> usize {
        self.t_r * self.r_r
    }
}

impl Default for GhostConfig {
    fn default() -> Self {
        Self::paper_optimal()
    }
}

/// Integer ceil-division helper used across the timing models when mapping
/// graph/model dimensions onto the photonic array dimensions.
pub fn ceil_div(a: usize, b: usize) -> usize {
    debug_assert!(b > 0);
    a.div_ceil(b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_optimal_matches_fig7c() {
        let c = GhostConfig::paper_optimal();
        assert_eq!((c.n, c.v, c.r_r, c.r_c, c.t_r), (20, 20, 18, 7, 17));
        c.validate().expect("paper point must be device-feasible");
    }

    #[test]
    fn validate_rejects_zero_dims() {
        let mut c = GhostConfig::paper_optimal();
        c.v = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn validate_rejects_zero_chip_memory() {
        let mut c = GhostConfig::paper_optimal();
        c.chip_mem_bytes = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn default_chip_memory_matches_paper_hbm() {
        let c = GhostConfig::paper_optimal();
        assert_eq!(c.chip_mem_bytes, crate::memory::hbm::Hbm2::paper().capacity_bytes);
    }

    #[test]
    fn validate_rejects_infeasible_banks() {
        let mut c = GhostConfig::paper_optimal();
        c.r_c = 21; // > 20 coherent MRs
        assert!(c.validate().is_err());
        let mut c = GhostConfig::paper_optimal();
        c.r_r = 19; // > 18 wavelengths
        assert!(c.validate().is_err());
    }

    #[test]
    fn dac_sharing_reduces_by_v() {
        let c = GhostConfig::paper_optimal();
        assert_eq!(c.combine_dacs_unshared(), c.combine_dacs_shared() * c.v);
    }

    #[test]
    fn n_levels_is_two_pow_seven() {
        assert_eq!(N_LEVELS, 128);
    }

    #[test]
    fn ceil_div_basics() {
        assert_eq!(ceil_div(10, 3), 4);
        assert_eq!(ceil_div(9, 3), 3);
        assert_eq!(ceil_div(1, 7), 1);
    }
}
