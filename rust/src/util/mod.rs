//! In-crate substrates for an offline build: deterministic RNG, JSON
//! parsing/serialization, a scoped thread-pool map, the process-wide
//! telemetry spine (metrics registry + span tracing + Chrome-trace
//! export), and the micro-benchmark harness used by `rust/benches/`.

pub mod bench;
pub mod json;
pub mod parallel;
pub mod rng;
pub mod telemetry;

pub use json::Json;
pub use rng::Pcg64;
