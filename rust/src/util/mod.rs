//! In-crate substrates for an offline build: deterministic RNG, JSON
//! parsing/serialization, a scoped thread-pool map, and the
//! micro-benchmark harness used by `rust/benches/`.

pub mod bench;
pub mod json;
pub mod parallel;
pub mod rng;

pub use json::Json;
pub use rng::Pcg64;
