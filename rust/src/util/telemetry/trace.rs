//! Chrome-trace-event JSON emission (the `{"traceEvents": [...]}` format
//! Perfetto and `chrome://tracing` load), built on [`crate::util::json`].
//!
//! Two exporters share these builders:
//! * the **wall-clock** trace ([`wall_trace_json`]) — every recorded
//!   [`super::span()`] plus a snapshot of the metric registry, timestamps in
//!   real microseconds since the process trace epoch;
//! * the **simulated-time** timeline
//!   ([`crate::coordinator::plan::sim_timeline`]) — the modeled hardware
//!   schedule of an evaluated plan, timestamps in modeled microseconds.
//!
//! Top-level keys other than `traceEvents` are legal in the format and
//! ignored by viewers; both exporters put GHOST-specific payloads (metric
//! snapshots, exact per-kind totals) under a `"ghost"` key so checkers can
//! read them back from the same file.

use crate::util::json::{obj, Json};

/// A `ph:"X"` (complete) event: one box on track `(pid, tid)` spanning
/// `[ts_us, ts_us + dur_us]` microseconds.
pub fn complete_event(
    name: &str,
    cat: &str,
    pid: u64,
    tid: u64,
    ts_us: f64,
    dur_us: f64,
    args: Option<Json>,
) -> Json {
    let mut pairs = vec![
        ("name", Json::Str(name.to_string())),
        ("cat", Json::Str(cat.to_string())),
        ("ph", Json::Str("X".to_string())),
        ("pid", Json::Num(pid as f64)),
        ("tid", Json::Num(tid as f64)),
        ("ts", Json::Num(ts_us)),
        ("dur", Json::Num(dur_us)),
    ];
    if let Some(a) = args {
        pairs.push(("args", a));
    }
    obj(pairs)
}

/// A `ph:"i"` (instant) event with thread scope — used for phase-barrier
/// markers on the simulated timeline.
pub fn instant_event(name: &str, cat: &str, pid: u64, tid: u64, ts_us: f64) -> Json {
    obj(vec![
        ("name", Json::Str(name.to_string())),
        ("cat", Json::Str(cat.to_string())),
        ("ph", Json::Str("i".to_string())),
        ("s", Json::Str("t".to_string())),
        ("pid", Json::Num(pid as f64)),
        ("tid", Json::Num(tid as f64)),
        ("ts", Json::Num(ts_us)),
    ])
}

/// A `ph:"M"` metadata event naming process `pid` in the viewer.
pub fn process_name(pid: u64, name: &str) -> Json {
    obj(vec![
        ("name", Json::Str("process_name".to_string())),
        ("ph", Json::Str("M".to_string())),
        ("pid", Json::Num(pid as f64)),
        ("args", obj(vec![("name", Json::Str(name.to_string()))])),
    ])
}

/// A `ph:"M"` metadata event naming track `(pid, tid)` in the viewer.
pub fn thread_name(pid: u64, tid: u64, name: &str) -> Json {
    obj(vec![
        ("name", Json::Str("thread_name".to_string())),
        ("ph", Json::Str("M".to_string())),
        ("pid", Json::Num(pid as f64)),
        ("tid", Json::Num(tid as f64)),
        ("args", obj(vec![("name", Json::Str(name.to_string()))])),
    ])
}

/// Wraps built events into the trace document, attaching the GHOST payload
/// under the viewer-ignored `"ghost"` key.
pub fn trace_doc(events: Vec<Json>, ghost: Json) -> Json {
    obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::Str("ms".to_string())),
        ("ghost", ghost),
    ])
}

/// Trace pid of the wall-clock exporter (one process: this one).
pub const WALL_PID: u64 = 1;

/// The wall-clock trace: every span recorded so far (snapshot, not drain)
/// plus the current metric-registry snapshot. Timestamps convert ns → µs in
/// f64, which is exact for any run shorter than ~104 days (2^53 ns).
pub fn wall_trace_json() -> Json {
    let spans = super::span::snapshot();
    let mut events = vec![process_name(WALL_PID, "ghost (wall clock)")];
    let mut seen_tids: Vec<u64> = Vec::new();
    for ev in &spans {
        if !seen_tids.contains(&ev.tid) {
            seen_tids.push(ev.tid);
            events.push(thread_name(WALL_PID, ev.tid, &format!("thread {}", ev.tid)));
        }
        events.push(complete_event(
            ev.name,
            ev.cat,
            WALL_PID,
            ev.tid,
            ev.ts_ns as f64 / 1000.0,
            ev.dur_ns as f64 / 1000.0,
            None,
        ));
    }
    let ghost = obj(vec![
        ("clock", Json::Str("wall".to_string())),
        ("spans", Json::Num(spans.len() as f64)),
        ("metrics", super::registry().snapshot()),
    ]);
    trace_doc(events, ghost)
}

/// Renders [`wall_trace_json`] to `path` (with a trailing newline, like
/// every other artifact the CLI writes).
pub fn write_wall_trace(path: &str) -> std::io::Result<()> {
    let doc = wall_trace_json();
    std::fs::write(path, format!("{doc}\n"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn complete_event_shape() {
        let e = complete_event("gather", "sim-stage", 0, 1, 1.5, 2.5, None);
        assert_eq!(e.get("ph").and_then(|p| p.as_str()), Some("X"));
        assert_eq!(e.get("name").and_then(|p| p.as_str()), Some("gather"));
        assert_eq!(e.get("ts").and_then(|p| p.as_f64()), Some(1.5));
        assert_eq!(e.get("dur").and_then(|p| p.as_f64()), Some(2.5));
    }

    #[test]
    fn wall_trace_parses_as_json() {
        super::super::set_enabled(true);
        {
            let _s = super::super::span("test.trace.roundtrip");
        }
        let text = format!("{}", wall_trace_json());
        let parsed = crate::util::json::Json::parse(&text).expect("trace must parse");
        let events = parsed
            .get("traceEvents")
            .and_then(|e| e.as_array())
            .expect("traceEvents array");
        assert!(
            events.iter().any(|e| {
                e.get("name").and_then(|n| n.as_str()) == Some("test.trace.roundtrip")
            }),
            "span missing from exported trace"
        );
        assert!(parsed.get("ghost").and_then(|g| g.get("metrics")).is_some());
    }

    #[test]
    fn metadata_events_name_tracks() {
        let p = process_name(3, "chip 3");
        assert_eq!(p.get("ph").and_then(|x| x.as_str()), Some("M"));
        let t = thread_name(3, 2, "pipe 1");
        assert_eq!(
            t.get("args").and_then(|a| a.get("name")).and_then(|n| n.as_str()),
            Some("pipe 1")
        );
    }
}
