//! Wall-clock span recording with correct per-thread nesting across
//! [`crate::util::parallel`] workers.
//!
//! Each OS thread gets a stable trace `tid` (a process-wide counter, not
//! the OS thread id, so Perfetto tracks stay small and deterministic in
//! count) and buffers its finished spans in a thread local. The buffer's
//! `Drop` flushes into the global sink — `par_map` spawns fresh scoped
//! threads per call, so worker spans land in the sink by the time the
//! fan-out returns, with no explicit hand-off at the call sites.
//!
//! Nesting is structural: a [`SpanGuard`] records its event at `Drop`, and
//! Rust drop order guarantees LIFO per thread, so on any single `tid` the
//! recorded intervals are properly nested (a child's `[ts, ts+dur]` lies
//! inside its parent's) — the invariant the exporter tests assert.

use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

/// One finished wall-clock span, timestamps in nanoseconds since the
/// process trace epoch (first span or explicit [`now_ns`] call).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceEvent {
    pub name: &'static str,
    /// Trace category, e.g. `"wall"`.
    pub cat: &'static str,
    /// Stable per-thread track id (1-based; not the OS thread id).
    pub tid: u64,
    pub ts_ns: u64,
    pub dur_ns: u64,
}

static NEXT_TID: AtomicU64 = AtomicU64::new(1);
static SINK: Mutex<Vec<TraceEvent>> = Mutex::new(Vec::new());
static EPOCH: OnceLock<Instant> = OnceLock::new();

/// Nanoseconds since the process trace epoch (monotonic).
pub fn now_ns() -> u64 {
    EPOCH.get_or_init(Instant::now).elapsed().as_nanos() as u64
}

struct LocalBuf {
    tid: u64,
    events: Vec<TraceEvent>,
}

impl LocalBuf {
    fn new() -> LocalBuf {
        LocalBuf { tid: NEXT_TID.fetch_add(1, Ordering::Relaxed), events: Vec::new() }
    }
}

impl Drop for LocalBuf {
    fn drop(&mut self) {
        if !self.events.is_empty() {
            let mut sink = SINK.lock().expect("telemetry sink poisoned");
            sink.append(&mut self.events);
        }
    }
}

thread_local! {
    static LOCAL: RefCell<LocalBuf> = RefCell::new(LocalBuf::new());
}

/// RAII wall-clock span: created by [`span`], records one trace event on
/// drop. Inert (no clock read, no allocation) when tracing is disabled at
/// creation time.
#[must_use = "a span measures the scope it is bound to; binding to _ drops it immediately"]
pub struct SpanGuard {
    name: &'static str,
    start_ns: u64,
    active: bool,
}

/// Opens a span named `name` covering the guard's lifetime. The disabled
/// path is a single relaxed atomic load (the [`super::enabled`] check).
pub fn span(name: &'static str) -> SpanGuard {
    if !super::enabled() {
        return SpanGuard { name, start_ns: 0, active: false };
    }
    SpanGuard { name, start_ns: now_ns(), active: true }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if !self.active {
            return;
        }
        let end = now_ns();
        let ev = TraceEvent {
            name: self.name,
            cat: "wall",
            tid: 0, // patched from the thread local below
            ts_ns: self.start_ns,
            dur_ns: end.saturating_sub(self.start_ns),
        };
        // `try_with` so a guard outliving its thread's locals (teardown
        // order) degrades to a direct sink push instead of a panic.
        let pushed = LOCAL.try_with(|l| {
            let mut l = l.borrow_mut();
            let tid = l.tid;
            l.events.push(TraceEvent { tid, ..ev });
        });
        if pushed.is_err() {
            let mut sink = SINK.lock().expect("telemetry sink poisoned");
            sink.push(TraceEvent { tid: u64::MAX, ..ev });
        }
    }
}

/// Flushes the calling thread's buffered spans into the global sink.
/// Exporters call this so the main thread's still-open buffer is included;
/// worker threads flush automatically when they exit.
pub fn flush_thread() {
    LOCAL.with(|l| {
        let mut l = l.borrow_mut();
        if !l.events.is_empty() {
            let mut sink = SINK.lock().expect("telemetry sink poisoned");
            let events = &mut l.events;
            sink.append(events);
        }
    });
}

/// A snapshot (not a drain) of every flushed span, so concurrent recorders
/// and multiple exports don't race each other. Flushes the calling thread
/// first.
pub fn snapshot() -> Vec<TraceEvent> {
    flush_thread();
    SINK.lock().expect("telemetry sink poisoned").clone()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_span_records_nothing() {
        // Tracing may have been enabled by a concurrent test; only assert
        // when this thread observes the disabled state.
        if super::super::enabled() {
            return;
        }
        let before = snapshot().len();
        {
            let _s = span("test.disabled");
        }
        if super::super::enabled() {
            // A concurrent test enabled tracing mid-flight; nothing ever
            // disables it again, so the pre-check can't be trusted. Skip.
            return;
        }
        let after = snapshot()
            .iter()
            .filter(|e| e.name == "test.disabled")
            .count();
        assert_eq!(after, 0, "disabled span must not record (sink had {before})");
    }

    #[test]
    fn spans_nest_per_thread() {
        super::super::set_enabled(true);
        {
            let _outer = span("test.nest.outer");
            {
                let _inner = span("test.nest.inner");
            }
        }
        let events = snapshot();
        let outer = events
            .iter()
            .filter(|e| e.name == "test.nest.outer")
            .max_by_key(|e| e.ts_ns)
            .copied()
            .expect("outer span recorded");
        let inner = events
            .iter()
            .filter(|e| e.name == "test.nest.inner" && e.tid == outer.tid)
            .max_by_key(|e| e.ts_ns)
            .copied()
            .expect("inner span recorded on same thread");
        assert!(inner.ts_ns >= outer.ts_ns);
        assert!(inner.ts_ns + inner.dur_ns <= outer.ts_ns + outer.dur_ns);
    }

    #[test]
    fn parallel_workers_flush_on_scope_exit() {
        super::super::set_enabled(true);
        let items: Vec<u32> = (0..64).collect();
        let _ = crate::util::parallel::par_map(&items, |&x| {
            let _s = span("test.par.worker");
            x * 2
        });
        let count = snapshot().iter().filter(|e| e.name == "test.par.worker").count();
        assert!(count >= 64, "expected >=64 worker spans, saw {count}");
    }

    #[test]
    fn tids_are_stable_within_a_thread() {
        super::super::set_enabled(true);
        {
            let _a = span("test.tid.a");
        }
        {
            let _b = span("test.tid.b");
        }
        let events = snapshot();
        let a = events.iter().filter(|e| e.name == "test.tid.a").max_by_key(|e| e.ts_ns);
        let b = events.iter().filter(|e| e.name == "test.tid.b").max_by_key(|e| e.ts_ns);
        assert_eq!(a.unwrap().tid, b.unwrap().tid);
    }
}
