//! Process-wide telemetry spine: a [`Registry`] of typed, hierarchically
//! named metrics (counters / gauges / histograms, all cheap atomics), the
//! wall-clock span API ([`span()`]), and the Chrome-trace exporters
//! ([`trace`]). Every layer of the stack — `plan`, `engine`, `dse`, `soa`,
//! `graph::mutate`, `graph::partition`, `serve` — records into this one
//! module, so the ROADMAP's capacity planner (and any future perf PR) reads
//! a single spine instead of scattered ad-hoc statics.
//!
//! # Naming scheme
//!
//! Metric names are dot-separated paths, `layer.object.event`:
//! `engine.plan.builds`, `engine.plan.hits`, `delta.graph.patches`,
//! `serve.events.arrival`. The registry treats names as opaque keys; the
//! hierarchy exists for humans and for prefix-filtering in exported
//! snapshots.
//!
//! # Enablement and the disabled path
//!
//! *Counters, gauges, and histograms are always on.* They are single
//! relaxed atomic ops, they back exact-count getters that existing tests
//! assert on (`BatchEngine::plan_builds`, `soa::delta_counters`), and their
//! cost is already inside every pre-telemetry baseline.
//!
//! *Spans and trace events* are recorded only when tracing is enabled —
//! via the `GHOST_TRACE` environment variable (any value other than
//! `0`/`off`/`false`/`no`; a value containing `/` or ending in `.json`
//! also names the wall-trace output path) or programmatically via
//! [`set_enabled`] (the `--trace` CLI flag). The disabled path of a span
//! site is one relaxed atomic load and zero allocation — pinned ≤5% on the
//! evaluate hot path by `benches/telemetry_overhead.rs`.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, Once, OnceLock};

use crate::util::json::{obj, Json};

pub mod span;
pub mod trace;

pub use span::{span, SpanGuard};

/// Global tracing toggle. Seeded lazily from `GHOST_TRACE` on first query;
/// an explicit [`set_enabled`] (the `--trace` flag) wins over the
/// environment regardless of call order.
static ENABLED: AtomicBool = AtomicBool::new(false);
static ENV_SEED: Once = Once::new();

fn env_value_on(v: &str) -> bool {
    !matches!(v.trim().to_ascii_lowercase().as_str(), "" | "0" | "off" | "false" | "no")
}

/// Whether span/trace recording is on. One relaxed atomic load after the
/// first call (which seeds the flag from `GHOST_TRACE`).
pub fn enabled() -> bool {
    ENV_SEED.call_once(|| {
        if let Ok(v) = std::env::var("GHOST_TRACE") {
            if env_value_on(&v) {
                ENABLED.store(true, Ordering::Relaxed);
            }
        }
    });
    ENABLED.load(Ordering::Relaxed)
}

/// Programmatic toggle (the `--trace` CLI flag). Marks the environment
/// seed as done so a later [`enabled`] cannot override an explicit choice.
pub fn set_enabled(on: bool) {
    ENV_SEED.call_once(|| {});
    ENABLED.store(on, Ordering::Relaxed);
}

/// The wall-trace output path named by `GHOST_TRACE` itself, when its
/// value looks like a path (`GHOST_TRACE=trace.json ghost run …`) rather
/// than a bare switch (`GHOST_TRACE=1`).
pub fn env_trace_path() -> Option<String> {
    let v = std::env::var("GHOST_TRACE").ok()?;
    if env_value_on(&v) && (v.contains('/') || v.ends_with(".json")) {
        Some(v)
    } else {
        None
    }
}

/// A monotonically increasing count — one relaxed `fetch_add` per event.
/// Always on (see module docs): the exact-count getters layered on top
/// (`BatchEngine::plan_builds`, `soa::delta_counters`) must keep their
/// pre-telemetry semantics with tracing disabled.
#[derive(Debug)]
pub struct Counter {
    name: String,
    value: AtomicUsize,
}

impl Counter {
    /// A free-standing counter (not yet in any registry); the engine holds
    /// per-instance counters this way and registers only the global
    /// engine's set.
    pub fn new(name: impl Into<String>) -> Arc<Counter> {
        Arc::new(Counter { name: name.into(), value: AtomicUsize::new(0) })
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn inc(&self) {
        self.value.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: usize) {
        self.value.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> usize {
        self.value.load(Ordering::Relaxed)
    }
}

/// A last-write-wins instantaneous value (f64 bits in an atomic).
#[derive(Debug)]
pub struct Gauge {
    name: String,
    bits: AtomicU64,
}

impl Gauge {
    pub fn new(name: impl Into<String>) -> Arc<Gauge> {
        Arc::new(Gauge { name: name.into(), bits: AtomicU64::new(0f64.to_bits()) })
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn set(&self, v: f64) {
        self.bits.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.bits.load(Ordering::Relaxed))
    }
}

/// Power-of-two bucketed distribution: `record(v)` lands `v` (truncated to
/// an integer count of the caller's unit — requests, nanoseconds, …) in
/// bucket `⌈log2(v)⌉`, alongside an exact running count and sum. Lock-free;
/// merging concurrent recorders is just per-bucket addition.
#[derive(Debug)]
pub struct Histogram {
    name: String,
    count: AtomicUsize,
    /// Sum in the caller's unit, accumulated as integer to stay atomic.
    sum: AtomicU64,
    buckets: [AtomicU64; Self::N_BUCKETS],
}

impl Histogram {
    pub const N_BUCKETS: usize = 32;

    pub fn new(name: impl Into<String>) -> Arc<Histogram> {
        Arc::new(Histogram {
            name: name.into(),
            count: AtomicUsize::new(0),
            sum: AtomicU64::new(0),
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
        })
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// Records one observation of `v` units (negative values clamp to 0).
    pub fn record(&self, v: f64) {
        self.record_n(v, 1);
    }

    /// Records `n` observations of `v` units in one shot — three atomic
    /// ops total instead of `3n`. Hot loops (the serve event loop) tally
    /// per-value counts locally and flush them here once per run; the
    /// resulting buckets/count/sum are identical to `n` calls of
    /// [`Self::record`].
    pub fn record_n(&self, v: f64, n: u64) {
        if n == 0 {
            return;
        }
        let u = if v.is_finite() && v > 0.0 { v as u64 } else { 0 };
        let bucket = (64 - u.leading_zeros() as usize).min(Self::N_BUCKETS - 1);
        self.count.fetch_add(n as usize, Ordering::Relaxed);
        self.sum.fetch_add(u * n, Ordering::Relaxed);
        self.buckets[bucket].fetch_add(n, Ordering::Relaxed);
    }

    pub fn count(&self) -> usize {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    fn to_json(&self) -> Json {
        let buckets: Vec<Json> = self
            .buckets
            .iter()
            .map(|b| Json::Num(b.load(Ordering::Relaxed) as f64))
            .collect();
        obj(vec![
            ("count", Json::Num(self.count() as f64)),
            ("sum", Json::Num(self.sum() as f64)),
            ("pow2_buckets", Json::Arr(buckets)),
        ])
    }
}

/// The process-wide metric registry: get-or-create by name per type, plus
/// adoption of externally owned counters (the global engine's per-instance
/// set). [`Registry::snapshot`] renders everything for the trace exporter
/// and `--json` consumers.
#[derive(Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, Arc<Gauge>>>,
    histograms: Mutex<BTreeMap<String, Arc<Histogram>>>,
}

/// The process-wide registry.
pub fn registry() -> &'static Registry {
    static GLOBAL: OnceLock<Registry> = OnceLock::new();
    GLOBAL.get_or_init(Registry::default)
}

impl Registry {
    /// Get-or-create the counter named `name`. The `Arc` is cheap to clone
    /// and cache; hot paths should look their counters up once, not per
    /// event.
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut map = self.counters.lock().expect("telemetry registry poisoned");
        map.entry(name.to_string()).or_insert_with(|| Counter::new(name)).clone()
    }

    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut map = self.gauges.lock().expect("telemetry registry poisoned");
        map.entry(name.to_string()).or_insert_with(|| Gauge::new(name)).clone()
    }

    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        let mut map = self.histograms.lock().expect("telemetry registry poisoned");
        map.entry(name.to_string()).or_insert_with(|| Histogram::new(name)).clone()
    }

    /// Registers an externally created counter under its own name,
    /// replacing any placeholder created earlier by [`Registry::counter`].
    /// Used by [`crate::coordinator::engine::BatchEngine::global`]: engines
    /// hold per-instance counters (tests build private engines and assert
    /// exact counts), and only the global engine's set is visible here.
    pub fn adopt_counter(&self, c: &Arc<Counter>) {
        let mut map = self.counters.lock().expect("telemetry registry poisoned");
        map.insert(c.name().to_string(), Arc::clone(c));
    }

    /// Everything in the registry as one JSON object:
    /// `{"counters": {name: n}, "gauges": {name: v}, "histograms": {...}}`.
    pub fn snapshot(&self) -> Json {
        let counters: BTreeMap<String, Json> = self
            .counters
            .lock()
            .expect("telemetry registry poisoned")
            .iter()
            .map(|(k, c)| (k.clone(), Json::Num(c.get() as f64)))
            .collect();
        let gauges: BTreeMap<String, Json> = self
            .gauges
            .lock()
            .expect("telemetry registry poisoned")
            .iter()
            .map(|(k, g)| (k.clone(), Json::Num(g.get())))
            .collect();
        let histograms: BTreeMap<String, Json> = self
            .histograms
            .lock()
            .expect("telemetry registry poisoned")
            .iter()
            .map(|(k, h)| (k.clone(), h.to_json()))
            .collect();
        obj(vec![
            ("counters", Json::Obj(counters)),
            ("gauges", Json::Obj(gauges)),
            ("histograms", Json::Obj(histograms)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_is_always_on_and_exact() {
        let c = Counter::new("test.counter.exact");
        for _ in 0..1000 {
            c.inc();
        }
        c.add(234);
        assert_eq!(c.get(), 1234);
    }

    #[test]
    fn registry_get_or_create_returns_same_instance() {
        let a = registry().counter("test.registry.same");
        let b = registry().counter("test.registry.same");
        a.inc();
        b.inc();
        assert_eq!(a.get(), b.get());
        assert!(Arc::ptr_eq(&a, &b));
    }

    #[test]
    fn adopt_replaces_placeholder() {
        let mine = Counter::new("test.registry.adopted");
        mine.add(7);
        registry().adopt_counter(&mine);
        assert_eq!(registry().counter("test.registry.adopted").get(), 7);
    }

    #[test]
    fn gauge_round_trips_f64() {
        let g = registry().gauge("test.gauge.rt");
        g.set(0.1 + 0.2);
        assert_eq!(g.get(), 0.1 + 0.2);
    }

    #[test]
    fn histogram_buckets_and_sum() {
        let h = Histogram::new("test.hist");
        for v in [0.0, 1.0, 2.0, 3.0, 1000.0] {
            h.record(v);
        }
        assert_eq!(h.count(), 5);
        assert_eq!(h.sum(), 1006);
        let j = h.to_json();
        assert_eq!(j.get("count").and_then(|c| c.as_u64()), Some(5));
    }

    #[test]
    fn record_n_matches_repeated_record() {
        let a = Histogram::new("test.hist.n.a");
        let b = Histogram::new("test.hist.n.b");
        for (v, n) in [(1.0, 3u64), (7.0, 5), (1000.0, 2), (0.0, 4)] {
            a.record_n(v, n);
            for _ in 0..n {
                b.record(v);
            }
        }
        a.record_n(42.0, 0); // no-op
        assert_eq!(a.count(), b.count());
        assert_eq!(a.sum(), b.sum());
        assert_eq!(format!("{}", a.to_json()), format!("{}", b.to_json()));
    }

    #[test]
    fn snapshot_is_valid_json_and_lists_metrics() {
        registry().counter("test.snapshot.c").add(3);
        let snap = registry().snapshot();
        let text = format!("{snap}");
        let parsed = Json::parse(&text).expect("snapshot must be valid JSON");
        assert!(
            parsed
                .get("counters")
                .and_then(|c| c.get("test.snapshot.c"))
                .and_then(|v| v.as_u64())
                .map(|n| n >= 3)
                .unwrap_or(false),
            "snapshot missing test.snapshot.c: {text}"
        );
    }

    #[test]
    fn env_value_parsing() {
        assert!(!env_value_on("0"));
        assert!(!env_value_on("off"));
        assert!(!env_value_on("FALSE"));
        assert!(!env_value_on(""));
        assert!(env_value_on("1"));
        assert!(env_value_on("on"));
        assert!(env_value_on("trace.json"));
    }
}
