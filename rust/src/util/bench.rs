//! Micro-benchmark harness used by `rust/benches/` (criterion is not
//! available in the offline build). Provides warmup, timed repetitions,
//! and median/mean/min/percentile reporting, plus a black-box to defeat
//! const-propagation.
//!
//! The percentile math ([`percentile_index`], nearest-rank) is shared with
//! the serving simulator's latency recorder
//! ([`crate::serve::metrics`]), so a bench line and a serving report mean
//! the same thing by "p99".

use std::hint::black_box as std_black_box;
use std::time::{Duration, Instant};

/// Re-export of `std::hint::black_box`.
pub fn black_box<T>(x: T) -> T {
    std_black_box(x)
}

/// Nearest-rank percentile: the index into a *sorted* sample set of length
/// `n` holding the `q`-quantile (`q` in `[0, 1]`). With nearest-rank
/// semantics the result is always an actually-observed sample:
/// `ceil(q · n)` clamped to `1..=n`, minus one for zero-based indexing.
/// `n == 0` returns 0 (callers guard the empty case).
pub fn percentile_index(n: usize, q: f64) -> usize {
    if n == 0 {
        return 0;
    }
    let rank = (q * n as f64).ceil() as usize;
    rank.clamp(1, n) - 1
}

/// Nearest-rank percentile of a sorted `f64` slice; 0.0 when empty.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    sorted[percentile_index(sorted.len(), q)]
}

/// Timing statistics of one benchmark.
#[derive(Debug, Clone, Copy)]
pub struct BenchStats {
    pub iters: u32,
    pub mean: Duration,
    pub median: Duration,
    pub min: Duration,
    pub max: Duration,
    /// Nearest-rank 50th percentile (may differ from `median`, which keeps
    /// its historical `samples[n / 2]` definition for compatibility).
    pub p50: Duration,
    pub p95: Duration,
    pub p99: Duration,
}

impl BenchStats {
    fn from_samples(mut samples: Vec<Duration>) -> Self {
        samples.sort();
        let n = samples.len();
        let mean = samples.iter().sum::<Duration>() / n as u32;
        Self {
            iters: n as u32,
            mean,
            median: samples[n / 2],
            min: samples[0],
            max: samples[n - 1],
            p50: samples[percentile_index(n, 0.50)],
            p95: samples[percentile_index(n, 0.95)],
            p99: samples[percentile_index(n, 0.99)],
        }
    }
}

/// Runs `f` with `warmup` unmeasured and `iters` measured repetitions and
/// prints a criterion-style line.
pub fn bench<F: FnMut()>(name: &str, warmup: u32, iters: u32, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let samples: Vec<Duration> = (0..iters.max(1))
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed()
        })
        .collect();
    let stats = BenchStats::from_samples(samples);
    println!(
        "bench {name:<40} median {:>12?} mean {:>12?} min {:>12?} p95 {:>12?} p99 {:>12?} (n={})",
        stats.median, stats.mean, stats.min, stats.p95, stats.p99, stats.iters
    );
    stats
}

/// Times a single invocation (for long-running whole-figure regenerations).
pub fn time_once<T, F: FnOnce() -> T>(name: &str, f: F) -> T {
    let t0 = Instant::now();
    let out = f();
    println!("bench {name:<40} single run {:>12?}", t0.elapsed());
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_ordering() {
        let s = bench("noop", 1, 5, || {
            black_box(1 + 1);
        });
        assert!(s.min <= s.median && s.median <= s.max);
        assert!(s.min <= s.p50 && s.p50 <= s.p95 && s.p95 <= s.p99 && s.p99 <= s.max);
        assert_eq!(s.iters, 5);
    }

    #[test]
    fn time_once_returns_value() {
        let v = time_once("ret", || 42);
        assert_eq!(v, 42);
    }

    #[test]
    fn percentile_index_nearest_rank() {
        // n = 10: p50 is the 5th sample (index 4), p95 the 10th (index 9),
        // p99 also the 10th — nearest-rank never interpolates.
        assert_eq!(percentile_index(10, 0.50), 4);
        assert_eq!(percentile_index(10, 0.95), 9);
        assert_eq!(percentile_index(10, 0.99), 9);
        // Extremes clamp into range.
        assert_eq!(percentile_index(10, 0.0), 0);
        assert_eq!(percentile_index(10, 1.0), 9);
        assert_eq!(percentile_index(1, 0.999), 0);
        assert_eq!(percentile_index(0, 0.5), 0);
    }

    #[test]
    fn percentile_sorted_picks_observed_samples() {
        let v = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0, 10.0];
        assert_eq!(percentile_sorted(&v, 0.50), 5.0);
        assert_eq!(percentile_sorted(&v, 0.90), 9.0);
        assert_eq!(percentile_sorted(&v, 0.999), 10.0);
        assert_eq!(percentile_sorted(&[], 0.5), 0.0);
    }
}
