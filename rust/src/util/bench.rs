//! Micro-benchmark harness used by `rust/benches/` (criterion is not
//! available in the offline build). Provides warmup, timed repetitions,
//! and median/mean/min reporting, plus a black-box to defeat
//! const-propagation.

use std::hint::black_box as std_black_box;
use std::time::{Duration, Instant};

/// Re-export of `std::hint::black_box`.
pub fn black_box<T>(x: T) -> T {
    std_black_box(x)
}

/// Timing statistics of one benchmark.
#[derive(Debug, Clone, Copy)]
pub struct BenchStats {
    pub iters: u32,
    pub mean: Duration,
    pub median: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl BenchStats {
    fn from_samples(mut samples: Vec<Duration>) -> Self {
        samples.sort();
        let n = samples.len();
        let mean = samples.iter().sum::<Duration>() / n as u32;
        Self {
            iters: n as u32,
            mean,
            median: samples[n / 2],
            min: samples[0],
            max: samples[n - 1],
        }
    }
}

/// Runs `f` with `warmup` unmeasured and `iters` measured repetitions and
/// prints a criterion-style line.
pub fn bench<F: FnMut()>(name: &str, warmup: u32, iters: u32, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let samples: Vec<Duration> = (0..iters.max(1))
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed()
        })
        .collect();
    let stats = BenchStats::from_samples(samples);
    println!(
        "bench {name:<40} median {:>12?} mean {:>12?} min {:>12?} (n={})",
        stats.median, stats.mean, stats.min, stats.iters
    );
    stats
}

/// Times a single invocation (for long-running whole-figure regenerations).
pub fn time_once<T, F: FnOnce() -> T>(name: &str, f: F) -> T {
    let t0 = Instant::now();
    let out = f();
    println!("bench {name:<40} single run {:>12?}", t0.elapsed());
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_ordering() {
        let s = bench("noop", 1, 5, || {
            black_box(1 + 1);
        });
        assert!(s.min <= s.median && s.median <= s.max);
        assert_eq!(s.iters, 5);
    }

    #[test]
    fn time_once_returns_value() {
        let v = time_once("ret", || 42);
        assert_eq!(v, 42);
    }
}
