//! Minimal JSON parser + serializer (RFC 8259 subset sufficient for the
//! artifact manifests and result files exchanged with the build-time
//! Python). Implemented in-crate because the build is offline.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---------------------------------------------------------- accessors

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as u64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_object(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object-field access: `v.get("key")`.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_object().and_then(|o| o.get(key))
    }

    // ------------------------------------------------------------ parsing

    /// Parses a JSON document.
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing characters at byte {}", p.pos));
        }
        Ok(v)
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", b as char, self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek().ok_or("unexpected end of input")? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            _ => self.number(),
        }
    }

    fn literal(&mut self, word: &str, val: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            Err(format!("invalid literal at byte {}", self.pos))
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            arr.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(arr)),
                _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump().ok_or("unterminated string")? {
                b'"' => return Ok(out),
                b'\\' => match self.bump().ok_or("unterminated escape")? {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'b' => out.push('\u{0008}'),
                    b'f' => out.push('\u{000C}'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'u' => {
                        // RFC 8259 §7 encodes astral characters as a
                        // \uD8xx\uDCxx surrogate pair. Unpaired halves
                        // become U+FFFD (the same lenient stance as
                        // unmappable code points); a failed candidate low
                        // half is re-examined, since it may itself open a
                        // new pair: \uD83D\uD83D\uDE00 is U+FFFD then
                        // U+1F600, not three U+FFFD.
                        let mut code = self.hex4()?;
                        loop {
                            if (0xD800..=0xDBFF).contains(&code) {
                                if self.bytes[self.pos..].starts_with(b"\\u") {
                                    self.pos += 2;
                                    let next = self.hex4()?;
                                    if (0xDC00..=0xDFFF).contains(&next) {
                                        let c = 0x10000
                                            + ((code - 0xD800) << 10)
                                            + (next - 0xDC00);
                                        out.push(char::from_u32(c).unwrap_or('\u{FFFD}'));
                                        break;
                                    }
                                    // Unpaired high half; reprocess `next`.
                                    out.push('\u{FFFD}');
                                    code = next;
                                    continue;
                                }
                                out.push('\u{FFFD}');
                            } else if (0xDC00..=0xDFFF).contains(&code) {
                                // Unpaired low surrogate.
                                out.push('\u{FFFD}');
                            } else {
                                out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            }
                            break;
                        }
                    }
                    other => return Err(format!("bad escape \\{}", other as char)),
                },
                // Multi-byte UTF-8 passes through unchanged.
                b => {
                    let start = self.pos - 1;
                    let len = utf8_len(b);
                    let end = start + len;
                    if end > self.bytes.len() {
                        return Err("truncated UTF-8 sequence".into());
                    }
                    out.push_str(
                        std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|e| e.to_string())?,
                    );
                    self.pos = end;
                }
            }
        }
    }

    /// Four hex digits of a `\u` escape.
    fn hex4(&mut self) -> Result<u32, String> {
        let mut code = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or("bad \\u escape")? as char;
            code = code * 16 + c.to_digit(16).ok_or("bad hex digit in \\u escape")?;
        }
        Ok(code)
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        while matches!(
            self.peek(),
            Some(b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        ) {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).map_err(|e| e.to_string())?;
        s.parse::<f64>().map(Json::Num).map_err(|_| format!("invalid number '{s}'"))
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

// ------------------------------------------------------------- serializing

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if !n.is_finite() {
                    // NaN/±inf have no JSON representation; `{n}` would
                    // emit invalid tokens ("NaN", "inf"). Serialize as
                    // null, matching JavaScript's JSON.stringify.
                    write!(f, "null")
                } else if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

/// Convenience object builder.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_manifest_like_document() {
        let doc = r#"{
            "hlo": "gcn_cora.hlo.txt",
            "inputs": [{"name": "x", "shape": [2708, 1433], "dtype": "f32",
                        "file": "data", "offset": 0}],
            "meta": {"acc_fp32": 0.887, "quant": true, "note": null}
        }"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.get("hlo").unwrap().as_str().unwrap(), "gcn_cora.hlo.txt");
        let inputs = v.get("inputs").unwrap().as_array().unwrap();
        let shape = inputs[0].get("shape").unwrap().as_array().unwrap();
        assert_eq!(shape[0].as_u64(), Some(2708));
        assert_eq!(v.get("meta").unwrap().get("acc_fp32").unwrap().as_f64(), Some(0.887));
        assert_eq!(v.get("meta").unwrap().get("quant").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("meta").unwrap().get("note"), Some(&Json::Null));
    }

    #[test]
    fn round_trip() {
        let doc = r#"{"a":[1,2.5,-3,true,false,null,"s\"x"],"b":{"c":"d"}}"#;
        let v = Json::parse(doc).unwrap();
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("-1.5e3").unwrap().as_f64(), Some(-1500.0));
        assert_eq!(Json::parse("0").unwrap().as_u64(), Some(0));
        assert_eq!(Json::parse("-1").unwrap().as_u64(), None);
    }

    #[test]
    fn unicode_escapes_and_utf8() {
        let v = Json::parse(r#""café ☕""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "café ☕");
    }

    #[test]
    fn display_escapes() {
        let v = Json::Str("a\"b\nc".into());
        assert_eq!(v.to_string(), r#""a\"b\nc""#);
    }

    #[test]
    fn surrogate_pairs_decode_to_astral_chars() {
        // U+1F600 😀 = \ud83d\ude00. Used to decode to two U+FFFD.
        let v = Json::parse(r#""\ud83d\ude00""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "😀");
        // Round trip: the serializer emits raw UTF-8, the parser keeps it.
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
        // Mixed content around the pair, and raw UTF-8 passing through.
        let v = Json::parse(r#""a\ud83d\ude00b 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a😀b 😀");
    }

    #[test]
    fn unpaired_surrogates_become_replacement_chars() {
        assert_eq!(Json::parse(r#""\ud83d""#).unwrap().as_str().unwrap(), "\u{FFFD}");
        assert_eq!(Json::parse(r#""\ude00""#).unwrap().as_str().unwrap(), "\u{FFFD}");
        // High surrogate followed by a plain character.
        assert_eq!(Json::parse(r#""\ud83dx""#).unwrap().as_str().unwrap(), "\u{FFFD}x");
        // High surrogate followed by a \u escape that is not a low half:
        // replacement char, then the second escape decoded on its own.
        assert_eq!(
            Json::parse(r#""\ud83d\u0041""#).unwrap().as_str().unwrap(),
            "\u{FFFD}A"
        );
        // A failed candidate low half that is itself a high surrogate must
        // still open the pair that follows it.
        assert_eq!(
            Json::parse(r#""\ud83d\ud83d\ude00""#).unwrap().as_str().unwrap(),
            "\u{FFFD}\u{1F600}"
        );
    }

    #[test]
    fn non_finite_numbers_serialize_as_null() {
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let s = Json::Num(bad).to_string();
            assert_eq!(s, "null", "{bad} must not emit invalid JSON");
            // Round trip: the emitted document parses (to null).
            assert_eq!(Json::parse(&s).unwrap(), Json::Null);
        }
        // Nested: an array containing a NaN still round-trips as a
        // document.
        let doc = Json::Arr(vec![Json::Num(1.5), Json::Num(f64::NAN)]).to_string();
        assert_eq!(doc, "[1.5,null]");
        assert!(Json::parse(&doc).is_ok());
    }
}
