//! Deterministic pseudo-random number generation: PCG-XSH-RR 64/32 with a
//! 64-bit SplitMix seeder. Implemented in-crate (the build is offline);
//! the generator is fixed forever so dataset generation stays reproducible
//! across releases.

/// PCG-XSH-RR 64/32 (O'Neill 2014) — small, fast, statistically solid, and
/// deterministic across platforms.
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

fn splitmix64(x: &mut u64) -> u64 {
    *x = x.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *x;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Derives a decorrelated child seed from a base seed and a stream index
/// (SplitMix64 over the golden-ratio-spread combination). Parallel dataset
/// generation seeds graph `i` with `mix_seed(spec.seed, i)`, so every graph
/// is reproducible independently of generation order or worker count.
pub fn mix_seed(seed: u64, stream: u64) -> u64 {
    let mut s = seed ^ stream.wrapping_mul(0x9E3779B97F4A7C15);
    splitmix64(&mut s)
}

impl Pcg64 {
    /// Seed deterministically from a single u64.
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut s = seed;
        let state = splitmix64(&mut s);
        let inc = splitmix64(&mut s) | 1; // stream must be odd
        let mut rng = Self { state: 0, inc };
        rng.state = rng.state.wrapping_add(state);
        rng.next_u32();
        rng
    }

    /// Next 32 random bits.
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next 64 random bits.
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, 1)`.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform integer in `[lo, hi)` (Lemire-style unbiased rejection).
    pub fn gen_range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo, "empty range {lo}..{hi}");
        let span = (hi - lo) as u64;
        // Rejection sampling on the top bits to avoid modulo bias.
        let zone = u64::MAX - u64::MAX % span;
        loop {
            let v = self.next_u64();
            if v < zone {
                return lo + (v % span) as usize;
            }
        }
    }

    /// Uniform float in `[lo, hi)`.
    pub fn gen_range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.next_f64() * (hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn next_gaussian(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, data: &mut [T]) {
        for i in (1..data.len()).rev() {
            let j = self.gen_range(0, i + 1);
            data.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg64::seed_from_u64(42);
        let mut b = Pcg64::seed_from_u64(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::seed_from_u64(1);
        let mut b = Pcg64::seed_from_u64(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn gen_range_bounds_and_coverage() {
        let mut r = Pcg64::seed_from_u64(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.gen_range(3, 13);
            assert!((3..13).contains(&v));
            seen[v - 3] = true;
        }
        assert!(seen.iter().all(|&s| s), "all values in range must appear");
    }

    #[test]
    fn f64_in_unit_interval_and_roughly_uniform() {
        let mut r = Pcg64::seed_from_u64(9);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean = {mean}");
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Pcg64::seed_from_u64(11);
        let n = 100_000;
        let samples: Vec<f64> = (0..n).map(|_| r.next_gaussian()).collect();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean = {mean}");
        assert!((var - 1.0).abs() < 0.03, "var = {var}");
    }

    #[test]
    fn mix_seed_decorrelates_streams() {
        // Distinct (seed, stream) pairs must give distinct child seeds, and
        // the derivation must be pure.
        let mut seen = std::collections::HashSet::new();
        for seed in 0..8u64 {
            for stream in 0..64u64 {
                assert!(seen.insert(mix_seed(seed, stream)), "collision at ({seed}, {stream})");
            }
        }
        assert_eq!(mix_seed(42, 7), mix_seed(42, 7));
        assert_ne!(mix_seed(42, 0), 42, "child seed must not echo the base");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::seed_from_u64(13);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }
}
