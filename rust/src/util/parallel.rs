//! Scoped thread-pool `map` over a slice — the offline stand-in for rayon's
//! `par_iter().map()` — plus the index-range chunker used to fan contiguous
//! index spaces (output groups, graph lists) out with per-chunk scratch
//! state. Used by the architectural DSE sweep, the batch engine, partition
//! construction, and dataset generation.

/// The worker count [`par_map`] fans out to:
/// `std::thread::available_parallelism()`, falling back to 4 when the
/// platform cannot report it. Exposed so callers pinning explicit worker
/// counts (worker-invariance tests, serial-vs-parallel benches) can name
/// the default tier.
pub fn default_workers() -> usize {
    std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4)
}

/// Applies `f` to every element of `items`, fanning the index space across
/// [`default_workers`] scoped workers. Preserves order.
pub fn par_map<T, R, F>(items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    par_map_workers(items, default_workers(), f)
}

/// [`par_map`] with an explicit worker count (clamped to `1..=items.len()`).
/// Callers whose output must be provably worker-count-invariant (the serve
/// determinism tests, the partition property tests) pin different counts
/// and assert identical results.
pub fn par_map_workers<T, R, F>(items: &[T], workers: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.max(1).min(n);
    if workers <= 1 {
        return items.iter().map(&f).collect();
    }
    let next = std::sync::atomic::AtomicUsize::new(0);
    let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let slots_ptr = SendPtr(slots.as_mut_ptr());
    std::thread::scope(|scope| {
        for _ in 0..workers {
            let f = &f;
            let next = &next;
            let slots_ptr = &slots_ptr;
            scope.spawn(move || loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(&items[i]);
                // SAFETY: each index is claimed by exactly one worker via
                // the atomic counter, so writes never alias; the scope
                // guarantees the buffer outlives all workers.
                unsafe { *slots_ptr.0.add(i) = Some(r) };
            });
        }
    });
    slots.into_iter().map(|s| s.expect("worker filled every slot")).collect()
}

/// Raw-pointer wrapper that is Sync because disjoint indices are written by
/// disjoint workers (see SAFETY note above).
struct SendPtr<R>(*mut Option<R>);
unsafe impl<R: Send> Sync for SendPtr<R> {}

/// Splits `0..n` into at most `k` contiguous, near-equal ranges (the first
/// `n % k` ranges carry one extra element). Feed the ranges to [`par_map`]
/// when each worker needs private scratch state sized to the whole problem:
/// one allocation per chunk instead of one per element.
pub fn chunk_ranges(n: usize, k: usize) -> Vec<std::ops::Range<usize>> {
    if n == 0 || k == 0 {
        return Vec::new();
    }
    let k = k.min(n);
    let base = n / k;
    let extra = n % k;
    let mut out = Vec::with_capacity(k);
    let mut start = 0;
    for i in 0..k {
        let len = base + usize::from(i < extra);
        out.push(start..start + len);
        start += len;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order_and_values() {
        let items: Vec<u64> = (0..1000).collect();
        let out = par_map(&items, |&x| x * x);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, (i as u64) * (i as u64));
        }
    }

    #[test]
    fn empty_and_single() {
        let empty: Vec<u32> = vec![];
        assert!(par_map(&empty, |&x| x).is_empty());
        assert_eq!(par_map(&[7], |&x| x + 1), vec![8]);
    }

    #[test]
    fn chunk_ranges_cover_exactly_once() {
        for (n, k) in [(10usize, 3usize), (7, 7), (5, 9), (100, 4), (1, 1)] {
            let ranges = chunk_ranges(n, k);
            assert!(ranges.len() <= k);
            let mut next = 0;
            for r in &ranges {
                assert_eq!(r.start, next, "ranges must be contiguous");
                assert!(!r.is_empty(), "no empty chunks");
                next = r.end;
            }
            assert_eq!(next, n, "ranges must cover 0..{n}");
            // Near-equal: sizes differ by at most one.
            let sizes: Vec<usize> = ranges.iter().map(|r| r.len()).collect();
            let (min, max) =
                (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            assert!(max - min <= 1, "unbalanced chunks {sizes:?}");
        }
        assert!(chunk_ranges(0, 4).is_empty());
        assert!(chunk_ranges(4, 0).is_empty());
    }

    #[test]
    fn worker_count_does_not_change_results() {
        let items: Vec<u64> = (0..257).collect();
        let reference = par_map_workers(&items, 1, |&x| x.wrapping_mul(x) ^ 0xABCD);
        for workers in [2, 3, 8, 64, 1024] {
            let out = par_map_workers(&items, workers, |&x| x.wrapping_mul(x) ^ 0xABCD);
            assert_eq!(out, reference);
        }
    }

    #[test]
    fn actually_uses_threads_for_heavy_work() {
        // Smoke: results correct under contention.
        let items: Vec<usize> = (0..64).collect();
        let out = par_map(&items, |&x| {
            let mut acc = 0u64;
            for i in 0..10_000 {
                acc = acc.wrapping_add((x as u64).wrapping_mul(i));
            }
            acc
        });
        assert_eq!(out.len(), 64);
    }
}
