//! Scoped thread-pool `map` over a slice — the offline stand-in for rayon's
//! `par_iter().map()`, used by the architectural DSE sweep.

/// Applies `f` to every element of `items`, fanning the index space across
/// `std::thread::available_parallelism()` scoped workers. Preserves order.
pub fn par_map<T, R, F>(items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4).min(n);
    if workers <= 1 {
        return items.iter().map(&f).collect();
    }
    let next = std::sync::atomic::AtomicUsize::new(0);
    let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let slots_ptr = SendPtr(slots.as_mut_ptr());
    std::thread::scope(|scope| {
        for _ in 0..workers {
            let f = &f;
            let next = &next;
            let slots_ptr = &slots_ptr;
            scope.spawn(move || loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(&items[i]);
                // SAFETY: each index is claimed by exactly one worker via
                // the atomic counter, so writes never alias; the scope
                // guarantees the buffer outlives all workers.
                unsafe { *slots_ptr.0.add(i) = Some(r) };
            });
        }
    });
    slots.into_iter().map(|s| s.expect("worker filled every slot")).collect()
}

/// Raw-pointer wrapper that is Sync because disjoint indices are written by
/// disjoint workers (see SAFETY note above).
struct SendPtr<R>(*mut Option<R>);
unsafe impl<R: Send> Sync for SendPtr<R> {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order_and_values() {
        let items: Vec<u64> = (0..1000).collect();
        let out = par_map(&items, |&x| x * x);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, (i as u64) * (i as u64));
        }
    }

    #[test]
    fn empty_and_single() {
        let empty: Vec<u32> = vec![];
        assert!(par_map(&empty, |&x| x).is_empty());
        assert_eq!(par_map(&[7], |&x| x + 1), vec![8]);
    }

    #[test]
    fn actually_uses_threads_for_heavy_work() {
        // Smoke: results correct under contention.
        let items: Vec<usize> = (0..64).collect();
        let out = par_map(&items, |&x| {
            let mut acc = 0u64;
            for i in 0..10_000 {
                acc = acc.wrapping_add((x as u64).wrapping_mul(i));
            }
            acc
        });
        assert_eq!(out.len(), 64);
    }
}
