//! `ghost` — CLI for the GHOST silicon-photonic GNN accelerator
//! reproduction: run the simulator, regenerate the paper's tables and
//! figures, explore the design space, and drive real PJRT inference over
//! the AOT-compiled artifacts.
//!
//! Argument parsing is hand-rolled (the build is offline; see
//! `rust/src/util/`): `ghost <subcommand> [--flag[ value]]...`.

use std::collections::HashMap;

use anyhow::{anyhow, bail, Result};

use ghost::config::GhostConfig;
use ghost::coordinator::{dse as arch_dse, BatchEngine, OptFlags, SimRequest};
use ghost::figures;
use ghost::gnn::models::ModelKind;
use ghost::photonics::devices::DeviceParams;
use ghost::photonics::dse as device_dse;
#[cfg(feature = "pjrt")]
use ghost::runtime::{argmax_rows, masked_accuracy, Engine};
use ghost::util::json::Json;

const USAGE: &str = "\
ghost — GHOST silicon-photonic GNN accelerator (paper reproduction)

USAGE:
  ghost run --model <gcn|graphsage|gin|gat> --dataset <name>
            [--no-bp] [--no-pp] [--no-dac-sharing] [--wb]
        <name>: a Table-2 dataset (Cora, PubMed, Citeseer, Amazon,
        Proteins, Mutag, BZR, IMDB-binary), a large-tier dataset
        (ogbn-arxiv-syn, reddit-syn), or a parameterized R-MAT spec
        rmat-<V>v-<E>e[-<F>f][-<L>l][-<G>g][-<S>s]
  ghost dse [--coherent] [--noncoherent] [--arch] [--quick]
  ghost figures [--table1] [--table2] [--table3] [--fig8] [--fig9]
                [--comparison] [--datasets] [--all]
  ghost infer --artifact <name> [--dir artifacts] [--reps N]   (feature pjrt)
  ghost help
";

/// Tiny flag parser: `--key value` for options, `--key` for booleans.
struct Args {
    flags: HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String], boolean_flags: &[&str]) -> Result<Self> {
        let mut flags = HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            let key = a
                .strip_prefix("--")
                .ok_or_else(|| anyhow!("unexpected argument '{a}'"))?
                .to_string();
            if boolean_flags.contains(&key.as_str()) {
                flags.insert(key, "true".into());
                i += 1;
            } else {
                let val = argv
                    .get(i + 1)
                    .ok_or_else(|| anyhow!("flag --{key} expects a value"))?
                    .clone();
                flags.insert(key, val);
                i += 2;
            }
        }
        Ok(Self { flags })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    fn require(&self, key: &str) -> Result<&str> {
        self.get(key).ok_or_else(|| anyhow!("missing required flag --{key}"))
    }
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else {
        print!("{USAGE}");
        return Ok(());
    };
    let rest = &argv[1..];
    match cmd.as_str() {
        "run" => cmd_run(rest),
        "dse" => cmd_dse(rest),
        "figures" => cmd_figures(rest),
        "infer" => cmd_infer(rest),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown subcommand '{other}'\n{USAGE}"),
    }
}

fn cmd_run(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv, &["no-bp", "no-pp", "no-dac-sharing", "wb"])?;
    let model = args.require("model")?;
    let dataset = args.require("dataset")?;
    let kind = ModelKind::by_name(model).ok_or_else(|| anyhow!("unknown model {model}"))?;
    let wb = args.has("wb");
    let flags = OptFlags {
        buffer_partition: !args.has("no-bp"),
        pipelining: !args.has("no-pp"),
        dac_sharing: !args.has("no-dac-sharing") && !wb,
        workload_balancing: wb,
    };
    let r = BatchEngine::global()
        .run(&SimRequest::new(kind, dataset, GhostConfig::paper_optimal(), flags))?;
    println!("GHOST simulation: {} / {}", r.model.name(), r.dataset);
    println!("  flags        : {}", r.flags.label());
    println!("  latency      : {:.3} us", r.metrics.latency_s * 1e6);
    println!("  energy       : {:.3} mJ", r.metrics.energy_j * 1e3);
    println!("  power        : {:.2} W (platform {:.2} W)", r.metrics.power_w(), r.platform_w);
    println!("  throughput   : {:.1} GOPS", r.metrics.gops());
    println!("  EPB          : {:.3e} J/bit", r.metrics.epb());
    println!("  EPB/GOPS     : {:.3e}", r.metrics.epb_per_gops());
    let (a, c, u) = r.breakdown();
    println!(
        "  breakdown    : aggregate {:.1}% | combine {:.1}% | update {:.1}%",
        a * 100.0,
        c * 100.0,
        u * 100.0
    );
    Ok(())
}

fn cmd_dse(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv, &["coherent", "noncoherent", "arch", "quick"])?;
    let all = !args.has("coherent") && !args.has("noncoherent") && !args.has("arch");
    if args.has("coherent") || all {
        let p = DeviceParams::paper();
        println!("Fig. 7(a): coherent MR bank feasibility (SNR cutoff per eq. 12)");
        for lambda in [1520.0, 1530.0, 1540.0, 1550.0, 1560.0, 1570.0] {
            let max = device_dse::max_feasible_coherent(&p, lambda, 40);
            println!("  lambda {lambda:.0} nm: up to {max} MRs per coherent chain");
        }
    }
    if args.has("noncoherent") || all {
        println!("Fig. 7(b): non-coherent WDM bank feasibility (1 nm spacing from 1550 nm)");
        let max = device_dse::max_feasible_noncoherent(30);
        println!("  up to {max} wavelengths ({} MRs)", 2 * max);
        for pt in device_dse::noncoherent_sweep(24) {
            println!(
                "  {:>2} MRs: SNR {:.2} dB (cutoff {:.2} dB) {}",
                pt.n_mrs,
                pt.snr_db,
                pt.cutoff_db,
                if pt.feasible { "ok" } else { "infeasible" }
            );
        }
    }
    if args.has("arch") || all {
        println!("Fig. 7(c): architectural DSE over [N,V,Rr,Rc,Tr] (EPB/GOPS, lower = better)");
        let grid = arch_dse::default_grid();
        let workloads = arch_dse::workload_set(args.has("quick"))?;
        let engine = BatchEngine::new();
        let report = arch_dse::explore_with_engine(&engine, &grid, &workloads);
        for (i, p) in report.points.iter().take(10).enumerate() {
            println!(
                "  #{:<2} [{}, {}, {}, {}, {}]  EPB/GOPS {:.3e}  GOPS {:.0}  EPB {:.3e}",
                i + 1,
                p.cfg.n,
                p.cfg.v,
                p.cfg.r_r,
                p.cfg.r_c,
                p.cfg.t_r,
                p.epb_per_gops,
                p.gops,
                p.epb
            );
        }
        if let Some(rank) =
            report.points.iter().position(|p| p.cfg == GhostConfig::paper_optimal())
        {
            println!(
                "  paper point [20,20,18,7,17] ranks #{} of {}",
                rank + 1,
                report.points.len()
            );
        }
        if !report.failures.is_empty() {
            println!("  {} configuration(s) failed or were filtered:", report.failures.len());
            for f in report.failures.iter().take(5) {
                let c = f.cfg;
                println!(
                    "    [{}, {}, {}, {}, {}]: {}",
                    c.n, c.v, c.r_r, c.r_c, c.t_r, f.error
                );
            }
        }
        println!(
            "  partition sets built once per (dataset, V, N): {}",
            engine.partition_builds()
        );
    }
    Ok(())
}

fn cmd_figures(argv: &[String]) -> Result<()> {
    let args = Args::parse(
        argv,
        &["table1", "table2", "table3", "fig8", "fig9", "comparison", "datasets", "all"],
    )?;
    let all = args.has("all")
        || !(args.has("table1")
            || args.has("table2")
            || args.has("table3")
            || args.has("fig8")
            || args.has("fig9")
            || args.has("comparison")
            || args.has("datasets"));
    let cfg = GhostConfig::paper_optimal();
    if args.has("datasets") {
        figures::print_dataset_catalog();
        println!();
    }
    if args.has("table1") || all {
        figures::print_table1();
        println!();
    }
    if args.has("table2") || all {
        figures::print_table2();
        println!();
    }
    if args.has("table3") || all {
        print_table3()?;
        println!();
    }
    if args.has("fig8") || all {
        figures::print_fig8(cfg);
        println!();
    }
    if args.has("fig9") || all {
        figures::print_fig9(cfg);
        println!();
    }
    if args.has("comparison") || all {
        figures::print_comparison(cfg);
    }
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_infer(_argv: &[String]) -> Result<()> {
    bail!(
        "`ghost infer` needs the PJRT datapath, which this binary was built \
         without: add the `xla` crate (xla-rs, with a local xla_extension \
         install) to rust/Cargo.toml, then rebuild with `--features pjrt`. \
         See the Feature gating section of rust/src/runtime/mod.rs."
    )
}

#[cfg(feature = "pjrt")]
fn cmd_infer(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv, &[])?;
    let artifact = args.require("artifact")?;
    let dir = args.get("dir").unwrap_or("artifacts");
    let reps: usize = args.get("reps").unwrap_or("3").parse()?;
    let engine = Engine::load(dir, artifact)?;
    println!("loaded {artifact} on {}", engine.platform());
    let mut last = None;
    let mut times = Vec::new();
    for _ in 0..reps.max(1) {
        let t0 = std::time::Instant::now();
        let out = engine.run()?;
        times.push(t0.elapsed().as_secs_f64());
        last = Some(out);
    }
    let outputs = last.unwrap();
    let logits = outputs[0].as_f32()?;
    let shape = outputs[0].shape().to_vec();
    println!("output logits shape {shape:?}");
    if let (Ok(labels), 2) = (engine.extra("labels"), shape.len()) {
        let pred = argmax_rows(logits, shape[0], shape[1]);
        let mask = engine.extra("test_mask").ok();
        let acc = masked_accuracy(
            &pred,
            labels.as_i32()?,
            mask.as_ref().and_then(|m| m.as_i32().ok()),
        );
        println!("accuracy: {:.2}%", acc * 100.0);
    }
    let best = times.iter().cloned().fold(f64::INFINITY, f64::min);
    println!("PJRT execute latency: best {:.3} ms over {} reps", best * 1e3, times.len());
    Ok(())
}

/// Table 3: model accuracies at fp32 vs int8, measured by
/// `python/compile/train.py` during `make artifacts`.
fn print_table3() -> Result<()> {
    let path = "artifacts/accuracy.json";
    match std::fs::read_to_string(path) {
        Ok(s) => {
            let rows = Json::parse(&s).map_err(|e| anyhow!("parsing {path}: {e}"))?;
            println!("Table 3: GNN model accuracy (fp32 vs int8), measured");
            println!("{:<10} {:<12} {:>10} {:>10}", "Model", "Dataset", "fp32", "int8");
            if let Some(arr) = rows.as_array() {
                for r in arr {
                    println!(
                        "{:<10} {:<12} {:>9.1}% {:>9.1}%",
                        r.get("model").and_then(Json::as_str).unwrap_or("?"),
                        r.get("dataset").and_then(Json::as_str).unwrap_or("?"),
                        r.get("acc_fp32").and_then(Json::as_f64).unwrap_or(0.0) * 100.0,
                        r.get("acc_int8").and_then(Json::as_f64).unwrap_or(0.0) * 100.0,
                    );
                }
            }
        }
        Err(_) => println!("Table 3: run `make artifacts` first ({path} not found)"),
    }
    Ok(())
}
