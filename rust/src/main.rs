//! `ghost` — CLI for the GHOST silicon-photonic GNN accelerator
//! reproduction: run the simulator, regenerate the paper's tables and
//! figures, explore the design space, and drive real PJRT inference over
//! the AOT-compiled artifacts.
//!
//! Argument parsing is hand-rolled (the build is offline; see
//! `rust/src/util/`): `ghost <subcommand> [--flag[ value]]...`.

use std::collections::HashMap;

use anyhow::{anyhow, bail, Result};

use ghost::config::GhostConfig;
use ghost::coordinator::{delta_counters, dse as arch_dse, BatchEngine, OptFlags, SimRequest};
use ghost::figures;
use ghost::gnn::models::ModelKind;
use ghost::photonics::devices::DeviceParams;
use ghost::photonics::dse as device_dse;
#[cfg(feature = "pjrt")]
use ghost::runtime::{argmax_rows, masked_accuracy, Engine};
use ghost::serve::{
    self, ArrivalProcess, BatchPolicy, CapacityPlanRequest, ChurnSpec, RoutePolicy,
    ServeConfig, TenantMix, TenantProfile, TrafficSpec,
};
use ghost::util::json::Json;

const USAGE: &str = "\
ghost — GHOST silicon-photonic GNN accelerator (paper reproduction)

USAGE:
  ghost run --model <gcn|graphsage|gin|gat> --dataset <name>
            [--no-bp] [--no-pp] [--no-dac-sharing] [--wb] [--shards N] [--json]
            [--trace] [--trace-out <path>] [--trace-sim <path>]
        <name>: a Table-2 dataset (Cora, PubMed, Citeseer, Amazon,
        Proteins, Mutag, BZR, IMDB-binary), a large-tier dataset
        (ogbn-arxiv-syn, reddit-syn), or a parameterized R-MAT spec
        rmat-<V>v-<E>e[-<F>f][-<L>l][-<G>g][-<S>s]
        --shards N executes the sharded multi-chip plan: the partition is
        split over N chips and cross-shard gathers become RemoteGather
        stages over the inter-chip link. Graphs whose per-chip footprint
        exceeds the chip memory budget error with the minimum shard count.
        --json emits the report plus the process-wide incremental-plan
        rebuild/patch counters as one JSON object.
        --trace enables wall-clock span tracing (also GHOST_TRACE=1) and
        writes a Chrome-trace-event JSON (load at ui.perfetto.dev) to
        --trace-out (default ghost_trace.json, or GHOST_TRACE=<path>).
        --trace-sim <path> writes the modeled hardware schedule as a
        simulated-time Chrome trace: one track per chip pipeline
        position, stages labeled by kind, RemoteGather barriers marked;
        its per-kind busy/energy totals equal the report's exactly.
  ghost dse [--coherent] [--noncoherent] [--arch] [--quick] [--json]
        --json runs the architectural sweep and emits the frontier,
        failures, and delta-evaluator rebuild/patch counters as one JSON
        object. Sweeps delta-evaluate by default (GHOST_DSE_DELTA=0 forces
        full rebuilds; GHOST_DSE_CHECK=1 cross-checks every point against
        the reference evaluator).
  ghost figures [--table1] [--table2] [--table3] [--fig8] [--fig9]
                [--comparison] [--datasets] [--sharding] [--all] [--json]
                [--shards <n,n,...>] [--shard-model <m>] [--shard-dataset <d>]
        --json emits the selected sections as one JSON object; the fig9
        and sharding sections carry the exact per-stage-kind busy/energy
        breakdown (incl. remote_gather). --sharding sweeps one workload
        over shard counts (default gcn / rmat-20000v-120000e / 1,2,4) and
        reports the communication-vs-compute split; it is explicit-only
        (not part of --all).
  ghost serve --model <m> --dataset <d> | --mix <m:d[:w],...>
              [--rps N] [--accelerators N] [--duration S] [--seed N]
              [--policy rr|jsq|affinity] [--batch immediate|max:<n>:<ms>|slo[:<n>]]
              [--arrival poisson|bursty|diurnal] [--slo-ms MS]
              [--clients N --think-ms MS] [--shards N]
              [--churn <edges/s> [--churn-batch N]] [--json]
              [--trace [--trace-out <path>]]
        online-serving simulation: replay a request stream against an
        N-accelerator fleet; report throughput, utilization, and exact
        p50/p95/p99/p999 latency. --clients switches to closed loop.
        --shards N gangs the fleet into groups of N chips; every request
        occupies its tenant's whole shard group (accelerators % N == 0).
        --churn serves under graph mutation: a seeded Poisson stream of
        edge-edit batches (--churn-batch ops each, default 8) mutates
        tenant datasets mid-run; partitions are spliced and plans patched
        incrementally (GHOST_CHURN_CHECK=1 cross-checks every patch
        against a cold rebuild), and the report gains a churn block plus
        the delta rebuild/patch counters under --json.
        --trace records spans for the serve event loop (and everything
        beneath it) and writes the wall-clock Chrome trace on exit.
  ghost plan-capacity --model <m> --dataset <d> | --mix <m:d[:w],...>
              --slo-ms MS [--rps N,N,...] [--max-accelerators N]
              [--duration S] [--seed N] [--policy rr|jsq|affinity]
              [--batch immediate|max:<n>:<ms>|slo[:<n>]]
              [--arrival poisson|bursty|diurnal] [--shards N]
              [--workers N] [--json]
        capacity planner: for each --rps point (comma-separated offered
        rates, default 500,1000,2000) bisect the fleet size to the
        minimum accelerator count whose p99 latency meets --slo-ms, up
        to --max-accelerators (default 16). Probe rounds fan out over
        the parallel sweep executor (--workers threads, default machine
        width); every probe shares the engine caches, so all plan and
        profile builds happen in round 1 — the curve reports the counter
        snapshots that witness it. --json emits the capacity-vs-rps
        curve (per point: min_accelerators or null, p99 at the minimum,
        p99 one shard group below) as one JSON object.
  ghost infer --artifact <name> [--dir artifacts] [--reps N]   (feature pjrt)
  ghost help

  Flags accept both '--key value' and '--key=value'; duplicates are errors.
";

/// Tiny flag parser: `--key value` or `--key=value` for options, `--key`
/// (or `--key=true`/`--key=false`) for booleans. Repeating a flag is an
/// error — silently keeping the last occurrence hid typos like
/// `--model gcn --model gat`.
struct Args {
    flags: HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String], boolean_flags: &[&str]) -> Result<Self> {
        let mut flags = HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            let body = a.strip_prefix("--").ok_or_else(|| anyhow!("unexpected argument '{a}'"))?;
            let (key, inline) = match body.split_once('=') {
                Some((k, v)) => (k.to_string(), Some(v.to_string())),
                None => (body.to_string(), None),
            };
            if key.is_empty() {
                bail!("malformed flag '{a}'");
            }
            let is_boolean = boolean_flags.contains(&key.as_str());
            let val = match inline {
                Some(v) => {
                    if is_boolean && v != "true" && v != "false" {
                        bail!("boolean flag --{key} accepts only 'true' or 'false', got '{v}'");
                    }
                    i += 1;
                    v
                }
                None if is_boolean => {
                    i += 1;
                    "true".into()
                }
                None => {
                    let v = argv
                        .get(i + 1)
                        .ok_or_else(|| anyhow!("flag --{key} expects a value"))?
                        .clone();
                    i += 2;
                    v
                }
            };
            if flags.insert(key.clone(), val).is_some() {
                bail!("duplicate flag --{key}");
            }
        }
        Ok(Self { flags })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    /// True when a boolean flag is set (bare `--flag` or `--flag=true`).
    fn has(&self, key: &str) -> bool {
        self.get(key) == Some("true")
    }

    fn require(&self, key: &str) -> Result<&str> {
        self.get(key).ok_or_else(|| anyhow!("missing required flag --{key}"))
    }
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else {
        print!("{USAGE}");
        return Ok(());
    };
    let rest = &argv[1..];
    match cmd.as_str() {
        "run" => cmd_run(rest),
        "dse" => cmd_dse(rest),
        "figures" => cmd_figures(rest),
        "serve" => cmd_serve(rest),
        "plan-capacity" => cmd_plan_capacity(rest),
        "infer" => cmd_infer(rest),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown subcommand '{other}'\n{USAGE}"),
    }
}

fn cmd_run(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv, &["no-bp", "no-pp", "no-dac-sharing", "wb", "json", "trace"])?;
    if args.has("trace") {
        ghost::util::telemetry::set_enabled(true);
    }
    let model = args.require("model")?;
    let dataset = args.require("dataset")?;
    let kind = ModelKind::by_name(model).ok_or_else(|| anyhow!("unknown model {model}"))?;
    let wb = args.has("wb");
    let flags = OptFlags {
        buffer_partition: !args.has("no-bp"),
        pipelining: !args.has("no-pp"),
        dac_sharing: !args.has("no-dac-sharing") && !wb,
        workload_balancing: wb,
    };
    let shards: usize = args.get("shards").unwrap_or("1").parse()?;
    let req = SimRequest::new(kind, dataset, GhostConfig::paper_optimal(), flags);
    let engine = BatchEngine::global();
    if let Some(path) = args.get("trace-sim") {
        // The simulated-time timeline comes from the same cached plan the
        // run below evaluates, so the trace and the report agree exactly.
        let timeline = if shards > 1 {
            ghost::coordinator::sim_timeline_sharded(&engine.sharded_plan(&req, shards)?)?
        } else {
            ghost::coordinator::sim_timeline(&engine.plan(&req)?)?
        };
        std::fs::write(path, format!("{timeline}\n"))?;
        eprintln!("wrote simulated-time trace to {path}");
    }
    let r = if shards > 1 { engine.run_sharded(&req, shards)? } else { engine.run(&req)? };
    if args.has("json") {
        let (a, c, u) = r.breakdown();
        let (rebuilds, patches) = delta_counters();
        println!(
            "{}",
            ghost::util::json::obj(vec![
                ("model", Json::Str(r.model.name().to_string())),
                ("dataset", Json::Str(r.dataset.clone())),
                ("flags", Json::Str(r.flags.label())),
                ("shards", Json::Num(shards as f64)),
                ("latency_s", Json::Num(r.metrics.latency_s)),
                ("energy_j", Json::Num(r.metrics.energy_j)),
                ("power_w", Json::Num(r.metrics.power_w())),
                ("gops", Json::Num(r.metrics.gops())),
                ("epb", Json::Num(r.metrics.epb())),
                ("epb_per_gops", Json::Num(r.metrics.epb_per_gops())),
                (
                    "breakdown",
                    ghost::util::json::obj(vec![
                        ("aggregate", Json::Num(a)),
                        ("combine", Json::Num(c)),
                        ("update", Json::Num(u)),
                    ])
                ),
                (
                    "delta",
                    ghost::util::json::obj(vec![
                        ("rebuilds", Json::Num(rebuilds as f64)),
                        ("patches", Json::Num(patches as f64)),
                    ])
                ),
            ])
        );
        maybe_write_wall_trace(&args)?;
        return Ok(());
    }
    println!("GHOST simulation: {} / {}", r.model.name(), r.dataset);
    println!("  flags        : {}", r.flags.label());
    if shards > 1 {
        let comm = &r.kinds.remote_gather;
        println!("  shards       : {shards} chips");
        println!(
            "  remote gather: {:.3} us busy, {:.3} mJ over the inter-chip link",
            comm.latency_s * 1e6,
            comm.energy_j * 1e3
        );
    }
    println!("  latency      : {:.3} us", r.metrics.latency_s * 1e6);
    println!("  energy       : {:.3} mJ", r.metrics.energy_j * 1e3);
    println!("  power        : {:.2} W (platform {:.2} W)", r.metrics.power_w(), r.platform_w);
    println!("  throughput   : {:.1} GOPS", r.metrics.gops());
    println!("  EPB          : {:.3e} J/bit", r.metrics.epb());
    println!("  EPB/GOPS     : {:.3e}", r.metrics.epb_per_gops());
    let (a, c, u) = r.breakdown();
    println!(
        "  breakdown    : aggregate {:.1}% | combine {:.1}% | update {:.1}%",
        a * 100.0,
        c * 100.0,
        u * 100.0
    );
    maybe_write_wall_trace(&args)?;
    Ok(())
}

/// Writes the wall-clock Chrome trace when tracing is enabled: to
/// `--trace-out`, else `GHOST_TRACE=<path>`, else `ghost_trace.json`.
/// The notice goes to stderr so `--json` stdout stays machine-readable.
fn maybe_write_wall_trace(args: &Args) -> Result<()> {
    use ghost::util::telemetry;
    if !telemetry::enabled() {
        return Ok(());
    }
    let path = args
        .get("trace-out")
        .map(str::to_string)
        .or_else(telemetry::env_trace_path)
        .unwrap_or_else(|| "ghost_trace.json".to_string());
    telemetry::trace::write_wall_trace(&path)?;
    eprintln!("wrote wall-clock trace to {path}");
    Ok(())
}

fn cmd_dse(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv, &["coherent", "noncoherent", "arch", "quick", "json"])?;
    if args.has("json") {
        // --json runs the architectural sweep (Fig. 7c) and emits the full
        // frontier, the failures, and the delta-evaluator counters as one
        // machine-readable object — the CI smoke diffs this output between
        // GHOST_DSE_DELTA=0 and =1.
        let grid = arch_dse::default_grid();
        let workloads = arch_dse::workload_set(args.has("quick"))?;
        let engine = BatchEngine::new();
        let report = arch_dse::explore_with_engine(&engine, &grid, &workloads);
        let cfg_json = |c: &GhostConfig| {
            ghost::util::json::obj(vec![
                ("n", Json::Num(c.n as f64)),
                ("v", Json::Num(c.v as f64)),
                ("r_r", Json::Num(c.r_r as f64)),
                ("r_c", Json::Num(c.r_c as f64)),
                ("t_r", Json::Num(c.t_r as f64)),
                ("chip_mem_bytes", Json::Num(c.chip_mem_bytes as f64)),
            ])
        };
        let points = Json::Arr(
            report
                .points
                .iter()
                .map(|p| {
                    ghost::util::json::obj(vec![
                        ("cfg", cfg_json(&p.cfg)),
                        ("epb_per_gops", Json::Num(p.epb_per_gops)),
                        ("gops", Json::Num(p.gops)),
                        ("epb", Json::Num(p.epb)),
                    ])
                })
                .collect(),
        );
        let failures = Json::Arr(
            report
                .failures
                .iter()
                .map(|f| {
                    ghost::util::json::obj(vec![
                        ("cfg", cfg_json(&f.cfg)),
                        ("error", Json::Str(f.error.to_string())),
                    ])
                })
                .collect(),
        );
        let delta = ghost::util::json::obj(vec![
            ("enabled", Json::Bool(arch_dse::delta_evaluation_enabled())),
            ("rebuilds", Json::Num(report.delta.rebuilds as f64)),
            ("patches", Json::Num(report.delta.patches as f64)),
        ]);
        println!(
            "{}",
            ghost::util::json::obj(vec![
                ("quick", Json::Bool(args.has("quick"))),
                ("grid_points", Json::Num(grid.len() as f64)),
                ("partition_builds", Json::Num(engine.partition_builds() as f64)),
                ("delta", delta),
                ("points", points),
                ("failures", failures),
            ])
        );
        return Ok(());
    }
    let all = !args.has("coherent") && !args.has("noncoherent") && !args.has("arch");
    if args.has("coherent") || all {
        let p = DeviceParams::paper();
        println!("Fig. 7(a): coherent MR bank feasibility (SNR cutoff per eq. 12)");
        for lambda in [1520.0, 1530.0, 1540.0, 1550.0, 1560.0, 1570.0] {
            let max = device_dse::max_feasible_coherent(&p, lambda, 40);
            println!("  lambda {lambda:.0} nm: up to {max} MRs per coherent chain");
        }
    }
    if args.has("noncoherent") || all {
        println!("Fig. 7(b): non-coherent WDM bank feasibility (1 nm spacing from 1550 nm)");
        let max = device_dse::max_feasible_noncoherent(30);
        println!("  up to {max} wavelengths ({} MRs)", 2 * max);
        for pt in device_dse::noncoherent_sweep(24) {
            println!(
                "  {:>2} MRs: SNR {:.2} dB (cutoff {:.2} dB) {}",
                pt.n_mrs,
                pt.snr_db,
                pt.cutoff_db,
                if pt.feasible { "ok" } else { "infeasible" }
            );
        }
    }
    if args.has("arch") || all {
        println!("Fig. 7(c): architectural DSE over [N,V,Rr,Rc,Tr] (EPB/GOPS, lower = better)");
        let grid = arch_dse::default_grid();
        let workloads = arch_dse::workload_set(args.has("quick"))?;
        let engine = BatchEngine::new();
        let report = arch_dse::explore_with_engine(&engine, &grid, &workloads);
        for (i, p) in report.points.iter().take(10).enumerate() {
            println!(
                "  #{:<2} [{}, {}, {}, {}, {}]  EPB/GOPS {:.3e}  GOPS {:.0}  EPB {:.3e}",
                i + 1,
                p.cfg.n,
                p.cfg.v,
                p.cfg.r_r,
                p.cfg.r_c,
                p.cfg.t_r,
                p.epb_per_gops,
                p.gops,
                p.epb
            );
        }
        if let Some(rank) =
            report.points.iter().position(|p| p.cfg == GhostConfig::paper_optimal())
        {
            println!(
                "  paper point [20,20,18,7,17] ranks #{} of {}",
                rank + 1,
                report.points.len()
            );
        }
        if !report.failures.is_empty() {
            println!("  {} configuration(s) failed or were filtered:", report.failures.len());
            for f in report.failures.iter().take(5) {
                let c = f.cfg;
                println!(
                    "    [{}, {}, {}, {}, {}]: {}",
                    c.n, c.v, c.r_r, c.r_c, c.t_r, f.error
                );
            }
        }
        println!(
            "  partition sets built once per (dataset, V, N): {}",
            engine.partition_builds()
        );
        if arch_dse::delta_evaluation_enabled() {
            println!(
                "  delta evaluation: {} full rebuilds, {} lane patches \
                 (GHOST_DSE_DELTA=0 to disable)",
                report.delta.rebuilds, report.delta.patches
            );
        }
    }
    Ok(())
}

/// Parses the `--sharding` sweep flags: `--shards` csv (default 1,2,4),
/// `--shard-model` (default gcn), `--shard-dataset` (default a mid-size
/// R-MAT graph large enough for cross-shard traffic to matter).
fn parse_sharding_args(args: &Args) -> Result<(ModelKind, String, Vec<usize>)> {
    let model = args.get("shard-model").unwrap_or("gcn");
    let kind = ModelKind::by_name(model).ok_or_else(|| anyhow!("unknown model {model}"))?;
    let dataset = args.get("shard-dataset").unwrap_or("rmat-20000v-120000e").to_string();
    let mut shard_counts = Vec::new();
    for part in args.get("shards").unwrap_or("1,2,4").split(',') {
        let n: usize =
            part.trim().parse().map_err(|_| anyhow!("bad shard count '{part}' in --shards"))?;
        shard_counts.push(n);
    }
    Ok((kind, dataset, shard_counts))
}

fn cmd_figures(argv: &[String]) -> Result<()> {
    let args = Args::parse(
        argv,
        &[
            "table1", "table2", "table3", "fig8", "fig9", "comparison", "datasets", "sharding",
            "all", "json",
        ],
    )?;
    // `--sharding` is explicit-only: a bare `ghost figures` (or `--all`)
    // regenerates the paper's sections, not the sharding sweep.
    let all = args.has("all")
        || !(args.has("table1")
            || args.has("table2")
            || args.has("table3")
            || args.has("fig8")
            || args.has("fig9")
            || args.has("comparison")
            || args.has("datasets")
            || args.has("sharding"));
    let cfg = GhostConfig::paper_optimal();
    if args.has("json") {
        // One JSON object holding every selected section, machine-readable
        // (the CI smoke checks the fig9 per-kind breakdown sums against
        // total_busy_s from this output).
        let mut sections: Vec<(&str, Json)> = Vec::new();
        if args.has("datasets") {
            sections.push(("datasets", figures::dataset_catalog_json()));
        }
        if args.has("table1") || all {
            sections.push(("table1", figures::table1_json()));
        }
        if args.has("table2") || all {
            sections.push(("table2", figures::table2_json()?));
        }
        if args.has("table3") || all {
            sections.push(("table3", table3_json()));
        }
        if args.has("fig8") || all {
            sections.push(("fig8", figures::fig8_json(cfg)?));
        }
        if args.has("fig9") || all {
            sections.push(("fig9", figures::fig9_json(cfg)?));
        }
        if args.has("comparison") || all {
            sections.push(("comparison", figures::comparison_json(cfg)?));
        }
        if args.has("sharding") {
            let (kind, dataset, shard_counts) = parse_sharding_args(&args)?;
            sections.push((
                "sharding",
                figures::sharding_json(cfg, kind, &dataset, &shard_counts)?,
            ));
        }
        println!("{}", ghost::util::json::obj(sections));
        return Ok(());
    }
    if args.has("datasets") {
        figures::print_dataset_catalog();
        println!();
    }
    if args.has("table1") || all {
        figures::print_table1();
        println!();
    }
    if args.has("table2") || all {
        figures::print_table2()?;
        println!();
    }
    if args.has("table3") || all {
        print_table3()?;
        println!();
    }
    if args.has("fig8") || all {
        figures::print_fig8(cfg)?;
        println!();
    }
    if args.has("fig9") || all {
        figures::print_fig9(cfg)?;
        println!();
    }
    if args.has("comparison") || all {
        figures::print_comparison(cfg)?;
        println!();
    }
    if args.has("sharding") {
        let (kind, dataset, shard_counts) = parse_sharding_args(&args)?;
        figures::print_sharding(cfg, kind, &dataset, &shard_counts)?;
    }
    Ok(())
}

/// Parses a `model:dataset[:weight]` comma-separated tenant mix.
fn parse_mix(spec: &str) -> Result<TenantMix> {
    let mut tenants = Vec::new();
    for part in spec.split(',') {
        let fields: Vec<&str> = part.split(':').collect();
        if fields.len() < 2 || fields.len() > 3 {
            bail!("tenant '{part}' must be model:dataset[:weight]");
        }
        let model = ModelKind::by_name(fields[0])
            .ok_or_else(|| anyhow!("unknown model '{}' in tenant '{part}'", fields[0]))?;
        let weight: f64 = match fields.get(2) {
            Some(w) => w
                .parse()
                .map_err(|_| anyhow!("bad weight '{w}' in tenant '{part}'"))?,
            None => 1.0,
        };
        tenants.push(TenantProfile::new(model, fields[1], weight));
    }
    TenantMix::new(tenants).map_err(|e| anyhow!(e))
}

/// Parses a `--batch` spec: `immediate`, `max:<n>:<wait_ms>`, or
/// `slo[:<n>]` (needs `--slo-ms`).
fn parse_batch_policy(spec: &str, slo_s: Option<f64>) -> Result<BatchPolicy> {
    let fields: Vec<&str> = spec.split(':').collect();
    match fields[0] {
        "immediate" => Ok(BatchPolicy::Immediate),
        "max" => {
            if fields.len() != 3 {
                bail!("--batch max policy is max:<n>:<wait_ms>");
            }
            let max_batch: usize = fields[1].parse()?;
            let wait_ms: f64 = fields[2].parse()?;
            Ok(BatchPolicy::MaxBatchOrWait { max_batch, max_wait_s: wait_ms * 1e-3 })
        }
        "slo" => {
            let slo_s =
                slo_s.ok_or_else(|| anyhow!("--batch slo requires --slo-ms"))?;
            let max_batch: usize =
                if fields.len() > 1 { fields[1].parse()? } else { 16 };
            Ok(BatchPolicy::SloAware { slo_s, max_batch })
        }
        other => {
            bail!("unknown batch policy '{other}' (immediate | max:<n>:<wait_ms> | slo[:<n>])")
        }
    }
}

fn cmd_serve(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv, &["json", "trace"])?;
    if args.has("trace") {
        ghost::util::telemetry::set_enabled(true);
    }
    // Reject conflicting flag sets instead of silently ignoring one side
    // (the same rationale as the duplicate-flag error).
    if args.get("mix").is_some() && (args.get("model").is_some() || args.get("dataset").is_some())
    {
        bail!("--mix conflicts with --model/--dataset: pick one way to name tenants");
    }
    if args.get("clients").is_some() && (args.get("rps").is_some() || args.get("arrival").is_some())
    {
        bail!("--clients (closed loop) conflicts with --rps/--arrival (open loop)");
    }
    if args.get("think-ms").is_some() && args.get("clients").is_none() {
        bail!("--think-ms only applies to closed-loop traffic; add --clients");
    }
    let mix = match args.get("mix") {
        Some(spec) => parse_mix(spec)?,
        None => {
            let model = args.require("model")?;
            let dataset = args.require("dataset")?;
            let kind =
                ModelKind::by_name(model).ok_or_else(|| anyhow!("unknown model {model}"))?;
            TenantMix::new(vec![TenantProfile::new(kind, dataset, 1.0)])
                .map_err(|e| anyhow!(e))?
        }
    };
    let duration_s: f64 = args.get("duration").unwrap_or("1").parse()?;
    let slo_s = match args.get("slo-ms") {
        Some(ms) => Some(ms.parse::<f64>()? * 1e-3),
        None => None,
    };
    let process = match args.get("arrival").unwrap_or("poisson") {
        "poisson" => ArrivalProcess::Poisson,
        "bursty" => {
            ArrivalProcess::Bursty { burst_factor: 4.0, mean_calm_s: 0.2, mean_burst_s: 0.05 }
        }
        "diurnal" => ArrivalProcess::Diurnal { period_s: duration_s, amplitude: 0.8 },
        other => bail!("unknown arrival process '{other}' (poisson | bursty | diurnal)"),
    };
    let traffic = match args.get("clients") {
        Some(c) => {
            let think_ms: f64 = args.get("think-ms").unwrap_or("1").parse()?;
            TrafficSpec::Closed { clients: c.parse()?, mean_think_s: think_ms * 1e-3 }
        }
        None => TrafficSpec::Open { process, rps: args.get("rps").unwrap_or("1000").parse()? },
    };
    let route = {
        let name = args.get("policy").unwrap_or("jsq");
        RoutePolicy::by_name(name)
            .ok_or_else(|| anyhow!("unknown routing policy '{name}' (rr | jsq | affinity)"))?
    };
    let mut cfg = ServeConfig::new(mix, traffic);
    cfg.accelerators = args.get("accelerators").unwrap_or("1").parse()?;
    cfg.shards = args.get("shards").unwrap_or("1").parse()?;
    cfg.route = route;
    cfg.batch = parse_batch_policy(args.get("batch").unwrap_or("immediate"), slo_s)?;
    cfg.duration_s = duration_s;
    cfg.seed = args.get("seed").unwrap_or("7").parse()?;
    cfg.slo_s = slo_s;
    match args.get("churn") {
        Some(rate) => {
            let mut spec = ChurnSpec::new(rate.parse()?);
            if let Some(b) = args.get("churn-batch") {
                spec.batch = b.parse()?;
            }
            cfg.churn = Some(spec);
        }
        None if args.get("churn-batch").is_some() => {
            bail!("--churn-batch only applies with --churn");
        }
        None => {}
    }

    let report = serve::simulate(BatchEngine::global(), &cfg)?;
    if args.has("json") {
        let mut j = report.to_json();
        if let Json::Obj(o) = &mut j {
            let (rebuilds, patches) = delta_counters();
            o.insert(
                "delta".into(),
                ghost::util::json::obj(vec![
                    ("rebuilds", Json::Num(rebuilds as f64)),
                    ("patches", Json::Num(patches as f64)),
                ]),
            );
        }
        println!("{j}");
        maybe_write_wall_trace(&args)?;
        return Ok(());
    }
    let tenant_list = cfg
        .mix
        .tenants()
        .iter()
        .map(|t| format!("{} (w {:.2})", t.label(), t.weight))
        .collect::<Vec<_>>()
        .join(", ");
    println!(
        "GHOST serving simulation: {} accelerator(s), route {}, batch {}",
        cfg.accelerators,
        cfg.route.name(),
        cfg.batch.label()
    );
    if cfg.shards > 1 {
        println!(
            "  sharding     : {} chips per group, {} schedulable group(s)",
            cfg.shards,
            cfg.shard_groups()
        );
    }
    println!("  tenants      : {tenant_list}");
    match cfg.traffic {
        TrafficSpec::Open { process, rps } => {
            println!("  traffic      : open loop, {} @ {rps:.0} req/s", process.name())
        }
        TrafficSpec::Closed { clients, mean_think_s } => println!(
            "  traffic      : closed loop, {clients} clients, think {:.3} ms",
            mean_think_s * 1e3
        ),
    }
    println!(
        "  offered      : {} requests over {:.3} s (completed {})",
        report.offered, report.duration_s, report.completed
    );
    println!(
        "  throughput   : {:.1} req/s over {:.3} s makespan",
        report.throughput_rps, report.makespan_s
    );
    let l = &report.latency;
    println!(
        "  latency      : p50 {:.3} ms | p95 {:.3} ms | p99 {:.3} ms | p999 {:.3} ms",
        l.p50_s * 1e3,
        l.p95_s * 1e3,
        l.p99_s * 1e3,
        l.p999_s * 1e3
    );
    println!(
        "                 mean {:.3} ms | max {:.3} ms",
        l.mean_s * 1e3,
        l.max_s * 1e3
    );
    let utils = report
        .accels
        .iter()
        .map(|a| format!("{:.2}", a.utilization))
        .collect::<Vec<_>>()
        .join(" ");
    println!(
        "  utilization  : fleet {:.2} (per-accel {utils})",
        report.fleet_utilization()
    );
    println!(
        "  batches      : {} (mean size {:.2}), {} weight programs",
        report.total_batches(),
        if report.total_batches() > 0 {
            report.completed as f64 / report.total_batches() as f64
        } else {
            0.0
        },
        report.total_weight_programs()
    );
    println!(
        "  queue depth  : mean {:.1}, peak {:.0} waiting",
        report.queue_depth.mean(),
        report.queue_depth.max()
    );
    println!("  energy       : {:.3} J photonic inference", report.energy_j);
    if let Some(c) = &report.churn {
        println!(
            "  churn        : {} events (+{} / -{} edges, +{} vertices)",
            c.events, c.edges_added, c.edges_removed, c.vertices_added
        );
        println!(
            "  maintenance  : {} incremental patches, {} rebuilds, {} re-profiles, \
             {} cache evictions",
            c.patches, c.rebuilds, c.reprofiles, c.evictions
        );
    }
    if let (Some(slo), Some(att)) = (cfg.slo_s, report.slo_attainment) {
        println!("  SLO {:.2} ms  : {:.2}% attainment", slo * 1e3, att * 100.0);
    }
    if report.tenants.len() > 1 {
        for t in &report.tenants {
            println!(
                "    {:<20} {:>8} done | p50 {:.3} ms | p99 {:.3} ms",
                t.label,
                t.completed,
                t.latency.p50_s * 1e3,
                t.latency.p99_s * 1e3
            );
        }
    }
    maybe_write_wall_trace(&args)?;
    Ok(())
}

fn cmd_plan_capacity(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv, &["json"])?;
    if args.get("mix").is_some() && (args.get("model").is_some() || args.get("dataset").is_some())
    {
        bail!("--mix conflicts with --model/--dataset: pick one way to name tenants");
    }
    let mix = match args.get("mix") {
        Some(spec) => parse_mix(spec)?,
        None => {
            let model = args.require("model")?;
            let dataset = args.require("dataset")?;
            let kind =
                ModelKind::by_name(model).ok_or_else(|| anyhow!("unknown model {model}"))?;
            TenantMix::new(vec![TenantProfile::new(kind, dataset, 1.0)])
                .map_err(|e| anyhow!(e))?
        }
    };
    let duration_s: f64 = args.get("duration").unwrap_or("0.5").parse()?;
    let slo_ms: f64 = args.require("slo-ms")?.parse()?;
    let slo_s = slo_ms * 1e-3;
    let process = match args.get("arrival").unwrap_or("poisson") {
        "poisson" => ArrivalProcess::Poisson,
        "bursty" => {
            ArrivalProcess::Bursty { burst_factor: 4.0, mean_calm_s: 0.2, mean_burst_s: 0.05 }
        }
        "diurnal" => ArrivalProcess::Diurnal { period_s: duration_s, amplitude: 0.8 },
        other => bail!("unknown arrival process '{other}' (poisson | bursty | diurnal)"),
    };
    let route = {
        let name = args.get("policy").unwrap_or("jsq");
        RoutePolicy::by_name(name)
            .ok_or_else(|| anyhow!("unknown routing policy '{name}' (rr | jsq | affinity)"))?
    };
    let rps_points = args
        .get("rps")
        .unwrap_or("500,1000,2000")
        .split(',')
        .map(|s| {
            s.trim()
                .parse::<f64>()
                .map_err(|_| anyhow!("bad rps point '{s}' (expected a number)"))
        })
        .collect::<Result<Vec<f64>>>()?;
    let mut base = ServeConfig::new(
        mix,
        TrafficSpec::Open { process, rps: rps_points.first().copied().unwrap_or(1.0) },
    );
    base.shards = args.get("shards").unwrap_or("1").parse()?;
    base.route = route;
    base.batch = parse_batch_policy(args.get("batch").unwrap_or("immediate"), Some(slo_s))?;
    base.duration_s = duration_s;
    base.seed = args.get("seed").unwrap_or("7").parse()?;
    base.slo_s = Some(slo_s);
    let workers = match args.get("workers") {
        Some(w) => w.parse()?,
        None => ghost::util::parallel::default_workers(),
    };
    let req = CapacityPlanRequest {
        base,
        rps_points,
        slo_p99_s: slo_s,
        max_accelerators: args.get("max-accelerators").unwrap_or("16").parse()?,
        workers,
    };
    // A fresh (non-global) engine so the curve's plan/profile counter
    // snapshots account for this plan alone.
    let engine = BatchEngine::new();
    let curve = serve::plan_capacity(&engine, &req)?;
    if args.has("json") {
        println!("{}", curve.to_json());
        return Ok(());
    }
    println!(
        "GHOST capacity plan: p99 SLO {:.2} ms, fleet ceiling {} accelerator(s), \
         shard groups of {}",
        slo_ms, curve.max_accelerators, curve.shards
    );
    for p in &curve.points {
        match p.min_accelerators {
            Some(n) => {
                let below = match p.p99_below_s {
                    Some(b) => {
                        format!(", p99 {:.3} ms at {} (violates)", b * 1e3, n - curve.shards)
                    }
                    None => String::new(),
                };
                println!(
                    "  {:>8.0} rps : {:>3} accelerator(s)  p99 {:.3} ms{below}",
                    p.rps,
                    n,
                    p.p99_s * 1e3
                );
            }
            None => println!(
                "  {:>8.0} rps : SLO not met at ceiling (p99 {:.3} ms at {})",
                p.rps,
                p.p99_s * 1e3,
                curve.max_accelerators
            ),
        }
    }
    println!(
        "  probes       : {} over {} round(s), {} worker(s)",
        curve.probes, curve.rounds, req.workers
    );
    println!(
        "  cache builds : plans {} -> {}, profiles {} -> {} (round 1 -> final)",
        curve.plan_builds_round1,
        curve.plan_builds_final,
        curve.profile_builds_round1,
        curve.profile_builds_final
    );
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_infer(_argv: &[String]) -> Result<()> {
    bail!(
        "`ghost infer` needs the PJRT datapath, which this binary was built \
         without: add the `xla` crate (xla-rs, with a local xla_extension \
         install) to rust/Cargo.toml, then rebuild with `--features pjrt`. \
         See the Feature gating section of rust/src/runtime/mod.rs."
    )
}

#[cfg(feature = "pjrt")]
fn cmd_infer(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv, &[])?;
    let artifact = args.require("artifact")?;
    let dir = args.get("dir").unwrap_or("artifacts");
    let reps: usize = args.get("reps").unwrap_or("3").parse()?;
    let engine = Engine::load(dir, artifact)?;
    println!("loaded {artifact} on {}", engine.platform());
    let mut last = None;
    let mut times = Vec::new();
    for _ in 0..reps.max(1) {
        let t0 = std::time::Instant::now();
        let out = engine.run()?;
        times.push(t0.elapsed().as_secs_f64());
        last = Some(out);
    }
    let outputs = last.unwrap();
    let logits = outputs[0].as_f32()?;
    let shape = outputs[0].shape().to_vec();
    println!("output logits shape {shape:?}");
    if let (Ok(labels), 2) = (engine.extra("labels"), shape.len()) {
        let pred = argmax_rows(logits, shape[0], shape[1]);
        let mask = engine.extra("test_mask").ok();
        let acc = masked_accuracy(
            &pred,
            labels.as_i32()?,
            mask.as_ref().and_then(|m| m.as_i32().ok()),
        );
        println!("accuracy: {:.2}%", acc * 100.0);
    }
    let best = times.iter().cloned().fold(f64::INFINITY, f64::min);
    println!("PJRT execute latency: best {:.3} ms over {} reps", best * 1e3, times.len());
    Ok(())
}

/// Table 3 as JSON: the measured accuracy rows from `make artifacts`
/// verbatim, or `null` when the artifact file is absent or unparseable.
fn table3_json() -> Json {
    std::fs::read_to_string("artifacts/accuracy.json")
        .ok()
        .and_then(|s| Json::parse(&s).ok())
        .unwrap_or(Json::Null)
}

/// Table 3: model accuracies at fp32 vs int8, measured by
/// `python/compile/train.py` during `make artifacts`.
fn print_table3() -> Result<()> {
    let path = "artifacts/accuracy.json";
    match std::fs::read_to_string(path) {
        Ok(s) => {
            let rows = Json::parse(&s).map_err(|e| anyhow!("parsing {path}: {e}"))?;
            println!("Table 3: GNN model accuracy (fp32 vs int8), measured");
            println!("{:<10} {:<12} {:>10} {:>10}", "Model", "Dataset", "fp32", "int8");
            if let Some(arr) = rows.as_array() {
                for r in arr {
                    println!(
                        "{:<10} {:<12} {:>9.1}% {:>9.1}%",
                        r.get("model").and_then(Json::as_str).unwrap_or("?"),
                        r.get("dataset").and_then(Json::as_str).unwrap_or("?"),
                        r.get("acc_fp32").and_then(Json::as_f64).unwrap_or(0.0) * 100.0,
                        r.get("acc_int8").and_then(Json::as_f64).unwrap_or(0.0) * 100.0,
                    );
                }
            }
        }
        Err(_) => println!("Table 3: run `make artifacts` first ({path} not found)"),
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parse_accepts_space_and_equals_forms() {
        let a = Args::parse(&argv(&["--model", "gcn", "--dataset=Cora"]), &[]).unwrap();
        assert_eq!(a.get("model"), Some("gcn"));
        assert_eq!(a.get("dataset"), Some("Cora"));
        // Values containing '=' split only on the first one.
        let a = Args::parse(&argv(&["--expr=a=b"]), &[]).unwrap();
        assert_eq!(a.get("expr"), Some("a=b"));
    }

    #[test]
    fn parse_boolean_flags_bare_and_inline() {
        let a = Args::parse(&argv(&["--wb", "--no-pp=false"]), &["wb", "no-pp"]).unwrap();
        assert!(a.has("wb"));
        assert!(!a.has("no-pp"), "--no-pp=false must read as unset");
        let e = Args::parse(&argv(&["--wb=yes"]), &["wb"]).unwrap_err();
        assert!(e.to_string().contains("true"), "{e}");
    }

    #[test]
    fn parse_rejects_duplicates_instead_of_keeping_last() {
        let e = Args::parse(&argv(&["--model", "gcn", "--model", "gat"]), &[]).unwrap_err();
        assert!(e.to_string().contains("duplicate flag --model"), "{e}");
        let e = Args::parse(&argv(&["--model=gcn", "--model", "gat"]), &[]).unwrap_err();
        assert!(e.to_string().contains("duplicate"), "{e}");
        let e = Args::parse(&argv(&["--wb", "--wb"]), &["wb"]).unwrap_err();
        assert!(e.to_string().contains("duplicate"), "{e}");
    }

    #[test]
    fn parse_still_rejects_malformed_input() {
        assert!(Args::parse(&argv(&["stray"]), &[]).is_err());
        assert!(Args::parse(&argv(&["--model"]), &[]).is_err());
        assert!(Args::parse(&argv(&["--=x"]), &[]).is_err());
        assert!(Args::parse(&argv(&["--"]), &[]).is_err());
    }

    #[test]
    fn mix_spec_round_trips() {
        let mix = parse_mix("gcn:Cora:3,gat:Citeseer").unwrap();
        assert_eq!(mix.len(), 2);
        assert_eq!(mix.tenants()[0].weight, 3.0);
        assert_eq!(mix.tenants()[1].weight, 1.0);
        assert!(parse_mix("gcn").is_err());
        assert!(parse_mix("nope:Cora").is_err());
        assert!(parse_mix("gcn:Cora:zero").is_err());
        assert!(parse_mix("gcn:Cora:0").is_err());
    }

    #[test]
    fn sharding_sweep_args_parse() {
        let a = Args::parse(&argv(&["--shards", "1,2, 8", "--shard-model=gat"]), &[]).unwrap();
        let (kind, dataset, counts) = parse_sharding_args(&a).unwrap();
        assert_eq!(kind, ModelKind::Gat);
        assert_eq!(dataset, "rmat-20000v-120000e");
        assert_eq!(counts, vec![1, 2, 8]);

        let a = Args::parse(&argv(&[]), &[]).unwrap();
        let (kind, _, counts) = parse_sharding_args(&a).unwrap();
        assert_eq!(kind, ModelKind::Gcn);
        assert_eq!(counts, vec![1, 2, 4]);

        let a = Args::parse(&argv(&["--shards", "1,x"]), &[]).unwrap();
        assert!(parse_sharding_args(&a).is_err());
    }

    #[test]
    fn batch_policy_specs_parse() {
        assert_eq!(parse_batch_policy("immediate", None).unwrap(), BatchPolicy::Immediate);
        assert_eq!(
            parse_batch_policy("max:8:0.5", None).unwrap(),
            BatchPolicy::MaxBatchOrWait { max_batch: 8, max_wait_s: 0.5e-3 }
        );
        assert_eq!(
            parse_batch_policy("slo:4", Some(2e-3)).unwrap(),
            BatchPolicy::SloAware { slo_s: 2e-3, max_batch: 4 }
        );
        assert_eq!(
            parse_batch_policy("slo", Some(2e-3)).unwrap(),
            BatchPolicy::SloAware { slo_s: 2e-3, max_batch: 16 }
        );
        assert!(parse_batch_policy("slo", None).is_err());
        assert!(parse_batch_policy("max:8", None).is_err());
        assert!(parse_batch_policy("nope", None).is_err());
    }
}
