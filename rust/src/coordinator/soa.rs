//! Structure-of-arrays lowering of the plan IR, cost provenance, and
//! delta re-costing.
//!
//! [`super::plan::build`] lowers every plan into a [`PlanSoA`] at build
//! time: flat `latency` / `energy` lanes (segment-major, group-major,
//! position-minor — mirroring the flat-CSR `PartitionMatrix` layout), the
//! per-group block sums and per-segment schedule results the evaluator
//! consumes, and a walk directory (`SoaEntry`) in schedule order.
//! Evaluation ([`super::plan::evaluate`]) is then an `O(groups)` replay of
//! cached quantities instead of an `O(slots)` re-derivation — and, because
//! the cached values are exactly the per-group / per-segment partials the
//! reference item walk accumulates, the replay is bit-identical to it.
//!
//! [`ParamSet`] records *cost provenance*: which [`GhostConfig`] parameters
//! each [`StageKind`]'s cost depends on. [`DeltaPlan`] exploits it for the
//! DSE sweep: between neighboring grid points it re-costs only the lanes
//! whose provenance intersects the changed parameters (patching the
//! derived sums and re-running the recurrence for affected segments)
//! instead of rebuilding the plan — a full rebuild happens only when a
//! structural parameter (`n`, `v`, or the chip memory budget) changes.
//!
//! [`GraphDeltaPlan`] is the *graph-churn* counterpart: the configuration
//! is fixed but the graph mutates under it
//! ([`crate::graph::mutate::apply_to_dataset`]). A patch re-costs only the
//! lane positions of the output groups a mutation's [`AppliedDelta`] names
//! (plus the mutated graph's edge-stream and readout serial stages),
//! falling back to a rebuild when the mutation reshapes the plan itself
//! (group-count change, a DRAM-spill flip, or a sharded plan).

use std::sync::Arc;
use std::sync::OnceLock;

use crate::arch::{ecu, ArchContext, StageCost};
use crate::config::GhostConfig;
use crate::gnn::models::{Model, ModelKind};
use crate::gnn::workload::Workload;
use crate::graph::datasets::{Dataset, DatasetSpec};
use crate::graph::mutate::AppliedDelta;
use crate::graph::partition::{OutputGroupPlan, PartitionMatrix, ShardPlan};
use crate::sim::{self, QuadSched};
use crate::util::telemetry::{self, Counter};

use super::error::SimError;
use super::optimizations::OptFlags;
use super::plan::{self, Block, ChipPlan, PlanItem, StageKind, PIPELINE_STAGES};
use super::schedule::SimReport;

/// Process-wide full-rebuild count across every delta-plan instance
/// ([`DeltaPlan`] and [`GraphDeltaPlan`]) — a registry counter
/// (`delta.rebuilds`), surfaced by [`delta_counters`] for the `--json`
/// outputs of `ghost run` / `ghost serve` / `ghost dse`.
fn global_rebuilds() -> &'static Arc<Counter> {
    static C: OnceLock<Arc<Counter>> = OnceLock::new();
    C.get_or_init(|| telemetry::registry().counter("delta.rebuilds"))
}

/// Process-wide incremental-patch count (`delta.patches` in the registry),
/// same scope as [`global_rebuilds`].
fn global_patches() -> &'static Arc<Counter> {
    static C: OnceLock<Arc<Counter>> = OnceLock::new();
    C.get_or_init(|| telemetry::registry().counter("delta.patches"))
}

/// `(rebuilds, patches)` performed by every delta plan in this process so
/// far — both the DSE sweep's [`DeltaPlan`] retargets and the churn
/// engine's [`GraphDeltaPlan`] graph retargets. Monotone counters; readers
/// diff two snapshots to attribute work to a phase. Thin wrapper over the
/// `delta.rebuilds` / `delta.patches` registry counters.
pub fn delta_counters() -> (usize, usize) {
    (global_rebuilds().get(), global_patches().get())
}

/// A set of [`GhostConfig`] parameters, as a bitmask — the provenance
/// vocabulary of the delta evaluator.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub struct ParamSet(u8);

impl ParamSet {
    /// The empty set: a cost that no config parameter influences.
    pub const NONE: ParamSet = ParamSet(0);
    /// Edge-control-unit count `N`.
    pub const N: ParamSet = ParamSet(1);
    /// Gather/reduce lane count `V`.
    pub const V: ParamSet = ParamSet(1 << 1);
    /// Reduce-array rows (wavelengths) `R_r`.
    pub const R_R: ParamSet = ParamSet(1 << 2);
    /// Reduce-array columns (coherent MRs) `R_c`.
    pub const R_C: ParamSet = ParamSet(1 << 3);
    /// Transform-array rows `T_r`.
    pub const T_R: ParamSet = ParamSet(1 << 4);
    /// Per-chip memory budget (not an arch lattice axis, but it gates the
    /// footprint check, so a change forces a rebuild).
    pub const MEM: ParamSet = ParamSet(1 << 5);
    /// Parameters whose change invalidates the plan *structure* — the
    /// partitioning (and with it every group shape) is keyed on `(v, n)`,
    /// and the memory budget gates whether the plan exists at all. A delta
    /// across any of these rebuilds instead of patching.
    pub const STRUCTURAL: ParamSet = ParamSet(Self::N.0 | Self::V.0 | Self::MEM.0);

    /// Set union.
    pub const fn union(self, other: ParamSet) -> ParamSet {
        ParamSet(self.0 | other.0)
    }

    /// Whether the two sets share any parameter.
    pub const fn intersects(self, other: ParamSet) -> bool {
        self.0 & other.0 != 0
    }

    /// Whether no parameter is in the set.
    pub const fn is_empty(self) -> bool {
        self.0 == 0
    }

    /// The set of parameters on which two configurations differ.
    pub fn diff(a: &GhostConfig, b: &GhostConfig) -> ParamSet {
        let mut d = ParamSet::NONE;
        if a.n != b.n {
            d = d.union(ParamSet::N);
        }
        if a.v != b.v {
            d = d.union(ParamSet::V);
        }
        if a.r_r != b.r_r {
            d = d.union(ParamSet::R_R);
        }
        if a.r_c != b.r_c {
            d = d.union(ParamSet::R_C);
        }
        if a.t_r != b.t_r {
            d = d.union(ParamSet::T_R);
        }
        if a.chip_mem_bytes != b.chip_mem_bytes {
            d = d.union(ParamSet::MEM);
        }
        d
    }
}

/// One walk entry of the lowered plan, in schedule order.
#[derive(Debug, Clone, Copy)]
pub(crate) enum SoaEntry {
    /// A serial stage; the cost is stored inline (and patched in place by
    /// [`DeltaPlan`]).
    Serial { kind: StageKind, cost: StageCost },
    /// A pipelined segment, by index into [`PlanSoA::segs`].
    Segment { seg: usize },
}

/// Per-segment directory entry: where the segment's slots and groups live
/// in the flat lanes, plus the tags delta re-costing needs.
#[derive(Debug, Clone, Copy)]
pub(crate) struct SegMeta {
    /// Graph index within the dataset.
    pub graph: u32,
    /// Layer index within the model.
    pub layer: u32,
    /// Owning chip (0 for single-chip plans).
    pub chip: u32,
    /// Stage kind at each pipeline position.
    pub kinds: [StageKind; PIPELINE_STAGES],
    /// First slot of this segment in the `latency` / `energy` lanes.
    pub slot_start: usize,
    /// First entry of this segment in the per-group derived lanes.
    pub group_start: usize,
    /// Group count (slots span `n_groups * PIPELINE_STAGES`).
    pub n_groups: usize,
}

/// The structure-of-arrays mirror of a plan, cached at build time.
#[derive(Debug, Clone)]
pub struct PlanSoA {
    /// Walk entries in schedule order, flattened `(chip, phase)`-major for
    /// sharded plans.
    pub(crate) entries: Vec<SoaEntry>,
    /// Entry-index boundaries of each `(chip, phase)` span, row-major:
    /// chip `c`'s phase `p` covers
    /// `entries[phase_ptr[c * n_phases + p]..phase_ptr[c * n_phases + p + 1]]`.
    pub(crate) phase_ptr: Vec<usize>,
    pub(crate) n_chips: usize,
    pub(crate) n_phases: usize,
    /// Flat per-slot latency lane, segment-major then group-major then
    /// position-minor (`PIPELINE_STAGES` slots per group).
    pub(crate) latency: Vec<f64>,
    /// Flat per-slot dynamic-energy lane, same layout.
    pub(crate) energy: Vec<f64>,
    /// Derived per-group sums, indexed by a global group index
    /// (`SegMeta::group_start`): total dynamic energy of the group's four
    /// slots, and its latency attributed to each Fig. 9 block — the exact
    /// partials the reference evaluator accumulates per group.
    pub(crate) group_energy: Vec<f64>,
    pub(crate) group_agg: Vec<f64>,
    pub(crate) group_comb: Vec<f64>,
    pub(crate) group_upd: Vec<f64>,
    /// Derived per-segment schedule results for the plan's pipelining
    /// flag (the recurrence only re-runs when a lane of the segment
    /// changes).
    pub(crate) scheds: Vec<QuadSched>,
    /// Segment directory, in schedule order.
    pub(crate) segs: Vec<SegMeta>,
}

impl PlanSoA {
    fn empty() -> PlanSoA {
        PlanSoA {
            entries: Vec::new(),
            phase_ptr: vec![0],
            n_chips: 0,
            n_phases: 0,
            latency: Vec::new(),
            energy: Vec::new(),
            group_energy: Vec::new(),
            group_agg: Vec::new(),
            group_comb: Vec::new(),
            group_upd: Vec::new(),
            scheds: Vec::new(),
            segs: Vec::new(),
        }
    }

    /// Lowers a single-chip item list: one chip, one phase.
    pub(crate) fn lower_single(items: &[PlanItem], pipelining: bool) -> PlanSoA {
        let mut soa = PlanSoA::empty();
        soa.n_chips = 1;
        soa.n_phases = 1;
        soa.push_items(items, 0, pipelining);
        soa.phase_ptr.push(soa.entries.len());
        soa
    }

    /// Lowers a sharded plan's per-chip phased item lists. Every chip must
    /// carry the same phase count (guaranteed by `build_sharded`).
    pub(crate) fn lower_sharded(chips: &[ChipPlan], pipelining: bool) -> PlanSoA {
        let mut soa = PlanSoA::empty();
        soa.n_chips = chips.len();
        soa.n_phases = chips.first().map(|c| c.phases.len()).unwrap_or(0);
        for (ci, chip) in chips.iter().enumerate() {
            debug_assert_eq!(chip.phases.len(), soa.n_phases);
            for phase in &chip.phases {
                soa.push_items(phase, ci as u32, pipelining);
                soa.phase_ptr.push(soa.entries.len());
            }
        }
        soa
    }

    fn push_items(&mut self, items: &[PlanItem], chip: u32, pipelining: bool) {
        for item in items {
            match item {
                PlanItem::Serial { kind, cost } => {
                    self.entries.push(SoaEntry::Serial { kind: *kind, cost: *cost });
                }
                PlanItem::Pipeline(seg) => {
                    let slot_start = self.latency.len();
                    let group_start = self.group_energy.len();
                    let n_groups = seg.n_groups();
                    for c in &seg.costs {
                        self.latency.push(c.latency_s);
                        self.energy.push(c.energy_j);
                    }
                    let new_groups = group_start + n_groups;
                    self.group_energy.resize(new_groups, 0.0);
                    self.group_agg.resize(new_groups, 0.0);
                    self.group_comb.resize(new_groups, 0.0);
                    self.group_upd.resize(new_groups, 0.0);
                    let idx = self.segs.len();
                    self.segs.push(SegMeta {
                        graph: seg.graph,
                        layer: seg.layer,
                        chip,
                        kinds: seg.kinds,
                        slot_start,
                        group_start,
                        n_groups,
                    });
                    self.scheds.push(QuadSched::default());
                    self.entries.push(SoaEntry::Segment { seg: idx });
                    self.rederive_segment(idx, pipelining);
                }
            }
        }
    }

    /// The entry range of one `(chip, phase)` span.
    pub(crate) fn phase_span(&self, chip: usize, phase: usize) -> std::ops::Range<usize> {
        let i = chip * self.n_phases + phase;
        self.phase_ptr[i]..self.phase_ptr[i + 1]
    }

    /// Recomputes one segment's derived state from its lanes: the
    /// per-group block sums (in the reference evaluator's exact
    /// accumulation order) and the pipelined / sequential recurrence.
    pub(crate) fn rederive_segment(&mut self, idx: usize, pipelining: bool) {
        let seg = self.segs[idx];
        for g in 0..seg.n_groups {
            let base = seg.slot_start + g * PIPELINE_STAGES;
            let mut group_energy = 0.0f64;
            let mut agg = 0.0f64;
            let mut comb = 0.0f64;
            let mut upd = 0.0f64;
            for s in 0..PIPELINE_STAGES {
                group_energy += self.energy[base + s];
                match seg.kinds[s].block() {
                    Some(Block::Aggregate) => agg += self.latency[base + s],
                    Some(Block::Combine) => comb += self.latency[base + s],
                    Some(Block::Update) => upd += self.latency[base + s],
                    None => {}
                }
            }
            self.group_energy[seg.group_start + g] = group_energy;
            self.group_agg[seg.group_start + g] = agg;
            self.group_comb[seg.group_start + g] = comb;
            self.group_upd[seg.group_start + g] = upd;
        }
        let slots = seg.slot_start..seg.slot_start + seg.n_groups * PIPELINE_STAGES;
        self.scheds[idx] = if pipelining {
            sim::pipelined_quads(&self.latency[slots.clone()], &self.energy[slots])
        } else {
            sim::sequential_quads(&self.latency[slots.clone()], &self.energy[slots])
        };
    }
}

/// The non-lane half of an evaluated plan — everything
/// [`super::plan::evaluate`] needs besides the [`PlanSoA`] itself.
/// [`DeltaPlan`] keeps one alongside its lanes so patched plans evaluate
/// without materializing a `StagePlan`.
#[derive(Debug, Clone)]
pub(crate) struct EvalHeader {
    pub model: ModelKind,
    pub dataset: String,
    pub cfg: GhostConfig,
    pub flags: OptFlags,
    pub shards: usize,
    pub spilled_layer_gathers: usize,
    pub platform_w: f64,
    pub ops: u64,
    pub bits: u64,
}

/// Current lowered state of a [`DeltaPlan`].
#[derive(Debug)]
struct DeltaState {
    header: EvalHeader,
    soa: PlanSoA,
    /// `Some` iff `shards > 1`.
    shard_plan: Option<ShardPlan>,
    /// Effective (neighbor-sample-capped) group plans, aligned with the
    /// global group index of `soa` — the per-group inputs a lane recompute
    /// needs. Capping depends only on `(v, layer)`, both fixed within one
    /// lowered state.
    eff_groups: Vec<OutputGroupPlan>,
}

/// Incrementally re-costed plan for sweeps that visit many configurations
/// of one `(model, dataset, flags, shards)` workload.
///
/// [`DeltaPlan::retarget`] moves the plan to a new configuration: if only
/// non-structural parameters (`r_r`, `r_c`, `t_r`) changed, it re-costs
/// exactly the lanes whose [`StageKind::provenance`] intersects the
/// changed set — through the same cost helpers construction uses, so the
/// patched lanes are bit-identical to a fresh build's — and re-derives the
/// affected segments' sums and recurrences. Structural changes (`n`, `v`,
/// memory budget) rebuild from scratch.
#[derive(Debug)]
pub struct DeltaPlan<'a> {
    kind: ModelKind,
    flags: OptFlags,
    shards: usize,
    dataset: &'a Dataset,
    model: Model,
    partitions: Option<Arc<Vec<PartitionMatrix>>>,
    state: Option<DeltaState>,
    rebuilds: usize,
    patches: usize,
}

impl<'a> DeltaPlan<'a> {
    /// Creates an untargeted delta plan; call [`Self::retarget`] before
    /// [`Self::evaluate`]. `shards == 1` builds single-chip plans (the
    /// same path as `plan::build`), larger counts build sharded plans.
    pub fn new(
        kind: ModelKind,
        dataset: &'a Dataset,
        flags: OptFlags,
        shards: usize,
    ) -> DeltaPlan<'a> {
        DeltaPlan {
            kind,
            flags,
            shards,
            dataset,
            model: Model::for_dataset(kind, &dataset.spec),
            partitions: None,
            state: None,
            rebuilds: 0,
            patches: 0,
        }
    }

    /// Full rebuilds performed so far (first target included).
    pub fn rebuilds(&self) -> usize {
        self.rebuilds
    }

    /// Incremental lane patches performed so far.
    pub fn patches(&self) -> usize {
        self.patches
    }

    /// Moves the plan to `cfg`. `partitions` must be the `(cfg.v, cfg.n)`
    /// partition set of the dataset (the engine's cache hands these out);
    /// it is only consulted when a structural change forces a rebuild.
    pub fn retarget(
        &mut self,
        cfg: GhostConfig,
        partitions: &Arc<Vec<PartitionMatrix>>,
    ) -> Result<(), SimError> {
        let rebuild = match &self.state {
            None => true,
            Some(st) => {
                let diff = ParamSet::diff(&st.header.cfg, &cfg);
                if diff.is_empty() {
                    return Ok(());
                }
                diff.intersects(ParamSet::STRUCTURAL)
            }
        };
        if rebuild {
            self.rebuild(cfg, partitions)
        } else {
            self.patch(cfg);
            Ok(())
        }
    }

    /// Evaluates the current target. Bit-identical to building a fresh
    /// plan at the same configuration and evaluating it (pinned by the
    /// schedule property tests and the DSE debug-check mode).
    pub fn evaluate(&self) -> Result<SimReport, SimError> {
        let st = self.state.as_ref().ok_or_else(|| {
            SimError::InvalidConfig("DeltaPlan::evaluate before retarget".into())
        })?;
        Ok(plan::evaluate_soa(&st.soa, &st.header))
    }

    fn rebuild(
        &mut self,
        cfg: GhostConfig,
        partitions: &Arc<Vec<PartitionMatrix>>,
    ) -> Result<(), SimError> {
        let _span = telemetry::span("delta.rebuild");
        self.state = None;
        self.rebuilds += 1;
        global_rebuilds().inc();
        let (header, soa, shard_plan) = if self.shards == 1 {
            let p = plan::build(self.kind, self.dataset, partitions, cfg, self.flags)?;
            let header = EvalHeader {
                model: p.model,
                dataset: p.dataset,
                cfg: p.cfg,
                flags: p.flags,
                shards: 1,
                spilled_layer_gathers: p.spilled_layer_gathers,
                platform_w: p.platform_w,
                ops: p.ops,
                bits: p.bits,
            };
            (header, p.soa, None)
        } else {
            let p = plan::build_sharded(
                self.kind,
                self.dataset,
                partitions,
                cfg,
                self.flags,
                self.shards,
            )?;
            let header = EvalHeader {
                model: p.model,
                dataset: p.dataset,
                cfg: p.cfg,
                flags: p.flags,
                shards: p.shards,
                spilled_layer_gathers: p.spilled_layer_gathers,
                platform_w: p.platform_w,
                ops: p.ops,
                bits: p.bits,
            };
            (header, p.soa, Some(p.shard_plan))
        };
        let mut eff_groups = Vec::with_capacity(soa.group_energy.len());
        for seg in &soa.segs {
            let layer = &self.model.layers[seg.layer as usize];
            let pm = &partitions[seg.graph as usize];
            let groups: &[OutputGroupPlan] = match &shard_plan {
                None => &pm.groups,
                Some(sp) => &pm.groups[sp.group_range(seg.graph as usize, seg.chip as usize)],
            };
            debug_assert_eq!(groups.len(), seg.n_groups);
            for grp in groups {
                eff_groups.push(plan::effective_group(grp, layer.neighbor_sample, cfg.v));
            }
        }
        self.partitions = Some(Arc::clone(partitions));
        self.state = Some(DeltaState { header, soa, shard_plan, eff_groups });
        Ok(())
    }

    /// Re-costs only what the parameter delta touches; `diff` is known to
    /// be non-structural here (no `n` / `v` / memory change), so group
    /// shapes, spill decisions, phase structure, and workload totals are
    /// all unchanged.
    fn patch(&mut self, cfg: GhostConfig) {
        let _span = telemetry::span("delta.patch");
        self.patches += 1;
        global_patches().inc();
        let st = self.state.as_mut().expect("patch requires a lowered state");
        let diff = ParamSet::diff(&st.header.cfg, &cfg);
        let ctx = ArchContext::paper(cfg);
        let soa = &mut st.soa;

        // Serial stages. Weight staging and readout are the only serial
        // kinds with non-empty non-structural provenance; both recompute
        // from walk-order counters (weight stages appear in layer order
        // and readouts in graph order within each chip, by construction).
        let patch_ws = StageKind::WeightStage.provenance().intersects(diff);
        let patch_ro = StageKind::Readout.provenance().intersects(diff);
        if patch_ws || patch_ro {
            let ro_width =
                self.model.layers.last().map(|l| l.out_dim * l.heads).unwrap_or(0);
            let partitions =
                self.partitions.as_ref().expect("patch requires partitions");
            for c in 0..soa.n_chips {
                let chip_entries =
                    soa.phase_ptr[c * soa.n_phases]..soa.phase_ptr[(c + 1) * soa.n_phases];
                let mut li = 0usize;
                let mut ro_gi = 0usize;
                for e in &mut soa.entries[chip_entries] {
                    match e {
                        SoaEntry::Serial { kind: StageKind::WeightStage, cost } => {
                            if patch_ws {
                                *cost =
                                    plan::weight_stage_item(&ctx, &self.model.layers[li]);
                            }
                            li += 1;
                        }
                        SoaEntry::Serial { kind: StageKind::Readout, cost } => {
                            if patch_ro {
                                let pm = &partitions[ro_gi];
                                let n_vertices = match &st.shard_plan {
                                    None => pm.n_vertices,
                                    Some(sp) => pm
                                        .group_range_vertices(sp.group_range(ro_gi, c)),
                                };
                                *cost = plan::readout_item(&ctx, n_vertices, ro_width);
                            }
                            ro_gi += 1;
                        }
                        _ => {}
                    }
                }
            }
        }

        // Pipelined segments: re-cost each position whose provenance
        // intersects the delta, then re-derive the segment's sums and
        // recurrence once. Group-invariant positions cost one helper call
        // broadcast across the lane.
        for idx in 0..soa.segs.len() {
            let seg = soa.segs[idx];
            if seg.n_groups == 0 {
                continue;
            }
            let layer = &self.model.layers[seg.layer as usize];
            let from_dram = match seg.kinds[0] {
                StageKind::Gather { from_dram } => from_dram,
                _ => false,
            };
            let mut changed = false;
            for s in 0..PIPELINE_STAGES {
                if !seg.kinds[s].provenance().intersects(diff) {
                    continue;
                }
                changed = true;
                if plan::position_group_invariant(&self.model, layer, s) {
                    let c = plan::position_cost(
                        &ctx,
                        &self.model,
                        layer,
                        &st.eff_groups[seg.group_start],
                        self.flags,
                        from_dram,
                        s,
                    );
                    for g in 0..seg.n_groups {
                        let slot = seg.slot_start + g * PIPELINE_STAGES + s;
                        soa.latency[slot] = c.latency_s;
                        soa.energy[slot] = c.energy_j;
                    }
                } else {
                    for g in 0..seg.n_groups {
                        let c = plan::position_cost(
                            &ctx,
                            &self.model,
                            layer,
                            &st.eff_groups[seg.group_start + g],
                            self.flags,
                            from_dram,
                            s,
                        );
                        let slot = seg.slot_start + g * PIPELINE_STAGES + s;
                        soa.latency[slot] = c.latency_s;
                        soa.energy[slot] = c.energy_j;
                    }
                }
            }
            if changed {
                soa.rederive_segment(idx, self.flags.pipelining);
            }
        }

        st.header.cfg = cfg;
        st.header.platform_w = crate::arch::platform_power_w(&ctx, self.flags.dac_sharing);
    }
}

/// Incrementally maintained plan for a *mutating graph* under a fixed
/// `(model, dataset, config, flags, shards)` workload — the plan-level
/// half of the churn engine (level 1 and 2 are CSR / partition splicing in
/// [`crate::graph::mutate`]).
///
/// [`GraphDeltaPlan::retarget_graph`] moves the plan to the dataset's
/// current mutation epoch. Handed the [`AppliedDelta`]s since the last
/// target, it patches in place: the mutated graph's edge-stream and
/// readout serial stages are re-costed from the new edge/vertex counts,
/// and within each of that graph's segments only the `changed_groups`
/// lanes are re-costed (and of those only the positions that read the
/// group shape — [`super::plan::position_group_invariant`] positions
/// cannot have moved, since layer dims and config are fixed). Patched
/// state is bit-identical to a cold [`super::plan::build`] on the mutated
/// dataset — the same cost helpers run over the same inputs — which the
/// churn oracle (`GHOST_CHURN_CHECK`, always-on in debug) asserts against
/// a fresh build after every patch.
///
/// A full rebuild happens when patching cannot be sound: no prior state,
/// no delta provided, a sharded plan (group→chip ranges move with group
/// shapes), a group-count change (lane layout reshapes), or a DRAM-spill
/// flip (vertex growth pushed a layer's feature map past the input-vertex
/// buffer, changing segment kinds and spill accounting).
#[derive(Debug)]
pub struct GraphDeltaPlan {
    kind: ModelKind,
    cfg: GhostConfig,
    flags: OptFlags,
    shards: usize,
    model: Model,
    state: Option<DeltaState>,
    rebuilds: usize,
    patches: usize,
}

impl GraphDeltaPlan {
    /// Creates an untargeted plan; call [`Self::retarget_graph`] before
    /// [`Self::evaluate`]. The model shape depends only on the dataset
    /// *spec*, which mutation never changes, so it is built once here.
    pub fn new(
        kind: ModelKind,
        spec: &DatasetSpec,
        cfg: GhostConfig,
        flags: OptFlags,
        shards: usize,
    ) -> GraphDeltaPlan {
        GraphDeltaPlan {
            kind,
            cfg,
            flags,
            shards,
            model: Model::for_dataset(kind, spec),
            state: None,
            rebuilds: 0,
            patches: 0,
        }
    }

    /// Full rebuilds performed so far (first target included).
    pub fn rebuilds(&self) -> usize {
        self.rebuilds
    }

    /// Incremental graph patches performed so far.
    pub fn patches(&self) -> usize {
        self.patches
    }

    /// Moves the plan to the dataset's current state. `partitions` must be
    /// the dataset's current `(cfg.v, cfg.n)` partition set (kept current
    /// by [`crate::graph::mutate::apply_to_dataset`]); `applied` is the
    /// mutation trail since the previous target — `None` (or an empty
    /// prior state) forces a rebuild.
    pub fn retarget_graph(
        &mut self,
        dataset: &Dataset,
        partitions: &[PartitionMatrix],
        applied: Option<&[AppliedDelta]>,
    ) -> Result<(), SimError> {
        let rebuild = match (&self.state, applied) {
            (None, _) | (_, None) => true,
            (Some(_), Some(ads)) => {
                self.shards != 1
                    || ads.iter().any(|ad| ad.new_n_groups != ad.old_n_groups)
                    || self.spill_flipped(ads)
            }
        };
        if rebuild {
            self.rebuild_graph(dataset, partitions)
        } else {
            self.patch_graph(dataset, partitions, applied.unwrap_or(&[]))
        }
    }

    /// Evaluates the current target. Bit-identical to building a fresh
    /// plan over the mutated dataset and evaluating it.
    pub fn evaluate(&self) -> Result<SimReport, SimError> {
        let st = self.state.as_ref().ok_or_else(|| {
            SimError::InvalidConfig("GraphDeltaPlan::evaluate before retarget_graph".into())
        })?;
        Ok(plan::evaluate_soa(&st.soa, &st.header))
    }

    /// Whether vertex growth flipped any post-layer-0 reduction layer's
    /// input feature map across the input-vertex-buffer boundary — the
    /// `from_dram` spill test of plan construction. A flip changes segment
    /// kinds (and the spill counter), so the plan must rebuild.
    fn spill_flipped(&self, ads: &[AppliedDelta]) -> bool {
        let buf = ArchContext::paper(self.cfg).buffers.input_vertices.size_bytes;
        ads.iter().any(|ad| {
            ad.old_n_vertices != ad.new_n_vertices
                && self.model.layers.iter().skip(1).any(|l| {
                    l.reduction.is_some()
                        && (ad.old_n_vertices * l.in_dim > buf)
                            != (ad.new_n_vertices * l.in_dim > buf)
                })
        })
    }

    fn rebuild_graph(
        &mut self,
        dataset: &Dataset,
        partitions: &[PartitionMatrix],
    ) -> Result<(), SimError> {
        let _span = telemetry::span("delta.rebuild_graph");
        self.state = None;
        self.rebuilds += 1;
        global_rebuilds().inc();
        let (header, soa, shard_plan) = if self.shards == 1 {
            let p = plan::build(self.kind, dataset, partitions, self.cfg, self.flags)?;
            let header = EvalHeader {
                model: p.model,
                dataset: p.dataset,
                cfg: p.cfg,
                flags: p.flags,
                shards: 1,
                spilled_layer_gathers: p.spilled_layer_gathers,
                platform_w: p.platform_w,
                ops: p.ops,
                bits: p.bits,
            };
            (header, p.soa, None)
        } else {
            let p = plan::build_sharded(
                self.kind,
                dataset,
                partitions,
                self.cfg,
                self.flags,
                self.shards,
            )?;
            let header = EvalHeader {
                model: p.model,
                dataset: p.dataset,
                cfg: p.cfg,
                flags: p.flags,
                shards: p.shards,
                spilled_layer_gathers: p.spilled_layer_gathers,
                platform_w: p.platform_w,
                ops: p.ops,
                bits: p.bits,
            };
            (header, p.soa, Some(p.shard_plan))
        };
        let mut eff_groups = Vec::with_capacity(soa.group_energy.len());
        for seg in &soa.segs {
            let layer = &self.model.layers[seg.layer as usize];
            let pm = &partitions[seg.graph as usize];
            let groups: &[OutputGroupPlan] = match &shard_plan {
                None => &pm.groups,
                Some(sp) => &pm.groups[sp.group_range(seg.graph as usize, seg.chip as usize)],
            };
            debug_assert_eq!(groups.len(), seg.n_groups);
            for grp in groups {
                eff_groups.push(plan::effective_group(grp, layer.neighbor_sample, self.cfg.v));
            }
        }
        self.state = Some(DeltaState { header, soa, shard_plan, eff_groups });
        Ok(())
    }

    /// Patches the lowered state through one or more applied mutations.
    /// Only reached for single-chip plans with unchanged group counts and
    /// no spill flip, so lane layout, segment kinds, phase structure, and
    /// the shard plan are all still valid.
    fn patch_graph(
        &mut self,
        dataset: &Dataset,
        partitions: &[PartitionMatrix],
        applied: &[AppliedDelta],
    ) -> Result<(), SimError> {
        let _span = telemetry::span("delta.patch_graph");
        // Vertex growth can push the resident footprint past the chip
        // budget — the same gate a cold build would apply.
        plan::check_chip_memory(&self.model, partitions, self.cfg)?;
        self.patches += 1;
        global_patches().inc();
        let ctx = ArchContext::paper(self.cfg);
        let st = self.state.as_mut().expect("patch requires a lowered state");
        let DeltaState { header, soa, shard_plan: _, eff_groups } = st;
        let ro_width = self.model.layers.last().map(|l| l.out_dim * l.heads).unwrap_or(0);
        for ad in applied {
            debug_assert!(ad.graph < partitions.len(), "applied delta names a live graph");
            let pm = &partitions[ad.graph];
            // The mutated graph's serial stages: its edge stream scales
            // with the new edge count, its readout with the new vertex
            // count. Both appear once per graph in graph order within the
            // single chip's walk, by construction.
            let mut es_gi = 0usize;
            let mut ro_gi = 0usize;
            for e in soa.entries.iter_mut() {
                match e {
                    SoaEntry::Serial { kind: StageKind::EdgeStream, cost } => {
                        if es_gi == ad.graph {
                            *cost = ecu::edge_stage_cost(&ctx, ad.new_n_edges as u64 * 8);
                        }
                        es_gi += 1;
                    }
                    SoaEntry::Serial { kind: StageKind::Readout, cost } => {
                        if ro_gi == ad.graph {
                            *cost = plan::readout_item(&ctx, ad.new_n_vertices, ro_width);
                        }
                        ro_gi += 1;
                    }
                    _ => {}
                }
            }
            // The mutated graph's segments: refresh the effective group
            // plan of every changed group and re-cost the positions that
            // read the group shape. Shape-free positions depend only on
            // layer dims and config — both fixed — so their lanes are
            // already bit-identical to a cold build's.
            for idx in 0..soa.segs.len() {
                let seg = soa.segs[idx];
                if seg.graph as usize != ad.graph || seg.n_groups == 0 {
                    continue;
                }
                let layer = &self.model.layers[seg.layer as usize];
                let from_dram = match seg.kinds[0] {
                    StageKind::Gather { from_dram } => from_dram,
                    _ => false,
                };
                let mut changed = false;
                for &cg in &ad.changed_groups {
                    let g = cg as usize;
                    debug_assert!(g < seg.n_groups, "changed group within segment");
                    changed = true;
                    eff_groups[seg.group_start + g] =
                        plan::effective_group(&pm.groups[g], layer.neighbor_sample, self.cfg.v);
                    for s in 0..PIPELINE_STAGES {
                        if plan::position_group_invariant(&self.model, layer, s) {
                            continue;
                        }
                        let c = plan::position_cost(
                            &ctx,
                            &self.model,
                            layer,
                            &eff_groups[seg.group_start + g],
                            self.flags,
                            from_dram,
                            s,
                        );
                        let slot = seg.slot_start + g * PIPELINE_STAGES + s;
                        soa.latency[slot] = c.latency_s;
                        soa.energy[slot] = c.energy_j;
                    }
                }
                if changed {
                    soa.rederive_segment(idx, self.flags.pipelining);
                }
            }
        }
        // Workload totals follow the mutated edge/vertex counts.
        let workload = Workload::characterize(&self.model, dataset);
        header.ops = workload.total_ops();
        header.bits = workload.total_bits();
        if crate::graph::mutate::churn_check_enabled() {
            let fresh = plan::build(self.kind, dataset, partitions, self.cfg, self.flags)?;
            let got = plan::evaluate_soa(soa, header);
            let want = plan::reference_evaluate(&fresh)?;
            assert_eq!(
                got, want,
                "graph-delta patch diverged from a cold rebuild on the mutated dataset"
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_set_diff_names_exactly_the_changed_axes() {
        let a = GhostConfig::paper_optimal();
        assert!(ParamSet::diff(&a, &a).is_empty());
        let b = GhostConfig { t_r: a.t_r + 1, ..a };
        let d = ParamSet::diff(&a, &b);
        assert!(d.intersects(ParamSet::T_R));
        assert!(!d.intersects(ParamSet::STRUCTURAL));
        let c = GhostConfig { v: a.v + 1, chip_mem_bytes: a.chip_mem_bytes * 2, ..a };
        let d = ParamSet::diff(&a, &c);
        assert!(d.intersects(ParamSet::V) && d.intersects(ParamSet::MEM));
        assert!(d.intersects(ParamSet::STRUCTURAL));
    }

    /// The provenance-targeted patch pin: after one `t_r`-only retarget,
    /// every lane, derived sum, cached recurrence, and serial cost of the
    /// patched [`PlanSoA`] is bit-identical to a from-scratch build at the
    /// new configuration — not just the evaluated report.
    #[test]
    fn one_lane_patch_matches_a_full_rebuild() {
        let base = GhostConfig::paper_optimal();
        let stepped = GhostConfig { t_r: 12, ..base };
        let flags = OptFlags::ghost_default();
        let ds = Dataset::by_name("Cora").unwrap();
        let pms = Arc::new(PartitionMatrix::build_all(&ds.graphs, base.v, base.n));

        let mut dp = DeltaPlan::new(ModelKind::Gat, &ds, flags, 1);
        dp.retarget(base, &pms).unwrap();
        dp.retarget(stepped, &pms).unwrap();
        assert_eq!(dp.rebuilds(), 1, "only the first target may rebuild");
        assert_eq!(dp.patches(), 1, "the t_r step must go through the patch path");

        let fresh = plan::build(ModelKind::Gat, &ds, &pms, stepped, flags).unwrap();
        let patched = &dp.state.as_ref().unwrap().soa;
        assert_eq!(patched.latency, fresh.soa.latency, "latency lanes diverged");
        assert_eq!(patched.energy, fresh.soa.energy, "energy lanes diverged");
        assert_eq!(patched.group_energy, fresh.soa.group_energy);
        assert_eq!(patched.group_agg, fresh.soa.group_agg);
        assert_eq!(patched.group_comb, fresh.soa.group_comb);
        assert_eq!(patched.group_upd, fresh.soa.group_upd);
        assert_eq!(patched.scheds, fresh.soa.scheds, "cached recurrences diverged");
        for (i, (a, b)) in patched.entries.iter().zip(&fresh.soa.entries).enumerate() {
            match (a, b) {
                (
                    SoaEntry::Serial { kind: ka, cost: ca },
                    SoaEntry::Serial { kind: kb, cost: cb },
                ) => {
                    assert_eq!(ka, kb, "entry {i} kind");
                    assert_eq!(ca.latency_s, cb.latency_s, "entry {i} ({ka:?}) latency");
                    assert_eq!(ca.energy_j, cb.energy_j, "entry {i} ({ka:?}) energy");
                }
                (SoaEntry::Segment { seg: sa }, SoaEntry::Segment { seg: sb }) => {
                    assert_eq!(sa, sb, "entry {i} segment index")
                }
                _ => panic!("entry {i}: walk shapes diverged"),
            }
        }
        assert_eq!(dp.evaluate().unwrap(), plan::reference_evaluate(&fresh).unwrap());
    }

    /// The graph-churn patch pin: after an edge-churn mutation batch, one
    /// `retarget_graph` goes through the patch path (not a rebuild) and
    /// the patched evaluation equals a cold rebuild on the mutated
    /// dataset, for both execution orderings.
    #[test]
    fn graph_patch_matches_a_cold_rebuild_after_mutation() {
        use crate::graph::mutate;
        use crate::util::rng::Pcg64;
        let cfg = GhostConfig::paper_optimal();
        let flags = OptFlags::ghost_default();
        for (kind, seed) in [(ModelKind::Gcn, 11u64), (ModelKind::Gat, 12u64)] {
            let mut ds = Dataset::by_name("Cora").unwrap();
            let mut pms = PartitionMatrix::build_all(&ds.graphs, cfg.v, cfg.n);
            let mut dp = GraphDeltaPlan::new(kind, &ds.spec, cfg, flags, 1);
            dp.retarget_graph(&ds, &pms, None).unwrap();
            assert_eq!((dp.rebuilds(), dp.patches()), (1, 0));
            let mut rng = Pcg64::seed_from_u64(seed);
            // Pure edge churn (no vertex adds) keeps the group count, so
            // the retarget must patch.
            let batch = mutate::random_batch(&ds.graphs[0], 250, 0.6, 0.0, &mut rng);
            let ad = mutate::apply_to_dataset(&mut ds, &mut pms, 0, &batch).unwrap();
            dp.retarget_graph(&ds, &pms, Some(std::slice::from_ref(&ad))).unwrap();
            assert_eq!(
                (dp.rebuilds(), dp.patches()),
                (1, 1),
                "{kind:?}: edge churn must take the patch path"
            );
            let fresh = plan::build(kind, &ds, &pms, cfg, flags).unwrap();
            assert_eq!(
                dp.evaluate().unwrap(),
                plan::reference_evaluate(&fresh).unwrap(),
                "{kind:?}: patched evaluation diverged from a cold rebuild"
            );
        }
    }

    /// Vertex growth that crosses a group boundary reshapes the lane
    /// layout, so the retarget must rebuild — and still match a cold
    /// build.
    #[test]
    fn group_count_change_forces_a_rebuild() {
        let cfg = GhostConfig::paper_optimal();
        let flags = OptFlags::ghost_default();
        let mut ds = Dataset::by_name("Citeseer").unwrap();
        let mut pms = PartitionMatrix::build_all(&ds.graphs, cfg.v, cfg.n);
        let mut dp = GraphDeltaPlan::new(ModelKind::Gcn, &ds.spec, cfg, flags, 1);
        dp.retarget_graph(&ds, &pms, None).unwrap();
        // Enough vertex adds to guarantee a new output group.
        let batch: Vec<_> = (0..cfg.v + 1).map(|_| crate::graph::mutate::GraphDelta::AddVertex).collect();
        let ad = crate::graph::mutate::apply_to_dataset(&mut ds, &mut pms, 0, &batch).unwrap();
        assert!(ad.new_n_groups > ad.old_n_groups);
        dp.retarget_graph(&ds, &pms, Some(std::slice::from_ref(&ad))).unwrap();
        assert_eq!((dp.rebuilds(), dp.patches()), (2, 0));
        let fresh = plan::build(ModelKind::Gcn, &ds, &pms, cfg, flags).unwrap();
        assert_eq!(dp.evaluate().unwrap(), plan::reference_evaluate(&fresh).unwrap());
    }
}
