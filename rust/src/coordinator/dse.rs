//! Architectural design-space exploration — Fig. 7(c).
//!
//! Sweeps `[N, V, R_r, R_c, T_r]` within the device-level feasibility
//! bounds (R_c ≤ 20 coherent MRs, R_r ≤ 18 wavelengths), evaluating the
//! average EPB/GOPS across the evaluation workloads, and reports the
//! frontier. The paper's optimum is `[20, 20, 18, 7, 17]`.

use crate::config::GhostConfig;
use crate::energy::geomean;
use crate::gnn::models::ModelKind;
use crate::graph::datasets::Dataset;
use crate::graph::partition::PartitionMatrix;

use super::optimizations::OptFlags;
use super::schedule::{simulate_with_partitions, simulate_workload};

/// One evaluated architecture point.
#[derive(Debug, Clone, Copy)]
pub struct ArchDsePoint {
    pub cfg: GhostConfig,
    /// Geometric-mean EPB/GOPS across the workload set (lower = better).
    pub epb_per_gops: f64,
    /// Geometric-mean GOPS.
    pub gops: f64,
    /// Geometric-mean EPB (J/bit).
    pub epb: f64,
}

/// The sweep grid: a lattice over the five parameters within device
/// feasibility, always containing the paper's optimum.
pub fn default_grid() -> Vec<GhostConfig> {
    let ns = [10usize, 20, 30];
    let vs = [10usize, 20, 30];
    let rrs = [6usize, 12, 18];
    let rcs = [3usize, 7, 14, 20];
    let trs = [5usize, 11, 17];
    let mut grid = Vec::new();
    for &n in &ns {
        for &v in &vs {
            for &r_r in &rrs {
                for &r_c in &rcs {
                    for &t_r in &trs {
                        let cfg = GhostConfig { n, v, r_r, r_c, t_r };
                        if cfg.validate().is_ok() {
                            grid.push(cfg);
                        }
                    }
                }
            }
        }
    }
    let paper = GhostConfig::paper_optimal();
    if !grid.contains(&paper) {
        grid.push(paper);
    }
    grid
}

/// Workload set for the sweep. `quick = true` uses one representative
/// dataset per model (the Fig. 7(c) shape at ~4× less compute);
/// `quick = false` uses all 16 model × dataset pairs as in the paper.
pub fn workload_set(quick: bool) -> Vec<(ModelKind, Dataset)> {
    let mut out = Vec::new();
    for kind in ModelKind::ALL {
        let names: &[&str] = if quick { &kind.datasets()[..1] } else { &kind.datasets()[..] };
        for name in names {
            out.push((kind, Dataset::by_name(name).expect("table-2 dataset")));
        }
    }
    out
}

/// Evaluate one configuration over a workload set (geometric means).
pub fn evaluate(cfg: GhostConfig, workloads: &[(ModelKind, Dataset)]) -> Option<ArchDsePoint> {
    let flags = OptFlags::ghost_default();
    let mut epb_gops = Vec::with_capacity(workloads.len());
    let mut gops = Vec::with_capacity(workloads.len());
    let mut epb = Vec::with_capacity(workloads.len());
    for (kind, ds) in workloads {
        let r = simulate_workload(*kind, ds, cfg, flags).ok()?;
        epb_gops.push(r.metrics.epb_per_gops());
        gops.push(r.metrics.gops());
        epb.push(r.metrics.epb());
    }
    Some(ArchDsePoint {
        cfg,
        epb_per_gops: geomean(epb_gops),
        gops: geomean(gops),
        epb: geomean(epb),
    })
}

/// Evaluate with partitions amortized per `(V, N)` (the configs sharing a
/// partition shape reuse the same preprocessing).
fn evaluate_with_partitions(
    cfg: GhostConfig,
    workloads: &[(ModelKind, Dataset)],
    partitions: &[Vec<PartitionMatrix>],
) -> Option<ArchDsePoint> {
    let flags = OptFlags::ghost_default();
    let mut epb_gops = Vec::with_capacity(workloads.len());
    let mut gops = Vec::with_capacity(workloads.len());
    let mut epb = Vec::with_capacity(workloads.len());
    for ((kind, ds), pms) in workloads.iter().zip(partitions) {
        let r = simulate_with_partitions(*kind, ds, pms, cfg, flags).ok()?;
        epb_gops.push(r.metrics.epb_per_gops());
        gops.push(r.metrics.gops());
        epb.push(r.metrics.epb());
    }
    Some(ArchDsePoint {
        cfg,
        epb_per_gops: geomean(epb_gops),
        gops: geomean(gops),
        epb: geomean(epb),
    })
}

/// Run the sweep (thread-pool parallel) and return points sorted by
/// EPB/GOPS ascending (the best configuration first). Partition matrices
/// are built once per distinct `(V, N)` pair and shared across the grid —
/// the sweep's dominant cost otherwise.
pub fn explore(grid: &[GhostConfig], workloads: &[(ModelKind, Dataset)]) -> Vec<ArchDsePoint> {
    use std::collections::HashMap;
    let mut shapes: Vec<(usize, usize)> = grid.iter().map(|c| (c.v, c.n)).collect();
    shapes.sort_unstable();
    shapes.dedup();
    let partition_sets: HashMap<(usize, usize), Vec<Vec<PartitionMatrix>>> =
        crate::util::parallel::par_map(&shapes, |&(v, n)| {
            let per_workload: Vec<Vec<PartitionMatrix>> = workloads
                .iter()
                .map(|(_, ds)| {
                    ds.graphs.iter().map(|g| PartitionMatrix::build(g, v, n)).collect()
                })
                .collect();
            ((v, n), per_workload)
        })
        .into_iter()
        .collect();
    let mut points: Vec<ArchDsePoint> = crate::util::parallel::par_map(grid, |&cfg| {
        evaluate_with_partitions(cfg, workloads, &partition_sets[&(cfg.v, cfg.n)])
    })
    .into_iter()
    .flatten()
    .collect();
    points.sort_by(|a, b| a.epb_per_gops.partial_cmp(&b.epb_per_gops).unwrap());
    points
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_contains_paper_point_and_respects_device_limits() {
        let grid = default_grid();
        assert!(grid.contains(&GhostConfig::paper_optimal()));
        for cfg in &grid {
            cfg.validate().unwrap();
        }
        assert!(grid.len() > 100, "grid too small: {}", grid.len());
    }

    #[test]
    fn paper_point_is_near_optimal() {
        // Small sweep around the paper point: it must rank in the top
        // quartile of its neighborhood on EPB/GOPS.
        let workloads = workload_set(true);
        let paper = GhostConfig::paper_optimal();
        let mut neighborhood = vec![paper];
        for (dn, dv) in [(-10i64, 0i64), (10, 0), (0, -10), (0, 10)] {
            let cfg = GhostConfig {
                n: (paper.n as i64 + dn).max(5) as usize,
                v: (paper.v as i64 + dv).max(5) as usize,
                ..paper
            };
            if cfg.validate().is_ok() {
                neighborhood.push(cfg);
            }
        }
        let pts = explore(&neighborhood, &workloads);
        let rank = pts.iter().position(|p| p.cfg == paper).unwrap();
        assert!(rank <= pts.len() / 2, "paper point ranked {rank} of {}", pts.len());
    }

    #[test]
    fn evaluate_produces_finite_metrics() {
        let workloads = workload_set(true);
        let p = evaluate(GhostConfig::paper_optimal(), &workloads).unwrap();
        assert!(p.epb_per_gops.is_finite() && p.epb_per_gops > 0.0);
        assert!(p.gops.is_finite() && p.gops > 0.0);
    }
}
