//! Architectural design-space exploration — Fig. 7(c).
//!
//! Sweeps `[N, V, R_r, R_c, T_r]` within the device-level feasibility
//! bounds (R_c ≤ 20 coherent MRs, R_r ≤ 18 wavelengths), evaluating the
//! average EPB/GOPS across the evaluation workloads, and reports the
//! frontier. The paper's optimum is `[20, 20, 18, 7, 17]`.
//!
//! The sweep runs through a [`BatchEngine`]: partition matrices are built
//! once per distinct `(dataset, V, N)` and shared across the whole grid —
//! the sweep's dominant cost otherwise. Failing points (unknown dataset,
//! infeasible config, non-finite metric) degrade to recorded
//! [`DseFailure`] entries instead of aborting the sweep.

use crate::config::GhostConfig;
use crate::energy::geomean;
use crate::gnn::models::ModelKind;
use crate::graph::datasets::Dataset;

use super::engine::BatchEngine;
use super::error::SimError;
use super::optimizations::OptFlags;
use super::schedule::{simulate_with_partitions, simulate_workload};

/// One evaluated architecture point.
#[derive(Debug, Clone, Copy)]
pub struct ArchDsePoint {
    pub cfg: GhostConfig,
    /// Geometric-mean EPB/GOPS across the workload set (lower = better).
    pub epb_per_gops: f64,
    /// Geometric-mean GOPS.
    pub gops: f64,
    /// Geometric-mean EPB (J/bit).
    pub epb: f64,
}

/// One grid point that produced no frontier entry, and why.
#[derive(Debug, Clone)]
pub struct DseFailure {
    pub cfg: GhostConfig,
    pub error: SimError,
}

/// Outcome of a sweep: the frontier (sorted by EPB/GOPS ascending, best
/// first) plus every point that failed or was filtered, with its reason.
#[derive(Debug, Clone, Default)]
pub struct DseReport {
    pub points: Vec<ArchDsePoint>,
    pub failures: Vec<DseFailure>,
}

impl DseReport {
    /// The best (lowest EPB/GOPS) point, if any point survived.
    pub fn best(&self) -> Option<&ArchDsePoint> {
        self.points.first()
    }
}

/// The sweep grid: a lattice over the five parameters within device
/// feasibility, always containing the paper's optimum.
pub fn default_grid() -> Vec<GhostConfig> {
    let ns = [10usize, 20, 30];
    let vs = [10usize, 20, 30];
    let rrs = [6usize, 12, 18];
    let rcs = [3usize, 7, 14, 20];
    let trs = [5usize, 11, 17];
    let mut grid = Vec::new();
    for &n in &ns {
        for &v in &vs {
            for &r_r in &rrs {
                for &r_c in &rcs {
                    for &t_r in &trs {
                        let cfg =
                            GhostConfig { n, v, r_r, r_c, t_r, ..GhostConfig::paper_optimal() };
                        if cfg.validate().is_ok() {
                            grid.push(cfg);
                        }
                    }
                }
            }
        }
    }
    let paper = GhostConfig::paper_optimal();
    if !grid.contains(&paper) {
        grid.push(paper);
    }
    grid
}

/// Workload names for the sweep. `quick = true` uses one representative
/// dataset per model (the Fig. 7(c) shape at ~4× less compute);
/// `quick = false` uses all 16 model × dataset pairs as in the paper.
pub fn workload_names(quick: bool) -> Vec<(ModelKind, &'static str)> {
    let mut out = Vec::new();
    for kind in ModelKind::ALL {
        let all = kind.datasets();
        let take = if quick { 1 } else { all.len() };
        for name in &all[..take] {
            out.push((kind, *name));
        }
    }
    out
}

/// Realizes the workload set. An unknown dataset name comes back as a
/// recoverable [`SimError::UnknownDataset`], not a panic.
pub fn workload_set(quick: bool) -> Result<Vec<(ModelKind, Dataset)>, SimError> {
    workload_names(quick)
        .into_iter()
        .map(|(kind, name)| {
            Dataset::by_name(name)
                .map(|ds| (kind, ds))
                .ok_or_else(|| SimError::UnknownDataset(name.to_string()))
        })
        .collect()
}

/// Evaluate one configuration over a workload set (geometric means),
/// rebuilding partitions from scratch — the uncached reference the engine
/// path is tested against. A failing workload is propagated with its
/// `(model, dataset)` identity attached.
pub fn evaluate(
    cfg: GhostConfig,
    workloads: &[(ModelKind, Dataset)],
) -> Result<ArchDsePoint, SimError> {
    let flags = OptFlags::ghost_default();
    let mut epb_gops = Vec::with_capacity(workloads.len());
    let mut gops = Vec::with_capacity(workloads.len());
    let mut epb = Vec::with_capacity(workloads.len());
    for (kind, ds) in workloads {
        let r = simulate_workload(*kind, ds, cfg, flags)
            .map_err(|e| e.in_workload(*kind, ds.spec.name))?;
        epb_gops.push(r.metrics.epb_per_gops());
        gops.push(r.metrics.gops());
        epb.push(r.metrics.epb());
    }
    Ok(ArchDsePoint {
        cfg,
        epb_per_gops: geomean(epb_gops),
        gops: geomean(gops),
        epb: geomean(epb),
    })
}

/// Evaluate one configuration through the engine's partition cache: every
/// config sharing a `(dataset, V, N)` key reuses the same preprocessing.
pub fn evaluate_with_engine(
    engine: &BatchEngine,
    cfg: GhostConfig,
    workloads: &[(ModelKind, Dataset)],
) -> Result<ArchDsePoint, SimError> {
    cfg.validate().map_err(SimError::InvalidConfig)?;
    let flags = OptFlags::ghost_default();
    let mut epb_gops = Vec::with_capacity(workloads.len());
    let mut gops = Vec::with_capacity(workloads.len());
    let mut epb = Vec::with_capacity(workloads.len());
    for (kind, ds) in workloads {
        let pms = engine.partitions_for(ds, cfg.v, cfg.n)?;
        let r = simulate_with_partitions(*kind, ds, &pms, cfg, flags)
            .map_err(|e| e.in_workload(*kind, ds.spec.name))?;
        epb_gops.push(r.metrics.epb_per_gops());
        gops.push(r.metrics.gops());
        epb.push(r.metrics.epb());
    }
    Ok(ArchDsePoint {
        cfg,
        epb_per_gops: geomean(epb_gops),
        gops: geomean(gops),
        epb: geomean(epb),
    })
}

/// Splits raw per-config results into the sorted frontier and the failure
/// list. Non-finite EPB/GOPS points are filtered with a warning instead of
/// poisoning the sort (which previously panicked via `partial_cmp`); the
/// survivors sort with `f64::total_cmp`.
fn sift_points(raw: Vec<(GhostConfig, Result<ArchDsePoint, SimError>)>) -> DseReport {
    let mut points = Vec::new();
    let mut failures = Vec::new();
    for (cfg, res) in raw {
        match res {
            Ok(p) if p.epb_per_gops.is_finite() => points.push(p),
            Ok(p) => {
                eprintln!(
                    "warning: dse point {cfg:?} produced non-finite EPB/GOPS ({}); \
                     dropping it from the frontier",
                    p.epb_per_gops
                );
                failures.push(DseFailure {
                    cfg,
                    error: SimError::NonFiniteMetric {
                        metric: "epb_per_gops",
                        value: p.epb_per_gops,
                    },
                });
            }
            Err(error) => failures.push(DseFailure { cfg, error }),
        }
    }
    points.sort_by(|a, b| a.epb_per_gops.total_cmp(&b.epb_per_gops));
    DseReport { points, failures }
}

/// Run the sweep (thread-pool parallel) through a sweep-local engine that
/// is dropped when the sweep returns, so a one-shot `explore` retains no
/// partition sets afterwards. Callers that want cross-sweep reuse pass
/// their own (or the [`BatchEngine::global`]) engine to
/// [`explore_with_engine`].
pub fn explore(grid: &[GhostConfig], workloads: &[(ModelKind, Dataset)]) -> DseReport {
    explore_with_engine(&BatchEngine::new(), grid, workloads)
}

/// Run the sweep through a specific engine with the default worker tier
/// ([`crate::util::parallel::default_workers`]). See
/// [`explore_with_engine_workers`] for the contract.
pub fn explore_with_engine(
    engine: &BatchEngine,
    grid: &[GhostConfig],
    workloads: &[(ModelKind, Dataset)],
) -> DseReport {
    explore_with_engine_workers(
        engine,
        grid,
        workloads,
        crate::util::parallel::default_workers(),
    )
}

/// Run the sweep through a specific engine with a pinned worker count.
/// Partition matrices are built once per distinct `(dataset, V, N)` pair
/// (pre-warmed in parallel, then shared across the grid); each grid point
/// evaluates on the thread pool, and failures are reported per point
/// instead of being silently dropped.
///
/// The report is **deterministic in the worker count**: grid points are
/// pure functions of `(cfg, workloads)`, results come back in grid order
/// regardless of scheduling ([`par_map_workers`] preserves order), and the
/// frontier sort is stable on a total order — so any two worker counts
/// produce the identical `DseReport` (pinned by a test). Benches exploit
/// the same knob to measure the parallel speedup
/// (`benches/dse_arch.rs`).
///
/// [`par_map_workers`]: crate::util::parallel::par_map_workers
pub fn explore_with_engine_workers(
    engine: &BatchEngine,
    grid: &[GhostConfig],
    workloads: &[(ModelKind, Dataset)],
    workers: usize,
) -> DseReport {
    // Pre-warm the partition cache: one parallel build per distinct shape.
    let mut shapes: Vec<(usize, usize)> = grid.iter().map(|c| (c.v, c.n)).collect();
    shapes.sort_unstable();
    shapes.dedup();
    crate::util::parallel::par_map_workers(&shapes, workers, |&(v, n)| {
        for (_, ds) in workloads {
            // Invalid shapes surface again per-point in the sweep below.
            let _ = engine.partitions_for(ds, v, n);
        }
    });
    let raw = crate::util::parallel::par_map_workers(grid, workers, |&cfg| {
        (cfg, evaluate_with_engine(engine, cfg, workloads))
    });
    sift_points(raw)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_contains_paper_point_and_respects_device_limits() {
        let grid = default_grid();
        assert!(grid.contains(&GhostConfig::paper_optimal()));
        for cfg in &grid {
            cfg.validate().unwrap();
        }
        assert!(grid.len() > 100, "grid too small: {}", grid.len());
    }

    #[test]
    fn paper_point_is_near_optimal() {
        // Small sweep around the paper point: it must rank in the top
        // quartile of its neighborhood on EPB/GOPS.
        let workloads = workload_set(true).unwrap();
        let paper = GhostConfig::paper_optimal();
        let mut neighborhood = vec![paper];
        for (dn, dv) in [(-10i64, 0i64), (10, 0), (0, -10), (0, 10)] {
            let cfg = GhostConfig {
                n: (paper.n as i64 + dn).max(5) as usize,
                v: (paper.v as i64 + dv).max(5) as usize,
                ..paper
            };
            if cfg.validate().is_ok() {
                neighborhood.push(cfg);
            }
        }
        let report = explore(&neighborhood, &workloads);
        assert!(report.failures.is_empty(), "failures: {:?}", report.failures);
        let pts = &report.points;
        let rank = pts.iter().position(|p| p.cfg == paper).unwrap();
        assert!(rank <= pts.len() / 2, "paper point ranked {rank} of {}", pts.len());
    }

    #[test]
    fn evaluate_produces_finite_metrics() {
        let workloads = workload_set(true).unwrap();
        let p = evaluate(GhostConfig::paper_optimal(), &workloads).unwrap();
        assert!(p.epb_per_gops.is_finite() && p.epb_per_gops > 0.0);
        assert!(p.gops.is_finite() && p.gops > 0.0);
    }

    #[test]
    fn engine_evaluation_matches_uncached_reference() {
        let workloads = workload_set(true).unwrap();
        let cfg = GhostConfig::paper_optimal();
        let engine = BatchEngine::new();
        let cached = evaluate_with_engine(&engine, cfg, &workloads).unwrap();
        let uncached = evaluate(cfg, &workloads).unwrap();
        assert_eq!(cached.epb_per_gops, uncached.epb_per_gops);
        assert_eq!(cached.gops, uncached.gops);
        assert_eq!(cached.epb, uncached.epb);
    }

    #[test]
    fn sift_filters_non_finite_points_and_sorts_with_total_cmp() {
        let cfg = GhostConfig::paper_optimal();
        let pt = |x: f64| ArchDsePoint { cfg, epb_per_gops: x, gops: 1.0, epb: 1.0 };
        let raw = vec![
            (cfg, Ok(pt(2.0))),
            (cfg, Ok(pt(f64::NAN))),
            (cfg, Ok(pt(1.0))),
            (cfg, Ok(pt(f64::INFINITY))),
            (cfg, Err(SimError::UnknownDataset("nope".into()))),
        ];
        let report = sift_points(raw);
        assert_eq!(report.points.len(), 2);
        assert_eq!(report.points[0].epb_per_gops, 1.0);
        assert_eq!(report.points[1].epb_per_gops, 2.0);
        assert_eq!(report.failures.len(), 3);
        assert!(report
            .failures
            .iter()
            .any(|f| matches!(f.error, SimError::NonFiniteMetric { .. })));
        assert!(report
            .failures
            .iter()
            .any(|f| matches!(f.error, SimError::UnknownDataset(_))));
        assert_eq!(report.best().unwrap().epb_per_gops, 1.0);
    }

    #[test]
    fn infeasible_grid_point_becomes_failure_not_abort() {
        let workloads = workload_set(true).unwrap();
        let good = GhostConfig::paper_optimal();
        let bad = GhostConfig { r_c: 25, ..good }; // > 20 coherent MRs
        let report = explore_with_engine(&BatchEngine::new(), &[good, bad], &workloads);
        assert_eq!(report.points.len(), 1);
        assert_eq!(report.failures.len(), 1);
        assert_eq!(report.failures[0].cfg, bad);
        assert!(matches!(report.failures[0].error, SimError::InvalidConfig(_)));
    }

    #[test]
    fn explore_report_invariant_under_worker_count() {
        // The sweep fans out over util::parallel::par_map_workers; the
        // resulting DseReport (points, order, exact metric values, and
        // failures) must be identical for any worker count.
        let workloads = workload_set(true).unwrap();
        let paper = GhostConfig::paper_optimal();
        let grid = vec![
            paper,
            GhostConfig { n: 10, ..paper },
            GhostConfig { v: 10, ..paper },
            GhostConfig { t_r: 11, ..paper },
            GhostConfig { r_c: 25, ..paper }, // infeasible → failure entry
        ];
        let reference =
            explore_with_engine_workers(&BatchEngine::new(), &grid, &workloads, 1);
        assert_eq!(reference.points.len(), 4);
        assert_eq!(reference.failures.len(), 1);
        for workers in [2usize, 4, 16] {
            let report =
                explore_with_engine_workers(&BatchEngine::new(), &grid, &workloads, workers);
            assert_eq!(report.points.len(), reference.points.len(), "workers={workers}");
            for (a, b) in report.points.iter().zip(&reference.points) {
                assert_eq!(a.cfg, b.cfg, "workers={workers}");
                // Bit-identical, not approximately equal: the evaluation
                // per point is single-threaded and pure.
                assert_eq!(a.epb_per_gops, b.epb_per_gops, "workers={workers}");
                assert_eq!(a.gops, b.gops, "workers={workers}");
                assert_eq!(a.epb, b.epb, "workers={workers}");
            }
            assert_eq!(report.failures.len(), reference.failures.len());
            for (a, b) in report.failures.iter().zip(&reference.failures) {
                assert_eq!(a.cfg, b.cfg, "workers={workers}");
            }
        }
    }

    #[test]
    fn workload_sets_cover_the_paper_matrix() {
        assert_eq!(workload_names(true).len(), 4);
        assert_eq!(workload_names(false).len(), 16);
        assert_eq!(workload_set(false).unwrap().len(), 16);
    }
}
