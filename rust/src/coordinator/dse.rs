//! Architectural design-space exploration — Fig. 7(c).
//!
//! Sweeps `[N, V, R_r, R_c, T_r]` within the device-level feasibility
//! bounds (R_c ≤ 20 coherent MRs, R_r ≤ 18 wavelengths), evaluating the
//! average EPB/GOPS across the evaluation workloads, and reports the
//! frontier. The paper's optimum is `[20, 20, 18, 7, 17]`.
//!
//! The sweep runs through a [`BatchEngine`]: partition matrices are built
//! once per distinct `(dataset, V, N)` and shared across the whole grid —
//! the sweep's dominant cost otherwise. Failing points (unknown dataset,
//! infeasible config, non-finite metric) degrade to recorded
//! [`DseFailure`] entries instead of aborting the sweep.
//!
//! By default the sweep **delta-evaluates**: the grid is visited in a
//! mixed-radix reflected-Gray order ([`gray_order`]) so neighboring points
//! differ in as few (and as inner) parameters as possible, and each
//! workload keeps one incrementally re-costed
//! [`DeltaPlan`](super::soa::DeltaPlan) across the whole chain — only the
//! lanes whose [`StageKind::provenance`] intersects the changed parameters
//! are re-costed between points, with a full rebuild only when `n`, `v`,
//! or the memory budget changes. The resulting [`DseReport`] is
//! bit-identical to the full-rebuild path (pinned by a test and by the
//! `GHOST_DSE_CHECK` debug mode, which re-derives every point through the
//! retained reference evaluator and `assert_eq!`s the whole `SimReport`).
//! Set `GHOST_DSE_DELTA=0` (or `off`/`false`) to force the full-rebuild
//! path.
//!
//! [`StageKind::provenance`]: super::plan::StageKind::provenance

use crate::config::GhostConfig;
use crate::energy::geomean;
use crate::gnn::models::ModelKind;
use crate::graph::datasets::Dataset;
use crate::graph::partition::PartitionMatrix;

use super::engine::BatchEngine;
use super::error::SimError;
use super::optimizations::OptFlags;
use super::plan;
use super::soa::DeltaPlan;

/// One evaluated architecture point.
#[derive(Debug, Clone, Copy)]
pub struct ArchDsePoint {
    pub cfg: GhostConfig,
    /// Geometric-mean EPB/GOPS across the workload set (lower = better).
    pub epb_per_gops: f64,
    /// Geometric-mean GOPS.
    pub gops: f64,
    /// Geometric-mean EPB (J/bit).
    pub epb: f64,
}

/// One grid point that produced no frontier entry, and why.
#[derive(Debug, Clone)]
pub struct DseFailure {
    pub cfg: GhostConfig,
    pub error: SimError,
}

/// Counters describing how a delta sweep moved across the grid: how many
/// points paid a full plan rebuild versus an incremental lane patch,
/// summed over every workload chain. Zero/zero for the full-rebuild path.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DeltaStats {
    /// Full `plan::build` reconstructions (structural parameter changes —
    /// `n` / `v` / memory budget — plus each chain's first point).
    pub rebuilds: usize,
    /// Provenance-targeted lane patches (only `r_r` / `r_c` / `t_r`
    /// moved).
    pub patches: usize,
}

/// Outcome of a sweep: the frontier (sorted by EPB/GOPS ascending, best
/// first) plus every point that failed or was filtered, with its reason.
#[derive(Debug, Clone, Default)]
pub struct DseReport {
    pub points: Vec<ArchDsePoint>,
    pub failures: Vec<DseFailure>,
    /// Rebuild/patch counters of the delta evaluator (all-zero when the
    /// sweep ran the full-rebuild path).
    pub delta: DeltaStats,
}

impl DseReport {
    /// The best (lowest EPB/GOPS) point, if any point survived.
    pub fn best(&self) -> Option<&ArchDsePoint> {
        self.points.first()
    }
}

/// The sweep grid: a lattice over the five parameters within device
/// feasibility, always containing the paper's optimum.
pub fn default_grid() -> Vec<GhostConfig> {
    let ns = [10usize, 20, 30];
    let vs = [10usize, 20, 30];
    let rrs = [6usize, 12, 18];
    let rcs = [3usize, 7, 14, 20];
    let trs = [5usize, 11, 17];
    let mut grid = Vec::new();
    for &n in &ns {
        for &v in &vs {
            for &r_r in &rrs {
                for &r_c in &rcs {
                    for &t_r in &trs {
                        let cfg =
                            GhostConfig { n, v, r_r, r_c, t_r, ..GhostConfig::paper_optimal() };
                        if cfg.validate().is_ok() {
                            grid.push(cfg);
                        }
                    }
                }
            }
        }
    }
    let paper = GhostConfig::paper_optimal();
    if !grid.contains(&paper) {
        grid.push(paper);
    }
    grid
}

/// Workload names for the sweep. `quick = true` uses one representative
/// dataset per model (the Fig. 7(c) shape at ~4× less compute);
/// `quick = false` uses all 16 model × dataset pairs as in the paper.
pub fn workload_names(quick: bool) -> Vec<(ModelKind, &'static str)> {
    let mut out = Vec::new();
    for kind in ModelKind::ALL {
        let all = kind.datasets();
        let take = if quick { 1 } else { all.len() };
        for name in &all[..take] {
            out.push((kind, *name));
        }
    }
    out
}

/// Realizes the workload set. An unknown dataset name comes back as a
/// recoverable [`SimError::UnknownDataset`], not a panic.
pub fn workload_set(quick: bool) -> Result<Vec<(ModelKind, Dataset)>, SimError> {
    workload_names(quick)
        .into_iter()
        .map(|(kind, name)| {
            Dataset::by_name(name)
                .map(|ds| (kind, ds))
                .ok_or_else(|| SimError::UnknownDataset(name.to_string()))
        })
        .collect()
}

/// Evaluate one configuration over a workload set (geometric means),
/// rebuilding partitions from scratch — the uncached reference oracle the
/// engine and delta paths are tested against. Goes straight through
/// [`plan::build`] / [`plan::evaluate`] like every other consumer. A
/// failing workload is propagated with its `(model, dataset)` identity
/// attached.
pub fn evaluate(
    cfg: GhostConfig,
    workloads: &[(ModelKind, Dataset)],
) -> Result<ArchDsePoint, SimError> {
    // Validate before partitioning: a zero-dimension config must come back
    // as an error, not trip the partition builder's assert.
    cfg.validate().map_err(SimError::InvalidConfig)?;
    let flags = OptFlags::ghost_default();
    let mut epb_gops = Vec::with_capacity(workloads.len());
    let mut gops = Vec::with_capacity(workloads.len());
    let mut epb = Vec::with_capacity(workloads.len());
    for (kind, ds) in workloads {
        let pms = PartitionMatrix::build_all(&ds.graphs, cfg.v, cfg.n);
        let r = plan::build(*kind, ds, &pms, cfg, flags)
            .and_then(|p| plan::evaluate(&p))
            .map_err(|e| e.in_workload(*kind, ds.spec.name))?;
        epb_gops.push(r.metrics.epb_per_gops());
        gops.push(r.metrics.gops());
        epb.push(r.metrics.epb());
    }
    Ok(ArchDsePoint {
        cfg,
        epb_per_gops: geomean(epb_gops),
        gops: geomean(gops),
        epb: geomean(epb),
    })
}

/// Evaluate one configuration through the engine's partition cache: every
/// config sharing a `(dataset, V, N)` key reuses the same preprocessing.
pub fn evaluate_with_engine(
    engine: &BatchEngine,
    cfg: GhostConfig,
    workloads: &[(ModelKind, Dataset)],
) -> Result<ArchDsePoint, SimError> {
    cfg.validate().map_err(SimError::InvalidConfig)?;
    let flags = OptFlags::ghost_default();
    let mut epb_gops = Vec::with_capacity(workloads.len());
    let mut gops = Vec::with_capacity(workloads.len());
    let mut epb = Vec::with_capacity(workloads.len());
    for (kind, ds) in workloads {
        let pms = engine.partitions_for(ds, cfg.v, cfg.n)?;
        let r = plan::build(*kind, ds, &pms, cfg, flags)
            .and_then(|p| plan::evaluate(&p))
            .map_err(|e| e.in_workload(*kind, ds.spec.name))?;
        epb_gops.push(r.metrics.epb_per_gops());
        gops.push(r.metrics.gops());
        epb.push(r.metrics.epb());
    }
    Ok(ArchDsePoint {
        cfg,
        epb_per_gops: geomean(epb_gops),
        gops: geomean(gops),
        epb: geomean(epb),
    })
}

/// Splits raw per-config results into the sorted frontier and the failure
/// list. Non-finite EPB/GOPS points are filtered with a warning instead of
/// poisoning the sort (which previously panicked via `partial_cmp`); the
/// survivors sort with `f64::total_cmp`.
fn sift_points(raw: Vec<(GhostConfig, Result<ArchDsePoint, SimError>)>) -> DseReport {
    let mut points = Vec::new();
    let mut failures = Vec::new();
    for (cfg, res) in raw {
        match res {
            Ok(p) if p.epb_per_gops.is_finite() => points.push(p),
            Ok(p) => {
                eprintln!(
                    "warning: dse point {cfg:?} produced non-finite EPB/GOPS ({}); \
                     dropping it from the frontier",
                    p.epb_per_gops
                );
                failures.push(DseFailure {
                    cfg,
                    error: SimError::NonFiniteMetric {
                        metric: "epb_per_gops",
                        value: p.epb_per_gops,
                    },
                });
            }
            Err(error) => failures.push(DseFailure { cfg, error }),
        }
    }
    points.sort_by(|a, b| a.epb_per_gops.total_cmp(&b.epb_per_gops));
    DseReport { points, failures, delta: DeltaStats::default() }
}

/// Run the sweep (thread-pool parallel) through a sweep-local engine that
/// is dropped when the sweep returns, so a one-shot `explore` retains no
/// partition sets afterwards. Callers that want cross-sweep reuse pass
/// their own (or the [`BatchEngine::global`]) engine to
/// [`explore_with_engine`].
pub fn explore(grid: &[GhostConfig], workloads: &[(ModelKind, Dataset)]) -> DseReport {
    explore_with_engine(&BatchEngine::new(), grid, workloads)
}

/// Run the sweep through a specific engine with the default worker tier
/// ([`crate::util::parallel::default_workers`]). See
/// [`explore_with_engine_workers`] for the contract.
pub fn explore_with_engine(
    engine: &BatchEngine,
    grid: &[GhostConfig],
    workloads: &[(ModelKind, Dataset)],
) -> DseReport {
    explore_with_engine_workers(
        engine,
        grid,
        workloads,
        crate::util::parallel::default_workers(),
    )
}

/// Run the sweep through a specific engine with a pinned worker count.
/// Partition matrices are built once per distinct `(dataset, V, N)` pair
/// (pre-warmed in parallel, then shared across the grid); each grid point
/// evaluates on the thread pool, and failures are reported per point
/// instead of being silently dropped.
///
/// The report is **deterministic in the worker count**: grid points are
/// pure functions of `(cfg, workloads)`, results come back in grid order
/// regardless of scheduling ([`par_map_workers`] preserves order), and the
/// frontier sort is stable on a total order — so any two worker counts
/// produce the identical `DseReport` (pinned by a test). Benches exploit
/// the same knob to measure the parallel speedup
/// (`benches/dse_arch.rs`).
///
/// [`par_map_workers`]: crate::util::parallel::par_map_workers
pub fn explore_with_engine_workers(
    engine: &BatchEngine,
    grid: &[GhostConfig],
    workloads: &[(ModelKind, Dataset)],
    workers: usize,
) -> DseReport {
    let _span = crate::util::telemetry::span("dse.explore");
    // Pre-warm the partition cache: one parallel build per distinct shape.
    let mut shapes: Vec<(usize, usize)> = grid.iter().map(|c| (c.v, c.n)).collect();
    shapes.sort_unstable();
    shapes.dedup();
    crate::util::parallel::par_map_workers(&shapes, workers, |&(v, n)| {
        for (_, ds) in workloads {
            // Invalid shapes surface again per-point in the sweep below.
            let _ = engine.partitions_for(ds, v, n);
        }
    });
    if delta_evaluation_enabled() {
        let (raw, delta) = delta_sweep(engine, grid, workloads, workers);
        let mut report = sift_points(raw);
        report.delta = delta;
        report
    } else {
        let raw = crate::util::parallel::par_map_workers(grid, workers, |&cfg| {
            (cfg, evaluate_with_engine(engine, cfg, workloads))
        });
        sift_points(raw)
    }
}

/// Whether sweeps delta-evaluate (the default). `GHOST_DSE_DELTA=0` /
/// `off` / `false` forces the full-rebuild path — the CI smoke diffs the
/// two frontiers.
pub fn delta_evaluation_enabled() -> bool {
    !matches!(
        std::env::var("GHOST_DSE_DELTA").as_deref(),
        Ok("0") | Ok("off") | Ok("false")
    )
}

/// Whether every delta-evaluated point is re-derived through the retained
/// reference oracle ([`plan::reference_evaluate`] over a fresh
/// [`plan::build`]) and `assert_eq!`d on the full `SimReport`. Always on
/// under `debug_assertions` (so `cargo test` pins bit-identity
/// everywhere); `GHOST_DSE_CHECK=1` / `on` / `true` forces it in release.
fn delta_check_enabled() -> bool {
    cfg!(debug_assertions)
        || matches!(
            std::env::var("GHOST_DSE_CHECK").as_deref(),
            Ok("1") | Ok("on") | Ok("true")
        )
}

/// Mixed-radix reflected-Gray visiting order over the grid, as indices
/// into `grid`.
///
/// Digits are `[chip_mem, n, v, r_r, r_c, t_r]`, outermost (least
/// frequently changing) first — so the structural axes that force a plan
/// rebuild (`n`, `v`, memory) change between only a handful of adjacent
/// visits, while the cheap patchable axes (`r_r`, `r_c`, `t_r`) absorb
/// almost every transition. Reflection makes each digit sweep
/// back-and-forth instead of wrapping around, so consecutive points in a
/// full lattice differ in exactly one digit; validity holes in the grid
/// can merge a few transitions but never reorder the blocks. Points with
/// equal codes (duplicates) keep their grid order.
pub fn gray_order(grid: &[GhostConfig]) -> Vec<usize> {
    fn uniq(mut v: Vec<u64>) -> Vec<u64> {
        v.sort_unstable();
        v.dedup();
        v
    }
    fn digits_of(cfg: &GhostConfig) -> [u64; 6] {
        [
            cfg.chip_mem_bytes,
            cfg.n as u64,
            cfg.v as u64,
            cfg.r_r as u64,
            cfg.r_c as u64,
            cfg.t_r as u64,
        ]
    }
    /// Position of a mixed-radix digit string in reflected-Gray visiting
    /// order: the first digit picks a block of `∏ radices[1..]` codes, and
    /// odd blocks are traversed in reverse so the boundary between blocks
    /// is a single-digit step.
    fn gray_pos(digits: &[usize], radices: &[usize]) -> usize {
        if digits.is_empty() {
            return 0;
        }
        let block: usize = radices[1..].iter().product();
        let sub = gray_pos(&digits[1..], &radices[1..]);
        digits[0] * block + if digits[0] % 2 == 0 { sub } else { block - 1 - sub }
    }
    let mut axes: [Vec<u64>; 6] = Default::default();
    for (a, axis) in axes.iter_mut().enumerate() {
        *axis = uniq(grid.iter().map(|c| digits_of(c)[a]).collect());
    }
    let radices: Vec<usize> = axes.iter().map(|a| a.len()).collect();
    let mut keyed: Vec<(usize, usize)> = grid
        .iter()
        .enumerate()
        .map(|(i, cfg)| {
            let vals = digits_of(cfg);
            let digits: Vec<usize> = vals
                .iter()
                .zip(&axes)
                .map(|(v, axis)| {
                    axis.binary_search(v).expect("axis values were collected from the grid")
                })
                .collect();
            (gray_pos(&digits, &radices), i)
        })
        .collect();
    keyed.sort_unstable();
    keyed.into_iter().map(|(_, i)| i).collect()
}

/// The delta sweep: every workload runs one [`DeltaPlan`] chain over the
/// Gray-ordered grid (chains are independent, so they fan out over the
/// worker pool and the merged result is worker-count invariant), then
/// per-point results are reassembled in grid order with exactly the
/// full-rebuild path's semantics — invalid configs fail with
/// `InvalidConfig`, a failing workload reports the error of the *first*
/// failing workload in workload order, and surviving points geomean the
/// same per-workload metric values (bit-identical reports → bit-identical
/// geomeans).
fn delta_sweep(
    engine: &BatchEngine,
    grid: &[GhostConfig],
    workloads: &[(ModelKind, Dataset)],
    workers: usize,
) -> (Vec<(GhostConfig, Result<ArchDsePoint, SimError>)>, DeltaStats) {
    let flags = OptFlags::ghost_default();
    let check = delta_check_enabled();
    let order = gray_order(grid);
    let wl_idx: Vec<usize> = (0..workloads.len()).collect();
    type Slot = Option<Result<(f64, f64, f64), SimError>>;
    let chains: Vec<(Vec<Slot>, DeltaStats)> =
        crate::util::parallel::par_map_workers(&wl_idx, workers, |&wi| {
            // One Gray-order chain per workload; the span lands on the
            // worker's own trace track, nested over the per-point
            // delta.patch / delta.rebuild spans.
            let _span = crate::util::telemetry::span("dse.chain");
            let (kind, ds) = &workloads[wi];
            let mut dp = DeltaPlan::new(*kind, ds, flags, 1);
            let mut slots: Vec<Slot> = vec![None; grid.len()];
            for &gi in &order {
                let cfg = grid[gi];
                if cfg.validate().is_err() {
                    // Never retarget onto an invalid config: the assembly
                    // below reports it as an `InvalidConfig` failure, same
                    // as `evaluate_with_engine`'s up-front validation.
                    continue;
                }
                // Partition-cache errors propagate unwrapped and
                // build/evaluate errors carry the workload identity —
                // mirroring `evaluate_with_engine` exactly.
                let res = match engine.partitions_for(ds, cfg.v, cfg.n) {
                    Err(e) => Err(e),
                    Ok(pms) => {
                        let r = dp.retarget(cfg, &pms).and_then(|_| dp.evaluate());
                        if check {
                            if let Ok(report) = &r {
                                let fresh = plan::build(*kind, ds, &pms, cfg, flags)
                                    .and_then(|p| plan::reference_evaluate(&p))
                                    .expect(
                                        "delta path evaluated a config the reference \
                                         oracle rejects",
                                    );
                                assert_eq!(
                                    report, &fresh,
                                    "delta evaluation diverged from the reference \
                                     oracle at {cfg:?}"
                                );
                            }
                        }
                        r.map_err(|e| e.in_workload(*kind, ds.spec.name))
                    }
                };
                slots[gi] = Some(res.map(|r| {
                    (r.metrics.epb_per_gops(), r.metrics.gops(), r.metrics.epb())
                }));
            }
            (slots, DeltaStats { rebuilds: dp.rebuilds(), patches: dp.patches() })
        });

    let mut stats = DeltaStats::default();
    for (_, s) in &chains {
        stats.rebuilds += s.rebuilds;
        stats.patches += s.patches;
    }
    let mut raw = Vec::with_capacity(grid.len());
    for (gi, cfg) in grid.iter().enumerate() {
        if let Err(e) = cfg.validate() {
            raw.push((*cfg, Err(SimError::InvalidConfig(e))));
            continue;
        }
        let mut epb_gops = Vec::with_capacity(workloads.len());
        let mut gops = Vec::with_capacity(workloads.len());
        let mut epb = Vec::with_capacity(workloads.len());
        let mut first_err = None;
        for (slots, _) in &chains {
            match slots[gi].as_ref().expect("every valid point is visited by each chain") {
                Err(e) => {
                    first_err = Some(e.clone());
                    break;
                }
                Ok((a, b, c)) => {
                    epb_gops.push(*a);
                    gops.push(*b);
                    epb.push(*c);
                }
            }
        }
        let res = match first_err {
            Some(e) => Err(e),
            None => Ok(ArchDsePoint {
                cfg: *cfg,
                epb_per_gops: geomean(epb_gops),
                gops: geomean(gops),
                epb: geomean(epb),
            }),
        };
        raw.push((*cfg, res));
    }
    (raw, stats)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_contains_paper_point_and_respects_device_limits() {
        let grid = default_grid();
        assert!(grid.contains(&GhostConfig::paper_optimal()));
        for cfg in &grid {
            cfg.validate().unwrap();
        }
        assert!(grid.len() > 100, "grid too small: {}", grid.len());
    }

    #[test]
    fn paper_point_is_near_optimal() {
        // Small sweep around the paper point: it must rank in the top
        // quartile of its neighborhood on EPB/GOPS.
        let workloads = workload_set(true).unwrap();
        let paper = GhostConfig::paper_optimal();
        let mut neighborhood = vec![paper];
        for (dn, dv) in [(-10i64, 0i64), (10, 0), (0, -10), (0, 10)] {
            let cfg = GhostConfig {
                n: (paper.n as i64 + dn).max(5) as usize,
                v: (paper.v as i64 + dv).max(5) as usize,
                ..paper
            };
            if cfg.validate().is_ok() {
                neighborhood.push(cfg);
            }
        }
        let report = explore(&neighborhood, &workloads);
        assert!(report.failures.is_empty(), "failures: {:?}", report.failures);
        let pts = &report.points;
        let rank = pts.iter().position(|p| p.cfg == paper).unwrap();
        assert!(rank <= pts.len() / 2, "paper point ranked {rank} of {}", pts.len());
    }

    #[test]
    fn evaluate_produces_finite_metrics() {
        let workloads = workload_set(true).unwrap();
        let p = evaluate(GhostConfig::paper_optimal(), &workloads).unwrap();
        assert!(p.epb_per_gops.is_finite() && p.epb_per_gops > 0.0);
        assert!(p.gops.is_finite() && p.gops > 0.0);
    }

    #[test]
    fn engine_evaluation_matches_uncached_reference() {
        let workloads = workload_set(true).unwrap();
        let cfg = GhostConfig::paper_optimal();
        let engine = BatchEngine::new();
        let cached = evaluate_with_engine(&engine, cfg, &workloads).unwrap();
        let uncached = evaluate(cfg, &workloads).unwrap();
        assert_eq!(cached.epb_per_gops, uncached.epb_per_gops);
        assert_eq!(cached.gops, uncached.gops);
        assert_eq!(cached.epb, uncached.epb);
    }

    #[test]
    fn sift_filters_non_finite_points_and_sorts_with_total_cmp() {
        let cfg = GhostConfig::paper_optimal();
        let pt = |x: f64| ArchDsePoint { cfg, epb_per_gops: x, gops: 1.0, epb: 1.0 };
        let raw = vec![
            (cfg, Ok(pt(2.0))),
            (cfg, Ok(pt(f64::NAN))),
            (cfg, Ok(pt(1.0))),
            (cfg, Ok(pt(f64::INFINITY))),
            (cfg, Err(SimError::UnknownDataset("nope".into()))),
        ];
        let report = sift_points(raw);
        assert_eq!(report.points.len(), 2);
        assert_eq!(report.points[0].epb_per_gops, 1.0);
        assert_eq!(report.points[1].epb_per_gops, 2.0);
        assert_eq!(report.failures.len(), 3);
        assert!(report
            .failures
            .iter()
            .any(|f| matches!(f.error, SimError::NonFiniteMetric { .. })));
        assert!(report
            .failures
            .iter()
            .any(|f| matches!(f.error, SimError::UnknownDataset(_))));
        assert_eq!(report.best().unwrap().epb_per_gops, 1.0);
    }

    #[test]
    fn infeasible_grid_point_becomes_failure_not_abort() {
        let workloads = workload_set(true).unwrap();
        let good = GhostConfig::paper_optimal();
        let bad = GhostConfig { r_c: 25, ..good }; // > 20 coherent MRs
        let report = explore_with_engine(&BatchEngine::new(), &[good, bad], &workloads);
        assert_eq!(report.points.len(), 1);
        assert_eq!(report.failures.len(), 1);
        assert_eq!(report.failures[0].cfg, bad);
        assert!(matches!(report.failures[0].error, SimError::InvalidConfig(_)));
    }

    #[test]
    fn explore_report_invariant_under_worker_count() {
        // The sweep fans out over util::parallel::par_map_workers; the
        // resulting DseReport (points, order, exact metric values, and
        // failures) must be identical for any worker count.
        let workloads = workload_set(true).unwrap();
        let paper = GhostConfig::paper_optimal();
        let grid = vec![
            paper,
            GhostConfig { n: 10, ..paper },
            GhostConfig { v: 10, ..paper },
            GhostConfig { t_r: 11, ..paper },
            GhostConfig { r_c: 25, ..paper }, // infeasible → failure entry
        ];
        let reference =
            explore_with_engine_workers(&BatchEngine::new(), &grid, &workloads, 1);
        assert_eq!(reference.points.len(), 4);
        assert_eq!(reference.failures.len(), 1);
        for workers in [2usize, 4, 16] {
            let report =
                explore_with_engine_workers(&BatchEngine::new(), &grid, &workloads, workers);
            assert_eq!(report.points.len(), reference.points.len(), "workers={workers}");
            for (a, b) in report.points.iter().zip(&reference.points) {
                assert_eq!(a.cfg, b.cfg, "workers={workers}");
                // Bit-identical, not approximately equal: the evaluation
                // per point is single-threaded and pure.
                assert_eq!(a.epb_per_gops, b.epb_per_gops, "workers={workers}");
                assert_eq!(a.gops, b.gops, "workers={workers}");
                assert_eq!(a.epb, b.epb, "workers={workers}");
            }
            assert_eq!(report.failures.len(), reference.failures.len());
            for (a, b) in report.failures.iter().zip(&reference.failures) {
                assert_eq!(a.cfg, b.cfg, "workers={workers}");
            }
        }
    }

    #[test]
    fn workload_sets_cover_the_paper_matrix() {
        assert_eq!(workload_names(true).len(), 4);
        assert_eq!(workload_names(false).len(), 16);
        assert_eq!(workload_set(false).unwrap().len(), 16);
    }

    #[test]
    fn gray_order_is_a_permutation_with_minimal_structural_churn() {
        use crate::coordinator::soa::ParamSet;
        let grid = default_grid();
        let order = gray_order(&grid);
        // A permutation of the grid indices.
        let mut seen = order.clone();
        seen.sort_unstable();
        assert_eq!(seen, (0..grid.len()).collect::<Vec<_>>());
        // Structural (n / v / mem) transitions happen exactly at the
        // boundaries between (mem, n, v) blocks: with every point of a
        // block contiguous in Gray order, that is #blocks − 1 transitions
        // no matter how many validity holes the inner axes have.
        let mut blocks: Vec<(u64, usize, usize)> =
            grid.iter().map(|c| (c.chip_mem_bytes, c.n, c.v)).collect();
        blocks.sort_unstable();
        blocks.dedup();
        let structural = order
            .windows(2)
            .filter(|w| {
                ParamSet::diff(&grid[w[0]], &grid[w[1]]).intersects(ParamSet::STRUCTURAL)
            })
            .count();
        assert_eq!(structural, blocks.len() - 1, "grid of {} points", grid.len());
        // And almost every transition is patchable: far fewer rebuild
        // boundaries than points.
        assert!(structural * 10 < grid.len());
    }

    #[test]
    fn delta_sweep_is_bit_identical_to_full_rebuild_sweep() {
        // The acceptance gate in miniature: the same grid (including an
        // infeasible point) swept via the delta chains and via per-point
        // full rebuilds must produce the identical raw results — every
        // metric bit, every failure — before sift_points ever runs.
        let workloads = workload_set(true).unwrap();
        let paper = GhostConfig::paper_optimal();
        let grid = vec![
            paper,
            GhostConfig { t_r: 11, ..paper },
            GhostConfig { r_c: 14, ..paper },
            GhostConfig { r_r: 12, r_c: 14, ..paper },
            GhostConfig { v: 10, ..paper },
            GhostConfig { v: 10, t_r: 11, ..paper },
            GhostConfig { n: 10, r_c: 25, ..paper }, // infeasible → failure
        ];
        let engine = BatchEngine::new();
        let (raw_delta, stats) = delta_sweep(&engine, &grid, &workloads, 2);
        let raw_full: Vec<(GhostConfig, Result<ArchDsePoint, SimError>)> = grid
            .iter()
            .map(|&cfg| (cfg, evaluate_with_engine(&engine, cfg, &workloads)))
            .collect();
        assert_eq!(raw_delta.len(), raw_full.len());
        for ((ca, ra), (cb, rb)) in raw_delta.iter().zip(&raw_full) {
            assert_eq!(ca, cb);
            match (ra, rb) {
                (Ok(a), Ok(b)) => {
                    assert_eq!(a.cfg, b.cfg);
                    assert_eq!(a.epb_per_gops, b.epb_per_gops, "{ca:?}");
                    assert_eq!(a.gops, b.gops, "{ca:?}");
                    assert_eq!(a.epb, b.epb, "{ca:?}");
                }
                (Err(ea), Err(eb)) => {
                    assert!(
                        matches!(ea, SimError::InvalidConfig(_))
                            && matches!(eb, SimError::InvalidConfig(_)),
                        "mismatched failures at {ca:?}: {ea:?} vs {eb:?}"
                    );
                }
                other => panic!("delta/full outcome mismatch at {ca:?}: {other:?}"),
            }
        }
        // Six valid points × four workloads: each chain rebuilds for its
        // first point and for the v-change boundary, patches the rest.
        assert_eq!(stats.rebuilds + stats.patches, 6 * workloads.len());
        assert!(stats.rebuilds >= workloads.len());
        assert!(stats.patches > stats.rebuilds, "stats: {stats:?}");
    }

    #[test]
    fn explore_reports_delta_counters() {
        let workloads = workload_set(true).unwrap();
        let paper = GhostConfig::paper_optimal();
        let grid = vec![
            paper,
            GhostConfig { t_r: 11, ..paper },
            GhostConfig { r_c: 14, ..paper },
        ];
        let report = explore_with_engine(&BatchEngine::new(), &grid, &workloads);
        assert_eq!(report.points.len(), 3);
        if delta_evaluation_enabled() {
            assert_eq!(
                report.delta.rebuilds + report.delta.patches,
                grid.len() * workloads.len()
            );
            assert!(report.delta.patches > 0);
        } else {
            assert_eq!(report.delta, DeltaStats::default());
        }
    }
}
