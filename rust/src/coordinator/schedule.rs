//! The GHOST simulator entry points: map a `(model, dataset, config,
//! optimization flags)` tuple onto a typed [`StagePlan`]
//! ([`crate::coordinator::plan::build`]) and evaluate it
//! ([`crate::coordinator::plan::evaluate`]).
//!
//! [`StagePlan`]: crate::coordinator::plan::StagePlan
//!
//! Execution orderings (§3.4.2 / Fig. 6):
//! * GCN / GraphSAGE / GIN — gather → reduce → transform → update per
//!   output-vertex group, groups pipelined against each other;
//! * GAT — gather → transform(+attention) → update(LeakyReLU+softmax) →
//!   reduce, same two-level pipelining.
//!
//! Multi-graph datasets are scheduled layer-major (all graphs through layer
//! `l`, then layer `l+1`) so each weight matrix is staged and the banks
//! TO-retargeted once per layer per dataset, not once per graph.
//!
//! The pre-IR single-pass simulator is retained as a test-only reference
//! (`mod legacy` below); a property test pins the plan-based pipeline
//! bit-identical to it across every Table-2 dataset × model × flag
//! combination.

use crate::config::GhostConfig;
use crate::energy::Metrics;
use crate::gnn::models::ModelKind;
use crate::graph::datasets::Dataset;
use crate::graph::partition::PartitionMatrix;

use super::error::SimError;
use super::optimizations::OptFlags;
use super::plan::{self, KindTotals};

pub use super::plan::TO_RETUNE_FRACTION;

/// Full simulation result for one `(model, dataset)` workload. Every field
/// is a query over the evaluated [`StagePlan`]
/// ([`crate::coordinator::plan::evaluate`]), not a hand-threaded
/// accumulator.
///
/// [`StagePlan`]: crate::coordinator::plan::StagePlan
#[derive(Debug, Clone, PartialEq)]
pub struct SimReport {
    pub model: ModelKind,
    pub dataset: String,
    pub config: GhostConfig,
    pub flags: OptFlags,
    pub metrics: Metrics,
    /// Busy time of the aggregate block (gather + reduce stages), seconds.
    pub aggregate_s: f64,
    /// Busy time of the combine block (transform stages), seconds.
    pub combine_s: f64,
    /// Busy time of the update block, seconds.
    pub update_s: f64,
    /// Busy time of the graph-classification readout (sum-pool on the
    /// reduce arrays), seconds; also included in `aggregate_s`. Zero for
    /// models without a readout.
    pub readout_s: f64,
    /// Latency spent staging weights and TO-retargeting the MR banks —
    /// once per layer per dataset, independent of graph count or batch
    /// size. This is the share of `metrics.latency_s` that an online
    /// server amortizes across a same-model batch (the weights are already
    /// programmed for every request after the first), so the serving
    /// simulator's per-request service time is
    /// `latency_s - weight_stage_s` (see [`crate::serve`]).
    pub weight_stage_s: f64,
    /// Dynamic energy of the weight staging + TO retargeting above, joules
    /// — the amortizable share of the *energy* bill, mirroring
    /// `weight_stage_s` for latency. (The static platform share of a
    /// weight stage is `platform_w · weight_stage_s`.)
    pub weight_stage_energy_j: f64,
    /// Number of post-layer-0 gather stages — one per `(layer, graph)`
    /// pair with an aggregation — whose input feature map did not fit the
    /// on-chip input-vertex buffer and spilled to DRAM. Residency is
    /// per graph (the layer-major schedule buffers one graph at a time),
    /// so multi-graph datasets of small graphs report 0 here.
    pub spilled_layer_gathers: usize,
    /// Always-on platform power for this configuration, watts.
    pub platform_w: f64,
    /// Exact per-[`crate::coordinator::plan::StageKind`] busy-time and
    /// dynamic-energy totals — readout and weight staging as first-class
    /// entries instead of being folded into the block split above.
    pub kinds: KindTotals,
}

impl SimReport {
    /// Fractional latency breakdown `(aggregate, combine, update)` — the
    /// Fig. 9 bars.
    pub fn breakdown(&self) -> (f64, f64, f64) {
        let total = self.aggregate_s + self.combine_s + self.update_s;
        if total == 0.0 {
            return (0.0, 0.0, 0.0);
        }
        (self.aggregate_s / total, self.combine_s / total, self.update_s / total)
    }
}

/// Simulate a model over a named Table-2 dataset.
pub fn simulate(
    kind: ModelKind,
    dataset_name: &str,
    cfg: GhostConfig,
    flags: OptFlags,
) -> Result<SimReport, SimError> {
    let dataset = Dataset::by_name(dataset_name)
        .ok_or_else(|| SimError::UnknownDataset(dataset_name.to_string()))?;
    simulate_workload(kind, &dataset, cfg, flags)
}

/// Simulate a model over an already-realized dataset. Partitions every
/// graph with the configuration's `(V, N)` first — use
/// [`simulate_with_partitions`] to amortize that offline preprocessing
/// across multiple simulations (the Fig. 8 sensitivity sweep and the
/// Fig. 7(c) DSE reuse partitions this way).
pub fn simulate_workload(
    kind: ModelKind,
    dataset: &Dataset,
    cfg: GhostConfig,
    flags: OptFlags,
) -> Result<SimReport, SimError> {
    // Validate before partitioning: a zero-dimension config must come back
    // as an error, not trip the partition builder's assert.
    cfg.validate().map_err(SimError::InvalidConfig)?;
    let partitions = PartitionMatrix::build_all(&dataset.graphs, cfg.v, cfg.n);
    simulate_with_partitions(kind, dataset, &partitions, cfg, flags)
}

/// Simulate with pre-built partition matrices (offline preprocessing per
/// the paper; `partitions[i]` must be the `(cfg.v, cfg.n)` partition of
/// `dataset.graphs[i]`). Builds a one-shot [`StagePlan`] and evaluates it;
/// callers that revisit the same `(model, dataset, config, flags)` tuple
/// should go through [`crate::coordinator::engine::BatchEngine`], whose
/// plan cache skips construction on every hit.
///
/// [`StagePlan`]: crate::coordinator::plan::StagePlan
pub fn simulate_with_partitions(
    kind: ModelKind,
    dataset: &Dataset,
    partitions: &[PartitionMatrix],
    cfg: GhostConfig,
    flags: OptFlags,
) -> Result<SimReport, SimError> {
    let p = plan::build(kind, dataset, partitions, cfg, flags)?;
    plan::evaluate(&p)
}

/// The pre-IR reference simulator, kept **temporarily, test-only** as the
/// bit-identity oracle for the plan-based pipeline. This is the literal
/// single-pass implementation that `simulate_with_partitions` used to be
/// (hand-threaded accumulators, anonymous latency rows); the property test
/// below pins `plan::build` + `plan::evaluate` to reproduce its every
/// output field bit-for-bit. Delete once the IR has soaked.
#[cfg(test)]
mod legacy {
    use crate::arch::{aggregate, combine, ecu, update, ArchContext, StageCost};
    use crate::config::{ceil_div, GhostConfig};
    use crate::gnn::models::{Activation, ExecOrdering, LayerSpec, Model, ModelKind};
    use crate::graph::datasets::Dataset;
    use crate::graph::partition::{OutputGroupPlan, PartitionMatrix};
    use crate::sim;

    use super::super::error::SimError;
    use super::super::optimizations::OptFlags;
    use super::TO_RETUNE_FRACTION;

    /// The fields the pre-IR simulator produced (a `SimReport` without the
    /// per-kind totals, which did not exist yet).
    #[derive(Debug, Clone)]
    pub struct LegacyReport {
        pub latency_s: f64,
        pub energy_j: f64,
        pub aggregate_s: f64,
        pub combine_s: f64,
        pub update_s: f64,
        pub readout_s: f64,
        pub weight_stage_s: f64,
        pub weight_stage_energy_j: f64,
        pub spilled_layer_gathers: usize,
        pub platform_w: f64,
    }

    pub fn simulate_with_partitions(
        kind: ModelKind,
        dataset: &Dataset,
        partitions: &[PartitionMatrix],
        cfg: GhostConfig,
        flags: OptFlags,
    ) -> Result<LegacyReport, SimError> {
        cfg.validate().map_err(SimError::InvalidConfig)?;
        flags.validate().map_err(SimError::InvalidFlags)?;
        if partitions.len() != dataset.graphs.len() {
            return Err(SimError::PartitionCountMismatch {
                expected: dataset.graphs.len(),
                got: partitions.len(),
            });
        }
        if let Some(pm) = partitions.iter().find(|p| p.v != cfg.v || p.n != cfg.n) {
            return Err(SimError::PartitionShapeMismatch {
                expected: (cfg.v, cfg.n),
                got: (pm.v, pm.n),
            });
        }
        let ctx = ArchContext::paper(cfg);
        let model = Model::for_dataset(kind, &dataset.spec);

        let mut latency = 0.0f64;
        let mut dynamic_energy = 0.0f64;
        let mut aggregate_s = 0.0f64;
        let mut combine_s = 0.0f64;
        let mut update_s = 0.0f64;
        let mut readout_s = 0.0f64;
        let mut weight_stage_s = 0.0f64;
        let mut weight_stage_energy_j = 0.0f64;
        let mut spilled_layer_gathers = 0usize;

        // Edge/partition descriptors stream in once per graph.
        for g in &dataset.graphs {
            let ec = ecu::edge_stage_cost(&ctx, g.n_edges() as u64 * 8);
            latency += ec.latency_s;
            dynamic_energy += ec.energy_j;
        }

        for (li, layer) in model.layers.iter().enumerate() {
            // Stage the layer's weights + TO-retarget the banks (once per
            // layer per dataset; graphs are scheduled layer-major).
            let wc = ecu::weight_stage_cost(
                &ctx,
                (layer.in_dim * layer.out_dim * layer.heads) as u64,
            );
            let stage_s = wc.latency_s.max(ctx.dev.to_tuning.latency_s);
            let stage_energy = wc.energy_j + to_retune_energy(&ctx);
            latency += stage_s;
            weight_stage_s += stage_s;
            weight_stage_energy_j += stage_energy;
            dynamic_energy += stage_energy;

            for pm in partitions {
                let feat_bytes = pm.n_vertices * layer.in_dim;
                let from_dram =
                    li == 0 || feat_bytes > ctx.buffers.input_vertices.size_bytes;
                if li > 0 && from_dram && layer.reduction.is_some() {
                    spilled_layer_gathers += 1;
                }
                let mut group_stages: Vec<sim::GroupStages> =
                    Vec::with_capacity(pm.groups.len());
                for grp in &pm.groups {
                    let (stages, block_split) =
                        layer_group_stages(&ctx, &model, layer, grp, flags, from_dram);
                    dynamic_energy += stages.iter().map(|s| s.energy_j).sum::<f64>();
                    aggregate_s += block_split.0;
                    combine_s += block_split.1;
                    update_s += block_split.2;
                    group_stages.push(stages.iter().map(|s| s.latency_s).collect());
                }
                let sched = if flags.pipelining {
                    sim::pipelined(&group_stages)?
                } else {
                    sim::sequential(&group_stages)
                };
                latency += sched.makespan_s;
            }
        }

        // Graph-classification readout (final embedding width).
        if model.has_readout {
            let width = model.layers.last().map(|l| l.out_dim * l.heads).unwrap_or(0);
            for g in &dataset.graphs {
                let passes =
                    ceil_div(g.n_vertices, cfg.v * cfg.r_c) * ceil_div(width, cfg.r_r);
                let cost = StageCost {
                    latency_s: passes as f64 * ctx.symbol_s(),
                    energy_j: (g.n_vertices * width) as f64 * ctx.dev.dac.energy_j(),
                };
                latency += cost.latency_s;
                dynamic_energy += cost.energy_j;
                aggregate_s += cost.latency_s;
                readout_s += cost.latency_s;
            }
        }

        let platform_w = crate::arch::platform_power_w(&ctx, flags.dac_sharing);
        let energy = dynamic_energy + platform_w * latency;
        Ok(LegacyReport {
            latency_s: latency,
            energy_j: energy,
            aggregate_s,
            combine_s,
            update_s,
            readout_s,
            weight_stage_s,
            weight_stage_energy_j,
            spilled_layer_gathers,
            platform_w,
        })
    }

    fn to_retune_energy(ctx: &ArchContext) -> f64 {
        let cfg = &ctx.cfg;
        let n_mrs = cfg.aggregate_mrs() + cfg.combine_mrs();
        n_mrs as f64
            * TO_RETUNE_FRACTION
            * ctx.dev.to_tuning.power_w
            * 0.25 // quarter-FSR average shift
            * ctx.dev.to_tuning.latency_s
    }

    fn layer_group_stages(
        ctx: &ArchContext,
        model: &Model,
        layer: &LayerSpec,
        grp: &OutputGroupPlan,
        flags: OptFlags,
        from_dram: bool,
    ) -> (Vec<StageCost>, (f64, f64, f64)) {
        let out_width = layer.out_dim * layer.heads;
        let grp_eff = effective_group(grp, layer.neighbor_sample, ctx.cfg.v);

        match (layer.reduction, model.ordering) {
            (None, _) => {
                let t = combine::transform_cost(
                    ctx,
                    layer.in_dim,
                    out_width,
                    flags.dac_sharing,
                    false,
                );
                let u = update::update_cost(ctx, layer.activation, out_width, 0)
                    .then(update::writeback_cost(ctx, out_width));
                let split = (0.0, t.latency_s, u.latency_s);
                (vec![StageCost::ZERO, StageCost::ZERO, t, u], split)
            }
            (Some(red), ExecOrdering::AggregateFirst) => {
                let g = gather_stage(
                    ctx,
                    &grp_eff,
                    layer.in_dim,
                    flags.buffer_partition,
                    from_dram,
                );
                let r = aggregate::reduce_cost(
                    ctx,
                    &grp_eff,
                    layer.in_dim,
                    red,
                    flags.workload_balancing,
                );
                let t = combine::transform_cost(
                    ctx,
                    layer.in_dim,
                    out_width,
                    flags.dac_sharing,
                    true,
                );
                let u = update::update_cost(ctx, layer.activation, out_width, 0)
                    .then(update::writeback_cost(ctx, out_width));
                let split = (g.latency_s + r.latency_s, t.latency_s, u.latency_s);
                (vec![g, r, t, u], split)
            }
            (Some(red), ExecOrdering::TransformFirst) => {
                let g =
                    own_vertex_gather(ctx, layer.in_dim, flags.buffer_partition, from_dram);
                let mut t = combine::transform_cost(
                    ctx,
                    layer.in_dim,
                    out_width,
                    flags.dac_sharing,
                    false,
                );
                t = t.then(attention_cost(ctx, layer, &grp_eff));
                let softmax_elems = grp_eff.total_edges as usize * layer.heads;
                let u = update::update_cost(ctx, Activation::Softmax, out_width, softmax_elems)
                    .then(update::writeback_cost(ctx, out_width));
                let nbr_bytes = grp_eff.distinct_sources as usize * out_width;
                let fetch = StageCost {
                    latency_s: ctx.buffers.input_vertices.stream_latency_s(nbr_bytes),
                    energy_j: ctx.buffers.input_vertices.stream_energy_j(nbr_bytes),
                };
                let r = fetch.then(aggregate::reduce_cost(
                    ctx,
                    &grp_eff,
                    out_width,
                    red,
                    flags.workload_balancing,
                ));
                let split = (g.latency_s + r.latency_s, t.latency_s, u.latency_s);
                (vec![g, t, u, r], split)
            }
        }
    }

    fn effective_group(
        grp: &OutputGroupPlan,
        sample: Option<usize>,
        v: usize,
    ) -> OutputGroupPlan {
        match sample {
            None => *grp,
            Some(s) => {
                let max_deg = grp.max_lane_degree.min(s as u32);
                let total = grp.total_edges.min((v * s) as u32);
                OutputGroupPlan {
                    out_group: grp.out_group,
                    n_blocks: grp.n_blocks,
                    max_lane_degree: max_deg,
                    total_edges: total,
                    distinct_sources: grp.distinct_sources.min(total),
                }
            }
        }
    }

    fn gather_stage(
        ctx: &ArchContext,
        grp: &OutputGroupPlan,
        in_dim: usize,
        bp: bool,
        from_dram: bool,
    ) -> StageCost {
        if from_dram {
            aggregate::gather_cost(ctx, grp, in_dim, bp)
        } else {
            let buf = &ctx.buffers.input_vertices;
            if bp {
                let bytes = grp.distinct_sources as usize * in_dim;
                StageCost {
                    latency_s: buf.stream_latency_s(bytes),
                    energy_j: buf.stream_energy_j(bytes),
                }
            } else {
                let per = buf.access_latency_s * ceil_div(in_dim, 64).max(1) as f64;
                let bytes = grp.total_edges as usize * in_dim;
                StageCost {
                    latency_s: grp.max_lane_degree as f64 * per,
                    energy_j: buf.stream_energy_j(bytes),
                }
            }
        }
    }

    fn own_vertex_gather(
        ctx: &ArchContext,
        in_dim: usize,
        bp: bool,
        from_dram: bool,
    ) -> StageCost {
        let bytes = ctx.cfg.v * in_dim;
        if from_dram {
            let hbm = &ctx.hbm;
            if bp {
                StageCost {
                    latency_s: hbm.access_latency_s + bytes as f64 / hbm.sustained_bw(),
                    energy_j: hbm.transfer_energy_j(bytes as u64)
                        + ctx.buffers.input_vertices.stream_energy_j(bytes),
                }
            } else {
                StageCost {
                    latency_s: hbm.access_latency_s
                        + in_dim as f64 / (hbm.peak_bw_bytes_per_s * hbm.random_efficiency),
                    energy_j: hbm.transfer_energy_j(bytes as u64)
                        + hbm.burst_overhead_j * ctx.cfg.v as f64
                        + ctx.buffers.input_vertices.stream_energy_j(bytes),
                }
            }
        } else {
            StageCost {
                latency_s: ctx.buffers.input_vertices.stream_latency_s(bytes),
                energy_j: ctx.buffers.input_vertices.stream_energy_j(bytes),
            }
        }
    }

    fn attention_cost(ctx: &ArchContext, layer: &LayerSpec, grp: &OutputGroupPlan) -> StageCost {
        let cfg = &ctx.cfg;
        let per_lane_logits = grp.max_lane_degree as usize * layer.heads;
        let passes =
            ceil_div(per_lane_logits.max(1), cfg.t_r) * ceil_div(2 * layer.out_dim, cfg.r_r);
        let values = grp.total_edges as f64 * (2 * layer.out_dim * layer.heads) as f64;
        StageCost {
            latency_s: passes as f64 * ctx.symbol_s(),
            energy_j: values * ctx.dev.dac.energy_j(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ceil_div;
    use crate::graph::datasets::ALL_DATASETS;

    fn sim(kind: ModelKind, ds: &str, flags: OptFlags) -> SimReport {
        simulate(kind, ds, GhostConfig::paper_optimal(), flags).unwrap()
    }

    #[test]
    fn gcn_cora_runs_and_is_fast() {
        let r = sim(ModelKind::Gcn, "Cora", OptFlags::ghost_default());
        assert!(r.metrics.latency_s > 0.0 && r.metrics.latency_s < 1e-2,
            "latency = {}", r.metrics.latency_s);
        assert!(r.metrics.gops() > 100.0, "gops = {}", r.metrics.gops());
        assert!(r.metrics.power_w() > 10.0 && r.metrics.power_w() < 60.0,
            "power = {}", r.metrics.power_w());
    }

    #[test]
    fn optimizations_reduce_energy() {
        let base = sim(ModelKind::Gcn, "Cora", OptFlags::baseline());
        let opt = sim(ModelKind::Gcn, "Cora", OptFlags::ghost_default());
        let ratio = base.metrics.energy_j / opt.metrics.energy_j;
        assert!(ratio > 1.5, "energy ratio = {ratio}");
    }

    #[test]
    fn gcn_aggregate_dominates_on_big_graphs() {
        let r = sim(ModelKind::Gcn, "PubMed", OptFlags::ghost_default());
        let (agg, _, _) = r.breakdown();
        assert!(agg > 0.5, "aggregate share = {agg}");
    }

    #[test]
    fn gat_combine_update_dominate() {
        let r = sim(ModelKind::Gat, "Cora", OptFlags::ghost_default());
        let (agg, comb, upd) = r.breakdown();
        assert!(comb + upd > agg, "agg={agg} comb={comb} upd={upd}");
    }

    #[test]
    fn gin_combine_dominates() {
        let r = sim(ModelKind::Gin, "Proteins", OptFlags::ghost_default());
        let (agg, comb, _) = r.breakdown();
        assert!(comb > agg, "agg={agg} comb={comb}");
    }

    #[test]
    fn pipelining_reduces_latency() {
        let no_pp = OptFlags { pipelining: false, ..OptFlags::ghost_default() };
        let with_pp = OptFlags::ghost_default();
        let a = sim(ModelKind::Gcn, "Citeseer", no_pp);
        let b = sim(ModelKind::Gcn, "Citeseer", with_pp);
        assert!(b.metrics.latency_s < a.metrics.latency_s);
    }

    #[test]
    fn multi_graph_post_l0_gathers_stay_on_chip() {
        // Regression: the layer-spill test used to compare the *dataset-wide*
        // feature footprint (all 1113 Proteins graphs summed) against the
        // input-vertex buffer, spilling every post-layer-0 gather to DRAM
        // even though each ~39-vertex graph trivially fits on-chip.
        for ds in ["Proteins", "Mutag", "BZR", "IMDB-binary"] {
            let r = sim(ModelKind::Gin, ds, OptFlags::ghost_default());
            assert_eq!(
                r.spilled_layer_gathers, 0,
                "{ds}: small per-graph feature maps must stay resident"
            );
        }
    }

    #[test]
    fn single_graph_spills_still_detected_per_graph() {
        // PubMed layer 1: 19717 vertices × 16 features ≈ 308 KB > the
        // 128 KB input-vertex buffer — a legitimate spill that the
        // per-graph residency test must keep reporting.
        let r = sim(ModelKind::Gcn, "PubMed", OptFlags::ghost_default());
        assert_eq!(r.spilled_layer_gathers, 1);
        // Cora layer 1: 2708 × 16 ≈ 42 KB fits.
        let r = sim(ModelKind::Gcn, "Cora", OptFlags::ghost_default());
        assert_eq!(r.spilled_layer_gathers, 0);
    }

    #[test]
    fn readout_cost_pools_final_embedding_width() {
        // Regression: the readout used to pool `layers.last().in_dim` (the
        // GIN classifier's 64-wide *input*) instead of the final embedding
        // width `out_dim × heads` (= n_labels = 2 for Mutag), overcounting
        // the sum-pool passes 4× at R_r = 18. Hand-computed expectation:
        // ceil(n_g / (V·R_c)) · ceil(width / R_r) passes per graph, one
        // symbol period each.
        let cfg = GhostConfig::paper_optimal();
        let ds = Dataset::by_name("Mutag").unwrap();
        let r = simulate_workload(ModelKind::Gin, &ds, cfg, OptFlags::ghost_default())
            .unwrap();
        let width = 2usize; // Mutag has 2 labels; last GIN layer is 2 wide.
        let symbol_s = 1.0 / crate::config::SYMBOL_RATE_HZ;
        let expected: f64 = ds
            .graphs
            .iter()
            .map(|g| {
                (ceil_div(g.n_vertices, cfg.v * cfg.r_c) * ceil_div(width, cfg.r_r)) as f64
                    * symbol_s
            })
            .sum();
        assert!(
            (r.readout_s - expected).abs() < 1e-15,
            "readout_s = {}, expected {expected}",
            r.readout_s
        );
        assert!(r.readout_s > 0.0);
    }

    #[test]
    fn weight_stage_share_is_positive_and_within_latency() {
        // The weight-programming share must be a real, strictly positive
        // slice of the end-to-end latency (every model stages at least one
        // weight matrix) and must never exceed it — the serving simulator
        // subtracts it to get the per-request service time.
        for kind in ModelKind::ALL {
            let r = sim(kind, kind.datasets()[0], OptFlags::ghost_default());
            assert!(r.weight_stage_s > 0.0, "{:?}", kind);
            assert!(
                r.weight_stage_s < r.metrics.latency_s,
                "{:?}: weight_stage_s {} >= latency {}",
                kind,
                r.weight_stage_s,
                r.metrics.latency_s
            );
        }
    }

    #[test]
    fn weight_stage_independent_of_graph_count() {
        // Weights are staged once per layer per *dataset* (layer-major
        // schedule), so the share depends on the model's layer stack, not
        // on how many graphs the dataset carries.
        let a = sim(ModelKind::Gin, "Mutag", OptFlags::ghost_default());
        let b = sim(ModelKind::Gin, "BZR", OptFlags::ghost_default());
        // Same hidden widths; only in_dim/out_dim of the edge layers vary
        // with the dataset, so the shares are the same order of magnitude
        // even though BZR has over twice Mutag's graphs.
        assert!(a.weight_stage_s > 0.0 && b.weight_stage_s > 0.0);
        assert!(b.weight_stage_s < a.weight_stage_s * 50.0);
    }

    #[test]
    fn all_sixteen_workloads_simulate() {
        for kind in ModelKind::ALL {
            for ds in kind.datasets() {
                let r = sim(kind, ds, OptFlags::ghost_default());
                assert!(r.metrics.latency_s > 0.0, "{:?}/{ds}", kind);
                assert!(r.metrics.energy_j > 0.0);
                assert!(r.metrics.ops > 0);
            }
        }
    }

    /// The refactor-safety pin: the plan-based pipeline must reproduce the
    /// retained pre-IR reference **bit-identically** — every report field,
    /// not approximately — across all 8 Table-2 datasets × all 4 models ×
    /// every Fig. 8 optimization-flag combination. Partitions are built
    /// once per dataset and shared by both paths.
    #[test]
    fn plan_pipeline_bit_identical_to_legacy_reference() {
        let cfg = GhostConfig::paper_optimal();
        let presets = OptFlags::fig8_presets();
        for spec in ALL_DATASETS.iter() {
            let ds = Dataset::by_name(spec.name).unwrap();
            let pms = PartitionMatrix::build_all(&ds.graphs, cfg.v, cfg.n);
            for kind in ModelKind::ALL {
                for &flags in &presets {
                    let ctx = format!("{}/{}/{}", kind.name(), spec.name, flags.label());
                    let p = simulate_with_partitions(kind, &ds, &pms, cfg, flags)
                        .unwrap_or_else(|e| panic!("plan path failed for {ctx}: {e}"));
                    let l = legacy::simulate_with_partitions(kind, &ds, &pms, cfg, flags)
                        .unwrap_or_else(|e| panic!("legacy path failed for {ctx}: {e}"));
                    assert_eq!(p.metrics.latency_s, l.latency_s, "latency {ctx}");
                    assert_eq!(p.metrics.energy_j, l.energy_j, "energy {ctx}");
                    assert_eq!(p.aggregate_s, l.aggregate_s, "aggregate {ctx}");
                    assert_eq!(p.combine_s, l.combine_s, "combine {ctx}");
                    assert_eq!(p.update_s, l.update_s, "update {ctx}");
                    assert_eq!(p.readout_s, l.readout_s, "readout {ctx}");
                    assert_eq!(p.weight_stage_s, l.weight_stage_s, "weight stage {ctx}");
                    assert_eq!(
                        p.weight_stage_energy_j, l.weight_stage_energy_j,
                        "weight-stage energy {ctx}"
                    );
                    assert_eq!(
                        p.spilled_layer_gathers, l.spilled_layer_gathers,
                        "spills {ctx}"
                    );
                    assert_eq!(p.platform_w, l.platform_w, "platform power {ctx}");
                }
            }
        }
    }

    /// The sharding-refactor pin: a 1-shard sharded plan must reproduce
    /// the single-chip plan **bit-identically** — every [`SimReport`]
    /// field — across all 8 Table-2 datasets × all 4 models × every
    /// Fig. 8 optimization-flag combination. One chip, one phase,
    /// identical items, shared evaluation code path.
    #[test]
    fn one_shard_plan_bit_identical_to_single_chip() {
        let cfg = GhostConfig::paper_optimal();
        let presets = OptFlags::fig8_presets();
        for spec in ALL_DATASETS.iter() {
            let ds = Dataset::by_name(spec.name).unwrap();
            let pms = PartitionMatrix::build_all(&ds.graphs, cfg.v, cfg.n);
            for kind in ModelKind::ALL {
                for &flags in &presets {
                    let ctx = format!("{}/{}/{}", kind.name(), spec.name, flags.label());
                    let single = simulate_with_partitions(kind, &ds, &pms, cfg, flags)
                        .unwrap_or_else(|e| panic!("single-chip path failed for {ctx}: {e}"));
                    let sp = plan::build_sharded(kind, &ds, &pms, cfg, flags, 1)
                        .unwrap_or_else(|e| panic!("sharded build failed for {ctx}: {e}"));
                    assert_eq!(sp.remote_gather_edges, 0, "{ctx}");
                    let sharded = plan::evaluate_sharded(&sp)
                        .unwrap_or_else(|e| panic!("sharded eval failed for {ctx}: {e}"));
                    assert_eq!(single, sharded, "1-shard report diverged for {ctx}");
                }
            }
        }
    }

    /// The SoA-refactor pin: the lane-replay evaluator behind
    /// [`plan::evaluate`] / [`plan::evaluate_sharded`] must reproduce the
    /// retained item-walk reference **bit-identically** — every
    /// [`SimReport`] field, via `PartialEq` — across all 8 Table-2
    /// datasets × all 4 models × every Fig. 8 optimization-flag
    /// combination × shard counts {1, 4}.
    #[test]
    fn soa_evaluation_bit_identical_to_reference() {
        let cfg = GhostConfig::paper_optimal();
        let presets = OptFlags::fig8_presets();
        for spec in ALL_DATASETS.iter() {
            let ds = Dataset::by_name(spec.name).unwrap();
            let pms = PartitionMatrix::build_all(&ds.graphs, cfg.v, cfg.n);
            for kind in ModelKind::ALL {
                for &flags in &presets {
                    let ctx = format!("{}/{}/{}", kind.name(), spec.name, flags.label());
                    let p = plan::build(kind, &ds, &pms, cfg, flags)
                        .unwrap_or_else(|e| panic!("build failed for {ctx}: {e}"));
                    let soa = plan::evaluate(&p)
                        .unwrap_or_else(|e| panic!("SoA eval failed for {ctx}: {e}"));
                    let reference = plan::reference_evaluate(&p)
                        .unwrap_or_else(|e| panic!("reference eval failed for {ctx}: {e}"));
                    assert_eq!(soa, reference, "SoA report diverged for {ctx}");
                    for shards in [1usize, 4] {
                        let sp = plan::build_sharded(kind, &ds, &pms, cfg, flags, shards)
                            .unwrap_or_else(|e| {
                                panic!("{shards}-shard build failed for {ctx}: {e}")
                            });
                        let soa = plan::evaluate_sharded(&sp).unwrap_or_else(|e| {
                            panic!("{shards}-shard SoA eval failed for {ctx}: {e}")
                        });
                        let reference =
                            plan::reference_evaluate_sharded(&sp).unwrap_or_else(|e| {
                                panic!("{shards}-shard reference eval failed for {ctx}: {e}")
                            });
                        assert_eq!(
                            soa, reference,
                            "{shards}-shard SoA report diverged for {ctx}"
                        );
                    }
                }
            }
        }
    }

    /// The delta-evaluation pin: walking a neighbor chain of configs
    /// through one [`DeltaPlan`] (patching only provenance-affected lanes
    /// between points) must reproduce a fresh build + reference evaluation
    /// **bit-identically** at every point — for 1-shard and 4-shard plans.
    #[test]
    fn delta_plan_chain_bit_identical_to_fresh_builds() {
        use super::super::soa::DeltaPlan;
        use std::sync::Arc;
        let base = GhostConfig::paper_optimal();
        // Neighbor chain: non-structural steps (r_r, r_c, t_r), one
        // structural step (v), then a combined step back — exercising both
        // the patch path and the rebuild path.
        let chain = [
            base,
            GhostConfig { t_r: 12, ..base },
            GhostConfig { r_r: 14, t_r: 12, ..base },
            GhostConfig { r_c: 10, r_r: 14, t_r: 12, ..base },
            GhostConfig { v: 10, r_c: 10, r_r: 14, t_r: 12, ..base },
            base,
        ];
        let flags = OptFlags::ghost_default();
        for (kind, name) in
            [(ModelKind::Gcn, "Cora"), (ModelKind::Gat, "Citeseer"), (ModelKind::Gin, "Mutag")]
        {
            let ds = Dataset::by_name(name).unwrap();
            for shards in [1usize, 4] {
                let mut dp = DeltaPlan::new(kind, &ds, flags, shards);
                for cfg in chain {
                    let pms =
                        Arc::new(PartitionMatrix::build_all(&ds.graphs, cfg.v, cfg.n));
                    dp.retarget(cfg, &pms).unwrap_or_else(|e| {
                        panic!("retarget failed for {}/{name}: {e}", kind.name())
                    });
                    let delta = dp.evaluate().unwrap_or_else(|e| {
                        panic!("delta eval failed for {}/{name}: {e}", kind.name())
                    });
                    let fresh = if shards == 1 {
                        plan::build(kind, &ds, &pms, cfg, flags)
                            .and_then(|p| plan::reference_evaluate(&p))
                    } else {
                        plan::build_sharded(kind, &ds, &pms, cfg, flags, shards)
                            .and_then(|p| plan::reference_evaluate_sharded(&p))
                    }
                    .unwrap_or_else(|e| {
                        panic!("fresh eval failed for {}/{name}: {e}", kind.name())
                    });
                    assert_eq!(
                        delta,
                        fresh,
                        "delta report diverged for {}/{name} x{shards} at {cfg:?}",
                        kind.name()
                    );
                }
                // The chain above has exactly two structural boundaries
                // (the v change and the return to base); everything else
                // must have gone through the lane-patch path.
                assert_eq!(dp.rebuilds(), 3, "{}/{name} x{shards}", kind.name());
                assert_eq!(dp.patches(), 3, "{}/{name} x{shards}", kind.name());
            }
        }
    }
}
