//! Orchestration & scheduling optimization flags (§3.4) and the Fig. 8
//! preset combinations.


/// Which of the four §3.4 optimizations are active.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct OptFlags {
    /// §3.4.1 graph buffering & partitioning (prefetched block streaming +
    /// all-zero-block skipping). Off = on-demand sequential gathers.
    pub buffer_partition: bool,
    /// §3.4.2 two-level execution pipelining. Off = fully sequential
    /// stages and groups.
    pub pipelining: bool,
    /// §3.4.3 weight-DAC sharing across the V transform units.
    pub dac_sharing: bool,
    /// §3.4.4 workload balancing across execution lanes.
    pub workload_balancing: bool,
}

impl OptFlags {
    pub const fn baseline() -> Self {
        Self {
            buffer_partition: false,
            pipelining: false,
            dac_sharing: false,
            workload_balancing: false,
        }
    }

    /// The configuration GHOST ships with (§4.4: BP + PP + DAC sharing).
    pub const fn ghost_default() -> Self {
        Self {
            buffer_partition: true,
            pipelining: true,
            dac_sharing: true,
            workload_balancing: false,
        }
    }

    /// BP + PP + WB — the alternative §4.4 explores (WB precludes DAC
    /// sharing because lanes run at different rates).
    pub const fn bp_pp_wb() -> Self {
        Self {
            buffer_partition: true,
            pipelining: true,
            dac_sharing: false,
            workload_balancing: true,
        }
    }

    /// Workload balancing requires BP (synchronized, prefetched accesses —
    /// §4.4 explains WB in isolation is impractical) and conflicts with
    /// DAC sharing (lanes at different speeds can't share weight DACs).
    pub fn validate(&self) -> Result<(), String> {
        if self.workload_balancing && !self.buffer_partition {
            return Err("workload balancing requires buffer & partition (§4.4)".into());
        }
        if self.workload_balancing && self.dac_sharing {
            return Err("workload balancing precludes weight-DAC sharing (§4.4)".into());
        }
        Ok(())
    }

    /// Short label matching the Fig. 8 x-axis.
    pub fn label(&self) -> String {
        if *self == Self::baseline() {
            return "Baseline".into();
        }
        let mut parts = Vec::new();
        if self.buffer_partition {
            parts.push("BP");
        }
        if self.pipelining {
            parts.push("PP");
        }
        if self.dac_sharing {
            parts.push("DAC_Sharing");
        }
        if self.workload_balancing {
            parts.push("WB");
        }
        parts.join("+")
    }

    /// The combination set evaluated in Fig. 8 (WB only alongside BP+PP,
    /// per §4.4).
    pub fn fig8_presets() -> Vec<OptFlags> {
        let f = |bp, pp, dac, wb| OptFlags {
            buffer_partition: bp,
            pipelining: pp,
            dac_sharing: dac,
            workload_balancing: wb,
        };
        vec![
            Self::baseline(),
            f(true, false, false, false),  // BP
            f(false, true, false, false),  // PP
            f(false, false, true, false),  // DAC_Sharing
            f(true, true, false, false),   // BP+PP
            f(true, false, true, false),   // BP+DAC
            f(false, true, true, false),   // PP+DAC
            f(true, true, true, false),    // BP+PP+DAC (ghost default)
            f(true, true, false, true),    // BP+PP+WB
        ]
    }
}

impl Default for OptFlags {
    fn default() -> Self {
        Self::ghost_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_are_valid() {
        for p in OptFlags::fig8_presets() {
            assert_eq!(p.validate(), Ok(()), "{}", p.label());
        }
    }

    #[test]
    fn wb_without_bp_rejected() {
        let bad = OptFlags {
            buffer_partition: false,
            pipelining: true,
            dac_sharing: false,
            workload_balancing: true,
        };
        assert!(bad.validate().is_err());
    }

    #[test]
    fn wb_with_dac_sharing_rejected() {
        let bad = OptFlags { workload_balancing: true, ..OptFlags::ghost_default() };
        assert!(bad.validate().is_err());
    }

    #[test]
    fn labels() {
        assert_eq!(OptFlags::baseline().label(), "Baseline");
        assert_eq!(OptFlags::ghost_default().label(), "BP+PP+DAC_Sharing");
        assert_eq!(OptFlags::bp_pp_wb().label(), "BP+PP+WB");
    }

    #[test]
    fn fig8_has_nine_bars() {
        assert_eq!(OptFlags::fig8_presets().len(), 9);
    }
}
