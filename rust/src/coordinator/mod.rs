//! The GHOST coordinator — the paper's L3 system contribution.
//!
//! * [`optimizations`] — the four orchestration/scheduling optimizations of
//!   §3.4 as toggleable flags (buffer & partition, pipelining, weight-DAC
//!   sharing, workload balancing) with the preset combinations of Fig. 8.
//! * [`schedule`] — maps a `(model, dataset, config, flags)` tuple onto
//!   per-group pipeline stages and evaluates latency/energy with the
//!   [`crate::sim`] pipeline model: the full GHOST simulator.
//! * [`engine`] — the batched simulation session: caches datasets,
//!   `(dataset, V, N)` partition sets, and per-request [`ServiceProfile`]s
//!   behind concurrent maps and fans [`SimRequest`] batches out over the
//!   thread pool.
//! * [`error`] — the structured [`SimError`] every fallible path returns.
//! * [`dse`] — the architectural design-space exploration of Fig. 7(c)
//!   over `[N, V, R_r, R_c, T_r]`, run through the engine.

pub mod dse;
pub mod engine;
pub mod error;
pub mod optimizations;
pub mod schedule;

pub use engine::{BatchEngine, ServiceProfile, SimRequest};
pub use error::SimError;
pub use optimizations::OptFlags;
pub use schedule::{simulate, simulate_with_partitions, simulate_workload, SimReport};
