//! The GHOST coordinator — the paper's L3 system contribution.
//!
//! * [`optimizations`] — the four orchestration/scheduling optimizations of
//!   §3.4 as toggleable flags (buffer & partition, pipelining, weight-DAC
//!   sharing, workload balancing) with the preset combinations of Fig. 8.
//! * [`plan`] — the typed schedule IR: [`StagePlan`] construction (arch
//!   block costs → kind-tagged [`StageCost`] stages) and evaluation (the
//!   pipelined recurrence → makespan, energy, and exact per-kind busy
//!   totals in one pass).
//! * [`schedule`] — the simulator entry points: map a `(model, dataset,
//!   config, flags)` tuple onto a plan and evaluate it into a
//!   [`SimReport`].
//! * [`engine`] — the batched simulation session: caches datasets,
//!   `(dataset, V, N)` partition sets, [`StagePlan`]s, and per-request
//!   [`ServiceProfile`]s behind concurrent maps and fans [`SimRequest`]
//!   batches out over the thread pool.
//! * [`error`] — the structured [`SimError`] every fallible path returns.
//! * [`soa`] — the structure-of-arrays cost core: every plan carries a
//!   [`PlanSoA`] lowering (flat latency/energy lanes + cached per-group /
//!   per-segment partials) that evaluation replays, [`DeltaPlan`]
//!   re-costs only provenance-affected lanes between neighboring sweep
//!   points, and [`GraphDeltaPlan`] re-costs only mutation-touched groups
//!   when the *graph* changes under a fixed configuration.
//! * [`dse`] — the architectural design-space exploration of Fig. 7(c)
//!   over `[N, V, R_r, R_c, T_r]`, run through the engine; sweeps walk the
//!   grid in Gray order and delta-evaluate by default.
//!
//! [`StageCost`]: crate::arch::StageCost

pub mod dse;
pub mod engine;
pub mod error;
pub mod optimizations;
pub mod plan;
pub mod schedule;
pub mod soa;

pub use engine::{BatchEngine, ServiceProfile, SimRequest};
pub use error::SimError;
pub use optimizations::OptFlags;
pub use plan::{
    build_sharded, evaluate_sharded, reference_evaluate, reference_evaluate_sharded,
    sim_timeline, sim_timeline_sharded, ChipPlan, KindTotals, PipelineSegment, PlanItem,
    ShardedStagePlan, StageKind, StagePlan,
};
pub use soa::{delta_counters, DeltaPlan, GraphDeltaPlan, ParamSet, PlanSoA};
pub use schedule::{simulate, simulate_with_partitions, simulate_workload, SimReport};
