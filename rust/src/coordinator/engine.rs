//! The batched simulation engine — the session object behind every sweep.
//!
//! GHOST's evaluation (Figs. 7–9) is thousands of `(model, dataset,
//! config, flags)` simulations, and the dominant cost of each is the
//! offline graph preprocessing: generating the dataset and building its
//! `V×N` [`PartitionMatrix`] set. Both depend only on `(dataset, V, N)` —
//! never on the model, the array shapes `R_r/R_c/T_r`, or the optimization
//! flags — so a sweep that rebuilds them per simulation does the same work
//! hundreds of times over.
//!
//! [`BatchEngine`] amortizes that cost behind three concurrent caches:
//!
//! * a dataset cache keyed by canonical dataset name — a Table-2 name, a
//!   large-tier name (`ogbn-arxiv-syn`, `reddit-syn`), or a parameterized
//!   `rmat-<V>v-<E>e...` spec (see [`crate::graph::datasets`]) —
//! * a partition cache keyed by `(dataset, V, N)`, and
//! * a [`StagePlan`] cache keyed by the full `(model, dataset, config,
//!   flags)` tuple: plan *construction* (all the architecture-block cost
//!   modelling) happens once per key, and every [`BatchEngine::run`] after
//!   the first only *evaluates* the cached plan — which is what makes
//!   figure re-runs, ablation re-sweeps, and serving-profile resolution
//!   cheap (see `benches/plan_reuse.rs`).
//!
//! Each cache entry is an [`OnceLock`] cell, so concurrent requests for
//! the same key build **at most once** (losers block on the winner instead
//! of duplicating the build); [`BatchEngine::partition_builds`] and
//! [`BatchEngine::plan_builds`] count the actual builds so tests can
//! verify the guarantee. Batches of [`SimRequest`]s fan out over
//! [`crate::util::parallel::par_map`] and every failure comes back as a
//! structured [`SimError`] value — a bad point degrades to a reported
//! error, never a process abort.

use std::collections::HashMap;
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};

use crate::config::GhostConfig;
use crate::gnn::models::ModelKind;
use crate::graph::datasets::{spec_by_name, Dataset};
use crate::graph::partition::PartitionMatrix;
use crate::util::parallel::par_map;
use crate::util::telemetry::{self, Counter};

use super::error::SimError;
use super::optimizations::OptFlags;
use super::plan::{self, ShardedStagePlan, StagePlan};
use super::schedule::SimReport;

/// One simulation to run: the full `(model, dataset, config, flags)` tuple.
#[derive(Debug, Clone, PartialEq)]
pub struct SimRequest {
    pub model: ModelKind,
    /// Table-2 dataset name (case-insensitive).
    pub dataset: String,
    pub cfg: GhostConfig,
    pub flags: OptFlags,
}

impl SimRequest {
    pub fn new(
        model: ModelKind,
        dataset: impl Into<String>,
        cfg: GhostConfig,
        flags: OptFlags,
    ) -> Self {
        Self { model, dataset: dataset.into(), cfg, flags }
    }
}

type DatasetCell = Arc<OnceLock<Arc<Dataset>>>;
type PartitionCell = Arc<OnceLock<Arc<Vec<PartitionMatrix>>>>;
/// `(canonical name, graph-mutation epoch, V, N)`. The epoch
/// ([`Dataset::epoch`], bumped by [`crate::graph::mutate::apply_to_dataset`])
/// keys mutated dataset instances away from the canonical epoch-0 entries,
/// so a churned graph can never alias a stale cached partition set.
type PartitionKey = (String, u64, usize, usize);
/// `(model, canonical name, graph-mutation epoch, config, flags)` — the
/// epoch field makes stale plans/profiles unreachable once a dataset
/// mutates (see [`BatchEngine::evict_dataset_epochs_below`]).
type ProfileKey = (ModelKind, String, u64, GhostConfig, OptFlags);
/// Plans and profiles key on the identical request tuple — one alias, so
/// the two caches cannot silently diverge if the key ever gains a field.
type PlanKey = ProfileKey;
/// Plan cells cache the whole build `Result`: a failure is as
/// deterministic as a success for a given key (the build is pure), so
/// caching it keeps the at-most-once guarantee without a poisoned or
/// placeholder state.
type PlanCell = Arc<OnceLock<Result<Arc<StagePlan>, SimError>>>;
/// Sharded plans key on the request tuple *plus* the shard count — the
/// same workload sharded 2-way and 4-way are different schedules.
type ShardedPlanKey = (PlanKey, usize);
type ShardedPlanCell = Arc<OnceLock<Result<Arc<ShardedStagePlan>, SimError>>>;
/// Profiles use the same cell scheme as plans: concurrent first lookups of
/// one key block on a single simulation instead of racing to duplicate it,
/// which is what lets a parallel scenario sweep ([`crate::serve::sweep`])
/// guarantee exactly one profile build per distinct tenant tuple.
type ProfileCell = Arc<OnceLock<Result<ServiceProfile, SimError>>>;

/// The service-time decomposition of one `(model, dataset, config, flags)`
/// request, derived from a full [`SimReport`] and cached by the engine for
/// the online-serving simulator ([`crate::serve`]).
///
/// A single offline inference pays `latency_s` end to end, but
/// `weight_stage_s` of that — staging the weight matrices and
/// TO-retargeting the MR banks — is programmed state, not per-request
/// work: a server running a batch of same-tenant requests pays it once per
/// batch (or not at all, if the accelerator is already programmed for the
/// tenant). The remainder, [`ServiceProfile::per_request_s`], scales
/// linearly with batch size.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ServiceProfile {
    /// Full single-inference latency, seconds (`metrics.latency_s`).
    pub latency_s: f64,
    /// The once-per-batch weight-programming share of `latency_s`.
    pub weight_stage_s: f64,
    /// Energy of one inference, joules (`metrics.energy_j`).
    pub energy_j: f64,
    /// The once-per-batch weight-programming share of `energy_j`: the
    /// staging/TO-retune dynamic energy plus the platform power burned
    /// over `weight_stage_s`. A batch that skips programming skips this
    /// energy too.
    pub weight_stage_energy_j: f64,
}

impl ServiceProfile {
    /// Derives the decomposition from a full report — the single formula
    /// the cached path, the sharded path, and the churn engine's live
    /// re-profiles all share (so they cannot drift apart).
    pub fn from_report(report: &SimReport) -> Self {
        ServiceProfile {
            latency_s: report.metrics.latency_s,
            weight_stage_s: report.weight_stage_s,
            energy_j: report.metrics.energy_j,
            weight_stage_energy_j: report.weight_stage_energy_j
                + report.platform_w * report.weight_stage_s,
        }
    }

    /// Per-request service time once the weights are programmed.
    pub fn per_request_s(&self) -> f64 {
        (self.latency_s - self.weight_stage_s).max(0.0)
    }

    /// Per-request energy once the weights are programmed.
    pub fn per_request_energy_j(&self) -> f64 {
        (self.energy_j - self.weight_stage_energy_j).max(0.0)
    }

    /// Service time of a same-tenant batch of `n` requests.
    /// `programmed = true` skips the weight-staging share (the accelerator
    /// ran this tenant last and the banks are still tuned to its weights).
    pub fn batch_service_s(&self, n: usize, programmed: bool) -> f64 {
        if n == 0 {
            return 0.0;
        }
        let stage = if programmed { 0.0 } else { self.weight_stage_s };
        stage + n as f64 * self.per_request_s()
    }

    /// Energy of a same-tenant batch of `n` requests, mirroring
    /// [`Self::batch_service_s`].
    pub fn batch_energy_j(&self, n: usize, programmed: bool) -> f64 {
        if n == 0 {
            return 0.0;
        }
        let stage = if programmed { 0.0 } else { self.weight_stage_energy_j };
        stage + n as f64 * self.per_request_energy_j()
    }
}

/// Cached, parallel batch simulation session. Cheap to share by reference
/// across threads; see the module docs for the caching contract.
///
/// The build/hit/eviction counters are [`telemetry::Counter`]s held
/// *per instance* — tests build private engines and assert exact counts,
/// so instances cannot share process-wide state. Only the global engine's
/// set is adopted into the telemetry registry (see [`BatchEngine::global`]),
/// under the `engine.*` names.
pub struct BatchEngine {
    datasets: Mutex<HashMap<String, DatasetCell>>,
    partitions: Mutex<HashMap<PartitionKey, PartitionCell>>,
    plans: Mutex<HashMap<PlanKey, PlanCell>>,
    sharded_plans: Mutex<HashMap<ShardedPlanKey, ShardedPlanCell>>,
    profiles: Mutex<HashMap<ProfileKey, ProfileCell>>,
    dataset_builds: Arc<Counter>,
    partition_builds: Arc<Counter>,
    plan_builds: Arc<Counter>,
    sharded_plan_builds: Arc<Counter>,
    profile_builds: Arc<Counter>,
    evictions: Arc<Counter>,
    dataset_hits: Arc<Counter>,
    partition_hits: Arc<Counter>,
    plan_hits: Arc<Counter>,
    sharded_plan_hits: Arc<Counter>,
    profile_hits: Arc<Counter>,
}

impl Default for BatchEngine {
    fn default() -> Self {
        BatchEngine {
            datasets: Mutex::default(),
            partitions: Mutex::default(),
            plans: Mutex::default(),
            sharded_plans: Mutex::default(),
            profiles: Mutex::default(),
            dataset_builds: Counter::new("engine.dataset.builds"),
            partition_builds: Counter::new("engine.partition.builds"),
            plan_builds: Counter::new("engine.plan.builds"),
            sharded_plan_builds: Counter::new("engine.sharded_plan.builds"),
            profile_builds: Counter::new("engine.profile.builds"),
            evictions: Counter::new("engine.evictions"),
            dataset_hits: Counter::new("engine.dataset.hits"),
            partition_hits: Counter::new("engine.partition.hits"),
            plan_hits: Counter::new("engine.plan.hits"),
            sharded_plan_hits: Counter::new("engine.sharded_plan.hits"),
            profile_hits: Counter::new("engine.profile.hits"),
        }
    }
}

/// Locks a mutex, recovering the guard from a poisoned lock (the protected
/// maps are always left consistent, so a panicked peer is harmless and the
/// hot path must not cascade the panic).
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Cheap structural check that a cached partition set was built from (a
/// dataset identical in shape to) `dataset`: same graph count and, per
/// graph, same vertex and edge counts.
fn partitions_match(pms: &[PartitionMatrix], dataset: &Dataset) -> bool {
    pms.len() == dataset.graphs.len()
        && pms
            .iter()
            .zip(&dataset.graphs)
            .all(|(pm, g)| pm.n_vertices == g.n_vertices && pm.total_edges() == g.n_edges() as u64)
}

impl BatchEngine {
    pub fn new() -> Self {
        Self::default()
    }

    /// A process-wide shared engine: the figure/table regeneration paths
    /// all run through it, so `figures --all` (and the test suite) builds
    /// each dataset and partition set once for the whole process.
    ///
    /// Cached entries live until [`Self::clear`] is called. The footprint
    /// is bounded by the eight Table-2 datasets times the distinct `(V, N)`
    /// shapes requested; long-running consumers sweeping many shapes
    /// should use their own [`BatchEngine::new`] (dropped with the sweep)
    /// or call `clear()` between sweeps.
    pub fn global() -> &'static BatchEngine {
        static GLOBAL: OnceLock<BatchEngine> = OnceLock::new();
        GLOBAL.get_or_init(|| {
            let engine = BatchEngine::new();
            // Only the process-wide engine's counters are visible in the
            // registry; private engines (tests, sweeps) keep theirs local
            // so exact-count assertions can't interfere across threads.
            let registry = telemetry::registry();
            for counter in [
                &engine.dataset_builds,
                &engine.partition_builds,
                &engine.plan_builds,
                &engine.sharded_plan_builds,
                &engine.profile_builds,
                &engine.evictions,
                &engine.dataset_hits,
                &engine.partition_hits,
                &engine.plan_hits,
                &engine.sharded_plan_hits,
                &engine.profile_hits,
            ] {
                registry.adopt_counter(counter);
            }
            engine
        })
    }

    /// Drops every cached dataset and partition set (in-flight users keep
    /// their `Arc`s alive until they finish). The build counters are *not*
    /// reset: they count builds ever performed, and keep exposing re-build
    /// churn after a clear.
    pub fn clear(&self) {
        lock(&self.datasets).clear();
        lock(&self.partitions).clear();
        lock(&self.plans).clear();
        lock(&self.sharded_plans).clear();
        lock(&self.profiles).clear();
    }

    /// The realized dataset for a name in any tier (Table-2, large-graph,
    /// or parameterized `rmat-...`), generated at most once per engine.
    /// Lookup is case-insensitive and parameterized specs canonicalize
    /// (`"cora"`/`"Cora"` share one entry; so do `"rmat-1000v-5000e"` and
    /// `"RMAT-1000v-5000e-128f"`).
    pub fn dataset(&self, name: &str) -> Result<Arc<Dataset>, SimError> {
        let spec =
            spec_by_name(name).ok_or_else(|| SimError::UnknownDataset(name.to_string()))?;
        let cell: DatasetCell =
            lock(&self.datasets).entry(spec.name.to_string()).or_default().clone();
        if cell.get().is_some() {
            self.dataset_hits.inc();
        }
        // Built outside the map lock; concurrent losers block on the cell.
        let ds = cell.get_or_init(|| {
            self.dataset_builds.inc();
            Arc::new(Dataset::generate(spec))
        });
        Ok(ds.clone())
    }

    /// The `(V, N)` partition set of every graph in `dataset`, built at
    /// most once per distinct `(dataset, V, N)` key for this engine's
    /// lifetime and shared by all simulations that need it.
    pub fn partitions_for(
        &self,
        dataset: &Dataset,
        v: usize,
        n: usize,
    ) -> Result<Arc<Vec<PartitionMatrix>>, SimError> {
        if v == 0 || n == 0 {
            return Err(SimError::InvalidConfig(format!(
                "partition dimensions must be non-zero, got (V, N) = ({v}, {n})"
            )));
        }
        let key: PartitionKey = (dataset.spec.name.to_string(), dataset.epoch, v, n);
        let cell: PartitionCell = lock(&self.partitions).entry(key).or_default().clone();
        if cell.get().is_some() {
            self.partition_hits.inc();
        }
        let pms = cell.get_or_init(|| {
            self.partition_builds.inc();
            Arc::new(PartitionMatrix::build_all(&dataset.graphs, v, n))
        });
        // The cache is keyed by name and first-writer-wins; a caller may
        // hold a *modified* Dataset under a canonical name (the fields are
        // public). If the cached set does not match this instance's graph
        // shapes, fall back to an uncached (but counted) build from the
        // dataset actually passed in. The match is structural — graph count
        // plus per-graph vertex/edge counts — not content-exact: a
        // hand-rewired graph with identical counts will still alias, and a
        // modified instance arriving *first* keeps the key, demoting later
        // canonical callers to the fallback. Callers mixing modified and
        // canonical instances of one name should use separate engines (or
        // simulate_workload, which never touches the cache).
        if !partitions_match(pms, dataset) {
            self.partition_builds.inc();
            return Ok(Arc::new(PartitionMatrix::build_all(&dataset.graphs, v, n)));
        }
        Ok(pms.clone())
    }

    /// Dataset-by-name convenience for [`Self::partitions_for`].
    pub fn partitions(
        &self,
        dataset_name: &str,
        v: usize,
        n: usize,
    ) -> Result<Arc<Vec<PartitionMatrix>>, SimError> {
        let ds = self.dataset(dataset_name)?;
        self.partitions_for(&ds, v, n)
    }

    /// How many dataset generations this engine has actually performed.
    pub fn dataset_builds(&self) -> usize {
        self.dataset_builds.get()
    }

    /// How many partition sets this engine has actually built: one per
    /// distinct `(dataset, V, N)` key ever requested — regardless of how
    /// many simulations shared it — plus any structural-mismatch fallback
    /// builds (see [`Self::partitions_for`]), so cache churn is visible.
    pub fn partition_builds(&self) -> usize {
        self.partition_builds.get()
    }

    /// The cached [`StagePlan`] of a request, constructed at most once per
    /// distinct `(model, canonical dataset, config, flags)` key for this
    /// engine's lifetime (`"cora"`/`"Cora"` and aliasing `rmat-...`
    /// spellings share one entry). Construction resolves the dataset and
    /// partition caches first, so a plan build implies at most one dataset
    /// generation and one partition build underneath — and a cached plan
    /// implies none at all.
    pub fn plan(&self, req: &SimRequest) -> Result<Arc<StagePlan>, SimError> {
        // Validate before touching any cache, so a rejected request leaves
        // no entries (and no build-counter increments) behind.
        req.cfg.validate().map_err(SimError::InvalidConfig)?;
        req.flags.validate().map_err(SimError::InvalidFlags)?;
        let spec = spec_by_name(&req.dataset)
            .ok_or_else(|| SimError::UnknownDataset(req.dataset.clone()))?;
        let dataset = self.dataset(&req.dataset)?;
        let partitions = self.partitions_for(&dataset, req.cfg.v, req.cfg.n)?;
        let key: PlanKey =
            (req.model, spec.name.to_string(), dataset.epoch, req.cfg, req.flags);
        let cell: PlanCell = lock(&self.plans).entry(key).or_default().clone();
        if cell.get().is_some() {
            self.plan_hits.inc();
        }
        // Built outside the map lock; concurrent losers block on the cell.
        // A build failure (unreachable in practice: config and flags were
        // validated above and the partitions come from the same dataset
        // and shape) is cached like a success — it is just as
        // deterministic.
        cell.get_or_init(|| {
            self.plan_builds.inc();
            plan::build(req.model, &dataset, &partitions, req.cfg, req.flags).map(Arc::new)
        })
        .clone()
    }

    /// How many [`StagePlan`]s this engine has actually constructed: one
    /// per distinct `(model, dataset, config, flags)` key ever requested,
    /// however many evaluations shared it.
    pub fn plan_builds(&self) -> usize {
        self.plan_builds.get()
    }

    /// Runs one simulation through the caches: dataset, partitions, and
    /// the typed [`StagePlan`] are all reused when present, so a repeated
    /// request costs one plan *evaluation* (a single walk over the cached
    /// stages) instead of a full re-simulation.
    pub fn run(&self, req: &SimRequest) -> Result<SimReport, SimError> {
        let plan = self.plan(req)?;
        plan::evaluate(&plan)
    }

    /// The cached [`ShardedStagePlan`] of a request sharded across
    /// `shards` chips, constructed at most once per distinct
    /// `((model, dataset, config, flags), shards)` key. The single-chip
    /// plan cache is untouched: shard counts are a separate key dimension.
    pub fn sharded_plan(
        &self,
        req: &SimRequest,
        shards: usize,
    ) -> Result<Arc<ShardedStagePlan>, SimError> {
        req.cfg.validate().map_err(SimError::InvalidConfig)?;
        req.flags.validate().map_err(SimError::InvalidFlags)?;
        if shards == 0 {
            return Err(SimError::InvalidConfig("shard count must be >= 1".into()));
        }
        let spec = spec_by_name(&req.dataset)
            .ok_or_else(|| SimError::UnknownDataset(req.dataset.clone()))?;
        let dataset = self.dataset(&req.dataset)?;
        let partitions = self.partitions_for(&dataset, req.cfg.v, req.cfg.n)?;
        let key: ShardedPlanKey =
            ((req.model, spec.name.to_string(), dataset.epoch, req.cfg, req.flags), shards);
        let cell: ShardedPlanCell =
            lock(&self.sharded_plans).entry(key).or_default().clone();
        if cell.get().is_some() {
            self.sharded_plan_hits.inc();
        }
        // Built outside the map lock; failures (e.g. a slice over the
        // per-chip memory budget) are deterministic and cached like
        // successes.
        cell.get_or_init(|| {
            self.sharded_plan_builds.inc();
            plan::build_sharded(req.model, &dataset, &partitions, req.cfg, req.flags, shards)
                .map(Arc::new)
        })
        .clone()
    }

    /// How many [`ShardedStagePlan`]s this engine has actually constructed.
    pub fn sharded_plan_builds(&self) -> usize {
        self.sharded_plan_builds.get()
    }

    /// Runs one simulation sharded across `shards` chips through the
    /// caches. A workload whose resident footprint exceeds
    /// `cfg.chip_mem_bytes` per chip fails with
    /// [`SimError::ExceedsChipMemory`] naming the minimum shard count —
    /// never a silent spill.
    pub fn run_sharded(
        &self,
        req: &SimRequest,
        shards: usize,
    ) -> Result<SimReport, SimError> {
        let plan = self.sharded_plan(req, shards)?;
        plan::evaluate_sharded(&plan)
    }

    /// The [`ServiceProfile`] of a request served by a `shards`-chip group
    /// — same decomposition as [`Self::service_profile`], derived from the
    /// sharded report (uncached beyond the sharded-plan cache: the serve
    /// resolver calls this once per tenant).
    pub fn sharded_service_profile(
        &self,
        req: &SimRequest,
        shards: usize,
    ) -> Result<ServiceProfile, SimError> {
        let report = self.run_sharded(req, shards)?;
        Ok(ServiceProfile::from_report(&report))
    }

    /// The cached [`ServiceProfile`] of a request: one full simulation the
    /// first time a `(model, dataset, config, flags)` tuple is seen, a map
    /// lookup ever after. The key uses the *canonical* dataset name, so
    /// `"cora"` and `"Cora"` (and aliasing `rmat-...` spellings) share one
    /// entry. The serving simulator resolves every tenant through this
    /// before its event loop starts, so steady-state serving never
    /// re-simulates.
    ///
    /// Profiles live in [`OnceLock`] cells like plans: concurrent first
    /// lookups of one key block on a single simulation, so a parallel
    /// scenario sweep resolving the same tenant from many workers still
    /// builds the profile exactly once ([`Self::profile_builds`] counts
    /// the actual simulations, and `tests/sweep_capacity.rs` pins the
    /// guarantee).
    pub fn service_profile(&self, req: &SimRequest) -> Result<ServiceProfile, SimError> {
        let spec = spec_by_name(&req.dataset)
            .ok_or_else(|| SimError::UnknownDataset(req.dataset.clone()))?;
        // Resolve the dataset first: its graph-mutation epoch is part of
        // the key, so a profile cached before a mutation can never be
        // served after it (the churn path evicts superseded epochs, and
        // even an unevicted entry is unreachable under the new epoch).
        let dataset = self.dataset(&req.dataset)?;
        let key: ProfileKey =
            (req.model, spec.name.to_string(), dataset.epoch, req.cfg, req.flags);
        let cell: ProfileCell = lock(&self.profiles).entry(key).or_default().clone();
        if cell.get().is_some() {
            self.profile_hits.inc();
        }
        // Simulated outside the map lock; a failure is as deterministic as
        // a success for the key (the plan build underneath caches its own
        // `Result`), so caching it keeps at-most-once without a poisoned
        // state.
        cell.get_or_init(|| {
            self.profile_builds.inc();
            let report = self.run(req)?;
            Ok(ServiceProfile::from_report(&report))
        })
        .clone()
    }

    /// How many full simulations [`Self::service_profile`] has performed:
    /// one per distinct `(model, dataset, epoch, config, flags)` key ever
    /// requested, however many concurrent lookups shared it.
    pub fn profile_builds(&self) -> usize {
        self.profile_builds.get()
    }

    /// Drops every partition / plan / sharded-plan / profile cache entry
    /// of `dataset_name` whose graph-mutation epoch is below `epoch`,
    /// returning how many entries were evicted. The churn path calls this
    /// after each applied [`crate::graph::mutate::GraphDelta`] batch: the
    /// epoch-in-key scheme already makes superseded entries unreachable
    /// for the mutated instance, so this is memory hygiene plus an
    /// observable counter ([`Self::evictions`]) for the serve report.
    /// Unknown names evict nothing.
    pub fn evict_dataset_epochs_below(&self, dataset_name: &str, epoch: u64) -> usize {
        let Some(spec) = spec_by_name(dataset_name) else {
            return 0;
        };
        let name = spec.name;
        let mut evicted = 0usize;
        {
            let mut m = lock(&self.partitions);
            let before = m.len();
            m.retain(|(n, e, _, _), _| n.as_str() != name || *e >= epoch);
            evicted += before - m.len();
        }
        {
            let mut m = lock(&self.plans);
            let before = m.len();
            m.retain(|(_, n, e, _, _), _| n.as_str() != name || *e >= epoch);
            evicted += before - m.len();
        }
        {
            let mut m = lock(&self.sharded_plans);
            let before = m.len();
            m.retain(|((_, n, e, _, _), _), _| n.as_str() != name || *e >= epoch);
            evicted += before - m.len();
        }
        {
            let mut m = lock(&self.profiles);
            let before = m.len();
            m.retain(|(_, n, e, _, _), _| n.as_str() != name || *e >= epoch);
            evicted += before - m.len();
        }
        self.evictions.add(evicted);
        evicted
    }

    /// How many cache entries [`Self::evict_dataset_epochs_below`] has
    /// dropped over this engine's lifetime (monotone, like the build
    /// counters).
    pub fn evictions(&self) -> usize {
        self.evictions.get()
    }

    /// Fans a batch of requests out over the scoped thread pool
    /// ([`crate::util::parallel::par_map`]). Results come back in request
    /// order; each failure is a per-request [`SimError`], so one bad
    /// request never sinks the batch.
    pub fn run_batch(&self, reqs: &[SimRequest]) -> Vec<Result<SimReport, SimError>> {
        par_map(reqs, |req| self.run(req))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dataset_cache_is_case_insensitive_and_shared() {
        let engine = BatchEngine::new();
        let a = engine.dataset("Cora").unwrap();
        let b = engine.dataset("cora").unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(engine.dataset_builds(), 1);
    }

    #[test]
    fn unknown_dataset_is_a_value_not_a_panic() {
        let engine = BatchEngine::new();
        assert_eq!(
            engine.dataset("NoSuchDataset").unwrap_err(),
            SimError::UnknownDataset("NoSuchDataset".into())
        );
    }

    #[test]
    fn partition_cache_reuses_by_shape_key() {
        let engine = BatchEngine::new();
        let a = engine.partitions("Cora", 20, 20).unwrap();
        let b = engine.partitions("Cora", 20, 20).unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        let c = engine.partitions("Cora", 10, 20).unwrap();
        assert!(!Arc::ptr_eq(&a, &c));
        assert_eq!(engine.partition_builds(), 2);
    }

    #[test]
    fn zero_shape_rejected_before_the_partition_assert() {
        let engine = BatchEngine::new();
        let ds = engine.dataset("Cora").unwrap();
        assert!(matches!(
            engine.partitions_for(&ds, 0, 20),
            Err(SimError::InvalidConfig(_))
        ));
    }

    #[test]
    fn run_validates_config_and_flags_first() {
        let engine = BatchEngine::new();
        let bad_cfg = GhostConfig { r_c: 25, ..GhostConfig::paper_optimal() };
        let req =
            SimRequest::new(ModelKind::Gcn, "Cora", bad_cfg, OptFlags::ghost_default());
        assert!(matches!(engine.run(&req), Err(SimError::InvalidConfig(_))));
        let bad_flags =
            OptFlags { workload_balancing: true, ..OptFlags::ghost_default() };
        let req = SimRequest::new(
            ModelKind::Gcn,
            "Cora",
            GhostConfig::paper_optimal(),
            bad_flags,
        );
        assert!(matches!(engine.run(&req), Err(SimError::InvalidFlags(_))));
        // Nothing was cached for the rejected requests.
        assert_eq!(engine.partition_builds(), 0);
    }

    #[test]
    fn batch_preserves_order_and_isolates_failures() {
        let engine = BatchEngine::new();
        let cfg = GhostConfig::paper_optimal();
        let flags = OptFlags::ghost_default();
        let reqs = vec![
            SimRequest::new(ModelKind::Gcn, "Cora", cfg, flags),
            SimRequest::new(ModelKind::Gcn, "NoSuchDataset", cfg, flags),
            SimRequest::new(ModelKind::Gat, "Cora", cfg, flags),
        ];
        let results = engine.run_batch(&reqs);
        assert_eq!(results.len(), 3);
        assert!(results[0].is_ok());
        assert!(matches!(results[1], Err(SimError::UnknownDataset(_))));
        assert!(results[2].is_ok());
        assert_eq!(results[0].as_ref().unwrap().model, ModelKind::Gcn);
        assert_eq!(results[2].as_ref().unwrap().model, ModelKind::Gat);
    }

    #[test]
    fn modified_dataset_never_gets_stale_cached_partitions() {
        let engine = BatchEngine::new();
        let canonical = engine.dataset("Cora").unwrap();
        let cached = engine.partitions_for(&canonical, 20, 20).unwrap();
        // Same canonical name, different graph: the cache must not serve
        // Cora's partitions for it.
        let modified = Dataset {
            spec: canonical.spec,
            graphs: vec![crate::graph::csr::CsrGraph::from_edges(10, &[(0, 1), (1, 2)])],
            epoch: 0,
        };
        let fresh = engine.partitions_for(&modified, 20, 20).unwrap();
        assert!(!Arc::ptr_eq(&cached, &fresh));
        assert_eq!(fresh[0].n_vertices, 10);
        // Canonical requests still hit the cache.
        let again = engine.partitions_for(&canonical, 20, 20).unwrap();
        assert!(Arc::ptr_eq(&cached, &again));
    }

    #[test]
    fn clear_evicts_caches_but_counters_persist() {
        let engine = BatchEngine::new();
        engine.partitions("Cora", 20, 20).unwrap();
        assert_eq!(engine.partition_builds(), 1);
        engine.clear();
        engine.partitions("Cora", 20, 20).unwrap();
        assert_eq!(engine.partition_builds(), 2);
        assert_eq!(engine.dataset_builds(), 2);
    }

    #[test]
    fn service_profile_caches_by_canonical_request() {
        let engine = BatchEngine::new();
        let cfg = GhostConfig::paper_optimal();
        let flags = OptFlags::ghost_default();
        let a = engine
            .service_profile(&SimRequest::new(ModelKind::Gcn, "Cora", cfg, flags))
            .unwrap();
        // Case-insensitive aliasing hits the same entry.
        let b = engine
            .service_profile(&SimRequest::new(ModelKind::Gcn, "cora", cfg, flags))
            .unwrap();
        assert_eq!(a, b);
        assert_eq!(engine.profile_builds(), 1);
        // A different model is a different key.
        engine
            .service_profile(&SimRequest::new(ModelKind::Gat, "Cora", cfg, flags))
            .unwrap();
        assert_eq!(engine.profile_builds(), 2);
        // The decomposition is consistent with the full report.
        let r = engine.run(&SimRequest::new(ModelKind::Gcn, "Cora", cfg, flags)).unwrap();
        assert_eq!(a.latency_s, r.metrics.latency_s);
        assert_eq!(a.weight_stage_s, r.weight_stage_s);
        assert!(a.per_request_s() > 0.0 && a.per_request_s() < a.latency_s);
        // Batch arithmetic: programmed batches skip the staging share.
        let two = a.batch_service_s(2, false);
        assert!((two - (a.weight_stage_s + 2.0 * a.per_request_s())).abs() < 1e-18);
        assert!((a.batch_service_s(2, true) - 2.0 * a.per_request_s()).abs() < 1e-18);
        assert_eq!(a.batch_service_s(0, false), 0.0);
        // The energy decomposition mirrors the latency one.
        assert!(a.weight_stage_energy_j > 0.0);
        assert!(a.weight_stage_energy_j < a.energy_j);
        assert!(a.per_request_energy_j() > 0.0);
        assert!(a.batch_energy_j(3, true) < a.batch_energy_j(3, false));
        assert_eq!(a.batch_energy_j(0, false), 0.0);
    }

    #[test]
    fn service_profile_unknown_dataset_is_an_error() {
        let engine = BatchEngine::new();
        let req = SimRequest::new(
            ModelKind::Gcn,
            "NoSuchDataset",
            GhostConfig::paper_optimal(),
            OptFlags::ghost_default(),
        );
        assert!(matches!(
            engine.service_profile(&req),
            Err(SimError::UnknownDataset(_))
        ));
        assert_eq!(engine.profile_builds(), 0);
    }

    #[test]
    fn plan_cache_builds_once_per_canonical_request() {
        let engine = BatchEngine::new();
        let cfg = GhostConfig::paper_optimal();
        let flags = OptFlags::ghost_default();
        let a = engine.plan(&SimRequest::new(ModelKind::Gcn, "Cora", cfg, flags)).unwrap();
        // Case-insensitive aliasing shares the entry.
        let b = engine.plan(&SimRequest::new(ModelKind::Gcn, "cora", cfg, flags)).unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(engine.plan_builds(), 1);
        // run() goes through the same cache: no new construction.
        engine.run(&SimRequest::new(ModelKind::Gcn, "Cora", cfg, flags)).unwrap();
        assert_eq!(engine.plan_builds(), 1);
        // A different model, config, or flag set is a different plan.
        engine.plan(&SimRequest::new(ModelKind::Gat, "Cora", cfg, flags)).unwrap();
        engine
            .plan(&SimRequest::new(ModelKind::Gcn, "Cora", cfg, OptFlags::baseline()))
            .unwrap();
        assert_eq!(engine.plan_builds(), 3);
        // Underneath, Cora was generated and partitioned exactly once.
        assert_eq!(engine.dataset_builds(), 1);
        assert_eq!(engine.partition_builds(), 1);
    }

    #[test]
    fn concurrent_identical_requests_share_one_plan_build() {
        let engine = BatchEngine::new();
        let cfg = GhostConfig::paper_optimal();
        let flags = OptFlags::ghost_default();
        let reqs: Vec<SimRequest> =
            (0..16).map(|_| SimRequest::new(ModelKind::Gcn, "Cora", cfg, flags)).collect();
        for r in engine.run_batch(&reqs) {
            r.expect("every request simulates");
        }
        // The OnceLock cell serializes the build: 16 concurrent identical
        // requests construct the plan exactly once.
        assert_eq!(engine.plan_builds(), 1);
    }

    #[test]
    fn plan_cache_clear_evicts_and_counter_persists() {
        let engine = BatchEngine::new();
        let cfg = GhostConfig::paper_optimal();
        let req = SimRequest::new(ModelKind::Gcn, "Cora", cfg, OptFlags::ghost_default());
        engine.run(&req).unwrap();
        assert_eq!(engine.plan_builds(), 1);
        engine.clear();
        engine.run(&req).unwrap();
        assert_eq!(engine.plan_builds(), 2);
    }

    #[test]
    fn cached_plan_evaluation_matches_uncached_simulation() {
        use crate::coordinator::schedule::simulate_workload;
        let engine = BatchEngine::new();
        let cfg = GhostConfig::paper_optimal();
        let flags = OptFlags::ghost_default();
        for (kind, name) in [(ModelKind::Gcn, "PubMed"), (ModelKind::Gin, "Mutag")] {
            let req = SimRequest::new(kind, name, cfg, flags);
            // Evaluate twice through the cache; both must be bit-identical
            // to the uncached one-shot simulation.
            let first = engine.run(&req).unwrap();
            let second = engine.run(&req).unwrap();
            let ds = Dataset::by_name(name).unwrap();
            let uncached = simulate_workload(kind, &ds, cfg, flags).unwrap();
            for r in [&first, &second] {
                assert_eq!(r.metrics.latency_s, uncached.metrics.latency_s, "{name}");
                assert_eq!(r.metrics.energy_j, uncached.metrics.energy_j, "{name}");
                assert_eq!(r.aggregate_s, uncached.aggregate_s, "{name}");
                assert_eq!(r.weight_stage_s, uncached.weight_stage_s, "{name}");
                assert_eq!(r.kinds, uncached.kinds, "{name}");
            }
        }
        assert_eq!(engine.plan_builds(), 2);
    }

    #[test]
    fn plan_rejects_invalid_requests_without_caching() {
        let engine = BatchEngine::new();
        let bad_cfg = GhostConfig { r_c: 25, ..GhostConfig::paper_optimal() };
        let req =
            SimRequest::new(ModelKind::Gcn, "Cora", bad_cfg, OptFlags::ghost_default());
        assert!(matches!(engine.plan(&req), Err(SimError::InvalidConfig(_))));
        let req = SimRequest::new(
            ModelKind::Gcn,
            "NoSuchDataset",
            GhostConfig::paper_optimal(),
            OptFlags::ghost_default(),
        );
        assert!(matches!(engine.plan(&req), Err(SimError::UnknownDataset(_))));
        assert_eq!(engine.plan_builds(), 0);
        assert_eq!(engine.partition_builds(), 0);
    }

    #[test]
    fn sharded_plan_cache_builds_once_per_shard_count() {
        let engine = BatchEngine::new();
        let cfg = GhostConfig::paper_optimal();
        let flags = OptFlags::ghost_default();
        let req = SimRequest::new(ModelKind::Gcn, "Cora", cfg, flags);
        let a = engine.sharded_plan(&req, 2).unwrap();
        let b = engine.sharded_plan(&req, 2).unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(engine.sharded_plan_builds(), 1);
        // A different shard count is a different schedule.
        engine.sharded_plan(&req, 4).unwrap();
        assert_eq!(engine.sharded_plan_builds(), 2);
        // The single-chip plan cache is a separate dimension.
        assert_eq!(engine.plan_builds(), 0);
        // shards == 0 is rejected before touching any cache.
        assert!(matches!(
            engine.run_sharded(&req, 0),
            Err(SimError::InvalidConfig(_))
        ));
        assert_eq!(engine.sharded_plan_builds(), 2);
        engine.clear();
        engine.sharded_plan(&req, 2).unwrap();
        assert_eq!(engine.sharded_plan_builds(), 3);
    }

    #[test]
    fn one_shard_engine_run_matches_single_chip() {
        let engine = BatchEngine::new();
        let cfg = GhostConfig::paper_optimal();
        let flags = OptFlags::ghost_default();
        let req = SimRequest::new(ModelKind::Gcn, "Cora", cfg, flags);
        let single = engine.run(&req).unwrap();
        let sharded = engine.run_sharded(&req, 1).unwrap();
        assert_eq!(single, sharded);
        let p = engine.service_profile(&req).unwrap();
        let sp = engine.sharded_service_profile(&req, 1).unwrap();
        assert_eq!(p, sp);
    }

    #[test]
    fn over_budget_graph_errors_single_chip_and_runs_sharded() {
        let engine = BatchEngine::new();
        // ~30000 vertices × 128-byte features + 200k edge descriptors
        // ≈ 5.6 MB resident — over a 3 MiB per-chip budget.
        let cfg =
            GhostConfig { chip_mem_bytes: 3 << 20, ..GhostConfig::paper_optimal() };
        let flags = OptFlags::ghost_default();
        let req = SimRequest::new(ModelKind::Gcn, "rmat-30000v-200000e", cfg, flags);
        let err = engine.run(&req).unwrap_err();
        match err {
            SimError::ExceedsChipMemory { footprint_bytes, budget_bytes, min_shards } => {
                assert_eq!(budget_bytes, 3 << 20);
                assert!(footprint_bytes > budget_bytes);
                assert!(min_shards >= 2, "min_shards = {min_shards}");
            }
            other => panic!("expected ExceedsChipMemory, got {other:?}"),
        }
        // The same workload runs end-to-end across 4 simulated chips, with
        // real inter-chip communication in the breakdown.
        let r = engine.run_sharded(&req, 4).unwrap();
        assert!(r.metrics.latency_s > 0.0);
        assert!(r.kinds.remote_gather.latency_s > 0.0);
        assert!(r.kinds.remote_gather.energy_j > 0.0);
        let plan = engine.sharded_plan(&req, 4).unwrap();
        assert!(plan.shard_plan.fits_budget(cfg.chip_mem_bytes));
    }

    /// The churn-safety regression pin: once a dataset mutates (epoch
    /// bump), every cache row keyed at the old epoch is unreachable for
    /// the mutated instance and evictable by name — a mutated dataset can
    /// never be served a stale partition set, plan, or [`ServiceProfile`].
    #[test]
    fn mutated_dataset_epoch_keys_and_eviction_prevent_stale_serving() {
        use crate::graph::mutate::{self, GraphDelta};
        let engine = BatchEngine::new();
        let cfg = GhostConfig::paper_optimal();
        let flags = OptFlags::ghost_default();
        let req = SimRequest::new(ModelKind::Gcn, "Cora", cfg, flags);
        let stale = engine.service_profile(&req).unwrap();
        assert_eq!(engine.profile_builds(), 1);
        // Mutate a private copy (the engine's canonical Arc is immutable):
        // the epoch bumps and the spliced partitions ride along.
        let mut ds = (*engine.dataset("Cora").unwrap()).clone();
        let mut pms = (*engine.partitions_for(&ds, cfg.v, cfg.n).unwrap()).clone();
        let batch = vec![GraphDelta::AddEdge { src: 0, dst: 1 }; 50];
        mutate::apply_to_dataset(&mut ds, &mut pms, 0, &batch).unwrap();
        assert_eq!(ds.epoch, 1);
        // One partition set, one plan, one profile were cached at epoch 0.
        let evicted = engine.evict_dataset_epochs_below("Cora", ds.epoch);
        assert_eq!(evicted, 3, "epoch-0 partition/plan/profile rows must go");
        assert_eq!(engine.evictions(), 3);
        // The mutated instance keys its own partition row (epoch 1) and
        // gets partitions matching its mutated graph, not Cora's.
        let fresh = engine.partitions_for(&ds, cfg.v, cfg.n).unwrap();
        assert_eq!(fresh[0].total_edges(), ds.graphs[0].n_edges() as u64);
        let again = engine.partitions_for(&ds, cfg.v, cfg.n).unwrap();
        assert!(Arc::ptr_eq(&fresh, &again), "epoch-keyed row is cached, not a fallback");
        // A canonical re-request re-simulates — the stale profile is gone
        // from the map, and the canonical state is unchanged, so the new
        // value agrees.
        let rebuilt = engine.service_profile(&req).unwrap();
        assert_eq!(engine.profile_builds(), 2, "stale profile must not be served");
        assert_eq!(stale, rebuilt);
        // from_report is the same formula the cached path used.
        let r = engine.run(&req).unwrap();
        assert_eq!(ServiceProfile::from_report(&r), rebuilt);
    }

    #[test]
    fn global_engine_is_one_instance() {
        let a = BatchEngine::global() as *const BatchEngine;
        let b = BatchEngine::global() as *const BatchEngine;
        assert_eq!(a, b);
    }
}
