//! The batched simulation engine — the session object behind every sweep.
//!
//! GHOST's evaluation (Figs. 7–9) is thousands of `(model, dataset,
//! config, flags)` simulations, and the dominant cost of each is the
//! offline graph preprocessing: generating the dataset and building its
//! `V×N` [`PartitionMatrix`] set. Both depend only on `(dataset, V, N)` —
//! never on the model, the array shapes `R_r/R_c/T_r`, or the optimization
//! flags — so a sweep that rebuilds them per simulation does the same work
//! hundreds of times over.
//!
//! [`BatchEngine`] amortizes that cost behind two concurrent caches:
//!
//! * a dataset cache keyed by canonical dataset name — a Table-2 name, a
//!   large-tier name (`ogbn-arxiv-syn`, `reddit-syn`), or a parameterized
//!   `rmat-<V>v-<E>e...` spec (see [`crate::graph::datasets`]) — and
//! * a partition cache keyed by `(dataset, V, N)`.
//!
//! Each cache entry is an [`OnceLock`] cell, so concurrent requests for
//! the same key build **at most once** (losers block on the winner instead
//! of duplicating the build); [`BatchEngine::partition_builds`] counts the
//! actual builds so tests can verify the guarantee. Batches of
//! [`SimRequest`]s fan out over [`crate::util::parallel::par_map`] and
//! every failure comes back as a structured [`SimError`] value — a bad
//! point degrades to a reported error, never a process abort.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};

use crate::config::GhostConfig;
use crate::gnn::models::ModelKind;
use crate::graph::datasets::{spec_by_name, Dataset};
use crate::graph::partition::PartitionMatrix;
use crate::util::parallel::par_map;

use super::error::SimError;
use super::optimizations::OptFlags;
use super::schedule::{simulate_with_partitions, SimReport};

/// One simulation to run: the full `(model, dataset, config, flags)` tuple.
#[derive(Debug, Clone, PartialEq)]
pub struct SimRequest {
    pub model: ModelKind,
    /// Table-2 dataset name (case-insensitive).
    pub dataset: String,
    pub cfg: GhostConfig,
    pub flags: OptFlags,
}

impl SimRequest {
    pub fn new(
        model: ModelKind,
        dataset: impl Into<String>,
        cfg: GhostConfig,
        flags: OptFlags,
    ) -> Self {
        Self { model, dataset: dataset.into(), cfg, flags }
    }
}

type DatasetCell = Arc<OnceLock<Arc<Dataset>>>;
type PartitionCell = Arc<OnceLock<Arc<Vec<PartitionMatrix>>>>;
type PartitionKey = (String, usize, usize);

/// Cached, parallel batch simulation session. Cheap to share by reference
/// across threads; see the module docs for the caching contract.
#[derive(Default)]
pub struct BatchEngine {
    datasets: Mutex<HashMap<String, DatasetCell>>,
    partitions: Mutex<HashMap<PartitionKey, PartitionCell>>,
    dataset_builds: AtomicUsize,
    partition_builds: AtomicUsize,
}

/// Locks a mutex, recovering the guard from a poisoned lock (the protected
/// maps are always left consistent, so a panicked peer is harmless and the
/// hot path must not cascade the panic).
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Cheap structural check that a cached partition set was built from (a
/// dataset identical in shape to) `dataset`: same graph count and, per
/// graph, same vertex and edge counts.
fn partitions_match(pms: &[PartitionMatrix], dataset: &Dataset) -> bool {
    pms.len() == dataset.graphs.len()
        && pms
            .iter()
            .zip(&dataset.graphs)
            .all(|(pm, g)| pm.n_vertices == g.n_vertices && pm.total_edges() == g.n_edges() as u64)
}

impl BatchEngine {
    pub fn new() -> Self {
        Self::default()
    }

    /// A process-wide shared engine: the figure/table regeneration paths
    /// all run through it, so `figures --all` (and the test suite) builds
    /// each dataset and partition set once for the whole process.
    ///
    /// Cached entries live until [`Self::clear`] is called. The footprint
    /// is bounded by the eight Table-2 datasets times the distinct `(V, N)`
    /// shapes requested; long-running consumers sweeping many shapes
    /// should use their own [`BatchEngine::new`] (dropped with the sweep)
    /// or call `clear()` between sweeps.
    pub fn global() -> &'static BatchEngine {
        static GLOBAL: OnceLock<BatchEngine> = OnceLock::new();
        GLOBAL.get_or_init(BatchEngine::new)
    }

    /// Drops every cached dataset and partition set (in-flight users keep
    /// their `Arc`s alive until they finish). The build counters are *not*
    /// reset: they count builds ever performed, and keep exposing re-build
    /// churn after a clear.
    pub fn clear(&self) {
        lock(&self.datasets).clear();
        lock(&self.partitions).clear();
    }

    /// The realized dataset for a name in any tier (Table-2, large-graph,
    /// or parameterized `rmat-...`), generated at most once per engine.
    /// Lookup is case-insensitive and parameterized specs canonicalize
    /// (`"cora"`/`"Cora"` share one entry; so do `"rmat-1000v-5000e"` and
    /// `"RMAT-1000v-5000e-128f"`).
    pub fn dataset(&self, name: &str) -> Result<Arc<Dataset>, SimError> {
        let spec =
            spec_by_name(name).ok_or_else(|| SimError::UnknownDataset(name.to_string()))?;
        let cell: DatasetCell =
            lock(&self.datasets).entry(spec.name.to_string()).or_default().clone();
        // Built outside the map lock; concurrent losers block on the cell.
        let ds = cell.get_or_init(|| {
            self.dataset_builds.fetch_add(1, Ordering::Relaxed);
            Arc::new(Dataset::generate(spec))
        });
        Ok(ds.clone())
    }

    /// The `(V, N)` partition set of every graph in `dataset`, built at
    /// most once per distinct `(dataset, V, N)` key for this engine's
    /// lifetime and shared by all simulations that need it.
    pub fn partitions_for(
        &self,
        dataset: &Dataset,
        v: usize,
        n: usize,
    ) -> Result<Arc<Vec<PartitionMatrix>>, SimError> {
        if v == 0 || n == 0 {
            return Err(SimError::InvalidConfig(format!(
                "partition dimensions must be non-zero, got (V, N) = ({v}, {n})"
            )));
        }
        let key: PartitionKey = (dataset.spec.name.to_string(), v, n);
        let cell: PartitionCell = lock(&self.partitions).entry(key).or_default().clone();
        let pms = cell.get_or_init(|| {
            self.partition_builds.fetch_add(1, Ordering::Relaxed);
            Arc::new(PartitionMatrix::build_all(&dataset.graphs, v, n))
        });
        // The cache is keyed by name and first-writer-wins; a caller may
        // hold a *modified* Dataset under a canonical name (the fields are
        // public). If the cached set does not match this instance's graph
        // shapes, fall back to an uncached (but counted) build from the
        // dataset actually passed in. The match is structural — graph count
        // plus per-graph vertex/edge counts — not content-exact: a
        // hand-rewired graph with identical counts will still alias, and a
        // modified instance arriving *first* keeps the key, demoting later
        // canonical callers to the fallback. Callers mixing modified and
        // canonical instances of one name should use separate engines (or
        // simulate_workload, which never touches the cache).
        if !partitions_match(pms, dataset) {
            self.partition_builds.fetch_add(1, Ordering::Relaxed);
            return Ok(Arc::new(PartitionMatrix::build_all(&dataset.graphs, v, n)));
        }
        Ok(pms.clone())
    }

    /// Dataset-by-name convenience for [`Self::partitions_for`].
    pub fn partitions(
        &self,
        dataset_name: &str,
        v: usize,
        n: usize,
    ) -> Result<Arc<Vec<PartitionMatrix>>, SimError> {
        let ds = self.dataset(dataset_name)?;
        self.partitions_for(&ds, v, n)
    }

    /// How many dataset generations this engine has actually performed.
    pub fn dataset_builds(&self) -> usize {
        self.dataset_builds.load(Ordering::Relaxed)
    }

    /// How many partition sets this engine has actually built: one per
    /// distinct `(dataset, V, N)` key ever requested — regardless of how
    /// many simulations shared it — plus any structural-mismatch fallback
    /// builds (see [`Self::partitions_for`]), so cache churn is visible.
    pub fn partition_builds(&self) -> usize {
        self.partition_builds.load(Ordering::Relaxed)
    }

    /// Runs one simulation through the caches.
    pub fn run(&self, req: &SimRequest) -> Result<SimReport, SimError> {
        req.cfg.validate().map_err(SimError::InvalidConfig)?;
        req.flags.validate().map_err(SimError::InvalidFlags)?;
        let dataset = self.dataset(&req.dataset)?;
        let partitions = self.partitions_for(&dataset, req.cfg.v, req.cfg.n)?;
        simulate_with_partitions(req.model, &dataset, &partitions, req.cfg, req.flags)
    }

    /// Fans a batch of requests out over the scoped thread pool
    /// ([`crate::util::parallel::par_map`]). Results come back in request
    /// order; each failure is a per-request [`SimError`], so one bad
    /// request never sinks the batch.
    pub fn run_batch(&self, reqs: &[SimRequest]) -> Vec<Result<SimReport, SimError>> {
        par_map(reqs, |req| self.run(req))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dataset_cache_is_case_insensitive_and_shared() {
        let engine = BatchEngine::new();
        let a = engine.dataset("Cora").unwrap();
        let b = engine.dataset("cora").unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(engine.dataset_builds(), 1);
    }

    #[test]
    fn unknown_dataset_is_a_value_not_a_panic() {
        let engine = BatchEngine::new();
        assert_eq!(
            engine.dataset("NoSuchDataset").unwrap_err(),
            SimError::UnknownDataset("NoSuchDataset".into())
        );
    }

    #[test]
    fn partition_cache_reuses_by_shape_key() {
        let engine = BatchEngine::new();
        let a = engine.partitions("Cora", 20, 20).unwrap();
        let b = engine.partitions("Cora", 20, 20).unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        let c = engine.partitions("Cora", 10, 20).unwrap();
        assert!(!Arc::ptr_eq(&a, &c));
        assert_eq!(engine.partition_builds(), 2);
    }

    #[test]
    fn zero_shape_rejected_before_the_partition_assert() {
        let engine = BatchEngine::new();
        let ds = engine.dataset("Cora").unwrap();
        assert!(matches!(
            engine.partitions_for(&ds, 0, 20),
            Err(SimError::InvalidConfig(_))
        ));
    }

    #[test]
    fn run_validates_config_and_flags_first() {
        let engine = BatchEngine::new();
        let bad_cfg = GhostConfig { r_c: 25, ..GhostConfig::paper_optimal() };
        let req =
            SimRequest::new(ModelKind::Gcn, "Cora", bad_cfg, OptFlags::ghost_default());
        assert!(matches!(engine.run(&req), Err(SimError::InvalidConfig(_))));
        let bad_flags =
            OptFlags { workload_balancing: true, ..OptFlags::ghost_default() };
        let req = SimRequest::new(
            ModelKind::Gcn,
            "Cora",
            GhostConfig::paper_optimal(),
            bad_flags,
        );
        assert!(matches!(engine.run(&req), Err(SimError::InvalidFlags(_))));
        // Nothing was cached for the rejected requests.
        assert_eq!(engine.partition_builds(), 0);
    }

    #[test]
    fn batch_preserves_order_and_isolates_failures() {
        let engine = BatchEngine::new();
        let cfg = GhostConfig::paper_optimal();
        let flags = OptFlags::ghost_default();
        let reqs = vec![
            SimRequest::new(ModelKind::Gcn, "Cora", cfg, flags),
            SimRequest::new(ModelKind::Gcn, "NoSuchDataset", cfg, flags),
            SimRequest::new(ModelKind::Gat, "Cora", cfg, flags),
        ];
        let results = engine.run_batch(&reqs);
        assert_eq!(results.len(), 3);
        assert!(results[0].is_ok());
        assert!(matches!(results[1], Err(SimError::UnknownDataset(_))));
        assert!(results[2].is_ok());
        assert_eq!(results[0].as_ref().unwrap().model, ModelKind::Gcn);
        assert_eq!(results[2].as_ref().unwrap().model, ModelKind::Gat);
    }

    #[test]
    fn modified_dataset_never_gets_stale_cached_partitions() {
        let engine = BatchEngine::new();
        let canonical = engine.dataset("Cora").unwrap();
        let cached = engine.partitions_for(&canonical, 20, 20).unwrap();
        // Same canonical name, different graph: the cache must not serve
        // Cora's partitions for it.
        let modified = Dataset {
            spec: canonical.spec,
            graphs: vec![crate::graph::csr::CsrGraph::from_edges(10, &[(0, 1), (1, 2)])],
        };
        let fresh = engine.partitions_for(&modified, 20, 20).unwrap();
        assert!(!Arc::ptr_eq(&cached, &fresh));
        assert_eq!(fresh[0].n_vertices, 10);
        // Canonical requests still hit the cache.
        let again = engine.partitions_for(&canonical, 20, 20).unwrap();
        assert!(Arc::ptr_eq(&cached, &again));
    }

    #[test]
    fn clear_evicts_caches_but_counters_persist() {
        let engine = BatchEngine::new();
        engine.partitions("Cora", 20, 20).unwrap();
        assert_eq!(engine.partition_builds(), 1);
        engine.clear();
        engine.partitions("Cora", 20, 20).unwrap();
        assert_eq!(engine.partition_builds(), 2);
        assert_eq!(engine.dataset_builds(), 2);
    }

    #[test]
    fn global_engine_is_one_instance() {
        let a = BatchEngine::global() as *const BatchEngine;
        let b = BatchEngine::global() as *const BatchEngine;
        assert_eq!(a, b);
    }
}
