//! The typed schedule IR — `StagePlan`.
//!
//! The paper's execution model (§3.4.2 / Fig. 6) is a *stage-level* one:
//! every `(graph, layer, output-vertex group)` runs a fixed pipeline of
//! gather → reduce → transform → update stages (GAT re-orders the same
//! stages), bracketed by per-graph edge-descriptor streams, per-layer
//! weight staging, and a per-graph readout for graph classification. This
//! module makes that model an explicit, typed value instead of the
//! anonymous `Vec<Vec<f64>>` latency rows the scheduler used to hand-thread
//! through one long function:
//!
//! * [`build`] — plan *construction*: maps `(model, dataset, partitions,
//!   config, flags)` onto a [`StagePlan`] whose stages are tagged with a
//!   [`StageKind`] and carry a full [`StageCost`] (latency **and** energy).
//!   Construction is where all the architecture-block cost modelling
//!   happens, and for multi-graph datasets it fans out over
//!   [`crate::util::parallel::par_map`] (one worker item per graph).
//! * [`evaluate`] — plan *evaluation*: runs the pipelined recurrence
//!   ([`crate::sim::pipelined_costs`]) over every segment and derives the
//!   complete [`SimReport`] — makespan, energy, the legacy per-block busy
//!   split, and the exact per-kind totals ([`KindTotals`]) — in one walk.
//!
//! A plan is immutable and depends only on its `(model, dataset, config,
//! flags)` key, so [`crate::coordinator::engine::BatchEngine`] caches
//! plans and re-evaluates them for free; sweeps that re-visit a tuple
//! (figure regeneration, serving profiles, ablation re-runs) skip
//! construction entirely.
//!
//! Evaluation reproduces the pre-IR simulator **bit-identically**: every
//! floating-point accumulation happens at the same granularity and in the
//! same order as the legacy single-pass code (pinned by a property test in
//! [`crate::coordinator::schedule`] against the retained reference
//! implementation).

use crate::arch::{aggregate, combine, ecu, update, ArchContext, StageCost};
use crate::config::{ceil_div, GhostConfig};
use crate::energy::Metrics;
use crate::gnn::models::{Activation, ExecOrdering, LayerSpec, Model, ModelKind};
use crate::gnn::workload::Workload;
use crate::graph::datasets::Dataset;
use crate::graph::partition::{OutputGroupPlan, PartitionMatrix, ShardPlan};
use crate::sim;
use crate::util::json::{obj, Json};
use crate::util::parallel::par_map;
use crate::util::telemetry;
use crate::util::telemetry::trace as ttrace;

use super::error::SimError;
use super::optimizations::OptFlags;
use super::schedule::SimReport;
use super::soa::{EvalHeader, ParamSet, PlanSoA, SoaEntry};

/// Fraction of MR banks whose per-layer retarget exceeds the EO range and
/// needs the TO heater (with TED decoupling).
pub const TO_RETUNE_FRACTION: f64 = 0.05;

/// Stage count of every pipelined segment: the four-slot pipeline of
/// §3.4.2 (gather/reduce/transform/update, in either execution ordering).
pub const PIPELINE_STAGES: usize = 4;

/// Plans below this many `(group, layer)` slots build serially: the work
/// is too small to amortize spawning scoped workers, and callers that are
/// already running on the thread pool (`BatchEngine::run_batch`, the DSE
/// grid, the serve resolver) should not pay a nested fan-out for tiny
/// multi-graph corpora. Mirrors the partition builder's
/// widest-level-only rule (`graph::partition::PAR_EDGE_THRESHOLD`).
const PAR_SLOT_THRESHOLD: usize = 4096;

/// What a stage does — the taxonomy every consumer (Fig. 9 breakdowns,
/// serving profiles, DSE bottleneck analysis) queries the plan by.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum StageKind {
    /// Per-graph edge/partition descriptor stream into the ECU (serial,
    /// once per graph, before any layer runs).
    EdgeStream,
    /// Per-layer weight staging + TO retargeting of the MR banks (serial,
    /// once per layer per dataset — the layer-major schedule amortizes it
    /// across graphs and, online, across same-tenant batches).
    WeightStage,
    /// Sharded execution only: receiving the halo (ghost-vertex) features
    /// this chip's gathers need from chip `src_chip` over the inter-chip
    /// link ([`crate::arch::LinkParams`]), before a layer's segments can
    /// run. Serial against the chip's local work; a barrier precedes any
    /// layer that has one.
    RemoteGather { src_chip: u32 },
    /// Neighbor-feature gather feeding the aggregate block. `from_dram`
    /// records whether the layer's input feature map spilled past the
    /// input-vertex buffer (layer 0 always streams from DRAM).
    Gather { from_dram: bool },
    /// Coherent summation on the aggregate block's reduce arrays.
    Reduce,
    /// Weight transform (plus attention logits for GAT) on the combine
    /// block.
    Transform,
    /// Activation / softmax + writeback in the update block.
    Update,
    /// Graph-classification sum-pool readout on the reduce arrays (serial,
    /// once per graph, after the last layer).
    Readout,
}

/// The physical block a stage occupies in the Fig. 9 latency breakdown.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Block {
    /// Gather + reduce (+ readout, which runs on the reduce arrays).
    Aggregate,
    Combine,
    Update,
}

impl StageKind {
    /// Snake-case name used by the JSON figure output.
    pub fn name(&self) -> &'static str {
        match self {
            StageKind::EdgeStream => "edge_stream",
            StageKind::WeightStage => "weight_stage",
            StageKind::RemoteGather { .. } => "remote_gather",
            StageKind::Gather { .. } => "gather",
            StageKind::Reduce => "reduce",
            StageKind::Transform => "transform",
            StageKind::Update => "update",
            StageKind::Readout => "readout",
        }
    }

    /// Which Fig. 9 block this stage's busy time is attributed to; `None`
    /// for the ECU/DRAM path stages (edge streams, weight staging) that
    /// the per-block breakdown never counted.
    pub fn block(&self) -> Option<Block> {
        match self {
            StageKind::Gather { .. } | StageKind::Reduce | StageKind::Readout => {
                Some(Block::Aggregate)
            }
            StageKind::Transform => Some(Block::Combine),
            StageKind::Update => Some(Block::Update),
            StageKind::EdgeStream
            | StageKind::WeightStage
            | StageKind::RemoteGather { .. } => None,
        }
    }

    /// Cost provenance: which [`crate::config::GhostConfig`] parameters
    /// this kind's stage cost depends on (directly or through the cost
    /// helpers it is built from). The delta evaluator
    /// ([`crate::coordinator::soa::DeltaPlan`]) re-costs a lane between
    /// neighboring sweep points only when its provenance intersects the
    /// changed parameter set; `chip_mem_bytes`, `n`, and `v` changes
    /// rebuild outright (they reshape the partition / plan structure), so
    /// only the `r_r` / `r_c` / `t_r` bits below ever gate a patch.
    ///
    /// Derivation, per kind:
    /// * `EdgeStream` — `ecu::edge_stage_cost` reads HBM/ECU constants
    ///   only.
    /// * `WeightStage` — the HBM stream is config-free, but the retune
    ///   floor/energy scale with the MR bank counts
    ///   (`aggregate_mrs`/`combine_mrs`: `v`, `r_r`, `r_c`, `t_r`).
    /// * `RemoteGather` — link parameters only.
    /// * `Gather` — lane count `v` (own-vertex bytes, effective-group
    ///   capping); HBM/buffer constants otherwise.
    /// * `Reduce` — `v` (balanced effective degree), `r_c` (passes),
    ///   `r_r` (chunks, VCSEL/PD idle-energy term).
    /// * `Transform` — `r_r` (input chunks), `t_r` (output chunks / tile),
    ///   `v` (vector count); GAT attention adds `t_r`, `r_r` passes.
    /// * `Update` — `t_r` (activation passes), `v` (softmax lanes).
    /// * `Readout` — `v`/`r_c` (vertex passes), `r_r` (width chunks).
    pub fn provenance(&self) -> ParamSet {
        match self {
            StageKind::EdgeStream | StageKind::RemoteGather { .. } => ParamSet::NONE,
            StageKind::WeightStage => ParamSet::V
                .union(ParamSet::R_R)
                .union(ParamSet::R_C)
                .union(ParamSet::T_R),
            StageKind::Gather { .. } => ParamSet::V,
            StageKind::Reduce | StageKind::Readout => {
                ParamSet::V.union(ParamSet::R_R).union(ParamSet::R_C)
            }
            StageKind::Transform => {
                ParamSet::V.union(ParamSet::R_R).union(ParamSet::T_R)
            }
            StageKind::Update => ParamSet::V.union(ParamSet::T_R),
        }
    }
}

/// Exact per-[`StageKind`] busy-time and dynamic-energy totals of one
/// evaluated plan — the first-class Fig. 9 extension (readout and weight
/// staging as their own bars instead of being folded into aggregate).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct KindTotals {
    pub edge_stream: StageCost,
    pub weight_stage: StageCost,
    /// Inter-chip halo transfers; zero for every single-chip (unsharded)
    /// plan.
    pub remote_gather: StageCost,
    pub gather: StageCost,
    pub reduce: StageCost,
    pub transform: StageCost,
    pub update: StageCost,
    pub readout: StageCost,
}

impl KindTotals {
    fn add(&mut self, kind: StageKind, latency_s: f64, energy_j: f64) {
        let slot = match kind {
            StageKind::EdgeStream => &mut self.edge_stream,
            StageKind::WeightStage => &mut self.weight_stage,
            StageKind::RemoteGather { .. } => &mut self.remote_gather,
            StageKind::Gather { .. } => &mut self.gather,
            StageKind::Reduce => &mut self.reduce,
            StageKind::Transform => &mut self.transform,
            StageKind::Update => &mut self.update,
            StageKind::Readout => &mut self.readout,
        };
        slot.latency_s += latency_s;
        slot.energy_j += energy_j;
    }

    /// `(kind name, totals)` rows in schedule order.
    pub fn rows(&self) -> [(&'static str, StageCost); 8] {
        [
            ("edge_stream", self.edge_stream),
            ("weight_stage", self.weight_stage),
            ("remote_gather", self.remote_gather),
            ("gather", self.gather),
            ("reduce", self.reduce),
            ("transform", self.transform),
            ("update", self.update),
            ("readout", self.readout),
        ]
    }

    /// Total busy time across every kind, seconds.
    pub fn busy_s(&self) -> f64 {
        self.rows().iter().map(|(_, c)| c.latency_s).sum()
    }

    /// Total dynamic energy across every kind, joules.
    pub fn energy_j(&self) -> f64 {
        self.rows().iter().map(|(_, c)| c.energy_j).sum()
    }
}

/// The pipelined stages of one `(layer, graph)`: a `groups × 4` matrix of
/// stage costs, with the per-position kinds (identical for every group of
/// the segment) alongside.
#[derive(Debug, Clone)]
pub struct PipelineSegment {
    /// Layer index within the model.
    pub layer: u32,
    /// Graph index within the dataset.
    pub graph: u32,
    /// Stage kind at each of the four pipeline positions.
    pub kinds: [StageKind; PIPELINE_STAGES],
    /// Group-major stage costs: `costs[g * PIPELINE_STAGES + s]`.
    pub costs: Vec<StageCost>,
}

impl PipelineSegment {
    pub fn n_groups(&self) -> usize {
        self.costs.len() / PIPELINE_STAGES
    }

    /// Iterator over per-group stage-cost rows.
    pub fn groups(&self) -> std::slice::Chunks<'_, StageCost> {
        self.costs.chunks(PIPELINE_STAGES)
    }
}

/// One entry of a plan, in schedule order.
#[derive(Debug, Clone)]
pub enum PlanItem {
    /// A stage that runs serially against everything else (edge streams,
    /// weight staging, readout).
    Serial { kind: StageKind, cost: StageCost },
    /// A two-level-pipelined `(layer, graph)` segment.
    Pipeline(PipelineSegment),
}

/// The complete typed schedule of one `(model, dataset, config, flags)`
/// tuple. Immutable once built; evaluation ([`evaluate`]) is cheap and
/// repeatable, which is what the engine's plan cache exploits.
#[derive(Debug, Clone)]
pub struct StagePlan {
    pub model: ModelKind,
    pub dataset: String,
    pub cfg: GhostConfig,
    pub flags: OptFlags,
    /// Plan items in schedule order: per-graph edge streams, then for each
    /// layer (layer-major across graphs) the weight stage followed by one
    /// pipelined segment per graph, then per-graph readouts.
    pub items: Vec<PlanItem>,
    /// Post-layer-0 gathers whose input feature map spilled to DRAM (one
    /// per `(layer, graph)` pair with an aggregation).
    pub spilled_layer_gathers: usize,
    /// Always-on platform power for this configuration, watts.
    pub platform_w: f64,
    /// Workload op count (for [`Metrics`]).
    pub ops: u64,
    /// Workload bit count (for [`Metrics`]).
    pub bits: u64,
    /// Structure-of-arrays lowering of `items`, cached at build time —
    /// what [`evaluate`] actually walks.
    pub soa: PlanSoA,
}

impl StagePlan {
    /// Number of pipelined `(layer, graph)` segments.
    pub fn n_segments(&self) -> usize {
        self.items.iter().filter(|i| matches!(i, PlanItem::Pipeline(_))).count()
    }

    /// Total stage count: serial stages plus every `(group, position)`
    /// slot of every segment.
    pub fn n_stages(&self) -> usize {
        self.items
            .iter()
            .map(|i| match i {
                PlanItem::Serial { .. } => 1,
                PlanItem::Pipeline(seg) => seg.costs.len(),
            })
            .sum()
    }
}

/// Builds the typed plan for a workload over pre-built partitions
/// (`partitions[i]` must be the `(cfg.v, cfg.n)` partition of
/// `dataset.graphs[i]`). Multi-graph datasets construct their per-graph
/// segments in parallel; the assembled plan is identical for any worker
/// count because graphs are independent and assembly is ordered.
pub fn build(
    kind: ModelKind,
    dataset: &Dataset,
    partitions: &[PartitionMatrix],
    cfg: GhostConfig,
    flags: OptFlags,
) -> Result<StagePlan, SimError> {
    let _span = telemetry::span("plan.build");
    cfg.validate().map_err(SimError::InvalidConfig)?;
    flags.validate().map_err(SimError::InvalidFlags)?;
    // Real checks, not debug_asserts: a mismatched partition silently
    // produces wrong metrics in --release otherwise.
    if partitions.len() != dataset.graphs.len() {
        return Err(SimError::PartitionCountMismatch {
            expected: dataset.graphs.len(),
            got: partitions.len(),
        });
    }
    if let Some(pm) = partitions.iter().find(|p| p.v != cfg.v || p.n != cfg.n) {
        return Err(SimError::PartitionShapeMismatch {
            expected: (cfg.v, cfg.n),
            got: (pm.v, pm.n),
        });
    }
    let ctx = ArchContext::paper(cfg);
    let model = Model::for_dataset(kind, &dataset.spec);
    check_chip_memory(&model, partitions, cfg)?;
    let workload = Workload::characterize(&model, dataset);

    let n_graphs = dataset.graphs.len();
    let n_layers = model.layers.len();
    let mut items = Vec::with_capacity(
        n_graphs * (1 + n_layers)
            + n_layers
            + if model.has_readout { n_graphs } else { 0 },
    );

    // Edge/partition descriptors stream in once per graph.
    for g in &dataset.graphs {
        items.push(PlanItem::Serial {
            kind: StageKind::EdgeStream,
            cost: ecu::edge_stage_cost(&ctx, g.n_edges() as u64 * 8),
        });
    }

    // Per-graph segments for every layer. Graphs are independent, so
    // large multi-graph datasets fan out one worker item per graph; tiny
    // corpora (and single graphs) build serially to avoid a nested
    // fan-out under already-parallel sweep callers. The spill test
    // (per-graph residency, see `StageKind::Gather`) rides along. The
    // result is identical either way: par_map preserves order and graphs
    // are computed independently.
    let build_graph = |gi: usize| -> (Vec<PipelineSegment>, usize) {
        let pm = &partitions[gi];
        let mut segs = Vec::with_capacity(n_layers);
        let mut spills = 0usize;
        for (li, layer) in model.layers.iter().enumerate() {
            let feat_bytes = pm.n_vertices * layer.in_dim;
            let from_dram =
                li == 0 || feat_bytes > ctx.buffers.input_vertices.size_bytes;
            if li > 0 && from_dram && layer.reduction.is_some() {
                spills += 1;
            }
            segs.push(build_segment(&ctx, &model, li, layer, gi, &pm.groups, flags, from_dram));
        }
        (segs, spills)
    };
    let total_groups: usize = partitions.iter().map(|pm| pm.groups.len()).sum();
    let per_graph: Vec<(Vec<PipelineSegment>, usize)> =
        if n_graphs > 1 && total_groups * n_layers >= PAR_SLOT_THRESHOLD {
            let graph_idx: Vec<usize> = (0..n_graphs).collect();
            par_map(&graph_idx, |&gi| build_graph(gi))
        } else {
            (0..n_graphs).map(build_graph).collect()
        };
    let spilled_layer_gathers: usize = per_graph.iter().map(|(_, s)| *s).sum();

    // Assemble layer-major (all graphs through layer `l`, then `l+1`), so
    // each weight matrix is staged and the banks TO-retargeted once per
    // layer per dataset, not once per graph.
    let weight_stages: Vec<StageCost> =
        model.layers.iter().map(|layer| weight_stage_item(&ctx, layer)).collect();
    let per_graph_segments: Vec<Vec<PipelineSegment>> =
        per_graph.into_iter().map(|(segs, _)| segs).collect();
    interleave_layer_major(per_graph_segments, &weight_stages, &mut items)?;

    // Graph-classification readout: sum-pool each graph's vertex
    // embeddings — the *output* of the last layer, `out_dim × heads` wide —
    // on the reduce arrays.
    if model.has_readout {
        let width = model.layers.last().map(|l| l.out_dim * l.heads).unwrap_or(0);
        for g in &dataset.graphs {
            items.push(PlanItem::Serial {
                kind: StageKind::Readout,
                cost: readout_item(&ctx, g.n_vertices, width),
            });
        }
    }

    let soa = PlanSoA::lower_single(&items, flags.pipelining);
    Ok(StagePlan {
        model: kind,
        dataset: dataset.spec.name.to_string(),
        cfg,
        flags,
        items,
        spilled_layer_gathers,
        platform_w: crate::arch::platform_power_w(&ctx, flags.dac_sharing),
        ops: workload.total_ops(),
        bits: workload.total_bits(),
        soa,
    })
}

/// The widest per-vertex feature state (bytes at 8-bit quantization) any
/// layer keeps resident: the max over layer input widths and the final
/// output width — what the footprint / shard-balancing model charges per
/// vertex.
fn resident_feat_bytes(model: &Model) -> usize {
    let mut w = 0;
    for l in &model.layers {
        w = w.max(l.in_dim);
    }
    if let Some(l) = model.layers.last() {
        w = w.max(l.out_dim * l.heads);
    }
    w
}

/// Rejects workloads whose single-chip resident footprint exceeds the
/// configured per-chip memory budget, naming the smallest shard count
/// whose even split could hold it. `pub(crate)` so the churn engine's
/// [`super::soa::GraphDeltaPlan`] can re-gate a vertex-grown graph on the
/// patch path exactly as a cold build would.
pub(crate) fn check_chip_memory(
    model: &Model,
    partitions: &[PartitionMatrix],
    cfg: GhostConfig,
) -> Result<(), SimError> {
    let feat = resident_feat_bytes(model);
    let footprint =
        partitions.iter().map(|pm| pm.footprint_bytes(feat)).max().unwrap_or(0);
    if footprint > cfg.chip_mem_bytes {
        return Err(SimError::ExceedsChipMemory {
            footprint_bytes: footprint,
            budget_bytes: cfg.chip_mem_bytes,
            min_shards: footprint.div_ceil(cfg.chip_mem_bytes) as usize,
        });
    }
    Ok(())
}

/// Cost of staging one layer's weight matrix into the MR banks: the HBM
/// stream overlapped with (bounded below by) the TO retarget latency, plus
/// the retune energy.
pub(crate) fn weight_stage_item(ctx: &ArchContext, layer: &LayerSpec) -> StageCost {
    let wc =
        ecu::weight_stage_cost(ctx, (layer.in_dim * layer.out_dim * layer.heads) as u64);
    StageCost {
        latency_s: wc.latency_s.max(ctx.dev.to_tuning.latency_s),
        energy_j: wc.energy_j + to_retune_energy(ctx),
    }
}

/// Cost of the sum-pool readout over `n_vertices` embeddings of `width`
/// elements on the reduce arrays.
pub(crate) fn readout_item(ctx: &ArchContext, n_vertices: usize, width: usize) -> StageCost {
    let cfg = &ctx.cfg;
    let passes = ceil_div(n_vertices, cfg.v * cfg.r_c) * ceil_div(width, cfg.r_r);
    StageCost {
        latency_s: passes as f64 * ctx.symbol_s(),
        energy_j: (n_vertices * width) as f64 * ctx.dev.dac.energy_j(),
    }
}

/// Assembles per-graph segment lists into the layer-major item order (the
/// weight stage of layer `l`, then every graph's layer-`l` segment),
/// returning a structured error — not a panic — if any graph's segment
/// list does not have exactly one segment per layer.
pub(crate) fn interleave_layer_major(
    per_graph_segments: Vec<Vec<PipelineSegment>>,
    weight_stages: &[StageCost],
    items: &mut Vec<PlanItem>,
) -> Result<(), SimError> {
    let n_layers = weight_stages.len();
    for (gi, segs) in per_graph_segments.iter().enumerate() {
        if segs.len() != n_layers {
            return Err(SimError::SegmentShapeMismatch {
                graph: gi,
                expected: n_layers,
                got: segs.len(),
            });
        }
    }
    let mut iters: Vec<std::vec::IntoIter<PipelineSegment>> =
        per_graph_segments.into_iter().map(|s| s.into_iter()).collect();
    for wc in weight_stages {
        items.push(PlanItem::Serial { kind: StageKind::WeightStage, cost: *wc });
        for segs in iters.iter_mut() {
            // The per-graph lengths were checked above, so each iterator
            // yields exactly one segment per layer here.
            if let Some(seg) = segs.next() {
                items.push(PlanItem::Pipeline(seg));
            }
        }
    }
    Ok(())
}

/// One chip's slice of a sharded plan: its items grouped into *phases*
/// separated by inter-chip barriers. Phase `p` of every chip must complete
/// before phase `p + 1` starts anywhere (a barrier precedes each layer
/// that begins with remote gathers).
#[derive(Debug, Clone)]
pub struct ChipPlan {
    pub phases: Vec<Vec<PlanItem>>,
}

/// The complete typed schedule of one `(model, dataset, config, flags)`
/// tuple sharded across `shards` chips — the multi-chip counterpart of
/// [`StagePlan`], built by [`build_sharded`] and evaluated by
/// [`evaluate_sharded`].
#[derive(Debug, Clone)]
pub struct ShardedStagePlan {
    pub model: ModelKind,
    pub dataset: String,
    pub cfg: GhostConfig,
    pub flags: OptFlags,
    /// Chip count (≥ 1). Every chip has the same number of phases.
    pub shards: usize,
    /// Per-chip phased item lists, indexed by chip.
    pub chips: Vec<ChipPlan>,
    /// The group→chip assignment and exchange volumes the plan was built
    /// from.
    pub shard_plan: ShardPlan,
    /// Number of layers that required a halo exchange (and therefore a
    /// barrier) before their gathers.
    pub exchange_layers: usize,
    /// Total edges whose source features crossed the inter-chip link,
    /// summed over every `(chip, layer, graph)` remote gather — equals
    /// `exchange_layers × shard_plan.total_cross_shard_edges()`.
    pub remote_gather_edges: u64,
    /// Post-layer-0 gathers whose input feature map spilled to DRAM,
    /// summed across chips (per-chip residency: a shard's slice may fit
    /// where the whole graph would spill).
    pub spilled_layer_gathers: usize,
    /// Always-on platform power of **one** chip, watts (evaluation burns
    /// it on every chip for the whole makespan).
    pub platform_w: f64,
    pub ops: u64,
    pub bits: u64,
    /// Structure-of-arrays lowering of `chips`, cached at build time —
    /// what [`evaluate_sharded`] actually walks.
    pub soa: PlanSoA,
}

impl ShardedStagePlan {
    /// Number of barrier-separated phases (identical on every chip).
    pub fn n_phases(&self) -> usize {
        self.chips.first().map(|c| c.phases.len()).unwrap_or(0)
    }

    /// Total remote-gather items across all chips and phases.
    pub fn n_remote_gathers(&self) -> usize {
        self.chips
            .iter()
            .flat_map(|c| c.phases.iter())
            .flatten()
            .filter(|i| {
                matches!(
                    i,
                    PlanItem::Serial { kind: StageKind::RemoteGather { .. }, .. }
                )
            })
            .count()
    }
}

/// Builds the sharded plan: assigns output groups to `shards` chips via
/// [`ShardPlan::build`], checks every chip's slice against the per-chip
/// memory budget, and emits each chip's phased schedule. Layers whose
/// gathers need remote source features start with
/// [`StageKind::RemoteGather`] items (one per sending chip with non-zero
/// volume) behind a barrier.
///
/// With `shards == 1` the single chip's items are constructed by the same
/// helpers in the same order as [`build`], so evaluation is bit-identical
/// to the single-chip path.
pub fn build_sharded(
    kind: ModelKind,
    dataset: &Dataset,
    partitions: &[PartitionMatrix],
    cfg: GhostConfig,
    flags: OptFlags,
    shards: usize,
) -> Result<ShardedStagePlan, SimError> {
    let _span = telemetry::span("plan.build_sharded");
    cfg.validate().map_err(SimError::InvalidConfig)?;
    flags.validate().map_err(SimError::InvalidFlags)?;
    if shards == 0 {
        return Err(SimError::InvalidConfig("shard count must be >= 1".into()));
    }
    if partitions.len() != dataset.graphs.len() {
        return Err(SimError::PartitionCountMismatch {
            expected: dataset.graphs.len(),
            got: partitions.len(),
        });
    }
    if let Some(pm) = partitions.iter().find(|p| p.v != cfg.v || p.n != cfg.n) {
        return Err(SimError::PartitionShapeMismatch {
            expected: (cfg.v, cfg.n),
            got: (pm.v, pm.n),
        });
    }
    let ctx = ArchContext::paper(cfg);
    let model = Model::for_dataset(kind, &dataset.spec);
    let feat = resident_feat_bytes(&model);
    let shard_plan = ShardPlan::build(partitions, shards, feat);
    if !shard_plan.fits_budget(cfg.chip_mem_bytes) {
        // Contiguous-range balancing may need more than the even-split
        // lower bound; always suggest progress over the attempted count.
        let whole =
            partitions.iter().map(|pm| pm.footprint_bytes(feat)).max().unwrap_or(0);
        return Err(SimError::ExceedsChipMemory {
            footprint_bytes: shard_plan.max_chip_footprint_bytes(),
            budget_bytes: cfg.chip_mem_bytes,
            min_shards: (whole.div_ceil(cfg.chip_mem_bytes) as usize).max(shards + 1),
        });
    }
    let workload = Workload::characterize(&model, dataset);

    // Which layers need a halo exchange before their gathers can run.
    // Aggregate-first models gather *input* features — layer 0's raw
    // features are replicated to every chip up front (halo replication),
    // so only later layers (whose inputs are produced remotely) exchange.
    // Transform-first (GAT) reduces over remotely *transformed* features,
    // so every reduction layer exchanges, including layer 0.
    let total_exchange = shard_plan.total_cross_shard_edges();
    let needs_exchange: Vec<bool> = model
        .layers
        .iter()
        .enumerate()
        .map(|(li, layer)| {
            total_exchange > 0
                && layer.reduction.is_some()
                && match model.ordering {
                    ExecOrdering::AggregateFirst => li > 0,
                    ExecOrdering::TransformFirst => true,
                }
        })
        .collect();
    let exchange_layers = needs_exchange.iter().filter(|&&x| x).count();

    let weight_stages: Vec<StageCost> =
        model.layers.iter().map(|layer| weight_stage_item(&ctx, layer)).collect();

    let mut chips = Vec::with_capacity(shards);
    let mut spilled_layer_gathers = 0usize;
    let mut remote_gather_edges = 0u64;
    for c in 0..shards {
        let mut phases: Vec<Vec<PlanItem>> = Vec::new();
        let mut cur: Vec<PlanItem> = Vec::new();
        // Each chip streams the edge/partition descriptors of its own
        // group range (all of them for a 1-shard plan — the per-group edge
        // counts partition the graph's edges exactly).
        for gi in 0..dataset.graphs.len() {
            let pm = &partitions[gi];
            let range = shard_plan.group_range(gi, c);
            let edges: u64 =
                pm.groups[range].iter().map(|grp| grp.total_edges as u64).sum();
            cur.push(PlanItem::Serial {
                kind: StageKind::EdgeStream,
                cost: ecu::edge_stage_cost(&ctx, edges * 8),
            });
        }
        for (li, layer) in model.layers.iter().enumerate() {
            if needs_exchange[li] {
                // This layer's gathers depend on remote shards: everything
                // before it (on every chip) must complete first.
                phases.push(std::mem::take(&mut cur));
            }
            cur.push(PlanItem::Serial {
                kind: StageKind::WeightStage,
                cost: weight_stages[li],
            });
            // Width of one exchanged feature vector, bytes at 8-bit
            // quantization: raw/hidden inputs for aggregate-first,
            // transformed outputs for transform-first.
            let width = match model.ordering {
                ExecOrdering::AggregateFirst => layer.in_dim,
                ExecOrdering::TransformFirst => layer.out_dim * layer.heads,
            };
            for gi in 0..dataset.graphs.len() {
                let pm = &partitions[gi];
                let range = shard_plan.group_range(gi, c);
                if needs_exchange[li] {
                    for src in 0..shards {
                        if src == c {
                            continue;
                        }
                        let xch = shard_plan.exchange_edges(gi, c, src);
                        if xch == 0 {
                            continue;
                        }
                        remote_gather_edges += xch;
                        cur.push(PlanItem::Serial {
                            kind: StageKind::RemoteGather { src_chip: src as u32 },
                            cost: ctx.link.transfer_cost(xch * width as u64),
                        });
                    }
                }
                let chip_vertices = pm.group_range_vertices(range.clone());
                let feat_bytes = chip_vertices * layer.in_dim;
                let from_dram =
                    li == 0 || feat_bytes > ctx.buffers.input_vertices.size_bytes;
                if li > 0 && from_dram && layer.reduction.is_some() {
                    spilled_layer_gathers += 1;
                }
                cur.push(PlanItem::Pipeline(build_segment(
                    &ctx,
                    &model,
                    li,
                    layer,
                    gi,
                    &pm.groups[range],
                    flags,
                    from_dram,
                )));
            }
        }
        if model.has_readout {
            let width = model.layers.last().map(|l| l.out_dim * l.heads).unwrap_or(0);
            for gi in 0..dataset.graphs.len() {
                let pm = &partitions[gi];
                let range = shard_plan.group_range(gi, c);
                let chip_vertices = pm.group_range_vertices(range);
                cur.push(PlanItem::Serial {
                    kind: StageKind::Readout,
                    cost: readout_item(&ctx, chip_vertices, width),
                });
            }
        }
        phases.push(cur);
        chips.push(ChipPlan { phases });
    }

    let soa = PlanSoA::lower_sharded(&chips, flags.pipelining);
    Ok(ShardedStagePlan {
        model: kind,
        dataset: dataset.spec.name.to_string(),
        cfg,
        flags,
        shards,
        chips,
        shard_plan,
        exchange_layers,
        remote_gather_edges,
        spilled_layer_gathers,
        platform_w: crate::arch::platform_power_w(&ctx, flags.dac_sharing),
        ops: workload.total_ops(),
        bits: workload.total_bits(),
        soa,
    })
}

/// Per-item accumulator state shared by [`evaluate`] and
/// [`evaluate_sharded`] — one code path for both, so a 1-shard sharded
/// plan reproduces the single-chip evaluation bit-identically (every
/// floating-point add happens in the same order at the same granularity).
#[derive(Default)]
struct EvalAccum {
    dynamic_energy: f64,
    aggregate_s: f64,
    combine_s: f64,
    update_s: f64,
    readout_s: f64,
    weight_stage_s: f64,
    weight_stage_energy_j: f64,
    kinds: KindTotals,
}

impl EvalAccum {
    /// Folds one plan item in. Serial stages add their latency to
    /// `latency` (the caller's running local time — the whole plan for the
    /// single-chip walk, one chip's phase under sharding); pipelined
    /// segments add their recurrence makespan. `count_weight_stage` gates
    /// the critical-path weight-staging split (chip 0 only under sharding:
    /// every chip stages the same weights concurrently, so one chip's
    /// staging time is the schedule's share — the per-kind totals still
    /// count every chip's busy time).
    fn add_item(
        &mut self,
        item: &PlanItem,
        pipelining: bool,
        count_weight_stage: bool,
        latency: &mut f64,
    ) -> Result<(), SimError> {
        match item {
            PlanItem::Serial { kind, cost } => {
                *latency += cost.latency_s;
                self.dynamic_energy += cost.energy_j;
                self.kinds.add(*kind, cost.latency_s, cost.energy_j);
                match kind {
                    StageKind::WeightStage if count_weight_stage => {
                        self.weight_stage_s += cost.latency_s;
                        self.weight_stage_energy_j += cost.energy_j;
                    }
                    StageKind::Readout => {
                        self.aggregate_s += cost.latency_s;
                        self.readout_s += cost.latency_s;
                    }
                    _ => {}
                }
            }
            PlanItem::Pipeline(seg) => {
                // Per-block accounting at the same per-group granularity
                // (and therefore the same floating-point rounding) as the
                // reference single-pass simulator.
                for g in seg.groups() {
                    let mut group_energy = 0.0f64;
                    let mut agg = 0.0f64;
                    let mut comb = 0.0f64;
                    let mut upd = 0.0f64;
                    for (s, c) in g.iter().enumerate() {
                        group_energy += c.energy_j;
                        match seg.kinds[s].block() {
                            Some(Block::Aggregate) => agg += c.latency_s,
                            Some(Block::Combine) => comb += c.latency_s,
                            Some(Block::Update) => upd += c.latency_s,
                            None => {}
                        }
                    }
                    self.dynamic_energy += group_energy;
                    self.aggregate_s += agg;
                    self.combine_s += comb;
                    self.update_s += upd;
                }
                let views: Vec<&[StageCost]> = seg.groups().collect();
                let sched = if pipelining {
                    sim::pipelined_costs(&views).map_err(SimError::RaggedSchedule)?
                } else {
                    sim::sequential_costs(&views)
                };
                *latency += sched.makespan_s;
                for (s, kind) in
                    seg.kinds.iter().enumerate().take(sched.stage_busy_s.len())
                {
                    self.kinds.add(*kind, sched.stage_busy_s[s], sched.stage_energy_j[s]);
                }
            }
        }
        Ok(())
    }

    /// Finalizes the accumulated state into a [`SimReport`].
    #[allow(clippy::too_many_arguments)]
    fn into_report(
        self,
        model: ModelKind,
        dataset: String,
        cfg: GhostConfig,
        flags: OptFlags,
        latency_s: f64,
        energy_j: f64,
        ops: u64,
        bits: u64,
        spilled_layer_gathers: usize,
        platform_w: f64,
    ) -> SimReport {
        SimReport {
            model,
            dataset,
            config: cfg,
            flags,
            metrics: Metrics { latency_s, energy_j, ops, bits },
            aggregate_s: self.aggregate_s,
            combine_s: self.combine_s,
            update_s: self.update_s,
            readout_s: self.readout_s,
            weight_stage_s: self.weight_stage_s,
            weight_stage_energy_j: self.weight_stage_energy_j,
            spilled_layer_gathers,
            platform_w,
            kinds: self.kinds,
        }
    }
}

/// Evaluates a plan: an `O(groups)` replay of the cached [`PlanSoA`]
/// (per-group block sums, per-segment recurrence results), deriving every
/// [`SimReport`] field. Bit-identical to the retained item walk
/// ([`reference_evaluate`]) because the cached quantities are exactly the
/// partials that walk accumulates, consumed in the same order.
pub fn evaluate(plan: &StagePlan) -> Result<SimReport, SimError> {
    let _span = telemetry::span("plan.evaluate");
    evaluate_core(plan)
}

/// [`evaluate`] minus the telemetry span — the pre-instrumentation
/// baseline `benches/telemetry_overhead.rs` pins the instrumented entry
/// against (disabled-path overhead ≤5%).
pub fn evaluate_core(plan: &StagePlan) -> Result<SimReport, SimError> {
    let header = EvalHeader {
        model: plan.model,
        dataset: plan.dataset.clone(),
        cfg: plan.cfg,
        flags: plan.flags,
        shards: 1,
        spilled_layer_gathers: plan.spilled_layer_gathers,
        platform_w: plan.platform_w,
        ops: plan.ops,
        bits: plan.bits,
    };
    Ok(evaluate_soa(&plan.soa, &header))
}

/// Evaluates a sharded plan via its cached [`PlanSoA`]: each chip's phases
/// accumulate locally, the makespan is the barriered recurrence over chips
/// ([`sim::barriered_lanes`] — phases advance together, each gated by its
/// slowest chip), and platform power burns on every chip for the whole
/// makespan. With 1 shard the result is bit-identical to [`evaluate`] of
/// the single-chip plan (one chip, one phase, identical lanes).
pub fn evaluate_sharded(plan: &ShardedStagePlan) -> Result<SimReport, SimError> {
    let _span = telemetry::span("plan.evaluate_sharded");
    evaluate_sharded_core(plan)
}

/// [`evaluate_sharded`] minus the telemetry span (see [`evaluate_core`]).
pub fn evaluate_sharded_core(plan: &ShardedStagePlan) -> Result<SimReport, SimError> {
    let header = EvalHeader {
        model: plan.model,
        dataset: plan.dataset.clone(),
        cfg: plan.cfg,
        flags: plan.flags,
        shards: plan.shards,
        spilled_layer_gathers: plan.spilled_layer_gathers,
        platform_w: plan.platform_w,
        ops: plan.ops,
        bits: plan.bits,
    };
    Ok(evaluate_soa(&plan.soa, &header))
}

/// The SoA evaluator both public entry points (and [`soa::DeltaPlan`],
/// which carries its own header) share: walk the lowered entries
/// `(chip, phase)`-major, replaying cached per-group sums and per-segment
/// schedule results, then close over the barriered makespan. Infallible —
/// lowering guarantees uniform four-slot groups, so no ragged-schedule
/// error can arise.
///
/// [`soa::DeltaPlan`]: super::soa::DeltaPlan
pub(crate) fn evaluate_soa(soa: &PlanSoA, h: &EvalHeader) -> SimReport {
    let mut acc = EvalAccum::default();
    let mut phase_busy = Vec::with_capacity(soa.n_chips * soa.n_phases);
    for c in 0..soa.n_chips {
        let count_weight_stage = c == 0;
        for p in 0..soa.n_phases {
            let mut local = 0.0f64;
            for entry in &soa.entries[soa.phase_span(c, p)] {
                match entry {
                    SoaEntry::Serial { kind, cost } => {
                        local += cost.latency_s;
                        acc.dynamic_energy += cost.energy_j;
                        acc.kinds.add(*kind, cost.latency_s, cost.energy_j);
                        match kind {
                            StageKind::WeightStage if count_weight_stage => {
                                acc.weight_stage_s += cost.latency_s;
                                acc.weight_stage_energy_j += cost.energy_j;
                            }
                            StageKind::Readout => {
                                acc.aggregate_s += cost.latency_s;
                                acc.readout_s += cost.latency_s;
                            }
                            _ => {}
                        }
                    }
                    SoaEntry::Segment { seg } => {
                        let m = soa.segs[*seg];
                        for g in m.group_start..m.group_start + m.n_groups {
                            acc.dynamic_energy += soa.group_energy[g];
                            acc.aggregate_s += soa.group_agg[g];
                            acc.combine_s += soa.group_comb[g];
                            acc.update_s += soa.group_upd[g];
                        }
                        let sched = &soa.scheds[*seg];
                        local += sched.makespan_s;
                        // The reference walk's per-kind adds cover
                        // `stage_busy_s.len()` stages, which is zero for an
                        // empty segment — mirror that skip exactly.
                        if m.n_groups > 0 {
                            for (s, kind) in m.kinds.iter().enumerate() {
                                acc.kinds.add(
                                    *kind,
                                    sched.stage_busy_s[s],
                                    sched.stage_energy_j[s],
                                );
                            }
                        }
                    }
                }
            }
            phase_busy.push(local);
        }
    }
    let latency = sim::barriered_lanes(&phase_busy, soa.n_phases);
    // `x * 1.0 == x` bitwise, so the sharded energy formula covers the
    // single-chip case without a branch.
    let energy = acc.dynamic_energy + h.platform_w * latency * h.shards as f64;
    acc.into_report(
        h.model,
        h.dataset.clone(),
        h.cfg,
        h.flags,
        latency,
        energy,
        h.ops,
        h.bits,
        h.spilled_layer_gathers,
        h.platform_w,
    )
}

/// Trace track id of a chip's serial lane (edge streams, weight staging,
/// remote gathers, readouts); pipeline positions render on tids `1..=4`.
const SIM_SERIAL_TID: u64 = 0;

/// The simulated-time timeline of a single-chip plan as a Chrome-trace
/// JSON document (`ghost run --trace-sim`): the modeled hardware schedule
/// with one Perfetto process per chip and one track per pipeline position,
/// events named (and therefore colored) by [`StageKind`]. See
/// [`sim_timeline_sharded`] for the multi-chip variant and the conservation
/// guarantee both share.
pub fn sim_timeline(plan: &StagePlan) -> Result<Json, SimError> {
    let report = evaluate_core(plan)?;
    Ok(sim_timeline_soa(&plan.soa, &report))
}

/// The simulated-time timeline of a sharded plan: chips render as separate
/// Perfetto processes, and phase barriers (every chip waits for the
/// slowest before its [`StageKind::RemoteGather`] items run) appear as
/// `barrier` instants plus idle gaps on the faster chips.
pub fn sim_timeline_sharded(plan: &ShardedStagePlan) -> Result<Json, SimError> {
    let report = evaluate_sharded_core(plan)?;
    Ok(sim_timeline_soa(&plan.soa, &report))
}

/// Renders the SoA schedule as trace events. **Conservation contract:**
/// one `cat:"sim-stage"` event is emitted per `KindTotals::add` call of
/// [`evaluate_soa`], in the same `(chip, phase, entry)` walk order, with
/// the exact f64 addends in `args.busy_s` / `args.energy_j`. A checker
/// that folds the events per kind in array order therefore performs the
/// identical sequence of f64 additions and reproduces
/// [`SimReport::kinds`] *bitwise* — the embedded `ghost.kind_totals` block
/// is the reference it must match exactly.
///
/// Timestamps are modeled microseconds: phase `p` starts once every chip
/// has finished phase `p-1` ([`sim::barriered_lanes`] semantics) and each
/// chip lays its entries out sequentially within the phase; a segment's
/// four position tracks overlap for the segment's makespan, each busy for
/// its own `stage_busy_s`.
fn sim_timeline_soa(soa: &PlanSoA, report: &SimReport) -> Json {
    // Pass 1: per-(chip, phase) busy time — evaluate_soa's `local` sums.
    let mut phase_busy = vec![vec![0.0f64; soa.n_phases]; soa.n_chips];
    for (c, chip_busy) in phase_busy.iter_mut().enumerate() {
        for (p, busy) in chip_busy.iter_mut().enumerate() {
            let mut local = 0.0f64;
            for entry in &soa.entries[soa.phase_span(c, p)] {
                match entry {
                    SoaEntry::Serial { cost, .. } => local += cost.latency_s,
                    SoaEntry::Segment { seg } => local += soa.scheds[*seg].makespan_s,
                }
            }
            *busy = local;
        }
    }
    let mut phase_start = vec![0.0f64; soa.n_phases + 1];
    for p in 0..soa.n_phases {
        let widest =
            (0..soa.n_chips).map(|c| phase_busy[c][p]).fold(0.0f64, f64::max);
        phase_start[p + 1] = phase_start[p] + widest;
    }

    // Track metadata: one viewer process per chip, named tracks per lane.
    let mut events = Vec::new();
    for c in 0..soa.n_chips {
        let pid = c as u64;
        events.push(ttrace::process_name(pid, &format!("chip {c}")));
        events.push(ttrace::thread_name(pid, SIM_SERIAL_TID, "serial"));
        for s in 0..PIPELINE_STAGES {
            events.push(ttrace::thread_name(pid, 1 + s as u64, &format!("pipe {s}")));
        }
    }

    // Pass 2: the event walk (see the conservation contract above).
    for c in 0..soa.n_chips {
        let pid = c as u64;
        for p in 0..soa.n_phases {
            let mut t = phase_start[p];
            if p > 0 {
                events.push(ttrace::instant_event(
                    "barrier",
                    "sim-barrier",
                    pid,
                    SIM_SERIAL_TID,
                    t * 1e6,
                ));
            }
            for entry in &soa.entries[soa.phase_span(c, p)] {
                match entry {
                    SoaEntry::Serial { kind, cost } => {
                        events.push(ttrace::complete_event(
                            kind.name(),
                            "sim-stage",
                            pid,
                            SIM_SERIAL_TID,
                            t * 1e6,
                            cost.latency_s * 1e6,
                            Some(obj(vec![
                                ("busy_s", Json::Num(cost.latency_s)),
                                ("energy_j", Json::Num(cost.energy_j)),
                            ])),
                        ));
                        t += cost.latency_s;
                    }
                    SoaEntry::Segment { seg } => {
                        let m = soa.segs[*seg];
                        let sched = &soa.scheds[*seg];
                        if m.n_groups > 0 {
                            for (s, kind) in m.kinds.iter().enumerate() {
                                events.push(ttrace::complete_event(
                                    kind.name(),
                                    "sim-stage",
                                    pid,
                                    1 + s as u64,
                                    t * 1e6,
                                    sched.stage_busy_s[s] * 1e6,
                                    Some(obj(vec![
                                        ("busy_s", Json::Num(sched.stage_busy_s[s])),
                                        ("energy_j", Json::Num(sched.stage_energy_j[s])),
                                        ("layer", Json::Num(f64::from(m.layer))),
                                        ("graph", Json::Num(f64::from(m.graph))),
                                        ("groups", Json::Num(m.n_groups as f64)),
                                    ])),
                                ));
                            }
                        }
                        t += sched.makespan_s;
                    }
                }
            }
        }
    }

    let kind_totals: Vec<(&str, Json)> = report
        .kinds
        .rows()
        .iter()
        .map(|(name, cost)| {
            (
                *name,
                obj(vec![
                    ("busy_s", Json::Num(cost.latency_s)),
                    ("energy_j", Json::Num(cost.energy_j)),
                ]),
            )
        })
        .collect();
    let ghost = obj(vec![
        ("clock", Json::Str("simulated".to_string())),
        ("chips", Json::Num(soa.n_chips as f64)),
        ("phases", Json::Num(soa.n_phases as f64)),
        ("latency_s", Json::Num(report.metrics.latency_s)),
        ("kind_totals", obj(kind_totals)),
    ]);
    ttrace::trace_doc(events, ghost)
}

/// The retained reference evaluator: the original per-item walk over
/// `plan.items`, running the pipelined recurrence per segment. Kept as the
/// oracle the SoA replay is pinned against (schedule property tests,
/// `GHOST_DSE_CHECK`) — [`evaluate`] must reproduce it bit-identically.
pub fn reference_evaluate(plan: &StagePlan) -> Result<SimReport, SimError> {
    let mut acc = EvalAccum::default();
    let mut latency = 0.0f64;
    for item in &plan.items {
        acc.add_item(item, plan.flags.pipelining, true, &mut latency)?;
    }
    let platform_w = plan.platform_w;
    let energy = acc.dynamic_energy + platform_w * latency;
    Ok(acc.into_report(
        plan.model,
        plan.dataset.clone(),
        plan.cfg,
        plan.flags,
        latency,
        energy,
        plan.ops,
        plan.bits,
        plan.spilled_layer_gathers,
        platform_w,
    ))
}

/// The retained sharded reference evaluator (see [`reference_evaluate`]):
/// per-chip per-phase item walks closed over
/// [`sim::barriered_makespan`] — the oracle for [`evaluate_sharded`].
pub fn reference_evaluate_sharded(plan: &ShardedStagePlan) -> Result<SimReport, SimError> {
    let mut acc = EvalAccum::default();
    let mut chip_phase_times: Vec<Vec<f64>> = Vec::with_capacity(plan.chips.len());
    for (ci, chip) in plan.chips.iter().enumerate() {
        let mut phase_times = Vec::with_capacity(chip.phases.len());
        for phase in &chip.phases {
            let mut local = 0.0f64;
            for item in phase {
                acc.add_item(item, plan.flags.pipelining, ci == 0, &mut local)?;
            }
            phase_times.push(local);
        }
        chip_phase_times.push(phase_times);
    }
    let latency = sim::barriered_makespan(&chip_phase_times).map_err(SimError::RaggedSchedule)?;
    let platform_w = plan.platform_w;
    let energy = acc.dynamic_energy + platform_w * latency * plan.shards as f64;
    Ok(acc.into_report(
        plan.model,
        plan.dataset.clone(),
        plan.cfg,
        plan.flags,
        latency,
        energy,
        plan.ops,
        plan.bits,
        plan.spilled_layer_gathers,
        platform_w,
    ))
}

/// Energy of one per-layer TO retarget event across the banks that need it,
/// with TED keeping heaters decoupled (so each pays only its own shift).
pub(crate) fn to_retune_energy(ctx: &ArchContext) -> f64 {
    let cfg = &ctx.cfg;
    let n_mrs = cfg.aggregate_mrs() + cfg.combine_mrs();
    n_mrs as f64
        * TO_RETUNE_FRACTION
        * ctx.dev.to_tuning.power_w
        * 0.25 // quarter-FSR average shift
        * ctx.dev.to_tuning.latency_s
}

/// The stage kinds of one segment, by pipeline position. Kinds depend only
/// on the layer shape and execution ordering, never on the group.
fn segment_kinds(
    layer: &LayerSpec,
    ordering: ExecOrdering,
    from_dram: bool,
) -> [StageKind; PIPELINE_STAGES] {
    match (layer.reduction, ordering) {
        // Pure MLP layer: the gather/reduce slots exist (zero-cost) so the
        // pipeline shape stays uniform across the model's segments.
        (None, _) => [
            StageKind::Gather { from_dram: false },
            StageKind::Reduce,
            StageKind::Transform,
            StageKind::Update,
        ],
        (Some(_), ExecOrdering::AggregateFirst) => [
            StageKind::Gather { from_dram },
            StageKind::Reduce,
            StageKind::Transform,
            StageKind::Update,
        ],
        (Some(_), ExecOrdering::TransformFirst) => [
            StageKind::Gather { from_dram },
            StageKind::Transform,
            StageKind::Update,
            StageKind::Reduce,
        ],
    }
}

/// Builds one `(layer, graph)` segment: per-group stage costs in pipeline
/// order, tagged by the segment's kinds. `groups` is the output-group
/// plans the segment covers — the whole graph for a single-chip plan, one
/// chip's contiguous shard range for a sharded one.
#[allow(clippy::too_many_arguments)]
fn build_segment(
    ctx: &ArchContext,
    model: &Model,
    li: usize,
    layer: &LayerSpec,
    gi: usize,
    groups: &[OutputGroupPlan],
    flags: OptFlags,
    from_dram: bool,
) -> PipelineSegment {
    let kinds = segment_kinds(layer, model.ordering, from_dram);
    let mut costs = Vec::with_capacity(groups.len() * PIPELINE_STAGES);
    for grp in groups {
        costs.extend_from_slice(&group_stage_costs(ctx, model, layer, grp, flags, from_dram));
    }
    PipelineSegment { layer: li as u32, graph: gi as u32, kinds, costs }
}

/// The pipeline stage costs of one output-vertex group for one layer
/// (§3.4.2 orderings; see [`segment_kinds`] for the position → kind map):
/// one [`position_cost`] call per slot over the sample-capped group.
fn group_stage_costs(
    ctx: &ArchContext,
    model: &Model,
    layer: &LayerSpec,
    grp: &OutputGroupPlan,
    flags: OptFlags,
    from_dram: bool,
) -> [StageCost; PIPELINE_STAGES] {
    // GraphSAGE-style neighbor sampling caps the effective group shape.
    let grp_eff = effective_group(grp, layer.neighbor_sample, ctx.cfg.v);
    std::array::from_fn(|s| position_cost(ctx, model, layer, &grp_eff, flags, from_dram, s))
}

/// The cost of one pipeline position of one (sample-capped) group — the
/// single recompute unit of delta re-costing: when a parameter change
/// intersects a position's [`StageKind::provenance`],
/// [`crate::coordinator::soa::DeltaPlan`] re-runs exactly this function
/// for the affected lanes. `grp_eff` must already be the
/// [`effective_group`] of the raw group plan (the cap depends only on the
/// layer and `v`, both fixed across patches).
pub(crate) fn position_cost(
    ctx: &ArchContext,
    model: &Model,
    layer: &LayerSpec,
    grp_eff: &OutputGroupPlan,
    flags: OptFlags,
    from_dram: bool,
    pos: usize,
) -> StageCost {
    let out_width = layer.out_dim * layer.heads;
    match (layer.reduction, model.ordering, pos) {
        // Pure MLP layer (GIN inner layers): features already on-chip,
        // transform + update only — the gather/reduce slots exist but are
        // zero-cost.
        (None, _, 0) | (None, _, 1) => StageCost::ZERO,
        (None, _, 2) => {
            combine::transform_cost(ctx, layer.in_dim, out_width, flags.dac_sharing, false)
        }
        (None, _, _) => update::update_cost(ctx, layer.activation, out_width, 0)
            .then(update::writeback_cost(ctx, out_width)),
        (Some(_), ExecOrdering::AggregateFirst, 0) => {
            gather_stage(ctx, grp_eff, layer.in_dim, flags.buffer_partition, from_dram)
        }
        (Some(red), ExecOrdering::AggregateFirst, 1) => {
            aggregate::reduce_cost(ctx, grp_eff, layer.in_dim, red, flags.workload_balancing)
        }
        (Some(_), ExecOrdering::AggregateFirst, 2) => {
            combine::transform_cost(ctx, layer.in_dim, out_width, flags.dac_sharing, true)
        }
        (Some(_), ExecOrdering::AggregateFirst, _) => {
            update::update_cost(ctx, layer.activation, out_width, 0)
                .then(update::writeback_cost(ctx, out_width))
        }
        // GAT: each lane fetches *its own* vertex once (transforms are
        // independent, §3.4.2), W-transforms it and computes attention
        // logits; LeakyReLU + neighborhood softmax run in the update
        // block; the final reduce aggregates the *transformed*
        // (out_width-dim) neighbor features from the intermediate buffer.
        (Some(_), ExecOrdering::TransformFirst, 0) => {
            own_vertex_gather(ctx, layer.in_dim, flags.buffer_partition, from_dram)
        }
        (Some(_), ExecOrdering::TransformFirst, 1) => {
            combine::transform_cost(ctx, layer.in_dim, out_width, flags.dac_sharing, false)
                .then(attention_cost(ctx, layer, grp_eff))
        }
        (Some(_), ExecOrdering::TransformFirst, 2) => {
            let softmax_elems = grp_eff.total_edges as usize * layer.heads;
            update::update_cost(ctx, Activation::Softmax, out_width, softmax_elems)
                .then(update::writeback_cost(ctx, out_width))
        }
        (Some(red), ExecOrdering::TransformFirst, _) => {
            // Neighbor fetch of transformed features (on-chip intermediate
            // buffer) + the coherent summation itself.
            let nbr_bytes = grp_eff.distinct_sources as usize * out_width;
            let fetch = StageCost {
                latency_s: ctx.buffers.input_vertices.stream_latency_s(nbr_bytes),
                energy_j: ctx.buffers.input_vertices.stream_energy_j(nbr_bytes),
            };
            fetch.then(aggregate::reduce_cost(
                ctx,
                grp_eff,
                out_width,
                red,
                flags.workload_balancing,
            ))
        }
    }
}

/// Whether a pipeline position's cost is identical for every group of a
/// segment (no [`OutputGroupPlan`] field feeds it) — the delta evaluator
/// then computes it once and broadcasts across the lane instead of
/// looping groups.
pub(crate) fn position_group_invariant(model: &Model, layer: &LayerSpec, pos: usize) -> bool {
    match (layer.reduction, model.ordering) {
        // MLP slots never read the group shape.
        (None, _) => true,
        // Transform and update depend only on layer dims.
        (Some(_), ExecOrdering::AggregateFirst) => pos >= 2,
        // Only the own-vertex fetch is shape-free; attention, softmax, and
        // the final reduce all read edge counts.
        (Some(_), ExecOrdering::TransformFirst) => pos == 0,
    }
}

/// Applies a neighbor-sample cap to a group's shape (GraphSAGE §2.1).
pub(crate) fn effective_group(
    grp: &OutputGroupPlan,
    sample: Option<usize>,
    v: usize,
) -> OutputGroupPlan {
    match sample {
        None => *grp,
        Some(s) => {
            let max_deg = grp.max_lane_degree.min(s as u32);
            let total = grp.total_edges.min((v * s) as u32);
            OutputGroupPlan {
                out_group: grp.out_group,
                n_blocks: grp.n_blocks,
                max_lane_degree: max_deg,
                total_edges: total,
                distinct_sources: grp.distinct_sources.min(total),
            }
        }
    }
}

/// Gather stage: DRAM-backed for layer-0 / spilled feature maps, on-chip
/// intermediate-buffer reads otherwise.
fn gather_stage(
    ctx: &ArchContext,
    grp: &OutputGroupPlan,
    in_dim: usize,
    bp: bool,
    from_dram: bool,
) -> StageCost {
    if from_dram {
        aggregate::gather_cost(ctx, grp, in_dim, bp)
    } else {
        // Intermediate vertex buffer: streamed (BP) or per-neighbor (no BP).
        let buf = &ctx.buffers.input_vertices;
        if bp {
            let bytes = grp.distinct_sources as usize * in_dim;
            StageCost {
                latency_s: buf.stream_latency_s(bytes),
                energy_j: buf.stream_energy_j(bytes),
            }
        } else {
            let per = buf.access_latency_s * ceil_div(in_dim, 64).max(1) as f64;
            let bytes = grp.total_edges as usize * in_dim;
            StageCost {
                latency_s: grp.max_lane_degree as f64 * per,
                energy_j: buf.stream_energy_j(bytes),
            }
        }
    }
}

/// Transform-first own-vertex fetch: each of the `V` lanes streams the
/// feature vector of the single vertex it will transform. With BP the
/// fetches are one prefetched stream; without, each lane issues an
/// on-demand access.
fn own_vertex_gather(ctx: &ArchContext, in_dim: usize, bp: bool, from_dram: bool) -> StageCost {
    let bytes = ctx.cfg.v * in_dim;
    if from_dram {
        let hbm = &ctx.hbm;
        if bp {
            StageCost {
                latency_s: hbm.access_latency_s + bytes as f64 / hbm.sustained_bw(),
                energy_j: hbm.transfer_energy_j(bytes as u64)
                    + ctx.buffers.input_vertices.stream_energy_j(bytes),
            }
        } else {
            StageCost {
                latency_s: hbm.access_latency_s
                    + in_dim as f64 / (hbm.peak_bw_bytes_per_s * hbm.random_efficiency),
                energy_j: hbm.transfer_energy_j(bytes as u64)
                    + hbm.burst_overhead_j * ctx.cfg.v as f64
                    + ctx.buffers.input_vertices.stream_energy_j(bytes),
            }
        }
    } else {
        StageCost {
            latency_s: ctx.buffers.input_vertices.stream_latency_s(bytes),
            energy_j: ctx.buffers.input_vertices.stream_energy_j(bytes),
        }
    }
}

/// GAT attention-logit cost: `aᵀ[Wh_i ‖ Wh_j]` per edge per head on the
/// transform arrays (2·out_dim-long dot products).
fn attention_cost(ctx: &ArchContext, layer: &LayerSpec, grp: &OutputGroupPlan) -> StageCost {
    let cfg = &ctx.cfg;
    let per_lane_logits = grp.max_lane_degree as usize * layer.heads;
    let passes = ceil_div(per_lane_logits.max(1), cfg.t_r) * ceil_div(2 * layer.out_dim, cfg.r_r);
    let values = grp.total_edges as f64 * (2 * layer.out_dim * layer.heads) as f64;
    StageCost {
        latency_s: passes as f64 * ctx.symbol_s(),
        energy_j: values * ctx.dev.dac.energy_j(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plan_for(kind: ModelKind, name: &str, flags: OptFlags) -> StagePlan {
        let cfg = GhostConfig::paper_optimal();
        let ds = Dataset::by_name(name).unwrap();
        let pms = PartitionMatrix::build_all(&ds.graphs, cfg.v, cfg.n);
        build(kind, &ds, &pms, cfg, flags).unwrap()
    }

    #[test]
    fn plan_shape_matches_schedule_structure() {
        // GCN/Cora: 1 graph, 2 layers → 1 edge stream + 2 weight stages +
        // 2 segments, no readout.
        let p = plan_for(ModelKind::Gcn, "Cora", OptFlags::ghost_default());
        assert_eq!(p.n_segments(), 2);
        assert_eq!(p.items.len(), 1 + 2 + 2);
        let serial_kinds: Vec<StageKind> = p
            .items
            .iter()
            .filter_map(|i| match i {
                PlanItem::Serial { kind, .. } => Some(*kind),
                _ => None,
            })
            .collect();
        assert_eq!(
            serial_kinds,
            vec![StageKind::EdgeStream, StageKind::WeightStage, StageKind::WeightStage]
        );
        // Cora has 2708 vertices → ceil(2708 / 20) = 136 groups per layer.
        for item in &p.items {
            if let PlanItem::Pipeline(seg) = item {
                assert_eq!(seg.n_groups(), 136);
                assert_eq!(seg.costs.len(), 136 * PIPELINE_STAGES);
            }
        }
    }

    #[test]
    fn readout_items_only_for_graph_classification() {
        let gin = plan_for(ModelKind::Gin, "Mutag", OptFlags::ghost_default());
        let n_graphs = Dataset::by_name("Mutag").unwrap().graphs.len();
        let readouts = gin
            .items
            .iter()
            .filter(|i| matches!(i, PlanItem::Serial { kind: StageKind::Readout, .. }))
            .count();
        assert_eq!(readouts, n_graphs);
        let gcn = plan_for(ModelKind::Gcn, "Cora", OptFlags::ghost_default());
        assert!(!gcn
            .items
            .iter()
            .any(|i| matches!(i, PlanItem::Serial { kind: StageKind::Readout, .. })));
    }

    #[test]
    fn gat_segments_use_transform_first_ordering() {
        let p = plan_for(ModelKind::Gat, "Cora", OptFlags::ghost_default());
        for item in &p.items {
            if let PlanItem::Pipeline(seg) = item {
                assert!(matches!(seg.kinds[0], StageKind::Gather { .. }));
                assert_eq!(seg.kinds[1], StageKind::Transform);
                assert_eq!(seg.kinds[2], StageKind::Update);
                assert_eq!(seg.kinds[3], StageKind::Reduce);
            }
        }
    }

    #[test]
    fn layer0_gathers_come_from_dram() {
        let p = plan_for(ModelKind::Gcn, "Cora", OptFlags::ghost_default());
        let mut seen = Vec::new();
        for item in &p.items {
            if let PlanItem::Pipeline(seg) = item {
                if let StageKind::Gather { from_dram } = seg.kinds[0] {
                    seen.push((seg.layer, from_dram));
                }
            }
        }
        // Layer 0 streams from DRAM; Cora's 2708 × 16 layer-1 features fit
        // the input-vertex buffer.
        assert_eq!(seen, vec![(0, true), (1, false)]);
    }

    #[test]
    fn build_parallelism_is_deterministic() {
        // The par_map fan-out over graphs must not change the plan: the
        // items of a multi-graph dataset are in layer-major (layer, graph)
        // order regardless of worker interleaving (par_map preserves
        // order, pinned here by the segment tags). Proteins crosses
        // PAR_SLOT_THRESHOLD (1113 graphs × ~2 groups × 9 layers), so this
        // exercises the parallel construction path.
        let p = plan_for(ModelKind::Gin, "Proteins", OptFlags::ghost_default());
        let mut expected = Vec::new();
        let n_graphs = Dataset::by_name("Proteins").unwrap().graphs.len() as u32;
        for li in 0..9u32 {
            for gi in 0..n_graphs {
                expected.push((li, gi));
            }
        }
        let got: Vec<(u32, u32)> = p
            .items
            .iter()
            .filter_map(|i| match i {
                PlanItem::Pipeline(seg) => Some((seg.layer, seg.graph)),
                _ => None,
            })
            .collect();
        assert_eq!(got, expected);
    }

    #[test]
    fn evaluate_kind_totals_are_consistent_with_block_split() {
        for (kind, ds) in [
            (ModelKind::Gcn, "Cora"),
            (ModelKind::Gat, "Citeseer"),
            (ModelKind::Gin, "Mutag"),
            (ModelKind::GraphSage, "PubMed"),
        ] {
            let p = plan_for(kind, ds, OptFlags::ghost_default());
            let r = evaluate(&p).unwrap();
            let k = &r.kinds;
            // The per-kind totals and the legacy block split accumulate in
            // different association orders, so compare to a relative
            // tolerance, not bit-exactly.
            let agg = k.gather.latency_s + k.reduce.latency_s + k.readout.latency_s;
            assert!(
                (agg - r.aggregate_s).abs() <= 1e-9 * r.aggregate_s.max(1e-30),
                "{ds}: per-kind aggregate {agg} vs block split {}",
                r.aggregate_s
            );
            assert!((k.transform.latency_s - r.combine_s).abs() <= 1e-9 * r.combine_s);
            assert!((k.update.latency_s - r.update_s).abs() <= 1e-9 * r.update_s);
            assert!((k.weight_stage.latency_s - r.weight_stage_s).abs() <= 1e-15);
            assert!((k.readout.latency_s - r.readout_s).abs() <= 1e-12 * r.readout_s.max(1e-30));
            assert!(k.busy_s() > 0.0);
            // Busy time never exceeds the sequential bound and the
            // makespan never exceeds total busy (pipelining overlaps).
            assert!(r.metrics.latency_s <= k.busy_s() + 1e-12);
        }
    }

    fn sharded_plan_for(
        kind: ModelKind,
        name: &str,
        flags: OptFlags,
        shards: usize,
    ) -> ShardedStagePlan {
        let cfg = GhostConfig::paper_optimal();
        let ds = Dataset::by_name(name).unwrap();
        let pms = PartitionMatrix::build_all(&ds.graphs, cfg.v, cfg.n);
        build_sharded(kind, &ds, &pms, cfg, flags, shards).unwrap()
    }

    #[test]
    fn interleave_rejects_malformed_segment_shapes() {
        // A graph with too few segments (the shape the old
        // `.expect("one segment per layer per graph")` panicked on) now
        // returns a structured error naming the graph and both counts.
        let p = plan_for(ModelKind::Gcn, "Cora", OptFlags::ghost_default());
        let segs: Vec<PipelineSegment> = p
            .items
            .iter()
            .filter_map(|i| match i {
                PlanItem::Pipeline(seg) => Some(seg.clone()),
                _ => None,
            })
            .collect();
        assert_eq!(segs.len(), 2);
        let weight_stages = [StageCost::ZERO, StageCost::ZERO];
        // Graph 0 ok, graph 1 short by one segment.
        let mut items = Vec::new();
        let err = interleave_layer_major(
            vec![segs.clone(), segs[..1].to_vec()],
            &weight_stages,
            &mut items,
        )
        .unwrap_err();
        assert_eq!(err, SimError::SegmentShapeMismatch { graph: 1, expected: 2, got: 1 });
        // Leftover segments are just as malformed.
        let mut items = Vec::new();
        let err = interleave_layer_major(
            vec![vec![segs[0].clone(), segs[0].clone(), segs[1].clone()]],
            &weight_stages,
            &mut items,
        )
        .unwrap_err();
        assert_eq!(err, SimError::SegmentShapeMismatch { graph: 0, expected: 2, got: 3 });
        // Well-formed shapes still assemble.
        let mut items = Vec::new();
        interleave_layer_major(vec![segs], &weight_stages, &mut items).unwrap();
        assert_eq!(items.len(), 2 + 2);
    }

    #[test]
    fn one_shard_plan_is_bit_identical_to_single_chip() {
        for (kind, ds) in [
            (ModelKind::Gcn, "Cora"),
            (ModelKind::Gat, "Citeseer"),
            (ModelKind::Gin, "Mutag"),
        ] {
            let flags = OptFlags::ghost_default();
            let single = evaluate(&plan_for(kind, ds, flags)).unwrap();
            let sharded = sharded_plan_for(kind, ds, flags, 1);
            assert_eq!(sharded.n_phases(), 1);
            assert_eq!(sharded.n_remote_gathers(), 0);
            assert_eq!(sharded.remote_gather_edges, 0);
            let r = evaluate_sharded(&sharded).unwrap();
            assert_eq!(single, r, "{ds}: 1-shard report diverged");
        }
    }

    #[test]
    fn sharded_plan_has_barriers_and_remote_gathers() {
        // GCN: aggregate-first, 2 layers → layer 1 exchanges (1 barrier →
        // 2 phases). GAT: transform-first → both layers exchange (3
        // phases).
        let gcn = sharded_plan_for(ModelKind::Gcn, "Cora", OptFlags::ghost_default(), 4);
        assert_eq!(gcn.shards, 4);
        assert_eq!(gcn.exchange_layers, 1);
        assert_eq!(gcn.n_phases(), 2);
        assert!(gcn.n_remote_gathers() > 0);
        assert_eq!(
            gcn.remote_gather_edges,
            gcn.exchange_layers as u64 * gcn.shard_plan.total_cross_shard_edges()
        );
        for chip in &gcn.chips {
            assert_eq!(chip.phases.len(), gcn.n_phases());
        }
        let gat = sharded_plan_for(ModelKind::Gat, "Cora", OptFlags::ghost_default(), 4);
        assert_eq!(gat.exchange_layers, 2);
        assert_eq!(gat.n_phases(), 3);
        assert_eq!(
            gat.remote_gather_edges,
            gat.exchange_layers as u64 * gat.shard_plan.total_cross_shard_edges()
        );
        // The sharded evaluation accounts remote gathers as their own kind
        // and the per-kind busy total stays conservative.
        let r = evaluate_sharded(&gcn).unwrap();
        assert!(r.kinds.remote_gather.latency_s > 0.0);
        assert!(r.kinds.remote_gather.energy_j > 0.0);
    }

    #[test]
    fn build_rejects_over_budget_graphs_with_min_shards() {
        let mut cfg = GhostConfig::paper_optimal();
        // Cora at 2708 vertices × 1433-byte features ≈ 3.9 MB resident.
        cfg.chip_mem_bytes = 1 << 20;
        let ds = Dataset::by_name("Cora").unwrap();
        let pms = PartitionMatrix::build_all(&ds.graphs, cfg.v, cfg.n);
        let err =
            build(ModelKind::Gcn, &ds, &pms, cfg, OptFlags::ghost_default()).unwrap_err();
        match err {
            SimError::ExceedsChipMemory { footprint_bytes, budget_bytes, min_shards } => {
                assert_eq!(budget_bytes, 1 << 20);
                assert!(footprint_bytes > budget_bytes);
                assert_eq!(
                    min_shards,
                    footprint_bytes.div_ceil(budget_bytes) as usize
                );
                assert!(min_shards >= 2);
            }
            other => panic!("expected ExceedsChipMemory, got {other:?}"),
        }
        // A sharded build over the min count succeeds (contiguous ranges
        // may need a little slack over the even split).
        let sharded =
            build_sharded(ModelKind::Gcn, &ds, &pms, cfg, OptFlags::ghost_default(), 8)
                .unwrap();
        assert!(sharded.shard_plan.fits_budget(cfg.chip_mem_bytes));
        evaluate_sharded(&sharded).unwrap();
    }

    #[test]
    fn sharded_build_validates_inputs() {
        let cfg = GhostConfig::paper_optimal();
        let ds = Dataset::by_name("Cora").unwrap();
        let pms = PartitionMatrix::build_all(&ds.graphs, cfg.v, cfg.n);
        assert!(matches!(
            build_sharded(ModelKind::Gcn, &ds, &pms, cfg, OptFlags::ghost_default(), 0),
            Err(SimError::InvalidConfig(_))
        ));
        assert!(matches!(
            build_sharded(ModelKind::Gcn, &ds, &[], cfg, OptFlags::ghost_default(), 2),
            Err(SimError::PartitionCountMismatch { .. })
        ));
    }

    #[test]
    fn build_validates_inputs() {
        let cfg = GhostConfig::paper_optimal();
        let ds = Dataset::by_name("Cora").unwrap();
        let pms = PartitionMatrix::build_all(&ds.graphs, cfg.v, cfg.n);
        // Wrong partition count.
        assert!(matches!(
            build(ModelKind::Gcn, &ds, &[], cfg, OptFlags::ghost_default()),
            Err(SimError::PartitionCountMismatch { .. })
        ));
        // Wrong partition shape.
        let wrong = PartitionMatrix::build_all(&ds.graphs, 10, 10);
        assert!(matches!(
            build(ModelKind::Gcn, &ds, &wrong, cfg, OptFlags::ghost_default()),
            Err(SimError::PartitionShapeMismatch { .. })
        ));
        // Invalid flags.
        let bad = OptFlags { workload_balancing: true, ..OptFlags::ghost_default() };
        assert!(matches!(
            build(ModelKind::Gcn, &ds, &pms, cfg, bad),
            Err(SimError::InvalidFlags(_))
        ));
    }
}
