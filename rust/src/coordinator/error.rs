//! Structured simulation errors.
//!
//! Every fallible path of the simulation layer returns a [`SimError`]
//! instead of a bare `String` or a panic, so batch sweeps can record *why*
//! a point failed (and which workload inside it) without aborting the
//! whole run.

use std::fmt;

use crate::gnn::models::ModelKind;

/// Why a simulation (or one point of a sweep) could not produce a result.
#[derive(Debug, Clone, PartialEq)]
pub enum SimError {
    /// The named dataset is in no tier: not a Table-2 corpus, not a
    /// large-graph name, and not a parseable `rmat-...` spec.
    UnknownDataset(String),
    /// The architectural configuration violates the device-level
    /// feasibility bounds (see [`crate::config::GhostConfig::validate`]).
    InvalidConfig(String),
    /// The optimization-flag combination is inconsistent (§4.4 rules).
    InvalidFlags(String),
    /// A pre-built partition slice does not cover the dataset's graphs.
    PartitionCountMismatch { expected: usize, got: usize },
    /// A pre-built partition was constructed for a different `(V, N)`
    /// shape than the configuration being simulated.
    PartitionShapeMismatch { expected: (usize, usize), got: (usize, usize) },
    /// A pipelined schedule was assembled with mismatched per-group stage
    /// counts (see [`crate::sim::RaggedStages`]); previously a
    /// `debug_assert`, i.e. a panic or silent under-accounting in
    /// `--release`.
    RaggedSchedule(crate::sim::RaggedStages),
    /// An aggregated metric came out NaN/infinite and the point was
    /// dropped from the frontier instead of poisoning the sort.
    NonFiniteMetric { metric: &'static str, value: f64 },
    /// The workload's resident state (features + edge descriptors +
    /// partition metadata) exceeds one chip's memory budget
    /// (`GhostConfig::chip_mem_bytes`); `min_shards` is the smallest chip
    /// count whose even split could hold it. Raised instead of silently
    /// spilling — run the workload sharded.
    ExceedsChipMemory { footprint_bytes: u64, budget_bytes: u64, min_shards: usize },
    /// Plan assembly produced the wrong number of pipeline segments for a
    /// graph — a construction-path invariant violation (one segment per
    /// layer per graph), previously a panic.
    SegmentShapeMismatch { graph: usize, expected: usize, got: usize },
    /// A graph-mutation batch was rejected
    /// ([`crate::graph::mutate::MutateError`], pre-rendered — the delta
    /// never touched the graph, partition, or epoch).
    Mutation(String),
    /// A specific workload inside a multi-workload evaluation failed;
    /// carries which `(model, dataset)` pair so sweeps can report why a
    /// configuration point vanished.
    Workload { model: ModelKind, dataset: String, source: Box<SimError> },
}

impl fmt::Display for SimError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SimError::UnknownDataset(name) => {
                write!(
                    f,
                    "unknown dataset {name} (not a Table-2 name, a large-tier name, or an \
                     rmat-<V>v-<E>e spec)"
                )
            }
            SimError::InvalidConfig(msg) => write!(f, "invalid configuration: {msg}"),
            SimError::InvalidFlags(msg) => write!(f, "invalid optimization flags: {msg}"),
            SimError::PartitionCountMismatch { expected, got } => write!(
                f,
                "partition count mismatch: dataset has {expected} graph(s) but {got} partition matrix(es) were supplied"
            ),
            SimError::PartitionShapeMismatch { expected, got } => write!(
                f,
                "partition shape mismatch: config wants (V, N) = {expected:?} but a partition was built for {got:?}"
            ),
            SimError::RaggedSchedule(e) => write!(f, "{e}"),
            SimError::NonFiniteMetric { metric, value } => {
                write!(f, "non-finite {metric} = {value}")
            }
            SimError::ExceedsChipMemory { footprint_bytes, budget_bytes, min_shards } => write!(
                f,
                "graph footprint of {footprint_bytes} bytes exceeds the per-chip memory \
                 budget of {budget_bytes} bytes; shard across at least {min_shards} chips \
                 (run_sharded / --shards {min_shards})"
            ),
            SimError::SegmentShapeMismatch { graph, expected, got } => write!(
                f,
                "plan assembly for graph {graph} expected {expected} pipeline segment(s) \
                 (one per layer) but produced {got}"
            ),
            SimError::Mutation(msg) => write!(f, "graph mutation rejected: {msg}"),
            SimError::Workload { model, dataset, source } => {
                write!(f, "workload {}/{dataset}: {source}", model.name())
            }
        }
    }
}

impl std::error::Error for SimError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            SimError::Workload { source, .. } => Some(source),
            _ => None,
        }
    }
}

impl SimError {
    /// Wraps an error with the `(model, dataset)` workload it came from.
    pub fn in_workload(self, model: ModelKind, dataset: impl Into<String>) -> Self {
        SimError::Workload { model, dataset: dataset.into(), source: Box::new(self) }
    }
}

impl From<crate::sim::RaggedStages> for SimError {
    fn from(e: crate::sim::RaggedStages) -> Self {
        SimError::RaggedSchedule(e)
    }
}

impl From<crate::graph::mutate::MutateError> for SimError {
    fn from(e: crate::graph::mutate::MutateError) -> Self {
        SimError::Mutation(e.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_carries_context() {
        let e = SimError::UnknownDataset("Nope".into());
        assert!(e.to_string().contains("Nope"));
        let wrapped = e.in_workload(ModelKind::Gcn, "Cora");
        let msg = wrapped.to_string();
        assert!(msg.contains("GCN") && msg.contains("Cora") && msg.contains("Nope"), "{msg}");
    }

    #[test]
    fn workload_exposes_source() {
        use std::error::Error;
        let e = SimError::InvalidConfig("bad".into()).in_workload(ModelKind::Gat, "Citeseer");
        assert!(e.source().is_some());
        assert!(SimError::InvalidConfig("bad".into()).source().is_none());
    }

    #[test]
    fn exceeds_chip_memory_names_min_shards() {
        let e = SimError::ExceedsChipMemory {
            footprint_bytes: 10 << 30,
            budget_bytes: 4 << 30,
            min_shards: 3,
        };
        let msg = e.to_string();
        assert!(msg.contains("at least 3 chips"), "{msg}");
    }

    #[test]
    fn segment_shape_mismatch_names_graph_and_counts() {
        let e = SimError::SegmentShapeMismatch { graph: 7, expected: 3, got: 2 };
        let msg = e.to_string();
        assert!(msg.contains("graph 7") && msg.contains('3') && msg.contains('2'), "{msg}");
    }

    #[test]
    fn shape_mismatch_formats_both_shapes() {
        let e = SimError::PartitionShapeMismatch { expected: (20, 20), got: (10, 10) };
        let msg = e.to_string();
        assert!(msg.contains("(20, 20)") && msg.contains("(10, 10)"), "{msg}");
    }
}
