//! The functional PJRT datapath.
//!
//! `make artifacts` (build-time Python, never on the request path) lowers
//! each `(model, dataset)` forward pass — JAX calling the Pallas photonic
//! kernels — to HLO **text** under `artifacts/`, together with a JSON
//! manifest describing the input tensors and the binary files holding the
//! trained weights and the dataset arrays. This module loads an artifact,
//! compiles it on the PJRT CPU client, binds its inputs from the manifest,
//! and executes real GNN inference from Rust.
//!
//! HLO text (not a serialized `HloModuleProto`) is the interchange format:
//! jax ≥ 0.5 emits 64-bit instruction ids that xla_extension 0.5.1 rejects;
//! the text parser reassigns ids (see `/opt/xla-example/README.md`).
//!
//! # Feature gating (`pjrt`)
//!
//! Everything that touches the `xla` crate — [`Engine`] and
//! `HostTensor::to_literal` — is behind the off-by-default `pjrt` cargo
//! feature, so the core simulator builds and tests without `xla_extension`
//! installed. Enabling `pjrt` additionally requires adding the `xla` crate
//! (xla-rs) to `rust/Cargo.toml` and pointing `XLA_EXTENSION_DIR` at a
//! local `xla_extension` install. The manifest/tensor plumbing
//! ([`Manifest`], [`TensorRef`], [`HostTensor`]) and the evaluation helpers
//! ([`argmax_rows`], [`masked_accuracy`]) are always available.
//!
//! # When `artifacts/` is absent
//!
//! The repository does not ship pre-built artifacts; `rust/artifacts/`
//! exists only after `make artifacts` runs the Python build
//! (`python/compile/aot.py`). Until then every consumer degrades
//! gracefully rather than failing the build or the test suite:
//!
//! * [`Engine::load`] returns an `Err` whose context names the missing
//!   manifest path (`reading "…/<name>.json"`) — callers decide whether
//!   that is fatal;
//! * the runtime integration tests (`tests/integration_runtime.rs`) check
//!   for `artifacts/.stamp` and *skip* (not fail) when it is missing;
//! * `ghost infer` and the end-to-end examples print a
//!   "run `make artifacts` first" hint and exit;
//! * `benches/hotpath.rs` skips its PJRT section.

use std::collections::HashMap;
#[cfg(feature = "pjrt")]
use std::path::{Path, PathBuf};

#[cfg(feature = "pjrt")]
use anyhow::Context;
use anyhow::{anyhow, bail, Result};

use crate::util::json::Json;

/// Element type of a manifest tensor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    pub fn size_bytes(&self) -> usize {
        4
    }

    fn parse(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            other => bail!("unsupported dtype {other}"),
        }
    }
}

/// A tensor stored in one of the artifact's binary files.
#[derive(Debug, Clone)]
pub struct TensorRef {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
    /// Key into [`Manifest::files`].
    pub file: String,
    /// Byte offset within the file.
    pub offset: u64,
}

impl TensorRef {
    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn byte_len(&self) -> usize {
        self.element_count() * self.dtype.size_bytes()
    }

    fn from_json(v: &Json) -> Result<Self> {
        let name = v
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("tensor missing name"))?
            .to_string();
        let shape = v
            .get("shape")
            .and_then(Json::as_array)
            .ok_or_else(|| anyhow!("tensor {name} missing shape"))?
            .iter()
            .map(|d| d.as_u64().map(|d| d as usize).ok_or_else(|| anyhow!("bad dim")))
            .collect::<Result<Vec<_>>>()?;
        let dtype = Dtype::parse(
            v.get("dtype").and_then(Json::as_str).ok_or_else(|| anyhow!("missing dtype"))?,
        )?;
        let file = v
            .get("file")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("tensor {name} missing file"))?
            .to_string();
        let offset = v.get("offset").and_then(Json::as_u64).unwrap_or(0);
        Ok(Self { name, shape, dtype, file, offset })
    }
}

/// Artifact manifest written by `python/compile/aot.py`.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// HLO text filename (relative to the artifacts dir).
    pub hlo: String,
    /// Executable inputs, in call order.
    pub inputs: Vec<TensorRef>,
    /// Non-input tensors (labels, masks) for evaluation.
    pub extras: HashMap<String, TensorRef>,
    /// Logical file key → filename.
    pub files: HashMap<String, String>,
    /// Free-form metadata (model, dataset, measured accuracies, …).
    pub meta: Json,
}

impl Manifest {
    /// Parses the manifest JSON document.
    pub fn parse(text: &str) -> Result<Self> {
        let v = Json::parse(text).map_err(|e| anyhow!("manifest JSON: {e}"))?;
        let hlo = v
            .get("hlo")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("manifest missing 'hlo'"))?
            .to_string();
        let inputs = v
            .get("inputs")
            .and_then(Json::as_array)
            .ok_or_else(|| anyhow!("manifest missing 'inputs'"))?
            .iter()
            .map(TensorRef::from_json)
            .collect::<Result<Vec<_>>>()?;
        let mut extras = HashMap::new();
        if let Some(obj) = v.get("extras").and_then(Json::as_object) {
            for (k, t) in obj {
                extras.insert(k.clone(), TensorRef::from_json(t)?);
            }
        }
        let mut files = HashMap::new();
        if let Some(obj) = v.get("files").and_then(Json::as_object) {
            for (k, f) in obj {
                files.insert(
                    k.clone(),
                    f.as_str().ok_or_else(|| anyhow!("bad file entry"))?.to_string(),
                );
            }
        }
        let meta = v.get("meta").cloned().unwrap_or(Json::Null);
        Ok(Self { hlo, inputs, extras, files, meta })
    }
}

/// Raw host copy of a tensor.
#[derive(Debug, Clone)]
pub enum HostTensor {
    F32(Vec<f32>, Vec<usize>),
    I32(Vec<i32>, Vec<usize>),
}

impl HostTensor {
    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32(_, s) | HostTensor::I32(_, s) => s,
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32(v, _) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32(v, _) => Ok(v),
            _ => bail!("tensor is not i32"),
        }
    }
}

#[cfg(feature = "pjrt")]
impl HostTensor {
    /// Converts to an XLA literal with this tensor's shape.
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let lit = match self {
            HostTensor::F32(v, shape) => {
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(v).reshape(&dims).map_err(|e| anyhow!("{e:?}"))?
            }
            HostTensor::I32(v, shape) => {
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(v).reshape(&dims).map_err(|e| anyhow!("{e:?}"))?
            }
        };
        Ok(lit)
    }
}

/// A loaded, compiled artifact ready to execute (`pjrt` feature).
#[cfg(feature = "pjrt")]
pub struct Engine {
    client: xla::PjRtClient,
    exe: xla::PjRtLoadedExecutable,
    pub manifest: Manifest,
    dir: PathBuf,
    /// Cache of the binary files backing the manifest tensors.
    file_cache: HashMap<String, Vec<u8>>,
}

#[cfg(feature = "pjrt")]
impl Engine {
    /// Loads `artifacts_dir/<name>.json`, compiles its HLO on the PJRT CPU
    /// client, and memory-loads the referenced binary files. When the
    /// artifacts directory has not been built (`make artifacts`), this
    /// fails with a "reading …/<name>.json" error rather than panicking.
    pub fn load(artifacts_dir: impl AsRef<Path>, name: &str) -> Result<Self> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        let manifest_path = dir.join(format!("{name}.json"));
        let manifest = Manifest::parse(
            &std::fs::read_to_string(&manifest_path)
                .with_context(|| format!("reading {manifest_path:?}"))?,
        )?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        let proto = xla::HloModuleProto::from_text_file(dir.join(&manifest.hlo))
            .map_err(|e| anyhow!("parsing HLO text: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).map_err(|e| anyhow!("compiling: {e:?}"))?;
        let mut file_cache = HashMap::new();
        for (key, fname) in &manifest.files {
            let bytes = std::fs::read(dir.join(fname))
                .with_context(|| format!("reading artifact file {fname}"))?;
            file_cache.insert(key.clone(), bytes);
        }
        Ok(Self { client, exe, manifest, dir, file_cache })
    }

    /// The artifacts directory this engine was loaded from.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// PJRT platform name (always "cpu" in this build).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Reads a manifest tensor from the cached binary files.
    pub fn host_tensor(&self, t: &TensorRef) -> Result<HostTensor> {
        let file = self
            .file_cache
            .get(&t.file)
            .ok_or_else(|| anyhow!("manifest references unknown file key {}", t.file))?;
        let start = t.offset as usize;
        let end = start + t.byte_len();
        if end > file.len() {
            bail!(
                "tensor {} spans {}..{} but file {} has {} bytes",
                t.name,
                start,
                end,
                t.file,
                file.len()
            );
        }
        let bytes = &file[start..end];
        Ok(match t.dtype {
            Dtype::F32 => HostTensor::F32(
                bytes.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect(),
                t.shape.clone(),
            ),
            Dtype::I32 => HostTensor::I32(
                bytes.chunks_exact(4).map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect(),
                t.shape.clone(),
            ),
        })
    }

    /// Reads an `extras` tensor by name.
    pub fn extra(&self, name: &str) -> Result<HostTensor> {
        let t = self
            .manifest
            .extras
            .get(name)
            .ok_or_else(|| anyhow!("no extra tensor named {name}"))?;
        self.host_tensor(t)
    }

    /// Executes the artifact with its manifest-bound inputs. Returns the
    /// flattened output tensors (the lowering uses `return_tuple=True`).
    pub fn run(&self) -> Result<Vec<HostTensor>> {
        let literals: Vec<xla::Literal> = self
            .manifest
            .inputs
            .iter()
            .map(|t| self.host_tensor(t)?.to_literal())
            .collect::<Result<_>>()?;
        self.run_with(&literals)
    }

    /// Executes with caller-provided input literals (manifest order).
    pub fn run_with(&self, inputs: &[xla::Literal]) -> Result<Vec<HostTensor>> {
        let result =
            self.exe.execute::<xla::Literal>(inputs).map_err(|e| anyhow!("execute: {e:?}"))?;
        let tuple = result[0][0].to_literal_sync().map_err(|e| anyhow!("to_literal: {e:?}"))?;
        let parts = tuple.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))?;
        parts
            .into_iter()
            .map(|lit| {
                let shape = lit.array_shape().map_err(|e| anyhow!("shape: {e:?}"))?;
                let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
                match shape.ty() {
                    xla::ElementType::F32 => Ok(HostTensor::F32(
                        lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))?,
                        dims,
                    )),
                    xla::ElementType::S32 => Ok(HostTensor::I32(
                        lit.to_vec::<i32>().map_err(|e| anyhow!("to_vec: {e:?}"))?,
                        dims,
                    )),
                    other => bail!("unsupported output element type {other:?}"),
                }
            })
            .collect()
    }
}

/// Row-wise argmax of a `[n, c]` logits tensor.
pub fn argmax_rows(logits: &[f32], n: usize, c: usize) -> Vec<usize> {
    (0..n)
        .map(|i| {
            let row = &logits[i * c..(i + 1) * c];
            row.iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .map(|(j, _)| j)
                .unwrap_or(0)
        })
        .collect()
}

/// Accuracy of predictions against labels over an optional 0/1 mask.
pub fn masked_accuracy(pred: &[usize], labels: &[i32], mask: Option<&[i32]>) -> f64 {
    let mut correct = 0usize;
    let mut total = 0usize;
    for i in 0..pred.len() {
        if mask.map(|m| m[i] != 0).unwrap_or(true) {
            total += 1;
            if pred[i] == labels[i] as usize {
                correct += 1;
            }
        }
    }
    if total == 0 {
        0.0
    } else {
        correct as f64 / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_rows_basics() {
        let logits = [0.1, 0.9, 0.0, 3.0, -1.0, 2.0];
        assert_eq!(argmax_rows(&logits, 2, 3), vec![1, 0]);
    }

    #[test]
    fn masked_accuracy_counts() {
        let pred = vec![0usize, 1, 2, 1];
        let labels = vec![0i32, 1, 0, 1];
        assert!((masked_accuracy(&pred, &labels, None) - 0.75).abs() < 1e-12);
        let mask = vec![1i32, 1, 0, 0];
        assert!((masked_accuracy(&pred, &labels, Some(&mask)) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn manifest_parses() {
        let json = r#"{
            "hlo": "m.hlo.txt",
            "inputs": [{"name":"x","shape":[2,3],"dtype":"f32","file":"data","offset":0}],
            "files": {"data": "d.bin"},
            "meta": {"model": "GCN"}
        }"#;
        let m = Manifest::parse(json).unwrap();
        assert_eq!(m.inputs[0].element_count(), 6);
        assert_eq!(m.inputs[0].byte_len(), 24);
        assert_eq!(m.files["data"], "d.bin");
        assert_eq!(m.meta.get("model").unwrap().as_str(), Some("GCN"));
    }

    #[test]
    fn manifest_rejects_bad_dtype() {
        let json = r#"{
            "hlo": "m.hlo.txt",
            "inputs": [{"name":"x","shape":[2],"dtype":"f64","file":"d","offset":0}],
            "files": {}
        }"#;
        assert!(Manifest::parse(json).is_err());
    }

    #[test]
    fn tensor_ref_sizes() {
        let t = TensorRef {
            name: "w".into(),
            shape: vec![4, 5],
            dtype: Dtype::I32,
            file: "data".into(),
            offset: 16,
        };
        assert_eq!(t.element_count(), 20);
        assert_eq!(t.byte_len(), 80);
    }
}
