//! Regeneration of every table and figure in the paper's evaluation
//! section (§4). Each function returns structured rows; `print_*` helpers
//! render them as the text tables the CLI and benches emit.


use crate::baselines::{run_baseline, supports, PLATFORMS};
use crate::config::GhostConfig;
use crate::coordinator::{BatchEngine, KindTotals, OptFlags, SimError, SimReport, SimRequest};
use crate::energy::{geomean, Metrics};
use crate::gnn::models::{Model, ModelKind};
use crate::gnn::workload::Workload;
use crate::graph::datasets::{DatasetSpec, ALL_DATASETS, LARGE_DATASETS};
use crate::photonics::devices::DeviceParams;
use crate::util::json::{obj, Json};

/// All 16 evaluated `(model, dataset)` workloads, paper order.
pub fn all_pairs() -> Vec<(ModelKind, &'static str)> {
    let mut v = Vec::new();
    for kind in ModelKind::ALL {
        for ds in kind.datasets() {
            v.push((kind, ds));
        }
    }
    v
}

/// Runs the GHOST simulator on every workload with the given flags,
/// fanned out in parallel through the process-wide [`BatchEngine`] (each
/// dataset is generated and partitioned once per `(dataset, V, N)` for the
/// whole process, however many figures ask for it). The first failing
/// workload aborts the batch with its [`SimError`] — figures never panic
/// on an unsimulatable configuration.
pub fn ghost_reports(cfg: GhostConfig, flags: OptFlags) -> Result<Vec<SimReport>, SimError> {
    let reqs: Vec<SimRequest> = all_pairs()
        .into_iter()
        .map(|(kind, ds)| SimRequest::new(kind, ds, cfg, flags))
        .collect();
    BatchEngine::global().run_batch(&reqs).into_iter().collect()
}

// ---------------------------------------------------------------- Table 1

/// Table 1: device latency/power parameters.
pub fn table1() -> Vec<(String, f64, f64)> {
    let p = DeviceParams::paper();
    vec![
        ("EO Tuning".into(), p.eo_tuning.latency_s, p.eo_tuning.power_w),
        ("TO Tuning".into(), p.to_tuning.latency_s, p.to_tuning.power_w),
        ("VCSEL".into(), p.vcsel.latency_s, p.vcsel.power_w),
        ("Photodetector".into(), p.photodetector.latency_s, p.photodetector.power_w),
        ("SOA".into(), p.soa.latency_s, p.soa.power_w),
        ("DAC (8 bit)".into(), p.dac.latency_s, p.dac.power_w),
        ("ADC (8 bit)".into(), p.adc.latency_s, p.adc.power_w),
    ]
}

pub fn print_table1() {
    println!("Table 1: device parameters");
    println!("{:<16} {:>12} {:>12}", "Device", "Latency", "Power");
    for (name, lat, pow) in table1() {
        println!("{name:<16} {:>10.3} ns {:>9.3} mW", lat * 1e9, pow * 1e3);
    }
}

// ---------------------------------------------------------------- Table 2

/// Table 2: dataset statistics (measured from the generated graphs, which
/// must match the paper's spec).
#[derive(Debug)]
pub struct Table2Row {
    pub name: &'static str,
    pub avg_nodes: f64,
    pub avg_edges: f64,
    pub n_features: usize,
    pub n_labels: usize,
    pub n_graphs: usize,
}

pub fn table2() -> Result<Vec<Table2Row>, SimError> {
    let engine = BatchEngine::global();
    ALL_DATASETS
        .iter()
        .map(|spec| {
            let d = engine.dataset(spec.name)?;
            Ok(Table2Row {
                name: spec.name,
                avg_nodes: d.total_vertices() as f64 / d.graphs.len() as f64,
                avg_edges: d.total_edges() as f64 / d.graphs.len() as f64,
                n_features: spec.n_features,
                n_labels: spec.n_labels,
                n_graphs: spec.n_graphs,
            })
        })
        .collect()
}

pub fn print_table2() -> Result<(), SimError> {
    println!("Table 2: graph dataset parameters (generated)");
    println!(
        "{:<12} {:>10} {:>10} {:>10} {:>8} {:>8}",
        "Dataset", "#Nodes", "#Edges", "#Feat", "#Labels", "#Graphs"
    );
    for r in table2()? {
        println!(
            "{:<12} {:>10.1} {:>10.1} {:>10} {:>8} {:>8}",
            r.name, r.avg_nodes, r.avg_edges, r.n_features, r.n_labels, r.n_graphs
        );
    }
    Ok(())
}

// -------------------------------------------------------- dataset catalog

/// One row of the dataset catalog: every *named* dataset the simulator
/// serves, across both tiers. Spec values only — nothing is generated
/// (reddit-syn takes seconds and hundreds of MB; it stays on demand).
#[derive(Debug)]
pub struct DatasetCatalogRow {
    pub name: &'static str,
    /// `"table-2"` or `"large"`.
    pub tier: &'static str,
    pub avg_nodes: usize,
    pub avg_edges: usize,
    pub n_features: usize,
    pub n_labels: usize,
    pub n_graphs: usize,
}

/// The named datasets of both tiers (parameterized `rmat-...` specs are
/// open-ended and therefore not enumerated here).
pub fn dataset_catalog() -> Vec<DatasetCatalogRow> {
    let row = |spec: &DatasetSpec, tier: &'static str| DatasetCatalogRow {
        name: spec.name,
        tier,
        avg_nodes: spec.avg_nodes,
        avg_edges: spec.avg_edges,
        n_features: spec.n_features,
        n_labels: spec.n_labels,
        n_graphs: spec.n_graphs,
    };
    ALL_DATASETS
        .iter()
        .map(|s| row(s, "table-2"))
        .chain(LARGE_DATASETS.iter().map(|s| row(s, "large")))
        .collect()
}

pub fn print_dataset_catalog() {
    println!("Dataset catalog (both tiers; values are spec targets)");
    println!(
        "{:<16} {:<8} {:>10} {:>12} {:>8} {:>8} {:>8}",
        "Dataset", "Tier", "#Nodes", "#Edges", "#Feat", "#Labels", "#Graphs"
    );
    for r in dataset_catalog() {
        println!(
            "{:<16} {:<8} {:>10} {:>12} {:>8} {:>8} {:>8}",
            r.name, r.tier, r.avg_nodes, r.avg_edges, r.n_features, r.n_labels, r.n_graphs
        );
    }
    println!(
        "Arbitrary scales: rmat-<V>v-<E>e[-<F>f][-<L>l][-<G>g][-<S>s], \
         e.g. rmat-200000v-1300000e"
    );
}

// ----------------------------------------------------------------- Fig. 8

/// One Fig. 8 bar: normalized energy (baseline = 1.0) per workload for an
/// optimization combination.
#[derive(Debug)]
pub struct Fig8Row {
    pub label: String,
    /// `(model, dataset, normalized energy)` per workload.
    pub per_workload: Vec<(String, String, f64)>,
    /// Geometric-mean normalized energy.
    pub mean: f64,
}

pub fn fig8(cfg: GhostConfig) -> Result<Vec<Fig8Row>, SimError> {
    // The engine's partition cache makes the 9 preset evaluations share
    // one partitioning per workload (offline preprocessing is
    // flag-independent, so every preset hits the same (dataset, V, N) key).
    let baseline: Vec<SimReport> = ghost_reports(cfg, OptFlags::baseline())?;
    OptFlags::fig8_presets()
        .into_iter()
        .map(|flags| {
            let reports = ghost_reports(cfg, flags)?;
            let per_workload: Vec<(String, String, f64)> = reports
                .iter()
                .zip(&baseline)
                .map(|(r, b)| {
                    (
                        r.model.name().to_string(),
                        r.dataset.clone(),
                        r.metrics.energy_j / b.metrics.energy_j,
                    )
                })
                .collect();
            let mean = geomean(per_workload.iter().map(|(_, _, e)| *e));
            Ok(Fig8Row { label: flags.label(), per_workload, mean })
        })
        .collect()
}

pub fn print_fig8(cfg: GhostConfig) -> Result<(), SimError> {
    println!("Fig. 8: normalized energy per optimization combination (baseline = 1.0)");
    for row in fig8(cfg)? {
        println!("{:<22} mean {:.3}  (reduction {:.2}x)", row.label, row.mean, 1.0 / row.mean);
    }
    Ok(())
}

// ----------------------------------------------------------------- Fig. 9

/// One Fig. 9 bar: the paper's per-block latency fractions plus the exact
/// per-[`crate::coordinator::StageKind`] totals from the evaluated plan —
/// readout and weight staging as first-class entries instead of being
/// folded into the aggregate bar.
#[derive(Debug)]
pub struct Fig9Row {
    pub model: String,
    pub dataset: String,
    /// Fractional block split (aggregate includes gather, reduce, and
    /// readout — the paper's three-bar presentation).
    pub aggregate: f64,
    pub combine: f64,
    pub update: f64,
    /// Exact per-kind busy-time and energy totals.
    pub kinds: KindTotals,
    /// Total busy time summed from the report's block accumulators
    /// (aggregate + combine + update + weight staging + edge streams),
    /// seconds. The per-kind totals in `kinds` must sum to this — the CI
    /// smoke asserts it on the JSON output.
    pub total_busy_s: f64,
}

pub fn fig9(cfg: GhostConfig) -> Result<Vec<Fig9Row>, SimError> {
    Ok(ghost_reports(cfg, OptFlags::ghost_default())?
        .into_iter()
        .map(|r| {
            let (a, c, u) = r.breakdown();
            let total_busy_s = r.aggregate_s
                + r.combine_s
                + r.update_s
                + r.weight_stage_s
                + r.kinds.edge_stream.latency_s;
            Fig9Row {
                model: r.model.name().to_string(),
                dataset: r.dataset,
                aggregate: a,
                combine: c,
                update: u,
                kinds: r.kinds,
                total_busy_s,
            }
        })
        .collect())
}

pub fn print_fig9(cfg: GhostConfig) -> Result<(), SimError> {
    let rows = fig9(cfg)?;
    println!("Fig. 9: latency breakdown per block");
    println!("{:<10} {:<12} {:>10} {:>10} {:>10}", "Model", "Dataset", "Aggregate", "Combine", "Update");
    for r in &rows {
        println!(
            "{:<10} {:<12} {:>9.1}% {:>9.1}% {:>9.1}%",
            r.model,
            r.dataset,
            r.aggregate * 100.0,
            r.combine * 100.0,
            r.update * 100.0
        );
    }
    println!();
    println!("Fig. 9 (exact per-kind busy time, us; readout & weight staging unfolded)");
    println!(
        "{:<10} {:<12} {:>9} {:>9} {:>9} {:>9} {:>9} {:>9} {:>9} {:>9}",
        "Model", "Dataset", "Gather", "Reduce", "Transfrm", "Update", "Readout", "WeightSt", "EdgeStrm", "RemoteGt"
    );
    for r in &rows {
        let k = &r.kinds;
        println!(
            "{:<10} {:<12} {:>9.3} {:>9.3} {:>9.3} {:>9.3} {:>9.3} {:>9.3} {:>9.3} {:>9.3}",
            r.model,
            r.dataset,
            k.gather.latency_s * 1e6,
            k.reduce.latency_s * 1e6,
            k.transform.latency_s * 1e6,
            k.update.latency_s * 1e6,
            k.readout.latency_s * 1e6,
            k.weight_stage.latency_s * 1e6,
            k.edge_stream.latency_s * 1e6,
            k.remote_gather.latency_s * 1e6,
        );
    }
    Ok(())
}

// ----------------------------------------------------- sharded execution

/// One row of the communication-vs-compute sharding breakdown: one
/// workload executed across `shards` chips.
#[derive(Debug)]
pub struct ShardingRow {
    pub model: String,
    pub dataset: String,
    pub shards: usize,
    /// End-to-end barriered makespan, seconds.
    pub makespan_s: f64,
    /// Total busy time across every stage kind and chip, seconds. The
    /// per-kind totals in `kinds` (including `remote_gather`) sum to this
    /// — the CI smoke asserts it on the JSON output.
    pub total_busy_s: f64,
    /// Inter-chip communication busy time (remote gathers), seconds.
    pub comm_s: f64,
    /// `comm_s / total_busy_s`; 0 for a 1-shard run.
    pub comm_frac: f64,
    pub kinds: KindTotals,
}

/// Runs one workload at each shard count through the global engine's
/// sharded-plan cache and derives the communication-vs-compute split.
pub fn sharding(
    cfg: GhostConfig,
    model: ModelKind,
    dataset: &str,
    shard_counts: &[usize],
) -> Result<Vec<ShardingRow>, SimError> {
    let engine = BatchEngine::global();
    let req = SimRequest::new(model, dataset, cfg, OptFlags::ghost_default());
    shard_counts
        .iter()
        .map(|&shards| {
            let r = engine.run_sharded(&req, shards)?;
            // Kind-level weight staging counts every chip's busy time
            // (r.weight_stage_s is the chip-0 critical-path share), so the
            // per-kind rows sum to this total exactly.
            let total_busy_s = r.aggregate_s
                + r.combine_s
                + r.update_s
                + r.kinds.weight_stage.latency_s
                + r.kinds.edge_stream.latency_s
                + r.kinds.remote_gather.latency_s;
            let comm_s = r.kinds.remote_gather.latency_s;
            Ok(ShardingRow {
                model: r.model.name().to_string(),
                dataset: r.dataset,
                shards,
                makespan_s: r.metrics.latency_s,
                total_busy_s,
                comm_s,
                comm_frac: if total_busy_s > 0.0 { comm_s / total_busy_s } else { 0.0 },
                kinds: r.kinds,
            })
        })
        .collect()
}

pub fn print_sharding(
    cfg: GhostConfig,
    model: ModelKind,
    dataset: &str,
    shard_counts: &[usize],
) -> Result<(), SimError> {
    let rows = sharding(cfg, model, dataset, shard_counts)?;
    println!("Sharded execution: communication vs compute ({}/{dataset})", model.name());
    println!(
        "{:>7} {:>13} {:>13} {:>13} {:>8}",
        "Shards", "Makespan us", "Busy us", "Comm us", "Comm %"
    );
    for r in &rows {
        println!(
            "{:>7} {:>13.3} {:>13.3} {:>13.3} {:>7.2}%",
            r.shards,
            r.makespan_s * 1e6,
            r.total_busy_s * 1e6,
            r.comm_s * 1e6,
            r.comm_frac * 100.0
        );
    }
    Ok(())
}

// ----------------------------------------------------- Figs. 10 / 11 / 12

/// One comparison row: GHOST-vs-platform ratios averaged (geomean) over the
/// workloads that platform supports.
#[derive(Debug)]
pub struct ComparisonRow {
    pub platform: &'static str,
    /// GHOST GOPS ÷ platform GOPS (Fig. 10; > 1 = GHOST wins).
    pub gops_ratio: f64,
    /// Platform EPB ÷ GHOST EPB (Fig. 11; > 1 = GHOST wins).
    pub epb_ratio: f64,
    /// Platform EPB/GOPS ÷ GHOST EPB/GOPS (Fig. 12; > 1 = GHOST wins).
    pub epb_gops_ratio: f64,
    /// Workloads compared.
    pub n_workloads: usize,
}

/// Per-workload metrics for GHOST and every supporting platform.
pub fn comparison_detail(
    cfg: GhostConfig,
) -> Result<Vec<(ModelKind, &'static str, Metrics, Vec<(&'static str, Metrics)>)>, SimError> {
    let engine = BatchEngine::global();
    all_pairs()
        .into_iter()
        .map(|(kind, ds)| {
            let dataset = engine.dataset(ds)?;
            let ghost = engine
                .run(&SimRequest::new(kind, ds, cfg, OptFlags::ghost_default()))?
                .metrics;
            let model = Model::for_dataset(kind, &dataset.spec);
            let w = Workload::characterize(&model, &dataset);
            let rows: Vec<(&'static str, Metrics)> = PLATFORMS
                .iter()
                .filter(|p| supports(p.name, kind))
                .map(|p| (p.name, run_baseline(p, &w)))
                .collect();
            Ok((kind, ds, ghost, rows))
        })
        .collect()
}

/// The Figs. 10–12 summary: geomean ratios per platform.
pub fn comparison_summary(cfg: GhostConfig) -> Result<Vec<ComparisonRow>, SimError> {
    let detail = comparison_detail(cfg)?;
    Ok(PLATFORMS
        .iter()
        .map(|p| {
            let mut gops = Vec::new();
            let mut epb = Vec::new();
            let mut eg = Vec::new();
            for (_, _, ghost, rows) in &detail {
                if let Some((_, m)) = rows.iter().find(|(n, _)| *n == p.name) {
                    gops.push(ghost.gops() / m.gops());
                    epb.push(m.epb() / ghost.epb());
                    eg.push(m.epb_per_gops() / ghost.epb_per_gops());
                }
            }
            ComparisonRow {
                platform: p.name,
                gops_ratio: geomean(gops.iter().copied()),
                epb_ratio: geomean(epb.iter().copied()),
                epb_gops_ratio: geomean(eg.iter().copied()),
                n_workloads: gops.len(),
            }
        })
        .collect())
}

pub fn print_comparison(cfg: GhostConfig) -> Result<(), SimError> {
    println!("Figs. 10-12: GHOST vs platforms (geomean ratios, >1 = GHOST wins)");
    println!(
        "{:<10} {:>12} {:>12} {:>14} {:>6}",
        "Platform", "GOPS ratio", "EPB ratio", "EPB/GOPS", "N"
    );
    for r in comparison_summary(cfg)? {
        println!(
            "{:<10} {:>11.1}x {:>11.1}x {:>13.2e} {:>6}",
            r.platform, r.gops_ratio, r.epb_ratio, r.epb_gops_ratio, r.n_workloads
        );
    }
    Ok(())
}

// ------------------------------------------------------- JSON serializers

/// `{busy_s, energy_j}` object per kind, in schedule order.
pub fn kind_totals_json(kinds: &KindTotals) -> Json {
    obj(kinds
        .rows()
        .iter()
        .map(|(name, c)| {
            (
                *name,
                obj(vec![
                    ("busy_s", Json::Num(c.latency_s)),
                    ("energy_j", Json::Num(c.energy_j)),
                ]),
            )
        })
        .collect())
}

/// Table 1 rows as JSON.
pub fn table1_json() -> Json {
    Json::Arr(
        table1()
            .into_iter()
            .map(|(device, latency_s, power_w)| {
                obj(vec![
                    ("device", Json::Str(device)),
                    ("latency_s", Json::Num(latency_s)),
                    ("power_w", Json::Num(power_w)),
                ])
            })
            .collect(),
    )
}

/// Table 2 rows as JSON.
pub fn table2_json() -> Result<Json, SimError> {
    Ok(Json::Arr(
        table2()?
            .into_iter()
            .map(|r| {
                obj(vec![
                    ("dataset", Json::Str(r.name.to_string())),
                    ("avg_nodes", Json::Num(r.avg_nodes)),
                    ("avg_edges", Json::Num(r.avg_edges)),
                    ("n_features", Json::Num(r.n_features as f64)),
                    ("n_labels", Json::Num(r.n_labels as f64)),
                    ("n_graphs", Json::Num(r.n_graphs as f64)),
                ])
            })
            .collect(),
    ))
}

/// Dataset catalog rows (both tiers) as JSON.
pub fn dataset_catalog_json() -> Json {
    Json::Arr(
        dataset_catalog()
            .into_iter()
            .map(|r| {
                obj(vec![
                    ("dataset", Json::Str(r.name.to_string())),
                    ("tier", Json::Str(r.tier.to_string())),
                    ("avg_nodes", Json::Num(r.avg_nodes as f64)),
                    ("avg_edges", Json::Num(r.avg_edges as f64)),
                    ("n_features", Json::Num(r.n_features as f64)),
                    ("n_labels", Json::Num(r.n_labels as f64)),
                    ("n_graphs", Json::Num(r.n_graphs as f64)),
                ])
            })
            .collect(),
    )
}

/// Fig. 8 rows as JSON (per-workload normalized energies + geomean).
pub fn fig8_json(cfg: GhostConfig) -> Result<Json, SimError> {
    Ok(Json::Arr(
        fig8(cfg)?
            .into_iter()
            .map(|r| {
                obj(vec![
                    ("label", Json::Str(r.label)),
                    ("mean_normalized_energy", Json::Num(r.mean)),
                    (
                        "per_workload",
                        Json::Arr(
                            r.per_workload
                                .into_iter()
                                .map(|(model, dataset, e)| {
                                    obj(vec![
                                        ("model", Json::Str(model)),
                                        ("dataset", Json::Str(dataset)),
                                        ("normalized_energy", Json::Num(e)),
                                    ])
                                })
                                .collect(),
                        ),
                    ),
                ])
            })
            .collect(),
    ))
}

/// Fig. 9 rows as JSON: block fractions, total busy time, and the exact
/// per-kind breakdown (`kinds.<kind>.busy_s` sums to `total_busy_s` — the
/// CI smoke pins the invariant).
pub fn fig9_json(cfg: GhostConfig) -> Result<Json, SimError> {
    Ok(Json::Arr(
        fig9(cfg)?
            .into_iter()
            .map(|r| {
                obj(vec![
                    ("model", Json::Str(r.model)),
                    ("dataset", Json::Str(r.dataset)),
                    ("aggregate_frac", Json::Num(r.aggregate)),
                    ("combine_frac", Json::Num(r.combine)),
                    ("update_frac", Json::Num(r.update)),
                    ("total_busy_s", Json::Num(r.total_busy_s)),
                    ("kinds", kind_totals_json(&r.kinds)),
                ])
            })
            .collect(),
    ))
}

/// Sharding breakdown rows as JSON: makespan, total busy time, and the
/// communication-vs-compute split. `kinds.<kind>.busy_s` (including
/// `remote_gather`) sums to `total_busy_s` — the CI smoke pins it.
pub fn sharding_json(
    cfg: GhostConfig,
    model: ModelKind,
    dataset: &str,
    shard_counts: &[usize],
) -> Result<Json, SimError> {
    Ok(Json::Arr(
        sharding(cfg, model, dataset, shard_counts)?
            .into_iter()
            .map(|r| {
                obj(vec![
                    ("model", Json::Str(r.model)),
                    ("dataset", Json::Str(r.dataset)),
                    ("shards", Json::Num(r.shards as f64)),
                    ("makespan_s", Json::Num(r.makespan_s)),
                    ("total_busy_s", Json::Num(r.total_busy_s)),
                    ("comm_s", Json::Num(r.comm_s)),
                    ("comm_frac", Json::Num(r.comm_frac)),
                    ("kinds", kind_totals_json(&r.kinds)),
                ])
            })
            .collect(),
    ))
}

/// Figs. 10–12 summary rows as JSON.
pub fn comparison_json(cfg: GhostConfig) -> Result<Json, SimError> {
    Ok(Json::Arr(
        comparison_summary(cfg)?
            .into_iter()
            .map(|r| {
                obj(vec![
                    ("platform", Json::Str(r.platform.to_string())),
                    ("gops_ratio", Json::Num(r.gops_ratio)),
                    ("epb_ratio", Json::Num(r.epb_ratio)),
                    ("epb_gops_ratio", Json::Num(r.epb_gops_ratio)),
                    ("n_workloads", Json::Num(r.n_workloads as f64)),
                ])
            })
            .collect(),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sixteen_pairs() {
        assert_eq!(all_pairs().len(), 16);
    }

    #[test]
    fn table1_has_seven_devices() {
        assert_eq!(table1().len(), 7);
    }

    #[test]
    fn dataset_catalog_spans_both_tiers() {
        let rows = dataset_catalog();
        assert_eq!(rows.len(), 10);
        assert_eq!(rows.iter().filter(|r| r.tier == "table-2").count(), 8);
        assert_eq!(rows.iter().filter(|r| r.tier == "large").count(), 2);
        let arxiv = rows.iter().find(|r| r.name == "ogbn-arxiv-syn").unwrap();
        assert!(arxiv.avg_edges > 1_000_000);
    }

    #[test]
    fn table2_matches_spec() {
        for r in table2().unwrap() {
            let spec = crate::graph::datasets::spec_by_name(r.name).unwrap();
            assert_eq!(r.n_graphs, spec.n_graphs);
            // Single-graph datasets match exactly; multi-graph within 30 %.
            if spec.n_graphs == 1 {
                assert_eq!(r.avg_nodes as usize, spec.avg_nodes);
                assert_eq!(r.avg_edges as usize, spec.avg_edges);
            } else {
                assert!((r.avg_nodes - spec.avg_nodes as f64).abs() / (spec.avg_nodes as f64) < 0.3);
            }
        }
    }

    #[test]
    fn sharding_rows_conserve_busy_time() {
        let rows =
            sharding(GhostConfig::paper_optimal(), ModelKind::Gcn, "Cora", &[1, 2]).unwrap();
        assert_eq!(rows.len(), 2);

        let one = &rows[0];
        assert_eq!(one.shards, 1);
        assert_eq!(one.comm_s, 0.0);
        assert_eq!(one.comm_frac, 0.0);

        let two = &rows[1];
        assert_eq!(two.shards, 2);
        assert!(two.comm_s > 0.0, "2-shard Cora must pay remote gathers");
        assert!(two.comm_frac > 0.0 && two.comm_frac < 1.0);

        // Per-kind busy totals (incl. remote_gather) sum to total_busy_s —
        // the same invariant the CI JSON smoke checks.
        for r in &rows {
            let kind_sum: f64 = r.kinds.rows().iter().map(|(_, c)| c.latency_s).sum();
            assert!(
                (kind_sum - r.total_busy_s).abs() <= 1e-12 * r.total_busy_s.max(1e-30),
                "kind busy sum {kind_sum} != total {total}",
                total = r.total_busy_s
            );
        }
    }

    #[test]
    fn unknown_dataset_reaches_figures_as_an_error() {
        // Regression: figure paths used to `.expect()` on engine results;
        // an unknown dataset must now surface as a structured SimError.
        let err = sharding(
            GhostConfig::paper_optimal(),
            ModelKind::Gcn,
            "definitely-not-a-dataset",
            &[1],
        )
        .unwrap_err();
        match err {
            SimError::UnknownDataset(name) => assert_eq!(name, "definitely-not-a-dataset"),
            other => panic!("expected UnknownDataset, got {other:?}"),
        }
    }

    #[test]
    fn infeasible_config_propagates_instead_of_panicking() {
        // Regression for the former `.expect("table-2 workload simulates")`
        // in ghost_reports: a chip memory budget far too small for any
        // Table-2 workload must return Err, not abort the process.
        let starved = GhostConfig { chip_mem_bytes: 1 << 10, ..GhostConfig::paper_optimal() };
        assert!(fig9(starved).is_err());
        assert!(ghost_reports(starved, OptFlags::ghost_default()).is_err());
    }
}
