//! The parallel scenario-sweep executor and the capacity planner built
//! on it.
//!
//! A capacity study asks the serving simulator the same question many
//! times with one knob turned — fleet sizes, arrival rates, batching
//! policies — and every probe is an independent deterministic simulation
//! against the same [`BatchEngine`]. [`sweep_with_workers`] fans those
//! probes over worker threads while the engine's plan/profile caches stay
//! shared: concurrent probes that need the same tenant block on one
//! build ([`BatchEngine::service_profile`]'s once-cell cells) instead of
//! duplicating it, so a sweep over any number of fleet-shape variants
//! performs exactly one plan build and one profile build per distinct
//! tenant tuple — `tests/sweep_capacity.rs` pins the counters, and
//! `benches/serve_scale.rs` measures the resulting scaling.
//!
//! ## Determinism
//!
//! The executor inherits the serving layer's guarantee wholesale: each
//! probe runs [`simulate_with_workers`] with **one** inner worker (the
//! parallelism budget is spent across probes, not inside them), each
//! report depends only on its own [`ServeConfig`], and
//! [`par_map_workers`] preserves input order — so the result vector is
//! bit-identical for any worker count, 1 through the machine width.
//!
//! ## Capacity planning
//!
//! [`plan_capacity`] answers "how many accelerators does this tenant mix
//! need to hold a p99 SLO at R requests/sec" for a whole curve of rates
//! at once. Fleet size is monotone in feasibility — more shard groups
//! strictly add service capacity while routing and batching are
//! unchanged — so each rate point bisects over the group count. Round 1
//! probes every point at the fleet ceiling (feasibility screen, and the
//! round that pays every cache build); later rounds batch one bisection
//! step per unresolved point through the sweep executor, re-using
//! memoized probes across points. After round 1 the engine's
//! `plan_builds` / `profile_builds` counters stay flat — the curve
//! carries before/after snapshots so callers (and the CI smoke) can
//! assert it.

use std::collections::BTreeMap;

use crate::coordinator::{BatchEngine, SimError};
use crate::util::json::Json;
use crate::util::parallel::par_map_workers;

use super::metrics::ServeReport;
use super::traffic::TrafficSpec;
use super::{simulate_with_workers, ServeConfig};

/// Runs every scenario through the engine-backed serving simulator,
/// fanning the probes over `workers` threads (serial at `workers <= 1`).
///
/// Results come back in input order, one per config, each exactly what
/// [`simulate_with_workers`] returns for that config alone — see the
/// module docs for why the fan-out cannot change them. Errors are
/// per-probe: one invalid scenario does not poison its siblings.
pub fn sweep_with_workers(
    engine: &BatchEngine,
    cfgs: &[ServeConfig],
    workers: usize,
) -> Vec<Result<ServeReport, SimError>> {
    par_map_workers(cfgs, workers, |cfg| simulate_with_workers(engine, cfg, 1))
}

/// A capacity-planning question: for each arrival rate in `rps_points`,
/// the minimum fleet size (in accelerators, counted in whole shard
/// groups of `base.shards`) whose p99 latency meets `slo_p99_s`.
#[derive(Debug, Clone)]
pub struct CapacityPlanRequest {
    /// Template scenario: tenant mix, routing, batching, shards, horizon,
    /// seed, accelerator architecture. Its `accelerators` field and
    /// open-loop rate are overridden per probe; its traffic must be
    /// [`TrafficSpec::Open`] (a closed loop self-limits, so "offered
    /// rps" is not a free variable to plan against).
    pub base: ServeConfig,
    /// Offered arrival rates to plan for (requests/sec, each > 0).
    pub rps_points: Vec<f64>,
    /// The p99 latency SLO (seconds) a fleet must meet to qualify.
    pub slo_p99_s: f64,
    /// Fleet-size ceiling; rates that miss the SLO even at this size
    /// report `min_accelerators: None`.
    pub max_accelerators: usize,
    /// Sweep-executor threads for each probe round.
    pub workers: usize,
}

/// One rate point of a [`CapacityCurve`].
#[derive(Debug, Clone, PartialEq)]
pub struct CapacityPoint {
    pub rps: f64,
    /// Minimum qualifying fleet size, `None` when even
    /// `max_accelerators` misses the SLO.
    pub min_accelerators: Option<usize>,
    /// p99 at `min_accelerators` when met, at the ceiling otherwise.
    pub p99_s: f64,
    /// p99 one shard group below the minimum — the violation evidence
    /// (`None` when the minimum is a single group, so no smaller fleet
    /// exists).
    pub p99_below_s: Option<f64>,
    /// Simulations this point consumed (memoized probes not re-counted).
    pub probes: usize,
}

/// The capacity-vs-rps curve [`plan_capacity`] produces, plus the
/// engine-counter snapshots that witness the sweep's cache guarantee.
#[derive(Debug, Clone, PartialEq)]
pub struct CapacityCurve {
    pub slo_p99_s: f64,
    pub max_accelerators: usize,
    /// Chips per shard group — fleet candidates are its multiples.
    pub shards: usize,
    pub points: Vec<CapacityPoint>,
    /// Total simulations across all rounds and points.
    pub probes: usize,
    /// Probe rounds (round 1 is the ceiling screen; each later round is
    /// one batched bisection step).
    pub rounds: usize,
    /// `BatchEngine::plan_builds()` right after round 1 / at the end.
    /// Equal values are the "every build happens in round 1" guarantee.
    pub plan_builds_round1: usize,
    pub plan_builds_final: usize,
    /// Same snapshots for `BatchEngine::profile_builds()`.
    pub profile_builds_round1: usize,
    pub profile_builds_final: usize,
}

impl CapacityCurve {
    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("slo_p99_s".into(), Json::Num(self.slo_p99_s));
        o.insert("max_accelerators".into(), Json::Num(self.max_accelerators as f64));
        o.insert("shards".into(), Json::Num(self.shards as f64));
        o.insert("probes".into(), Json::Num(self.probes as f64));
        o.insert("rounds".into(), Json::Num(self.rounds as f64));
        o.insert("plan_builds_round1".into(), Json::Num(self.plan_builds_round1 as f64));
        o.insert("plan_builds_final".into(), Json::Num(self.plan_builds_final as f64));
        o.insert(
            "profile_builds_round1".into(),
            Json::Num(self.profile_builds_round1 as f64),
        );
        o.insert(
            "profile_builds_final".into(),
            Json::Num(self.profile_builds_final as f64),
        );
        let points = self
            .points
            .iter()
            .map(|p| {
                let mut po = BTreeMap::new();
                po.insert("rps".into(), Json::Num(p.rps));
                po.insert(
                    "min_accelerators".into(),
                    match p.min_accelerators {
                        Some(n) => Json::Num(n as f64),
                        None => Json::Null,
                    },
                );
                po.insert("slo_met".into(), Json::Bool(p.min_accelerators.is_some()));
                po.insert("p99_s".into(), Json::Num(p.p99_s));
                po.insert(
                    "p99_below_s".into(),
                    match p.p99_below_s {
                        Some(v) => Json::Num(v),
                        None => Json::Null,
                    },
                );
                po.insert("probes".into(), Json::Num(p.probes as f64));
                Json::Obj(po)
            })
            .collect();
        o.insert("points".into(), Json::Arr(points));
        Json::Obj(o)
    }
}

/// Bisection state of one rate point: the invariant is `ok(hi)` and
/// `!ok(lo - 1)` (vacuous at `lo == 1`), both in shard-group units.
struct PointState {
    rps: f64,
    lo: usize,
    hi: usize,
    /// `Some(None)` = infeasible at the ceiling; `Some(Some(g))` = min
    /// group count found.
    resolved: Option<Option<usize>>,
    /// Group count → measured p99 (every simulation this point ran).
    memo: BTreeMap<usize, f64>,
    probes: usize,
}

/// Bisects each rate point of `req` to the minimum fleet meeting the p99
/// SLO, batching every round's probes through [`sweep_with_workers`].
///
/// The whole plan is deterministic: probes inherit `req.base.seed`, and
/// the bisection path is a pure function of the (deterministic) probe
/// outcomes — so a curve is reproducible bit-for-bit from its request,
/// and `tests/sweep_capacity.rs` pins that the minimum fleet is
/// non-decreasing in the arrival rate.
pub fn plan_capacity(
    engine: &BatchEngine,
    req: &CapacityPlanRequest,
) -> Result<CapacityCurve, SimError> {
    let invalid = |msg: String| Err(SimError::InvalidConfig(msg));
    if req.rps_points.is_empty() {
        return invalid("capacity planning needs at least one rps point".into());
    }
    for &rps in &req.rps_points {
        if !rps.is_finite() || rps <= 0.0 {
            return invalid(format!("rps point {rps} must be finite and > 0"));
        }
    }
    if !req.slo_p99_s.is_finite() || req.slo_p99_s <= 0.0 {
        return invalid(format!("p99 SLO {} must be finite and > 0", req.slo_p99_s));
    }
    let process = match req.base.traffic {
        TrafficSpec::Open { process, .. } => process,
        TrafficSpec::Closed { .. } => {
            return invalid(
                "capacity planning requires open-loop traffic (a closed loop's offered \
                 rate follows fleet speed, so rps is not a free variable)"
                    .into(),
            )
        }
    };
    let shards = req.base.shards.max(1);
    let max_groups = req.max_accelerators / shards;
    if max_groups == 0 {
        return invalid(format!(
            "max_accelerators ({}) must fit at least one shard group of {}",
            req.max_accelerators, shards
        ));
    }
    // Validate the template once at the ceiling; per-probe validation
    // then only re-checks what the overrides could change.
    let probe_cfg = |rps: f64, groups: usize| {
        let mut cfg = req.base.clone();
        cfg.accelerators = groups * shards;
        cfg.traffic = TrafficSpec::Open { process, rps };
        cfg
    };
    probe_cfg(req.rps_points[0], max_groups).validate()?;

    let mut points: Vec<PointState> = req
        .rps_points
        .iter()
        .map(|&rps| PointState {
            rps,
            lo: 1,
            hi: max_groups,
            resolved: None,
            memo: BTreeMap::new(),
            probes: 0,
        })
        .collect();
    let slo = req.slo_p99_s;
    let mut probes_total = 0usize;
    let mut rounds = 0usize;
    let mut plan_builds_round1 = 0usize;
    let mut profile_builds_round1 = 0usize;

    // Run one batched probe round: `wanted[i]` is the group count point
    // `i` needs measured (deduped against each point's memo by the
    // caller). Returns the measured p99s in the same order.
    let run_round = |batch: &[(usize, usize)],
                     points: &mut [PointState]|
     -> Result<(), SimError> {
        let cfgs: Vec<ServeConfig> =
            batch.iter().map(|&(pi, g)| probe_cfg(points[pi].rps, g)).collect();
        let reports = sweep_with_workers(engine, &cfgs, req.workers);
        for (&(pi, g), report) in batch.iter().zip(reports) {
            let report = report?;
            points[pi].memo.insert(g, report.latency.p99_s);
            points[pi].probes += 1;
        }
        Ok(())
    };

    // Round 1: every point at the fleet ceiling. Infeasible points end
    // here; feasible ones enter bisection with the invariant holding.
    // This round touches every distinct tenant tuple, so it is the round
    // that pays every plan/profile build — snapshot the counters after
    // it and again at the end to witness flatness.
    let screen: Vec<(usize, usize)> = (0..points.len()).map(|pi| (pi, max_groups)).collect();
    run_round(&screen, &mut points)?;
    probes_total += screen.len();
    rounds += 1;
    for p in points.iter_mut() {
        if p.memo[&max_groups] > slo {
            p.resolved = Some(None);
        } else if max_groups == 1 {
            p.resolved = Some(Some(1));
        }
    }
    plan_builds_round1 += engine.plan_builds();
    profile_builds_round1 += engine.profile_builds();

    loop {
        // Advance each unresolved point to its next un-memoized probe
        // (memo hits replay instantly — distinct rps points share no
        // probes, but a point revisits its own history only when the
        // evidence pass below asks for an already-measured size).
        let mut batch: Vec<(usize, usize)> = Vec::new();
        for (pi, p) in points.iter_mut().enumerate() {
            if p.resolved.is_some() {
                continue;
            }
            loop {
                if p.lo == p.hi {
                    p.resolved = Some(Some(p.lo));
                    break;
                }
                let mid = (p.lo + p.hi) / 2;
                match p.memo.get(&mid) {
                    Some(&p99) => {
                        if p99 <= slo {
                            p.hi = mid;
                        } else {
                            p.lo = mid + 1;
                        }
                    }
                    None => {
                        batch.push((pi, mid));
                        break;
                    }
                }
            }
        }
        if batch.is_empty() {
            break;
        }
        run_round(&batch, &mut points)?;
        probes_total += batch.len();
        rounds += 1;
    }

    // Evidence pass: make sure every met point has its minimum-minus-one
    // probe on record (bisection leaves it memoized except when the
    // search never descended there).
    let mut evidence: Vec<(usize, usize)> = Vec::new();
    for (pi, p) in points.iter().enumerate() {
        if let Some(Some(g)) = p.resolved {
            if g > 1 && !p.memo.contains_key(&(g - 1)) {
                evidence.push((pi, g - 1));
            }
        }
    }
    if !evidence.is_empty() {
        run_round(&evidence, &mut points)?;
        probes_total += evidence.len();
        rounds += 1;
    }

    let out = points
        .iter()
        .map(|p| {
            let min_groups = p.resolved.expect("every point resolves");
            let (p99_s, p99_below_s) = match min_groups {
                Some(g) => (
                    p.memo[&g],
                    (g > 1).then(|| p.memo[&(g - 1)]),
                ),
                None => (p.memo[&max_groups], None),
            };
            CapacityPoint {
                rps: p.rps,
                min_accelerators: min_groups.map(|g| g * shards),
                p99_s,
                p99_below_s,
                probes: p.probes,
            }
        })
        .collect();
    Ok(CapacityCurve {
        slo_p99_s: slo,
        max_accelerators: req.max_accelerators,
        shards,
        points: out,
        probes: probes_total,
        rounds,
        plan_builds_round1,
        plan_builds_final: engine.plan_builds(),
        profile_builds_round1,
        profile_builds_final: engine.profile_builds(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gnn::models::ModelKind;
    use crate::serve::traffic::{ArrivalProcess, TenantMix, TenantProfile};

    fn base_cfg() -> ServeConfig {
        let mix =
            TenantMix::new(vec![TenantProfile::new(ModelKind::Gcn, "Cora", 1.0)]).unwrap();
        let mut cfg = ServeConfig::new(
            mix,
            TrafficSpec::Open { process: ArrivalProcess::Poisson, rps: 100.0 },
        );
        cfg.duration_s = 0.2;
        cfg
    }

    #[test]
    fn closed_loop_capacity_request_is_rejected() {
        let mut base = base_cfg();
        base.traffic = TrafficSpec::Closed { clients: 4, mean_think_s: 0.01 };
        let req = CapacityPlanRequest {
            base,
            rps_points: vec![100.0],
            slo_p99_s: 0.01,
            max_accelerators: 4,
            workers: 1,
        };
        assert!(matches!(
            plan_capacity(&BatchEngine::new(), &req),
            Err(SimError::InvalidConfig(_))
        ));
    }

    #[test]
    fn degenerate_capacity_requests_are_rejected() {
        let mk = |f: fn(&mut CapacityPlanRequest)| {
            let mut req = CapacityPlanRequest {
                base: base_cfg(),
                rps_points: vec![100.0],
                slo_p99_s: 0.01,
                max_accelerators: 4,
                workers: 1,
            };
            f(&mut req);
            req
        };
        let engine = BatchEngine::new();
        for req in [
            mk(|r| r.rps_points.clear()),
            mk(|r| r.rps_points = vec![0.0]),
            mk(|r| r.rps_points = vec![f64::NAN]),
            mk(|r| r.slo_p99_s = 0.0),
            mk(|r| r.max_accelerators = 0),
        ] {
            assert!(
                matches!(plan_capacity(&engine, &req), Err(SimError::InvalidConfig(_))),
                "request should have been rejected"
            );
        }
        // Ceiling smaller than one shard group.
        let mut req = mk(|_| {});
        req.base.accelerators = 4;
        req.base.shards = 4;
        req.max_accelerators = 2;
        assert!(matches!(plan_capacity(&engine, &req), Err(SimError::InvalidConfig(_))));
    }
}
