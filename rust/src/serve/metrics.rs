//! Serving metrics: exact latency percentiles, SLO attainment, per-tenant
//! and per-accelerator breakdowns, and queue/utilization time series —
//! serialized through [`crate::util::json`].
//!
//! The latency recorder keeps every sample (8 bytes each — a million
//! requests is 8 MB) and reduces them at summary time by progressive
//! quickselect (`select_nth_unstable`, O(n) total instead of an O(n log n)
//! sort), so the reported p50/p95/p99/p999 are *exact* nearest-rank
//! percentiles over the full run, not sketch approximations. The
//! percentile math is [`crate::util::bench::percentile_index`], shared
//! with the bench harness so "p99" means the same thing in both.

use std::collections::BTreeMap;

use crate::util::bench::percentile_index;
use crate::util::json::Json;

/// Collects individual request latencies.
#[derive(Debug, Clone, Default)]
pub struct LatencyRecorder {
    samples: Vec<f64>,
    sum: f64,
}

impl LatencyRecorder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, seconds: f64) {
        self.samples.push(seconds);
        self.sum += seconds;
    }

    pub fn count(&self) -> usize {
        self.samples.len()
    }

    /// Fraction of recorded latencies within `slo_s` (1.0 when nothing was
    /// recorded — an empty stream vacuously meets any SLO).
    pub fn attainment(&self, slo_s: f64) -> f64 {
        if self.samples.is_empty() {
            return 1.0;
        }
        let ok = self.samples.iter().filter(|&&s| s <= slo_s).count();
        ok as f64 / self.samples.len() as f64
    }

    /// Reduces a scratch copy of the samples to exact nearest-rank
    /// percentiles. Each quantile is an order statistic found by
    /// `select_nth_unstable` on a progressively narrowing tail (the ranks
    /// are non-decreasing in `q`, and each selection partitions everything
    /// below its rank to the left), so the whole summary is O(n) — the
    /// values are identical to sorting and indexing.
    pub fn summary(&self) -> LatencySummary {
        if self.samples.is_empty() {
            return LatencySummary::default();
        }
        let mut scratch = self.samples.clone();
        let n = scratch.len();
        let (mut min_s, mut max_s) = (scratch[0], scratch[0]);
        for &s in &scratch[1..] {
            if s.total_cmp(&min_s).is_lt() {
                min_s = s;
            }
            if s.total_cmp(&max_s).is_gt() {
                max_s = s;
            }
        }
        let ranks = [
            percentile_index(n, 0.50),
            percentile_index(n, 0.95),
            percentile_index(n, 0.99),
            percentile_index(n, 0.999),
        ];
        let mut picked = [0.0f64; 4];
        let mut floor = 0;
        for (slot, &rank) in ranks.iter().enumerate() {
            let (_, v, _) =
                scratch[floor..].select_nth_unstable_by(rank - floor, f64::total_cmp);
            picked[slot] = *v;
            floor = rank;
        }
        LatencySummary {
            count: n as u64,
            mean_s: self.sum / n as f64,
            min_s,
            max_s,
            p50_s: picked[0],
            p95_s: picked[1],
            p99_s: picked[2],
            p999_s: picked[3],
        }
    }
}

/// Exact latency distribution of one request population.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct LatencySummary {
    pub count: u64,
    pub mean_s: f64,
    pub min_s: f64,
    pub max_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub p99_s: f64,
    pub p999_s: f64,
}

impl LatencySummary {
    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("count".into(), Json::Num(self.count as f64));
        o.insert("mean_s".into(), Json::Num(self.mean_s));
        o.insert("min_s".into(), Json::Num(self.min_s));
        o.insert("max_s".into(), Json::Num(self.max_s));
        o.insert("p50_s".into(), Json::Num(self.p50_s));
        o.insert("p95_s".into(), Json::Num(self.p95_s));
        o.insert("p99_s".into(), Json::Num(self.p99_s));
        o.insert("p999_s".into(), Json::Num(self.p999_s));
        Json::Obj(o)
    }
}

/// A sampled `(time, value)` series (queue depth, busy fraction) with
/// bounded memory: pushes are decimated by a sampling `stride`, and when
/// the retained buffer reaches `cap` points it is halved (every other
/// point dropped, oldest-first parity so the first point survives) and the
/// stride doubles. The result holds at most `cap` points for any stream
/// length, deterministically — no RNG, so equal streams stay equal.
///
/// The default cap ([`TimeSeries::DEFAULT_CAP`]) is far above any normal
/// serve run's sample count, so short runs retain every point and their
/// JSON output is unchanged from the unbounded implementation.
#[derive(Debug, Clone, PartialEq)]
pub struct TimeSeries {
    pub points: Vec<(f64, f64)>,
    /// Retain ceiling; reaching it triggers a halve-and-double-stride step.
    cap: usize,
    /// Keep every `stride`-th observation (1 = keep all).
    stride: u64,
    /// Observations offered via [`TimeSeries::push`], including dropped.
    seen: u64,
}

impl Default for TimeSeries {
    fn default() -> Self {
        Self::with_cap(Self::DEFAULT_CAP)
    }
}

impl TimeSeries {
    /// Default retain ceiling: 64k points (1 MB of `(f64, f64)`), far
    /// above the sample counts any current caller produces.
    pub const DEFAULT_CAP: usize = 65_536;

    /// An empty series that will never retain more than `cap` points
    /// (minimum 2, so decimation always makes progress).
    pub fn with_cap(cap: usize) -> Self {
        TimeSeries { points: Vec::new(), cap: cap.max(2), stride: 1, seen: 0 }
    }

    /// Observations offered over the series' lifetime (retained or not).
    pub fn seen(&self) -> u64 {
        self.seen
    }

    /// Current sampling stride (doubles on every decimation).
    pub fn stride(&self) -> u64 {
        self.stride
    }

    /// Retain ceiling of this series.
    pub fn cap(&self) -> usize {
        self.cap
    }

    pub fn push(&mut self, t_s: f64, value: f64) {
        let keep = self.seen % self.stride == 0;
        self.seen += 1;
        if !keep {
            return;
        }
        self.points.push((t_s, value));
        if self.points.len() >= self.cap {
            let mut i = 0;
            self.points.retain(|_| {
                let keep = i % 2 == 0;
                i += 1;
                keep
            });
            self.stride *= 2;
        }
    }

    pub fn max(&self) -> f64 {
        self.points.iter().map(|&(_, v)| v).fold(0.0, f64::max)
    }

    pub fn mean(&self) -> f64 {
        if self.points.is_empty() {
            return 0.0;
        }
        self.points.iter().map(|&(_, v)| v).sum::<f64>() / self.points.len() as f64
    }

    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.points
                .iter()
                .map(|&(t, v)| Json::Arr(vec![Json::Num(t), Json::Num(v)]))
                .collect(),
        )
    }
}

/// Per-tenant serving outcome.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantStats {
    /// `model/dataset` tag.
    pub label: String,
    pub offered: u64,
    pub completed: u64,
    pub latency: LatencySummary,
    /// Fraction of this tenant's requests within the SLO (when one is set).
    pub slo_attainment: Option<f64>,
}

/// Per-accelerator serving outcome.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AccelStats {
    /// Busy time divided by the fleet makespan; in `[0, 1]` by
    /// construction (an accelerator serves one batch at a time).
    pub utilization: f64,
    pub busy_s: f64,
    pub completed: u64,
    pub batches: u64,
    /// Weight-programming events: batches whose tenant differed from the
    /// previously programmed one.
    pub weight_programs: u64,
}

impl AccelStats {
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            return 0.0;
        }
        self.completed as f64 / self.batches as f64
    }
}

/// Outcome of the graph-mutation stream of a serving-under-churn run
/// (present on the report exactly when [`super::ServeConfig::churn`] was
/// set).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct ChurnStats {
    /// Mutation events fired (each applies one edge-operation batch).
    pub events: u64,
    pub edges_added: u64,
    pub edges_removed: u64,
    pub vertices_added: u64,
    /// Full plan reconstructions across all tenant delta plans (first
    /// targeting, group-count changes, spill flips, sharded tenants).
    pub rebuilds: u64,
    /// Incremental plan patches: only mutation-touched groups re-costed.
    pub patches: u64,
    /// Service-profile refreshes pushed into the fleet (one per tenant
    /// sharing the mutated dataset, per event).
    pub reprofiles: u64,
    /// Engine cache entries dropped because their graph epoch was
    /// superseded ([`crate::coordinator::BatchEngine::evict_dataset_epochs_below`]).
    pub evictions: u64,
    /// Total applied graph epochs across churned datasets, sampled on the
    /// metric ticks — monotone nondecreasing by construction.
    pub epochs: TimeSeries,
}

impl ChurnStats {
    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("events".into(), Json::Num(self.events as f64));
        o.insert("edges_added".into(), Json::Num(self.edges_added as f64));
        o.insert("edges_removed".into(), Json::Num(self.edges_removed as f64));
        o.insert("vertices_added".into(), Json::Num(self.vertices_added as f64));
        o.insert("rebuilds".into(), Json::Num(self.rebuilds as f64));
        o.insert("patches".into(), Json::Num(self.patches as f64));
        o.insert("reprofiles".into(), Json::Num(self.reprofiles as f64));
        o.insert("evictions".into(), Json::Num(self.evictions as f64));
        o.insert("epochs".into(), self.epochs.to_json());
        Json::Obj(o)
    }
}

/// Full result of one serving simulation.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeReport {
    /// Configured traffic horizon, seconds: arrivals stop here.
    pub duration_s: f64,
    /// Horizon through the last completion (equals `duration_s` when the
    /// fleet drains in time; larger when it was overloaded).
    pub makespan_s: f64,
    pub offered: u64,
    pub completed: u64,
    /// `completed / makespan_s`.
    pub throughput_rps: f64,
    pub latency: LatencySummary,
    /// Overall SLO attainment (when an SLO is set).
    pub slo_attainment: Option<f64>,
    /// Photonic inference energy of all completed requests, joules.
    pub energy_j: f64,
    pub tenants: Vec<TenantStats>,
    pub accels: Vec<AccelStats>,
    /// Waiting (not yet dispatched) requests across the fleet, sampled at
    /// fixed intervals over `duration_s`.
    pub queue_depth: TimeSeries,
    /// Fraction of accelerators busy at each sample instant.
    pub busy_frac: TimeSeries,
    /// Graph-mutation outcome; `Some` exactly when the run served under
    /// churn ([`super::ServeConfig::churn`]).
    pub churn: Option<ChurnStats>,
}

impl ServeReport {
    /// Mean utilization across the fleet.
    pub fn fleet_utilization(&self) -> f64 {
        if self.accels.is_empty() {
            return 0.0;
        }
        self.accels.iter().map(|a| a.utilization).sum::<f64>() / self.accels.len() as f64
    }

    pub fn total_weight_programs(&self) -> u64 {
        self.accels.iter().map(|a| a.weight_programs).sum()
    }

    pub fn total_batches(&self) -> u64 {
        self.accels.iter().map(|a| a.batches).sum()
    }

    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("duration_s".into(), Json::Num(self.duration_s));
        o.insert("makespan_s".into(), Json::Num(self.makespan_s));
        o.insert("offered".into(), Json::Num(self.offered as f64));
        o.insert("completed".into(), Json::Num(self.completed as f64));
        o.insert("throughput_rps".into(), Json::Num(self.throughput_rps));
        o.insert("latency".into(), self.latency.to_json());
        if let Some(a) = self.slo_attainment {
            o.insert("slo_attainment".into(), Json::Num(a));
        }
        o.insert("energy_j".into(), Json::Num(self.energy_j));
        o.insert("fleet_utilization".into(), Json::Num(self.fleet_utilization()));
        o.insert(
            "tenants".into(),
            Json::Arr(
                self.tenants
                    .iter()
                    .map(|t| {
                        let mut to = BTreeMap::new();
                        to.insert("tenant".into(), Json::Str(t.label.clone()));
                        to.insert("offered".into(), Json::Num(t.offered as f64));
                        to.insert("completed".into(), Json::Num(t.completed as f64));
                        to.insert("latency".into(), t.latency.to_json());
                        if let Some(a) = t.slo_attainment {
                            to.insert("slo_attainment".into(), Json::Num(a));
                        }
                        Json::Obj(to)
                    })
                    .collect(),
            ),
        );
        o.insert(
            "accelerators".into(),
            Json::Arr(
                self.accels
                    .iter()
                    .map(|a| {
                        let mut ao = BTreeMap::new();
                        ao.insert("utilization".into(), Json::Num(a.utilization));
                        ao.insert("busy_s".into(), Json::Num(a.busy_s));
                        ao.insert("completed".into(), Json::Num(a.completed as f64));
                        ao.insert("batches".into(), Json::Num(a.batches as f64));
                        ao.insert("mean_batch".into(), Json::Num(a.mean_batch()));
                        ao.insert(
                            "weight_programs".into(),
                            Json::Num(a.weight_programs as f64),
                        );
                        Json::Obj(ao)
                    })
                    .collect(),
            ),
        );
        o.insert("queue_depth".into(), self.queue_depth.to_json());
        o.insert("busy_frac".into(), self.busy_frac.to_json());
        if let Some(c) = &self.churn {
            o.insert("churn".into(), c.to_json());
        }
        Json::Obj(o)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recorder_percentiles_exact_on_known_distribution() {
        let mut r = LatencyRecorder::new();
        // 1..=1000 ms, shuffled order must not matter.
        for i in (1..=1000u32).rev() {
            r.record(i as f64 * 1e-3);
        }
        let s = r.summary();
        assert_eq!(s.count, 1000);
        assert_eq!(s.min_s, 1e-3);
        assert_eq!(s.max_s, 1.0);
        assert!((s.p50_s - 0.5).abs() < 1e-12, "p50 {}", s.p50_s);
        assert!((s.p95_s - 0.95).abs() < 1e-12, "p95 {}", s.p95_s);
        assert!((s.p99_s - 0.99).abs() < 1e-12, "p99 {}", s.p99_s);
        assert!((s.p999_s - 0.999).abs() < 1e-12, "p999 {}", s.p999_s);
        assert!((s.mean_s - 0.5005).abs() < 1e-9);
        assert!((r.attainment(0.5) - 0.5).abs() < 1e-12);
        assert_eq!(r.attainment(2.0), 1.0);
    }

    #[test]
    fn empty_recorder_is_well_defined() {
        let r = LatencyRecorder::new();
        let s = r.summary();
        assert_eq!(s.count, 0);
        assert_eq!(s.p99_s, 0.0);
        assert_eq!(r.attainment(1.0), 1.0);
    }

    #[test]
    fn summary_percentiles_are_monotone() {
        let mut r = LatencyRecorder::new();
        let mut x = 1.0f64;
        for _ in 0..500 {
            x = (x * 1.13) % 7.3; // deterministic scatter
            r.record(x);
        }
        let s = r.summary();
        assert!(s.min_s <= s.p50_s);
        assert!(s.p50_s <= s.p95_s);
        assert!(s.p95_s <= s.p99_s);
        assert!(s.p99_s <= s.p999_s);
        assert!(s.p999_s <= s.max_s);
    }

    #[test]
    fn time_series_short_runs_retain_every_point() {
        let mut ts = TimeSeries::default();
        for k in 0..1000 {
            ts.push(k as f64, (k * 2) as f64);
        }
        assert_eq!(ts.points.len(), 1000);
        assert_eq!(ts.stride(), 1);
        assert_eq!(ts.seen(), 1000);
        assert_eq!(ts.points[7], (7.0, 14.0));
    }

    #[test]
    fn time_series_memory_bounded_on_one_million_events() {
        let mut ts = TimeSeries::with_cap(1024);
        for k in 0..1_000_000u64 {
            ts.push(k as f64 * 1e-3, k as f64);
        }
        assert!(
            ts.points.len() <= 1024,
            "cap violated: {} points retained",
            ts.points.len()
        );
        assert!(ts.points.len() >= 512, "decimation overshot: {}", ts.points.len());
        assert_eq!(ts.seen(), 1_000_000);
        // Retained points are exactly the stride-multiples of the
        // observation index, so the series stays a uniform subsample.
        let stride = ts.stride();
        assert!(stride.is_power_of_two() && stride > 1);
        for (i, &(_, v)) in ts.points.iter().enumerate() {
            assert_eq!(v, (i as u64 * stride) as f64);
        }
        // First observation always survives; max/mean stay well-defined.
        assert_eq!(ts.points[0], (0.0, 0.0));
        assert!(ts.max() <= 1e6);
        // The default cap also bounds a 1M-event run.
        let mut def = TimeSeries::default();
        for k in 0..1_000_000u64 {
            def.push(k as f64, k as f64);
        }
        assert!(def.points.len() <= TimeSeries::DEFAULT_CAP);
    }

    #[test]
    fn report_json_round_trips() {
        let mut rec = LatencyRecorder::new();
        rec.record(1e-3);
        rec.record(2e-3);
        let report = ServeReport {
            duration_s: 1.0,
            makespan_s: 1.5,
            offered: 2,
            completed: 2,
            throughput_rps: 2.0 / 1.5,
            latency: rec.summary(),
            slo_attainment: Some(1.0),
            energy_j: 3e-6,
            tenants: vec![TenantStats {
                label: "GCN/Cora".into(),
                offered: 2,
                completed: 2,
                latency: rec.summary(),
                slo_attainment: Some(1.0),
            }],
            accels: vec![AccelStats {
                utilization: 0.5,
                busy_s: 0.75,
                completed: 2,
                batches: 2,
                weight_programs: 1,
            }],
            queue_depth: TimeSeries {
                points: vec![(0.5, 1.0), (1.0, 0.0)],
                ..TimeSeries::default()
            },
            busy_frac: TimeSeries {
                points: vec![(0.5, 1.0), (1.0, 0.0)],
                ..TimeSeries::default()
            },
            churn: None,
        };
        let text = report.to_json().to_string();
        let parsed = Json::parse(&text).expect("report JSON parses");
        assert!(parsed.get("churn").is_none(), "no churn block without churn");
        let mut churned = report.clone();
        churned.churn = Some(ChurnStats {
            events: 3,
            edges_added: 20,
            edges_removed: 4,
            patches: 3,
            epochs: TimeSeries { points: vec![(0.5, 2.0), (1.0, 3.0)], ..TimeSeries::default() },
            ..ChurnStats::default()
        });
        let parsed_churn = Json::parse(&churned.to_json().to_string()).unwrap();
        assert_eq!(
            parsed_churn
                .get("churn")
                .and_then(|c| c.get("patches"))
                .and_then(Json::as_u64),
            Some(3)
        );
        assert_eq!(parsed.get("offered").and_then(Json::as_u64), Some(2));
        assert_eq!(
            parsed
                .get("latency")
                .and_then(|l| l.get("count"))
                .and_then(Json::as_u64),
            Some(2)
        );
        assert_eq!(
            parsed.get("tenants").and_then(Json::as_array).map(|a| a.len()),
            Some(1)
        );
        assert!((report.fleet_utilization() - 0.5).abs() < 1e-12);
        assert_eq!(report.total_weight_programs(), 1);
        assert_eq!(report.accels[0].mean_batch(), 1.0);
    }
}
