//! The retained baseline event loop: the fleet scheduler exactly as it
//! stood before the serve fast path landed, kept as an in-crate oracle.
//!
//! [`super::fleet`] now runs a restructured loop — side-channel arrival /
//! sample / churn sources instead of heap residency, pooled batch
//! buffers, active-tenant index tables, and locally tallied telemetry.
//! Those are pure mechanical optimizations: for any non-churn
//! [`ServeConfig`] the fast loop must produce a [`ServeReport`]
//! **bit-identical** to this module's, and `benches/serve_scale.rs`
//! measures its events/sec against this baseline (the ≥2× floor). The
//! equivalence is pinned by `tests/sweep_capacity.rs`; keep this file
//! frozen unless the simulation *semantics* deliberately change, in which
//! case both loops move together.
//!
//! The implementation notes below are the original ones. One binary heap
//! orders all six event kinds by `(time, sequence)`; every arrival,
//! sampling tick, and wake-up is a heap push and pop; each dispatched
//! batch allocates its own request buffer; per-event telemetry counters
//! are bumped through process-wide atomics. Churn mode is not replicated
//! here — it exercises engine machinery, not the loop shape, and the
//! churn determinism tests pin the live loop against itself.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};

use crate::coordinator::{ServiceProfile, SimError};
use crate::util::rng::{mix_seed, Pcg64};
use crate::util::telemetry;

use super::fleet::RoutePolicy;
use super::metrics::{
    AccelStats, LatencyRecorder, ServeReport, TenantStats, TimeSeries,
};
use super::traffic::{exp_sample, OpenLoopArrivals, TenantMix, TrafficSpec};
use super::ServeConfig;

#[derive(Debug, Clone, Copy)]
enum EventKind {
    /// An open-loop request lands (tenant pre-sampled at schedule time).
    Arrival { tenant: usize },
    /// A closed-loop client issues its next request.
    ClientArrival { client: u32 },
    /// The in-flight batch on `accel` finishes.
    BatchDone { accel: usize },
    /// A batching deadline passed on `accel`; re-evaluate dispatch.
    Wake { accel: usize },
    /// Metrics sampling tick.
    Sample,
}

#[derive(Debug, Clone, Copy)]
struct Event {
    time: f64,
    seq: u64,
    kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}

impl Eq for Event {}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.time.total_cmp(&other.time).then_with(|| self.seq.cmp(&other.seq))
    }
}

#[derive(Debug, Clone, Copy)]
struct Request {
    tenant: usize,
    arrival_s: f64,
    /// Closed-loop client that issued this request, if any.
    client: Option<u32>,
}

struct Accel {
    /// Per-tenant FIFO queues of waiting requests.
    queues: Vec<VecDeque<Request>>,
    /// Total waiting requests across all tenant queues.
    queued: usize,
    busy: bool,
    /// Requests of the in-flight batch (empty when idle).
    current: Vec<Request>,
    /// Tenant whose weights are on the MR banks (None before first batch).
    programmed: Option<usize>,
    /// Earliest pending Wake event for this accelerator (infinity when
    /// none) — dedupes wake-ups so queue growth toward a fixed batching
    /// deadline does not re-push the same event.
    next_wake_s: f64,
    /// Dataset ids whose partition sets this accelerator holds.
    resident: Vec<bool>,
    busy_s: f64,
    completed: u64,
    batches: u64,
    weight_programs: u64,
}

impl Accel {
    fn new(n_tenants: usize, n_datasets: usize) -> Self {
        Self {
            queues: (0..n_tenants).map(|_| VecDeque::new()).collect(),
            queued: 0,
            busy: false,
            current: Vec::new(),
            programmed: None,
            next_wake_s: f64::INFINITY,
            resident: vec![false; n_datasets],
            busy_s: 0.0,
            completed: 0,
            batches: 0,
            weight_programs: 0,
        }
    }

    /// Waiting + in-flight requests: the JSQ load signal.
    fn depth(&self) -> usize {
        self.queued + self.current.len()
    }
}

/// Dense dataset ids over the tenant mix, as the original loop computed
/// them (tenants sharing a dataset share an id and therefore residency).
fn dense_dataset_ids(mix: &TenantMix) -> (Vec<String>, Vec<usize>) {
    let mut names: Vec<String> = Vec::new();
    let mut tenant_dataset = Vec::with_capacity(mix.len());
    for t in mix.tenants() {
        let id = match names.iter().position(|d| d == &t.dataset) {
            Some(i) => i,
            None => {
                names.push(t.dataset.clone());
                names.len() - 1
            }
        };
        tenant_dataset.push(id);
    }
    (names, tenant_dataset)
}

struct RefSim<'a> {
    cfg: &'a ServeConfig,
    profiles: Vec<ServiceProfile>,
    tenant_dataset: Vec<usize>,
    accels: Vec<Accel>,
    heap: BinaryHeap<Reverse<Event>>,
    seq: u64,
    rr_next: usize,
    tenant_rng: Pcg64,
    think_rng: Pcg64,
    latency: LatencyRecorder,
    tenant_latency: Vec<LatencyRecorder>,
    tenant_offered: Vec<u64>,
    tenant_completed: Vec<u64>,
    offered: u64,
    completed: u64,
    energy_j: f64,
    queue_depth: TimeSeries,
    busy_frac: TimeSeries,
    last_completion_s: f64,
}

impl<'a> RefSim<'a> {
    fn push(&mut self, time: f64, kind: EventKind) {
        self.seq += 1;
        self.heap.push(Reverse(Event { time, seq: self.seq, kind }));
    }

    fn route(&mut self, tenant: usize) -> usize {
        let n = self.accels.len();
        match self.cfg.route {
            RoutePolicy::RoundRobin => {
                let i = self.rr_next % n;
                self.rr_next = self.rr_next.wrapping_add(1);
                i
            }
            RoutePolicy::JoinShortestQueue => self.shortest_queue(|_| true),
            RoutePolicy::GraphAffinity => {
                let ds = self.tenant_dataset[tenant];
                let any_resident = self.accels.iter().any(|a| a.resident[ds]);
                if any_resident {
                    self.shortest_queue(|a| a.resident[ds])
                } else {
                    self.shortest_queue(|_| true)
                }
            }
        }
    }

    /// Lowest-index accelerator with minimum depth among those `keep`
    /// admits (callers guarantee at least one does).
    fn shortest_queue<F: Fn(&Accel) -> bool>(&self, keep: F) -> usize {
        let mut best = usize::MAX;
        let mut best_depth = usize::MAX;
        for (i, a) in self.accels.iter().enumerate() {
            if keep(a) && a.depth() < best_depth {
                best = i;
                best_depth = a.depth();
            }
        }
        debug_assert!(best != usize::MAX, "router filter admitted no accelerator");
        best
    }

    fn enqueue(&mut self, tenant: usize, arrival_s: f64, client: Option<u32>) {
        self.offered += 1;
        self.tenant_offered[tenant] += 1;
        let idx = self.route(tenant);
        let a = &mut self.accels[idx];
        a.queues[tenant].push_back(Request { tenant, arrival_s, client });
        a.queued += 1;
        self.try_dispatch(idx, arrival_s);
    }

    /// If `idx` is idle and some tenant queue is dispatchable now, launch
    /// the FIFO-oldest ready batch; otherwise schedule a wake-up at the
    /// earliest batching deadline.
    fn try_dispatch(&mut self, idx: usize, now: f64) {
        if self.accels[idx].busy || self.accels[idx].queued == 0 {
            return;
        }
        let policy = self.cfg.batch;
        // Decide with a shared borrow, mutate after.
        let mut ready: Option<(f64, usize)> = None; // (oldest arrival, tenant)
        let mut next_deadline = f64::INFINITY;
        for (tn, q) in self.accels[idx].queues.iter().enumerate() {
            let Some(front) = q.front() else { continue };
            let at = policy.ready_at(front.arrival_s, q.len(), &self.profiles[tn]);
            if at <= now {
                let cand = (front.arrival_s, tn);
                let better = match ready {
                    None => true,
                    Some(best) => cand.0 < best.0 || (cand.0 == best.0 && cand.1 < best.1),
                };
                if better {
                    ready = Some(cand);
                }
            } else if at < next_deadline {
                next_deadline = at;
            }
        }
        let Some((_, tenant)) = ready else {
            // One pending wake per accelerator is enough: re-push only when
            // the new deadline beats the earliest already scheduled (stale
            // later wakes fire as harmless re-evaluations).
            if next_deadline.is_finite() && next_deadline < self.accels[idx].next_wake_s {
                self.accels[idx].next_wake_s = next_deadline;
                self.push(next_deadline, EventKind::Wake { accel: idx });
            }
            return;
        };
        let ds = self.tenant_dataset[tenant];
        let profile = self.profiles[tenant];
        let a = &mut self.accels[idx];
        let take = policy.max_batch().min(a.queues[tenant].len());
        let mut batch = Vec::with_capacity(take);
        for _ in 0..take {
            if let Some(r) = a.queues[tenant].pop_front() {
                batch.push(r);
            }
        }
        a.queued -= take;
        super::fleet::batch_size_hist().record(take as f64);
        let programmed = a.programmed == Some(tenant);
        if !programmed {
            a.weight_programs += 1;
        }
        let service_s = profile.batch_service_s(take, programmed);
        a.programmed = Some(tenant);
        a.resident[ds] = true;
        a.busy = true;
        a.current = batch;
        a.busy_s += service_s;
        a.batches += 1;
        // Energy is decided at launch (the batch either paid the staging
        // share or not); the fleet drains, so launch-time accounting equals
        // completion-time totals.
        let batch_energy = profile.batch_energy_j(take, programmed);
        self.energy_j += batch_energy;
        self.push(now + service_s, EventKind::BatchDone { accel: idx });
    }

    fn complete_batch(&mut self, idx: usize, now: f64) {
        let batch = std::mem::take(&mut self.accels[idx].current);
        self.accels[idx].busy = false;
        self.accels[idx].completed += batch.len() as u64;
        self.last_completion_s = now;
        let mean_think_s = match self.cfg.traffic {
            TrafficSpec::Closed { mean_think_s, .. } => mean_think_s,
            TrafficSpec::Open { .. } => 0.0,
        };
        for req in batch {
            let lat = now - req.arrival_s;
            self.latency.record(lat);
            self.tenant_latency[req.tenant].record(lat);
            self.tenant_completed[req.tenant] += 1;
            self.completed += 1;
            if let Some(client) = req.client {
                let gap = if mean_think_s > 0.0 {
                    exp_sample(&mut self.think_rng, 1.0 / mean_think_s)
                } else {
                    0.0
                };
                let next = now + gap;
                if next <= self.cfg.duration_s {
                    self.push(next, EventKind::ClientArrival { client });
                }
            }
        }
        self.try_dispatch(idx, now);
    }

    fn sample_metrics(&mut self, now: f64) {
        let waiting: usize = self.accels.iter().map(|a| a.queued).sum();
        let busy = self.accels.iter().filter(|a| a.busy).count();
        self.queue_depth.push(now, waiting as f64);
        self.busy_frac.push(now, busy as f64 / self.accels.len() as f64);
    }
}

/// Runs the original (pre-fast-path) serving event loop against
/// pre-resolved tenant service profiles. Same contract as
/// [`super::simulate_with_profiles`]: churn configurations are rejected,
/// arrivals stop at the horizon, the fleet drains.
pub fn simulate_fleet_reference(
    cfg: &ServeConfig,
    profiles: &[ServiceProfile],
) -> Result<ServeReport, SimError> {
    cfg.validate()?;
    if cfg.churn.is_some() {
        return Err(SimError::InvalidConfig(
            "the reference event loop does not serve under churn; use serve::simulate"
                .into(),
        ));
    }
    if profiles.len() != cfg.mix.len() {
        return Err(SimError::InvalidConfig(format!(
            "{} service profiles supplied for {} tenants",
            profiles.len(),
            cfg.mix.len()
        )));
    }
    for (i, p) in profiles.iter().enumerate() {
        let finite = p.latency_s.is_finite()
            && p.weight_stage_s.is_finite()
            && p.energy_j.is_finite()
            && p.weight_stage_energy_j.is_finite();
        if !finite
            || p.weight_stage_s < 0.0
            || p.energy_j < 0.0
            || p.weight_stage_energy_j < 0.0
            || p.per_request_s() <= 0.0
        {
            return Err(SimError::InvalidConfig(format!(
                "service profile for tenant {} ({}) is degenerate \
                 (needs finite fields and per-request time > 0): {p:?}",
                i,
                cfg.mix.tenants()[i].label()
            )));
        }
    }
    let n_tenants = cfg.mix.len();
    let slots = cfg.shard_groups();
    let (dataset_names, tenant_dataset) = dense_dataset_ids(&cfg.mix);
    let n_datasets = dataset_names.len();

    let mut sim = RefSim {
        cfg,
        profiles: profiles.to_vec(),
        tenant_dataset,
        accels: (0..slots).map(|_| Accel::new(n_tenants, n_datasets)).collect(),
        heap: BinaryHeap::new(),
        seq: 0,
        rr_next: 0,
        tenant_rng: Pcg64::seed_from_u64(mix_seed(cfg.seed, 1)),
        think_rng: Pcg64::seed_from_u64(mix_seed(cfg.seed, 2)),
        latency: LatencyRecorder::new(),
        tenant_latency: (0..n_tenants).map(|_| LatencyRecorder::new()).collect(),
        tenant_offered: vec![0; n_tenants],
        tenant_completed: vec![0; n_tenants],
        offered: 0,
        completed: 0,
        energy_j: 0.0,
        queue_depth: TimeSeries::default(),
        busy_frac: TimeSeries::default(),
        last_completion_s: 0.0,
    };

    // Seed the event heap: traffic source plus sampling ticks — every
    // sampling tick lives in the heap from the start, as it originally did.
    let mut arrivals = match cfg.traffic {
        TrafficSpec::Open { process, rps } => {
            let mut src = OpenLoopArrivals::new(process, rps, mix_seed(cfg.seed, 0))
                .map_err(SimError::InvalidConfig)?;
            let t0 = src.next_arrival();
            if t0 <= cfg.duration_s {
                let tenant = sim.cfg.mix.sample(&mut sim.tenant_rng);
                sim.push(t0, EventKind::Arrival { tenant });
            }
            Some(src)
        }
        TrafficSpec::Closed { clients, mean_think_s } => {
            for client in 0..clients as u32 {
                let gap = if mean_think_s > 0.0 {
                    exp_sample(&mut sim.think_rng, 1.0 / mean_think_s)
                } else {
                    0.0
                };
                if gap <= cfg.duration_s {
                    sim.push(gap, EventKind::ClientArrival { client });
                }
            }
            None
        }
    };
    let sample_dt = cfg.duration_s / cfg.samples as f64;
    for k in 1..=cfg.samples {
        sim.push(k as f64 * sample_dt, EventKind::Sample);
    }

    // The event loop. Arrivals stop at the horizon; the heap then drains.
    // Per-event telemetry goes straight to the process-wide atomics — the
    // baseline cost profile the fast path is measured against.
    let _loop_span = telemetry::span("serve.event_loop.reference");
    let registry = telemetry::registry();
    let ev_arrival = registry.counter("serve.reference.events.arrival");
    let ev_batch_done = registry.counter("serve.reference.events.batch_done");
    let ev_wake = registry.counter("serve.reference.events.wake");
    let ev_sample = registry.counter("serve.reference.events.sample");
    let queue_gauge = registry.gauge("serve.reference.queue_depth");
    while let Some(Reverse(ev)) = sim.heap.pop() {
        let now = ev.time;
        match ev.kind {
            EventKind::Arrival { tenant } => {
                ev_arrival.inc();
                sim.enqueue(tenant, now, None);
                if let Some(src) = arrivals.as_mut() {
                    let t = src.next_arrival();
                    if t <= cfg.duration_s {
                        let next_tenant = sim.cfg.mix.sample(&mut sim.tenant_rng);
                        sim.push(t, EventKind::Arrival { tenant: next_tenant });
                    }
                }
            }
            EventKind::ClientArrival { client } => {
                ev_arrival.inc();
                let tenant = sim.cfg.mix.sample(&mut sim.tenant_rng);
                sim.enqueue(tenant, now, Some(client));
            }
            EventKind::BatchDone { accel } => {
                ev_batch_done.inc();
                sim.complete_batch(accel, now);
            }
            EventKind::Wake { accel } => {
                ev_wake.inc();
                // This wake (or an earlier stale one) has fired; allow the
                // next deadline to schedule a fresh one.
                if sim.accels[accel].next_wake_s <= now {
                    sim.accels[accel].next_wake_s = f64::INFINITY;
                }
                sim.try_dispatch(accel, now);
            }
            EventKind::Sample => {
                ev_sample.inc();
                sim.sample_metrics(now);
                queue_gauge.set(sim.accels.iter().map(|a| a.queued).sum::<usize>() as f64);
            }
        }
    }

    debug_assert_eq!(sim.offered, sim.completed, "fleet must drain every request");
    let makespan_s = cfg.duration_s.max(sim.last_completion_s);
    let tenants = cfg
        .mix
        .tenants()
        .iter()
        .enumerate()
        .map(|(i, t)| TenantStats {
            label: t.label(),
            offered: sim.tenant_offered[i],
            completed: sim.tenant_completed[i],
            latency: sim.tenant_latency[i].summary(),
            slo_attainment: cfg.slo_s.map(|slo| sim.tenant_latency[i].attainment(slo)),
        })
        .collect();
    let mut accels = Vec::with_capacity(slots * cfg.shards);
    for a in &sim.accels {
        let stats = AccelStats {
            utilization: a.busy_s / makespan_s,
            busy_s: a.busy_s,
            completed: a.completed,
            batches: a.batches,
            weight_programs: a.weight_programs,
        };
        for _ in 0..cfg.shards {
            accels.push(stats);
        }
    }
    Ok(ServeReport {
        duration_s: cfg.duration_s,
        makespan_s,
        offered: sim.offered,
        completed: sim.completed,
        throughput_rps: if makespan_s > 0.0 { sim.completed as f64 / makespan_s } else { 0.0 },
        latency: sim.latency.summary(),
        slo_attainment: cfg.slo_s.map(|slo| sim.latency.attainment(slo)),
        energy_j: sim.energy_j,
        tenants,
        accels,
        queue_depth: sim.queue_depth,
        busy_frac: sim.busy_frac,
        churn: None,
    })
}
