//! The fleet scheduler: a deterministic discrete-event simulation of N
//! GHOST accelerators serving a request stream.
//!
//! ## Event model
//!
//! Events are totally ordered by `(time, sequence)`; the sequence number
//! breaks time ties in insertion order, so the trace — and every metric
//! derived from it — is bit-identical for a given [`super::ServeConfig`]
//! on every run, platform, and host thread count (the event loop itself
//! is single-threaded; the only parallelism in serving is the engine-side
//! service-profile resolution, which is worker-count-invariant by the
//! engine's own guarantees).
//!
//! The loop keeps its three *predictable* event sources out of the binary
//! heap: the next open-loop arrival, the next metrics sampling tick, and
//! the next churn event are each a pending `(time, seq)` scalar, and every
//! iteration picks the earliest of those three and the heap top. Only the
//! genuinely dynamic events — batch completions, batcher wake-ups, and
//! closed-loop client arrivals — pay heap traffic, which shrinks the heap
//! from `O(samples + pending)` to a handful of in-flight entries and
//! removes two heap operations from the per-arrival path. Sequence
//! numbers are allocated for side-channel events exactly where the
//! all-heap loop would have pushed them, so the merged order is identical
//! to a single heap's — [`super::reference`] retains that original
//! all-heap loop, and `tests/sweep_capacity.rs` pins the two bit-equal.
//!
//! Five event kinds drive the loop: open-loop arrivals (each schedules
//! its successor from the lazy generator), closed-loop client arrivals
//! (rescheduled think-time after each response), batch completions,
//! batcher wake-ups (deadline re-evaluation), metric sampling ticks, and
//! — in serving-under-churn mode — graph-mutation events that splice the
//! tenant's dataset in place and refresh its service profile through
//! incremental plan maintenance ([`crate::coordinator::GraphDeltaPlan`]).
//!
//! ## Fast-path bookkeeping
//!
//! The per-event costs the original loop paid are hoisted or pooled
//! (`benches/serve_scale.rs` pins the ≥2× events/sec floor against the
//! retained baseline):
//!
//! * batch buffers round-trip through a per-accelerator spare `Vec`
//!   instead of allocating per dispatch;
//! * each accelerator keeps an index table of tenants with waiting
//!   requests, so dispatch scans only non-empty queues (selection is a
//!   pure min, so scan order cannot affect the result);
//! * telemetry event counters and the batch-size histogram are tallied in
//!   locals and flushed to the process-wide atomics once per run;
//! * requests are 16 bytes (`f64` arrival + dense `u32` tenant/client).
//!
//! ## Accelerator model
//!
//! Each accelerator serves one batch at a time from per-tenant FIFO
//! queues. A batch of `n` same-tenant requests takes
//! [`ServiceProfile::batch_service_s`]`(n, programmed)` — the per-request
//! share scales linearly, and the weight-programming share is paid only
//! when the accelerator was last programmed for a *different* tenant
//! (consecutive same-tenant batches keep the MR banks tuned). Which
//! datasets' partition sets are resident on an accelerator is tracked for
//! [`RoutePolicy::GraphAffinity`]; HBM capacity is not modeled (residency
//! only grows — the large-graph tier fits many partition sets in 8 GB).

use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};
use std::sync::Arc;

use crate::coordinator::{BatchEngine, GraphDeltaPlan, ServiceProfile, SimError};
use crate::graph::datasets::Dataset;
use crate::graph::mutate::{apply_to_dataset, random_batch};
use crate::graph::partition::PartitionMatrix;
use crate::util::rng::{mix_seed, Pcg64};
use crate::util::telemetry;

use super::metrics::{
    AccelStats, ChurnStats, LatencyRecorder, ServeReport, TenantStats, TimeSeries,
};
use super::traffic::{exp_sample, ChurnSpec, OpenLoopArrivals, TenantMix, TrafficSpec};
use super::ServeConfig;

/// How arriving requests are spread across the fleet.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Cycle through accelerators in arrival order.
    RoundRobin,
    /// Send each request to the accelerator with the fewest waiting +
    /// in-flight requests (ties to the lowest index).
    JoinShortestQueue,
    /// Prefer accelerators that already hold the tenant's partition sets
    /// (shortest queue among those); fall back to shortest-queue overall
    /// when no accelerator does. Keeps tenants sticky, which also
    /// minimizes weight reprogramming.
    GraphAffinity,
}

impl RoutePolicy {
    pub fn by_name(name: &str) -> Option<Self> {
        match name.to_ascii_lowercase().as_str() {
            "rr" | "round-robin" | "roundrobin" => Some(RoutePolicy::RoundRobin),
            "jsq" | "shortest" | "join-shortest-queue" => Some(RoutePolicy::JoinShortestQueue),
            "affinity" | "graph-affinity" => Some(RoutePolicy::GraphAffinity),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            RoutePolicy::RoundRobin => "round-robin",
            RoutePolicy::JoinShortestQueue => "jsq",
            RoutePolicy::GraphAffinity => "graph-affinity",
        }
    }
}

/// Heap-resident event kinds — only the dynamically scheduled ones.
/// Arrivals, sampling ticks, and churn events never enter the heap (see
/// the module docs); their pending `(time, seq)` scalars merge with the
/// heap top each iteration.
#[derive(Debug, Clone, Copy)]
enum EventKind {
    /// A closed-loop client issues its next request.
    ClientArrival { client: u32 },
    /// The in-flight batch on `accel` finishes.
    BatchDone { accel: u32 },
    /// A batching deadline passed on `accel`; re-evaluate dispatch.
    Wake { accel: u32 },
}

#[derive(Debug, Clone, Copy)]
struct Event {
    time: f64,
    seq: u64,
    kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}

impl Eq for Event {}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.time.total_cmp(&other.time).then_with(|| self.seq.cmp(&other.seq))
    }
}

/// Sentinel for [`Request::client`]: the request came from the open-loop
/// stream, not a closed-loop client.
const NO_CLIENT: u32 = u32::MAX;

/// One queued request — 16 bytes, half the original layout (`usize`
/// tenant + `Option<u32>` client), so queue and batch traffic moves less
/// memory per event.
#[derive(Debug, Clone, Copy)]
struct Request {
    arrival_s: f64,
    tenant: u32,
    /// Closed-loop client that issued this request ([`NO_CLIENT`] when
    /// open-loop).
    client: u32,
}

struct Accel {
    /// Per-tenant FIFO queues of waiting requests.
    queues: Vec<VecDeque<Request>>,
    /// Tenants with a non-empty queue on this accelerator, in no
    /// particular order (swap-removed when a queue empties). Dispatch
    /// scans this instead of every tenant; selection is a pure min over
    /// `(arrival, tenant)`, so the unordered scan cannot change results.
    active: Vec<u32>,
    /// Tenant → its position in `active` (`u32::MAX` when queue empty).
    active_pos: Vec<u32>,
    /// Total waiting requests across all tenant queues.
    queued: usize,
    busy: bool,
    /// Requests of the in-flight batch (empty when idle).
    current: Vec<Request>,
    /// Retired batch buffer, reused by the next dispatch so steady-state
    /// batch launches allocate nothing.
    spare: Vec<Request>,
    /// Tenant whose weights are on the MR banks (None before first batch).
    programmed: Option<usize>,
    /// Earliest pending Wake event for this accelerator (infinity when
    /// none) — dedupes wake-ups so queue growth toward a fixed batching
    /// deadline does not re-push the same event.
    next_wake_s: f64,
    /// Dataset ids whose partition sets this accelerator holds.
    resident: Vec<bool>,
    busy_s: f64,
    completed: u64,
    batches: u64,
    weight_programs: u64,
}

impl Accel {
    fn new(n_tenants: usize, n_datasets: usize) -> Self {
        Self {
            queues: (0..n_tenants).map(|_| VecDeque::new()).collect(),
            active: Vec::new(),
            active_pos: vec![u32::MAX; n_tenants],
            queued: 0,
            busy: false,
            current: Vec::new(),
            spare: Vec::new(),
            programmed: None,
            next_wake_s: f64::INFINITY,
            resident: vec![false; n_datasets],
            busy_s: 0.0,
            completed: 0,
            batches: 0,
            weight_programs: 0,
        }
    }

    /// Waiting + in-flight requests: the JSQ load signal.
    fn depth(&self) -> usize {
        self.queued + self.current.len()
    }
}

/// Process-wide dispatched-batch-size distribution (`serve.batch.size` in
/// the telemetry registry). The fast loop tallies sizes locally and
/// flushes once per run through [`telemetry::Histogram::record_n`]; the
/// retained baseline ([`super::reference`]) still records per batch.
pub(crate) fn batch_size_hist() -> &'static Arc<telemetry::Histogram> {
    static H: std::sync::OnceLock<Arc<telemetry::Histogram>> = std::sync::OnceLock::new();
    H.get_or_init(|| telemetry::registry().histogram("serve.batch.size"))
}

/// Batch sizes above this are recorded directly instead of through the
/// dense local tally (bounds the tally allocation for pathological
/// `max_batch` settings).
const MAX_TALLIED_BATCH: usize = 1024;

/// Dense dataset ids over the tenant mix: `names[id]` is the dataset of
/// every tenant `t` with `tenant_dataset[t] == id` (tenants sharing a
/// dataset share an id — and therefore residency and churn state).
fn dense_dataset_ids(mix: &TenantMix) -> (Vec<String>, Vec<usize>) {
    let mut names: Vec<String> = Vec::new();
    let mut tenant_dataset = Vec::with_capacity(mix.len());
    for t in mix.tenants() {
        let id = match names.iter().position(|d| d == &t.dataset) {
            Some(i) => i,
            None => {
                names.push(t.dataset.clone());
                names.len() - 1
            }
        };
        tenant_dataset.push(id);
    }
    (names, tenant_dataset)
}

/// Live mutation state of a serving-under-churn run: per-dataset
/// copy-on-write graph + partition handles (the engine's cached instances
/// stay canonical at their original epoch), one [`GraphDeltaPlan`] per
/// tenant, and the dedicated churn PCG stream.
///
/// Setup shares the engine's `Arc`s directly — no deep dataset or
/// partition clone at fleet start. The first mutation event touching a
/// dataset pays one lazy [`Arc::make_mut`] clone (the engine's canonical
/// copy must not mutate); every later event splices that private copy in
/// place. A churn config that never fires an event clones nothing.
///
/// Each mutation event samples a tenant by mix weight, applies one
/// [`crate::graph::mutate::GraphDelta`] batch to that tenant's dataset
/// (splicing the partition matrices in place and bumping the graph
/// epoch), evicts the engine's superseded-epoch cache entries, and
/// re-profiles every tenant sharing the dataset through its delta plan —
/// an incremental *patch* of only the mutation-touched groups in steady
/// state, never a cold re-simulation.
struct ChurnRuntime<'e> {
    engine: &'e BatchEngine,
    spec: ChurnSpec,
    rng: Pcg64,
    /// Dense dataset id → copy-on-write dataset handle (epoch advances in
    /// the private copy; the engine's canonical Arc is never mutated).
    datasets: Vec<Arc<Dataset>>,
    /// Dense dataset id → its `(V, N)` partition set, same COW scheme.
    partitions: Vec<Arc<Vec<PartitionMatrix>>>,
    /// Tenant index → incrementally maintained plan.
    plans: Vec<GraphDeltaPlan>,
    tenant_dataset: Vec<usize>,
    events: u64,
    edges_added: u64,
    edges_removed: u64,
    vertices_added: u64,
    reprofiles: u64,
    evictions: u64,
    epochs: TimeSeries,
}

impl<'e> ChurnRuntime<'e> {
    /// Adopts the engine's canonical dataset/partition `Arc`s (zero
    /// copies) and primes every tenant's delta plan with one cold build,
    /// so each in-loop mutation event runs the incremental path.
    fn new(
        engine: &'e BatchEngine,
        cfg: &ServeConfig,
        spec: ChurnSpec,
    ) -> Result<Self, SimError> {
        let (names, tenant_dataset) = dense_dataset_ids(&cfg.mix);
        let mut datasets = Vec::with_capacity(names.len());
        let mut partitions = Vec::with_capacity(names.len());
        for name in &names {
            let ds = engine.dataset(name)?;
            let pms = engine.partitions_for(&ds, cfg.accel_cfg.v, cfg.accel_cfg.n)?;
            datasets.push(ds);
            partitions.push(pms);
        }
        let mut plans = Vec::with_capacity(cfg.mix.len());
        for (i, t) in cfg.mix.tenants().iter().enumerate() {
            let ds_id = tenant_dataset[i];
            let ds_ref: &Dataset = &datasets[ds_id];
            let pm_ref: &[PartitionMatrix] = &partitions[ds_id];
            let mut plan = GraphDeltaPlan::new(
                t.model,
                &ds_ref.spec,
                cfg.accel_cfg,
                cfg.flags,
                cfg.shards,
            );
            plan.retarget_graph(ds_ref, pm_ref, None)
                .map_err(|e| e.in_workload(t.model, t.dataset.clone()))?;
            plans.push(plan);
        }
        Ok(Self {
            engine,
            spec,
            rng: Pcg64::seed_from_u64(mix_seed(cfg.seed, 3)),
            datasets,
            partitions,
            plans,
            tenant_dataset,
            events: 0,
            edges_added: 0,
            edges_removed: 0,
            vertices_added: 0,
            reprofiles: 0,
            evictions: 0,
            epochs: TimeSeries::default(),
        })
    }

    /// Gap to the next mutation event (exponential at `edges_per_s /
    /// batch` events/sec).
    fn next_gap(&mut self) -> f64 {
        exp_sample(&mut self.rng, self.spec.events_per_s())
    }

    /// Applies one mutation event: mutate a tenant-sampled dataset, evict
    /// the engine's stale-epoch entries, and refresh the in-fleet service
    /// profile of every tenant sharing the dataset. In-flight batches
    /// keep the service time they were dispatched with; batches launched
    /// after this instant use the refreshed profiles.
    fn apply_event(
        &mut self,
        mix: &TenantMix,
        profiles: &mut [ServiceProfile],
    ) -> Result<(), SimError> {
        self.events += 1;
        let tenant = mix.sample(&mut self.rng);
        let ds_id = self.tenant_dataset[tenant];
        // Copy-on-write: the first event on a dataset detaches it from the
        // engine's canonical Arc; later events mutate the copy in place.
        let dataset = Arc::make_mut(&mut self.datasets[ds_id]);
        let g = if dataset.graphs.len() > 1 {
            self.rng.gen_range(0, dataset.graphs.len())
        } else {
            0
        };
        let batch = random_batch(
            &dataset.graphs[g],
            self.spec.batch,
            self.spec.add_fraction,
            self.spec.vertex_fraction,
            &mut self.rng,
        );
        let applied = apply_to_dataset(
            dataset,
            Arc::make_mut(&mut self.partitions[ds_id]),
            g,
            &batch,
        )?;
        self.edges_added += applied.edges_added as u64;
        self.edges_removed += applied.edges_removed as u64;
        self.vertices_added += applied.vertices_added as u64;
        self.evictions +=
            self.engine.evict_dataset_epochs_below(&dataset.spec.name, dataset.epoch) as u64;
        let trail = [applied];
        for (t, plan) in self.plans.iter_mut().enumerate() {
            if self.tenant_dataset[t] != ds_id {
                continue;
            }
            let ds_ref: &Dataset = &self.datasets[ds_id];
            let pm_ref: &[PartitionMatrix] = &self.partitions[ds_id];
            plan.retarget_graph(ds_ref, pm_ref, Some(&trail)).map_err(|e| {
                let tn = &mix.tenants()[t];
                e.in_workload(tn.model, tn.dataset.clone())
            })?;
            let report = plan.evaluate()?;
            profiles[t] = ServiceProfile::from_report(&report);
            self.reprofiles += 1;
        }
        Ok(())
    }

    /// Records the applied-epoch total on a metrics sampling tick.
    fn sample(&mut self, now: f64) {
        let total: u64 = self.datasets.iter().map(|d| d.epoch).sum();
        self.epochs.push(now, total as f64);
    }

    /// Final per-run churn accounting for the serve report.
    fn stats(self) -> ChurnStats {
        ChurnStats {
            events: self.events,
            edges_added: self.edges_added,
            edges_removed: self.edges_removed,
            vertices_added: self.vertices_added,
            rebuilds: self.plans.iter().map(|p| p.rebuilds() as u64).sum(),
            patches: self.plans.iter().map(|p| p.patches() as u64).sum(),
            reprofiles: self.reprofiles,
            evictions: self.evictions,
            epochs: self.epochs,
        }
    }
}

/// Which source supplies the next event: the heap of dynamic events or
/// one of the three pending side-channel scalars.
#[derive(Clone, Copy)]
enum NextSource {
    Heap,
    Arrival,
    Sample,
    Churn,
}

/// Whether `(t, s)` beats the current best `(time, seq)` candidate —
/// exactly the heap's `Ord`, so the side-channel merge reproduces the
/// all-heap event order.
fn earlier(t: f64, s: u64, best: Option<(f64, u64, NextSource)>) -> bool {
    match best {
        None => true,
        Some((bt, bs, _)) => match t.total_cmp(&bt) {
            std::cmp::Ordering::Less => true,
            std::cmp::Ordering::Greater => false,
            std::cmp::Ordering::Equal => s < bs,
        },
    }
}

struct FleetSim<'a> {
    cfg: &'a ServeConfig,
    profiles: Vec<ServiceProfile>,
    /// Present exactly when `cfg.churn` is set and an engine was supplied.
    churn: Option<ChurnRuntime<'a>>,
    /// Tenant index → dense dataset id (tenants sharing a dataset share
    /// residency).
    tenant_dataset: Vec<usize>,
    /// Dense dataset id → how many accelerators hold it resident (the
    /// affinity router's existence check, maintained incrementally instead
    /// of scanned per arrival).
    dataset_resident: Vec<u32>,
    accels: Vec<Accel>,
    heap: BinaryHeap<Reverse<Event>>,
    seq: u64,
    rr_next: usize,
    tenant_rng: Pcg64,
    think_rng: Pcg64,
    /// Mean think time of the closed-loop population (0 when open-loop),
    /// hoisted out of the completion path.
    mean_think_s: f64,
    /// Local tally of dispatched batch sizes (index = size), flushed to
    /// the `serve.batch.size` histogram once per run.
    batch_size_counts: Vec<u64>,
    // Metrics accumulators.
    latency: LatencyRecorder,
    tenant_latency: Vec<LatencyRecorder>,
    tenant_offered: Vec<u64>,
    tenant_completed: Vec<u64>,
    offered: u64,
    completed: u64,
    energy_j: f64,
    queue_depth: TimeSeries,
    busy_frac: TimeSeries,
    last_completion_s: f64,
}

impl<'a> FleetSim<'a> {
    fn push(&mut self, time: f64, kind: EventKind) {
        self.seq += 1;
        self.heap.push(Reverse(Event { time, seq: self.seq, kind }));
    }

    fn route(&mut self, tenant: usize) -> usize {
        let n = self.accels.len();
        match self.cfg.route {
            RoutePolicy::RoundRobin => {
                let i = self.rr_next % n;
                self.rr_next = self.rr_next.wrapping_add(1);
                i
            }
            RoutePolicy::JoinShortestQueue => self.shortest_queue(|_| true),
            RoutePolicy::GraphAffinity => {
                let ds = self.tenant_dataset[tenant];
                if self.dataset_resident[ds] > 0 {
                    self.shortest_queue(|a| a.resident[ds])
                } else {
                    self.shortest_queue(|_| true)
                }
            }
        }
    }

    /// Lowest-index accelerator with minimum depth among those `keep`
    /// admits (callers guarantee at least one does).
    fn shortest_queue<F: Fn(&Accel) -> bool>(&self, keep: F) -> usize {
        let mut best = usize::MAX;
        let mut best_depth = usize::MAX;
        for (i, a) in self.accels.iter().enumerate() {
            if keep(a) && a.depth() < best_depth {
                best = i;
                best_depth = a.depth();
            }
        }
        debug_assert!(best != usize::MAX, "router filter admitted no accelerator");
        best
    }

    fn enqueue(&mut self, tenant: usize, arrival_s: f64, client: u32) {
        self.offered += 1;
        self.tenant_offered[tenant] += 1;
        let idx = self.route(tenant);
        let a = &mut self.accels[idx];
        if a.queues[tenant].is_empty() {
            a.active_pos[tenant] = a.active.len() as u32;
            a.active.push(tenant as u32);
        }
        a.queues[tenant].push_back(Request { arrival_s, tenant: tenant as u32, client });
        a.queued += 1;
        self.try_dispatch(idx, arrival_s);
    }

    /// If `idx` is idle and some tenant queue is dispatchable now, launch
    /// the FIFO-oldest ready batch; otherwise schedule a wake-up at the
    /// earliest batching deadline.
    fn try_dispatch(&mut self, idx: usize, now: f64) {
        if self.accels[idx].busy || self.accels[idx].queued == 0 {
            return;
        }
        let policy = self.cfg.batch;
        // Decide with a shared borrow, mutate after. Only tenants with
        // waiting requests are scanned; `ready` is a min over
        // `(arrival, tenant)` and the deadline a min-fold, so the
        // unordered `active` scan selects exactly what a full ordered
        // scan would.
        let mut ready: Option<(f64, usize)> = None; // (oldest arrival, tenant)
        let mut next_deadline = f64::INFINITY;
        for &tn in &self.accels[idx].active {
            let tn = tn as usize;
            let q = &self.accels[idx].queues[tn];
            let Some(front) = q.front() else {
                debug_assert!(false, "active table lists an empty tenant queue");
                continue;
            };
            let at = policy.ready_at(front.arrival_s, q.len(), &self.profiles[tn]);
            if at <= now {
                let cand = (front.arrival_s, tn);
                let better = match ready {
                    None => true,
                    Some(best) => cand.0 < best.0 || (cand.0 == best.0 && cand.1 < best.1),
                };
                if better {
                    ready = Some(cand);
                }
            } else if at < next_deadline {
                next_deadline = at;
            }
        }
        let Some((_, tenant)) = ready else {
            // One pending wake per accelerator is enough: re-push only when
            // the new deadline beats the earliest already scheduled (stale
            // later wakes fire as harmless re-evaluations).
            if next_deadline.is_finite() && next_deadline < self.accels[idx].next_wake_s {
                self.accels[idx].next_wake_s = next_deadline;
                self.push(next_deadline, EventKind::Wake { accel: idx as u32 });
            }
            return;
        };
        let ds = self.tenant_dataset[tenant];
        let profile = self.profiles[tenant];
        let take;
        let programmed;
        let service_s;
        let mut newly_resident = false;
        {
            let a = &mut self.accels[idx];
            take = policy.max_batch().min(a.queues[tenant].len());
            // Reuse the retired batch buffer; steady state allocates
            // nothing per dispatch.
            let mut batch = std::mem::take(&mut a.spare);
            batch.clear();
            batch.extend(a.queues[tenant].drain(..take));
            a.queued -= take;
            if a.queues[tenant].is_empty() {
                let pos = a.active_pos[tenant] as usize;
                a.active.swap_remove(pos);
                if pos < a.active.len() {
                    a.active_pos[a.active[pos] as usize] = pos as u32;
                }
                a.active_pos[tenant] = u32::MAX;
            }
            programmed = a.programmed == Some(tenant);
            if !programmed {
                a.weight_programs += 1;
            }
            service_s = profile.batch_service_s(take, programmed);
            a.programmed = Some(tenant);
            if !a.resident[ds] {
                a.resident[ds] = true;
                newly_resident = true;
            }
            a.busy = true;
            a.current = batch;
            a.busy_s += service_s;
            a.batches += 1;
        }
        if newly_resident {
            self.dataset_resident[ds] += 1;
        }
        if take < self.batch_size_counts.len() {
            self.batch_size_counts[take] += 1;
        } else {
            batch_size_hist().record(take as f64);
        }
        // Energy is decided at launch (the batch either paid the staging
        // share or not); the fleet drains, so launch-time accounting equals
        // completion-time totals.
        self.energy_j += profile.batch_energy_j(take, programmed);
        self.push(now + service_s, EventKind::BatchDone { accel: idx as u32 });
    }

    fn complete_batch(&mut self, idx: usize, now: f64) {
        let batch = std::mem::take(&mut self.accels[idx].current);
        self.accels[idx].busy = false;
        self.accels[idx].completed += batch.len() as u64;
        self.last_completion_s = now;
        for req in &batch {
            let lat = now - req.arrival_s;
            self.latency.record(lat);
            self.tenant_latency[req.tenant as usize].record(lat);
            self.tenant_completed[req.tenant as usize] += 1;
            self.completed += 1;
            if req.client != NO_CLIENT {
                let gap = if self.mean_think_s > 0.0 {
                    exp_sample(&mut self.think_rng, 1.0 / self.mean_think_s)
                } else {
                    0.0
                };
                let next = now + gap;
                if next <= self.cfg.duration_s {
                    self.push(next, EventKind::ClientArrival { client: req.client });
                }
            }
        }
        // Retire the buffer for the next dispatch on this accelerator.
        let mut spare = batch;
        spare.clear();
        self.accels[idx].spare = spare;
        self.try_dispatch(idx, now);
    }

    fn sample_metrics(&mut self, now: f64) {
        let waiting: usize = self.accels.iter().map(|a| a.queued).sum();
        let busy = self.accels.iter().filter(|a| a.busy).count();
        self.queue_depth.push(now, waiting as f64);
        self.busy_frac.push(now, busy as f64 / self.accels.len() as f64);
        if let Some(c) = self.churn.as_mut() {
            c.sample(now);
        }
    }
}

/// Runs the serving simulation against pre-resolved tenant service
/// profiles (`profiles[i]` belongs to `cfg.mix.tenants()[i]`).
///
/// Arrivals stop at `cfg.duration_s`; the fleet then drains, so every
/// offered request completes and the report's makespan extends past the
/// horizon exactly when the offered load exceeded fleet capacity.
///
/// Rejects configurations with [`ServeConfig::churn`] set: mutation
/// events re-derive service profiles through the engine's incremental
/// machinery, which a profile-only entry point cannot reach — use
/// [`super::simulate`] (or [`super::simulate_with_workers`]) for
/// serving-under-churn runs.
pub fn simulate_fleet(
    cfg: &ServeConfig,
    profiles: &[ServiceProfile],
) -> Result<ServeReport, SimError> {
    cfg.validate()?;
    if cfg.churn.is_some() {
        return Err(SimError::InvalidConfig(
            "serving under churn maintains plans through an engine; use serve::simulate \
             or serve::simulate_with_workers instead of the profile-only entry point"
                .into(),
        ));
    }
    run_fleet(cfg, profiles.to_vec(), None)
}

/// [`simulate_fleet`] plus the serving-under-churn mode: when
/// `cfg.churn` is set, a [`ChurnRuntime`] interleaves graph-mutation
/// events with the request stream and refreshes tenant profiles through
/// incremental plan maintenance. Callers (the `serve::simulate*` entry
/// points) validate `cfg` before resolving profiles, so this does not
/// re-validate.
pub(crate) fn simulate_fleet_churn(
    engine: &BatchEngine,
    cfg: &ServeConfig,
    profiles: Vec<ServiceProfile>,
) -> Result<ServeReport, SimError> {
    let churn = match cfg.churn {
        Some(spec) => Some(ChurnRuntime::new(engine, cfg, spec)?),
        None => None,
    };
    run_fleet(cfg, profiles, churn)
}

fn run_fleet<'a>(
    cfg: &'a ServeConfig,
    profiles: Vec<ServiceProfile>,
    churn: Option<ChurnRuntime<'a>>,
) -> Result<ServeReport, SimError> {
    if profiles.len() != cfg.mix.len() {
        return Err(SimError::InvalidConfig(format!(
            "{} service profiles supplied for {} tenants",
            profiles.len(),
            cfg.mix.len()
        )));
    }
    // Engine-derived profiles always satisfy this; hand-built ones (the
    // public simulate_with_profiles path) must too, or the event loop
    // could stall time (zero per-request service with zero think time
    // recurs at one timestamp forever) or poison every metric with NaN.
    for (i, p) in profiles.iter().enumerate() {
        let finite = p.latency_s.is_finite()
            && p.weight_stage_s.is_finite()
            && p.energy_j.is_finite()
            && p.weight_stage_energy_j.is_finite();
        if !finite
            || p.weight_stage_s < 0.0
            || p.energy_j < 0.0
            || p.weight_stage_energy_j < 0.0
            || p.per_request_s() <= 0.0
        {
            return Err(SimError::InvalidConfig(format!(
                "service profile for tenant {} ({}) is degenerate \
                 (needs finite fields and per-request time > 0): {p:?}",
                i,
                cfg.mix.tenants()[i].label()
            )));
        }
    }
    let n_tenants = cfg.mix.len();
    // Sharded serving: the schedulable unit is a *shard group* of
    // `cfg.shards` chips executing one sharded plan in lockstep — the
    // event loop runs over groups (all of the fleet at shards == 1, so
    // that path is structurally unchanged) and the per-chip stats are
    // expanded from the group stats at the end.
    let slots = cfg.shard_groups();
    // Dense dataset ids: tenants sharing a dataset share residency.
    let (dataset_names, tenant_dataset) = dense_dataset_ids(&cfg.mix);
    let n_datasets = dataset_names.len();
    let mean_think_s = match cfg.traffic {
        TrafficSpec::Closed { mean_think_s, .. } => mean_think_s,
        TrafficSpec::Open { .. } => 0.0,
    };

    let mut sim = FleetSim {
        cfg,
        profiles,
        churn,
        tenant_dataset,
        dataset_resident: vec![0; n_datasets],
        accels: (0..slots).map(|_| Accel::new(n_tenants, n_datasets)).collect(),
        heap: BinaryHeap::new(),
        seq: 0,
        rr_next: 0,
        tenant_rng: Pcg64::seed_from_u64(mix_seed(cfg.seed, 1)),
        think_rng: Pcg64::seed_from_u64(mix_seed(cfg.seed, 2)),
        mean_think_s,
        batch_size_counts: vec![0; cfg.batch.max_batch().min(MAX_TALLIED_BATCH) + 1],
        latency: LatencyRecorder::new(),
        tenant_latency: (0..n_tenants).map(|_| LatencyRecorder::new()).collect(),
        tenant_offered: vec![0; n_tenants],
        tenant_completed: vec![0; n_tenants],
        offered: 0,
        completed: 0,
        energy_j: 0.0,
        queue_depth: TimeSeries::default(),
        busy_frac: TimeSeries::default(),
        last_completion_s: 0.0,
    };

    // Seed the event sources. Sequence numbers are allocated in the same
    // order the all-heap loop pushed events — first the traffic source,
    // then one per sampling tick (a reserved contiguous block; tick `k`
    // owns `sample_base_seq + k`), then the first churn event — so every
    // `(time, seq)` comparison, and therefore the event order, matches
    // the retained baseline bit for bit.
    let mut pending_arrival: Option<(f64, u64, usize)> = None;
    let mut arrivals = match cfg.traffic {
        TrafficSpec::Open { process, rps } => {
            let mut src = OpenLoopArrivals::new(process, rps, mix_seed(cfg.seed, 0))
                .map_err(SimError::InvalidConfig)?;
            let t0 = src.next_arrival();
            if t0 <= cfg.duration_s {
                let tenant = sim.cfg.mix.sample(&mut sim.tenant_rng);
                sim.seq += 1;
                pending_arrival = Some((t0, sim.seq, tenant));
            }
            Some(src)
        }
        TrafficSpec::Closed { clients, mean_think_s } => {
            for client in 0..clients as u32 {
                let gap = if mean_think_s > 0.0 {
                    exp_sample(&mut sim.think_rng, 1.0 / mean_think_s)
                } else {
                    0.0
                };
                if gap <= cfg.duration_s {
                    sim.push(gap, EventKind::ClientArrival { client });
                }
            }
            None
        }
    };
    let sample_dt = cfg.duration_s / cfg.samples as f64;
    let sample_base_seq = sim.seq;
    sim.seq += cfg.samples as u64;
    let mut next_sample: usize = 1;
    // Churn events stop at the horizon with the arrivals, so the drain
    // phase serves the final graph state.
    let mut pending_churn: Option<(f64, u64)> = None;
    if let Some(c) = sim.churn.as_mut() {
        let t0 = c.next_gap();
        if t0 <= cfg.duration_s {
            sim.seq += 1;
            pending_churn = Some((t0, sim.seq));
        }
    }

    // The event loop. Arrivals stop at the horizon; the remaining events
    // then drain. Each iteration merges the heap top with the pending
    // side-channel events by `(time, seq)`. Event counts are tallied in
    // locals and flushed to the `serve.events.*` registry counters after
    // the loop — no per-event atomics.
    let _loop_span = telemetry::span("serve.event_loop");
    let registry = telemetry::registry();
    let queue_gauge = registry.gauge("serve.queue_depth");
    let (mut n_arrival, mut n_batch_done, mut n_wake, mut n_sample, mut n_churn) =
        (0usize, 0usize, 0usize, 0usize, 0usize);
    loop {
        let mut best: Option<(f64, u64, NextSource)> =
            sim.heap.peek().map(|&Reverse(e)| (e.time, e.seq, NextSource::Heap));
        if let Some((t, s, _)) = pending_arrival {
            if earlier(t, s, best) {
                best = Some((t, s, NextSource::Arrival));
            }
        }
        if next_sample <= cfg.samples {
            let t = next_sample as f64 * sample_dt;
            let s = sample_base_seq + next_sample as u64;
            if earlier(t, s, best) {
                best = Some((t, s, NextSource::Sample));
            }
        }
        if let Some((t, s)) = pending_churn {
            if earlier(t, s, best) {
                best = Some((t, s, NextSource::Churn));
            }
        }
        let Some((now, _, source)) = best else { break };
        match source {
            NextSource::Heap => {
                let Some(Reverse(ev)) = sim.heap.pop() else { unreachable!() };
                match ev.kind {
                    EventKind::ClientArrival { client } => {
                        n_arrival += 1;
                        let tenant = sim.cfg.mix.sample(&mut sim.tenant_rng);
                        sim.enqueue(tenant, now, client);
                    }
                    EventKind::BatchDone { accel } => {
                        n_batch_done += 1;
                        sim.complete_batch(accel as usize, now);
                    }
                    EventKind::Wake { accel } => {
                        n_wake += 1;
                        let accel = accel as usize;
                        // This wake (or an earlier stale one) has fired;
                        // allow the next deadline to schedule a fresh one.
                        if sim.accels[accel].next_wake_s <= now {
                            sim.accels[accel].next_wake_s = f64::INFINITY;
                        }
                        sim.try_dispatch(accel, now);
                    }
                }
            }
            NextSource::Arrival => {
                n_arrival += 1;
                let (_, _, tenant) = pending_arrival.take().expect("selected pending arrival");
                sim.enqueue(tenant, now, NO_CLIENT);
                if let Some(src) = arrivals.as_mut() {
                    let t = src.next_arrival();
                    if t <= cfg.duration_s {
                        let next_tenant = sim.cfg.mix.sample(&mut sim.tenant_rng);
                        sim.seq += 1;
                        pending_arrival = Some((t, sim.seq, next_tenant));
                    }
                }
            }
            NextSource::Sample => {
                n_sample += 1;
                sim.sample_metrics(now);
                queue_gauge.set(sim.accels.iter().map(|a| a.queued).sum::<usize>() as f64);
                next_sample += 1;
            }
            NextSource::Churn => {
                let _span = telemetry::span("serve.churn_event");
                n_churn += 1;
                pending_churn = None;
                if let Some(c) = sim.churn.as_mut() {
                    c.apply_event(&cfg.mix, &mut sim.profiles)?;
                    let t = now + c.next_gap();
                    if t <= cfg.duration_s {
                        sim.seq += 1;
                        pending_churn = Some((t, sim.seq));
                    }
                }
            }
        }
    }
    registry.counter("serve.events.arrival").add(n_arrival);
    registry.counter("serve.events.batch_done").add(n_batch_done);
    registry.counter("serve.events.wake").add(n_wake);
    registry.counter("serve.events.sample").add(n_sample);
    registry.counter("serve.events.churn").add(n_churn);
    for (size, &count) in sim.batch_size_counts.iter().enumerate() {
        if count > 0 {
            batch_size_hist().record_n(size as f64, count);
        }
    }

    debug_assert_eq!(sim.offered, sim.completed, "fleet must drain every request");
    let makespan_s = cfg.duration_s.max(sim.last_completion_s);
    let tenants = cfg
        .mix
        .tenants()
        .iter()
        .enumerate()
        .map(|(i, t)| TenantStats {
            label: t.label(),
            offered: sim.tenant_offered[i],
            completed: sim.tenant_completed[i],
            latency: sim.tenant_latency[i].summary(),
            slo_attainment: cfg.slo_s.map(|slo| sim.tenant_latency[i].attainment(slo)),
        })
        .collect();
    // Expand group stats to member chips: every chip of a shard group is
    // busy exactly when its group is, so busy time and utilization are the
    // chip's own; the request/batch/program counts are the group's work,
    // replicated per chip (each chip participates in every batch).
    let mut accels = Vec::with_capacity(slots * cfg.shards);
    for a in &sim.accels {
        let stats = AccelStats {
            utilization: a.busy_s / makespan_s,
            busy_s: a.busy_s,
            completed: a.completed,
            batches: a.batches,
            weight_programs: a.weight_programs,
        };
        for _ in 0..cfg.shards {
            accels.push(stats);
        }
    }
    Ok(ServeReport {
        duration_s: cfg.duration_s,
        makespan_s,
        offered: sim.offered,
        completed: sim.completed,
        throughput_rps: if makespan_s > 0.0 { sim.completed as f64 / makespan_s } else { 0.0 },
        latency: sim.latency.summary(),
        slo_attainment: cfg.slo_s.map(|slo| sim.latency.attainment(slo)),
        energy_j: sim.energy_j,
        tenants,
        accels,
        queue_depth: sim.queue_depth,
        busy_frac: sim.busy_frac,
        churn: sim.churn.map(ChurnRuntime::stats),
    })
}
