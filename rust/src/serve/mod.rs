//! `ghost::serve` — online-serving simulation over the batch engine.
//!
//! The paper's evaluation (§4, Figs. 7–9) is *offline*: one inference at a
//! time, latency and energy per run. A deployed GNN accelerator instead
//! sees an endless request stream — arrivals queue, batches form, and the
//! question becomes "what p99 latency does a 4-chip fleet hold at 50k
//! requests/sec", which no per-inference number answers (the
//! serving-vs-offline gap the GNN-acceleration surveys call out). This
//! module closes that gap with a deterministic discrete-event simulator:
//!
//! * [`traffic`] — seeded open-loop arrival processes (Poisson,
//!   bursty/MMPP, diurnal ramp) and closed-loop client populations,
//!   mixing weighted `(model, dataset)` tenants in one stream;
//! * [`batcher`] — dynamic micro-batching policies (immediate,
//!   max-batch/max-wait, SLO-aware) that amortize weight programming over
//!   same-tenant batches;
//! * [`fleet`] — the N-accelerator event loop: round-robin /
//!   join-shortest-queue / graph-affinity routing over a binary-heap
//!   event queue;
//! * [`metrics`] — exact p50/p95/p99/p999 latency percentiles, SLO
//!   attainment, queue-depth and busy-fraction time series, per-tenant
//!   and per-accelerator breakdowns, serialized through
//!   [`crate::util::json`];
//! * [`sweep`] — the parallel scenario-sweep executor
//!   ([`sweep_with_workers`]) fanning independent config probes over
//!   worker threads against the shared engine caches, and the capacity
//!   planner ([`plan_capacity`]) that bisects fleet size to the minimum
//!   meeting a p99 SLO per rps point;
//! * [`reference`] — the retained pre-fast-path event loop, kept as the
//!   bit-identity oracle for `benches/serve_scale.rs` and the equivalence
//!   tests.
//!
//! Setting [`ServeConfig::churn`] turns the run into *serving under
//! mutation*: a seeded Poisson stream of graph-edit batches
//! ([`crate::graph::mutate::GraphDelta`]) interleaves with the request
//! stream, each event splicing the tenant's partition matrices in place,
//! evicting superseded engine cache epochs, and refreshing the affected
//! service profiles through incremental
//! [`crate::coordinator::GraphDeltaPlan`] patches — the report then
//! answers "what p99 does the fleet hold while the graph changes
//! underneath it", with the maintenance work itemized in
//! [`ChurnStats`].
//!
//! Service times come from the same simulator that reproduces the paper:
//! each tenant resolves to a cached
//! [`ServiceProfile`](crate::coordinator::ServiceProfile) through
//! [`BatchEngine::service_profile`], so the serving layer shares the
//! engine's dataset/partition caches and a fleet sweep never re-simulates
//! a tenant.
//!
//! ## Determinism guarantee
//!
//! A [`ServeConfig`] (which includes the seed) maps to **one** report,
//! bit-identical across runs, platforms, and worker counts: the event
//! loop is single-threaded with total `(time, sequence)` event ordering,
//! all randomness flows from per-purpose PCG streams derived via
//! [`crate::util::rng::mix_seed`], and the parallel service-profile
//! resolution is worker-count-invariant by the engine's guarantees
//! (`tests/integration_serve.rs` pins this with 1 vs 4 workers).

pub mod batcher;
pub mod fleet;
pub mod metrics;
pub mod reference;
pub mod sweep;
pub mod traffic;

pub use batcher::BatchPolicy;
pub use fleet::RoutePolicy;
pub use metrics::{
    AccelStats, ChurnStats, LatencyRecorder, LatencySummary, ServeReport, TenantStats,
    TimeSeries,
};
pub use sweep::{plan_capacity, sweep_with_workers, CapacityCurve, CapacityPlanRequest};
pub use traffic::{
    ArrivalProcess, ChurnSpec, OpenLoopArrivals, TenantMix, TenantProfile, TrafficSpec,
};

use crate::config::GhostConfig;
use crate::coordinator::{BatchEngine, OptFlags, ServiceProfile, SimError, SimRequest};
use crate::util::parallel::{par_map, par_map_workers};

use fleet::simulate_fleet;

/// Everything one serving run needs. Construct with [`ServeConfig::new`]
/// and override fields as needed; [`simulate`] validates before running.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeConfig {
    pub mix: TenantMix,
    pub traffic: TrafficSpec,
    /// Fleet size (≥ 1). Every accelerator is one GHOST instance with the
    /// same architectural configuration.
    pub accelerators: usize,
    /// Chips per shard group (≥ 1, must divide `accelerators`). At 1 every
    /// accelerator serves whole requests independently; above 1 the fleet
    /// is partitioned into `accelerators / shards` groups, each group's
    /// chips execute one sharded plan in lockstep, and a request occupies
    /// its tenant's whole group for the sharded service time. Routing
    /// policies operate over groups.
    pub shards: usize,
    pub route: RoutePolicy,
    pub batch: BatchPolicy,
    /// Traffic horizon, seconds: arrivals stop here and the fleet drains.
    pub duration_s: f64,
    pub seed: u64,
    /// Latency SLO for attainment reporting (and the SLO-aware batcher).
    pub slo_s: Option<f64>,
    /// Architectural configuration of each accelerator.
    pub accel_cfg: GhostConfig,
    pub flags: OptFlags,
    /// Queue-depth / busy-fraction samples taken over `duration_s` (≥ 1).
    pub samples: usize,
    /// Serve under graph mutation: when set, a seeded Poisson stream of
    /// [`crate::graph::mutate::GraphDelta`] batches mutates tenant
    /// datasets *during* the run. Each event splices the partition
    /// matrices incrementally, evicts superseded engine cache epochs, and
    /// refreshes affected tenants' service profiles through
    /// [`crate::coordinator::GraphDeltaPlan`] patches — so the report's
    /// tail latency is measured *under churn*. Requires the engine-backed
    /// entry points ([`simulate`] / [`simulate_with_workers`]).
    pub churn: Option<ChurnSpec>,
}

impl ServeConfig {
    pub fn new(mix: TenantMix, traffic: TrafficSpec) -> Self {
        Self {
            mix,
            traffic,
            accelerators: 1,
            shards: 1,
            route: RoutePolicy::JoinShortestQueue,
            batch: BatchPolicy::Immediate,
            duration_s: 1.0,
            seed: 7,
            slo_s: None,
            accel_cfg: GhostConfig::paper_optimal(),
            flags: OptFlags::ghost_default(),
            samples: 100,
            churn: None,
        }
    }

    /// Structural validation as a typed [`SimError`] — field problems
    /// (fleet shape, horizon, traffic, batching, churn, accelerator
    /// config) come back as [`SimError::InvalidConfig`] and optimization
    /// flags as [`SimError::InvalidFlags`], matching the engine's request
    /// validation, so CLI and sweep callers report one error type.
    pub fn validate(&self) -> Result<(), SimError> {
        let field = |msg: String| Err(SimError::InvalidConfig(msg));
        if self.mix.is_empty() {
            return field("tenant mix must not be empty".into());
        }
        if self.accelerators == 0 {
            return field("fleet needs at least one accelerator".into());
        }
        if self.shards == 0 {
            return field("shards must be >= 1".into());
        }
        if self.accelerators % self.shards != 0 {
            return field(format!(
                "shards ({}) must divide the fleet size ({}) into whole shard groups",
                self.shards, self.accelerators
            ));
        }
        if !self.duration_s.is_finite() || self.duration_s <= 0.0 {
            return field(format!("duration {} must be finite and > 0", self.duration_s));
        }
        if self.samples == 0 {
            return field("samples must be >= 1".into());
        }
        if let Some(slo) = self.slo_s {
            if !slo.is_finite() || slo <= 0.0 {
                return field(format!("SLO {slo} must be finite and > 0"));
            }
        }
        self.traffic.validate().map_err(SimError::InvalidConfig)?;
        self.batch.validate().map_err(SimError::InvalidConfig)?;
        if let Some(churn) = &self.churn {
            churn.validate().map_err(SimError::InvalidConfig)?;
        }
        self.accel_cfg.validate().map_err(SimError::InvalidConfig)?;
        self.flags.validate().map_err(SimError::InvalidFlags)
    }

    /// Number of independent scheduling slots: shard groups of `shards`
    /// chips each (the whole fleet when `shards == 1`).
    pub fn shard_groups(&self) -> usize {
        self.accelerators / self.shards.max(1)
    }

    /// The engine requests resolving each tenant's service profile.
    pub fn tenant_requests(&self) -> Vec<SimRequest> {
        self.mix
            .tenants()
            .iter()
            .map(|t| SimRequest::new(t.model, t.dataset.clone(), self.accel_cfg, self.flags))
            .collect()
    }
}

/// Tags each tenant's resolution failure with its `(model, dataset)` pair
/// and unwraps the successes in mix order.
fn collect_profiles(
    cfg: &ServeConfig,
    resolved: Vec<Result<ServiceProfile, SimError>>,
) -> Result<Vec<ServiceProfile>, SimError> {
    let mut profiles = Vec::with_capacity(resolved.len());
    for (result, t) in resolved.into_iter().zip(cfg.mix.tenants()) {
        profiles.push(result.map_err(|e| e.in_workload(t.model, t.dataset.clone()))?);
    }
    Ok(profiles)
}

/// Resolves every tenant's [`ServiceProfile`] through the engine over an
/// explicit worker count — the profiles, and therefore the report, are
/// identical for any count (the determinism tests pin 1 vs 4) — and runs
/// the fleet simulation.
pub fn simulate_with_workers(
    engine: &BatchEngine,
    cfg: &ServeConfig,
    workers: usize,
) -> Result<ServeReport, SimError> {
    cfg.validate()?;
    let reqs = cfg.tenant_requests();
    let resolved = if cfg.shards > 1 {
        par_map_workers(&reqs, workers, |req| {
            engine.sharded_service_profile(req, cfg.shards)
        })
    } else {
        par_map_workers(&reqs, workers, |req| engine.service_profile(req))
    };
    let profiles = collect_profiles(cfg, resolved)?;
    fleet::simulate_fleet_churn(engine, cfg, profiles)
}

/// [`simulate_with_workers`] at the pool's default parallelism
/// ([`par_map`]) — the entry point the CLI and benches use.
pub fn simulate(engine: &BatchEngine, cfg: &ServeConfig) -> Result<ServeReport, SimError> {
    cfg.validate()?;
    let reqs = cfg.tenant_requests();
    let resolved = if cfg.shards > 1 {
        par_map(&reqs, |req| engine.sharded_service_profile(req, cfg.shards))
    } else {
        par_map(&reqs, |req| engine.service_profile(req))
    };
    let profiles = collect_profiles(cfg, resolved)?;
    fleet::simulate_fleet_churn(engine, cfg, profiles)
}

/// Runs the fleet against already-resolved profiles (`profiles[i]` pairs
/// with `cfg.mix.tenants()[i]`) — lets benches time the event loop alone.
/// Rejects churn configurations (no engine to maintain plans against).
pub fn simulate_with_profiles(
    cfg: &ServeConfig,
    profiles: &[ServiceProfile],
) -> Result<ServeReport, SimError> {
    simulate_fleet(cfg, profiles)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gnn::models::ModelKind;

    fn single_tenant() -> TenantMix {
        TenantMix::new(vec![TenantProfile::new(ModelKind::Gcn, "Cora", 1.0)]).unwrap()
    }

    #[test]
    fn config_validation_catches_each_field() {
        let base = ServeConfig::new(
            single_tenant(),
            TrafficSpec::Open { process: ArrivalProcess::Poisson, rps: 100.0 },
        );
        base.validate().unwrap();
        let mut c = base.clone();
        c.accelerators = 0;
        assert!(c.validate().is_err());
        let mut c = base.clone();
        c.duration_s = 0.0;
        assert!(c.validate().is_err());
        let mut c = base.clone();
        c.samples = 0;
        assert!(c.validate().is_err());
        let mut c = base.clone();
        c.slo_s = Some(-1.0);
        assert!(c.validate().is_err());
        let mut c = base.clone();
        c.batch = BatchPolicy::MaxBatchOrWait { max_batch: 0, max_wait_s: 0.0 };
        assert!(c.validate().is_err());
        let mut c = base.clone();
        c.traffic = TrafficSpec::Open { process: ArrivalProcess::Poisson, rps: -5.0 };
        assert!(c.validate().is_err());
        let mut c = base.clone();
        c.shards = 0;
        assert!(c.validate().is_err());
        let mut c = base.clone();
        c.accelerators = 4;
        c.shards = 3; // does not divide the fleet
        assert!(c.validate().is_err());
        let mut c = base.clone();
        c.accelerators = 4;
        c.shards = 2;
        c.validate().unwrap();
        assert_eq!(c.shard_groups(), 2);
        let mut c = base;
        c.accel_cfg.r_c = 25;
        assert!(c.validate().is_err());
    }

    #[test]
    fn sharded_fleet_schedules_whole_groups() {
        // 4 chips in 2 shard groups: requests occupy a whole group; the
        // report still exposes per-chip stats, identical within a group.
        let mut cfg = ServeConfig::new(
            single_tenant(),
            TrafficSpec::Open { process: ArrivalProcess::Poisson, rps: 200.0 },
        );
        cfg.accelerators = 4;
        cfg.shards = 2;
        cfg.duration_s = 0.2;
        let engine = BatchEngine::new();
        let report = simulate_with_workers(&engine, &cfg, 1).unwrap();
        assert_eq!(report.accels.len(), 4);
        assert_eq!(report.offered, report.completed);
        for pair in report.accels.chunks(2) {
            assert_eq!(pair[0], pair[1], "chips of one shard group diverged");
        }
        // The profiles came from the sharded path.
        assert_eq!(engine.sharded_plan_builds(), 1);
        assert_eq!(engine.profile_builds(), 0);
    }

    #[test]
    fn unknown_tenant_dataset_surfaces_as_workload_error() {
        let mix =
            TenantMix::new(vec![TenantProfile::new(ModelKind::Gcn, "NoSuchDataset", 1.0)])
                .unwrap();
        let cfg = ServeConfig::new(
            mix,
            TrafficSpec::Open { process: ArrivalProcess::Poisson, rps: 100.0 },
        );
        let engine = BatchEngine::new();
        match simulate_with_workers(&engine, &cfg, 1) {
            Err(SimError::Workload { model, dataset, source }) => {
                assert_eq!(model, ModelKind::Gcn);
                assert_eq!(dataset, "NoSuchDataset");
                assert!(matches!(*source, SimError::UnknownDataset(_)));
            }
            other => panic!("expected workload error, got {other:?}"),
        }
    }

    #[test]
    fn profile_count_mismatch_rejected() {
        let cfg = ServeConfig::new(
            single_tenant(),
            TrafficSpec::Open { process: ArrivalProcess::Poisson, rps: 100.0 },
        );
        assert!(matches!(
            simulate_with_profiles(&cfg, &[]),
            Err(SimError::InvalidConfig(_))
        ));
    }

    #[test]
    fn churn_serving_mutates_patches_and_stays_deterministic() {
        let mut cfg = ServeConfig::new(
            TenantMix::new(vec![
                TenantProfile::new(ModelKind::Gcn, "Cora", 2.0),
                TenantProfile::new(ModelKind::Gat, "Citeseer", 1.0),
            ])
            .unwrap(),
            TrafficSpec::Open { process: ArrivalProcess::Poisson, rps: 300.0 },
        );
        cfg.duration_s = 0.5;
        cfg.churn = Some(ChurnSpec::new(400.0));
        let engine = BatchEngine::new();
        let report = simulate_with_workers(&engine, &cfg, 1).unwrap();
        let churn = report.churn.as_ref().expect("churn stats present");
        assert!(churn.events > 0, "no mutation events over the horizon");
        assert_eq!(churn.reprofiles, churn.events, "one tenant per dataset here");
        // Priming rebuilds once per tenant; every in-loop event patches.
        assert_eq!(churn.rebuilds, cfg.mix.len() as u64);
        assert_eq!(churn.patches, churn.events);
        assert!(churn.evictions > 0, "superseded epochs were never evicted");
        assert_eq!(engine.evictions() as u64, churn.evictions);
        assert!(
            churn.edges_added + churn.edges_removed > 0,
            "events applied no edge operations"
        );
        // Epoch series is monotone and ends at the applied total.
        let epochs = &churn.epochs.points;
        assert!(epochs.windows(2).all(|w| w[0].1 <= w[1].1));
        assert_eq!(epochs.last().map(|&(_, e)| e), Some(churn.events as f64));
        assert_eq!(report.offered, report.completed);
        // Bit-identical replay: same config + seed, fresh engine.
        let replay = simulate_with_workers(&BatchEngine::new(), &cfg, 4).unwrap();
        assert_eq!(report, replay, "churn serving must stay deterministic");
        // Without churn the same config yields no churn block.
        cfg.churn = None;
        let quiet = simulate_with_workers(&BatchEngine::new(), &cfg, 1).unwrap();
        assert!(quiet.churn.is_none());
    }

    #[test]
    fn churn_rejected_without_an_engine() {
        let mut cfg = ServeConfig::new(
            single_tenant(),
            TrafficSpec::Open { process: ArrivalProcess::Poisson, rps: 100.0 },
        );
        cfg.churn = Some(ChurnSpec::new(100.0));
        let p = ServiceProfile {
            latency_s: 1e-4,
            weight_stage_s: 1e-5,
            energy_j: 1e-6,
            weight_stage_energy_j: 1e-7,
        };
        assert!(matches!(
            simulate_with_profiles(&cfg, &[p]),
            Err(SimError::InvalidConfig(_))
        ));
        let mut bad = cfg.clone();
        bad.churn = Some(ChurnSpec { batch: 0, ..ChurnSpec::new(100.0) });
        assert!(bad.validate().is_err());
    }
}
