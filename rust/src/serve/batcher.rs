//! Dynamic micro-batching policies.
//!
//! GHOST programs a model's weights onto the MR banks once and then
//! streams requests through them, so a batch of same-tenant requests pays
//! the weight-programming latency ([`ServiceProfile::weight_stage_s`]) at
//! most once. The batcher decides how long a queue may hold requests to
//! grow that batch: not at all ([`BatchPolicy::Immediate`]), up to a fixed
//! size/wait bound ([`BatchPolicy::MaxBatchOrWait`]), or up to whatever
//! slack the oldest request's latency SLO still allows
//! ([`BatchPolicy::SloAware`]).
//!
//! A policy is a pure function of `(oldest arrival, queue length, tenant
//! profile)` — it owns no state and makes no RNG draws — which keeps the
//! fleet simulator's event loop deterministic.

use crate::coordinator::ServiceProfile;

/// When a per-tenant queue becomes dispatchable.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum BatchPolicy {
    /// Dispatch every request alone as soon as an accelerator frees up.
    /// Minimum queueing delay, zero amortization.
    Immediate,
    /// Close a batch when `max_batch` requests are queued or when the
    /// oldest has waited `max_wait_s`, whichever comes first.
    MaxBatchOrWait { max_batch: usize, max_wait_s: f64 },
    /// Grow the batch as long as the oldest request can still meet
    /// `slo_s`: the wait budget is the SLO minus the worst-case (cold,
    /// full-batch) service time. Falls back to immediate dispatch when the
    /// service time alone exhausts the SLO.
    SloAware { slo_s: f64, max_batch: usize },
}

impl BatchPolicy {
    pub fn validate(&self) -> Result<(), String> {
        match *self {
            BatchPolicy::Immediate => Ok(()),
            BatchPolicy::MaxBatchOrWait { max_batch, max_wait_s } => {
                if max_batch == 0 {
                    return Err("max_batch must be >= 1".into());
                }
                if !max_wait_s.is_finite() || max_wait_s < 0.0 {
                    return Err(format!("max_wait_s {max_wait_s} must be finite and >= 0"));
                }
                Ok(())
            }
            BatchPolicy::SloAware { slo_s, max_batch } => {
                if max_batch == 0 {
                    return Err("max_batch must be >= 1".into());
                }
                if !slo_s.is_finite() || slo_s <= 0.0 {
                    return Err(format!("slo_s {slo_s} must be finite and > 0"));
                }
                Ok(())
            }
        }
    }

    /// Most requests the policy closes into one batch.
    pub fn max_batch(&self) -> usize {
        match *self {
            BatchPolicy::Immediate => 1,
            BatchPolicy::MaxBatchOrWait { max_batch, .. }
            | BatchPolicy::SloAware { max_batch, .. } => max_batch,
        }
    }

    /// The earliest instant a non-empty queue may dispatch, given the
    /// arrival time of its oldest request and its current length. A value
    /// `<= now` means "ready"; otherwise the fleet schedules a wake-up at
    /// the returned deadline (re-evaluated if more requests land first).
    pub fn ready_at(
        &self,
        oldest_arrival_s: f64,
        queued: usize,
        profile: &ServiceProfile,
    ) -> f64 {
        match *self {
            BatchPolicy::Immediate => oldest_arrival_s,
            BatchPolicy::MaxBatchOrWait { max_batch, max_wait_s } => {
                if queued >= max_batch {
                    oldest_arrival_s
                } else {
                    oldest_arrival_s + max_wait_s
                }
            }
            BatchPolicy::SloAware { slo_s, max_batch } => {
                if queued >= max_batch {
                    return oldest_arrival_s;
                }
                let budget = (slo_s - profile.batch_service_s(max_batch, false)).max(0.0);
                oldest_arrival_s + budget
            }
        }
    }

    pub fn label(&self) -> String {
        match *self {
            BatchPolicy::Immediate => "immediate".into(),
            BatchPolicy::MaxBatchOrWait { max_batch, max_wait_s } => {
                format!("max:{max_batch}:{:.3}ms", max_wait_s * 1e3)
            }
            BatchPolicy::SloAware { slo_s, max_batch } => {
                format!("slo:{max_batch}@{:.3}ms", slo_s * 1e3)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn profile() -> ServiceProfile {
        ServiceProfile {
            latency_s: 1.0e-3,
            weight_stage_s: 4.0e-4,
            energy_j: 1.0e-6,
            weight_stage_energy_j: 4.0e-7,
        }
    }

    #[test]
    fn immediate_is_always_ready_with_singleton_batches() {
        let p = BatchPolicy::Immediate;
        assert_eq!(p.max_batch(), 1);
        assert_eq!(p.ready_at(3.5, 10, &profile()), 3.5);
        p.validate().unwrap();
    }

    #[test]
    fn max_batch_or_wait_holds_until_deadline_or_fill() {
        let p = BatchPolicy::MaxBatchOrWait { max_batch: 4, max_wait_s: 0.01 };
        // Short queue: dispatchable only after the wait deadline.
        assert_eq!(p.ready_at(2.0, 1, &profile()), 2.01);
        assert_eq!(p.ready_at(2.0, 3, &profile()), 2.01);
        // Full batch: ready the moment the oldest arrived.
        assert_eq!(p.ready_at(2.0, 4, &profile()), 2.0);
        assert_eq!(p.ready_at(2.0, 9, &profile()), 2.0);
        assert_eq!(p.max_batch(), 4);
    }

    #[test]
    fn slo_aware_budget_shrinks_with_service_time() {
        let pr = profile(); // full cold batch of 8: 4e-4 + 8·6e-4 = 5.2 ms
        let tight = BatchPolicy::SloAware { slo_s: 6.0e-3, max_batch: 8 };
        let ready = tight.ready_at(0.0, 1, &pr);
        assert!((ready - 8.0e-4).abs() < 1e-12, "budget = {ready}");
        // An SLO the service time already exceeds leaves no wait budget.
        let hopeless = BatchPolicy::SloAware { slo_s: 1.0e-3, max_batch: 8 };
        assert_eq!(hopeless.ready_at(5.0, 1, &pr), 5.0);
        // A full batch dispatches immediately regardless of budget.
        assert_eq!(tight.ready_at(5.0, 8, &pr), 5.0);
    }

    #[test]
    fn validation_rejects_degenerate_policies() {
        assert!(BatchPolicy::MaxBatchOrWait { max_batch: 0, max_wait_s: 0.1 }
            .validate()
            .is_err());
        assert!(BatchPolicy::MaxBatchOrWait { max_batch: 4, max_wait_s: f64::NAN }
            .validate()
            .is_err());
        assert!(BatchPolicy::SloAware { slo_s: 0.0, max_batch: 4 }.validate().is_err());
        assert!(BatchPolicy::SloAware { slo_s: 1.0, max_batch: 0 }.validate().is_err());
        assert!(!BatchPolicy::Immediate.label().is_empty());
    }
}
