//! Request-stream generation for the serving simulator: seeded open-loop
//! arrival processes (Poisson, bursty/MMPP, diurnal ramp), closed-loop
//! client populations, and weighted multi-tenant mixes.
//!
//! Everything here is driven by the in-crate PCG generator
//! ([`crate::util::rng::Pcg64`]) seeded from the serve seed via
//! [`crate::util::rng::mix_seed`], so a `(spec, seed)` pair produces one
//! arrival stream, bit-identical on every run and platform.

use crate::gnn::models::ModelKind;
use crate::util::rng::Pcg64;

/// One tenant of the serving fleet: a `(model, dataset)` pair plus its
/// relative share of the request stream.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantProfile {
    pub model: ModelKind,
    /// Dataset name in any tier (resolved/canonicalized by the engine).
    pub dataset: String,
    /// Relative mixing weight (> 0); normalized across the mix.
    pub weight: f64,
}

impl TenantProfile {
    pub fn new(model: ModelKind, dataset: impl Into<String>, weight: f64) -> Self {
        Self { model, dataset: dataset.into(), weight }
    }

    /// Human-readable `model/dataset` tag used in reports.
    pub fn label(&self) -> String {
        format!("{}/{}", self.model.name(), self.dataset)
    }
}

/// A weighted set of tenants sharing one request stream.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantMix {
    tenants: Vec<TenantProfile>,
    /// Normalized cumulative weights; last entry is exactly 1.0.
    cum: Vec<f64>,
}

impl TenantMix {
    /// Builds a mix, validating that every weight is finite and positive.
    pub fn new(tenants: Vec<TenantProfile>) -> Result<Self, String> {
        if tenants.is_empty() {
            return Err("tenant mix must contain at least one tenant".into());
        }
        let mut total = 0.0f64;
        for t in &tenants {
            if !t.weight.is_finite() || t.weight <= 0.0 {
                return Err(format!(
                    "tenant {} has weight {}; weights must be finite and > 0",
                    t.label(),
                    t.weight
                ));
            }
            total += t.weight;
        }
        let mut cum = Vec::with_capacity(tenants.len());
        let mut acc = 0.0f64;
        for t in &tenants {
            acc += t.weight / total;
            cum.push(acc);
        }
        // Guard against accumulated rounding leaving the last bucket short.
        if let Some(last) = cum.last_mut() {
            *last = 1.0;
        }
        Ok(Self { tenants, cum })
    }

    pub fn tenants(&self) -> &[TenantProfile] {
        &self.tenants
    }

    pub fn len(&self) -> usize {
        self.tenants.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tenants.is_empty()
    }

    /// Samples a tenant index with probability proportional to its weight.
    pub fn sample(&self, rng: &mut Pcg64) -> usize {
        let u = rng.next_f64();
        self.cum.iter().position(|&c| u < c).unwrap_or(self.cum.len() - 1)
    }
}

/// Open-loop arrival process shape. All variants are calibrated so the
/// *long-run average* rate equals the configured requests/sec.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalProcess {
    /// Memoryless arrivals at a constant rate.
    Poisson,
    /// Two-state Markov-modulated Poisson process: calm periods at a base
    /// rate and bursts at `burst_factor ×` that rate, with exponentially
    /// distributed dwell times. The base rate is derived so the
    /// time-weighted average stays at the configured rps.
    Bursty {
        /// Burst-state rate multiplier (≥ 1).
        burst_factor: f64,
        /// Mean dwell time in the calm state, seconds (> 0).
        mean_calm_s: f64,
        /// Mean dwell time in the burst state, seconds (> 0).
        mean_burst_s: f64,
    },
    /// Sinusoidal rate ramp `rps · (1 + amplitude · sin(2πt / period))`
    /// (a compressed diurnal cycle), realized by thinning against the peak
    /// rate.
    Diurnal {
        /// Cycle length, seconds (> 0).
        period_s: f64,
        /// Relative swing in `[0, 1)`; the instantaneous rate stays > 0.
        amplitude: f64,
    },
}

impl ArrivalProcess {
    pub fn validate(&self) -> Result<(), String> {
        match *self {
            ArrivalProcess::Poisson => Ok(()),
            ArrivalProcess::Bursty { burst_factor, mean_calm_s, mean_burst_s } => {
                if !burst_factor.is_finite() || burst_factor < 1.0 {
                    return Err(format!("burst_factor {burst_factor} must be >= 1"));
                }
                if !mean_calm_s.is_finite()
                    || mean_calm_s <= 0.0
                    || !mean_burst_s.is_finite()
                    || mean_burst_s <= 0.0
                {
                    return Err(format!(
                        "bursty dwell times ({mean_calm_s}, {mean_burst_s}) must be > 0"
                    ));
                }
                Ok(())
            }
            ArrivalProcess::Diurnal { period_s, amplitude } => {
                if !period_s.is_finite() || period_s <= 0.0 {
                    return Err(format!("diurnal period {period_s} must be > 0"));
                }
                if !(0.0..1.0).contains(&amplitude) {
                    return Err(format!("diurnal amplitude {amplitude} must be in [0, 1)"));
                }
                Ok(())
            }
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ArrivalProcess::Poisson => "poisson",
            ArrivalProcess::Bursty { .. } => "bursty",
            ArrivalProcess::Diurnal { .. } => "diurnal",
        }
    }
}

/// How requests are offered to the fleet.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TrafficSpec {
    /// Open loop: arrivals at `rps` regardless of completions (the load
    /// does not back off when the fleet saturates — the regime that
    /// exposes tail latency).
    Open { process: ArrivalProcess, rps: f64 },
    /// Closed loop: `clients` clients, each holding at most one request in
    /// flight and thinking for an exponential `mean_think_s` between its
    /// response and its next request. Throughput self-limits to fleet
    /// capacity.
    Closed { clients: usize, mean_think_s: f64 },
}

impl TrafficSpec {
    pub fn validate(&self) -> Result<(), String> {
        match *self {
            TrafficSpec::Open { process, rps } => {
                process.validate()?;
                if !rps.is_finite() || rps <= 0.0 {
                    return Err(format!("rps {rps} must be finite and > 0"));
                }
                Ok(())
            }
            TrafficSpec::Closed { clients, mean_think_s } => {
                if clients == 0 {
                    return Err("closed-loop traffic needs at least one client".into());
                }
                if !mean_think_s.is_finite() || mean_think_s < 0.0 {
                    return Err(format!("mean think time {mean_think_s} must be >= 0"));
                }
                Ok(())
            }
        }
    }
}

/// Graph-mutation stream interleaved with the request stream: the
/// serving-under-churn mode. Mutation *events* arrive as a Poisson
/// process at `edges_per_s / batch` events/sec; each event applies one
/// [`crate::graph::mutate::GraphDelta`] batch of `batch` edge operations
/// to a tenant-sampled dataset, so the long-run average mutation rate is
/// `edges_per_s` regardless of the batch size.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ChurnSpec {
    /// Long-run average edge mutations per second across the fleet (> 0).
    pub edges_per_s: f64,
    /// Edge operations applied per mutation event (≥ 1). Larger batches
    /// amortize the incremental re-plan over more edges.
    pub batch: usize,
    /// Fraction of operations that add (vs. remove) an edge, in `[0, 1]`.
    pub add_fraction: f64,
    /// Fraction of operations that add a *vertex* instead of touching an
    /// edge, in `[0, 1]`. Vertex growth that crosses a `V` boundary
    /// changes the output-group count and forces a plan rebuild, so the
    /// default keeps this at 0 (pure edge churn — the patchable regime).
    pub vertex_fraction: f64,
}

impl ChurnSpec {
    /// Pure edge churn at `edges_per_s`, 8-op batches, 70% additions.
    pub fn new(edges_per_s: f64) -> Self {
        Self { edges_per_s, batch: 8, add_fraction: 0.7, vertex_fraction: 0.0 }
    }

    pub fn validate(&self) -> Result<(), String> {
        if !self.edges_per_s.is_finite() || self.edges_per_s <= 0.0 {
            return Err(format!(
                "churn rate {} edges/s must be finite and > 0",
                self.edges_per_s
            ));
        }
        if self.batch == 0 {
            return Err("churn batch must be >= 1 edge operation".into());
        }
        if !(0.0..=1.0).contains(&self.add_fraction) {
            return Err(format!(
                "churn add fraction {} must be in [0, 1]",
                self.add_fraction
            ));
        }
        if !(0.0..=1.0).contains(&self.vertex_fraction) {
            return Err(format!(
                "churn vertex fraction {} must be in [0, 1]",
                self.vertex_fraction
            ));
        }
        Ok(())
    }

    /// Mutation events per second (`edges_per_s / batch`).
    pub fn events_per_s(&self) -> f64 {
        self.edges_per_s / self.batch as f64
    }
}

/// Exponential sample with the given rate (inverse-CDF over the PCG
/// stream). `u ∈ [0, 1)` keeps `1 - u ∈ (0, 1]`, so the log never blows
/// up.
pub(crate) fn exp_sample(rng: &mut Pcg64, rate: f64) -> f64 {
    -(1.0 - rng.next_f64()).ln() / rate
}

/// Lazy open-loop arrival generator: yields strictly increasing absolute
/// arrival times, one per call, so million-request streams never
/// materialize in memory.
#[derive(Debug, Clone)]
pub struct OpenLoopArrivals {
    process: ArrivalProcess,
    rps: f64,
    /// Bursty only: the calm-state rate that keeps the long-run average at
    /// `rps` given the dwell-time split.
    calm_rps: f64,
    rng: Pcg64,
    t: f64,
    in_burst: bool,
    next_switch: f64,
}

impl OpenLoopArrivals {
    pub fn new(process: ArrivalProcess, rps: f64, seed: u64) -> Result<Self, String> {
        TrafficSpec::Open { process, rps }.validate()?;
        let mut rng = Pcg64::seed_from_u64(seed);
        let (calm_rps, next_switch) = match process {
            ArrivalProcess::Bursty { burst_factor, mean_calm_s, mean_burst_s } => {
                // Solve rps = (calm·mc + calm·bf·mb) / (mc + mb) for calm.
                let weighted = mean_calm_s + burst_factor * mean_burst_s;
                let calm = rps * (mean_calm_s + mean_burst_s) / weighted;
                let first_switch = exp_sample(&mut rng, 1.0 / mean_calm_s);
                (calm, first_switch)
            }
            _ => (rps, f64::INFINITY),
        };
        Ok(Self { process, rps, calm_rps, rng, t: 0.0, in_burst: false, next_switch })
    }

    /// Absolute time of the next arrival.
    pub fn next_arrival(&mut self) -> f64 {
        match self.process {
            ArrivalProcess::Poisson => {
                self.t += exp_sample(&mut self.rng, self.rps);
                self.t
            }
            ArrivalProcess::Bursty { burst_factor, mean_calm_s, mean_burst_s } => loop {
                let rate =
                    if self.in_burst { self.calm_rps * burst_factor } else { self.calm_rps };
                let gap = exp_sample(&mut self.rng, rate);
                if self.t + gap <= self.next_switch {
                    self.t += gap;
                    return self.t;
                }
                // Competing exponentials: the state switch preempts the
                // candidate arrival; the memoryless property lets us
                // resample from the switch instant.
                self.t = self.next_switch;
                self.in_burst = !self.in_burst;
                let dwell = if self.in_burst { mean_burst_s } else { mean_calm_s };
                self.next_switch = self.t + exp_sample(&mut self.rng, 1.0 / dwell);
            },
            ArrivalProcess::Diurnal { period_s, amplitude } => {
                // Thinning (Lewis–Shedler): propose at the peak rate,
                // accept with probability rate(t) / peak.
                let peak = self.rps * (1.0 + amplitude);
                loop {
                    self.t += exp_sample(&mut self.rng, peak);
                    let phase = 2.0 * std::f64::consts::PI * self.t / period_s;
                    let rate = self.rps * (1.0 + amplitude * phase.sin());
                    if self.rng.next_f64() * peak < rate {
                        return self.t;
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drain(src: &mut OpenLoopArrivals, horizon_s: f64) -> Vec<f64> {
        let mut out = Vec::new();
        loop {
            let t = src.next_arrival();
            if t > horizon_s {
                return out;
            }
            out.push(t);
        }
    }

    #[test]
    fn poisson_hits_the_configured_rate() {
        let mut g = OpenLoopArrivals::new(ArrivalProcess::Poisson, 1000.0, 42).unwrap();
        let arrivals = drain(&mut g, 20.0);
        let rate = arrivals.len() as f64 / 20.0;
        assert!((rate - 1000.0).abs() < 50.0, "measured rate {rate}");
    }

    #[test]
    fn bursty_long_run_rate_matches_and_bursts_exist() {
        let p = ArrivalProcess::Bursty {
            burst_factor: 5.0,
            mean_calm_s: 0.5,
            mean_burst_s: 0.1,
        };
        let mut g = OpenLoopArrivals::new(p, 1000.0, 7).unwrap();
        let arrivals = drain(&mut g, 60.0);
        let rate = arrivals.len() as f64 / 60.0;
        assert!((rate - 1000.0).abs() < 150.0, "measured rate {rate}");
        // Burstiness: the arrival-count variance across 100 ms windows must
        // exceed a Poisson stream's (index of dispersion >> 1).
        let mut counts = vec![0u32; 600];
        for &t in &arrivals {
            let w = ((t / 0.1) as usize).min(599);
            counts[w] += 1;
        }
        let n = counts.len() as f64;
        let mean = counts.iter().map(|&c| c as f64).sum::<f64>() / n;
        let var =
            counts.iter().map(|&c| (c as f64 - mean) * (c as f64 - mean)).sum::<f64>() / n;
        assert!(var / mean > 2.0, "dispersion {} not bursty", var / mean);
    }

    #[test]
    fn diurnal_rate_ramps_with_phase() {
        let p = ArrivalProcess::Diurnal { period_s: 10.0, amplitude: 0.9 };
        let mut g = OpenLoopArrivals::new(p, 2000.0, 11).unwrap();
        let arrivals = drain(&mut g, 10.0);
        let rate = arrivals.len() as f64 / 10.0;
        assert!((rate - 2000.0).abs() < 200.0, "measured rate {rate}");
        // First half-period (sin > 0) must carry more than the second.
        let first = arrivals.iter().filter(|&&t| t < 5.0).count();
        let second = arrivals.len() - first;
        assert!(
            first as f64 > second as f64 * 1.5,
            "ramp missing: {first} vs {second}"
        );
    }

    #[test]
    fn arrivals_strictly_ordered_and_deterministic() {
        for p in [
            ArrivalProcess::Poisson,
            ArrivalProcess::Bursty { burst_factor: 3.0, mean_calm_s: 0.2, mean_burst_s: 0.05 },
            ArrivalProcess::Diurnal { period_s: 1.0, amplitude: 0.5 },
        ] {
            let mut a = OpenLoopArrivals::new(p, 500.0, 99).unwrap();
            let mut b = OpenLoopArrivals::new(p, 500.0, 99).unwrap();
            let mut prev = 0.0;
            for _ in 0..2000 {
                let ta = a.next_arrival();
                assert_eq!(ta, b.next_arrival(), "{p:?} not deterministic");
                assert!(ta >= prev, "{p:?} went backwards");
                prev = ta;
            }
        }
    }

    #[test]
    fn mix_sampling_tracks_weights() {
        let mix = TenantMix::new(vec![
            TenantProfile::new(ModelKind::Gcn, "Cora", 3.0),
            TenantProfile::new(ModelKind::Gat, "Citeseer", 1.0),
        ])
        .unwrap();
        let mut rng = Pcg64::seed_from_u64(5);
        let mut counts = [0usize; 2];
        for _ in 0..10_000 {
            counts[mix.sample(&mut rng)] += 1;
        }
        let share = counts[0] as f64 / 10_000.0;
        assert!((share - 0.75).abs() < 0.02, "share {share}");
        assert_eq!(mix.len(), 2);
        assert!(!mix.is_empty());
    }

    #[test]
    fn invalid_specs_rejected() {
        assert!(TenantMix::new(vec![]).is_err());
        assert!(TenantMix::new(vec![TenantProfile::new(ModelKind::Gcn, "Cora", 0.0)]).is_err());
        assert!(
            TenantMix::new(vec![TenantProfile::new(ModelKind::Gcn, "Cora", f64::NAN)]).is_err()
        );
        assert!(OpenLoopArrivals::new(ArrivalProcess::Poisson, 0.0, 1).is_err());
        assert!(ArrivalProcess::Bursty {
            burst_factor: 0.5,
            mean_calm_s: 1.0,
            mean_burst_s: 1.0
        }
        .validate()
        .is_err());
        assert!(ArrivalProcess::Diurnal { period_s: 1.0, amplitude: 1.0 }.validate().is_err());
        assert!(TrafficSpec::Closed { clients: 0, mean_think_s: 0.1 }.validate().is_err());
        assert!(TrafficSpec::Closed { clients: 4, mean_think_s: -1.0 }.validate().is_err());
    }

    #[test]
    fn churn_spec_validates_each_field() {
        let base = ChurnSpec::new(1000.0);
        base.validate().unwrap();
        assert!((base.events_per_s() - 125.0).abs() < 1e-12);
        assert!(ChurnSpec { edges_per_s: 0.0, ..base }.validate().is_err());
        assert!(ChurnSpec { edges_per_s: f64::INFINITY, ..base }.validate().is_err());
        assert!(ChurnSpec { batch: 0, ..base }.validate().is_err());
        assert!(ChurnSpec { add_fraction: 1.5, ..base }.validate().is_err());
        assert!(ChurnSpec { vertex_fraction: -0.1, ..base }.validate().is_err());
    }
}
