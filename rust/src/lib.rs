//! # GHOST — a silicon-photonic GNN inference accelerator
//!
//! Reproduction of *GHOST: A Graph Neural Network Accelerator using Silicon
//! Photonics* (Afifi et al., 2023). The crate contains:
//!
//! * [`photonics`] — device and circuit models: microring resonators (MRs),
//!   VCSELs, photodetectors, SOAs, hybrid EO/TO tuning with TED
//!   thermal-crosstalk cancellation, heterodyne/homodyne crosstalk noise,
//!   SNR feasibility (paper eqs. 2–13), and the device-level design-space
//!   exploration behind Figs. 7(a)/7(b).
//! * [`memory`] — HBM2 main-memory and ECU SRAM-buffer models.
//! * [`graph`] — CSR graphs, the flat-blocks V×N partition matrix ("buffer
//!   & partition", built in parallel), and the seeded synthetic dataset
//!   generators: the Table-2 tier plus the million-edge large-graph tier
//!   (`ogbn-arxiv-syn`, `reddit-syn`, parameterized `rmat-...` specs).
//! * [`gnn`] — GNN model descriptors (GCN / GraphSAGE / GIN / GAT) and the
//!   workload characterization (MACs / bytes / stage ops) that drives both
//!   the GHOST simulator and the baseline roofline models.
//! * [`arch`] — the three photonic pipeline blocks (aggregate / combine /
//!   update) and the electronic control unit (ECU).
//! * [`sim`] — the pipeline-stage latency/energy simulator.
//! * [`coordinator`] — the L3 contribution: partition scheduling, two-level
//!   pipelining (GCN-family and GAT orderings), weight-DAC sharing, and
//!   workload balancing; the cached, parallel
//!   [`coordinator::engine::BatchEngine`] every sweep runs through; plus
//!   the architectural DSE of Fig. 7(c).
//! * [`baselines`] — analytic roofline models of the nine comparison
//!   platforms (GRIP, HyGCN, EnGN, HW_ACC, ReGNN, ReGraphX, TPU, CPU, GPU).
//! * [`energy`] — EPB / GOPS / EPB-per-GOPS accounting shared by all models.
//! * [`serve`] — the online-serving subsystem: a deterministic
//!   discrete-event simulator replaying open/closed-loop request streams
//!   (Poisson, bursty, diurnal; multi-tenant mixes) against an
//!   N-accelerator fleet with dynamic micro-batching and
//!   routing policies, reporting exact tail-latency percentiles — the
//!   "what p99 does a 4-chip fleet hold at 50k rps" axis the offline
//!   figures cannot answer.
//! * [`runtime`] — the PJRT functional datapath (execution requires the
//!   off-by-default `pjrt` cargo feature): loads `artifacts/*.hlo.txt`
//!   lowered from the JAX/Pallas model (build-time Python) and executes real
//!   GNN inference from Rust.
//! * [`figures`] — regenerates every table and figure in the paper's
//!   evaluation section.
//!
//! See `DESIGN.md` for the system inventory and `EXPERIMENTS.md` for
//! paper-vs-measured results.

pub mod arch;
pub mod baselines;
pub mod config;
pub mod coordinator;
pub mod energy;
pub mod figures;
pub mod gnn;
pub mod graph;
pub mod memory;
pub mod photonics;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod util;

pub use config::GhostConfig;
