//! Graph data structures and workloads.
//!
//! * [`csr`] — compressed-sparse-row graphs (the in-memory form the ECU
//!   streams from HBM).
//! * [`datasets`] — seeded synthetic generators matched to the Table-2
//!   statistics of the eight evaluation datasets (documented substitution
//!   for the real downloads; every simulator result depends on the graphs
//!   only through the size/sparsity/degree statistics matched here).
//! * [`partition`] — the V×N "buffer & partition" matrix (§3.4.1) with
//!   all-zero-block skipping and offline prefetch ordering.
//! * [`mutate`] — typed graph-mutation batches ([`mutate::GraphDelta`])
//!   applied incrementally: CSR row splicing plus
//!   [`PartitionMatrix::splice`] group re-derivation, validated
//!   byte-identical against from-scratch rebuilds.

pub mod csr;
pub mod datasets;
pub mod mutate;
pub mod partition;

pub use csr::CsrGraph;
pub use datasets::{Dataset, DatasetSpec};
pub use mutate::{AppliedDelta, GraphDelta, MutateError};
pub use partition::{PartitionMatrix, ShardPlan};
