//! Typed graph mutations applied incrementally — the dynamic-graph layer.
//!
//! Production recommendation/social workloads mutate constantly; rebuilding
//! the CSR and the `V×N` partition from scratch per mutation wastes orders
//! of magnitude of work when ≤1 % of edges changed. A [`GraphDelta`] batch
//! is applied at two levels here:
//!
//! 1. **CSR splicing** — [`apply_batch`] validates the batch against the
//!    running graph state and rebuilds only the destination rows whose
//!    in-edge multiset changed, copying every other row verbatim. Because a
//!    CSR built by [`CsrGraph::from_edges`] depends only on the edge
//!    multiset (rows fully sorted), the spliced graph is byte-identical to
//!    a from-scratch build of the mutated edge list.
//! 2. **Partition splicing** — [`apply_to_dataset`] forwards the touched
//!    destination groups to [`PartitionMatrix::splice`], which re-derives
//!    only those output groups and bumps the dataset's mutation
//!    [`Dataset::epoch`] so every epoch-keyed cache upstream invalidates.
//!
//! The third level — plan maintenance — lives in
//! [`crate::coordinator::soa::GraphDeltaPlan`], which re-costs only the SoA
//! lane positions owned by changed groups.
//!
//! **Oracle:** with `GHOST_CHURN_CHECK=1` (or always in debug builds, via
//! [`churn_check_enabled`]) every splice is asserted byte-identical to a
//! full [`PartitionMatrix::build_serial`] rebuild — the same
//! belt-and-suspenders pattern as `GHOST_DSE_CHECK` on the DSE delta path.

use std::collections::HashMap;
use std::fmt;

use super::csr::CsrGraph;
use super::datasets::Dataset;
use super::partition::PartitionMatrix;
use crate::util::rng::Pcg64;

/// One graph mutation. Batches are ordered: an edge may reference a vertex
/// added earlier in the same batch, and a removal may cancel an earlier
/// addition.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GraphDelta {
    /// Appends one vertex (index = current vertex count) with no edges.
    AddVertex,
    /// Inserts a directed edge `src → dst` (duplicates allowed, matching
    /// [`CsrGraph::from_edges`] multigraph semantics).
    AddEdge { src: u32, dst: u32 },
    /// Removes one copy of the directed edge `src → dst`.
    RemoveEdge { src: u32, dst: u32 },
}

/// Why a [`GraphDelta`] batch was rejected. Validation is transactional:
/// a rejected batch leaves the graph, partition, and epoch untouched.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum MutateError {
    /// Op `index` referenced a vertex at or beyond the running count.
    VertexOutOfRange { index: usize, vertex: u32, n_vertices: usize },
    /// Op `index` removed an edge with no remaining multiplicity.
    MissingEdge { index: usize, src: u32, dst: u32 },
    /// The graph index was out of range for the dataset.
    GraphOutOfRange { graph: usize, n_graphs: usize },
    /// The partition slice does not pair 1:1 with the dataset's graphs.
    PartitionMismatch { graphs: usize, partitions: usize },
}

impl fmt::Display for MutateError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            MutateError::VertexOutOfRange { index, vertex, n_vertices } => write!(
                f,
                "mutation {index} references vertex {vertex} of a {n_vertices}-vertex graph"
            ),
            MutateError::MissingEdge { index, src, dst } => write!(
                f,
                "mutation {index} removes edge {src} -> {dst}, which has no remaining copy"
            ),
            MutateError::GraphOutOfRange { graph, n_graphs } => {
                write!(f, "graph index {graph} out of range for a {n_graphs}-graph dataset")
            }
            MutateError::PartitionMismatch { graphs, partitions } => write!(
                f,
                "{partitions} partition matrices supplied for a {graphs}-graph dataset"
            ),
        }
    }
}

impl std::error::Error for MutateError {}

/// Outcome of [`apply_batch`]: the mutated CSR plus what changed.
#[derive(Debug, Clone)]
pub struct CsrPatch {
    pub graph: CsrGraph,
    /// Destination vertices whose in-edge rows changed, sorted ascending.
    pub touched_dsts: Vec<u32>,
    pub edges_added: usize,
    pub edges_removed: usize,
    pub vertices_added: usize,
}

/// Summary of one batch applied through [`apply_to_dataset`] — everything
/// plan maintenance needs to patch incrementally.
#[derive(Debug, Clone)]
pub struct AppliedDelta {
    /// Index of the mutated graph within the dataset.
    pub graph: usize,
    pub old_n_vertices: usize,
    pub new_n_vertices: usize,
    pub old_n_edges: usize,
    pub new_n_edges: usize,
    pub edges_added: usize,
    pub edges_removed: usize,
    pub vertices_added: usize,
    pub old_n_groups: usize,
    pub new_n_groups: usize,
    /// Output groups (new group space, sorted, deduplicated) whose
    /// [`crate::graph::partition::OutputGroupPlan`] may differ from before:
    /// groups owning a touched destination row, the boundary group whose
    /// vertex range grew, and every newly created group.
    pub changed_groups: Vec<u32>,
}

fn check_endpoint(index: usize, vertex: u32, n_vertices: usize) -> Result<(), MutateError> {
    if (vertex as usize) < n_vertices {
        Ok(())
    } else {
        Err(MutateError::VertexOutOfRange { index, vertex, n_vertices })
    }
}

/// Copies of `src` in the (sorted) in-edge row of `dst`.
fn original_multiplicity(graph: &CsrGraph, src: u32, dst: u32) -> usize {
    if dst as usize >= graph.n_vertices {
        return 0;
    }
    let row = graph.neighbors(dst as usize);
    row.partition_point(|&s| s <= src) - row.partition_point(|&s| s < src)
}

/// Validates and applies one mutation batch against `graph`, splicing only
/// the destination rows whose in-edge multiset changed. The result is
/// byte-identical to [`CsrGraph::from_edges`] over the mutated edge list
/// (row content depends only on the edge multiset; both keep rows fully
/// sorted). Runs in `O(E_copy + touched rows · row cost)` — the bulk copy
/// of untouched rows is a straight `memcpy`.
pub fn apply_batch(graph: &CsrGraph, batch: &[GraphDelta]) -> Result<CsrPatch, MutateError> {
    let mut n_vertices = graph.n_vertices;
    // Net multiplicity change per (src, dst), order-validated as we go.
    let mut net: HashMap<(u32, u32), i64> = HashMap::new();
    let mut edges_added = 0usize;
    let mut edges_removed = 0usize;
    let mut vertices_added = 0usize;
    for (index, &op) in batch.iter().enumerate() {
        match op {
            GraphDelta::AddVertex => {
                n_vertices += 1;
                vertices_added += 1;
            }
            GraphDelta::AddEdge { src, dst } => {
                check_endpoint(index, src, n_vertices)?;
                check_endpoint(index, dst, n_vertices)?;
                *net.entry((src, dst)).or_insert(0) += 1;
                edges_added += 1;
            }
            GraphDelta::RemoveEdge { src, dst } => {
                check_endpoint(index, src, n_vertices)?;
                check_endpoint(index, dst, n_vertices)?;
                let have = original_multiplicity(graph, src, dst) as i64
                    + net.get(&(src, dst)).copied().unwrap_or(0);
                if have <= 0 {
                    return Err(MutateError::MissingEdge { index, src, dst });
                }
                *net.entry((src, dst)).or_insert(0) -= 1;
                edges_removed += 1;
            }
        }
    }
    // Group the surviving net changes by destination row.
    let mut row_net: HashMap<u32, Vec<(u32, i64)>> = HashMap::new();
    for (&(src, dst), &n) in &net {
        if n != 0 {
            row_net.entry(dst).or_default().push((src, n));
        }
    }
    let mut touched_dsts: Vec<u32> = row_net.keys().copied().collect();
    touched_dsts.sort_unstable();

    let mut row_ptr = Vec::with_capacity(n_vertices + 1);
    row_ptr.push(0u32);
    let cap = (graph.n_edges() + edges_added).saturating_sub(edges_removed);
    let mut col_idx: Vec<u32> = Vec::with_capacity(cap);
    let mut row_buf: Vec<u32> = Vec::new();
    for dst in 0..n_vertices {
        let old_row: &[u32] =
            if dst < graph.n_vertices { graph.neighbors(dst) } else { &[] };
        match row_net.get(&(dst as u32)) {
            None => col_idx.extend_from_slice(old_row),
            Some(changes) => {
                row_buf.clear();
                row_buf.extend_from_slice(old_row);
                // Per-row edits commute (multiset adds/removes), so the
                // HashMap's iteration order cannot leak into the result.
                for &(src, n) in changes {
                    if n > 0 {
                        row_buf.extend(std::iter::repeat(src).take(n as usize));
                    } else {
                        let mut left = (-n) as usize;
                        row_buf.retain(|&s| {
                            if s == src && left > 0 {
                                left -= 1;
                                false
                            } else {
                                true
                            }
                        });
                        debug_assert_eq!(left, 0, "validated removal missing from row");
                    }
                }
                row_buf.sort_unstable();
                col_idx.extend_from_slice(&row_buf);
            }
        }
        row_ptr.push(col_idx.len() as u32);
    }
    Ok(CsrPatch {
        graph: CsrGraph { row_ptr, col_idx, n_vertices },
        touched_dsts,
        edges_added,
        edges_removed,
        vertices_added,
    })
}

/// Applies one batch to graph `graph` of a dataset *and* its paired
/// partition matrix: CSR rows are spliced, the partition re-derives only
/// the changed output groups, and the dataset's mutation epoch is bumped
/// (so epoch-keyed caches upstream can never serve the old topology).
/// Under [`churn_check_enabled`] the spliced partition is asserted
/// byte-identical to a from-scratch [`PartitionMatrix::build_serial`].
///
/// Errors leave the dataset, partitions, and epoch untouched.
pub fn apply_to_dataset(
    dataset: &mut Dataset,
    partitions: &mut [PartitionMatrix],
    graph: usize,
    batch: &[GraphDelta],
) -> Result<AppliedDelta, MutateError> {
    let _span = crate::util::telemetry::span("mutate.apply_to_dataset");
    if graph >= dataset.graphs.len() {
        return Err(MutateError::GraphOutOfRange { graph, n_graphs: dataset.graphs.len() });
    }
    if partitions.len() != dataset.graphs.len() {
        return Err(MutateError::PartitionMismatch {
            graphs: dataset.graphs.len(),
            partitions: partitions.len(),
        });
    }
    let old = &dataset.graphs[graph];
    let (old_n_vertices, old_n_edges) = (old.n_vertices, old.n_edges());
    let patch = apply_batch(old, batch)?;
    let pm = &mut partitions[graph];
    let old_n_groups = pm.n_output_groups();
    let new_n_groups = patch.graph.n_vertices.div_ceil(pm.v).max(1);
    let mut changed_groups: Vec<u32> =
        patch.touched_dsts.iter().map(|&d| d as usize / pm.v).map(|g| g as u32).collect();
    if patch.vertices_added > 0 {
        // Vertex growth re-shapes the old boundary group and creates the
        // new trailing groups.
        for og in (old_n_vertices / pm.v)..new_n_groups {
            changed_groups.push(og as u32);
        }
    }
    changed_groups.sort_unstable();
    changed_groups.dedup();
    pm.splice(&patch.graph, &changed_groups);
    if churn_check_enabled() {
        let reference = PartitionMatrix::build_serial(&patch.graph, pm.v, pm.n);
        assert_eq!(
            *pm, reference,
            "spliced partition diverged from a full rebuild (graph {graph})"
        );
    }
    let new_n_vertices = patch.graph.n_vertices;
    let new_n_edges = patch.graph.n_edges();
    dataset.graphs[graph] = patch.graph;
    dataset.epoch += 1;
    Ok(AppliedDelta {
        graph,
        old_n_vertices,
        new_n_vertices,
        old_n_edges,
        new_n_edges,
        edges_added: patch.edges_added,
        edges_removed: patch.edges_removed,
        vertices_added: patch.vertices_added,
        old_n_groups,
        new_n_groups,
        changed_groups,
    })
}

/// Generates a valid random mutation batch against `graph`: `n_ops`
/// operations, a `vertex_fraction` share of vertex additions, an
/// `add_fraction` share of edge additions, and removals for the rest.
/// Removals sample *distinct* original edge slots (two slots holding the
/// same duplicate pair are still distinct copies), so the batch always
/// validates against the base graph regardless of operation order.
pub fn random_batch(
    graph: &CsrGraph,
    n_ops: usize,
    add_fraction: f64,
    vertex_fraction: f64,
    rng: &mut Pcg64,
) -> Vec<GraphDelta> {
    let mut batch = Vec::with_capacity(n_ops);
    let mut n_vertices = graph.n_vertices.max(1);
    let mut removed_slots = std::collections::HashSet::new();
    for _ in 0..n_ops {
        let u = rng.next_f64();
        if u < vertex_fraction {
            batch.push(GraphDelta::AddVertex);
            n_vertices += 1;
            continue;
        }
        let want_remove = u >= vertex_fraction + add_fraction
            && removed_slots.len() < graph.n_edges();
        if want_remove {
            // Rejection-sample an original edge slot not yet removed; a
            // bounded retry keeps the generator O(n_ops) even when most
            // slots are gone.
            let mut slot = rng.gen_range(0, graph.n_edges());
            let mut tries = 0;
            while removed_slots.contains(&slot) && tries < 64 {
                slot = rng.gen_range(0, graph.n_edges());
                tries += 1;
            }
            if !removed_slots.contains(&slot) {
                removed_slots.insert(slot);
                let (src, dst) = graph.edge_endpoints(slot);
                batch.push(GraphDelta::RemoveEdge { src, dst });
                continue;
            }
        }
        let src = rng.gen_range(0, n_vertices) as u32;
        let dst = rng.gen_range(0, n_vertices) as u32;
        batch.push(GraphDelta::AddEdge { src, dst });
    }
    batch
}

/// Whether the churn oracle runs: always in debug builds, and in release
/// when `GHOST_CHURN_CHECK` is `1`/`on`/`true` — the graph-mutation twin
/// of the DSE delta path's `GHOST_DSE_CHECK`.
pub fn churn_check_enabled() -> bool {
    if cfg!(debug_assertions) {
        return true;
    }
    matches!(
        std::env::var("GHOST_CHURN_CHECK").ok().as_deref(),
        Some("1") | Some("on") | Some("true")
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::mix_seed;

    fn base() -> CsrGraph {
        // 5 vertices, multigraph (duplicate 0→1), hub at 2.
        CsrGraph::from_edges(
            5,
            &[(0, 1), (0, 1), (3, 1), (0, 2), (1, 2), (3, 2), (4, 2), (2, 0)],
        )
    }

    /// Replays a batch naively over an edge list, then from_edges.
    fn reference_apply(graph: &CsrGraph, batch: &[GraphDelta]) -> CsrGraph {
        let mut n = graph.n_vertices;
        let mut edges: Vec<(u32, u32)> =
            (0..graph.n_edges()).map(|e| graph.edge_endpoints(e)).collect();
        for &op in batch {
            match op {
                GraphDelta::AddVertex => n += 1,
                GraphDelta::AddEdge { src, dst } => edges.push((src, dst)),
                GraphDelta::RemoveEdge { src, dst } => {
                    let at = edges.iter().position(|&e| e == (src, dst)).expect("edge exists");
                    edges.swap_remove(at);
                }
            }
        }
        CsrGraph::from_edges(n, &edges)
    }

    #[test]
    fn batch_apply_matches_from_edges_reference() {
        let g = base();
        let batch = vec![
            GraphDelta::AddEdge { src: 4, dst: 0 },
            GraphDelta::RemoveEdge { src: 0, dst: 1 },
            GraphDelta::AddVertex,
            GraphDelta::AddEdge { src: 5, dst: 2 },
            GraphDelta::AddEdge { src: 2, dst: 5 },
            GraphDelta::RemoveEdge { src: 0, dst: 1 }, // second copy
        ];
        let patch = apply_batch(&g, &batch).unwrap();
        assert_eq!(patch.graph, reference_apply(&g, &batch));
        assert_eq!(patch.edges_added, 3);
        assert_eq!(patch.edges_removed, 2);
        assert_eq!(patch.vertices_added, 1);
        assert_eq!(patch.touched_dsts, vec![0, 1, 2, 5]);
    }

    #[test]
    fn cancelling_ops_touch_nothing() {
        let g = base();
        let batch = vec![
            GraphDelta::AddEdge { src: 4, dst: 3 },
            GraphDelta::RemoveEdge { src: 4, dst: 3 },
        ];
        let patch = apply_batch(&g, &batch).unwrap();
        assert_eq!(patch.graph, g);
        assert!(patch.touched_dsts.is_empty());
    }

    #[test]
    fn removal_of_batch_added_edge_is_valid() {
        let g = CsrGraph::from_edges(2, &[]);
        let batch = vec![
            GraphDelta::AddEdge { src: 0, dst: 1 },
            GraphDelta::RemoveEdge { src: 0, dst: 1 },
            GraphDelta::RemoveEdge { src: 0, dst: 1 },
        ];
        assert_eq!(
            apply_batch(&g, &batch),
            Err(MutateError::MissingEdge { index: 2, src: 0, dst: 1 })
        );
    }

    #[test]
    fn endpoint_validation_tracks_running_vertex_count() {
        let g = base();
        assert_eq!(
            apply_batch(&g, &[GraphDelta::AddEdge { src: 5, dst: 0 }]),
            Err(MutateError::VertexOutOfRange { index: 0, vertex: 5, n_vertices: 5 })
        );
        // Legal once a vertex lands first.
        let ok = vec![GraphDelta::AddVertex, GraphDelta::AddEdge { src: 5, dst: 0 }];
        assert!(apply_batch(&g, &ok).is_ok());
        // Removing more copies than exist fails at the right index.
        let over = vec![
            GraphDelta::RemoveEdge { src: 0, dst: 1 },
            GraphDelta::RemoveEdge { src: 0, dst: 1 },
            GraphDelta::RemoveEdge { src: 0, dst: 1 },
        ];
        assert_eq!(
            apply_batch(&g, &over),
            Err(MutateError::MissingEdge { index: 2, src: 0, dst: 1 })
        );
    }

    #[test]
    fn random_batches_always_validate_and_match_reference() {
        let d = Dataset::by_name("rmat-600v-4000e-16f").unwrap();
        let g = &d.graphs[0];
        for seed in 0..12u64 {
            let mut rng = Pcg64::seed_from_u64(mix_seed(99, seed));
            let batch = random_batch(g, 200, 0.55, 0.05, &mut rng);
            let patch = apply_batch(g, &batch)
                .unwrap_or_else(|e| panic!("seed {seed}: batch must validate: {e}"));
            assert_eq!(patch.graph, reference_apply(g, &batch), "seed {seed}");
        }
    }

    #[test]
    fn apply_to_dataset_splices_partition_and_bumps_epoch() {
        let mut d = Dataset::by_name("Cora").unwrap();
        let mut parts =
            vec![PartitionMatrix::build_serial(&d.graphs[0], 20, 20)];
        let mut rng = Pcg64::seed_from_u64(41);
        let batch = random_batch(&d.graphs[0], 300, 0.5, 0.02, &mut rng);
        let applied = apply_to_dataset(&mut d, &mut parts, 0, &batch).unwrap();
        assert_eq!(d.epoch, 1);
        assert_eq!(applied.new_n_edges, d.graphs[0].n_edges());
        assert_eq!(applied.new_n_groups, parts[0].n_output_groups());
        // The splice oracle inside apply_to_dataset already asserted
        // byte-identity (debug build); pin it independently here too.
        assert_eq!(parts[0], PartitionMatrix::build_serial(&d.graphs[0], 20, 20));
        // A second batch stacks on the mutated state.
        let batch2 = random_batch(&d.graphs[0], 100, 0.3, 0.0, &mut rng);
        apply_to_dataset(&mut d, &mut parts, 0, &batch2).unwrap();
        assert_eq!(d.epoch, 2);
        assert_eq!(parts[0], PartitionMatrix::build_serial(&d.graphs[0], 20, 20));
    }

    #[test]
    fn apply_to_dataset_rejects_bad_indices_untouched() {
        let mut d = Dataset::by_name("Cora").unwrap();
        let mut parts =
            vec![PartitionMatrix::build_serial(&d.graphs[0], 20, 20)];
        let err = apply_to_dataset(&mut d, &mut parts, 1, &[GraphDelta::AddVertex]);
        assert_eq!(err, Err(MutateError::GraphOutOfRange { graph: 1, n_graphs: 1 }));
        let err = apply_to_dataset(&mut d, &mut [], 0, &[GraphDelta::AddVertex]);
        assert_eq!(err, Err(MutateError::PartitionMismatch { graphs: 1, partitions: 0 }));
        assert_eq!(d.epoch, 0, "failed batches must not bump the epoch");
    }
}
