//! Synthetic evaluation datasets: the Table-2 tier plus a large-graph tier.
//!
//! We cannot ship Cora/PubMed/Citeseer/Amazon/Proteins/Mutag/BZR/IMDB-binary
//! downloads, so each dataset is generated synthetically with the exact
//! Table-2 statistics — node count, edge count, feature dimensionality,
//! label count, graph count — and a skewed (Zipf-like) in-degree
//! distribution matching the irregularity the paper's optimizations target.
//! Every simulator result depends on the graphs only through these
//! statistics. Generation is fully deterministic: graph `i` of a dataset is
//! seeded with [`mix_seed`]`(spec.seed, i)`, so multi-graph corpora generate
//! in parallel ([`crate::util::parallel::par_map`]) with bit-identical
//! output for any worker count. `python/compile/datasets.py` regenerates
//! the *functional-path* datasets (features + labels + topology) with its
//! own seeded generator and exports them to `artifacts/` for the PJRT
//! datapath.
//!
//! ## The large-graph tier
//!
//! The paper's evaluation stops at Table-2 scale (≤238k edges); real GNN
//! deployments are dominated by ogbn/Reddit-class graphs with millions of
//! edges. [`LARGE_DATASETS`] adds named specs with those shapes
//! (`ogbn-arxiv-syn`, `reddit-syn`), generated with an R-MAT recursive
//! quadrant sampler ([`generate_rmat_graph`]) instead of the Zipf sampler.
//! Any other scale can be requested by a **parameterized name**:
//!
//! ```text
//! rmat-<V>v-<E>e[-<F>f][-<L>l][-<G>g][-<S>s]
//! ```
//!
//! e.g. `rmat-200000v-1300000e` (defaults: 128 features, 16 labels, one
//! graph, derived seed). [`spec_by_name`] parses these into interned
//! [`DatasetSpec`]s whose canonical names make them cacheable by the
//! [`crate::coordinator::engine::BatchEngine`] exactly like Table-2 names.

use std::collections::HashSet;
use std::sync::{Mutex, OnceLock};

use crate::util::parallel::par_map;
use crate::util::rng::{mix_seed, Pcg64};

use super::csr::CsrGraph;

/// Which GNN task a dataset serves (Table 2 / §4.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Task {
    /// Node classification (Cora, PubMed, Citeseer, Amazon).
    NodeClassification,
    /// Graph classification (Proteins, Mutag, BZR, IMDB-binary).
    GraphClassification,
}

/// Which synthetic topology generator realizes a spec.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum GraphGen {
    /// Zipf-skewed in-degree rejection sampler (the Table-2 tier).
    Skewed,
    /// R-MAT recursive quadrant descent (the large-graph tier): power-law
    /// degrees *and* community block structure, the standard generator for
    /// graph benchmarks at scale (Graph500).
    RMat,
}

/// Static description of a dataset — the Table-2 row (or its large-tier
/// analog).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DatasetSpec {
    pub name: &'static str,
    /// (Average) node count per graph.
    pub avg_nodes: usize,
    /// (Average) edge count per graph.
    pub avg_edges: usize,
    /// Feature dimensionality.
    pub n_features: usize,
    /// Label count.
    pub n_labels: usize,
    /// Number of graphs in the dataset.
    pub n_graphs: usize,
    pub task: Task,
    /// Cap on the maximum in-degree used by the synthetic generator (keeps
    /// the padded-neighbor functional representation bounded; Table 2 only
    /// constrains the *average* degree).
    pub max_degree_cap: usize,
    /// Seed for deterministic generation.
    pub seed: u64,
    /// Topology generator realizing the spec.
    pub generator: GraphGen,
}

/// The eight Table-2 datasets.
pub const ALL_DATASETS: [DatasetSpec; 8] = [
    DatasetSpec {
        name: "Cora",
        avg_nodes: 2708,
        avg_edges: 10_556,
        n_features: 1433,
        n_labels: 7,
        n_graphs: 1,
        task: Task::NodeClassification,
        max_degree_cap: 128,
        seed: 0xC08A,
        generator: GraphGen::Skewed,
    },
    DatasetSpec {
        name: "PubMed",
        avg_nodes: 19_717,
        avg_edges: 88_651,
        n_features: 500,
        n_labels: 3,
        n_graphs: 1,
        task: Task::NodeClassification,
        max_degree_cap: 128,
        seed: 0x9B3D,
        generator: GraphGen::Skewed,
    },
    DatasetSpec {
        name: "Citeseer",
        avg_nodes: 3327,
        avg_edges: 9104,
        n_features: 3703,
        n_labels: 6,
        n_graphs: 1,
        task: Task::NodeClassification,
        max_degree_cap: 96,
        seed: 0xC17E,
        generator: GraphGen::Skewed,
    },
    DatasetSpec {
        name: "Amazon",
        avg_nodes: 7650,
        avg_edges: 238_162,
        n_features: 745,
        n_labels: 8,
        n_graphs: 1,
        task: Task::NodeClassification,
        max_degree_cap: 256,
        seed: 0xA32,
        generator: GraphGen::Skewed,
    },
    DatasetSpec {
        name: "Proteins",
        avg_nodes: 39,
        avg_edges: 73,
        n_features: 3,
        n_labels: 2,
        n_graphs: 1113,
        task: Task::GraphClassification,
        max_degree_cap: 16,
        seed: 0x980,
        generator: GraphGen::Skewed,
    },
    DatasetSpec {
        name: "Mutag",
        avg_nodes: 18,
        avg_edges: 40,
        n_features: 143,
        n_labels: 2,
        n_graphs: 188,
        task: Task::GraphClassification,
        max_degree_cap: 8,
        seed: 0x3074,
        generator: GraphGen::Skewed,
    },
    DatasetSpec {
        name: "BZR",
        avg_nodes: 34,
        avg_edges: 38,
        n_features: 189,
        n_labels: 2,
        n_graphs: 405,
        task: Task::GraphClassification,
        max_degree_cap: 8,
        seed: 0xB2,
        generator: GraphGen::Skewed,
    },
    DatasetSpec {
        name: "IMDB-binary",
        avg_nodes: 20,
        avg_edges: 193,
        n_features: 136,
        n_labels: 2,
        n_graphs: 1000,
        task: Task::GraphClassification,
        max_degree_cap: 19,
        seed: 0x1DB,
        generator: GraphGen::Skewed,
    },
];

/// The named large-graph tier: shapes matched to the million-edge corpora
/// that dominate deployed GNN serving (see the acceleration surveys cited
/// in ROADMAP/PAPERS). `reddit-syn` follows the sparsified (GraphSAINT)
/// Reddit variant; generating it takes seconds and ~200 MB — nothing in the
/// test suite builds it implicitly.
pub const LARGE_DATASETS: [DatasetSpec; 2] = [
    DatasetSpec {
        name: "ogbn-arxiv-syn",
        avg_nodes: 169_343,
        avg_edges: 1_166_243,
        n_features: 128,
        n_labels: 40,
        n_graphs: 1,
        task: Task::NodeClassification,
        max_degree_cap: 8192,
        seed: 0x0A87,
        generator: GraphGen::RMat,
    },
    DatasetSpec {
        name: "reddit-syn",
        avg_nodes: 232_965,
        avg_edges: 11_606_919,
        n_features: 602,
        n_labels: 41,
        n_graphs: 1,
        task: Task::NodeClassification,
        max_degree_cap: 16_384,
        seed: 0x4EDD,
        generator: GraphGen::RMat,
    },
];

/// Look a dataset up by (case-insensitive) name: the Table-2 tier, the
/// large-graph tier, or a parameterized `rmat-...` spec (see the module
/// docs for the grammar).
pub fn spec_by_name(name: &str) -> Option<DatasetSpec> {
    let lower = name.to_ascii_lowercase();
    ALL_DATASETS
        .iter()
        .chain(LARGE_DATASETS.iter())
        .find(|d| d.name.to_ascii_lowercase() == lower)
        .copied()
        .or_else(|| parse_rmat_name(&lower))
}

/// Interns a dataset name so parameterized specs can carry `&'static str`
/// names (one leak per *distinct* canonical name, however many times it is
/// requested).
fn intern_name(name: String) -> &'static str {
    static INTERNED: OnceLock<Mutex<HashSet<&'static str>>> = OnceLock::new();
    let mut set = INTERNED
        .get_or_init(Default::default)
        .lock()
        .unwrap_or_else(|poisoned| poisoned.into_inner());
    if let Some(&existing) = set.get(name.as_str()) {
        return existing;
    }
    let leaked: &'static str = Box::leak(name.into_boxed_str());
    set.insert(leaked);
    leaked
}

/// Parses a parameterized R-MAT dataset name (already lowercased):
/// `rmat-<V>v-<E>e[-<F>f][-<L>l][-<G>g][-<S>s]`. Returns a spec whose
/// `name` is the fully-expanded canonical form, so every spelling of the
/// same parameters shares one cache identity.
fn parse_rmat_name(lower: &str) -> Option<DatasetSpec> {
    let rest = lower.strip_prefix("rmat-")?;
    let mut nodes: Option<usize> = None;
    let mut edges: Option<usize> = None;
    let mut n_features = 128usize;
    let mut n_labels = 16usize;
    let mut n_graphs = 1usize;
    let mut seed: Option<u64> = None;
    for tok in rest.split('-') {
        if tok.len() < 2 || !tok.is_ascii() {
            return None;
        }
        let (num, suffix) = tok.split_at(tok.len() - 1);
        let val: usize = num.parse().ok()?;
        match suffix {
            "v" => nodes = Some(val),
            "e" => edges = Some(val),
            "f" => n_features = val,
            "l" => n_labels = val,
            "g" => n_graphs = val,
            "s" => seed = Some(val as u64),
            _ => return None,
        }
    }
    let avg_nodes = nodes?;
    let avg_edges = edges?;
    if avg_nodes < 2 || avg_edges == 0 || n_features == 0 || n_labels == 0 || n_graphs == 0 {
        return None;
    }
    // Cap well above the average degree so the R-MAT skew shows, but
    // bounded so worst-case lanes stay finite.
    let avg_degree = avg_edges.div_ceil(avg_nodes);
    let max_degree_cap = (avg_degree * 16).max(64);
    let seed = seed.unwrap_or_else(|| {
        mix_seed(0x524D_4154, mix_seed(avg_nodes as u64, avg_edges as u64))
    });
    let name = intern_name(format!(
        "rmat-{avg_nodes}v-{avg_edges}e-{n_features}f-{n_labels}l-{n_graphs}g-{seed}s"
    ));
    Some(DatasetSpec {
        name,
        avg_nodes,
        avg_edges,
        n_features,
        n_labels,
        n_graphs,
        task: Task::NodeClassification,
        max_degree_cap,
        seed,
        generator: GraphGen::RMat,
    })
}

/// A realized dataset: one or more generated graph topologies.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub spec: DatasetSpec,
    pub graphs: Vec<CsrGraph>,
    /// Graph-mutation epoch: 0 for a freshly generated dataset, bumped by
    /// [`crate::graph::mutate::apply_to_dataset`] on every applied delta
    /// batch. Cache keys include it so a mutated dataset can never alias a
    /// stale cached partition set, plan, or service profile.
    pub epoch: u64,
}

impl Dataset {
    /// Generates the dataset deterministically from its spec. Graph `i` is
    /// seeded with `mix_seed(spec.seed, i)` and the graphs generate in
    /// parallel; the result is identical for any worker count.
    pub fn generate(spec: DatasetSpec) -> Self {
        let indices: Vec<usize> = (0..spec.n_graphs).collect();
        let graphs = par_map(&indices, |&i| {
            let mut rng = Pcg64::seed_from_u64(mix_seed(spec.seed, i as u64));
            // Multi-graph datasets vary ±30 % around the averages so the
            // collection has the irregularity of the real corpora.
            let (n, e) = if spec.n_graphs > 1 {
                let jitter = |avg: usize, rng: &mut Pcg64| {
                    let lo = (avg as f64 * 0.7) as usize;
                    let hi = (avg as f64 * 1.3) as usize + 1;
                    rng.gen_range(lo.max(2), hi.max(3).max(lo.max(2) + 1))
                };
                (jitter(spec.avg_nodes, &mut rng), jitter(spec.avg_edges, &mut rng))
            } else {
                (spec.avg_nodes, spec.avg_edges)
            };
            match spec.generator {
                GraphGen::Skewed => {
                    generate_skewed_graph(n, e, spec.max_degree_cap, &mut rng)
                }
                GraphGen::RMat => generate_rmat_graph(n, e, spec.max_degree_cap, &mut rng),
            }
        });
        Self { spec, graphs, epoch: 0 }
    }

    /// Generate a dataset by name (any tier; see [`spec_by_name`]).
    pub fn by_name(name: &str) -> Option<Self> {
        spec_by_name(name).map(Self::generate)
    }

    /// Total edges across all graphs.
    pub fn total_edges(&self) -> usize {
        self.graphs.iter().map(|g| g.n_edges()).sum()
    }

    /// Total vertices across all graphs.
    pub fn total_vertices(&self) -> usize {
        self.graphs.iter().map(|g| g.n_vertices).sum()
    }
}

/// Generates a directed graph with `n_edges` edges over `n_vertices`
/// vertices whose in-degree distribution is Zipf-skewed (exponent ≈ 0.8,
/// citation-network-like) with a hard cap, plus a guaranteed self-loop-free
/// edge set. Deterministic given the RNG state.
pub fn generate_skewed_graph(
    n_vertices: usize,
    n_edges: usize,
    max_degree_cap: usize,
    rng: &mut Pcg64,
) -> CsrGraph {
    assert!(n_vertices >= 2, "need at least 2 vertices");
    // The cap bounds total in-degree capacity; clamp infeasible requests
    // (duplicate-source edges are allowed, self-loops are not).
    let n_edges = n_edges.min(n_vertices * max_degree_cap);
    // Zipf-ish popularity weights over destinations, randomly permuted so
    // partitions see mixed hot/cold blocks (as in real node orderings).
    let mut perm: Vec<usize> = (0..n_vertices).collect();
    rng.shuffle(&mut perm);
    let weights: Vec<f64> =
        (0..n_vertices).map(|i| 1.0 / ((perm[i] + 1) as f64).powf(0.8)).collect();
    // Cumulative table for weighted sampling.
    let mut cum = Vec::with_capacity(n_vertices);
    let mut acc = 0.0;
    for &w in &weights {
        acc += w;
        cum.push(acc);
    }
    let total = acc;

    let mut degree = vec![0usize; n_vertices];
    let mut edges = Vec::with_capacity(n_edges);
    let mut attempts = 0usize;
    let max_attempts = n_edges * 20;
    while edges.len() < n_edges && attempts < max_attempts {
        attempts += 1;
        let x = rng.gen_range_f64(0.0, total);
        let dst = cum.partition_point(|&c| c < x).min(n_vertices - 1);
        if degree[dst] >= max_degree_cap {
            continue;
        }
        let src = rng.gen_range(0, n_vertices) as u32;
        if src as usize == dst {
            continue;
        }
        degree[dst] += 1;
        edges.push((src, dst as u32));
    }
    fill_remaining_round_robin(n_vertices, n_edges, max_degree_cap, &mut degree, &mut edges, rng);
    CsrGraph::from_edges(n_vertices, &edges)
}

/// R-MAT (recursive matrix) generator — Chakrabarti et al. 2004, the
/// Graph500 standard for power-law graphs at scale. Each edge descends
/// `ceil(log2 V)` levels of the adjacency matrix, picking a quadrant with
/// probabilities `(a, b, c, d) = (0.57, 0.19, 0.19, 0.05)`; the result has
/// heavy-tailed in-degrees *and* the block/community structure that makes
/// partition matrices non-uniform. Self-loops, out-of-range endpoints
/// (non-power-of-two `V`), and over-cap destinations are resampled;
/// infeasible tails are filled round-robin so the edge count is exact.
/// Deterministic given the RNG state.
pub fn generate_rmat_graph(
    n_vertices: usize,
    n_edges: usize,
    max_degree_cap: usize,
    rng: &mut Pcg64,
) -> CsrGraph {
    assert!(n_vertices >= 2, "need at least 2 vertices");
    let n_edges = n_edges.min(n_vertices * max_degree_cap);
    let scale = usize::BITS - (n_vertices - 1).leading_zeros();
    const A: f64 = 0.57;
    const B: f64 = 0.19;
    const C: f64 = 0.19;
    let mut degree = vec![0usize; n_vertices];
    let mut edges = Vec::with_capacity(n_edges);
    let mut attempts = 0usize;
    let max_attempts = n_edges.saturating_mul(40);
    while edges.len() < n_edges && attempts < max_attempts {
        attempts += 1;
        let mut src = 0usize;
        let mut dst = 0usize;
        for _ in 0..scale {
            let r = rng.next_f64();
            let (src_bit, dst_bit) = if r < A {
                (0, 0)
            } else if r < A + B {
                (0, 1)
            } else if r < A + B + C {
                (1, 0)
            } else {
                (1, 1)
            };
            src = (src << 1) | src_bit;
            dst = (dst << 1) | dst_bit;
        }
        if src >= n_vertices || dst >= n_vertices || src == dst || degree[dst] >= max_degree_cap
        {
            continue;
        }
        degree[dst] += 1;
        edges.push((src as u32, dst as u32));
    }
    fill_remaining_round_robin(n_vertices, n_edges, max_degree_cap, &mut degree, &mut edges, rng);
    CsrGraph::from_edges(n_vertices, &edges)
}

/// If rejection sampling ran out of attempts (a tight degree cap makes the
/// target unreachable by sampling alone), round-robin fill the slack so the
/// generated edge count is exactly `n_edges.min(capacity)`.
fn fill_remaining_round_robin(
    n_vertices: usize,
    n_edges: usize,
    max_degree_cap: usize,
    degree: &mut [usize],
    edges: &mut Vec<(u32, u32)>,
    rng: &mut Pcg64,
) {
    let mut v = 0usize;
    while edges.len() < n_edges {
        if degree[v] < max_degree_cap {
            let src = rng.gen_range(0, n_vertices) as u32;
            if src as usize != v {
                degree[v] += 1;
                edges.push((src, v as u32));
            }
        }
        v = (v + 1) % n_vertices;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_eight_datasets_present() {
        assert_eq!(ALL_DATASETS.len(), 8);
        let names: Vec<_> = ALL_DATASETS.iter().map(|d| d.name).collect();
        for n in ["Cora", "PubMed", "Citeseer", "Amazon", "Proteins", "Mutag", "BZR", "IMDB-binary"] {
            assert!(names.contains(&n), "missing {n}");
        }
    }

    #[test]
    fn table2_stats_exact_for_cora() {
        let d = Dataset::by_name("Cora").unwrap();
        assert_eq!(d.graphs.len(), 1);
        assert_eq!(d.graphs[0].n_vertices, 2708);
        assert_eq!(d.graphs[0].n_edges(), 10_556);
        assert_eq!(d.spec.n_features, 1433);
        assert_eq!(d.spec.n_labels, 7);
    }

    #[test]
    fn multi_graph_dataset_counts() {
        let d = Dataset::by_name("Mutag").unwrap();
        assert_eq!(d.graphs.len(), 188);
        // Averages within 30 % of Table 2 values.
        let avg_nodes = d.total_vertices() as f64 / 188.0;
        let avg_edges = d.total_edges() as f64 / 188.0;
        assert!((avg_nodes - 18.0).abs() / 18.0 < 0.3, "avg_nodes = {avg_nodes}");
        assert!((avg_edges - 40.0).abs() / 40.0 < 0.3, "avg_edges = {avg_edges}");
    }

    #[test]
    fn generation_is_deterministic() {
        let a = Dataset::by_name("Citeseer").unwrap();
        let b = Dataset::by_name("Citeseer").unwrap();
        assert_eq!(a.graphs[0], b.graphs[0]);
        // Multi-graph generation is parallel; per-graph derived seeds keep
        // it deterministic for any worker count.
        let a = Dataset::by_name("Mutag").unwrap();
        let b = Dataset::by_name("Mutag").unwrap();
        assert_eq!(a.graphs, b.graphs);
    }

    #[test]
    fn degree_cap_respected() {
        let d = Dataset::by_name("Amazon").unwrap();
        assert!(d.graphs[0].max_degree() <= d.spec.max_degree_cap);
    }

    #[test]
    fn skew_produces_irregularity() {
        let d = Dataset::by_name("PubMed").unwrap();
        let g = &d.graphs[0];
        // Max degree should be far above the mean for a skewed graph.
        assert!(g.max_degree() as f64 > 4.0 * g.avg_degree());
    }

    #[test]
    fn case_insensitive_lookup() {
        assert!(Dataset::by_name("cora").is_some());
        assert!(Dataset::by_name("imdb-BINARY").is_some());
        assert!(Dataset::by_name("nope").is_none());
    }

    #[test]
    fn large_tier_specs_resolve_by_name() {
        let arxiv = spec_by_name("ogbn-arxiv-syn").unwrap();
        assert_eq!(arxiv.avg_nodes, 169_343);
        assert_eq!(arxiv.avg_edges, 1_166_243);
        assert_eq!(arxiv.n_labels, 40);
        assert_eq!(arxiv.generator, GraphGen::RMat);
        let reddit = spec_by_name("Reddit-SYN").unwrap();
        assert_eq!(reddit.avg_nodes, 232_965);
        // Large names must not collide with the Table-2 tier.
        assert_eq!(ALL_DATASETS.len() + LARGE_DATASETS.len(), 10);
    }

    #[test]
    fn rmat_names_parse_with_defaults_and_canonicalize() {
        let a = spec_by_name("rmat-1000v-5000e").unwrap();
        assert_eq!(a.avg_nodes, 1000);
        assert_eq!(a.avg_edges, 5000);
        assert_eq!(a.n_features, 128);
        assert_eq!(a.n_labels, 16);
        assert_eq!(a.n_graphs, 1);
        assert_eq!(a.generator, GraphGen::RMat);
        // Different spellings of the same parameters share one canonical
        // name (the engine's cache identity).
        let b = spec_by_name("RMAT-1000v-5000e-128f").unwrap();
        assert_eq!(a.name, b.name);
        assert!(std::ptr::eq(a.name, b.name), "canonical names are interned");
        // Canonical names round-trip through the parser.
        let c = spec_by_name(a.name).unwrap();
        assert_eq!(a, c);
        // Overrides.
        let d = spec_by_name("rmat-300v-900e-8f-4l-5g-99s").unwrap();
        assert_eq!((d.n_features, d.n_labels, d.n_graphs, d.seed), (8, 4, 5, 99));
        assert_ne!(d.name, a.name);
    }

    #[test]
    fn rmat_names_reject_garbage() {
        for bad in [
            "rmat-",
            "rmat-1000v",          // no edge count
            "rmat-5000e",          // no node count
            "rmat-1000v-5000x",    // unknown suffix
            "rmat-v-5000e",        // empty number
            "rmat-1v-5e",          // below minimum nodes
            "rmat-1000v-0e",       // zero edges
            "rmat-1000v-5000e-0f", // zero features
            "rmatt-1000v-5000e",
        ] {
            assert!(spec_by_name(bad).is_none(), "{bad} must not parse");
        }
    }

    #[test]
    fn rmat_generation_exact_deterministic_and_skewed() {
        let a = Dataset::by_name("rmat-3000v-24000e").unwrap();
        let b = Dataset::by_name("rmat-3000v-24000e").unwrap();
        let g = &a.graphs[0];
        assert_eq!(g.n_vertices, 3000);
        assert_eq!(g.n_edges(), 24_000);
        assert_eq!(g, &b.graphs[0]);
        assert!(g.max_degree() <= a.spec.max_degree_cap);
        // Heavy-tailed in-degrees: the hubs sit far above the mean.
        assert!(g.max_degree() as f64 > 4.0 * g.avg_degree(), "max {}", g.max_degree());
        // No self loops.
        for v in 0..g.n_vertices {
            assert!(!g.neighbors(v).contains(&(v as u32)), "self loop at {v}");
        }
    }

    #[test]
    fn rmat_multi_graph_datasets_generate_in_parallel() {
        let d = Dataset::by_name("rmat-200v-600e-8f-2l-5g").unwrap();
        assert_eq!(d.graphs.len(), 5);
        // Jitter makes the graphs distinct; derived seeds keep them stable.
        let again = Dataset::by_name("rmat-200v-600e-8f-2l-5g").unwrap();
        assert_eq!(d.graphs, again.graphs);
        assert!(d.graphs.windows(2).any(|w| w[0] != w[1]), "graphs should differ");
    }
}
