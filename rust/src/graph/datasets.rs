//! Synthetic evaluation datasets matched to Table 2 of the paper.
//!
//! We cannot ship Cora/PubMed/Citeseer/Amazon/Proteins/Mutag/BZR/IMDB-binary
//! downloads, so each dataset is generated synthetically with the exact
//! Table-2 statistics — node count, edge count, feature dimensionality,
//! label count, graph count — and a skewed (Zipf-like) in-degree
//! distribution matching the irregularity the paper's optimizations target.
//! Every simulator result depends on the graphs only through these
//! statistics. Generation is fully deterministic (PCG64, fixed per-dataset
//! seeds); `python/compile/datasets.py` regenerates the *functional-path*
//! datasets (features + labels + topology) with its own seeded generator
//! and exports them to `artifacts/` for the PJRT datapath.

use crate::util::rng::Pcg64;

use super::csr::CsrGraph;

/// Which GNN task a dataset serves (Table 2 / §4.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Task {
    /// Node classification (Cora, PubMed, Citeseer, Amazon).
    NodeClassification,
    /// Graph classification (Proteins, Mutag, BZR, IMDB-binary).
    GraphClassification,
}

/// Static description of a dataset — the Table-2 row.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DatasetSpec {
    pub name: &'static str,
    /// (Average) node count per graph.
    pub avg_nodes: usize,
    /// (Average) edge count per graph.
    pub avg_edges: usize,
    /// Feature dimensionality.
    pub n_features: usize,
    /// Label count.
    pub n_labels: usize,
    /// Number of graphs in the dataset.
    pub n_graphs: usize,
    pub task: Task,
    /// Cap on the maximum in-degree used by the synthetic generator (keeps
    /// the padded-neighbor functional representation bounded; Table 2 only
    /// constrains the *average* degree).
    pub max_degree_cap: usize,
    /// Seed for deterministic generation.
    pub seed: u64,
}

/// The eight Table-2 datasets.
pub const ALL_DATASETS: [DatasetSpec; 8] = [
    DatasetSpec { name: "Cora", avg_nodes: 2708, avg_edges: 10_556, n_features: 1433, n_labels: 7, n_graphs: 1, task: Task::NodeClassification, max_degree_cap: 128, seed: 0xC08A },
    DatasetSpec { name: "PubMed", avg_nodes: 19_717, avg_edges: 88_651, n_features: 500, n_labels: 3, n_graphs: 1, task: Task::NodeClassification, max_degree_cap: 128, seed: 0x9B3D },
    DatasetSpec { name: "Citeseer", avg_nodes: 3327, avg_edges: 9104, n_features: 3703, n_labels: 6, n_graphs: 1, task: Task::NodeClassification, max_degree_cap: 96, seed: 0xC17E },
    DatasetSpec { name: "Amazon", avg_nodes: 7650, avg_edges: 238_162, n_features: 745, n_labels: 8, n_graphs: 1, task: Task::NodeClassification, max_degree_cap: 256, seed: 0xA32 },
    DatasetSpec { name: "Proteins", avg_nodes: 39, avg_edges: 73, n_features: 3, n_labels: 2, n_graphs: 1113, task: Task::GraphClassification, max_degree_cap: 16, seed: 0x980 },
    DatasetSpec { name: "Mutag", avg_nodes: 18, avg_edges: 40, n_features: 143, n_labels: 2, n_graphs: 188, task: Task::GraphClassification, max_degree_cap: 8, seed: 0x3074 },
    DatasetSpec { name: "BZR", avg_nodes: 34, avg_edges: 38, n_features: 189, n_labels: 2, n_graphs: 405, task: Task::GraphClassification, max_degree_cap: 8, seed: 0xB2 },
    DatasetSpec { name: "IMDB-binary", avg_nodes: 20, avg_edges: 193, n_features: 136, n_labels: 2, n_graphs: 1000, task: Task::GraphClassification, max_degree_cap: 19, seed: 0x1DB },
];

/// Look a dataset up by (case-insensitive) name.
pub fn spec_by_name(name: &str) -> Option<DatasetSpec> {
    let lower = name.to_ascii_lowercase();
    ALL_DATASETS.iter().copied().find(|d| d.name.to_ascii_lowercase() == lower)
}

/// A realized dataset: one or more generated graph topologies.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub spec: DatasetSpec,
    pub graphs: Vec<CsrGraph>,
}

impl Dataset {
    /// Generates the dataset deterministically from its spec.
    pub fn generate(spec: DatasetSpec) -> Self {
        let mut rng = Pcg64::seed_from_u64(spec.seed);
        let graphs = (0..spec.n_graphs)
            .map(|_| {
                // Multi-graph datasets vary ±30 % around the averages so
                // the collection has the irregularity of the real corpora.
                let (n, e) = if spec.n_graphs > 1 {
                    let jitter = |avg: usize, rng: &mut Pcg64| {
                        let lo = (avg as f64 * 0.7) as usize;
                        let hi = (avg as f64 * 1.3) as usize + 1;
                        rng.gen_range(lo.max(2), hi.max(3).max(lo.max(2) + 1))
                    };
                    (jitter(spec.avg_nodes, &mut rng), jitter(spec.avg_edges, &mut rng))
                } else {
                    (spec.avg_nodes, spec.avg_edges)
                };
                generate_skewed_graph(n, e, spec.max_degree_cap, &mut rng)
            })
            .collect();
        Self { spec, graphs }
    }

    /// Generate a dataset by name.
    pub fn by_name(name: &str) -> Option<Self> {
        spec_by_name(name).map(Self::generate)
    }

    /// Total edges across all graphs.
    pub fn total_edges(&self) -> usize {
        self.graphs.iter().map(|g| g.n_edges()).sum()
    }

    /// Total vertices across all graphs.
    pub fn total_vertices(&self) -> usize {
        self.graphs.iter().map(|g| g.n_vertices).sum()
    }
}

/// Generates a directed graph with `n_edges` edges over `n_vertices`
/// vertices whose in-degree distribution is Zipf-skewed (exponent ≈ 0.8,
/// citation-network-like) with a hard cap, plus a guaranteed self-loop-free
/// edge set. Deterministic given the RNG state.
pub fn generate_skewed_graph(
    n_vertices: usize,
    n_edges: usize,
    max_degree_cap: usize,
    rng: &mut Pcg64,
) -> CsrGraph {
    assert!(n_vertices >= 2, "need at least 2 vertices");
    // The cap bounds total in-degree capacity; clamp infeasible requests
    // (duplicate-source edges are allowed, self-loops are not).
    let n_edges = n_edges.min(n_vertices * max_degree_cap);
    // Zipf-ish popularity weights over destinations, randomly permuted so
    // partitions see mixed hot/cold blocks (as in real node orderings).
    let mut perm: Vec<usize> = (0..n_vertices).collect();
    rng.shuffle(&mut perm);
    let weights: Vec<f64> =
        (0..n_vertices).map(|i| 1.0 / ((perm[i] + 1) as f64).powf(0.8)).collect();
    // Cumulative table for weighted sampling.
    let mut cum = Vec::with_capacity(n_vertices);
    let mut acc = 0.0;
    for &w in &weights {
        acc += w;
        cum.push(acc);
    }
    let total = acc;

    let mut degree = vec![0usize; n_vertices];
    let mut edges = Vec::with_capacity(n_edges);
    let mut attempts = 0usize;
    let max_attempts = n_edges * 20;
    while edges.len() < n_edges && attempts < max_attempts {
        attempts += 1;
        let x = rng.gen_range_f64(0.0, total);
        let dst = cum.partition_point(|&c| c < x).min(n_vertices - 1);
        if degree[dst] >= max_degree_cap {
            continue;
        }
        let src = rng.gen_range(0, n_vertices) as u32;
        if src as usize == dst {
            continue;
        }
        degree[dst] += 1;
        edges.push((src, dst as u32));
    }
    // If the cap made the target unreachable, round-robin fill the slack.
    let mut v = 0usize;
    while edges.len() < n_edges {
        if degree[v] < max_degree_cap {
            let src = rng.gen_range(0, n_vertices) as u32;
            if src as usize != v {
                degree[v] += 1;
                edges.push((src, v as u32));
            }
        }
        v = (v + 1) % n_vertices;
    }
    CsrGraph::from_edges(n_vertices, &edges)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_eight_datasets_present() {
        assert_eq!(ALL_DATASETS.len(), 8);
        let names: Vec<_> = ALL_DATASETS.iter().map(|d| d.name).collect();
        for n in ["Cora", "PubMed", "Citeseer", "Amazon", "Proteins", "Mutag", "BZR", "IMDB-binary"] {
            assert!(names.contains(&n), "missing {n}");
        }
    }

    #[test]
    fn table2_stats_exact_for_cora() {
        let d = Dataset::by_name("Cora").unwrap();
        assert_eq!(d.graphs.len(), 1);
        assert_eq!(d.graphs[0].n_vertices, 2708);
        assert_eq!(d.graphs[0].n_edges(), 10_556);
        assert_eq!(d.spec.n_features, 1433);
        assert_eq!(d.spec.n_labels, 7);
    }

    #[test]
    fn multi_graph_dataset_counts() {
        let d = Dataset::by_name("Mutag").unwrap();
        assert_eq!(d.graphs.len(), 188);
        // Averages within 30 % of Table 2 values.
        let avg_nodes = d.total_vertices() as f64 / 188.0;
        let avg_edges = d.total_edges() as f64 / 188.0;
        assert!((avg_nodes - 18.0).abs() / 18.0 < 0.3, "avg_nodes = {avg_nodes}");
        assert!((avg_edges - 40.0).abs() / 40.0 < 0.3, "avg_edges = {avg_edges}");
    }

    #[test]
    fn generation_is_deterministic() {
        let a = Dataset::by_name("Citeseer").unwrap();
        let b = Dataset::by_name("Citeseer").unwrap();
        assert_eq!(a.graphs[0], b.graphs[0]);
    }

    #[test]
    fn degree_cap_respected() {
        let d = Dataset::by_name("Amazon").unwrap();
        assert!(d.graphs[0].max_degree() <= d.spec.max_degree_cap);
    }

    #[test]
    fn skew_produces_irregularity() {
        let d = Dataset::by_name("PubMed").unwrap();
        let g = &d.graphs[0];
        // Max degree should be far above the mean for a skewed graph.
        assert!(g.max_degree() as f64 > 4.0 * g.avg_degree());
    }

    #[test]
    fn case_insensitive_lookup() {
        assert!(Dataset::by_name("cora").is_some());
        assert!(Dataset::by_name("imdb-BINARY").is_some());
        assert!(Dataset::by_name("nope").is_none());
    }
}
