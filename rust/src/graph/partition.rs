//! The "buffer & partition" matrix — §3.4.1.
//!
//! Output (destination) vertices are split into groups of `V` and input
//! (source) vertices into groups of `N`; edges fall into `V×N` blocks.
//! Blocks with no edges are *skipped entirely*, which is how GHOST turns
//! extreme adjacency sparsity into dense, prefetchable work units. The
//! partition matrix, the per-group prefetch order, and the per-group
//! worst-case neighbor counts are all computed once offline (graph
//! preprocessing), exactly as in the paper.
//!
//! ## Layout and parallelism
//!
//! Block references live in **one flat CSR-style array** indexed by output
//! group (`block_ptr[g]..block_ptr[g + 1]`), not in a per-group `Vec` —
//! million-vertex graphs have hundreds of thousands of output groups, and
//! one allocation per group dominated the build. [`PartitionMatrix::build`]
//! fans contiguous output-group ranges out over
//! [`crate::util::parallel::par_map`] with per-chunk scratch arrays and
//! splices the chunk results; the output is identical to
//! [`PartitionMatrix::build_serial`] (the single-threaded reference)
//! regardless of worker count, because every group is computed
//! independently and chunks are ordered.

use super::csr::CsrGraph;
use crate::util::parallel::{chunk_ranges, par_map};

/// Graphs below this edge count build serially: the work is too small to
/// amortize spawning the scoped worker threads.
const PAR_EDGE_THRESHOLD: usize = 100_000;

/// One non-empty `V×N` block of the partition matrix.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BlockRef {
    /// Index of the input-vertex group (column block).
    pub input_group: u32,
    /// Number of edges inside this block.
    pub n_edges: u32,
}

/// Execution plan for one output-vertex group (one assignment of the `V`
/// execution lanes). The group's non-empty blocks live in the matrix-level
/// flat array ([`PartitionMatrix::group_blocks`]); the plan carries only
/// their count, which keeps it `Copy` and allocation-free.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OutputGroupPlan {
    /// Index of the output group.
    pub out_group: u32,
    /// Number of non-empty input blocks feeding this group.
    pub n_blocks: u32,
    /// Largest in-degree among the vertices of this group — the aggregate
    /// stage of the group finishes with its slowest lane (§3.3.1).
    pub max_lane_degree: u32,
    /// Total edges aggregated by this group.
    pub total_edges: u32,
    /// Distinct source vertices feeding this group — the number of feature
    /// vectors the buffer-and-partition prefetch actually streams (sources
    /// with several destinations in the group are fetched once).
    pub distinct_sources: u32,
}

/// The full offline partition of one graph for a `(V, N)` configuration.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PartitionMatrix {
    /// Output-group size (`V` execution lanes).
    pub v: usize,
    /// Input-group size (`N` edge-control units).
    pub n: usize,
    /// Vertex count of the partitioned graph.
    pub n_vertices: usize,
    /// Per-output-group plans, ascending group index.
    pub groups: Vec<OutputGroupPlan>,
    /// All non-empty blocks, flat, grouped by output group in ascending
    /// input-group (prefetch) order within each group.
    blocks: Vec<BlockRef>,
    /// CSR offsets into `blocks`, length `groups.len() + 1`.
    block_ptr: Vec<u32>,
}

/// One chunk's worth of group plans: plans, flat blocks, and chunk-relative
/// block offsets (`block_ptr[0] == 0`).
struct ChunkPlan {
    groups: Vec<OutputGroupPlan>,
    blocks: Vec<BlockRef>,
    block_ptr: Vec<u32>,
}

/// Builds the plans for output groups `range` of the graph. Scratch state
/// (per-input-group edge counters, epoch stamps for distinct-source
/// counting, the touched-block list) is local to the call, so ranges can be
/// built concurrently.
fn build_group_range(
    graph: &CsrGraph,
    v: usize,
    n: usize,
    range: std::ops::Range<usize>,
) -> ChunkPlan {
    let n_in_groups = graph.n_vertices.div_ceil(n).max(1);
    // Scratch: edge counts per input group, reused across output groups.
    let mut block_edges = vec![0u32; n_in_groups];
    // Scratch: epoch stamps for distinct-source counting; a source is new
    // in this group iff its stamp differs from the group epoch.
    let mut seen_epoch = vec![u32::MAX; graph.n_vertices];
    // Scratch: input groups touched by the current output group.
    let mut touched: Vec<u32> = Vec::new();
    let mut groups = Vec::with_capacity(range.len());
    let mut blocks: Vec<BlockRef> = Vec::new();
    let mut block_ptr = Vec::with_capacity(range.len() + 1);
    block_ptr.push(0u32);
    for og in range {
        let lo = og * v;
        let hi = ((og + 1) * v).min(graph.n_vertices);
        let mut max_lane_degree = 0u32;
        let mut total_edges = 0u32;
        let mut distinct_sources = 0u32;
        let epoch = og as u32;
        for dst in lo..hi {
            let deg = graph.degree(dst) as u32;
            max_lane_degree = max_lane_degree.max(deg);
            total_edges += deg;
            for &src in graph.neighbors(dst) {
                if seen_epoch[src as usize] != epoch {
                    seen_epoch[src as usize] = epoch;
                    distinct_sources += 1;
                }
                let ig = src as usize / n;
                if block_edges[ig] == 0 {
                    touched.push(ig as u32);
                }
                block_edges[ig] += 1;
            }
        }
        touched.sort_unstable();
        for &ig in &touched {
            blocks.push(BlockRef { input_group: ig, n_edges: block_edges[ig as usize] });
            block_edges[ig as usize] = 0; // reset scratch
        }
        groups.push(OutputGroupPlan {
            out_group: og as u32,
            n_blocks: touched.len() as u32,
            max_lane_degree,
            total_edges,
            distinct_sources,
        });
        touched.clear();
        block_ptr.push(blocks.len() as u32);
    }
    ChunkPlan { groups, blocks, block_ptr }
}

impl PartitionMatrix {
    /// Builds the partition matrix from a destination-major CSR graph,
    /// fanning output-group ranges across the scoped thread pool for large
    /// graphs. Runs in `O(E + groups)` work: distinct-source counting uses
    /// an epoch-stamped scratch array (no per-group sort), and block
    /// discovery reuses a per-input-group counter array across output
    /// groups. The result is identical to [`Self::build_serial`] for any
    /// worker count.
    pub fn build(graph: &CsrGraph, v: usize, n: usize) -> Self {
        assert!(v > 0 && n > 0);
        let n_out_groups = graph.n_vertices.div_ceil(v).max(1);
        let workers =
            std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
        if workers <= 1 || n_out_groups < 2 || graph.n_edges() < PAR_EDGE_THRESHOLD {
            return Self::build_serial(graph, v, n);
        }
        // More chunks than workers lets the atomic work queue balance
        // skewed graphs (hub-heavy ranges take longer); each chunk pays one
        // O(V + E/N) scratch allocation, so the count stays small.
        let n_chunks = (workers * 2).min(n_out_groups);
        let ranges = chunk_ranges(n_out_groups, n_chunks);
        let parts = par_map(&ranges, |r| build_group_range(graph, v, n, r.clone()));
        let total_blocks: usize = parts.iter().map(|p| p.blocks.len()).sum();
        let mut groups = Vec::with_capacity(n_out_groups);
        let mut blocks = Vec::with_capacity(total_blocks);
        let mut block_ptr = Vec::with_capacity(n_out_groups + 1);
        block_ptr.push(0u32);
        for mut part in parts {
            let base = blocks.len() as u32;
            groups.append(&mut part.groups);
            block_ptr.extend(part.block_ptr.iter().skip(1).map(|&p| base + p));
            blocks.append(&mut part.blocks);
        }
        Self { v, n, n_vertices: graph.n_vertices, groups, blocks, block_ptr }
    }

    /// Partitions every graph of a dataset, parallelizing at the widest
    /// level only: a multi-graph dataset fans *graphs* over the pool (each
    /// built serially — its graphs are small and nesting `par_map` inside
    /// `par_map` would oversubscribe the cores), while a single-graph
    /// dataset lets [`Self::build`] fan its output groups out instead.
    /// Per-graph output is identical either way.
    pub fn build_all(graphs: &[CsrGraph], v: usize, n: usize) -> Vec<Self> {
        if graphs.len() > 1 {
            par_map(graphs, |g| Self::build_serial(g, v, n))
        } else {
            graphs.iter().map(|g| Self::build(g, v, n)).collect()
        }
    }

    /// Single-threaded reference build. `build` must produce byte-identical
    /// output; `benches/partition_scale.rs` measures the speedup between
    /// the two and the test suite asserts the equality.
    pub fn build_serial(graph: &CsrGraph, v: usize, n: usize) -> Self {
        assert!(v > 0 && n > 0);
        let n_out_groups = graph.n_vertices.div_ceil(v).max(1);
        let part = build_group_range(graph, v, n, 0..n_out_groups);
        Self {
            v,
            n,
            n_vertices: graph.n_vertices,
            groups: part.groups,
            blocks: part.blocks,
            block_ptr: part.block_ptr,
        }
    }

    /// The non-empty blocks of output group `g`, in ascending input-group
    /// (prefetch) order.
    pub fn group_blocks(&self, g: usize) -> &[BlockRef] {
        &self.blocks[self.block_ptr[g] as usize..self.block_ptr[g + 1] as usize]
    }

    /// Iterates `(plan, blocks)` pairs over all output groups.
    pub fn iter_groups(
        &self,
    ) -> impl Iterator<Item = (&OutputGroupPlan, &[BlockRef])> + '_ {
        self.groups.iter().enumerate().map(move |(i, g)| (g, self.group_blocks(i)))
    }

    /// The whole flat block array (all groups concatenated).
    pub fn flat_blocks(&self) -> &[BlockRef] {
        &self.blocks
    }

    /// Number of output groups (lane assignments).
    pub fn n_output_groups(&self) -> usize {
        self.groups.len()
    }

    /// Number of input groups.
    pub fn n_input_groups(&self) -> usize {
        self.n_vertices.div_ceil(self.n).max(1)
    }

    /// Total block slots in the dense `V×N` grid.
    pub fn total_block_slots(&self) -> usize {
        self.n_output_groups() * self.n_input_groups()
    }

    /// Non-empty blocks actually fetched.
    pub fn nonzero_blocks(&self) -> usize {
        self.blocks.len()
    }

    /// Fraction of block slots skipped by the all-zero-block optimization.
    pub fn skip_ratio(&self) -> f64 {
        if self.total_block_slots() == 0 {
            return 0.0;
        }
        1.0 - self.nonzero_blocks() as f64 / self.total_block_slots() as f64
    }

    /// Total edges covered by the plan (must equal the graph's edge count).
    pub fn total_edges(&self) -> u64 {
        self.groups.iter().map(|g| g.total_edges as u64).sum()
    }

    /// Total distinct-source fetches across all groups — the feature
    /// vectors the BP prefetcher streams from memory (≤ total edges).
    pub fn total_distinct_source_fetches(&self) -> u64 {
        self.groups.iter().map(|g| g.distinct_sources as u64).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets::Dataset;

    fn path_graph(n: usize) -> CsrGraph {
        let edges: Vec<(u32, u32)> = (1..n).map(|i| (i as u32 - 1, i as u32)).collect();
        CsrGraph::from_edges(n, &edges)
    }

    #[test]
    fn covers_all_edges() {
        let g = path_graph(103);
        let pm = PartitionMatrix::build(&g, 20, 20);
        assert_eq!(pm.total_edges(), g.n_edges() as u64);
        assert_eq!(pm.n_output_groups(), 6); // ceil(103/20)
    }

    #[test]
    fn path_graph_blocks_hug_diagonal() {
        let g = path_graph(100);
        let pm = PartitionMatrix::build(&g, 10, 10);
        // A path graph's edges live on the diagonal ± one block.
        for (grp, blocks) in pm.iter_groups() {
            for b in blocks {
                let diff = (b.input_group as i64 - grp.out_group as i64).abs();
                assert!(diff <= 1, "off-diagonal block {b:?} in group {}", grp.out_group);
            }
        }
        // Massive skip on a path graph.
        assert!(pm.skip_ratio() > 0.7, "skip = {}", pm.skip_ratio());
    }

    #[test]
    fn blocks_in_prefetch_order() {
        let d = Dataset::by_name("Cora").unwrap();
        let pm = PartitionMatrix::build(&d.graphs[0], 20, 20);
        for g in 0..pm.n_output_groups() {
            for w in pm.group_blocks(g).windows(2) {
                assert!(w[0].input_group < w[1].input_group);
            }
        }
    }

    #[test]
    fn real_dataset_skips_blocks() {
        let d = Dataset::by_name("Cora").unwrap();
        let pm = PartitionMatrix::build(&d.graphs[0], 20, 20);
        assert_eq!(pm.total_edges(), 10_556);
        // Cora is very sparse: most 20×20 blocks are empty.
        assert!(pm.skip_ratio() > 0.5, "skip = {}", pm.skip_ratio());
    }

    #[test]
    fn max_lane_degree_matches_graph() {
        let d = Dataset::by_name("Citeseer").unwrap();
        let g = &d.graphs[0];
        let pm = PartitionMatrix::build(g, 20, 20);
        let global_max: u32 = pm.groups.iter().map(|gr| gr.max_lane_degree).max().unwrap();
        assert_eq!(global_max as usize, g.max_degree());
    }

    #[test]
    fn distinct_sources_bounded_by_edges() {
        let d = Dataset::by_name("Amazon").unwrap();
        let pm = PartitionMatrix::build(&d.graphs[0], 20, 20);
        for grp in &pm.groups {
            assert!(grp.distinct_sources <= grp.total_edges.max(1));
        }
        assert!(pm.total_distinct_source_fetches() <= pm.total_edges());
        // A hub-heavy graph must show real fetch dedup.
        assert!(pm.total_distinct_source_fetches() < pm.total_edges());
    }

    #[test]
    fn single_group_when_v_exceeds_n_vertices() {
        let g = path_graph(5);
        let pm = PartitionMatrix::build(&g, 100, 100);
        assert_eq!(pm.n_output_groups(), 1);
        assert_eq!(pm.nonzero_blocks(), 1);
    }

    #[test]
    fn flat_layout_is_consistent() {
        let d = Dataset::by_name("Citeseer").unwrap();
        let pm = PartitionMatrix::build(&d.graphs[0], 20, 20);
        let from_plans: usize = pm.groups.iter().map(|g| g.n_blocks as usize).sum();
        assert_eq!(from_plans, pm.nonzero_blocks());
        assert_eq!(pm.flat_blocks().len(), pm.nonzero_blocks());
        for g in 0..pm.n_output_groups() {
            assert_eq!(pm.group_blocks(g).len(), pm.groups[g].n_blocks as usize);
            let block_edges: u32 = pm.group_blocks(g).iter().map(|b| b.n_edges).sum();
            assert_eq!(block_edges, pm.groups[g].total_edges);
        }
    }

    #[test]
    fn parallel_build_equals_serial_reference() {
        // Amazon (238k edges) crosses the parallel threshold; the smaller
        // graphs take the serial path, which must be trivially identical.
        for name in ["Cora", "Amazon"] {
            let d = Dataset::by_name(name).unwrap();
            for &(v, n) in &[(20usize, 20usize), (10, 30), (37, 11)] {
                let par = PartitionMatrix::build(&d.graphs[0], v, n);
                let ser = PartitionMatrix::build_serial(&d.graphs[0], v, n);
                assert_eq!(par, ser, "{name} at ({v}, {n})");
            }
        }
    }

    #[test]
    fn build_all_matches_per_graph_builds() {
        // Multi-graph path (parallel over graphs, serial per graph).
        let d = Dataset::by_name("Mutag").unwrap();
        let all = PartitionMatrix::build_all(&d.graphs, 20, 20);
        assert_eq!(all.len(), d.graphs.len());
        for (pm, g) in all.iter().zip(&d.graphs) {
            assert_eq!(pm, &PartitionMatrix::build_serial(g, 20, 20));
        }
        // Single-graph path delegates to the (possibly parallel) build.
        let cora = Dataset::by_name("Cora").unwrap();
        let one = PartitionMatrix::build_all(&cora.graphs, 20, 20);
        assert_eq!(one.len(), 1);
        assert_eq!(one[0], PartitionMatrix::build_serial(&cora.graphs[0], 20, 20));
    }

    #[test]
    fn empty_graph_builds_one_empty_group() {
        let g = CsrGraph::from_edges(0, &[]);
        let pm = PartitionMatrix::build(&g, 20, 20);
        assert_eq!(pm.n_output_groups(), 1);
        assert_eq!(pm.nonzero_blocks(), 0);
        assert_eq!(pm.total_edges(), 0);
    }
}
