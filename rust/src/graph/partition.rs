//! The "buffer & partition" matrix — §3.4.1.
//!
//! Output (destination) vertices are split into groups of `V` and input
//! (source) vertices into groups of `N`; edges fall into `V×N` blocks.
//! Blocks with no edges are *skipped entirely*, which is how GHOST turns
//! extreme adjacency sparsity into dense, prefetchable work units. The
//! partition matrix, the per-group prefetch order, and the per-group
//! worst-case neighbor counts are all computed once offline (graph
//! preprocessing), exactly as in the paper.
//!
//! ## Layout and parallelism
//!
//! Block references live in **one flat CSR-style array** indexed by output
//! group (`block_ptr[g]..block_ptr[g + 1]`), not in a per-group `Vec` —
//! million-vertex graphs have hundreds of thousands of output groups, and
//! one allocation per group dominated the build. [`PartitionMatrix::build`]
//! fans contiguous output-group ranges out over
//! [`crate::util::parallel::par_map`] with per-chunk scratch arrays and
//! splices the chunk results; the output is identical to
//! [`PartitionMatrix::build_serial`] (the single-threaded reference)
//! regardless of worker count, because every group is computed
//! independently and chunks are ordered.

use super::csr::CsrGraph;
use crate::util::parallel::{chunk_ranges, par_map};

/// Graphs below this edge count build serially: the work is too small to
/// amortize spawning the scoped worker threads.
const PAR_EDGE_THRESHOLD: usize = 100_000;

/// One non-empty `V×N` block of the partition matrix.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BlockRef {
    /// Index of the input-vertex group (column block).
    pub input_group: u32,
    /// Number of edges inside this block.
    pub n_edges: u32,
}

/// Execution plan for one output-vertex group (one assignment of the `V`
/// execution lanes). The group's non-empty blocks live in the matrix-level
/// flat array ([`PartitionMatrix::group_blocks`]); the plan carries only
/// their count, which keeps it `Copy` and allocation-free.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OutputGroupPlan {
    /// Index of the output group.
    pub out_group: u32,
    /// Number of non-empty input blocks feeding this group.
    pub n_blocks: u32,
    /// Largest in-degree among the vertices of this group — the aggregate
    /// stage of the group finishes with its slowest lane (§3.3.1).
    pub max_lane_degree: u32,
    /// Total edges aggregated by this group.
    pub total_edges: u32,
    /// Distinct source vertices feeding this group — the number of feature
    /// vectors the buffer-and-partition prefetch actually streams (sources
    /// with several destinations in the group are fetched once).
    pub distinct_sources: u32,
}

/// The full offline partition of one graph for a `(V, N)` configuration.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PartitionMatrix {
    /// Output-group size (`V` execution lanes).
    pub v: usize,
    /// Input-group size (`N` edge-control units).
    pub n: usize,
    /// Vertex count of the partitioned graph.
    pub n_vertices: usize,
    /// Per-output-group plans, ascending group index.
    pub groups: Vec<OutputGroupPlan>,
    /// All non-empty blocks, flat, grouped by output group in ascending
    /// input-group (prefetch) order within each group.
    blocks: Vec<BlockRef>,
    /// CSR offsets into `blocks`, length `groups.len() + 1`.
    block_ptr: Vec<u32>,
}

/// One chunk's worth of group plans: plans, flat blocks, and chunk-relative
/// block offsets (`block_ptr[0] == 0`).
struct ChunkPlan {
    groups: Vec<OutputGroupPlan>,
    blocks: Vec<BlockRef>,
    block_ptr: Vec<u32>,
}

/// Reusable scratch for deriving single output-group plans: per-input-group
/// edge counters, epoch stamps for distinct-source counting, and the
/// touched-block list. One allocation serves any number of *distinct*
/// output groups (the epoch stamps key on the group index), which is what
/// makes scattered-group re-derivation in [`PartitionMatrix::splice`] as
/// cheap per group as the bulk build.
struct GroupScratch {
    block_edges: Vec<u32>,
    seen_epoch: Vec<u32>,
    touched: Vec<u32>,
}

impl GroupScratch {
    fn new(graph: &CsrGraph, n: usize) -> Self {
        let n_in_groups = graph.n_vertices.div_ceil(n).max(1);
        Self {
            block_edges: vec![0u32; n_in_groups],
            seen_epoch: vec![u32::MAX; graph.n_vertices],
            touched: Vec::new(),
        }
    }

    /// Derives the plan for output group `og`, appending its non-empty
    /// blocks to `blocks` in ascending input-group order. Each distinct
    /// `og` may be derived at most once per scratch lifetime (a source
    /// vertex stamped by group `og` would be missed on a second pass).
    fn derive_group(
        &mut self,
        graph: &CsrGraph,
        v: usize,
        n: usize,
        og: usize,
        blocks: &mut Vec<BlockRef>,
    ) -> OutputGroupPlan {
        let lo = og * v;
        let hi = ((og + 1) * v).min(graph.n_vertices);
        let mut max_lane_degree = 0u32;
        let mut total_edges = 0u32;
        let mut distinct_sources = 0u32;
        let epoch = og as u32;
        for dst in lo..hi {
            let deg = graph.degree(dst) as u32;
            max_lane_degree = max_lane_degree.max(deg);
            total_edges += deg;
            for &src in graph.neighbors(dst) {
                if self.seen_epoch[src as usize] != epoch {
                    self.seen_epoch[src as usize] = epoch;
                    distinct_sources += 1;
                }
                let ig = src as usize / n;
                if self.block_edges[ig] == 0 {
                    self.touched.push(ig as u32);
                }
                self.block_edges[ig] += 1;
            }
        }
        self.touched.sort_unstable();
        for &ig in &self.touched {
            blocks.push(BlockRef { input_group: ig, n_edges: self.block_edges[ig as usize] });
            self.block_edges[ig as usize] = 0; // reset scratch
        }
        let plan = OutputGroupPlan {
            out_group: og as u32,
            n_blocks: self.touched.len() as u32,
            max_lane_degree,
            total_edges,
            distinct_sources,
        };
        self.touched.clear();
        plan
    }
}

/// Builds the plans for output groups `range` of the graph. Scratch state
/// is local to the call, so ranges can be built concurrently.
fn build_group_range(
    graph: &CsrGraph,
    v: usize,
    n: usize,
    range: std::ops::Range<usize>,
) -> ChunkPlan {
    let mut scratch = GroupScratch::new(graph, n);
    let mut groups = Vec::with_capacity(range.len());
    let mut blocks: Vec<BlockRef> = Vec::new();
    let mut block_ptr = Vec::with_capacity(range.len() + 1);
    block_ptr.push(0u32);
    for og in range {
        groups.push(scratch.derive_group(graph, v, n, og, &mut blocks));
        block_ptr.push(blocks.len() as u32);
    }
    ChunkPlan { groups, blocks, block_ptr }
}

impl PartitionMatrix {
    /// Builds the partition matrix from a destination-major CSR graph,
    /// fanning output-group ranges across the scoped thread pool for large
    /// graphs. Runs in `O(E + groups)` work: distinct-source counting uses
    /// an epoch-stamped scratch array (no per-group sort), and block
    /// discovery reuses a per-input-group counter array across output
    /// groups. The result is identical to [`Self::build_serial`] for any
    /// worker count.
    pub fn build(graph: &CsrGraph, v: usize, n: usize) -> Self {
        assert!(v > 0 && n > 0);
        let n_out_groups = graph.n_vertices.div_ceil(v).max(1);
        let workers =
            std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
        if workers <= 1 || n_out_groups < 2 || graph.n_edges() < PAR_EDGE_THRESHOLD {
            return Self::build_serial(graph, v, n);
        }
        // More chunks than workers lets the atomic work queue balance
        // skewed graphs (hub-heavy ranges take longer); each chunk pays one
        // O(V + E/N) scratch allocation, so the count stays small.
        let n_chunks = (workers * 2).min(n_out_groups);
        let ranges = chunk_ranges(n_out_groups, n_chunks);
        let parts = par_map(&ranges, |r| build_group_range(graph, v, n, r.clone()));
        let total_blocks: usize = parts.iter().map(|p| p.blocks.len()).sum();
        let mut groups = Vec::with_capacity(n_out_groups);
        let mut blocks = Vec::with_capacity(total_blocks);
        let mut block_ptr = Vec::with_capacity(n_out_groups + 1);
        block_ptr.push(0u32);
        for mut part in parts {
            let base = blocks.len() as u32;
            groups.append(&mut part.groups);
            block_ptr.extend(part.block_ptr.iter().skip(1).map(|&p| base + p));
            blocks.append(&mut part.blocks);
        }
        Self { v, n, n_vertices: graph.n_vertices, groups, blocks, block_ptr }
    }

    /// Partitions every graph of a dataset, parallelizing at the widest
    /// level only: a multi-graph dataset fans *graphs* over the pool (each
    /// built serially — its graphs are small and nesting `par_map` inside
    /// `par_map` would oversubscribe the cores), while a single-graph
    /// dataset lets [`Self::build`] fan its output groups out instead.
    /// Per-graph output is identical either way.
    pub fn build_all(graphs: &[CsrGraph], v: usize, n: usize) -> Vec<Self> {
        let _span = crate::util::telemetry::span("partition.build_all");
        if graphs.len() > 1 {
            par_map(graphs, |g| Self::build_serial(g, v, n))
        } else {
            graphs.iter().map(|g| Self::build(g, v, n)).collect()
        }
    }

    /// Single-threaded reference build. `build` must produce byte-identical
    /// output; `benches/partition_scale.rs` measures the speedup between
    /// the two and the test suite asserts the equality.
    pub fn build_serial(graph: &CsrGraph, v: usize, n: usize) -> Self {
        assert!(v > 0 && n > 0);
        let n_out_groups = graph.n_vertices.div_ceil(v).max(1);
        let part = build_group_range(graph, v, n, 0..n_out_groups);
        Self {
            v,
            n,
            n_vertices: graph.n_vertices,
            groups: part.groups,
            blocks: part.blocks,
            block_ptr: part.block_ptr,
        }
    }

    /// The non-empty blocks of output group `g`, in ascending input-group
    /// (prefetch) order.
    pub fn group_blocks(&self, g: usize) -> &[BlockRef] {
        &self.blocks[self.block_ptr[g] as usize..self.block_ptr[g + 1] as usize]
    }

    /// Iterates `(plan, blocks)` pairs over all output groups.
    pub fn iter_groups(
        &self,
    ) -> impl Iterator<Item = (&OutputGroupPlan, &[BlockRef])> + '_ {
        self.groups.iter().enumerate().map(move |(i, g)| (g, self.group_blocks(i)))
    }

    /// The whole flat block array (all groups concatenated).
    pub fn flat_blocks(&self) -> &[BlockRef] {
        &self.blocks
    }

    /// Number of output groups (lane assignments).
    pub fn n_output_groups(&self) -> usize {
        self.groups.len()
    }

    /// Number of input groups.
    pub fn n_input_groups(&self) -> usize {
        self.n_vertices.div_ceil(self.n).max(1)
    }

    /// Total block slots in the dense `V×N` grid.
    pub fn total_block_slots(&self) -> usize {
        self.n_output_groups() * self.n_input_groups()
    }

    /// Non-empty blocks actually fetched.
    pub fn nonzero_blocks(&self) -> usize {
        self.blocks.len()
    }

    /// Fraction of block slots skipped by the all-zero-block optimization.
    pub fn skip_ratio(&self) -> f64 {
        if self.total_block_slots() == 0 {
            return 0.0;
        }
        1.0 - self.nonzero_blocks() as f64 / self.total_block_slots() as f64
    }

    /// Total edges covered by the plan (must equal the graph's edge count).
    pub fn total_edges(&self) -> u64 {
        self.groups.iter().map(|g| g.total_edges as u64).sum()
    }

    /// Total distinct-source fetches across all groups — the feature
    /// vectors the BP prefetcher streams from memory (≤ total edges).
    pub fn total_distinct_source_fetches(&self) -> u64 {
        self.groups.iter().map(|g| g.distinct_sources as u64).sum()
    }

    /// Resident bytes for the contiguous output-group range `range`:
    /// feature state for the vertices those groups own, edge descriptors
    /// ([`EDGE_DESC_BYTES`]) for their in-edges, and partition metadata
    /// (one [`BlockRef`] per non-empty block). Ranges partition exactly —
    /// footprints over a partition of the group space sum to
    /// [`Self::footprint_bytes`], because vertices, edges, and blocks each
    /// belong to exactly one output group.
    pub fn group_range_footprint_bytes(
        &self,
        range: std::ops::Range<usize>,
        feat_bytes_per_vertex: usize,
    ) -> u64 {
        let lo_v = (range.start * self.v).min(self.n_vertices) as u64;
        let hi_v = (range.end * self.v).min(self.n_vertices) as u64;
        let edges: u64 =
            self.groups[range.clone()].iter().map(|g| g.total_edges as u64).sum();
        let blocks = (self.block_ptr[range.end] - self.block_ptr[range.start]) as u64;
        (hi_v - lo_v) * feat_bytes_per_vertex as u64
            + edges * EDGE_DESC_BYTES
            + blocks * std::mem::size_of::<BlockRef>() as u64
    }

    /// Whole-graph resident footprint at `feat_bytes_per_vertex` bytes of
    /// feature state per vertex — what one chip must hold to run this
    /// graph unsharded.
    pub fn footprint_bytes(&self, feat_bytes_per_vertex: usize) -> u64 {
        self.group_range_footprint_bytes(0..self.n_output_groups(), feat_bytes_per_vertex)
    }

    /// Number of vertices owned by output groups `range`.
    pub fn group_range_vertices(&self, range: std::ops::Range<usize>) -> usize {
        (range.end * self.v).min(self.n_vertices) - (range.start * self.v).min(self.n_vertices)
    }

    /// Incrementally patches this partition after the underlying graph
    /// mutated: re-derives only the output groups named in `changed`
    /// (sorted, deduplicated indices in the *new* group space), any group
    /// beyond the old group count, and the boundary group whose vertex
    /// range was clamped by the old vertex count — every other group's
    /// plan and block slice is copied verbatim. Output groups are derived
    /// independently of each other, so as long as `changed` covers every
    /// group owning a destination vertex whose in-edge row was touched,
    /// the result is byte-identical to
    /// [`Self::build_serial`]`(graph, v, n)` on the mutated graph (the
    /// property tests and the `GHOST_CHURN_CHECK` oracle pin this).
    pub fn splice(&mut self, graph: &CsrGraph, changed: &[u32]) {
        debug_assert!(
            changed.windows(2).all(|w| w[0] < w[1]),
            "changed groups must be sorted and deduplicated"
        );
        let new_n_out = graph.n_vertices.div_ceil(self.v).max(1);
        let old_n_out = self.n_output_groups();
        let mut scratch = GroupScratch::new(graph, self.n);
        let mut groups = Vec::with_capacity(new_n_out);
        let mut blocks = Vec::with_capacity(self.blocks.len());
        let mut block_ptr = Vec::with_capacity(new_n_out + 1);
        block_ptr.push(0u32);
        let mut next_changed = 0usize;
        for og in 0..new_n_out {
            let is_changed =
                next_changed < changed.len() && changed[next_changed] as usize == og;
            if is_changed {
                next_changed += 1;
            }
            // A group also changes structurally when it did not exist
            // before or when vertex growth unclamped its range.
            let old_hi = ((og + 1) * self.v).min(self.n_vertices);
            let new_hi = ((og + 1) * self.v).min(graph.n_vertices);
            if is_changed || og >= old_n_out || old_hi != new_hi {
                groups.push(scratch.derive_group(graph, self.v, self.n, og, &mut blocks));
            } else {
                groups.push(self.groups[og]);
                blocks.extend_from_slice(self.group_blocks(og));
            }
            block_ptr.push(blocks.len() as u32);
        }
        self.n_vertices = graph.n_vertices;
        self.groups = groups;
        self.blocks = blocks;
        self.block_ptr = block_ptr;
    }
}

/// Bytes per edge descriptor resident in HBM and streamed by the ECU —
/// matches the 8 B/edge the edge-stream cost model charges.
pub const EDGE_DESC_BYTES: u64 = 8;

/// Assignment of every graph's output groups to `shards` chips, plus the
/// halo-exchange volumes the assignment implies.
///
/// Each chip owns a **contiguous** range of output groups per graph
/// (destination-vertex sharding), chosen by balancing the prefix of
/// per-group resident footprints. Input (source) vertex features live with
/// the shard that owns them as *destinations*: input group `ig` is owned
/// by the shard owning output group `ig·N/V` (the group of its first
/// vertex — a group-granularity approximation of vertex ownership). Every
/// non-empty block whose input group lives on another shard contributes
/// its edge count to that shard pair's exchange volume: before the layer's
/// gathers can run, the owner must ship those source features over the
/// inter-chip link.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardPlan {
    /// Number of chips the dataset is sharded across (≥ 1).
    pub shards: usize,
    /// Feature bytes per vertex used for footprint balancing.
    pub feat_bytes_per_vertex: usize,
    /// Per graph: shard boundaries in output-group space, length
    /// `shards + 1`, non-decreasing, first 0, last `n_output_groups`.
    group_starts: Vec<Vec<u32>>,
    /// Per graph: flattened `shards × shards` matrix, entry
    /// `dst_shard * shards + src_shard` = edges whose destination group is
    /// on `dst_shard` but whose source (input) group is owned by
    /// `src_shard`. The diagonal is zero (intra-shard edges move no data).
    exchange: Vec<Vec<u64>>,
    /// Per chip: the bytes it must hold resident — max over graphs of its
    /// range footprint (graphs are processed one at a time, like the
    /// single-chip path).
    chip_footprint_bytes: Vec<u64>,
}

impl ShardPlan {
    /// Builds the shard assignment for a partitioned dataset. `shards`
    /// must be ≥ 1; a 1-shard plan assigns everything to chip 0 and has
    /// zero exchange volume.
    pub fn build(
        parts: &[PartitionMatrix],
        shards: usize,
        feat_bytes_per_vertex: usize,
    ) -> Self {
        assert!(shards >= 1, "shard count must be >= 1");
        let mut group_starts = Vec::with_capacity(parts.len());
        let mut exchange = Vec::with_capacity(parts.len());
        let mut chip_footprint_bytes = vec![0u64; shards];
        for pm in parts {
            let n_groups = pm.n_output_groups();
            // Prefix footprints over output groups; boundaries aim at
            // equal footprint per shard.
            let mut pref = Vec::with_capacity(n_groups + 1);
            pref.push(0u64);
            for g in 0..n_groups {
                pref.push(pref[g] + pm.group_range_footprint_bytes(g..g + 1, feat_bytes_per_vertex));
            }
            let total = pref[n_groups];
            let mut starts = vec![0u32; shards + 1];
            for s in 1..shards {
                let target = (total as u128 * s as u128 / shards as u128) as u64;
                let lower = (starts[s - 1] as usize + 1).min(n_groups);
                let upper = n_groups.saturating_sub(shards - s).max(lower);
                let b = pref.partition_point(|&p| p < target).clamp(lower, upper);
                starts[s] = b as u32;
            }
            starts[shards] = n_groups as u32;
            // Ownership of an input group: the shard of its first vertex's
            // output group.
            let owner = |ig: usize| -> usize {
                let og = (ig * pm.n / pm.v).min(n_groups.saturating_sub(1)) as u32;
                starts[1..].partition_point(|&b| b <= og)
            };
            let mut xch = vec![0u64; shards * shards];
            for s in 0..shards {
                let range = starts[s] as usize..starts[s + 1] as usize;
                for g in range.clone() {
                    for b in pm.group_blocks(g) {
                        let t = owner(b.input_group as usize);
                        if t != s {
                            xch[s * shards + t] += b.n_edges as u64;
                        }
                    }
                }
                let fp = pm.group_range_footprint_bytes(range, feat_bytes_per_vertex);
                chip_footprint_bytes[s] = chip_footprint_bytes[s].max(fp);
            }
            group_starts.push(starts);
            exchange.push(xch);
        }
        Self { shards, feat_bytes_per_vertex, group_starts, exchange, chip_footprint_bytes }
    }

    /// The contiguous output-group range chip `shard` owns of graph
    /// `graph`.
    pub fn group_range(&self, graph: usize, shard: usize) -> std::ops::Range<usize> {
        let starts = &self.group_starts[graph];
        starts[shard] as usize..starts[shard + 1] as usize
    }

    /// The shard owning output group `og` of graph `graph`.
    pub fn shard_of_group(&self, graph: usize, og: usize) -> usize {
        self.group_starts[graph][1..].partition_point(|&b| b as usize <= og)
    }

    /// The shard owning input group `ig` of graph `graph` (the shard of
    /// its first vertex's output group).
    pub fn owner_of_input_group(
        &self,
        graph: usize,
        pm: &PartitionMatrix,
        ig: usize,
    ) -> usize {
        let og = (ig * pm.n / pm.v).min(pm.n_output_groups().saturating_sub(1));
        self.shard_of_group(graph, og)
    }

    /// Edges of graph `graph` whose destination lives on `dst_shard` but
    /// whose source features are owned by `src_shard`.
    pub fn exchange_edges(&self, graph: usize, dst_shard: usize, src_shard: usize) -> u64 {
        self.exchange[graph][dst_shard * self.shards + src_shard]
    }

    /// Total cross-shard edges of one graph (sum of the exchange matrix).
    pub fn cross_shard_edges(&self, graph: usize) -> u64 {
        self.exchange[graph].iter().sum()
    }

    /// Total cross-shard edges across all graphs.
    pub fn total_cross_shard_edges(&self) -> u64 {
        (0..self.exchange.len()).map(|g| self.cross_shard_edges(g)).sum()
    }

    /// Per-chip resident footprints, bytes (max over graphs).
    pub fn chip_footprints(&self) -> &[u64] {
        &self.chip_footprint_bytes
    }

    /// The largest per-chip footprint — what each chip's memory budget
    /// must cover.
    pub fn max_chip_footprint_bytes(&self) -> u64 {
        self.chip_footprint_bytes.iter().copied().max().unwrap_or(0)
    }

    /// Whether every chip's resident state fits `budget_bytes`.
    pub fn fits_budget(&self, budget_bytes: u64) -> bool {
        self.max_chip_footprint_bytes() <= budget_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets::Dataset;

    fn path_graph(n: usize) -> CsrGraph {
        let edges: Vec<(u32, u32)> = (1..n).map(|i| (i as u32 - 1, i as u32)).collect();
        CsrGraph::from_edges(n, &edges)
    }

    #[test]
    fn covers_all_edges() {
        let g = path_graph(103);
        let pm = PartitionMatrix::build(&g, 20, 20);
        assert_eq!(pm.total_edges(), g.n_edges() as u64);
        assert_eq!(pm.n_output_groups(), 6); // ceil(103/20)
    }

    #[test]
    fn path_graph_blocks_hug_diagonal() {
        let g = path_graph(100);
        let pm = PartitionMatrix::build(&g, 10, 10);
        // A path graph's edges live on the diagonal ± one block.
        for (grp, blocks) in pm.iter_groups() {
            for b in blocks {
                let diff = (b.input_group as i64 - grp.out_group as i64).abs();
                assert!(diff <= 1, "off-diagonal block {b:?} in group {}", grp.out_group);
            }
        }
        // Massive skip on a path graph.
        assert!(pm.skip_ratio() > 0.7, "skip = {}", pm.skip_ratio());
    }

    #[test]
    fn blocks_in_prefetch_order() {
        let d = Dataset::by_name("Cora").unwrap();
        let pm = PartitionMatrix::build(&d.graphs[0], 20, 20);
        for g in 0..pm.n_output_groups() {
            for w in pm.group_blocks(g).windows(2) {
                assert!(w[0].input_group < w[1].input_group);
            }
        }
    }

    #[test]
    fn real_dataset_skips_blocks() {
        let d = Dataset::by_name("Cora").unwrap();
        let pm = PartitionMatrix::build(&d.graphs[0], 20, 20);
        assert_eq!(pm.total_edges(), 10_556);
        // Cora is very sparse: most 20×20 blocks are empty.
        assert!(pm.skip_ratio() > 0.5, "skip = {}", pm.skip_ratio());
    }

    #[test]
    fn max_lane_degree_matches_graph() {
        let d = Dataset::by_name("Citeseer").unwrap();
        let g = &d.graphs[0];
        let pm = PartitionMatrix::build(g, 20, 20);
        let global_max: u32 = pm.groups.iter().map(|gr| gr.max_lane_degree).max().unwrap();
        assert_eq!(global_max as usize, g.max_degree());
    }

    #[test]
    fn distinct_sources_bounded_by_edges() {
        let d = Dataset::by_name("Amazon").unwrap();
        let pm = PartitionMatrix::build(&d.graphs[0], 20, 20);
        for grp in &pm.groups {
            assert!(grp.distinct_sources <= grp.total_edges.max(1));
        }
        assert!(pm.total_distinct_source_fetches() <= pm.total_edges());
        // A hub-heavy graph must show real fetch dedup.
        assert!(pm.total_distinct_source_fetches() < pm.total_edges());
    }

    #[test]
    fn single_group_when_v_exceeds_n_vertices() {
        let g = path_graph(5);
        let pm = PartitionMatrix::build(&g, 100, 100);
        assert_eq!(pm.n_output_groups(), 1);
        assert_eq!(pm.nonzero_blocks(), 1);
    }

    #[test]
    fn flat_layout_is_consistent() {
        let d = Dataset::by_name("Citeseer").unwrap();
        let pm = PartitionMatrix::build(&d.graphs[0], 20, 20);
        let from_plans: usize = pm.groups.iter().map(|g| g.n_blocks as usize).sum();
        assert_eq!(from_plans, pm.nonzero_blocks());
        assert_eq!(pm.flat_blocks().len(), pm.nonzero_blocks());
        for g in 0..pm.n_output_groups() {
            assert_eq!(pm.group_blocks(g).len(), pm.groups[g].n_blocks as usize);
            let block_edges: u32 = pm.group_blocks(g).iter().map(|b| b.n_edges).sum();
            assert_eq!(block_edges, pm.groups[g].total_edges);
        }
    }

    #[test]
    fn parallel_build_equals_serial_reference() {
        // Amazon (238k edges) crosses the parallel threshold; the smaller
        // graphs take the serial path, which must be trivially identical.
        for name in ["Cora", "Amazon"] {
            let d = Dataset::by_name(name).unwrap();
            for &(v, n) in &[(20usize, 20usize), (10, 30), (37, 11)] {
                let par = PartitionMatrix::build(&d.graphs[0], v, n);
                let ser = PartitionMatrix::build_serial(&d.graphs[0], v, n);
                assert_eq!(par, ser, "{name} at ({v}, {n})");
            }
        }
    }

    #[test]
    fn build_all_matches_per_graph_builds() {
        // Multi-graph path (parallel over graphs, serial per graph).
        let d = Dataset::by_name("Mutag").unwrap();
        let all = PartitionMatrix::build_all(&d.graphs, 20, 20);
        assert_eq!(all.len(), d.graphs.len());
        for (pm, g) in all.iter().zip(&d.graphs) {
            assert_eq!(pm, &PartitionMatrix::build_serial(g, 20, 20));
        }
        // Single-graph path delegates to the (possibly parallel) build.
        let cora = Dataset::by_name("Cora").unwrap();
        let one = PartitionMatrix::build_all(&cora.graphs, 20, 20);
        assert_eq!(one.len(), 1);
        assert_eq!(one[0], PartitionMatrix::build_serial(&cora.graphs[0], 20, 20));
    }

    #[test]
    fn splice_matches_full_rebuild_after_edits() {
        let d = Dataset::by_name("Citeseer").unwrap();
        let g = &d.graphs[0];
        let nv = g.n_vertices;
        // Rebuild the edge list, drop a few edges, add a few, and a vertex.
        let mut edges: Vec<(u32, u32)> =
            (0..g.n_edges()).map(|e| g.edge_endpoints(e)).collect();
        let removed = [edges[3], edges[100], edges[2001]];
        edges.retain(|e| !removed.contains(e));
        let added = [(5u32, 9u32), (17, 9), (0, nv as u32)];
        edges.extend_from_slice(&added);
        let mutated = CsrGraph::from_edges(nv + 1, &edges);
        for &(v, n) in &[(20usize, 20usize), (10, 30), (37, 11)] {
            let mut pm = PartitionMatrix::build_serial(g, v, n);
            let mut changed: Vec<u32> = removed
                .iter()
                .chain(added.iter())
                .map(|&(_, dst)| (dst as usize / v) as u32)
                .collect();
            changed.sort_unstable();
            changed.dedup();
            pm.splice(&mutated, &changed);
            assert_eq!(pm, PartitionMatrix::build_serial(&mutated, v, n), "({v}, {n})");
        }
    }

    #[test]
    fn splice_with_no_changes_is_identity() {
        let d = Dataset::by_name("Cora").unwrap();
        let g = &d.graphs[0];
        let mut pm = PartitionMatrix::build_serial(g, 20, 20);
        let reference = pm.clone();
        pm.splice(g, &[]);
        assert_eq!(pm, reference);
    }

    #[test]
    fn empty_graph_builds_one_empty_group() {
        let g = CsrGraph::from_edges(0, &[]);
        let pm = PartitionMatrix::build(&g, 20, 20);
        assert_eq!(pm.n_output_groups(), 1);
        assert_eq!(pm.nonzero_blocks(), 0);
        assert_eq!(pm.total_edges(), 0);
    }

    #[test]
    fn footprint_counts_vertices_edges_and_blocks() {
        let d = Dataset::by_name("Cora").unwrap();
        let pm = PartitionMatrix::build(&d.graphs[0], 20, 20);
        let feat = 4 * 1433; // f32 features
        let expect = pm.n_vertices as u64 * feat as u64
            + pm.total_edges() * EDGE_DESC_BYTES
            + pm.nonzero_blocks() as u64 * std::mem::size_of::<BlockRef>() as u64;
        assert_eq!(pm.footprint_bytes(feat), expect);
    }

    #[test]
    fn group_range_footprints_are_additive() {
        let d = Dataset::by_name("Citeseer").unwrap();
        let pm = PartitionMatrix::build(&d.graphs[0], 20, 20);
        let n = pm.n_output_groups();
        for &cut in &[0, 1, n / 3, n / 2, n - 1, n] {
            let sum = pm.group_range_footprint_bytes(0..cut, 64)
                + pm.group_range_footprint_bytes(cut..n, 64);
            assert_eq!(sum, pm.footprint_bytes(64), "cut at {cut}");
        }
    }

    #[test]
    fn one_shard_plan_owns_everything_with_zero_exchange() {
        let d = Dataset::by_name("Cora").unwrap();
        let parts = vec![PartitionMatrix::build(&d.graphs[0], 20, 20)];
        let sp = ShardPlan::build(&parts, 1, 64);
        assert_eq!(sp.group_range(0, 0), 0..parts[0].n_output_groups());
        assert_eq!(sp.total_cross_shard_edges(), 0);
        assert_eq!(sp.chip_footprints(), &[parts[0].footprint_bytes(64)]);
    }

    #[test]
    fn shard_ranges_cover_groups_and_balance_footprint() {
        let d = Dataset::by_name("Amazon").unwrap();
        let parts = vec![PartitionMatrix::build(&d.graphs[0], 20, 20)];
        let pm = &parts[0];
        for shards in [2usize, 4, 8] {
            let sp = ShardPlan::build(&parts, shards, 64);
            let mut covered = 0;
            for s in 0..shards {
                let r = sp.group_range(0, s);
                assert_eq!(r.start, covered, "contiguous at shard {s}");
                covered = r.end;
                for g in r {
                    assert_eq!(sp.shard_of_group(0, g), s);
                }
            }
            assert_eq!(covered, pm.n_output_groups());
            // Shard footprints partition the whole graph's footprint.
            let sum: u64 = (0..shards)
                .map(|s| pm.group_range_footprint_bytes(sp.group_range(0, s), 64))
                .sum();
            assert_eq!(sum, pm.footprint_bytes(64));
            // Balanced: no chip holds more than ~2x the fair share.
            assert!(
                sp.max_chip_footprint_bytes() <= 2 * pm.footprint_bytes(64) / shards as u64,
                "{shards} shards: max {} vs total {}",
                sp.max_chip_footprint_bytes(),
                pm.footprint_bytes(64)
            );
        }
    }

    #[test]
    fn exchange_matrix_matches_block_ownership() {
        let d = Dataset::by_name("Citeseer").unwrap();
        let parts = vec![PartitionMatrix::build(&d.graphs[0], 20, 20)];
        let pm = &parts[0];
        let sp = ShardPlan::build(&parts, 4, 64);
        // Recount from scratch: every block's edges land either intra-shard
        // or in exactly one exchange cell.
        let mut intra = 0u64;
        let mut cross = vec![0u64; 16];
        for (grp, blocks) in pm.iter_groups() {
            let s = sp.shard_of_group(0, grp.out_group as usize);
            for b in blocks {
                let t = sp.owner_of_input_group(0, pm, b.input_group as usize);
                if s == t {
                    intra += b.n_edges as u64;
                } else {
                    cross[s * 4 + t] += b.n_edges as u64;
                }
            }
        }
        for s in 0..4 {
            for t in 0..4 {
                assert_eq!(sp.exchange_edges(0, s, t), cross[s * 4 + t], "pair ({s}, {t})");
            }
            assert_eq!(sp.exchange_edges(0, s, s), 0, "diagonal at {s}");
        }
        assert_eq!(intra + sp.cross_shard_edges(0), pm.total_edges());
        assert!(sp.cross_shard_edges(0) > 0, "4-way Citeseer must cross shards");
    }

    #[test]
    fn more_shards_than_groups_leaves_trailing_shards_empty() {
        let g = path_graph(15);
        let parts = vec![PartitionMatrix::build(&g, 20, 20)]; // 1 output group
        let sp = ShardPlan::build(&parts, 4, 16);
        assert_eq!(sp.group_range(0, 0), 0..1);
        for s in 1..4 {
            assert!(sp.group_range(0, s).is_empty());
        }
        assert_eq!(sp.total_cross_shard_edges(), 0);
    }
}
