//! The "buffer & partition" matrix — §3.4.1.
//!
//! Output (destination) vertices are split into groups of `V` and input
//! (source) vertices into groups of `N`; edges fall into `V×N` blocks.
//! Blocks with no edges are *skipped entirely*, which is how GHOST turns
//! extreme adjacency sparsity into dense, prefetchable work units. The
//! partition matrix, the per-group prefetch order, and the per-group
//! worst-case neighbor counts are all computed once offline (graph
//! preprocessing), exactly as in the paper.


use super::csr::CsrGraph;

/// One non-empty `V×N` block of the partition matrix.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BlockRef {
    /// Index of the input-vertex group (column block).
    pub input_group: u32,
    /// Number of edges inside this block.
    pub n_edges: u32,
}

/// Execution plan for one output-vertex group (one assignment of the `V`
/// execution lanes).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct OutputGroupPlan {
    /// Index of the output group.
    pub out_group: u32,
    /// Non-empty input blocks, in ascending input-group (prefetch) order.
    pub blocks: Vec<BlockRef>,
    /// Largest in-degree among the vertices of this group — the aggregate
    /// stage of the group finishes with its slowest lane (§3.3.1).
    pub max_lane_degree: u32,
    /// Total edges aggregated by this group.
    pub total_edges: u32,
    /// Distinct source vertices feeding this group — the number of feature
    /// vectors the buffer-and-partition prefetch actually streams (sources
    /// with several destinations in the group are fetched once).
    pub distinct_sources: u32,
}

/// The full offline partition of one graph for a `(V, N)` configuration.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PartitionMatrix {
    /// Output-group size (`V` execution lanes).
    pub v: usize,
    /// Input-group size (`N` edge-control units).
    pub n: usize,
    /// Vertex count of the partitioned graph.
    pub n_vertices: usize,
    /// Per-output-group plans, ascending group index.
    pub groups: Vec<OutputGroupPlan>,
}

impl PartitionMatrix {
    /// Builds the partition matrix from a destination-major CSR graph.
    /// Runs in `O(E + groups)`: distinct-source counting uses an epoch-
    /// stamped scratch array (no per-group sort), and block discovery
    /// reuses a per-input-group counter array across output groups.
    pub fn build(graph: &CsrGraph, v: usize, n: usize) -> Self {
        assert!(v > 0 && n > 0);
        let n_out_groups = graph.n_vertices.div_ceil(v).max(1);
        let n_in_groups = graph.n_vertices.div_ceil(n).max(1);
        let mut groups = Vec::with_capacity(n_out_groups);
        // Scratch: edge counts per input group, reused across output groups.
        let mut block_edges = vec![0u32; n_in_groups];
        // Scratch: epoch stamps for distinct-source counting; a source is
        // new in this group iff its stamp differs from the group epoch.
        let mut seen_epoch = vec![u32::MAX; graph.n_vertices];
        for og in 0..n_out_groups {
            let lo = og * v;
            let hi = ((og + 1) * v).min(graph.n_vertices);
            let mut max_lane_degree = 0u32;
            let mut total_edges = 0u32;
            let mut distinct_sources = 0u32;
            let mut touched: Vec<u32> = Vec::new();
            let epoch = og as u32;
            for dst in lo..hi {
                let deg = graph.degree(dst) as u32;
                max_lane_degree = max_lane_degree.max(deg);
                total_edges += deg;
                for &src in graph.neighbors(dst) {
                    if seen_epoch[src as usize] != epoch {
                        seen_epoch[src as usize] = epoch;
                        distinct_sources += 1;
                    }
                    let ig = src as usize / n;
                    if block_edges[ig] == 0 {
                        touched.push(ig as u32);
                    }
                    block_edges[ig] += 1;
                }
            }
            touched.sort_unstable();
            let blocks: Vec<BlockRef> = touched
                .iter()
                .map(|&ig| {
                    let e = block_edges[ig as usize];
                    block_edges[ig as usize] = 0; // reset scratch
                    BlockRef { input_group: ig, n_edges: e }
                })
                .collect();
            groups.push(OutputGroupPlan {
                out_group: og as u32,
                blocks,
                max_lane_degree,
                total_edges,
                distinct_sources,
            });
        }
        Self { v, n, n_vertices: graph.n_vertices, groups }
    }

    /// Number of output groups (lane assignments).
    pub fn n_output_groups(&self) -> usize {
        self.groups.len()
    }

    /// Number of input groups.
    pub fn n_input_groups(&self) -> usize {
        self.n_vertices.div_ceil(self.n).max(1)
    }

    /// Total block slots in the dense `V×N` grid.
    pub fn total_block_slots(&self) -> usize {
        self.n_output_groups() * self.n_input_groups()
    }

    /// Non-empty blocks actually fetched.
    pub fn nonzero_blocks(&self) -> usize {
        self.groups.iter().map(|g| g.blocks.len()).sum()
    }

    /// Fraction of block slots skipped by the all-zero-block optimization.
    pub fn skip_ratio(&self) -> f64 {
        if self.total_block_slots() == 0 {
            return 0.0;
        }
        1.0 - self.nonzero_blocks() as f64 / self.total_block_slots() as f64
    }

    /// Total edges covered by the plan (must equal the graph's edge count).
    pub fn total_edges(&self) -> u64 {
        self.groups.iter().map(|g| g.total_edges as u64).sum()
    }

    /// Total distinct-source fetches across all groups — the feature
    /// vectors the BP prefetcher streams from memory (≤ total edges).
    pub fn total_distinct_source_fetches(&self) -> u64 {
        self.groups.iter().map(|g| g.distinct_sources as u64).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets::Dataset;

    fn path_graph(n: usize) -> CsrGraph {
        let edges: Vec<(u32, u32)> = (1..n).map(|i| (i as u32 - 1, i as u32)).collect();
        CsrGraph::from_edges(n, &edges)
    }

    #[test]
    fn covers_all_edges() {
        let g = path_graph(103);
        let pm = PartitionMatrix::build(&g, 20, 20);
        assert_eq!(pm.total_edges(), g.n_edges() as u64);
        assert_eq!(pm.n_output_groups(), 6); // ceil(103/20)
    }

    #[test]
    fn path_graph_blocks_hug_diagonal() {
        let g = path_graph(100);
        let pm = PartitionMatrix::build(&g, 10, 10);
        // A path graph's edges live on the diagonal ± one block.
        for grp in &pm.groups {
            for b in &grp.blocks {
                let diff = (b.input_group as i64 - grp.out_group as i64).abs();
                assert!(diff <= 1, "off-diagonal block {b:?} in group {}", grp.out_group);
            }
        }
        // Massive skip on a path graph.
        assert!(pm.skip_ratio() > 0.7, "skip = {}", pm.skip_ratio());
    }

    #[test]
    fn blocks_in_prefetch_order() {
        let d = Dataset::by_name("Cora").unwrap();
        let pm = PartitionMatrix::build(&d.graphs[0], 20, 20);
        for grp in &pm.groups {
            for w in grp.blocks.windows(2) {
                assert!(w[0].input_group < w[1].input_group);
            }
        }
    }

    #[test]
    fn real_dataset_skips_blocks() {
        let d = Dataset::by_name("Cora").unwrap();
        let pm = PartitionMatrix::build(&d.graphs[0], 20, 20);
        assert_eq!(pm.total_edges(), 10_556);
        // Cora is very sparse: most 20×20 blocks are empty.
        assert!(pm.skip_ratio() > 0.5, "skip = {}", pm.skip_ratio());
    }

    #[test]
    fn max_lane_degree_matches_graph() {
        let d = Dataset::by_name("Citeseer").unwrap();
        let g = &d.graphs[0];
        let pm = PartitionMatrix::build(g, 20, 20);
        let global_max: u32 = pm.groups.iter().map(|gr| gr.max_lane_degree).max().unwrap();
        assert_eq!(global_max as usize, g.max_degree());
    }

    #[test]
    fn distinct_sources_bounded_by_edges() {
        let d = Dataset::by_name("Amazon").unwrap();
        let pm = PartitionMatrix::build(&d.graphs[0], 20, 20);
        for grp in &pm.groups {
            assert!(grp.distinct_sources <= grp.total_edges.max(1));
        }
        assert!(pm.total_distinct_source_fetches() <= pm.total_edges());
        // A hub-heavy graph must show real fetch dedup.
        assert!(pm.total_distinct_source_fetches() < pm.total_edges());
    }

    #[test]
    fn single_group_when_v_exceeds_n_vertices() {
        let g = path_graph(5);
        let pm = PartitionMatrix::build(&g, 100, 100);
        assert_eq!(pm.n_output_groups(), 1);
        assert_eq!(pm.nonzero_blocks(), 1);
    }
}
