//! Compressed-sparse-row directed graph.
//!
//! Edges are stored destination-major (`row = destination vertex`,
//! `cols = source neighbors`) because GHOST's aggregate stage iterates over
//! *output* vertices gathering their in-neighbors.


/// A directed graph in CSR (destination-major) form.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CsrGraph {
    /// Row pointers, length `n_vertices + 1`.
    pub row_ptr: Vec<u32>,
    /// Column (source-neighbor) indices, length `n_edges`.
    pub col_idx: Vec<u32>,
    /// Number of vertices.
    pub n_vertices: usize,
}

impl CsrGraph {
    /// Builds from an edge list of `(src, dst)` pairs. Duplicate edges are
    /// kept (matching how multigraph edge features would be processed);
    /// neighbor lists are sorted by source index.
    pub fn from_edges(n_vertices: usize, edges: &[(u32, u32)]) -> Self {
        let mut degree = vec![0u32; n_vertices];
        for &(_, dst) in edges {
            degree[dst as usize] += 1;
        }
        let mut row_ptr = vec![0u32; n_vertices + 1];
        for v in 0..n_vertices {
            row_ptr[v + 1] = row_ptr[v] + degree[v];
        }
        let mut col_idx = vec![0u32; edges.len()];
        let mut cursor = row_ptr[..n_vertices].to_vec();
        for &(src, dst) in edges {
            let c = &mut cursor[dst as usize];
            col_idx[*c as usize] = src;
            *c += 1;
        }
        for v in 0..n_vertices {
            col_idx[row_ptr[v] as usize..row_ptr[v + 1] as usize].sort_unstable();
        }
        Self { row_ptr, col_idx, n_vertices }
    }

    /// Number of edges.
    pub fn n_edges(&self) -> usize {
        self.col_idx.len()
    }

    /// In-neighbors of vertex `v`.
    pub fn neighbors(&self, v: usize) -> &[u32] {
        &self.col_idx[self.row_ptr[v] as usize..self.row_ptr[v + 1] as usize]
    }

    /// In-degree of vertex `v`.
    pub fn degree(&self, v: usize) -> usize {
        (self.row_ptr[v + 1] - self.row_ptr[v]) as usize
    }

    /// Maximum in-degree over all vertices.
    pub fn max_degree(&self) -> usize {
        (0..self.n_vertices).map(|v| self.degree(v)).max().unwrap_or(0)
    }

    /// Mean in-degree.
    pub fn avg_degree(&self) -> f64 {
        if self.n_vertices == 0 {
            return 0.0;
        }
        self.n_edges() as f64 / self.n_vertices as f64
    }

    /// Density of the adjacency matrix (fraction of non-zeros).
    pub fn density(&self) -> f64 {
        if self.n_vertices == 0 {
            return 0.0;
        }
        self.n_edges() as f64 / (self.n_vertices as f64 * self.n_vertices as f64)
    }

    /// `(src, dst)` endpoints of edge slot `e` (an index into `col_idx`):
    /// the destination is the row owning the slot, found by binary search
    /// over the row pointers. Panics when `e >= n_edges()`.
    pub fn edge_endpoints(&self, e: usize) -> (u32, u32) {
        assert!(e < self.n_edges(), "edge index {e} out of range");
        let dst = self.row_ptr.partition_point(|&p| p as usize <= e) - 1;
        (self.col_idx[e], dst as u32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> CsrGraph {
        // 0→2, 1→2, 2→0, 0→1
        CsrGraph::from_edges(3, &[(0, 2), (1, 2), (2, 0), (0, 1)])
    }

    #[test]
    fn csr_structure() {
        let g = tiny();
        assert_eq!(g.n_edges(), 4);
        assert_eq!(g.neighbors(2), &[0, 1]);
        assert_eq!(g.neighbors(0), &[2]);
        assert_eq!(g.neighbors(1), &[0]);
        assert_eq!(g.degree(2), 2);
        assert_eq!(g.max_degree(), 2);
    }

    #[test]
    fn degree_sums_to_edges() {
        let g = tiny();
        let total: usize = (0..g.n_vertices).map(|v| g.degree(v)).sum();
        assert_eq!(total, g.n_edges());
    }

    #[test]
    fn empty_graph() {
        let g = CsrGraph::from_edges(0, &[]);
        assert_eq!(g.n_edges(), 0);
        assert_eq!(g.max_degree(), 0);
        assert_eq!(g.avg_degree(), 0.0);
    }

    #[test]
    fn duplicate_edges_kept() {
        let g = CsrGraph::from_edges(2, &[(0, 1), (0, 1)]);
        assert_eq!(g.neighbors(1), &[0, 0]);
    }

    #[test]
    fn edge_endpoints_cover_every_slot() {
        let g = tiny();
        let mut recovered: Vec<(u32, u32)> =
            (0..g.n_edges()).map(|e| g.edge_endpoints(e)).collect();
        recovered.sort_unstable();
        let mut expected = vec![(0, 2), (1, 2), (2, 0), (0, 1)];
        expected.sort_unstable();
        assert_eq!(recovered, expected);
    }
}
