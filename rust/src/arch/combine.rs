//! Combine-block cost model (§3.3.2): `V` transform units, each a
//! non-coherent `T_r × R_r` MR-bank MVM array with balanced photodetectors
//! and optional broadband-MR batch normalization.

use super::{ArchContext, StageCost};
use crate::config::ceil_div;

/// Transform-stage cost for one output-vertex group applying a
/// `in_dim → out_width` linear map (heads folded into `out_width`).
///
/// Mapping: the aggregated feature vector rides `R_r` wavelengths; each of
/// the `T_r` rows produces one output feature per pass. A full transform
/// needs `ceil(in_dim/R_r) × ceil(out_width/T_r)` passes. When
/// `in_dim > R_r` the partial products must be converted (ADC) and
/// accumulated digitally between input chunks (§3.3.2's "otherwise the
/// output will need to be converted to the digital domain and buffered").
///
/// `dac_sharing` selects whether one weight-tile conversion is broadcast to
/// all `V` units (shared) or each unit re-converts its copy (unshared —
/// `V×` the conversion energy). `optical_input` marks that the activations
/// arrive directly on the waveguide from the reduce units (no input DACs);
/// GAT's transform-first ordering instead drives inputs electrically.
pub fn transform_cost(
    ctx: &ArchContext,
    in_dim: usize,
    out_width: usize,
    dac_sharing: bool,
    optical_input: bool,
) -> StageCost {
    let cfg = &ctx.cfg;
    let dev = &ctx.dev;
    let in_chunks = ceil_div(in_dim, cfg.r_r);
    let out_chunks = ceil_div(out_width, cfg.t_r);
    let passes = in_chunks * out_chunks;

    let mut latency = dev.eo_tuning.latency_s // weight-tile settle (pipelined)
        + passes as f64 * ctx.symbol_s()
        + dev.photodetector.latency_s; // BPD readout
    if in_chunks > 1 {
        // Partial-sum conversion + buffering per output chunk (pipelined,
        // one ADC latency exposed per chunk boundary).
        latency += out_chunks as f64 * dev.adc.latency_s;
    }

    // Weight-tile conversions: T_r × R_r values per pass.
    let tile_values = (cfg.t_r * cfg.r_r) as f64;
    let weight_conversions =
        passes as f64 * tile_values * if dac_sharing { 1.0 } else { cfg.v as f64 };
    let eo_energy_per_imprint = dev.eo_tuning.power_w * 0.5 * dev.eo_tuning.latency_s;
    let mut energy = weight_conversions * dev.dac.energy_j()
        // Every weight MR in every unit still gets its EO nudge.
        + passes as f64 * tile_values * cfg.v as f64 * eo_energy_per_imprint
        // BPDs active for the stage.
        + (cfg.v * cfg.t_r) as f64 * dev.photodetector.power_w * latency;
    if !optical_input {
        // Inputs imprinted electrically: one DAC conversion per input value
        // per vertex (V vertices in parallel).
        energy += (cfg.v * in_dim) as f64 * dev.dac.energy_j();
    }
    if in_chunks > 1 {
        // ADC + buffer traffic for partial sums: V × out_width values per
        // input chunk.
        let conversions = (cfg.v * out_width * in_chunks) as f64;
        energy += conversions * dev.adc.energy_j()
            + ctx.buffers.output_vertices.stream_energy_j(cfg.v * out_width * in_chunks);
    }
    StageCost { latency_s: latency, energy_j: energy }
}

/// Optional broadband-MR batch-normalization cost: one extra pipelined
/// imprint per output element (bypassed when the model has no BN).
pub fn batchnorm_cost(ctx: &ArchContext, out_width: usize) -> StageCost {
    let dev = &ctx.dev;
    let elements = (ctx.cfg.v * out_width) as f64;
    StageCost {
        latency_s: ctx.symbol_s(),
        energy_j: elements * dev.eo_tuning.power_w * 0.5 * dev.eo_tuning.latency_s,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GhostConfig;

    fn ctx() -> ArchContext {
        ArchContext::paper(GhostConfig::paper_optimal())
    }

    #[test]
    fn passes_scale_with_dims() {
        let c = ctx();
        let small = transform_cost(&c, 16, 7, true, true);
        let wide_in = transform_cost(&c, 1433, 7, true, true);
        let wide_out = transform_cost(&c, 16, 64, true, true);
        assert!(wide_in.latency_s > small.latency_s);
        assert!(wide_out.latency_s > small.latency_s);
    }

    #[test]
    fn dac_sharing_saves_energy_not_time() {
        let c = ctx();
        let shared = transform_cost(&c, 128, 16, true, true);
        let unshared = transform_cost(&c, 128, 16, false, true);
        assert_eq!(shared.latency_s, unshared.latency_s);
        assert!(unshared.energy_j > 2.0 * shared.energy_j);
    }

    #[test]
    fn single_chunk_needs_no_adc() {
        let c = ctx();
        // in_dim ≤ R_r → all-optical path, no ADC latency term.
        let direct = transform_cost(&c, 18, 17, true, true);
        let buffered = transform_cost(&c, 19, 17, true, true);
        assert!(buffered.latency_s > direct.latency_s + c.symbol_s() * 0.5);
    }

    #[test]
    fn electrical_input_costs_more() {
        let c = ctx();
        let optical = transform_cost(&c, 1433, 16, true, true);
        let electrical = transform_cost(&c, 1433, 16, true, false);
        assert!(electrical.energy_j > optical.energy_j);
    }

    #[test]
    fn batchnorm_is_one_symbol() {
        let c = ctx();
        let bn = batchnorm_cost(&c, 16);
        assert_eq!(bn.latency_s, c.symbol_s());
    }
}
