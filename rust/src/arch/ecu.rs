//! Electronic Control Unit: memory interfacing, weight staging, and the
//! platform power breakdown.

use super::{ArchContext, StageCost};

/// ECU digital logic power (partition sequencing, lane control, address
//  generation) — 7 nm-class estimate, watts.
pub const ECU_LOGIC_W: f64 = 1.5;

/// HBM2 PHY + controller standby power, watts.
pub const HBM_INTERFACE_W: f64 = 1.0;

/// Laser (VCSEL array) supply power attributable to always-on sources,
/// watts. Sized from `photonics::laser` for the combine block's 18-λ combs
/// across V units at the paper loss budget.
pub const LASER_SUPPLY_W: f64 = 1.2;

/// Always-on platform power, watts: every biased device plus ECU logic and
/// the memory interface. This is the figure the paper quotes as GHOST's
/// ~18 W power draw (for the DAC-shared configuration).
pub fn platform_power_w(ctx: &ArchContext, dac_sharing: bool) -> f64 {
    let cfg = &ctx.cfg;
    let dev = &ctx.dev;
    // DACs: the aggregate block needs one per reduce-array MR (neighbor
    // values are all distinct), the combine block shares weight DACs across
    // the V transform units when enabled (§3.4.3).
    let aggregate_dacs = cfg.v * cfg.r_r * cfg.r_c;
    let combine_dacs =
        if dac_sharing { cfg.combine_dacs_shared() } else { cfg.combine_dacs_unshared() };
    let dac_w = (aggregate_dacs + combine_dacs) as f64 * dev.dac.power_w;
    // ADCs: one per transform-unit output row.
    let adc_w = (cfg.v * cfg.t_r) as f64 * dev.adc.power_w;
    // VCSELs: reduce-unit sources (R_r per unit) + update-unit drivers.
    let vcsel_w = (cfg.v * (cfg.r_r + cfg.t_r)) as f64 * dev.vcsel.power_w;
    // PDs: recirculation PDs (R_r per reduce unit) + BPDs (T_r per
    // transform unit, two arms).
    let pd_w = (cfg.v * (cfg.r_r + 2 * cfg.t_r)) as f64 * dev.photodetector.power_w;
    // SOAs: T_r per update unit.
    let soa_w = (cfg.v * cfg.t_r) as f64 * dev.soa.power_w;
    let leakage_w = ctx.buffers.total_leakage_w();
    dac_w + adc_w + vcsel_w + pd_w + soa_w + leakage_w + ECU_LOGIC_W + HBM_INTERFACE_W
        + LASER_SUPPLY_W
}

/// Cost of staging one layer's weight matrix from DRAM into the weight
/// buffer (once per layer, amortized across all vertex groups).
pub fn weight_stage_cost(ctx: &ArchContext, weight_bytes: u64) -> StageCost {
    let hbm = &ctx.hbm;
    StageCost {
        latency_s: hbm.stream_time_s(weight_bytes),
        energy_j: hbm.transfer_energy_j(weight_bytes)
            + ctx.buffers.weights.stream_energy_j(weight_bytes as usize),
    }
}

/// Cost of streaming the edge-list/partition descriptors for one graph.
pub fn edge_stage_cost(ctx: &ArchContext, edge_bytes: u64) -> StageCost {
    let hbm = &ctx.hbm;
    StageCost {
        latency_s: hbm.stream_time_s(edge_bytes),
        energy_j: hbm.transfer_energy_j(edge_bytes)
            + ctx.buffers.edges.stream_energy_j(edge_bytes as usize),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GhostConfig;

    #[test]
    fn power_breakdown_components_positive() {
        let ctx = ArchContext::paper(GhostConfig::paper_optimal());
        let p = platform_power_w(&ctx, true);
        assert!(p > ECU_LOGIC_W + HBM_INTERFACE_W + LASER_SUPPLY_W);
    }

    #[test]
    fn weight_staging_scales() {
        let ctx = ArchContext::paper(GhostConfig::paper_optimal());
        let small = weight_stage_cost(&ctx, 1024);
        let big = weight_stage_cost(&ctx, 1024 * 1024);
        assert!(big.latency_s > small.latency_s);
        assert!(big.energy_j > small.energy_j);
    }
}
