//! The GHOST architecture blocks (Fig. 4): aggregate, combine, update, and
//! the electronic control unit.
//!
//! Each block exposes *stage-cost* functions: given one output-vertex
//! group's work (from the partition matrix) and the layer dimensions, they
//! return the latency and dynamic energy of that pipeline stage. The
//! coordinator assembles stage costs into a pipelined schedule
//! ([`crate::sim`]) and adds the platform's always-on power.
//!
//! Timing convention: analog values are imprinted through a pipelined
//! DAC → EO-tune chain, so a bank performs one *pass* (a full parallel
//! MAC/sum across its MRs) per symbol period
//! ([`crate::config::SYMBOL_RATE_HZ`], 1 GHz, set by the 8-bit converters),
//! after a one-time EO settle (20 ns) when the bank is retargeted.

pub mod aggregate;
pub mod combine;
pub mod ecu;
pub mod update;


use crate::config::GhostConfig;
use crate::memory::hbm::Hbm2;
use crate::memory::sram::EcuBuffers;
use crate::photonics::devices::DeviceParams;

/// Latency + dynamic energy of one pipeline stage.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct StageCost {
    pub latency_s: f64,
    pub energy_j: f64,
}

impl StageCost {
    pub const ZERO: StageCost = StageCost { latency_s: 0.0, energy_j: 0.0 };

    /// Sequential composition: latencies and energies add.
    pub fn then(self, other: StageCost) -> StageCost {
        StageCost {
            latency_s: self.latency_s + other.latency_s,
            energy_j: self.energy_j + other.energy_j,
        }
    }

    /// Parallel composition: max latency, summed energy.
    pub fn alongside(self, other: StageCost) -> StageCost {
        StageCost {
            latency_s: self.latency_s.max(other.latency_s),
            energy_j: self.energy_j + other.energy_j,
        }
    }
}

/// Inter-chip photonic link parameters for sharded multi-chip execution.
///
/// GHOST's datapath is already photonic, so chip-to-chip traffic rides the
/// same silicon-photonics substrate: a WDM fiber/waveguide link between
/// HBM-adjacent serializers. The defaults are conservative published
/// figures for co-packaged optical I/O — 256 GB/s per direction, 250 ns
/// end-to-end (serialize + time-of-flight + deserialize), 1 pJ/bit.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkParams {
    /// Sustained per-direction bandwidth, bytes/second.
    pub bandwidth_bytes_per_s: f64,
    /// Fixed per-transfer latency, seconds.
    pub latency_s: f64,
    /// Transfer energy, joules per bit.
    pub energy_per_bit_j: f64,
}

impl LinkParams {
    pub fn paper() -> Self {
        Self { bandwidth_bytes_per_s: 256.0e9, latency_s: 250.0e-9, energy_per_bit_j: 1.0e-12 }
    }

    /// Cost of moving `bytes` across the link as one transfer.
    pub fn transfer_cost(&self, bytes: u64) -> StageCost {
        StageCost {
            latency_s: self.latency_s + bytes as f64 / self.bandwidth_bytes_per_s,
            energy_j: bytes as f64 * 8.0 * self.energy_per_bit_j,
        }
    }
}

impl Default for LinkParams {
    fn default() -> Self {
        Self::paper()
    }
}

/// Everything the block cost models need, bundled.
#[derive(Debug, Clone, Copy)]
pub struct ArchContext {
    pub cfg: GhostConfig,
    pub dev: DeviceParams,
    pub buffers: EcuBuffers,
    pub hbm: Hbm2,
    /// Inter-chip link used by sharded (multi-chip) plans.
    pub link: LinkParams,
}

impl ArchContext {
    pub fn paper(cfg: GhostConfig) -> Self {
        Self {
            cfg,
            dev: DeviceParams::paper(),
            buffers: EcuBuffers::paper(),
            hbm: Hbm2::paper(),
            link: LinkParams::paper(),
        }
    }

    /// One symbol period of the analog datapath, seconds.
    pub fn symbol_s(&self) -> f64 {
        1.0 / crate::config::SYMBOL_RATE_HZ
    }
}

/// Always-on platform power, watts — lasers, converter bias, PD/SOA bias,
/// buffer leakage, and ECU logic. This is the power the paper quotes as
/// "relatively low power consumption of 18 W" for the optimized (DAC-shared)
/// configuration; see `ecu::platform_power_w` for the component breakdown.
pub fn platform_power_w(ctx: &ArchContext, dac_sharing: bool) -> f64 {
    ecu::platform_power_w(ctx, dac_sharing)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_cost_composition() {
        let a = StageCost { latency_s: 1.0, energy_j: 2.0 };
        let b = StageCost { latency_s: 3.0, energy_j: 4.0 };
        let seq = a.then(b);
        assert_eq!(seq.latency_s, 4.0);
        assert_eq!(seq.energy_j, 6.0);
        let par = a.alongside(b);
        assert_eq!(par.latency_s, 3.0);
        assert_eq!(par.energy_j, 6.0);
    }

    #[test]
    fn link_transfer_cost_scales_with_volume() {
        let link = LinkParams::paper();
        let small = link.transfer_cost(1 << 10);
        let big = link.transfer_cost(1 << 20);
        assert!(big.latency_s > small.latency_s);
        assert!(small.latency_s >= link.latency_s);
        assert_eq!(big.energy_j, (1u64 << 20) as f64 * 8.0 * link.energy_per_bit_j);
        // A zero-byte transfer still pays the fixed link latency.
        assert_eq!(link.transfer_cost(0).latency_s, link.latency_s);
        assert_eq!(link.transfer_cost(0).energy_j, 0.0);
    }

    #[test]
    fn paper_platform_power_near_18w() {
        let ctx = ArchContext::paper(GhostConfig::paper_optimal());
        let p = platform_power_w(&ctx, true);
        // The paper quotes 18 W for the DAC-shared configuration.
        assert!((p - 18.0).abs() < 3.0, "platform power = {p} W");
    }

    #[test]
    fn dac_sharing_cuts_platform_power() {
        let ctx = ArchContext::paper(GhostConfig::paper_optimal());
        let shared = platform_power_w(&ctx, true);
        let unshared = platform_power_w(&ctx, false);
        assert!(unshared > 1.5 * shared, "shared={shared}, unshared={unshared}");
    }
}
