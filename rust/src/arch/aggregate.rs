//! Aggregate-block cost model (§3.3.1): `N` edge-control units, `V` gather
//! units, and `V` reduce units (coherent-summation arrays of `R_r × R_c`
//! MRs plus one recirculation MR per feature row).

use super::{ArchContext, StageCost};
use crate::config::ceil_div;
use crate::gnn::models::Reduction;
use crate::graph::partition::OutputGroupPlan;

/// Gather-stage cost for one output-vertex group.
///
/// * With buffer-and-partition (`bp = true`): the offline prefetch order
///   lets the ECU *stream* exactly the distinct source-vertex feature
///   vectors the group needs (plus its edge block descriptors); DRAM
///   latency is overlapped by prefetching the next block.
/// * Without (`bp = false`, the Fig. 8 baseline): each gather unit requests
///   its lane's neighbors sequentially and on demand; the stage ends with
///   the slowest lane, and every request pays full DRAM access latency at
///   the random-access bandwidth plus the per-burst activation energy.
pub fn gather_cost(
    ctx: &ArchContext,
    group: &OutputGroupPlan,
    feat_bytes_per_vertex: usize,
    bp: bool,
) -> StageCost {
    let hbm = &ctx.hbm;
    let buf = &ctx.buffers.input_vertices;
    if bp {
        let bytes = group.distinct_sources as u64 * feat_bytes_per_vertex as u64
            + group.n_blocks as u64 * 8; // block descriptors
        let latency = bytes as f64 / hbm.sustained_bw()
            + hbm.access_latency_s // first-block fill; rest is prefetched
            + buf.access_latency_s;
        let energy = hbm.transfer_energy_j(bytes)
            + hbm.burst_overhead_j * group.n_blocks as f64
            + buf.stream_energy_j(bytes as usize) * 2.0; // write + read
        StageCost { latency_s: latency, energy_j: energy }
    } else {
        let per_fetch_latency = hbm.access_latency_s
            + feat_bytes_per_vertex as f64
                / (hbm.peak_bw_bytes_per_s * hbm.random_efficiency);
        // Slowest lane serializes its neighbor fetches.
        let latency = group.max_lane_degree as f64 * per_fetch_latency + buf.access_latency_s;
        let bytes = group.total_edges as u64 * feat_bytes_per_vertex as u64;
        let energy = hbm.transfer_energy_j(bytes)
            + hbm.burst_overhead_j * group.total_edges as f64
            + buf.stream_energy_j(bytes as usize) * 2.0;
        StageCost { latency_s: latency, energy_j: energy }
    }
}

/// Reduce-stage cost for one output-vertex group aggregating `agg_dim`
/// features per vertex.
///
/// The coherent array sums `R_c` neighbors × `R_r` features per pass; a
/// vertex with `d` neighbors needs `ceil(d / R_c)` passes (the recirculation
/// MR carries the partial sum between passes) and `ceil(agg_dim / R_r)`
/// feature chunks. Without workload balancing the group runs at its
/// slowest lane (`max_lane_degree`); with it (`wb = true`), finished lanes
/// absorb the remainder so the effective depth approaches the group mean,
/// plus a 10 % redistribution overhead (§3.4.4).
pub fn reduce_cost(
    ctx: &ArchContext,
    group: &OutputGroupPlan,
    agg_dim: usize,
    reduction: Reduction,
    wb: bool,
) -> StageCost {
    let cfg = &ctx.cfg;
    let dev = &ctx.dev;
    let effective_degree = if wb {
        let mean = group.total_edges as f64 / cfg.v as f64;
        (mean * 1.10).max(1.0)
    } else {
        (group.max_lane_degree as f64).max(1.0)
    };
    let passes = (effective_degree / cfg.r_c as f64).ceil() as usize;
    let chunks = ceil_div(agg_dim, cfg.r_r);
    // Mean divides by n via the trailing MR (one extra pipelined imprint);
    // max routes through the optical comparator with the same pass count.
    let extra_pass = match reduction {
        Reduction::Mean => 1,
        Reduction::Sum | Reduction::Max => 0,
    };
    let total_passes = passes * chunks + extra_pass;
    let latency = dev.eo_tuning.latency_s // bank retarget settle (pipelined after fill)
        + total_passes as f64 * ctx.symbol_s()
        + dev.photodetector.latency_s; // recirculation PD at chunk boundaries
    // Imprint energy: each aggregated value is one DAC conversion + one EO
    // nudge on its MR. Values = edges × features for the group.
    let values = group.total_edges as f64 * agg_dim as f64;
    let eo_energy_per_imprint = dev.eo_tuning.power_w * 0.5 * dev.eo_tuning.latency_s; // ~0.5 nm avg shift
    let energy = values * (dev.dac.energy_j() + eo_energy_per_imprint)
        // VCSELs + recirculation PDs active for the stage duration.
        + (cfg.v * cfg.r_r) as f64 * (dev.vcsel.power_w + dev.photodetector.power_w) * latency;
    StageCost { latency_s: latency, energy_j: energy }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GhostConfig;

    fn ctx() -> ArchContext {
        ArchContext::paper(GhostConfig::paper_optimal())
    }

    fn group(max_deg: u32, edges: u32, distinct: u32, blocks: usize) -> OutputGroupPlan {
        OutputGroupPlan {
            out_group: 0,
            n_blocks: blocks as u32,
            max_lane_degree: max_deg,
            total_edges: edges,
            distinct_sources: distinct,
        }
    }

    #[test]
    fn bp_gather_faster_than_on_demand() {
        let c = ctx();
        let g = group(30, 100, 80, 5);
        let bp = gather_cost(&c, &g, 1433, true);
        let od = gather_cost(&c, &g, 1433, false);
        assert!(bp.latency_s < od.latency_s, "bp={} od={}", bp.latency_s, od.latency_s);
        assert!(bp.energy_j < od.energy_j);
    }

    #[test]
    fn reduce_scales_with_degree_and_dim() {
        let c = ctx();
        let small = reduce_cost(&c, &group(5, 50, 40, 3), 16, Reduction::Sum, false);
        let deep = reduce_cost(&c, &group(50, 500, 400, 3), 16, Reduction::Sum, false);
        let wide = reduce_cost(&c, &group(5, 50, 40, 3), 1433, Reduction::Sum, false);
        assert!(deep.latency_s > small.latency_s);
        assert!(wide.latency_s > small.latency_s);
    }

    #[test]
    fn workload_balancing_helps_skewed_groups() {
        let c = ctx();
        // One lane with 100 neighbors, group total 150 → mean 7.5 ≪ 100.
        let g = group(100, 150, 120, 4);
        let without = reduce_cost(&c, &g, 64, Reduction::Sum, false);
        let with = reduce_cost(&c, &g, 64, Reduction::Sum, true);
        assert!(with.latency_s < without.latency_s);
    }

    #[test]
    fn mean_costs_one_extra_pass() {
        let c = ctx();
        let g = group(7, 70, 60, 3);
        let sum = reduce_cost(&c, &g, 18, Reduction::Sum, false);
        let mean = reduce_cost(&c, &g, 18, Reduction::Mean, false);
        assert!((mean.latency_s - sum.latency_s - c.symbol_s()).abs() < 1e-15);
    }
}
