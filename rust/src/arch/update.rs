//! Update-block cost model (§3.3.3): `V` update units of `T_r` SOA-based
//! activate rows, plus the digital LUT softmax unit [37] for functions that
//! resist optical implementation.

use super::{ArchContext, StageCost};
use crate::config::ceil_div;
use crate::gnn::models::Activation;

/// Energy of one digital LUT softmax element (lookup + add/sub datapath at
/// 7 nm) — CACTI-class estimate.
pub const SOFTMAX_ENERGY_PER_OP_J: f64 = 5e-12;

/// Update-stage cost for one output-vertex group producing `out_width`
/// activated features per vertex.
///
/// * ReLU / LeakyReLU: the transform output drives VCSELs whose light
///   passes through SOAs — fully pipelined, `ceil(out_width/T_r)` passes.
/// * Softmax: routed to the digital unit; `softmax_elems` elements are
///   processed at 294 MHz, one element per cycle per lane.
/// * None: pass-through (final-layer logits go straight to the buffer).
pub fn update_cost(
    ctx: &ArchContext,
    activation: Activation,
    out_width: usize,
    softmax_elems_per_group: usize,
) -> StageCost {
    let cfg = &ctx.cfg;
    let dev = &ctx.dev;
    match activation {
        Activation::Relu | Activation::LeakyRelu => {
            let passes = ceil_div(out_width, cfg.t_r);
            let latency = passes as f64 * ctx.symbol_s() + dev.soa.latency_s;
            let elements = (cfg.v * out_width) as f64;
            let energy = elements * (dev.vcsel.energy_j() + dev.soa.energy_j());
            StageCost { latency_s: latency, energy_j: energy }
        }
        Activation::Softmax => {
            // V lanes each own a softmax pipeline; elements are spread
            // across lanes.
            let per_lane = ceil_div(softmax_elems_per_group.max(1), cfg.v);
            let latency = per_lane as f64 / dev.softmax_freq_hz + dev.adc.latency_s;
            let energy = softmax_elems_per_group as f64
                * (SOFTMAX_ENERGY_PER_OP_J + dev.adc.energy_j());
            StageCost { latency_s: latency, energy_j: energy }
        }
        Activation::None => StageCost::ZERO,
    }
}

/// Cost of writing the group's updated vertex features back to the
/// intermediate vertex buffer (ADC conversion + SRAM write).
pub fn writeback_cost(ctx: &ArchContext, out_width: usize) -> StageCost {
    let dev = &ctx.dev;
    let values = ctx.cfg.v * out_width;
    StageCost {
        latency_s: dev.adc.latency_s + ctx.buffers.output_vertices.access_latency_s,
        energy_j: values as f64 * dev.adc.energy_j()
            + ctx.buffers.output_vertices.stream_energy_j(values),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GhostConfig;

    fn ctx() -> ArchContext {
        ArchContext::paper(GhostConfig::paper_optimal())
    }

    #[test]
    fn relu_is_fast_and_cheap() {
        let c = ctx();
        let relu = update_cost(&c, Activation::Relu, 16, 0);
        assert!(relu.latency_s < 10e-9);
        assert!(relu.energy_j > 0.0);
    }

    #[test]
    fn softmax_much_slower_than_relu() {
        let c = ctx();
        let relu = update_cost(&c, Activation::Relu, 16, 0);
        // 800 neighbor-logits per group through the 294 MHz unit.
        let sm = update_cost(&c, Activation::Softmax, 16, 800);
        assert!(sm.latency_s > 10.0 * relu.latency_s, "sm={} relu={}", sm.latency_s, relu.latency_s);
    }

    #[test]
    fn none_activation_is_free() {
        let c = ctx();
        assert_eq!(update_cost(&c, Activation::None, 64, 0), StageCost::ZERO);
    }

    #[test]
    fn writeback_scales_with_width() {
        let c = ctx();
        let narrow = writeback_cost(&c, 7);
        let wide = writeback_cost(&c, 64);
        assert!(wide.energy_j > narrow.energy_j);
    }
}
