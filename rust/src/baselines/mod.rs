//! Analytic roofline models of the nine comparison platforms (§4.6):
//! GRIP, HyGCN, EnGN, HW_ACC, ReGNN, ReGraphX, TPU v4, Xeon CPU, A100 GPU.
//!
//! We cannot re-run the authors' testbeds, so each platform is modeled as a
//! roofline driven by the *same* workload characterization GHOST uses:
//!
//! `latency = n_graphs · overhead + max(dense/(peak·u_d) + sparse/(peak·u_s),
//!            bytes/bw)`,  `energy = power · latency`.
//!
//! `peak`, `power`, and `bw` come from each platform's published
//! specification; the effective utilizations (`u_d` dense, `u_s` sparse)
//! and per-inference overheads are *calibrated* so the GHOST-vs-platform
//! ratios land near the paper's reported averages (Figs. 10–12). The
//! calibration lives entirely in [`PLATFORMS`]; the ratios' *shape* across
//! models/datasets (who wins where, the GIN-overhead effect, the
//! GPU/CPU/TPU cluster) emerges from the shared workload model.


use crate::energy::Metrics;
use crate::gnn::workload::Workload;

/// A comparison platform's roofline parameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PlatformSpec {
    pub name: &'static str,
    /// Peak throughput, ops/s (published spec, int8/fp16 as appropriate).
    pub peak_ops_per_s: f64,
    /// Wall power while busy, watts (published TDP / reported power).
    pub power_w: f64,
    /// Memory bandwidth, bytes/s.
    pub mem_bw_bytes_per_s: f64,
    /// Effective utilization on the dense combine phase.
    pub util_dense: f64,
    /// Effective utilization on sparse aggregation / attention phases.
    pub util_sparse: f64,
    /// Fixed overhead per inference invocation (framework dispatch, kernel
    /// launch, graph setup) — dominates the many-small-graph GIN datasets.
    pub overhead_s: f64,
}

/// The nine platforms, in the paper's comparison order.
///
/// Peak throughput, power, and bandwidth are published specifications
/// (ReGNN/ReGraphX power includes the ReRAM periphery the papers charge to
/// the accelerator). The utilizations/overheads are calibrated to the
/// paper's *measured* GHOST-vs-platform throughput ratios (Fig. 10); the
/// EPB ratios (Fig. 11) then follow from the published powers under our
/// uniform bit convention — see EXPERIMENTS.md for where that deviates
/// from the paper's vendor-reported-EPB accounting (notably HW_ACC).
pub const PLATFORMS: [PlatformSpec; 9] = [
    // GRIP [23]: 28 nm ASIC, specialized edge/vertex units.
    PlatformSpec { name: "GRIP", peak_ops_per_s: 2.0e12, power_w: 4.9, mem_bw_bytes_per_s: 128e9, util_dense: 7.0e-3, util_sparse: 1.9e-3, overhead_s: 4e-6 },
    // HyGCN [22]: hybrid aggregation+combination engines, 32×128 MACs;
    // severely underutilized on small sparse graphs (their own analysis).
    PlatformSpec { name: "HyGCN", peak_ops_per_s: 9.2e12, power_w: 6.7, mem_bw_bytes_per_s: 256e9, util_dense: 5.1e-4, util_sparse: 1.2e-4, overhead_s: 8e-6 },
    // EnGN [21]: unified dataflow, ring-edge-reduce; best electronic EPB.
    PlatformSpec { name: "EnG", peak_ops_per_s: 4.1e12, power_w: 2.56, mem_bw_bytes_per_s: 256e9, util_dense: 7.7e-3, util_sparse: 2.2e-3, overhead_s: 3e-6 },
    // HW_ACC [20]: tiled AGG/DNA modules; closest GOPS to GHOST.
    PlatformSpec { name: "HW_ACC", peak_ops_per_s: 0.8e12, power_w: 11.0, mem_bw_bytes_per_s: 128e9, util_dense: 0.315, util_sparse: 0.108, overhead_s: 2e-6 },
    // ReGNN [24]: ReRAM analog+digital PIM.
    PlatformSpec { name: "ReGNN", peak_ops_per_s: 1.4e12, power_w: 27.0, mem_bw_bytes_per_s: 192e9, util_dense: 0.070, util_sparse: 0.022, overhead_s: 3e-6 },
    // ReGraphX [25]: 3D ReRAM, training-oriented (inference inefficient).
    PlatformSpec { name: "ReGraphX", peak_ops_per_s: 1.1e12, power_w: 45.0, mem_bw_bytes_per_s: 192e9, util_dense: 8.1e-3, util_sparse: 2.0e-3, overhead_s: 6e-6 },
    // TPU v4: 275 TOPS int8, but batch-1 tiny-graph GNNs leave the MXU
    // idle and pay full host-dispatch per graph.
    PlatformSpec { name: "TPU", peak_ops_per_s: 275e12, power_w: 170.0, mem_bw_bytes_per_s: 1200e9, util_dense: 1.66e-5, util_sparse: 1.66e-6, overhead_s: 21.7e-3 },
    // Xeon CPU: PyG/framework-inclusive effective throughput.
    PlatformSpec { name: "CPU", peak_ops_per_s: 3.2e12, power_w: 150.0, mem_bw_bytes_per_s: 100e9, util_dense: 8.7e-4, util_sparse: 8.7e-5, overhead_s: 4.3e-3 },
    // NVIDIA A100: 312 TOPS int8 peak; kernel-launch bound on small graphs.
    PlatformSpec { name: "GPU", peak_ops_per_s: 312e12, power_w: 250.0, mem_bw_bytes_per_s: 1555e9, util_dense: 4.9e-5, util_sparse: 4.9e-6, overhead_s: 9.7e-3 },
];

/// Which models each platform supports, per §4.6 (comparisons are made
/// only on supported models).
pub fn supports(platform: &str, model: crate::gnn::models::ModelKind) -> bool {
    use crate::gnn::models::ModelKind::*;
    match platform {
        "GRIP" | "HyGCN" => matches!(model, Gcn | GraphSage | Gin),
        "EnG" => matches!(model, Gcn | GraphSage),
        "HW_ACC" => matches!(model, Gcn | Gat),
        "ReGNN" | "ReGraphX" => matches!(model, Gcn | GraphSage),
        "TPU" | "CPU" | "GPU" => true,
        _ => false,
    }
}

/// Look a platform up by name.
pub fn platform_by_name(name: &str) -> Option<PlatformSpec> {
    PLATFORMS.iter().copied().find(|p| p.name.eq_ignore_ascii_case(name))
}

/// Evaluate a workload on a platform roofline.
pub fn run_baseline(spec: &PlatformSpec, w: &Workload) -> Metrics {
    // Dense = linear transforms; sparse = aggregation + attention +
    // softmax + readout.
    let dense_ops: u64 = w.per_layer.iter().map(|l| 2 * l.comb_macs).sum();
    let total = w.total_ops();
    let sparse_ops = total.saturating_sub(dense_ops);
    let compute_s = dense_ops as f64 / (spec.peak_ops_per_s * spec.util_dense)
        + sparse_ops as f64 / (spec.peak_ops_per_s * spec.util_sparse);
    let memory_s = w.total_bytes() as f64 / spec.mem_bw_bytes_per_s;
    let latency = w.n_graphs as f64 * spec.overhead_s + compute_s.max(memory_s);
    Metrics {
        latency_s: latency,
        energy_j: spec.power_w * latency,
        ops: total,
        bits: w.total_bits(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gnn::models::{Model, ModelKind};
    use crate::graph::datasets::Dataset;

    fn workload(kind: ModelKind, ds: &str) -> Workload {
        let dataset = Dataset::by_name(ds).unwrap();
        let model = Model::for_dataset(kind, &dataset.spec);
        Workload::characterize(&model, &dataset)
    }

    #[test]
    fn nine_platforms() {
        assert_eq!(PLATFORMS.len(), 9);
        assert!(platform_by_name("hygcn").is_some());
        assert!(platform_by_name("nope").is_none());
    }

    #[test]
    fn support_matrix_matches_paper() {
        use crate::gnn::models::ModelKind::*;
        assert!(supports("GRIP", Gin));
        assert!(!supports("GRIP", Gat));
        assert!(supports("EnG", GraphSage));
        assert!(!supports("EnG", Gin));
        assert!(supports("HW_ACC", Gat));
        assert!(!supports("HW_ACC", Gin));
        assert!(supports("TPU", Gat));
    }

    #[test]
    fn baselines_produce_positive_metrics() {
        let w = workload(ModelKind::Gcn, "Cora");
        for p in &PLATFORMS {
            let m = run_baseline(p, &w);
            assert!(m.latency_s > 0.0 && m.energy_j > 0.0, "{}", p.name);
            assert!(m.gops() > 0.0);
        }
    }

    #[test]
    fn overhead_dominates_gin_on_commodity_platforms() {
        let w = workload(ModelKind::Gin, "Proteins");
        let tpu = platform_by_name("TPU").unwrap();
        let m = run_baseline(&tpu, &w);
        // 1113 graphs × 9 ms overhead ≈ 10 s — overhead-bound.
        assert!(m.latency_s > 0.9 * w.n_graphs as f64 * tpu.overhead_s);
    }

    #[test]
    fn accelerators_beat_commodity_on_gcn() {
        let w = workload(ModelKind::Gcn, "Cora");
        let hw = run_baseline(&platform_by_name("HW_ACC").unwrap(), &w);
        let cpu = run_baseline(&platform_by_name("CPU").unwrap(), &w);
        assert!(hw.gops() > cpu.gops());
        assert!(hw.epb() < cpu.epb());
    }
}
