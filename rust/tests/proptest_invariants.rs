//! Randomized property tests over the coordinator invariants (routing,
//! batching/partitioning, scheduling, quantization). proptest is not
//! available offline, so properties are driven by the in-crate
//! deterministic PCG generator with many sampled cases per property.

use ghost::config::GhostConfig;
use ghost::coordinator::{build_sharded, evaluate_sharded, simulate_workload, OptFlags};
use ghost::gnn::models::ModelKind;
use ghost::gnn::quant;
use ghost::graph::csr::CsrGraph;
use ghost::graph::datasets::{
    generate_rmat_graph, generate_skewed_graph, Dataset, DatasetSpec, GraphGen, Task,
};
use ghost::graph::partition::{PartitionMatrix, ShardPlan};
use ghost::sim;
use ghost::util::rng::Pcg64;

const CASES: usize = 60;

fn random_graph(rng: &mut Pcg64) -> CsrGraph {
    let n = rng.gen_range(2, 400);
    let e = rng.gen_range(1, 4 * n);
    let cap = rng.gen_range(2, 64);
    generate_skewed_graph(n, e, cap, rng)
}

#[test]
fn prop_partition_conserves_edges_and_orders_blocks() {
    let mut rng = Pcg64::seed_from_u64(101);
    for _ in 0..CASES {
        let g = random_graph(&mut rng);
        let v = rng.gen_range(1, 50);
        let n = rng.gen_range(1, 50);
        let pm = PartitionMatrix::build(&g, v, n);
        assert_eq!(pm.total_edges(), g.n_edges() as u64);
        for (grp, blocks) in pm.iter_groups() {
            for w in blocks.windows(2) {
                assert!(w[0].input_group < w[1].input_group, "prefetch order violated");
            }
            let block_sum: u32 = blocks.iter().map(|b| b.n_edges).sum();
            assert_eq!(block_sum, grp.total_edges);
            assert!(grp.distinct_sources <= grp.total_edges.max(1));
        }
        let skip = pm.skip_ratio();
        assert!((0.0..=1.0).contains(&skip));
    }
}

#[test]
fn prop_partition_max_degree_matches_graph() {
    let mut rng = Pcg64::seed_from_u64(202);
    for _ in 0..CASES {
        let g = random_graph(&mut rng);
        let pm = PartitionMatrix::build(&g, rng.gen_range(1, 30), rng.gen_range(1, 30));
        let plan_max = pm.groups.iter().map(|gr| gr.max_lane_degree).max().unwrap_or(0);
        assert_eq!(plan_max as usize, g.max_degree());
    }
}

#[test]
fn prop_pipelined_never_slower_than_sequential_and_bounded() {
    let mut rng = Pcg64::seed_from_u64(303);
    for _ in 0..CASES {
        let n_groups = rng.gen_range(1, 40);
        let n_stages = rng.gen_range(1, 6);
        let groups: Vec<Vec<f64>> = (0..n_groups)
            .map(|_| (0..n_stages).map(|_| rng.next_f64() * 10.0).collect())
            .collect();
        let p = sim::pipelined(&groups).expect("uniform stage counts");
        let s = sim::sequential(&groups);
        assert!(p.makespan_s <= s.makespan_s + 1e-9, "pipeline slower than sequential");
        // Lower bound: the slowest single stage column.
        let stage_totals = sim::stage_totals(&groups).expect("uniform stage counts");
        let bottleneck = stage_totals.iter().cloned().fold(0.0, f64::max);
        assert!(p.makespan_s >= bottleneck - 1e-9, "pipeline beats its bottleneck");
        // Conservation: total busy time is schedule-invariant.
        assert!((p.total_stage_time_s - s.total_stage_time_s).abs() < 1e-9);
    }
}

#[test]
fn prop_pipelined_critical_path_bounds() {
    // The pipelined makespan is sandwiched between its critical-path lower
    // bounds and the sequential upper bound:
    //
    //   max(max_s Σ_g t[g][s],  max_g Σ_s t[g][s])  ≤  makespan  ≤  Σ t
    //
    // The first bound is the bottleneck *stage column* — a stage is one
    // physical block, so every group serializes through it; the second is
    // the slowest single *group* — its stages are chained by the
    // same-group dependency. Skewed magnitudes (spanning ~4 orders) stress
    // the DP harder than the uniform samples of the older property.
    let mut rng = Pcg64::seed_from_u64(909);
    for _ in 0..CASES {
        let n_groups = rng.gen_range(1, 60);
        let n_stages = rng.gen_range(1, 8);
        let groups: Vec<Vec<f64>> = (0..n_groups)
            .map(|_| {
                (0..n_stages)
                    .map(|_| rng.next_f64() * 10f64.powi(rng.gen_range(0, 5) as i32 - 2))
                    .collect()
            })
            .collect();
        let p = sim::pipelined(&groups).expect("uniform stage counts");
        let seq = sim::sequential(&groups);
        let column_bound = sim::stage_totals(&groups)
            .expect("uniform stage counts")
            .iter()
            .cloned()
            .fold(0.0, f64::max);
        let group_bound =
            groups.iter().map(|g| g.iter().sum::<f64>()).fold(0.0, f64::max);
        let lower = column_bound.max(group_bound);
        let tol = 1e-12 * seq.makespan_s.max(1.0);
        assert!(
            p.makespan_s >= lower - tol,
            "makespan {} beats critical path {lower} (column {column_bound}, group {group_bound})",
            p.makespan_s
        );
        assert!(
            p.makespan_s <= seq.makespan_s + tol,
            "makespan {} exceeds sequential sum {}",
            p.makespan_s,
            seq.makespan_s
        );
    }
}

#[test]
fn prop_cost_schedule_consistent_with_latency_schedule() {
    // The generalized StageCost evaluation (what the typed schedule IR
    // runs) must agree with the latency-only recurrence on every random
    // schedule: identical makespan, per-position busy totals equal to
    // stage_totals, and total energy equal to the flat stage-energy sum.
    use ghost::arch::StageCost;
    let mut rng = Pcg64::seed_from_u64(1010);
    for _ in 0..CASES {
        let n_groups = rng.gen_range(1, 40);
        let n_stages = rng.gen_range(1, 6);
        let groups: Vec<Vec<StageCost>> = (0..n_groups)
            .map(|_| {
                (0..n_stages)
                    .map(|_| StageCost {
                        latency_s: rng.next_f64() * 10.0,
                        energy_j: rng.next_f64() * 3.0,
                    })
                    .collect()
            })
            .collect();
        let views: Vec<&[StageCost]> = groups.iter().map(|g| g.as_slice()).collect();
        let latencies: Vec<Vec<f64>> =
            groups.iter().map(|g| g.iter().map(|c| c.latency_s).collect()).collect();

        let c = sim::pipelined_costs(&views).expect("uniform stage counts");
        let l = sim::pipelined(&latencies).expect("uniform stage counts");
        assert_eq!(c.makespan_s, l.makespan_s, "cost/latency makespan diverged");
        assert_eq!(c.total_stage_time_s, l.total_stage_time_s);
        assert_eq!(c.stage_busy_s, sim::stage_totals(&latencies).unwrap());

        let cs = sim::sequential_costs(&views);
        let ls = sim::sequential(&latencies);
        assert_eq!(cs.makespan_s, ls.makespan_s);

        // Energy conservation: schedule-invariant, equal to the flat sum
        // of every stage's energy (tolerance for re-association only).
        let flat: f64 = groups.iter().flat_map(|g| g.iter()).map(|s| s.energy_j).sum();
        assert!((c.energy_j - flat).abs() <= 1e-9 * flat.max(1e-30));
        assert!((cs.energy_j - c.energy_j).abs() <= 1e-9 * flat.max(1e-30));
        let pos_sum: f64 = c.stage_energy_j.iter().sum();
        assert!((pos_sum - flat).abs() <= 1e-9 * flat.max(1e-30));
    }
}

#[test]
fn prop_quantization_round_trip_error_bounded() {
    let mut rng = Pcg64::seed_from_u64(404);
    for _ in 0..CASES {
        let len = rng.gen_range(1, 300);
        let scale_mag = 10f32.powf(rng.gen_range_f64(-3.0, 3.0) as f32);
        let data: Vec<f32> =
            (0..len).map(|_| (rng.next_f32() - 0.5) * 2.0 * scale_mag).collect();
        let s = quant::scale_for(&data);
        for &x in &data {
            let rt = quant::dequantize(quant::quantize(x, s), s);
            assert!(
                (rt - x).abs() <= quant::max_error(s) + 1e-6 * scale_mag,
                "x={x}, rt={rt}, scale={s}"
            );
        }
    }
}

#[test]
fn prop_simulator_monotone_in_optimizations() {
    // Any single optimization must not hurt (energy) on any random small
    // dataset: BP ≤ baseline, BP+PP ≤ BP, default ≤ BP+PP.
    let mut rng = Pcg64::seed_from_u64(505);
    let cfg = GhostConfig::paper_optimal();
    for case in 0..8 {
        let spec = DatasetSpec {
            name: "prop",
            avg_nodes: rng.gen_range(50, 800),
            avg_edges: rng.gen_range(100, 3000),
            n_features: rng.gen_range(8, 256),
            n_labels: rng.gen_range(2, 8),
            n_graphs: 1,
            task: Task::NodeClassification,
            max_degree_cap: 64,
            seed: 9000 + case as u64,
            generator: GraphGen::Skewed,
        };
        let ds = Dataset::generate(spec);
        let run = |flags: OptFlags| {
            simulate_workload(ModelKind::Gcn, &ds, cfg, flags).unwrap().metrics.energy_j
        };
        let base = run(OptFlags::baseline());
        let bp = run(OptFlags { buffer_partition: true, ..OptFlags::baseline() });
        let bp_pp = run(OptFlags {
            buffer_partition: true,
            pipelining: true,
            ..OptFlags::baseline()
        });
        let full = run(OptFlags::ghost_default());
        assert!(bp <= base * 1.001, "BP regressed: {bp} vs {base} (case {case})");
        assert!(bp_pp <= bp * 1.001, "PP regressed: {bp_pp} vs {bp} (case {case})");
        assert!(full <= bp_pp * 1.001, "DAC regressed: {full} vs {bp_pp} (case {case})");
    }
}

#[test]
fn prop_metrics_scale_with_workload() {
    // A strictly larger graph (same shape) must not be faster or cheaper.
    let mut rng = Pcg64::seed_from_u64(606);
    let cfg = GhostConfig::paper_optimal();
    for case in 0..6 {
        let base_nodes = rng.gen_range(100, 500);
        let mk = |scale: usize, seed: u64| {
            Dataset::generate(DatasetSpec {
                name: "scale",
                avg_nodes: base_nodes * scale,
                avg_edges: base_nodes * scale * 4,
                n_features: 64,
                n_labels: 4,
                n_graphs: 1,
                task: Task::NodeClassification,
                max_degree_cap: 32,
                seed,
                generator: GraphGen::Skewed,
            })
        };
        let small = mk(1, 7000 + case);
        let big = mk(3, 7000 + case);
        let f = OptFlags::ghost_default();
        let rs = simulate_workload(ModelKind::Gcn, &small, cfg, f).unwrap();
        let rb = simulate_workload(ModelKind::Gcn, &big, cfg, f).unwrap();
        assert!(rb.metrics.latency_s > rs.metrics.latency_s);
        assert!(rb.metrics.energy_j > rs.metrics.energy_j);
        assert!(rb.metrics.ops > rs.metrics.ops);
    }
}

#[test]
fn prop_generated_graphs_respect_spec() {
    let mut rng = Pcg64::seed_from_u64(707);
    for _ in 0..CASES {
        let n = rng.gen_range(2, 500);
        let e = rng.gen_range(1, 3 * n);
        let cap = rng.gen_range(1, 40);
        let g = generate_skewed_graph(n, e, cap, &mut rng);
        assert_eq!(g.n_vertices, n);
        // The generator clamps infeasible requests to the cap capacity.
        assert_eq!(g.n_edges(), e.min(n * cap));
        assert!(g.max_degree() <= cap);
        // No self loops.
        for v in 0..n {
            assert!(!g.neighbors(v).contains(&(v as u32)), "self loop at {v}");
        }
    }
}

#[test]
fn prop_shard_plan_partitions_groups_and_conserves_traffic() {
    // Over seeded R-MAT graphs: the shard assignment is an exact partition
    // of output-group space, per-chip footprints are additive slices of
    // the whole-graph footprint, the exchange matrix has a zero diagonal,
    // and cross-shard + shard-local edges conserve the graph's edge count.
    let mut rng = Pcg64::seed_from_u64(1111);
    for _ in 0..CASES {
        let n_v = rng.gen_range(2, 600);
        let e = rng.gen_range(1, 4 * n_v);
        let g = generate_rmat_graph(n_v, e, rng.gen_range(2, 48), &mut rng);
        let pm = PartitionMatrix::build(&g, rng.gen_range(1, 30), rng.gen_range(1, 30));
        let shards = rng.gen_range(1, 9);
        let feat = rng.gen_range(1, 512);
        let sp = ShardPlan::build(std::slice::from_ref(&pm), shards, feat);

        let mut covered = 0usize;
        let mut fp_sum = 0u64;
        let mut local_edges = 0u64;
        for s in 0..shards {
            let r = sp.group_range(0, s);
            assert_eq!(r.start, covered, "shard ranges must be contiguous");
            covered = r.end;
            let fp = pm.group_range_footprint_bytes(r.clone(), feat);
            fp_sum += fp;
            // Single-graph dataset: the chip footprint is its range's.
            assert_eq!(sp.chip_footprints()[s], fp);
            for og in r.clone() {
                assert_eq!(sp.shard_of_group(0, og), s, "range/ownership disagree");
                for b in pm.group_blocks(og) {
                    if sp.owner_of_input_group(0, &pm, b.input_group as usize) == s {
                        local_edges += b.n_edges as u64;
                    }
                }
            }
        }
        assert_eq!(covered, pm.n_output_groups(), "shards must cover every group");
        assert_eq!(fp_sum, pm.footprint_bytes(feat), "footprint additivity");
        for s in 0..shards {
            assert_eq!(sp.exchange_edges(0, s, s), 0, "diagonal exchange must be 0");
        }
        assert_eq!(
            sp.cross_shard_edges(0) + local_edges,
            pm.total_edges(),
            "cross-shard + local edges must conserve the edge count"
        );
        if shards == 1 {
            assert_eq!(sp.total_cross_shard_edges(), 0);
        }
        // The budget predicate is exact at the max chip footprint.
        let max = sp.max_chip_footprint_bytes();
        assert!(sp.fits_budget(max));
        if max > 0 {
            assert!(!sp.fits_budget(max - 1));
        }
    }
}

#[test]
fn prop_sharded_plan_remote_gather_traffic_matches_cross_shard_edges() {
    // The sharded plan's RemoteGather stages carry exactly the halo
    // traffic the shard assignment implies: one exchange of every
    // cross-shard edge per exchanging layer, with one stage per
    // (chip, exchanging layer, graph, remote source) pair that has
    // traffic. Evaluation charges the link iff there is traffic.
    let mut rng = Pcg64::seed_from_u64(1212);
    let cfg = GhostConfig::paper_optimal();
    let flags = OptFlags::ghost_default();
    for case in 0..8 {
        let ds = Dataset::generate(DatasetSpec {
            name: "shardprop",
            avg_nodes: rng.gen_range(100, 900),
            avg_edges: rng.gen_range(200, 4000),
            n_features: rng.gen_range(8, 128),
            n_labels: rng.gen_range(2, 8),
            n_graphs: 1 + (case % 3) as usize,
            task: Task::NodeClassification,
            max_degree_cap: 64,
            seed: 11_000 + case,
            generator: GraphGen::RMat,
        });
        let partitions: Vec<PartitionMatrix> =
            ds.graphs.iter().map(|g| PartitionMatrix::build(g, cfg.v, cfg.n)).collect();
        for kind in [ModelKind::Gcn, ModelKind::Gat] {
            let shards = rng.gen_range(2, 7);
            let plan = build_sharded(kind, &ds, &partitions, cfg, flags, shards)
                .expect("small random dataset fits the paper budget");
            assert_eq!(
                plan.remote_gather_edges,
                plan.exchange_layers as u64 * plan.shard_plan.total_cross_shard_edges(),
                "remote gather traffic != exchange layers x cross-shard edges"
            );
            let expected_stages: usize = plan.exchange_layers
                * (0..ds.graphs.len())
                    .map(|gi| {
                        let mut pairs = 0;
                        for dst in 0..shards {
                            for src in 0..shards {
                                if dst != src
                                    && plan.shard_plan.exchange_edges(gi, dst, src) > 0
                                {
                                    pairs += 1;
                                }
                            }
                        }
                        pairs
                    })
                    .sum::<usize>();
            assert_eq!(plan.n_remote_gathers(), expected_stages);
            let r = evaluate_sharded(&plan).expect("sharded evaluation");
            assert_eq!(
                r.kinds.remote_gather.latency_s > 0.0,
                plan.remote_gather_edges > 0,
                "link busy time iff there is halo traffic"
            );
            assert!(plan.shard_plan.fits_budget(cfg.chip_mem_bytes));
        }
    }
}

#[test]
fn prop_rmat_graphs_respect_spec() {
    // Same contract as the skewed generator: exact clamped edge counts,
    // cap respected, no self loops — for the large-graph tier's R-MAT.
    let mut rng = Pcg64::seed_from_u64(808);
    for _ in 0..CASES {
        let n = rng.gen_range(2, 500);
        let e = rng.gen_range(1, 3 * n);
        let cap = rng.gen_range(1, 40);
        let g = generate_rmat_graph(n, e, cap, &mut rng);
        assert_eq!(g.n_vertices, n);
        assert_eq!(g.n_edges(), e.min(n * cap));
        assert!(g.max_degree() <= cap);
        for v in 0..n {
            assert!(!g.neighbors(v).contains(&(v as u32)), "self loop at {v}");
        }
    }
}
