//! Smoke tests for every table/figure regeneration path (the same code the
//! CLI and benches run), plus the device-level DSE anchors.

use ghost::config::GhostConfig;
use ghost::figures;
use ghost::photonics::devices::DeviceParams;
use ghost::photonics::dse;

#[test]
fn table1_prints_paper_rows() {
    let rows = figures::table1();
    assert_eq!(rows.len(), 7);
    let eo = &rows[0];
    assert_eq!(eo.0, "EO Tuning");
    assert_eq!(eo.1, 20e-9);
}

#[test]
fn table2_has_all_eight_datasets() {
    let rows = figures::table2().unwrap();
    assert_eq!(rows.len(), 8);
    let cora = rows.iter().find(|r| r.name == "Cora").unwrap();
    assert_eq!(cora.avg_nodes as usize, 2708);
    assert_eq!(cora.avg_edges as usize, 10_556);
}

#[test]
fn fig7a_anchor_20_mrs_at_1520nm() {
    let p = DeviceParams::paper();
    assert_eq!(dse::max_feasible_coherent(&p, 1520.0, 40), 20);
    // And the wavelength trend of the paper's surface plot.
    assert!(dse::max_feasible_coherent(&p, 1570.0, 40) < 20);
}

#[test]
fn fig7b_anchor_18_wavelengths() {
    assert_eq!(dse::max_feasible_noncoherent(30), 18);
    let pts = dse::noncoherent_sweep(30);
    // Feasibility is monotone: once infeasible, stays infeasible.
    let mut seen_infeasible = false;
    for p in pts {
        if !p.feasible {
            seen_infeasible = true;
        } else {
            assert!(!seen_infeasible, "feasibility must be a prefix");
        }
    }
}

#[test]
fn fig8_rows_complete() {
    let rows = figures::fig8(GhostConfig::paper_optimal()).unwrap();
    assert_eq!(rows.len(), 9);
    for r in &rows {
        assert_eq!(r.per_workload.len(), 16, "{}", r.label);
        assert!(r.mean.is_finite() && r.mean > 0.0);
    }
}

#[test]
fn fig9_rows_complete() {
    let rows = figures::fig9(GhostConfig::paper_optimal()).unwrap();
    assert_eq!(rows.len(), 16);
}

#[test]
fn fig9_kind_breakdown_sums_to_total_busy_time() {
    // The exact per-StageKind totals must conserve the report's block
    // accumulators: summing the seven kinds recovers total busy time.
    // (This is the same invariant the CI `ghost figures --fig9 --json`
    // smoke asserts on the serialized output.)
    let rows = figures::fig9(GhostConfig::paper_optimal()).unwrap();
    for r in &rows {
        let sum: f64 = r.kinds.rows().iter().map(|(_, c)| c.latency_s).sum();
        assert!(
            (sum - r.total_busy_s).abs() <= 1e-9 * r.total_busy_s.max(1e-30),
            "{}/{}: per-kind sum {sum} vs total busy {}",
            r.model,
            r.dataset,
            r.total_busy_s
        );
        // Readout and weight staging are first-class entries, not folded
        // into the aggregate bar.
        assert!(r.kinds.weight_stage.latency_s > 0.0, "{}/{}", r.model, r.dataset);
        if r.model == "GIN" {
            assert!(r.kinds.readout.latency_s > 0.0, "{}", r.dataset);
        } else {
            assert_eq!(r.kinds.readout.latency_s, 0.0, "{}/{}", r.model, r.dataset);
        }
        assert!(r.kinds.energy_j() > 0.0);
    }
}

#[test]
fn fig9_json_carries_per_kind_breakdown() {
    let json = figures::fig9_json(GhostConfig::paper_optimal()).unwrap();
    let rows = json.as_array().unwrap();
    assert_eq!(rows.len(), 16);
    for r in rows {
        let total = r.get("total_busy_s").unwrap().as_f64().unwrap();
        let kinds = r.get("kinds").unwrap().as_object().unwrap();
        assert_eq!(kinds.len(), 7, "seven stage kinds serialized");
        let sum: f64 = kinds
            .values()
            .map(|k| k.get("busy_s").unwrap().as_f64().unwrap())
            .sum();
        assert!(
            (sum - total).abs() <= 1e-9 * total.max(1e-30),
            "serialized kinds sum {sum} vs total_busy_s {total}"
        );
        let expected_kinds = [
            "gather", "reduce", "transform", "update", "readout", "weight_stage", "edge_stream",
        ];
        for key in expected_kinds {
            assert!(kinds.contains_key(key), "missing kind {key}");
        }
    }
}

#[test]
fn comparison_covers_supported_workloads() {
    let rows = figures::comparison_summary(GhostConfig::paper_optimal()).unwrap();
    assert_eq!(rows.len(), 9);
    let n: std::collections::HashMap<&str, usize> =
        rows.iter().map(|r| (r.platform, r.n_workloads)).collect();
    // Support matrix from §4.6: GRIP/HyGCN 12, EnG/ReGNN/ReGraphX 8,
    // HW_ACC 8, commodity 16.
    assert_eq!(n["GRIP"], 12);
    assert_eq!(n["HyGCN"], 12);
    assert_eq!(n["EnG"], 8);
    assert_eq!(n["HW_ACC"], 8);
    assert_eq!(n["ReGNN"], 8);
    assert_eq!(n["ReGraphX"], 8);
    assert_eq!(n["TPU"], 16);
    assert_eq!(n["CPU"], 16);
    assert_eq!(n["GPU"], 16);
}
