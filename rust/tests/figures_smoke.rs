//! Smoke tests for every table/figure regeneration path (the same code the
//! CLI and benches run), plus the device-level DSE anchors.

use ghost::config::GhostConfig;
use ghost::figures;
use ghost::photonics::devices::DeviceParams;
use ghost::photonics::dse;

#[test]
fn table1_prints_paper_rows() {
    let rows = figures::table1();
    assert_eq!(rows.len(), 7);
    let eo = &rows[0];
    assert_eq!(eo.0, "EO Tuning");
    assert_eq!(eo.1, 20e-9);
}

#[test]
fn table2_has_all_eight_datasets() {
    let rows = figures::table2();
    assert_eq!(rows.len(), 8);
    let cora = rows.iter().find(|r| r.name == "Cora").unwrap();
    assert_eq!(cora.avg_nodes as usize, 2708);
    assert_eq!(cora.avg_edges as usize, 10_556);
}

#[test]
fn fig7a_anchor_20_mrs_at_1520nm() {
    let p = DeviceParams::paper();
    assert_eq!(dse::max_feasible_coherent(&p, 1520.0, 40), 20);
    // And the wavelength trend of the paper's surface plot.
    assert!(dse::max_feasible_coherent(&p, 1570.0, 40) < 20);
}

#[test]
fn fig7b_anchor_18_wavelengths() {
    assert_eq!(dse::max_feasible_noncoherent(30), 18);
    let pts = dse::noncoherent_sweep(30);
    // Feasibility is monotone: once infeasible, stays infeasible.
    let mut seen_infeasible = false;
    for p in pts {
        if !p.feasible {
            seen_infeasible = true;
        } else {
            assert!(!seen_infeasible, "feasibility must be a prefix");
        }
    }
}

#[test]
fn fig8_rows_complete() {
    let rows = figures::fig8(GhostConfig::paper_optimal());
    assert_eq!(rows.len(), 9);
    for r in &rows {
        assert_eq!(r.per_workload.len(), 16, "{}", r.label);
        assert!(r.mean.is_finite() && r.mean > 0.0);
    }
}

#[test]
fn fig9_rows_complete() {
    let rows = figures::fig9(GhostConfig::paper_optimal());
    assert_eq!(rows.len(), 16);
}

#[test]
fn comparison_covers_supported_workloads() {
    let rows = figures::comparison_summary(GhostConfig::paper_optimal());
    assert_eq!(rows.len(), 9);
    let n: std::collections::HashMap<&str, usize> =
        rows.iter().map(|r| (r.platform, r.n_workloads)).collect();
    // Support matrix from §4.6: GRIP/HyGCN 12, EnG/ReGNN/ReGraphX 8,
    // HW_ACC 8, commodity 16.
    assert_eq!(n["GRIP"], 12);
    assert_eq!(n["HyGCN"], 12);
    assert_eq!(n["EnG"], 8);
    assert_eq!(n["HW_ACC"], 8);
    assert_eq!(n["ReGNN"], 8);
    assert_eq!(n["ReGraphX"], 8);
    assert_eq!(n["TPU"], 16);
    assert_eq!(n["CPU"], 16);
    assert_eq!(n["GPU"], 16);
}
