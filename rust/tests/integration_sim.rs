//! Integration tests over the full simulator stack: device models →
//! partition → arch blocks → pipeline schedule → metrics, checked against
//! the paper's qualitative claims.

use ghost::config::GhostConfig;
use ghost::coordinator::{simulate, OptFlags};
use ghost::energy::geomean;
use ghost::figures;
use ghost::gnn::models::ModelKind;

fn ghost_cfg() -> GhostConfig {
    GhostConfig::paper_optimal()
}

#[test]
fn fig8_ghost_default_reduction_near_paper() {
    // Paper §4.4: BP+PP+DAC sharing reduces energy ~4.94× vs baseline.
    let rows = figures::fig8(ghost_cfg()).unwrap();
    let default_row = rows.iter().find(|r| r.label == "BP+PP+DAC_Sharing").unwrap();
    let reduction = 1.0 / default_row.mean;
    assert!(
        reduction > 3.0 && reduction < 10.0,
        "BP+PP+DAC reduction {reduction} outside the paper's ~4.94x band"
    );
}

#[test]
fn fig8_wb_weaker_than_dac_sharing() {
    // Paper §4.4: BP+PP+WB (2.92×) is weaker than BP+PP+DAC (4.94×).
    let rows = figures::fig8(ghost_cfg()).unwrap();
    let dac = rows.iter().find(|r| r.label == "BP+PP+DAC_Sharing").unwrap().mean;
    let wb = rows.iter().find(|r| r.label == "BP+PP+WB").unwrap().mean;
    assert!(dac < wb, "DAC-sharing combo must beat the WB combo (dac={dac}, wb={wb})");
}

#[test]
fn fig8_every_optimization_helps() {
    let rows = figures::fig8(ghost_cfg()).unwrap();
    for r in &rows {
        assert!(
            r.mean <= 1.0 + 1e-9,
            "{} must not exceed baseline energy (mean {})",
            r.label,
            r.mean
        );
    }
    // The full-combo row is the global best.
    let best = rows.iter().map(|r| r.mean).fold(f64::INFINITY, f64::min);
    let dac = rows.iter().find(|r| r.label == "BP+PP+DAC_Sharing").unwrap().mean;
    assert!((dac - best).abs() < 1e-12, "BP+PP+DAC must be the best combo");
}

#[test]
fn fig9_breakdown_shapes() {
    let rows = figures::fig9(ghost_cfg()).unwrap();
    for r in &rows {
        let total = r.aggregate + r.combine + r.update;
        assert!((total - 1.0).abs() < 1e-9, "fractions must sum to 1, got {total}");
        match r.model.as_str() {
            // Paper §4.5: aggregate consumes more than half for GCN/GS.
            "GCN" | "GraphSAGE" => {
                assert!(r.aggregate > 0.5, "{}/{}: aggregate {}", r.model, r.dataset, r.aggregate)
            }
            // GIN bottleneck is the combine phase.
            "GIN" => assert!(
                r.combine > r.aggregate,
                "{}/{}: combine {} vs aggregate {}",
                r.model,
                r.dataset,
                r.combine,
                r.aggregate
            ),
            // GAT latency is attributed mainly to combine + update.
            "GAT" => assert!(
                r.combine + r.update > 0.4,
                "{}/{}: combine+update {}",
                r.model,
                r.dataset,
                r.combine + r.update
            ),
            _ => unreachable!(),
        }
    }
}

#[test]
fn comparison_ratios_match_paper_shape() {
    let rows = figures::comparison_summary(ghost_cfg()).unwrap();
    let get = |name: &str| rows.iter().find(|r| r.platform == name).unwrap();
    // Headline claim: ≥10.2× throughput vs the best competitor (HW_ACC)
    // and ≥3.8× energy efficiency vs the best (EnGN).
    for r in &rows {
        assert!(r.gops_ratio > 5.0, "{}: GOPS ratio {}", r.platform, r.gops_ratio);
        assert!(r.epb_ratio > 1.0, "{}: EPB ratio {}", r.platform, r.epb_ratio);
    }
    assert!(
        get("HW_ACC").gops_ratio < get("GRIP").gops_ratio,
        "HW_ACC must be the closest GNN accelerator in throughput"
    );
    assert!(
        get("EnG").epb_ratio < get("GRIP").epb_ratio,
        "EnGN must be the closest in energy efficiency"
    );
    // Commodity platforms (TPU/CPU/GPU) lose by orders of magnitude.
    for name in ["TPU", "CPU", "GPU"] {
        assert!(get(name).gops_ratio > 100.0, "{name}: {}", get(name).gops_ratio);
        assert!(get(name).epb_ratio > 1000.0, "{name}: {}", get(name).epb_ratio);
    }
}

#[test]
fn gin_shows_largest_gops_gains() {
    // Paper §4.6.1: the largest GOPS improvements are observed with the
    // GIN datasets (per-graph overheads dominate the baselines).
    let detail = figures::comparison_detail(ghost_cfg()).unwrap();
    let mut gin_ratios = Vec::new();
    let mut other_ratios = Vec::new();
    for (kind, _, ghost_metrics, rows) in &detail {
        for (_, m) in rows {
            let ratio = ghost_metrics.gops() / m.gops();
            if *kind == ModelKind::Gin {
                gin_ratios.push(ratio);
            } else {
                other_ratios.push(ratio);
            }
        }
    }
    let gin = geomean(gin_ratios);
    let other = geomean(other_ratios);
    assert!(gin > other, "GIN geomean {gin} must exceed non-GIN {other}");
}

#[test]
fn platform_power_is_about_18w() {
    // §4.6.2 quotes GHOST's power as 18 W.
    let r = simulate(ModelKind::Gcn, "Cora", ghost_cfg(), OptFlags::ghost_default()).unwrap();
    assert!((r.platform_w - 18.0).abs() < 3.0, "platform power {}", r.platform_w);
    assert!(r.metrics.power_w() < 40.0, "total power {}", r.metrics.power_w());
}

#[test]
fn sweeping_v_trades_power_for_latency() {
    let small = GhostConfig { v: 10, ..ghost_cfg() };
    let big = GhostConfig { v: 30, ..ghost_cfg() };
    let flags = OptFlags::ghost_default();
    let rs = simulate(ModelKind::Gcn, "Citeseer", small, flags).unwrap();
    let rb = simulate(ModelKind::Gcn, "Citeseer", big, flags).unwrap();
    assert!(rb.metrics.latency_s < rs.metrics.latency_s, "more lanes must be faster");
    assert!(rb.platform_w > rs.platform_w, "more lanes must draw more power");
}

#[test]
fn invalid_configs_rejected() {
    let flags = OptFlags::ghost_default();
    let bad = GhostConfig { r_c: 25, ..ghost_cfg() }; // > 20 coherent MRs
    assert!(simulate(ModelKind::Gcn, "Cora", bad, flags).is_err());
    let bad_flags = OptFlags { workload_balancing: true, ..OptFlags::ghost_default() };
    assert!(simulate(ModelKind::Gcn, "Cora", ghost_cfg(), bad_flags).is_err());
    assert!(simulate(ModelKind::Gcn, "NoSuchDataset", ghost_cfg(), flags).is_err());
}
