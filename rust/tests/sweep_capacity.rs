//! Integration tests for the parallel scenario-sweep executor, the
//! capacity planner, and the serve event-loop fast path.
//!
//! The pins here are the PR's contracts: sweep results are bit-identical
//! for any worker count, the whole sweep performs one plan/profile build
//! per distinct tenant tuple, the fast event loop reproduces the retained
//! baseline loop bit for bit across the routing × batching × traffic
//! matrix, capacity curves are monotone with flat cache counters after
//! round 1, and churn setup shares engine state instead of cloning it.

use ghost::coordinator::{BatchEngine, OptFlags, SimError};
use ghost::gnn::models::ModelKind;
use ghost::serve::{
    self, plan_capacity, reference::simulate_fleet_reference, simulate_with_profiles,
    sweep_with_workers, ArrivalProcess, BatchPolicy, CapacityPlanRequest, ChurnSpec,
    RoutePolicy, ServeConfig, TenantMix, TenantProfile, TrafficSpec,
};

fn two_tenant_mix() -> TenantMix {
    TenantMix::new(vec![
        TenantProfile::new(ModelKind::Gcn, "Cora", 2.0),
        TenantProfile::new(ModelKind::Gat, "Citeseer", 1.0),
    ])
    .unwrap()
}

fn open(rps: f64) -> TrafficSpec {
    TrafficSpec::Open { process: ArrivalProcess::Poisson, rps }
}

/// A small scenario family varying fleet shape, batching, and rate.
fn scenario_family() -> Vec<ServeConfig> {
    let mut out = Vec::new();
    for &(accels, rps) in &[(1usize, 500.0), (2, 1500.0), (4, 3000.0), (4, 6000.0)] {
        let mut cfg = ServeConfig::new(two_tenant_mix(), open(rps));
        cfg.accelerators = accels;
        cfg.duration_s = 0.2;
        cfg.batch = BatchPolicy::MaxBatchOrWait { max_batch: 4, max_wait_s: 5e-4 };
        out.push(cfg);
    }
    out
}

#[test]
fn sweep_reports_bit_identical_across_worker_counts() {
    let engine = BatchEngine::new();
    let scenarios = scenario_family();
    let base: Vec<_> = sweep_with_workers(&engine, &scenarios, 1)
        .into_iter()
        .map(|r| r.expect("probe runs"))
        .collect();
    // One build per tenant for the whole 4-scenario sweep, already after
    // the serial pass…
    assert_eq!(engine.profile_builds(), 2);
    assert_eq!(engine.plan_builds(), 2);
    for workers in [2, 4, 16] {
        let got: Vec<_> = sweep_with_workers(&engine, &scenarios, workers)
            .into_iter()
            .map(|r| r.expect("probe runs"))
            .collect();
        assert_eq!(base, got, "sweep reports diverged at {workers} workers");
    }
    // …and every parallel re-sweep was pure cache hits.
    assert_eq!(engine.profile_builds(), 2);
    assert_eq!(engine.plan_builds(), 2);
}

#[test]
fn sweep_probe_errors_stay_per_probe() {
    let engine = BatchEngine::new();
    let mut scenarios = scenario_family();
    scenarios[1].accelerators = 0; // invalid — must not poison siblings
    let results = sweep_with_workers(&engine, &scenarios, 2);
    assert!(matches!(results[1], Err(SimError::InvalidConfig(_))));
    for (i, r) in results.iter().enumerate() {
        if i != 1 {
            assert!(r.is_ok(), "valid probe {i} failed: {r:?}");
        }
    }
}

#[test]
fn fast_loop_matches_reference_loop_across_configs() {
    let engine = BatchEngine::new();
    let mix = two_tenant_mix();
    let base = ServeConfig::new(mix, open(2000.0));
    let profiles: Vec<_> = base
        .tenant_requests()
        .iter()
        .map(|req| engine.service_profile(req).expect("tenant simulates"))
        .collect();
    let routes = [
        RoutePolicy::RoundRobin,
        RoutePolicy::JoinShortestQueue,
        RoutePolicy::GraphAffinity,
    ];
    let batches = [
        BatchPolicy::Immediate,
        BatchPolicy::MaxBatchOrWait { max_batch: 4, max_wait_s: 5e-4 },
        BatchPolicy::SloAware { slo_s: 2e-3, max_batch: 8 },
    ];
    let traffics = [
        open(2000.0),
        TrafficSpec::Closed { clients: 8, mean_think_s: 1e-3 },
    ];
    for route in routes {
        for batch in batches {
            for traffic in traffics.iter().cloned() {
                let mut cfg = base.clone();
                cfg.route = route;
                cfg.batch = batch;
                cfg.traffic = traffic;
                cfg.accelerators = 3;
                cfg.duration_s = 0.2;
                cfg.slo_s = Some(2e-3);
                let fast = simulate_with_profiles(&cfg, &profiles).expect("fast loop runs");
                let reference =
                    simulate_fleet_reference(&cfg, &profiles).expect("reference loop runs");
                assert_eq!(
                    fast, reference,
                    "fast loop diverged from baseline at route {:?} batch {:?} traffic {:?}",
                    route, cfg.batch, cfg.traffic
                );
                assert_eq!(fast.offered, fast.completed, "fleet must drain");
            }
        }
    }
}

#[test]
fn capacity_curve_is_monotone_with_flat_builds_after_round_one() {
    let mut base = ServeConfig::new(
        TenantMix::new(vec![TenantProfile::new(ModelKind::Gcn, "Cora", 1.0)]).unwrap(),
        open(1000.0),
    );
    base.duration_s = 0.25;
    let engine = BatchEngine::new();
    let req = CapacityPlanRequest {
        base,
        rps_points: vec![500.0, 5000.0, 20_000.0],
        slo_p99_s: 2e-3,
        max_accelerators: 8,
        workers: 2,
    };
    let curve = plan_capacity(&engine, &req).expect("capacity plan runs");
    assert_eq!(curve.points.len(), 3);

    // ROADMAP acceptance: every cache build happens in the round-1 screen;
    // the bisection rounds after it are pure hits.
    assert_eq!(curve.plan_builds_round1, curve.plan_builds_final, "plan builds not flat");
    assert_eq!(
        curve.profile_builds_round1, curve.profile_builds_final,
        "profile builds not flat"
    );
    assert_eq!(curve.profile_builds_final, 1, "one tenant, one profile build");

    // Minimum fleet is non-decreasing in the offered rate (None = not met
    // at the ceiling, which only ever gets worse as rps grows).
    let mins: Vec<Option<usize>> = curve.points.iter().map(|p| p.min_accelerators).collect();
    for w in mins.windows(2) {
        match (w[0], w[1]) {
            (Some(a), Some(b)) => assert!(a <= b, "min fleet decreased with rps: {mins:?}"),
            (None, Some(_)) => panic!("feasibility returned as rps grew: {mins:?}"),
            _ => {}
        }
    }
    // Per-point witnesses: the minimum meets the SLO, one group below
    // violates it, and infeasible points report the ceiling's p99.
    for p in &curve.points {
        match p.min_accelerators {
            Some(n) => {
                assert!(n >= curve.shards && n <= curve.max_accelerators);
                assert_eq!(n % curve.shards, 0, "fleet must be whole shard groups");
                assert!(p.p99_s <= curve.slo_p99_s, "reported minimum misses the SLO");
                if n > curve.shards {
                    let below = p.p99_below_s.expect("violation evidence for n > 1 group");
                    assert!(below > curve.slo_p99_s, "one group below must violate");
                } else {
                    assert!(p.p99_below_s.is_none());
                }
            }
            None => assert!(p.p99_s > curve.slo_p99_s, "unmet point must show a violation"),
        }
    }

    // Determinism: replaying the identical request on a fresh engine
    // reproduces the curve (counters included — same probe schedule).
    let replay = plan_capacity(&BatchEngine::new(), &req).expect("replay runs");
    assert_eq!(curve, replay, "capacity planning must be deterministic");
}

#[test]
fn capacity_bisection_agrees_with_linear_scan() {
    let mut base = ServeConfig::new(
        TenantMix::new(vec![TenantProfile::new(ModelKind::Gcn, "Cora", 1.0)]).unwrap(),
        open(1000.0),
    );
    base.duration_s = 0.2;
    let rps = 8000.0;
    let slo = 2e-3;
    let max = 6;
    let engine = BatchEngine::new();
    let req = CapacityPlanRequest {
        base: base.clone(),
        rps_points: vec![rps],
        slo_p99_s: slo,
        max_accelerators: max,
        workers: 1,
    };
    let curve = plan_capacity(&engine, &req).expect("capacity plan runs");

    // Brute force the same question one fleet size at a time.
    let p99_at = |n: usize| {
        let mut cfg = base.clone();
        cfg.accelerators = n;
        cfg.traffic = open(rps);
        serve::simulate(&engine, &cfg).expect("probe runs").latency.p99_s
    };
    let ok: Vec<bool> = (1..=max).map(|n| p99_at(n) <= slo).collect();
    // The planner's premise: feasibility is monotone in fleet size. Holds
    // for this workload; if it ever flips here, the workload (not the
    // bisection) changed.
    for w in ok.windows(2) {
        assert!(!(w[0] && !w[1]), "feasibility not monotone in fleet size: {ok:?}");
    }
    let linear_min = ok.iter().position(|&b| b).map(|i| i + 1);
    assert_eq!(
        curve.points[0].min_accelerators, linear_min,
        "bisection disagrees with the linear scan"
    );
}

#[test]
fn churn_setup_shares_engine_state_for_same_dataset_tenants() {
    // Two tenants over ONE dataset: fleet setup must reuse the engine's
    // dataset and partition set (one build each), not clone per tenant.
    let mix = TenantMix::new(vec![
        TenantProfile::new(ModelKind::Gcn, "Cora", 1.0),
        TenantProfile::new(ModelKind::Gat, "Cora", 1.0),
    ])
    .unwrap();
    let mut cfg = ServeConfig::new(mix, open(400.0));
    cfg.duration_s = 0.3;
    cfg.churn = Some(ChurnSpec::new(300.0));
    let engine = BatchEngine::new();
    let report = serve::simulate(&engine, &cfg).expect("churn serving runs");
    assert_eq!(engine.dataset_builds(), 1, "dataset built more than once");
    assert_eq!(engine.partition_builds(), 1, "partition set built more than once");
    let churn = report.churn.expect("churn stats present");
    assert!(churn.events > 0, "no mutation events over the horizon");
    // Both tenants share the dataset, so every event re-profiles both.
    assert_eq!(churn.reprofiles, 2 * churn.events);
    assert_eq!(report.offered, report.completed);
}

#[test]
fn serve_validation_yields_typed_errors() {
    let base = ServeConfig::new(two_tenant_mix(), open(1000.0));
    base.validate().unwrap();
    // Field problems are InvalidConfig…
    let mut c = base.clone();
    c.duration_s = f64::NAN;
    assert!(matches!(c.validate(), Err(SimError::InvalidConfig(_))));
    let mut c = base.clone();
    c.churn = Some(ChurnSpec { batch: 0, ..ChurnSpec::new(100.0) });
    assert!(matches!(c.validate(), Err(SimError::InvalidConfig(_))));
    // …and flag contradictions keep the engine's InvalidFlags shape.
    let mut c = base;
    c.flags = OptFlags {
        buffer_partition: false,
        pipelining: true,
        dac_sharing: false,
        workload_balancing: true,
    };
    assert!(matches!(c.validate(), Err(SimError::InvalidFlags(_))));
}
