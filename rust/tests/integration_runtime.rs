//! End-to-end functional-path tests: load the AOT artifacts produced by
//! `make artifacts` and execute real GNN inference through PJRT, checking
//! accuracy against the build-time (JAX) measurements.
//!
//! These tests are skipped (not failed) when `artifacts/` has not been
//! built, so `cargo test` works before the Python build step; CI runs
//! `make test` which builds artifacts first. The whole file is gated on
//! the `pjrt` cargo feature: the PJRT datapath needs the `xla` crate and a
//! local `xla_extension` install (see `rust/src/runtime/mod.rs`).

#![cfg(feature = "pjrt")]

use ghost::runtime::{argmax_rows, masked_accuracy, Engine};
use ghost::util::json::Json;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join(".stamp").exists().then_some(dir)
}

fn skip() {
    eprintln!("skipping: run `make artifacts` first");
}

#[test]
fn gcn_cora_end_to_end_accuracy() {
    let Some(dir) = artifacts_dir() else { return skip() };
    let engine = Engine::load(&dir, "gcn_cora").expect("load artifact");
    let outputs = engine.run().expect("execute");
    let logits = outputs[0].as_f32().unwrap();
    let shape = outputs[0].shape();
    assert_eq!(shape, &[2708, 7]);
    let labels = engine.extra("labels").unwrap();
    let mask = engine.extra("test_mask").unwrap();
    let pred = argmax_rows(logits, shape[0], shape[1]);
    let acc = masked_accuracy(&pred, labels.as_i32().unwrap(), Some(mask.as_i32().unwrap()));
    // Must match the python-side int8 accuracy recorded in the manifest.
    let expected = engine
        .manifest
        .meta
        .get("acc_int8")
        .and_then(Json::as_f64)
        .expect("manifest accuracy");
    assert!(
        (acc - expected).abs() < 0.02,
        "PJRT accuracy {acc} vs build-time measurement {expected}"
    );
    // And be far above chance (1/7) — the artifact really learned.
    assert!(acc > 0.5, "accuracy {acc}");
}

#[test]
fn gin_proteins_graph_classification() {
    let Some(dir) = artifacts_dir() else { return skip() };
    let engine = Engine::load(&dir, "gin_proteins").expect("load artifact");
    let outputs = engine.run().expect("execute");
    let logits = outputs[0].as_f32().unwrap();
    let shape = outputs[0].shape();
    assert_eq!(shape, &[1113, 2]);
    let labels = engine.extra("labels").unwrap();
    let mask = engine.extra("test_mask").unwrap();
    let pred = argmax_rows(logits, shape[0], shape[1]);
    let acc = masked_accuracy(&pred, labels.as_i32().unwrap(), Some(mask.as_i32().unwrap()));
    assert!(acc > 0.55, "graph-classification accuracy {acc} at chance level");
}

#[test]
fn gat_artifact_runs() {
    let Some(dir) = artifacts_dir() else { return skip() };
    let engine = Engine::load(&dir, "gat_citeseer").expect("load artifact");
    let outputs = engine.run().expect("execute");
    assert_eq!(outputs[0].shape(), &[3327, 6]);
}

#[test]
fn manifest_metadata_complete() {
    let Some(dir) = artifacts_dir() else { return skip() };
    for name in ["gcn_cora", "graphsage_pubmed", "gat_amazon", "gin_mutag"] {
        let engine = Engine::load(&dir, name).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert!(!engine.manifest.inputs.is_empty(), "{name}");
        assert!(engine.manifest.extras.contains_key("labels"), "{name}");
        assert_eq!(
            engine.manifest.meta.get("quantized").and_then(Json::as_bool),
            Some(true),
            "{name}: artifacts must be the int8 deployment configuration"
        );
    }
}

#[test]
fn repeated_execution_is_deterministic() {
    let Some(dir) = artifacts_dir() else { return skip() };
    let engine = Engine::load(&dir, "gcn_citeseer").expect("load artifact");
    let a = engine.run().expect("first run");
    let b = engine.run().expect("second run");
    assert_eq!(a[0].as_f32().unwrap(), b[0].as_f32().unwrap());
}
